"""Committed per-engine primitive-count budgets (DESIGN.md §2.9).

``baseline.json`` (next to this module) records, per canonical fold,
the total jaxpr equation count measured at commit time.  The
``jaxpr-budget`` rule compares fresh counts against it:

* fold missing from the baseline (new engine / new hook) — **error**:
  run ``python -m repro.analysis --baseline`` and commit the result;
* count grew beyond ``+10%`` — **error**: a compile-size regression
  (an accidental unroll, a lost fusion) fails CI loudly;
* count shrank below ``-10%`` — **info**: an improvement worth
  locking in with a baseline refresh;
* baseline entry with no live fold — **info**: stale entry.

The file also records the jax version it was measured under; on a
version mismatch budget *errors* downgrade to info, because primitive
counts legitimately move across jax releases — refresh the baseline
instead of chasing phantom regressions.
"""

from __future__ import annotations

import json
import math
from pathlib import Path

from repro.analysis.findings import Finding
from repro.analysis.jaxprs import EngineFold

DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")

#: relative growth/shrink tolerance before the budget rule fires
BUDGET_TOLERANCE = 0.10


def save_baseline(folds: list[EngineFold],
                  path: Path = DEFAULT_BASELINE) -> dict:
    import jax

    doc = {
        "jax": jax.__version__,
        "budgets": {f.key: f.n_primitives for f in folds if not f.host},
        "host_engines": sorted(f.engine for f in folds if f.host),
    }
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    return doc


def load_baseline(path: Path = DEFAULT_BASELINE) -> dict | None:
    if not path.exists():
        return None
    return json.loads(path.read_text())


def check_budgets(folds: list[EngineFold],
                  baseline: dict | None) -> list[Finding]:
    import jax

    if baseline is None:
        return [Finding(
            rule="jaxpr-budget", path=str(DEFAULT_BASELINE), line=0,
            message="no committed baseline; run `python -m "
                    "repro.analysis --baseline` and commit the result")]

    findings: list[Finding] = []
    jax_matches = baseline.get("jax") == jax.__version__
    severity = "error" if jax_matches else "info"
    if not jax_matches:
        findings.append(Finding(
            rule="jaxpr-budget", path="baseline.json", line=0,
            severity="info",
            message=f"baseline measured under jax {baseline.get('jax')}, "
                    f"running {jax.__version__}: budget regressions "
                    "downgraded to info — refresh the baseline"))

    budgets = dict(baseline.get("budgets", {}))
    hosts = set(baseline.get("host_engines", []))
    for fold in folds:
        if fold.host:
            if fold.engine not in hosts:
                findings.append(Finding(
                    rule="jaxpr-budget", path=fold.key, line=0,
                    severity=severity,
                    message=f"host engine {fold.engine!r} not recorded "
                            "in baseline (run --baseline)"))
            continue
        budget = budgets.pop(fold.key, None)
        if budget is None:
            findings.append(Finding(
                rule="jaxpr-budget", path=fold.key, line=0,
                severity=severity,
                message=f"fold not in baseline ({fold.n_primitives} "
                        "primitives measured); run --baseline"))
            continue
        hi = math.ceil(budget * (1 + BUDGET_TOLERANCE))
        lo = math.floor(budget * (1 - BUDGET_TOLERANCE))
        if fold.n_primitives > hi:
            findings.append(Finding(
                rule="jaxpr-budget", path=fold.key, line=0,
                severity=severity,
                message=f"primitive count {fold.n_primitives} exceeds "
                        f"budget {budget} (+{BUDGET_TOLERANCE:.0%} = "
                        f"{hi}): compile-size regression"))
        elif fold.n_primitives < lo:
            findings.append(Finding(
                rule="jaxpr-budget", path=fold.key, line=0,
                severity="info",
                message=f"primitive count {fold.n_primitives} is below "
                        f"budget {budget} (-{BUDGET_TOLERANCE:.0%}): "
                        "improvement — refresh the baseline to lock in"))
    for key in sorted(budgets):
        findings.append(Finding(
            rule="jaxpr-budget", path=key, line=0, severity="info",
            message="baseline entry has no live fold (stale); "
                    "run --baseline"))
    return findings
