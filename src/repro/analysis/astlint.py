"""Layer 1: AST lints over the repro source tree (DESIGN.md §2.9).

A visitor-free rule engine: every rule is a plain function over one
parsed :class:`ModuleSource` yielding :class:`~repro.analysis.findings.
Finding`s, registered with :func:`rule`.  Rules share two pieces of
per-module machinery, both computed lazily and cached on the module:

* an **import map** — ``import numpy as np`` / ``from repro.core import
  sim as _sim`` / ``from repro.core.trace import simulate`` all resolve
  attribute chains back to fully-qualified names, so a rule matches
  ``_sim.ssd_bandwidth_mb_s(...)`` no matter how the module spelled the
  import (this is what the old ``grep 'engine =='`` convention could
  never do);
* the **fold-body set** — every function or lambda passed as the body
  of ``jax.lax.scan`` / ``associative_scan`` / ``fori_loop`` /
  ``while_loop``, plus same-named local ``def``s (the
  ``_trace_step_fn`` factory pattern: the returned ``step`` is folded
  by reference).  Everything lexically inside a fold body is traced
  per-op under ``jit`` — the rules that police the determinism and
  host/device contracts apply there.

The rule catalog (ids are stable; DESIGN.md §2.9 documents each):

``rng-global``       global-state or unseeded RNG anywhere
``rng-in-fold``      RNG construction or wall-clock reads in fold bodies
``engine-dispatch``  string-compare engine dispatch outside the registry
``shim-internal``    internal calls to deprecated shim entry points
``host-in-fold``     ``float()`` / ``.item()`` / ``np.asarray`` on
                     in-fold values

Adding a rule is one function::

    @rule("my-rule", "one-line description")
    def _check_my_rule(mod: ModuleSource) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            ...
            yield mod.finding("my-rule", node, "message")
"""

from __future__ import annotations

import ast
import dataclasses
import functools
import typing
from pathlib import Path
from typing import Callable, Iterable, Iterator

from repro.analysis.findings import Finding

# ---------------------------------------------------------------------------
# Module model: parsed source + import resolution + fold-body detection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModuleSource:
    """One parsed source file presented to every rule."""

    path: Path           # absolute path on disk
    rel: str             # repo-relative display path (posix separators)
    tree: ast.Module

    #: repo-relative paths allowed to string-dispatch on engine names —
    #: exactly the registry module (DESIGN.md §2.5).
    DISPATCH_ALLOWED = ("src/repro/core/api.py",)

    @classmethod
    def parse(cls, path: Path, root: Path) -> "ModuleSource":
        text = path.read_text()
        rel = path.relative_to(root).as_posix() if path.is_relative_to(
            root) else path.as_posix()
        return cls(path=path, rel=rel, tree=ast.parse(text, str(path)))

    def finding(self, rule_id: str, node: ast.AST, message: str,
                severity: str = "error") -> Finding:
        return Finding(rule=rule_id, path=self.rel,
                       line=getattr(node, "lineno", 0), message=message,
                       severity=severity)

    # -- import resolution --------------------------------------------------

    @functools.cached_property
    def imports(self) -> dict[str, str]:
        """Local name -> fully-qualified name, for every import."""
        out: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and not node.level:
                for a in node.names:
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        return out

    def resolve(self, node: ast.AST) -> str | None:
        """Fully-qualified dotted name of an expression, or None when
        the head is not an imported name (a local variable, a call
        result, ...)."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.imports.get(node.id)
        if head is None:
            return None
        return ".".join([head] + parts[::-1])

    # -- fold-body detection ------------------------------------------------

    #: fully-qualified scan-like combinators -> positions of their body
    #: callables (kwarg names listed alongside)
    _SCAN_LIKE: typing.ClassVar[dict] = {
        "jax.lax.scan": ((0,), ("f",)),
        "jax.lax.associative_scan": ((0,), ("fn",)),
        "jax.lax.fori_loop": ((2,), ("body_fun",)),
        "jax.lax.while_loop": ((0, 1), ("cond_fun", "body_fun")),
    }

    @functools.cached_property
    def fold_bodies(self) -> list[ast.AST]:
        """Every FunctionDef/Lambda acting as a traced fold/step body."""
        marked: list[ast.AST] = []
        names: set[str] = set()
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            qual = self.resolve(node.func)
            spec = self._SCAN_LIKE.get(qual or "")
            if spec is None:
                continue
            pos, kws = spec
            cands = [node.args[i] for i in pos if i < len(node.args)]
            cands += [kw.value for kw in node.keywords if kw.arg in kws]
            for cand in cands:
                if isinstance(cand, ast.Lambda):
                    marked.append(cand)
                elif isinstance(cand, ast.Name):
                    names.add(cand.id)
        if names:
            for node in ast.walk(self.tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and node.name in names:
                    marked.append(node)
        return marked

    def walk_fold_bodies(self) -> Iterator[ast.AST]:
        """Every AST node lexically inside any fold body (deduplicated:
        a lambda inside a marked function is not yielded twice)."""
        seen: set[int] = set()
        for body in self.fold_bodies:
            for node in ast.walk(body):
                if id(node) not in seen:
                    seen.add(id(node))
                    yield node


def scan_paths(paths: Iterable[Path], root: Path) -> list[ModuleSource]:
    """Parse every ``.py`` file under ``paths`` (files or directories)."""
    mods = []
    for p in paths:
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            mods.append(ModuleSource.parse(f, root))
    return mods


# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------

RuleFn = Callable[[ModuleSource], Iterator[Finding]]
_RULES: dict[str, tuple[str, RuleFn]] = {}


def rule(rule_id: str, description: str):
    """Register an AST rule under a stable id (unique)."""

    def deco(fn: RuleFn) -> RuleFn:
        if rule_id in _RULES:
            raise ValueError(f"rule {rule_id!r} is already registered")
        _RULES[rule_id] = (description, fn)
        return fn

    return deco


def registered_rules() -> dict[str, str]:
    """rule id -> one-line description, sorted."""
    return {k: _RULES[k][0] for k in sorted(_RULES)}


def lint_module(mod: ModuleSource,
                only: set[str] | None = None) -> list[Finding]:
    out: list[Finding] = []
    for rule_id, (_, fn) in sorted(_RULES.items()):
        if only is None or rule_id in only:
            out.extend(fn(mod))
    return out


def lint_paths(paths: Iterable[Path], root: Path,
               only: set[str] | None = None
               ) -> tuple[list[Finding], int]:
    """(findings, number of files scanned) over every .py under paths."""
    mods = scan_paths(paths, root)
    out: list[Finding] = []
    for mod in mods:
        out.extend(lint_module(mod, only))
    return out, len(mods)


# ---------------------------------------------------------------------------
# RNG classification shared by the two RNG rules
# ---------------------------------------------------------------------------

#: numpy.random constructors that are fine *when seeded*
_NP_SEEDED_CTORS = frozenset({
    "default_rng", "Generator", "PCG64", "PCG64DXSM", "Philox", "MT19937",
    "SFC64", "SeedSequence", "RandomState", "BitGenerator",
})

#: stdlib ``random`` module-level functions (all share hidden global state)
_STDLIB_RANDOM_FNS = frozenset({
    "betavariate", "choice", "choices", "expovariate", "gauss",
    "getrandbits", "normalvariate", "paretovariate", "randbytes",
    "randint", "random", "randrange", "sample", "seed", "shuffle",
    "triangular", "uniform", "vonmisesvariate", "weibullvariate",
})

#: wall-clock reads (non-deterministic inputs a fold must never see)
_WALL_CLOCK = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.process_time_ns", "datetime.datetime.now",
    "datetime.datetime.utcnow", "datetime.date.today",
})


def _classify_rng_call(mod: ModuleSource,
                       call: ast.Call) -> tuple[str, str] | None:
    """("global" | "unseeded" | "seeded", description) for an RNG call,
    else None."""
    qual = mod.resolve(call.func)
    if qual is None:
        return None
    if qual.startswith("numpy.random."):
        tail = qual.rsplit(".", 1)[1]
        if tail in _NP_SEEDED_CTORS:
            if not call.args and not call.keywords:
                return "unseeded", f"{qual}() with no seed"
            return "seeded", qual
        return "global", f"{qual} (hidden global RNG state)"
    if qual.startswith("random.") \
            and qual.rsplit(".", 1)[1] in _STDLIB_RANDOM_FNS:
        return "global", f"{qual} (hidden global RNG state)"
    if qual in ("random.Random", "random.SystemRandom"):
        if not call.args and not call.keywords:
            return "unseeded", f"{qual}() with no seed"
        return "seeded", qual
    return None


# ---------------------------------------------------------------------------
# The rules
# ---------------------------------------------------------------------------


@rule("rng-global",
      "no global-state or unseeded RNG anywhere (determinism contract: "
      "every random draw flows from an explicit seed)")
def _rng_global(mod: ModuleSource) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        hit = _classify_rng_call(mod, node)
        if hit is None or hit[0] == "seeded":
            continue
        kind, desc = hit
        what = ("global-state RNG call"
                if kind == "global" else "unseeded RNG construction")
        yield mod.finding(
            "rng-global", node,
            f"{what}: {desc} — results would not be reproducible from "
            "a seed; construct a seeded np.random.Generator instead")


@rule("rng-in-fold",
      "no RNG construction or wall-clock reads inside fold/step bodies "
      "(sampling happens outside the fold; the fold stays pure)")
def _rng_in_fold(mod: ModuleSource) -> Iterator[Finding]:
    for node in mod.walk_fold_bodies():
        if not isinstance(node, ast.Call):
            continue
        qual = mod.resolve(node.func)
        if qual in _WALL_CLOCK:
            yield mod.finding(
                "rng-in-fold", node,
                f"wall-clock read {qual} inside a fold/step body — "
                "per-op times must be sampled outside the fold "
                "(DESIGN.md §2.8)")
            continue
        if _classify_rng_call(mod, node) is not None:
            yield mod.finding(
                "rng-in-fold", node,
                f"RNG use ({qual}) inside a fold/step body — engines "
                "must stay bit-deterministic given (trace, spec, seed); "
                "sample outside the fold and pass arrays in "
                "(DESIGN.md §2.8)")


_ENGINE_NAMES = frozenset({"engine", "engine_name"})
_STR_CMP_OPS = (ast.Eq, ast.NotEq, ast.In, ast.NotIn)


def _is_engine_expr(node: ast.AST) -> bool:
    return (isinstance(node, ast.Name) and node.id in _ENGINE_NAMES) or (
        isinstance(node, ast.Attribute) and node.attr in _ENGINE_NAMES)


def _has_str_constant(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return any(_has_str_constant(e) for e in node.elts)
    return False


@rule("engine-dispatch",
      "no string-compare engine dispatch outside the repro.core.api "
      "registry (capability rows, not ad-hoc name tests)")
def _engine_dispatch(mod: ModuleSource) -> Iterator[Finding]:
    if mod.rel in ModuleSource.DISPATCH_ALLOWED:
        return
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Compare):
            continue
        if not any(isinstance(op, _STR_CMP_OPS) for op in node.ops):
            continue
        sides = [node.left] + list(node.comparators)
        if any(_is_engine_expr(s) for s in sides) \
                and any(_has_str_constant(s) for s in sides):
            yield mod.finding(
                "engine-dispatch", node,
                "string comparison on an engine name outside the "
                "registry — dispatch through repro.core.api "
                "(get_engine / EngineCaps), which raises on unknown "
                "names and keeps capabilities declared in one place")


#: deprecated shim entry point -> its session-API replacement
DEPRECATED_SHIMS: dict[str, str] = {
    "repro.core.sim.channel_bandwidth_mb_s":
        "repro.api.steady_channel_bandwidth_mb_s",
    "repro.core.sim.ssd_bandwidth_mb_s": "repro.api.steady_bandwidth_mb_s",
    "repro.core.sim.sweep_bandwidth_mb_s":
        "repro.api.sweep_steady_bandwidth_mb_s",
    "repro.core.trace.simulate": "repro.api.Simulator.run",
    "repro.core.trace.simulate_batch": "repro.api.sweep_tables",
    "repro.core.trace.simulate_energy":
        "repro.api.Simulator.run(objective='energy')",
    "repro.core.trace.trace_bandwidth_mb_s":
        "repro.api.Simulator.run(objective='bandwidth')",
    "repro.core.trace.workload_trace":
        "repro.core.workload.build_workload",
}


@rule("shim-internal",
      "no internal calls to deprecated shim entry points (the static "
      "twin of the runtime DeprecationWarning-as-error filter)")
def _shim_internal(mod: ModuleSource) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        qual = mod.resolve(node.func)
        repl = DEPRECATED_SHIMS.get(qual or "")
        if repl is not None:
            yield mod.finding(
                "shim-internal", node,
                f"call to deprecated shim {qual} — internal code uses "
                f"the session API: {repl} (DESIGN.md §2.5)")


_HOST_ATTR_CALLS = frozenset({"item", "tolist", "block_until_ready"})
_HOST_NP_CALLS = frozenset({"numpy.asarray", "numpy.array",
                            "numpy.asanyarray", "numpy.ascontiguousarray"})


@rule("host-in-fold",
      "no float()/.item()/np.asarray on values inside jit-reachable "
      "fold/step bodies (host sync breaks tracing and fuses nothing)")
def _host_in_fold(mod: ModuleSource) -> Iterator[Finding]:
    for node in mod.walk_fold_bodies():
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Name) and node.func.id == "float" \
                and node.func.id not in mod.imports:
            yield mod.finding(
                "host-in-fold", node,
                "float() on an in-fold value — forces a host transfer "
                "under jit (TracerArrayConversionError) or silently "
                "constant-folds; keep the value a jax array")
            continue
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _HOST_ATTR_CALLS and not node.args:
            yield mod.finding(
                "host-in-fold", node,
                f".{node.func.attr}() on an in-fold value — host "
                "materialisation inside a traced fold body")
            continue
        qual = mod.resolve(node.func)
        if qual in _HOST_NP_CALLS:
            yield mod.finding(
                "host-in-fold", node,
                f"{qual} on an in-fold value — numpy conversion inside "
                "a traced fold body runs on host per trace, not per op; "
                "use jnp and keep the fold pure")
