"""Finding/report types shared by both analysis layers (DESIGN.md §2.9).

A :class:`Finding` is one violation of an engine contract: AST rules
emit them with a file/line anchor, jaxpr rules with the engine/fold
label in place of a path.  Severity is two-valued on purpose —
``error`` findings fail the CLI (and CI), ``info`` findings are
advisory (e.g. a primitive-count *improvement* that suggests a baseline
refresh) and never gate.
"""

from __future__ import annotations

import dataclasses
import json

SEVERITIES: tuple[str, ...] = ("error", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation (or advisory note)."""

    rule: str          # rule id, e.g. "rng-in-fold" / "jaxpr-dtype"
    path: str          # file path (AST layer) or engine/fold label
    line: int          # 1-based line (0 for non-source findings)
    message: str
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r} "
                             f"(one of {', '.join(SEVERITIES)})")

    @property
    def is_error(self) -> bool:
        return self.severity == "error"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.severity}: {self.message}"


def render_text(findings: list[Finding], *, n_files: int = 0,
                n_engines: int = 0) -> str:
    """Human report: findings sorted by location, then a one-line
    verdict (the line CI greps when the gate trips)."""
    lines = [f.format() for f in sorted(
        findings, key=lambda f: (f.path, f.line, f.rule))]
    errors = sum(f.is_error for f in findings)
    infos = len(findings) - errors
    lines.append(
        f"repro.analysis: {errors} error(s), {infos} info note(s) "
        f"across {n_files} file(s), {n_engines} engine fold(s)")
    return "\n".join(lines)


def render_json(findings: list[Finding], *, n_files: int = 0,
                n_engines: int = 0) -> str:
    return json.dumps({
        "errors": sum(f.is_error for f in findings),
        "infos": sum(not f.is_error for f in findings),
        "n_files": n_files,
        "n_engine_folds": n_engines,
        "findings": [f.to_dict() for f in findings],
    }, indent=2, sort_keys=True)
