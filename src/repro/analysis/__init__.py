"""Static contract checker for the repro engine stack (DESIGN.md §2.9).

Two layers: AST lints over the source tree (:mod:`repro.analysis.astlint`)
and jaxpr-level invariant checks over every registered engine's canonical
folds (:mod:`repro.analysis.jaxprs`), gated by the committed primitive
budgets of :mod:`repro.analysis.baseline`.  CLI: ``python -m
repro.analysis`` (:mod:`repro.analysis.cli`).
"""

from repro.analysis.findings import Finding, render_json, render_text

__all__ = ["Finding", "render_json", "render_text", "run_analysis"]


def run_analysis(*args, **kwargs):
    """Lazy re-export of :func:`repro.analysis.cli.run_analysis` (the
    CLI pulls in jax; keep package import light for the AST-only path)."""
    from repro.analysis.cli import run_analysis as _run

    return _run(*args, **kwargs)
