"""``python -m repro.analysis`` — the contract checker CLI.

Runs both layers (AST lints over the source tree, jaxpr invariant
checks over every registered engine), prints a text or JSON report,
and exits non-zero on any *error* finding.  ``--baseline`` re-measures
the per-engine primitive budgets and rewrites ``baseline.json``
instead of gating on it (commit the result).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import astlint, baseline as _baseline, jaxprs
from repro.analysis.findings import Finding, render_json, render_text

#: directories (relative to the repo root) the AST layer lints.  Tests
#: are deliberately excluded: fixtures *must* contain violations.
SCAN_ROOTS: tuple[str, ...] = ("src/repro", "benchmarks", "examples")


def repo_root() -> Path:
    """The checkout root: src/repro/analysis/cli.py -> three up."""
    return Path(__file__).resolve().parents[3]


def run_analysis(
        root: Path | None = None, *,
        run_ast: bool = True,
        run_jaxpr: bool = True,
        baseline_path: Path | None = None,
        update_baseline: bool = False,
) -> tuple[list[Finding], dict]:
    """Run the configured layers; return (findings, stats)."""
    root = Path(root) if root is not None else repo_root()
    baseline_path = baseline_path or _baseline.DEFAULT_BASELINE
    findings: list[Finding] = []
    stats = {"n_files": 0, "n_engine_folds": 0, "root": str(root)}

    if run_ast:
        paths = [root / sub for sub in SCAN_ROOTS if (root / sub).exists()]
        ast_findings, n_files = astlint.lint_paths(paths, root=root)
        findings += ast_findings
        stats["n_files"] = n_files

    if run_jaxpr:
        folds, jp_findings = jaxprs.collect_engine_folds()
        findings += jp_findings
        findings += jaxprs.check_padding_identity()
        stats["n_engine_folds"] = sum(1 for f in folds if not f.host)
        stats["engines"] = sorted({f.engine for f in folds})
        if update_baseline:
            doc = _baseline.save_baseline(folds, baseline_path)
            stats["baseline"] = {"path": str(baseline_path),
                                 "budgets": doc["budgets"]}
        else:
            findings += _baseline.check_budgets(
                folds, _baseline.load_baseline(baseline_path))
    return findings, stats


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lints + jaxpr invariant checks over the "
                    "repro engine contracts (DESIGN.md §2.9).")
    parser.add_argument("--check", action="store_true",
                        help="gate mode (the default behaviour; the "
                             "flag exists for CI readability)")
    parser.add_argument("--json", action="store_true",
                        help="machine-readable report on stdout")
    parser.add_argument("--baseline", action="store_true",
                        help="re-measure primitive budgets and rewrite "
                             "baseline.json instead of gating on it")
    parser.add_argument("--baseline-path", type=Path, default=None,
                        help="alternate baseline.json location")
    parser.add_argument("--root", type=Path, default=None,
                        help="repo root to lint (default: this checkout)")
    parser.add_argument("--no-ast", action="store_true",
                        help="skip the AST lint layer")
    parser.add_argument("--no-jaxpr", action="store_true",
                        help="skip the jaxpr trace layer")
    args = parser.parse_args(argv)

    findings, stats = run_analysis(
        args.root,
        run_ast=not args.no_ast,
        run_jaxpr=not args.no_jaxpr,
        baseline_path=args.baseline_path,
        update_baseline=args.baseline)

    render = render_json if args.json else render_text
    print(render(findings, n_files=stats["n_files"],
                 n_engines=stats["n_engine_folds"]))
    if args.baseline and not args.json:
        print(f"baseline written: "
              f"{stats.get('baseline', {}).get('path', '-')}")
    return 1 if any(f.is_error for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
