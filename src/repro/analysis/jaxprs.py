"""Layer 2: jaxpr-level invariant checks (DESIGN.md §2.9).

Every registered engine exposes ``canonical_folds`` — a hook returning
``{label: (fn, args)}`` closures over one canonical small request
(``repro.core.api._canonical_trace``: 48 mixed ops on a 2x4 MLC
geometry, staggered arrivals, sparse extra stalls).  This module traces
each closure with :func:`jax.make_jaxpr` and statically asserts the
contracts the engines' bit-for-bit claim rests on:

``jaxpr-hook``
    every registered engine must implement the hook (``None`` opts a
    host-Python engine out of tracing — the AST layer still lints it);
``jaxpr-rng``
    zero RNG primitives anywhere in a fold — randomness is sampled
    outside the folds from seeded streams (PR 7 determinism contract);
``jaxpr-dtype``
    no f64 value anywhere, floating outputs exactly f32.  Each fold is
    traced twice: once under the default config and once under
    ``jax.experimental.enable_x64`` — f32 discipline must come from
    explicit dtypes, not from the global f64 demotion silently papering
    over weak-type promotion;
``pad-identity``
    padding a masked fold to a larger power-of-two bucket is a (max,+)
    identity: the padded end time equals the unpadded scan bit-for-bit
    (checked by running the jitted folds, not by tracing);
``jaxpr-budget``
    per-fold primitive counts vs the committed baseline
    (:mod:`repro.analysis.baseline`).

The walk recurses into every sub-jaxpr (scan/while/pjit/pallas_call
bodies) by duck-typing eqn params: anything with ``.eqns`` is a Jaxpr,
anything with ``.jaxpr`` wraps one, tuples/lists are searched.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.analysis.findings import Finding

#: substrings identifying RNG primitives (threefry2x32, random_bits,
#: random_seed/wrap/fold_in/gamma, rng_bit_generator, ...)
RNG_PRIMITIVE_MARKERS: tuple[str, ...] = ("random", "threefry", "rng")


@dataclasses.dataclass(frozen=True)
class EngineFold:
    """One traced canonical fold of one engine."""

    engine: str
    label: str                 # hook key, e.g. "end_time"
    n_primitives: int          # total eqn count, sub-jaxprs included
    primitive_counts: dict     # name -> count (diagnostics/JSON)
    host: bool = False         # True for opted-out host-Python engines

    @property
    def key(self) -> str:
        return f"{self.engine}/{self.label}"


def _iter_subjaxprs(param) -> Iterable:
    """Yield every Jaxpr reachable from one eqn param value."""
    if hasattr(param, "eqns"):          # core.Jaxpr
        yield param
    elif hasattr(param, "jaxpr"):       # ClosedJaxpr and friends
        yield from _iter_subjaxprs(param.jaxpr)
    elif isinstance(param, (tuple, list)):
        for item in param:
            yield from _iter_subjaxprs(item)


def walk_eqns(jaxpr, visit: Callable) -> None:
    """Call ``visit(eqn)`` for every equation, recursing into the
    scan/while/pjit/pallas_call sub-jaxprs carried in eqn params."""
    for eqn in jaxpr.eqns:
        visit(eqn)
        for param in eqn.params.values():
            for sub in _iter_subjaxprs(param):
                walk_eqns(sub, visit)


def _is_rng_primitive(name: str) -> bool:
    return any(m in name for m in RNG_PRIMITIVE_MARKERS)


def _eqn_dtypes(eqn) -> Iterable[str]:
    for var in list(eqn.invars) + list(eqn.outvars):
        aval = getattr(var, "aval", None)
        dtype = getattr(aval, "dtype", None)
        if dtype is not None:
            yield str(dtype)


def canonical_simulator():
    """The session every fold is traced under: the canonical 2x4 MLC
    geometry (MLC exercises the lower/upper-page parity asymmetry)."""
    from repro.core.api import Simulator
    from repro.core.nand import CellType
    from repro.core.sim import SSDConfig

    return Simulator(SSDConfig(cell=CellType.MLC, channels=2, ways=4))


def _registered_engines() -> dict:
    from repro.core import api

    return {name: api.get_engine(name) for name in api.registered_engines()}


def _check_one(engine: str, label: str, fn, args,
               findings: list[Finding]) -> EngineFold:
    import jax

    key = f"{engine}/{label}"
    closed = jax.make_jaxpr(fn)(*args)

    counts: dict[str, int] = {}
    f64_hits: list[str] = []

    def visit(eqn):
        name = eqn.primitive.name
        counts[name] = counts.get(name, 0) + 1
        if _is_rng_primitive(name):
            findings.append(Finding(
                rule="jaxpr-rng", path=key, line=0,
                message=f"RNG primitive {name!r} inside the fold "
                        "(randomness must be sampled outside, from "
                        "seeded streams)"))
        if any(d == "float64" for d in _eqn_dtypes(eqn)):
            f64_hits.append(name)

    walk_eqns(closed.jaxpr, visit)

    for aval in closed.out_avals:
        dtype = str(getattr(aval, "dtype", ""))
        if dtype.startswith("float") and dtype != "float32":
            findings.append(Finding(
                rule="jaxpr-dtype", path=key, line=0,
                message=f"floating output is {dtype}, expected float32"))
    if f64_hits:
        findings.append(Finding(
            rule="jaxpr-dtype", path=key, line=0,
            message="float64 values in fold (via "
                    f"{', '.join(sorted(set(f64_hits)))})"))

    # Retrace with x64 enabled: a weak python-float constant that the
    # default config silently demotes to f32 promotes to f64 here.
    with jax.experimental.enable_x64():
        closed64 = jax.make_jaxpr(fn)(*args)
    f64_hits_x64: list[str] = []

    def visit64(eqn):
        if any(d == "float64" for d in _eqn_dtypes(eqn)):
            f64_hits_x64.append(eqn.primitive.name)

    walk_eqns(closed64.jaxpr, visit64)
    for aval in closed64.out_avals:
        if str(getattr(aval, "dtype", "")) == "float64":
            f64_hits_x64.append("<output>")
    if f64_hits_x64:
        findings.append(Finding(
            rule="jaxpr-dtype", path=key, line=0,
            message="weak-type f64 promotion under enable_x64 (via "
                    f"{', '.join(sorted(set(f64_hits_x64)))}); pin the "
                    "constant/array to an explicit float32 dtype"))

    return EngineFold(engine=engine, label=label,
                      n_primitives=sum(counts.values()),
                      primitive_counts=counts)


def collect_engine_folds(
        engines: dict | None = None,
        sim=None) -> tuple[list[EngineFold], list[Finding]]:
    """Trace every registered engine's canonical folds.

    ``engines``/``sim`` exist for test injection (a fake engine dict, a
    different geometry); the CLI always uses the live registry.
    """
    if engines is None:
        engines = _registered_engines()
    if sim is None:
        sim = canonical_simulator()

    folds: list[EngineFold] = []
    findings: list[Finding] = []
    for name in sorted(engines):
        engine = engines[name]
        try:
            hooks = engine.canonical_folds(sim)
        except NotImplementedError as exc:
            findings.append(Finding(
                rule="jaxpr-hook", path=f"engine:{name}", line=0,
                message=str(exc)))
            continue
        if hooks is None:
            folds.append(EngineFold(engine=name, label="host",
                                    n_primitives=0, primitive_counts={},
                                    host=True))
            continue
        for label, (fn, args) in sorted(hooks.items()):
            try:
                folds.append(_check_one(name, label, fn, args, findings))
            except Exception as exc:  # tracing itself blew up
                findings.append(Finding(
                    rule="jaxpr-hook", path=f"{name}/{label}", line=0,
                    message=f"canonical fold failed to trace: "
                            f"{type(exc).__name__}: {exc}"))
    return folds, findings


def check_padding_identity(sim=None) -> list[Finding]:
    """Run (not trace) the masked folds: padding the canonical trace to
    a larger power-of-two bucket must leave the end time bit-identical
    to the unpadded scan — the pad op is the (max,+) identity."""
    import jax.numpy as jnp

    from repro.core import api, sim as _sim

    if sim is None:
        sim = canonical_simulator()
    trace = api._canonical_trace()
    findings: list[Finding] = []

    base = float(_sim.trace_end_time(
        *sim._targs, *api._trace_args(trace),
        n_channels=trace.channels, batched=False))

    for bucket in (64, 128):
        padded = float(_sim.trace_end_time_masked(
            *sim._targs, *api._padded_trace_args(trace, bucket),
            n_channels=trace.channels, batched=False))
        if padded != base:
            findings.append(Finding(
                rule="pad-identity", path=f"scan/masked[{bucket}]", line=0,
                message=f"padding to bucket {bucket} changed the end "
                        f"time: {padded!r} != {base!r} (pad row is not "
                        "a (max,+) identity)"))

    # Streaming: chunked fold over the same padded operands must agree.
    e_tab = jnp.zeros((sim.table.n_classes, 2, 1), jnp.float32)
    for bucket in (64, 128):
        carry = _sim.trace_chunk_init(trace.channels, 1)
        _, _, end, _ = _sim.trace_chunk_fold(
            *sim._targs, e_tab, *api._padded_trace_args(trace, bucket),
            *api._carry_args(carry), n_channels=trace.channels,
            batched=False)
        streamed = float(end)
        if streamed != base:
            findings.append(Finding(
                rule="pad-identity", path=f"streaming/chunk[{bucket}]",
                line=0,
                message=f"chunked fold over bucket {bucket} changed the "
                        f"end time: {streamed!r} != {base!r}"))
    return findings
