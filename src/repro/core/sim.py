"""JAX-native discrete-event simulator of a multi-channel SSD.

The paper evaluates its DDR NAND interface with a behavioural RTL
co-simulation (MentorGraphics Seamless).  We reformulate that event loop as
a **data-parallel timeline recurrence**: the only state needed to advance
the simulation by one page operation is

    s = (bus_free[ch_0..ch_{C-1}],
         chip_free[ch, way_0..way_{W-1}],
         ctrl_free,                       # shared ECC/FTL controller
         round_start[ch])

and the per-op update is a (max, +) expression over that state.  Each op in
a trace carries (op-class, channel, way, page-parity); the per-op timing is
a gather from a small op-class table (``repro.core.trace.OpClassTable``),
so a single engine handles heterogeneous mixed read/write traffic across
all channels jointly.  Interchangeable engines evaluate the recurrence
(DESIGN.md §2):

* ``trace_end_time`` — ``jax.lax.scan`` over trace ops (jit/vmap-able,
  O(T) depth; ``trace_end_time_masked[_many]`` are the padded-bucket
  variants the ``repro.core.api`` session cache serves from);
* ``trace_end_time_prefix`` — the log-depth engine: per-op (max,+) step
  matrices built in-trace (``repro.core.maxplus_form``) and folded with
  a segmented parallel prefix, O(L + log T) depth (DESIGN.md §2.3);
* ``_squaring_end_time`` — homogeneous streams fold one period and
  reach ``n_pages`` by repeated (max,+) matrix squaring, O(log n_pages);
* ``repro.kernels.maxplus`` — the same recurrence as a blocked (max,+)
  matrix fold in Pallas, gathering the per-op-class matrix ``A[idx[t]]``
  per step (TPU-native, batched across design points; also exposes the
  segmented and squaring strategies);
* ``repro.core.sim_ref`` — plain-Python trace oracle for tests.

Every entry point is **arrival-aware** (DESIGN.md §2.6): the per-op
``arrival_us`` operand lower-bounds the ready time (zero = the old
back-to-back behaviour, bit-for-bit).  ``trace_completions`` emits
per-op completion times for request-latency percentiles, and
``dispatch_trace`` is the joint dispatch+simulate fold behind the
dynamic scheduling policies of ``repro.core.sched``.

All engine *dispatch* lives in ``repro.core.api`` (the registry behind
the ``Simulator`` session, DESIGN.md §2.5); this module holds only the
jit-compiled evaluation primitives.  The old query entry points
(``channel_bandwidth_mb_s`` / ``sweep_bandwidth_mb_s`` /
``ssd_bandwidth_mb_s``) survive below as deprecated delegating shims.

Every engine can also carry the phase-resolved energy accumulator of
``repro.core.energy`` alongside the end-time recurrence
(``trace_end_time_energy`` / ``trace_end_time_prefix_energy`` here, the
kernel fold in ``repro.kernels.maxplus``; DESIGN.md §2.4).

Model structure (C channels, W ways each, round-robin page striping)
--------------------------------------------------------------------
READ  page:  pre = t_CMD + t_R   (off-bus: command latch + array fetch)
             slot = t_DATA(page+spare) + t_ECC   (bus + ECC occupancy)
WRITE page:  slot = t_CMD + t_DATA + t_ECC + W*t_POLL  (the controller
             status-polls every way once per page slot), then the chip is
             busy for t_PROG.  MLC chips program paired pages with strongly
             asymmetric times (lower/upper page); the trace carries the
             page parity explicitly — it is what makes MLC write
             interleaving scale sub-ideally (paper §5.3.1 Case III).

Shared-controller occupancy (DESIGN.md §3)
------------------------------------------
The paper's SSD has ONE embedded controller arbitrating all channels,
while every channel carries its own NAND_IF + ECC hardware (§2.2.1).  Per
op, the clock-independent FTL/firmware share of the slot (``ctrl_us`` =
ECC fixed cost + write status polling) occupies that controller serially
across channels (``ctrl_free`` state row).  With more than one active
channel the firmware additionally pays, per bus grant, a context switch
plus a status scan of every other channel —
``arb_us = (CTRL_ARB_SWITCH_FRAC + CTRL_ARB_SCAN_FRAC*(C-1)) * ctrl_us``
(zero for a dedicated single-channel loop).  This replaces the retired
``STRIPE_EFFICIENCY_EXP`` bandwidth fudge: multi-channel Table 4 numbers
now come out of the joint simulation itself, and the old exponent survives
only as a calibration cross-check (``repro.core.calibrate``).

Scheduling policies
-------------------
The paper does not publish its firmware arbitration rules, which matter at
intermediate way counts (DESIGN.md §5).  Two documented policies bound the
behaviour:

* ``eager``   — a chip's next command is (re)issued as soon as the chip is
  idle (commands squeeze into bus gaps; 7 cycles ≈ 0.1 us vs transfers of
  12–90 us).
* ``batched`` — strict in-order firmware loop: round r's commands are only
  issued once the channel's bus drained round r-1's transfers.

Reads bracket the paper's measurements between these; writes are bus-gated
in both, so the policies coincide for writes.

Units: microseconds / bytes / MB-per-second (1 MB = 1e6 bytes, as in the
paper).
"""

from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

# make_interface / nand_chip are no longer used here since the query
# entry points moved to repro.core.api, but stay as deliberate
# re-exports (long-standing import site for tests and callers).
from repro.core.interface import (WRITE_POLL_FIXED_US, InterfaceKind,  # noqa: F401
                                  InterfaceParams, make_interface)
from repro.core.nand import CellType, NandChipParams, chip as nand_chip  # noqa: F401

MAX_WAYS = 16
MAX_CHANNELS = 8

# Firmware channel arbitration: with more than one active channel, each
# bus grant costs the single controller thread a context switch
# (CTRL_ARB_SWITCH_FRAC of the op's firmware occupancy) plus a status
# scan of every additional channel (CTRL_ARB_SCAN_FRAC each).  A
# dedicated single-channel loop pays neither.  Both fractions are
# calibrated on paper Table 4 (constant-capacity channel/way trade-off);
# see DESIGN.md §3.2 and ``repro.core.calibrate.stripe_crosscheck``.
CTRL_ARB_SWITCH_FRAC = 0.4
CTRL_ARB_SCAN_FRAC = 0.1

Policy = Literal["eager", "batched"]
Mode = Literal["read", "write"]
# evaluation strategy for the (identical) recurrence; the authoritative
# set is the repro.core.api registry — this literal mirrors it for the
# deprecated shim signatures
Engine = Literal["scan", "prefix", "squaring", "pallas", "oracle"]

POLICIES: tuple[str, ...] = ("eager", "batched")


def policy_is_batched(policy: str) -> bool:
    """Validate the ``Policy`` literal once and return its batched-ness.

    Every dispatch layer used to compare ``policy == "batched"`` ad hoc,
    so a typo like ``"bathced"`` silently simulated ``"eager"``; this is
    the single place that comparison is allowed to happen."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r} "
                         f"(one of {', '.join(map(repr, POLICIES))})")
    return policy == "batched"


def controller_arb_us(ctrl_us: float, channels: int) -> float:
    """Per-op firmware arbitration charge for a C-channel controller."""
    if channels <= 1:
        return 0.0
    return (CTRL_ARB_SWITCH_FRAC
            + CTRL_ARB_SCAN_FRAC * (channels - 1)) * ctrl_us


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    """One SSD design point (paper §5.3 axes)."""

    interface: InterfaceKind = InterfaceKind.PROPOSED
    cell: CellType = CellType.SLC
    channels: int = 1
    ways: int = 1
    policy: Policy = "eager"
    sata_mb_s: float = 300.0  # SATA2 ("SATA 3 Gbit/s"), paper footnote 1

    def __post_init__(self):
        policy_is_batched(self.policy)   # reject typos at construction

    def describe(self) -> str:
        return (
            f"{self.interface.value}/{self.cell.value}"
            f" {self.channels}ch x {self.ways}way [{self.policy}]"
        )


@dataclasses.dataclass(frozen=True)
class PageOpParams:
    """Scalar timing of one page-operation class.

    Recurrence consumed by all engines, per op on channel c / way w (see
    module docstring; arb_us = controller_arb_us(ctrl_us, C)):

        ready          = chip_free[c,w] + cmd_us + pre_us           (eager)
                         round_start[c] + (w+1)*cmd_us + pre_us     (batched)
        start          = max(bus_free[c], ready, ctrl_free) + arb_us
        bus_free'[c]   = start + slot_us
        ctrl_free'     = start + ctrl_us
        chip_free'[c,w]= bus_free'[c] + post_us(page parity)
    """

    cmd_us: float        # command/address latch occupancy
    pre_us: float        # off-bus latency after cmd (t_R for reads, 0 writes)
    slot_us: float       # bus+controller occupancy (data burst + ECC [+ polls])
    post_lo_us: float    # chip busy after slot (t_PROG; 0 for reads)
    post_hi_us: float    # odd-numbered page on a chip (MLC upper page)
    data_bytes: int      # user payload per op
    ctrl_us: float = 0.0  # FTL/firmware share of slot_us (shared controller)
    io_us: float = 0.0   # bus data-burst share of slot_us (energy phase split)

    def post_mean_us(self) -> float:
        return 0.5 * (self.post_lo_us + self.post_hi_us)


def page_op_params(
    iface: InterfaceParams, nand: NandChipParams, mode: Mode, ways: int
) -> PageOpParams:
    io_us = iface.data_us(nand.page_total_bytes)
    if mode == "read":
        return PageOpParams(
            cmd_us=iface.cmd_us,
            pre_us=nand.t_r_us,
            slot_us=io_us + iface.ecc_us(nand.cell),
            post_lo_us=0.0,
            post_hi_us=0.0,
            data_bytes=nand.page_data_bytes,
            ctrl_us=iface.ecc_fixed_us(nand.cell),
            io_us=io_us,
        )
    poll_us = (ways * nand.t_poll_cycles * iface.cycle_ns * 1e-3
               + WRITE_POLL_FIXED_US)
    return PageOpParams(
        cmd_us=iface.cmd_us,
        pre_us=0.0,
        slot_us=io_us + iface.ecc_us(nand.cell) + poll_us,
        post_lo_us=nand.t_prog_lo_us,
        post_hi_us=nand.t_prog_hi_us,
        data_bytes=nand.page_data_bytes,
        ctrl_us=iface.ecc_fixed_us(nand.cell) + poll_us,
        io_us=io_us,
    )


# ---------------------------------------------------------------------------
# lax.scan trace engine
# ---------------------------------------------------------------------------


def _trace_step_fn(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                   ctrl_us, arb_us, batched):
    """Single per-op state update — the one recurrence every scan-engine
    entry point (plain and energy-carrying) folds.  The op tuple carries
    the request arrival time: the ready base is maxed with it before the
    command-issue offset, so an op can never start before its request
    arrives (arrival 0 = the old back-to-back behaviour, bit-for-bit).
    It also carries the op's reliability surcharge ``ext`` (read-retry +
    jitter time sampled outside the fold, DESIGN.md §2.8): each retry
    re-runs the *sense* inside the die, so ``ext`` extends the op's chip
    occupancy (its release, and hence its completion) — never the
    channel bus and never the serial controller.  A retry storm
    therefore delays its own request and later ops on the *same chip*,
    but cannot head-of-line-block the channel or the FCFS issue stage —
    which is exactly what lets a hedged duplicate on another chip
    overtake it.  Adding 0.0 is exact in float32, so a fault-free
    vector reproduces the old state bit-for-bit."""

    def step(state, op):
        bus_free, chip_free, ctrl_free, round_start = state
        k, c, w, par, arr, ext = op
        cmd = cmd_us[k]
        round_start = jnp.where(
            w == 0, round_start.at[c].set(bus_free[c]), round_start)
        if batched:
            base = jnp.maximum(round_start[c], arr)
            ready = base + (w + 1).astype(jnp.float32) * cmd + pre_us[k]
        else:
            base = jnp.maximum(chip_free[c, w], arr)
            ready = base + cmd + pre_us[k]
        start = (jnp.maximum(jnp.maximum(bus_free[c], ready), ctrl_free)
                 + arb_us[k])
        new_bus = start + slot_us[k]
        post = jnp.where(par % 2 == 0, post_lo_us[k], post_hi_us[k])
        bus_free = bus_free.at[c].set(new_bus)
        chip_free = chip_free.at[c, w].set(new_bus + post + ext)
        return (bus_free, chip_free, start + ctrl_us[k], round_start)

    return step


def _trace_scan_init(n_channels):
    return (
        jnp.zeros((n_channels,), jnp.float32),
        jnp.zeros((n_channels, MAX_WAYS), jnp.float32),
        jnp.asarray(0.0, jnp.float32),
        jnp.zeros((n_channels,), jnp.float32),
    )


def _trace_ops(cls, channel, way, parity, arrival, extra):
    return (cls.astype(jnp.int32), channel.astype(jnp.int32),
            way.astype(jnp.int32), parity.astype(jnp.int32),
            arrival.astype(jnp.float32), extra.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_end_time(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K] shared-controller share of slot
    arb_us: jax.Array,       # [K] per-op firmware arbitration charge
    cls: jax.Array,          # [T] int32 op-class index per op
    channel: jax.Array,      # [T] int32
    way: jax.Array,          # [T] int32
    parity: jax.Array,       # [T] int32 page parity (MLC lower/upper)
    arrival_us: jax.Array,   # [T] float32 request arrival per op (0 = t0)
    extra_us: jax.Array,     # [T] float32 reliability surcharge (0 = none)
    n_channels: int,
    batched: bool,
) -> jax.Array:
    """Completion time (us) of a heterogeneous op trace on C channels."""
    upd = _trace_step_fn(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                         ctrl_us, arb_us, batched)
    (bus_free, chip_free, _, _), _ = jax.lax.scan(
        lambda s, op: (upd(s, op), None), _trace_scan_init(n_channels),
        _trace_ops(cls, channel, way, parity, arrival_us, extra_us))
    return jnp.maximum(jnp.max(bus_free), jnp.max(chip_free))


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_end_time_energy(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    e_op_uj: jax.Array,      # [K, 2, P] per-op phase energies (parity axis)
    cls: jax.Array,          # [T]
    channel: jax.Array,      # [T]
    way: jax.Array,          # [T]
    parity: jax.Array,       # [T]
    arrival_us: jax.Array,   # [T]
    extra_us: jax.Array,     # [T]
    n_channels: int,
    batched: bool,
) -> tuple[jax.Array, jax.Array]:
    """(end_us, [P] phase-energy sums in uJ): the same recurrence as
    ``trace_end_time`` carrying a phase-energy accumulator per op
    (DESIGN.md §2.4) — one fused scan, no second pass over the trace."""
    upd = _trace_step_fn(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                         ctrl_us, arb_us, batched)

    def step(carry, op):
        state, acc = carry
        k, c, w, par, arr, ext = op
        return (upd(state, op), acc + e_op_uj[k, par % 2]), None

    init = (_trace_scan_init(n_channels),
            jnp.zeros((e_op_uj.shape[-1],), jnp.float32))
    ((bus_free, chip_free, _, _), acc), _ = jax.lax.scan(
        step, init,
        _trace_ops(cls, channel, way, parity, arrival_us, extra_us))
    return jnp.maximum(jnp.max(bus_free), jnp.max(chip_free)), acc


def _trace_end_time_masked_impl(
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us,
        cls, channel, way, parity, arrival, extra, valid, n_channels,
        batched):
    upd = _trace_step_fn(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                         ctrl_us, arb_us, batched)

    def step(state, op):
        k, c, w, par, arr, ext, ok = op
        new = upd(state, (k, c, w, par, arr, ext))
        return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, state), None

    ops = _trace_ops(cls, channel, way, parity, arrival, extra) \
        + (valid.astype(bool),)
    (bus_free, chip_free, _, _), _ = jax.lax.scan(
        step, _trace_scan_init(n_channels), ops)
    return jnp.maximum(jnp.max(bus_free), jnp.max(chip_free))


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_end_time_masked(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    cls: jax.Array,          # [T] (T = padded length bucket)
    channel: jax.Array,      # [T]
    way: jax.Array,          # [T]
    parity: jax.Array,       # [T]
    arrival_us: jax.Array,   # [T]
    extra_us: jax.Array,     # [T]
    valid: jax.Array,        # [T] bool; False = padding (state no-op)
    n_channels: int,
    batched: bool,
) -> jax.Array:
    """``trace_end_time`` with a validity mask: invalid (padding) ops
    leave the carried state bitwise unchanged, so a trace padded to a
    power-of-two length bucket produces the *identical* end time while
    nearby trace lengths share one compiled program — the shape the
    ``repro.core.api`` session cache serves repeated queries from."""
    return _trace_end_time_masked_impl(
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us,
        cls, channel, way, parity, arrival_us, extra_us, valid, n_channels,
        batched)


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_end_time_masked_many(
    cmd_us: jax.Array,       # [K] one op-class table shared by the batch
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    cls: jax.Array,          # [B, T] a bucket of padded traces
    channel: jax.Array,      # [B, T]
    way: jax.Array,          # [B, T]
    parity: jax.Array,       # [B, T]
    arrival_us: jax.Array,   # [B, T]
    extra_us: jax.Array,     # [B, T]
    valid: jax.Array,        # [B, T]
    n_channels: int,
    batched: bool,
) -> jax.Array:
    """[B] completion times of a *bucket of traces* under one timing
    table — the packed serving path behind ``Simulator.run_many``:
    heterogeneous traces padded to a shared length bucket evaluate in
    one vmapped masked fold."""
    return jax.vmap(
        lambda a, b, c, d, e, x, v: _trace_end_time_masked_impl(
            cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us,
            arb_us, a, b, c, d, e, x, v, n_channels, batched)
    )(cls, channel, way, parity, arrival_us, extra_us, valid)


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_completions(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    cls: jax.Array,          # [T]
    channel: jax.Array,      # [T]
    way: jax.Array,          # [T]
    parity: jax.Array,       # [T]
    arrival_us: jax.Array,   # [T]
    extra_us: jax.Array,     # [T]
    n_channels: int,
    batched: bool,
) -> tuple[jax.Array, jax.Array]:
    """(end_us, [T] per-op completion times): the scan recurrence
    emitting each op's completion — bus drain for reads (data
    delivered), bus drain + t_PROG for writes (page durable).  This is
    the latency-extraction fold behind per-request p50/p99 on
    arrival-aware workloads (DESIGN.md §2.6); the end time is the same
    recurrence as ``trace_end_time``."""
    upd = _trace_step_fn(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                         ctrl_us, arb_us, batched)

    def step(state, op):
        new = upd(state, op)
        _, c, w, _, _, _ = op
        return new, new[1][c, w]                  # chip_free[c, w]

    (bus_free, chip_free, _, _), comp = jax.lax.scan(
        step, _trace_scan_init(n_channels),
        _trace_ops(cls, channel, way, parity, arrival_us, extra_us))
    return jnp.maximum(jnp.max(bus_free), jnp.max(chip_free)), comp


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_completions_masked(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    cls: jax.Array,          # [T] (T = padded length bucket)
    channel: jax.Array,      # [T]
    way: jax.Array,          # [T]
    parity: jax.Array,       # [T]
    arrival_us: jax.Array,   # [T]
    extra_us: jax.Array,     # [T]
    valid: jax.Array,        # [T] bool; False = padding (state no-op)
    n_channels: int,
    batched: bool,
) -> tuple[jax.Array, jax.Array]:
    """``trace_completions`` over a padded length bucket: padding ops
    leave the state bitwise unchanged and their emitted completions are
    trailing garbage the caller slices off — so workload latency
    queries share the same power-of-two compile buckets as the masked
    end-time fold instead of paying one XLA compile per trace length."""
    upd = _trace_step_fn(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                         ctrl_us, arb_us, batched)

    def step(state, op):
        k, c, w, par, arr, ext, ok = op
        new = upd(state, (k, c, w, par, arr, ext))
        new = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, state)
        return new, new[1][c, w]                  # chip_free[c, w]

    ops = _trace_ops(cls, channel, way, parity, arrival_us, extra_us) \
        + (valid.astype(bool),)
    (bus_free, chip_free, _, _), comp = jax.lax.scan(
        step, _trace_scan_init(n_channels), ops)
    return jnp.maximum(jnp.max(bus_free), jnp.max(chip_free)), comp


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_chunk_fold(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    e_op_uj: jax.Array,      # [K, 2, P] phase energies (zeros: end-time only)
    cls: jax.Array,          # [L] one fixed-size chunk of the trace
    channel: jax.Array,      # [L]
    way: jax.Array,          # [L]
    parity: jax.Array,       # [L]
    arrival_us: jax.Array,   # [L]
    extra_us: jax.Array,     # [L]
    valid: jax.Array,        # [L] bool; False = padding (state no-op)
    bus_free: jax.Array,     # [C]        carried occupancy state
    chip_free: jax.Array,    # [C, MAX_WAYS]
    ctrl_free: jax.Array,    # []
    round_start: jax.Array,  # [C]
    energy_acc: jax.Array,   # [P] carried phase-energy accumulator (uJ)
    n_channels: int,
    batched: bool,
) -> tuple[tuple, jax.Array, jax.Array, jax.Array]:
    """One chunk of the streaming engine (DESIGN.md §2.7): fold ``L``
    masked ops starting *from a caller-supplied occupancy state* and
    return ``((bus, chip, ctrl, round_start), energy_acc, end_us,
    comp[L])``.  This is the segment-product recurrence of §2.3
    specialised to its concrete carried state: because every chunk
    replays the exact per-op float sequence of ``_trace_step_fn`` (and
    masked padding is a bitwise state no-op), chaining chunks of *any*
    size reproduces the single-shot scan engine bit-for-bit — chunk-size
    invariance by construction, O(L) live memory regardless of trace
    length.  Energy adds ``where(valid, E[k, parity], 0)`` per step —
    adding +0.0 is exact, so the accumulator is chunk-invariant too."""
    upd = _trace_step_fn(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                         ctrl_us, arb_us, batched)

    def step(carry, op):
        state, acc = carry
        k, c, w, par, arr, ext, ok = op
        new = upd(state, (k, c, w, par, arr, ext))
        new = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, state)
        acc = acc + jnp.where(ok, e_op_uj[k, par % 2], jnp.float32(0.0))
        return (new, acc), new[1][c, w]           # chip_free[c, w]

    ops = _trace_ops(cls, channel, way, parity, arrival_us, extra_us) \
        + (valid.astype(bool),)
    init = ((bus_free, chip_free, ctrl_free, round_start), energy_acc)
    (state, acc), comp = jax.lax.scan(step, init, ops)
    end = jnp.maximum(jnp.max(state[0]), jnp.max(state[1]))
    return state, acc, end, comp


def trace_chunk_init(n_channels: int, n_phases: int):
    """Initial carry for :func:`trace_chunk_fold` — the zero occupancy
    state of ``_trace_scan_init`` plus a zero energy accumulator."""
    return (_trace_scan_init(n_channels),
            jnp.zeros((n_phases,), jnp.float32))


#: Dynamic dispatch rules evaluated inside the joint fold (sched-layer
#: names; the static policies lower offline in ``repro.core.sched``).
DISPATCH_RULES: tuple[str, ...] = ("least_loaded", "earliest_ready")


@functools.partial(jax.jit, static_argnames=("n_channels", "n_ways", "rule"))
def dispatch_trace(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    cls: jax.Array,          # [T] int32 op-class per op (placement-free)
    arrival_us: jax.Array,   # [T] float32 request arrival per op
    n_channels: int,
    n_ways: int,
    rule: str = "least_loaded",
    extra_us: jax.Array | None = None,   # [T] reliability surcharge
    retired: jax.Array | None = None,    # [C, W] bool bad-block mask
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Joint dispatch + simulate fold (DESIGN.md §2.6): the carried
    occupancy row *drives* the channel/way assignment, one decision per
    op inside the same ``lax.scan`` that advances the timeline —
    the dynamic half of the scheduler layer (static policies lower
    offline to an ``OpTrace`` instead and reach every engine).

    Rules:

    * ``least_loaded``  — the op goes to the chip with the smallest
      busy horizon ``max(bus_free[c], chip_free[c, w])`` (global greedy
      on the op's earliest feasible start: an idle chip behind a
      saturated bus is *not* a good target; ties break to the lowest
      index);
    * ``earliest_ready`` — the op goes to the channel whose bus drains
      first, then to that channel's least-loaded way.

    Page parity is derived in-fold from a carried per-chip op counter
    (the dispatch decides which chip's MLC pair advances).  Returns
    ``(end_us, completion[T], channel[T], way[T], parity[T])`` — the
    chosen placement is a full ``OpTrace`` assignment, so energy /
    bandwidth accounting and the oracles replay it exactly.  Dispatch
    is FCFS in trace order under the ``eager`` issue policy (a strict
    ``batched`` round loop has no meaning when rounds are not fixed at
    build time).

    ``extra_us`` extends the op's chip occupancy / completion like the
    replay engines (DESIGN.md §2.8; never the channel bus or the serial
    controller); ``retired`` marks bad-block chips
    the dispatcher must never choose — their horizon is +inf under
    ``least_loaded`` and they are masked out of ``earliest_ready``'s
    way choice (each channel must keep >= 1 live way, which the
    ``FaultSampler`` retirement draw guarantees)."""
    if rule not in DISPATCH_RULES:
        raise ValueError(f"unknown dispatch rule {rule!r} "
                         f"(one of {', '.join(DISPATCH_RULES)})")
    least_loaded = rule == "least_loaded"
    if extra_us is None:
        extra_us = jnp.zeros_like(arrival_us, dtype=jnp.float32)
    if retired is None:
        retired = jnp.zeros((n_channels, n_ways), bool)
    retired = jnp.asarray(retired, bool)
    inf = jnp.asarray(jnp.inf, jnp.float32)

    def step(state, op):
        bus_free, chip_free, ctrl_free, counts = state
        k, arr, ext = op
        if least_loaded:
            horizon = jnp.where(retired, inf,
                                jnp.maximum(chip_free, bus_free[:, None]))
            flat = jnp.argmin(horizon.reshape(-1))
            c, w = flat // n_ways, flat % n_ways
        else:
            c = jnp.argmin(bus_free)
            w = jnp.argmin(jnp.where(retired[c], inf, chip_free[c]))
        par = counts[c, w] % 2
        ready = jnp.maximum(chip_free[c, w], arr) + cmd_us[k] + pre_us[k]
        start = (jnp.maximum(jnp.maximum(bus_free[c], ready), ctrl_free)
                 + arb_us[k])
        new_bus = start + slot_us[k]
        post = jnp.where(par % 2 == 0, post_lo_us[k], post_hi_us[k])
        comp = new_bus + post + ext
        state = (bus_free.at[c].set(new_bus),
                 chip_free.at[c, w].set(comp),
                 start + ctrl_us[k],
                 counts.at[c, w].add(1))
        return state, (comp, c.astype(jnp.int32), w.astype(jnp.int32),
                       par.astype(jnp.int32))

    init = (jnp.zeros((n_channels,), jnp.float32),
            jnp.zeros((n_channels, n_ways), jnp.float32),
            jnp.asarray(0.0, jnp.float32),
            jnp.zeros((n_channels, n_ways), jnp.int32))
    (bus_free, chip_free, _, _), (comp, chan, way, par) = jax.lax.scan(
        step, init, (cls.astype(jnp.int32), arrival_us.astype(jnp.float32),
                     extra_us.astype(jnp.float32)))
    end = jnp.maximum(jnp.max(bus_free), jnp.max(chip_free))
    return end, comp, chan, way, par


# ---------------------------------------------------------------------------
# Log-depth engines (DESIGN.md §2.3)
# ---------------------------------------------------------------------------


def _trace_end_time_prefix_impl(
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us,
        cls, channel, way, parity, arrival, extra, n_channels, n_ways,
        batched, segment_len, combine, valid=None):
    from repro.core import maxplus_form as mf  # deferred: mf imports us

    prods = mf.structured_segment_products(
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us,
        cls, channel, way, parity, arrival, extra,
        channels=n_channels, ways=n_ways, batched=batched,
        segment_len=segment_len if segment_len is not None else 1,
        valid=valid)
    layout = mf.StateLayout(n_channels, n_ways)
    s0 = jnp.zeros((layout.n_state,), jnp.float32)
    if combine == "assoc":        # log-depth dense combine (TPU-shaped)
        pref = jax.lax.associative_scan(
            lambda x, y: mf.maxplus_matmul(y, x), prods, axis=0)
        final = mf.maxplus_matvec(pref[-1], s0)
    elif combine == "chain":      # O(S) matvec chain: no dense matmuls,
        final, _ = jax.lax.scan(  # the CPU-fast combine
            lambda s, p: (mf.maxplus_matvec(p, s), None), s0, prods)
    else:
        raise ValueError(f"unknown combine {combine!r} "
                         "(one of 'chain', 'assoc')")
    return jnp.max(final[: layout.n_completion_rows])


@functools.partial(jax.jit, static_argnames=("n_channels", "n_ways",
                                             "batched", "segment_len",
                                             "combine"))
def trace_end_time_prefix(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    cls: jax.Array,          # [T]
    channel: jax.Array,      # [T]
    way: jax.Array,          # [T]
    parity: jax.Array,       # [T]
    arrival_us: jax.Array,   # [T]
    extra_us: jax.Array,     # [T]
    n_channels: int,
    n_ways: int,
    batched: bool,
    segment_len: int | None = 64,
    combine: str = "chain",
    valid: jax.Array | None = None,   # [T] bool: False lanes skip exactly
) -> jax.Array:
    """Same recurrence as ``trace_end_time``, evaluated in O(L + S)
    depth (S = ceil(T/L)): the trace's S segment products are computed
    concurrently by the structured row fold of
    ``repro.core.maxplus_form.structured_segment_products`` (the scan
    recurrence on N-row-valued resource times — O(T·N) work, depth L),
    then combined across segments.  ``combine="chain"`` folds the S
    products into the initial state with O(S) cheap (max,+) matvecs
    (fastest on CPU); ``combine="assoc"`` combines them with a
    log-depth ``associative_scan`` of dense matmuls — O(L + log S)
    total depth, the shape that pays on TPU.  Compiles end to end from
    the raw table/trace arrays with no Python pass over the trace.

    ``n_ways`` bounds the way indices in the trace and sets the state
    layout (smaller than the scan engine's fixed MAX_WAYS block, so the
    combine matrices stay compact).  ``segment_len=None`` folds each op
    as its own segment — with ``combine="assoc"`` the pure O(log T)-
    depth dense form.

    ``valid`` (optional [T] bool) masks lanes out of the product
    exactly — the masked-fold identity for sparsely padded traces
    (the fused FTL sweep's emission rows, DESIGN.md §2.11)."""
    return _trace_end_time_prefix_impl(
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us,
        cls, channel, way, parity, arrival_us, extra_us, n_channels,
        n_ways, batched, segment_len, combine, valid)


@functools.partial(jax.jit, static_argnames=("n_channels", "n_ways",
                                             "batched", "segment_len",
                                             "combine"))
def trace_end_time_prefix_energy(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    e_op_uj: jax.Array,      # [K, 2, P] per-op phase energies
    cls: jax.Array,          # [T]
    channel: jax.Array,      # [T]
    way: jax.Array,          # [T]
    parity: jax.Array,       # [T]
    arrival_us: jax.Array,   # [T]
    extra_us: jax.Array,     # [T]
    n_channels: int,
    n_ways: int,
    batched: bool,
    segment_len: int | None = 64,
    combine: str = "chain",
) -> tuple[jax.Array, jax.Array]:
    """(end_us, [P] phase-energy sums in uJ) via the segmented prefix
    engine: energy is (+, +)-linear in the ops, so it rides the same
    segment chunking as ``structured_segment_products`` as a plain
    per-segment sum combined across segments (DESIGN.md §2.4)."""
    from repro.core import maxplus_form as mf  # deferred: mf imports us

    end = _trace_end_time_prefix_impl(
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us,
        cls, channel, way, parity, arrival_us, extra_us, n_channels,
        n_ways, batched, segment_len, combine)
    seg = mf.structured_segment_energy(
        e_op_uj, cls, parity,
        segment_len=segment_len if segment_len is not None else 1)
    return end, jnp.sum(seg, axis=0)


@functools.partial(jax.jit, static_argnames=("n_channels", "n_ways",
                                             "batched", "segment_len",
                                             "combine"))
def trace_end_time_prefix_batch(
    cmd_us: jax.Array,       # [B, K] stacked op-class timing tables
    pre_us: jax.Array,       # [B, K]
    slot_us: jax.Array,      # [B, K]
    post_lo_us: jax.Array,   # [B, K]
    post_hi_us: jax.Array,   # [B, K]
    ctrl_us: jax.Array,      # [B, K]
    arb_us: jax.Array,       # [B, K]
    cls: jax.Array,          # [T] one trace shared by the batch
    channel: jax.Array,      # [T]
    way: jax.Array,          # [T]
    parity: jax.Array,       # [T]
    arrival_us: jax.Array,   # [T]
    extra_us: jax.Array,     # [T]
    n_channels: int,
    n_ways: int,
    batched: bool,
    segment_len: int | None = 64,
    combine: str = "chain",
) -> jax.Array:
    """[B] completion times: one trace under a batch of design-point
    timing tables.  The structured segment fold vectorises over B×S
    lanes in one pass — the sweep-scaling form of the prefix engine
    (trace-only mask/pattern precomputation is shared across the
    batch)."""
    return jax.vmap(
        lambda *t: _trace_end_time_prefix_impl(
            *t, cls, channel, way, parity, arrival_us, extra_us,
            n_channels, n_ways, batched, segment_len, combine)
    )(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us)


@functools.partial(jax.jit, static_argnames=("n_pages", "batched"))
def _squaring_end_time(
    cmd_us: jax.Array,       # scalars (or [B] under vmap) — one op class
    pre_us: jax.Array,
    slot_us: jax.Array,
    post_lo_us: jax.Array,
    post_hi_us: jax.Array,
    ctrl_us: jax.Array,
    ways: jax.Array,
    n_pages: int,
    batched: bool,
) -> jax.Array:
    """Homogeneous single-channel completion time via periodic matrix
    squaring: fold one 2·MAX_WAYS-op period block with the structured
    row fold, then square to ``n_pages`` — O(log n_pages) dense (max,+)
    matmuls plus one structured remainder fold (DESIGN.md §2.3).
    Requires ways | MAX_WAYS so the block is a whole number of true
    periods (the paper's power-of-two sweep grid)."""
    from repro.core import maxplus_form as mf  # deferred: mf imports us

    period = 2 * MAX_WAYS
    table = tuple(jnp.reshape(x, (1,)) for x in (
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us,
        jnp.float32(0.0)))  # weak 0.0 would x64-promote the gathered table

    def block_product(n_ops: int) -> jax.Array:
        i = jnp.arange(n_ops)
        return mf.structured_segment_products(
            *table, jnp.zeros((n_ops,), jnp.int32),
            jnp.zeros((n_ops,), jnp.int32), (i % ways).astype(jnp.int32),
            ((i // ways) % 2).astype(jnp.int32),
            channels=1, ways=MAX_WAYS, batched=batched,
            segment_len=n_ops)[0]

    q, r = divmod(int(n_pages), period)
    if q:
        total = mf.maxplus_matrix_power(block_product(period), q)
        if r:
            total = mf.maxplus_matmul(block_product(r), total)
    else:
        total = block_product(r)
    s0 = jnp.zeros((mf.N_STATE,), jnp.float32)
    final = mf.maxplus_matvec(total, s0)
    return jnp.max(final[: mf.DEFAULT_LAYOUT.n_completion_rows])


@functools.partial(jax.jit, static_argnames=("n_channels", "batched"))
def trace_end_time_batch(
    cmd_us: jax.Array,       # [B, K] stacked tables (see trace_end_time)
    pre_us: jax.Array,
    slot_us: jax.Array,
    post_lo_us: jax.Array,
    post_hi_us: jax.Array,
    ctrl_us: jax.Array,
    arb_us: jax.Array,
    cls: jax.Array,          # [T] one trace shared by the batch
    channel: jax.Array,
    way: jax.Array,
    parity: jax.Array,
    arrival_us: jax.Array,
    extra_us: jax.Array,
    n_channels: int,
    batched: bool,
) -> jax.Array:
    """[B] completion times — the scan engine vmapped over tables."""
    return jax.vmap(
        lambda *t: trace_end_time(
            *t, cls, channel, way, parity, arrival_us, extra_us,
            n_channels=n_channels, batched=batched)
    )(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, arb_us)


def _validate_squaring_ways(ways) -> None:
    """engine="squaring" folds a 2·MAX_WAYS-op period block, which only
    tiles the stream when ways | MAX_WAYS (the paper's power-of-two
    grid) — reject anything else loudly rather than silently misalign.
    Traced values can't be inspected; the precondition then stands as
    documented."""
    try:
        arr = np.asarray(ways)
    except Exception:                  # jax tracer: defer to the docs
        return
    if np.any(arr < 1) or np.any(MAX_WAYS % np.maximum(arr, 1) != 0):
        raise ValueError(
            f"engine='squaring' requires ways dividing {MAX_WAYS}, got "
            f"{arr.tolist()}")


def _steady_pattern(n_pages, ways):
    """way/parity index pattern of a single-channel round-robin stream."""
    i = jnp.arange(n_pages)
    return jnp.mod(i, ways).astype(jnp.int32), ((i // ways) % 2).astype(jnp.int32)


def channel_bandwidth_mb_s(
    op: PageOpParams,
    ways: int | jax.Array,
    policy: Policy = "eager",
    n_pages: int = 512,
    engine: Engine = "scan",
) -> jax.Array:
    """Deprecated shim: use
    ``repro.api.steady_channel_bandwidth_mb_s`` (same arguments, engine
    dispatch through the registry).  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.sim.channel_bandwidth_mb_s is deprecated; use "
        "repro.api.steady_channel_bandwidth_mb_s",
        DeprecationWarning, stacklevel=2)
    return api.steady_channel_bandwidth_mb_s(
        op, ways, policy=policy, n_pages=n_pages, engine=engine)


def ssd_bandwidth_mb_s(cfg: SSDConfig, mode: Mode, n_pages: int = 512) -> float:
    """Deprecated shim: use ``repro.api.steady_bandwidth_mb_s`` (same
    joint multi-channel simulation through a cached ``Simulator``
    session).  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.sim.ssd_bandwidth_mb_s is deprecated; use "
        "repro.api.steady_bandwidth_mb_s",
        DeprecationWarning, stacklevel=2)
    return api.steady_bandwidth_mb_s(cfg, mode, n_pages=n_pages)


# ---------------------------------------------------------------------------
# Closed-form steady-state model (tests & napkin math)
# ---------------------------------------------------------------------------


def steady_state_mb_s(op: PageOpParams, ways: int) -> float:
    """Ideal round-robin steady state: min(bus-bound, chip-bound) rate."""
    bus_rate = op.data_bytes / op.slot_us
    cycle = op.cmd_us + op.pre_us + op.slot_us + op.post_mean_us()
    chip_rate = ways * op.data_bytes / cycle
    return min(bus_rate, chip_rate)


def saturation_ways(op: PageOpParams) -> int:
    """Smallest W with W*slot >= full chip cycle (paper's saturation point)."""
    cycle = op.cmd_us + op.pre_us + op.slot_us + op.post_mean_us()
    return max(1, math.ceil(cycle / op.slot_us))


# ---------------------------------------------------------------------------
# Batched design-space sweep (vmap over design points)
# ---------------------------------------------------------------------------


def sweep_bandwidth_mb_s(
    cmd_us: jax.Array,
    pre_us: jax.Array,
    slot_us: jax.Array,
    post_lo_us: jax.Array,
    post_hi_us: jax.Array,
    ctrl_us: jax.Array,
    data_bytes: jax.Array,
    ways: jax.Array,
    n_pages: int = 512,
    batched: bool = False,
    engine: Engine = "scan",
) -> jax.Array:
    """Deprecated shim: use ``repro.api.sweep_steady_bandwidth_mb_s``
    (same arguments, engine dispatch through the registry).
    Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.sim.sweep_bandwidth_mb_s is deprecated; use "
        "repro.api.sweep_steady_bandwidth_mb_s",
        DeprecationWarning, stacklevel=2)
    return api.sweep_steady_bandwidth_mb_s(
        cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us,
        data_bytes, ways, n_pages=n_pages, batched=batched, engine=engine)


@functools.partial(jax.jit, static_argnames=("n_pages", "batched"))
def _sweep_scan_jit(
    cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us,
    data_bytes, ways, n_pages: int, batched: bool,
) -> jax.Array:
    """Scan-engine half of the homogeneous design-point sweep: charges
    the shared-controller occupancy ``ctrl_us`` exactly like the
    per-point channel path (the two are regression-pinned equal)."""
    zeros_i = jnp.zeros((n_pages,), jnp.int32)
    zeros_f = jnp.zeros((n_pages,), jnp.float32)
    zero_k = jnp.zeros((1,), jnp.float32)

    def one(cmd, pre, slot, lo, hi, ctrl, nbytes, w):
        way, parity = _steady_pattern(n_pages, w)
        end = trace_end_time(
            cmd[None], pre[None], slot[None], lo[None], hi[None],
            ctrl[None], zero_k, zeros_i, zeros_i, way, parity, zeros_f,
            zeros_f, n_channels=1, batched=batched)
        return (n_pages * nbytes) / end

    return jax.vmap(one)(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us,
                         ctrl_us, data_bytes, ways)


@functools.partial(jax.jit, static_argnames=("n_pages", "batched"))
def _sweep_squaring_jit(
    cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us,
    data_bytes, ways, n_pages: int, batched: bool,
) -> jax.Array:
    """Squaring-engine half of the sweep: each point in O(log n_pages)
    (max,+) matmuls (every entry of ``ways`` must divide MAX_WAYS —
    validated by the caller, since tracers cannot be inspected here)."""

    def one_sq(cmd, pre, slot, lo, hi, ctrl, nbytes, w):
        end = _squaring_end_time(cmd, pre, slot, lo, hi, ctrl, w,
                                 n_pages=n_pages, batched=batched)
        return (n_pages * nbytes) / end

    return jax.vmap(one_sq)(cmd_us, pre_us, slot_us, post_lo_us,
                            post_hi_us, ctrl_us, data_bytes, ways)
