"""JAX-native discrete-event simulator of an SSD channel.

The paper evaluates its DDR NAND interface with a behavioural RTL
co-simulation (MentorGraphics Seamless).  We reformulate that event loop as
a **data-parallel timeline recurrence**: the only state needed to advance
the simulation by one page operation is

    s = (bus_free_time, chip_free_time[way_0..way_{W-1}] [, round_start])

and the per-page update is a (max, +) expression over that state.  This
gives three interchangeable engines:

* ``simulate_channel`` / ``channel_bandwidth_mb_s`` — ``jax.lax.scan`` over
  page ops (jit/vmap-able);
* ``repro.kernels.maxplus`` — the same recurrence as a blocked associative
  (max,+) matrix scan in Pallas (TPU-native, log-depth across a trace);
* ``repro.core.sim_ref`` — plain-Python oracle for tests.

Model structure (per channel, W ways, round-robin page striping)
-----------------------------------------------------------------
READ  page:  pre = t_CMD + t_R   (off-bus: command latch + array fetch)
             slot = t_DATA(page+spare) + t_ECC   (bus + ECC occupancy)
WRITE page:  slot = t_CMD + t_DATA + t_ECC + W*t_POLL  (the controller
             status-polls every way once per page slot), then the chip is
             busy for t_PROG.  MLC chips program paired pages with strongly
             asymmetric times (lower/upper page); we model the alternation
             explicitly — it is what makes MLC write interleaving scale
             sub-ideally (paper §5.3.1 Case III).

Scheduling policies
-------------------
The paper does not publish its firmware arbitration rules, which matter at
intermediate way counts (DESIGN.md §5).  Two documented policies bound the
behaviour:

* ``eager``   — a chip's next command is (re)issued as soon as the chip is
  idle (commands squeeze into bus gaps; 7 cycles ≈ 0.1 us vs transfers of
  12–90 us).
* ``batched`` — strict in-order firmware loop: round r's commands are only
  issued once the bus drained round r-1's transfers.

Reads bracket the paper's measurements between these; writes are bus-gated
in both, so the policies coincide for writes.

Units: microseconds / bytes / MB-per-second (1 MB = 1e6 bytes, as in the
paper).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.interface import (WRITE_POLL_FIXED_US, InterfaceKind,
                                  InterfaceParams, make_interface)
from repro.core.nand import CellType, NandChipParams, chip as nand_chip

MAX_WAYS = 16

Policy = Literal["eager", "batched"]
Mode = Literal["read", "write"]


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    """One SSD design point (paper §5.3 axes)."""

    interface: InterfaceKind = InterfaceKind.PROPOSED
    cell: CellType = CellType.SLC
    channels: int = 1
    ways: int = 1
    policy: Policy = "eager"
    sata_mb_s: float = 300.0  # SATA2 ("SATA 3 Gbit/s"), paper footnote 1

    def describe(self) -> str:
        return (
            f"{self.interface.value}/{self.cell.value}"
            f" {self.channels}ch x {self.ways}way [{self.policy}]"
        )


@dataclasses.dataclass(frozen=True)
class PageOpParams:
    """Scalar timing of one page-operation class on one channel.

    Recurrence consumed by all engines (see module docstring):

        ready        = chip_free[w] + cmd_us + pre_us              (eager)
                       round_start + (w+1)*cmd_us + pre_us         (batched)
        start        = max(bus_free, ready)
        bus_free'    = start + slot_us
        chip_free'[w]= bus_free' + post_us(page)
    """

    cmd_us: float        # command/address latch occupancy
    pre_us: float        # off-bus latency after cmd (t_R for reads, 0 writes)
    slot_us: float       # bus+controller occupancy (data burst + ECC [+ polls])
    post_lo_us: float    # chip busy after slot (t_PROG; 0 for reads)
    post_hi_us: float    # odd-numbered page on a chip (MLC upper page)
    data_bytes: int      # user payload per op

    def post_mean_us(self) -> float:
        return 0.5 * (self.post_lo_us + self.post_hi_us)


def page_op_params(
    iface: InterfaceParams, nand: NandChipParams, mode: Mode, ways: int
) -> PageOpParams:
    if mode == "read":
        return PageOpParams(
            cmd_us=iface.cmd_us,
            pre_us=nand.t_r_us,
            slot_us=iface.data_us(nand.page_total_bytes) + iface.ecc_us(nand.cell),
            post_lo_us=0.0,
            post_hi_us=0.0,
            data_bytes=nand.page_data_bytes,
        )
    return PageOpParams(
        cmd_us=iface.cmd_us,
        pre_us=0.0,
        slot_us=(
            iface.data_us(nand.page_total_bytes)
            + iface.ecc_us(nand.cell)
            + ways * nand.t_poll_cycles * iface.cycle_ns * 1e-3
            + WRITE_POLL_FIXED_US
        ),
        post_lo_us=nand.t_prog_lo_us,
        post_hi_us=nand.t_prog_hi_us,
        data_bytes=nand.page_data_bytes,
    )


# ---------------------------------------------------------------------------
# lax.scan engine
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_pages", "batched"))
def _channel_end_time(
    cmd_us: jax.Array,
    pre_us: jax.Array,
    slot_us: jax.Array,
    post_lo_us: jax.Array,
    post_hi_us: jax.Array,
    ways: jax.Array,
    n_pages: int,
    batched: bool,
) -> jax.Array:
    """Completion time of ``n_pages`` round-robin page ops on one channel."""

    def step(state, i):
        bus_free, chip_free, round_start = state
        w = jnp.mod(i, ways)
        rnd = i // ways
        round_start = jnp.where(w == 0, bus_free, round_start)
        if batched:
            ready = round_start + (w + 1).astype(jnp.float32) * cmd_us + pre_us
        else:
            ready = chip_free[w] + cmd_us + pre_us
        start = jnp.maximum(bus_free, ready)
        new_bus = start + slot_us
        post = jnp.where(rnd % 2 == 0, post_lo_us, post_hi_us)
        chip_free = chip_free.at[w].set(new_bus + post)
        return (new_bus, chip_free, round_start), None

    init = (
        jnp.asarray(0.0, jnp.float32),
        jnp.zeros((MAX_WAYS,), jnp.float32),
        jnp.asarray(0.0, jnp.float32),
    )
    (bus_free, chip_free, _), _ = jax.lax.scan(step, init, jnp.arange(n_pages))
    return jnp.maximum(bus_free, jnp.max(chip_free))


def channel_bandwidth_mb_s(
    op: PageOpParams,
    ways: int | jax.Array,
    policy: Policy = "eager",
    n_pages: int = 512,
) -> jax.Array:
    """Steady-stream bandwidth of a single channel, MB/s."""
    end = _channel_end_time(
        jnp.asarray(op.cmd_us, jnp.float32),
        jnp.asarray(op.pre_us, jnp.float32),
        jnp.asarray(op.slot_us, jnp.float32),
        jnp.asarray(op.post_lo_us, jnp.float32),
        jnp.asarray(op.post_hi_us, jnp.float32),
        jnp.asarray(ways, jnp.int32),
        n_pages=n_pages,
        batched=(policy == "batched"),
    )
    return (n_pages * op.data_bytes) / end  # bytes/us == MB/s


# Channel-striping efficiency exponent, calibrated on paper Table 4: the
# single embedded controller/FTL arbitrates all channels, costing ~5.5% of
# aggregate bandwidth per channel doubling (74.07/2×39.78 @2ch,
# 103.76/4×39.78-ish @4ch, consistent across cells/modes/interfaces).
STRIPE_EFFICIENCY_EXP = 0.92


def ssd_bandwidth_mb_s(cfg: SSDConfig, mode: Mode, n_pages: int = 512) -> float:
    """SSD-level bandwidth: striped channels (sub-linear, calibrated on
    Table 4), capped by the SATA2 host link."""
    iface = make_interface(cfg.interface)
    nand = nand_chip(cfg.cell)
    op = page_op_params(iface, nand, mode, cfg.ways)
    per_channel = channel_bandwidth_mb_s(op, cfg.ways, cfg.policy, n_pages=n_pages)
    total = per_channel * (cfg.channels ** STRIPE_EFFICIENCY_EXP)
    return float(jnp.minimum(total, cfg.sata_mb_s))


# ---------------------------------------------------------------------------
# Closed-form steady-state model (tests & napkin math)
# ---------------------------------------------------------------------------


def steady_state_mb_s(op: PageOpParams, ways: int) -> float:
    """Ideal round-robin steady state: min(bus-bound, chip-bound) rate."""
    bus_rate = op.data_bytes / op.slot_us
    cycle = op.cmd_us + op.pre_us + op.slot_us + op.post_mean_us()
    chip_rate = ways * op.data_bytes / cycle
    return min(bus_rate, chip_rate)


def saturation_ways(op: PageOpParams) -> int:
    """Smallest W with W*slot >= full chip cycle (paper's saturation point)."""
    cycle = op.cmd_us + op.pre_us + op.slot_us + op.post_mean_us()
    return max(1, math.ceil(cycle / op.slot_us))


# ---------------------------------------------------------------------------
# Batched design-space sweep (vmap over design points)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_pages", "batched"))
def sweep_bandwidth_mb_s(
    cmd_us: jax.Array,
    pre_us: jax.Array,
    slot_us: jax.Array,
    post_lo_us: jax.Array,
    post_hi_us: jax.Array,
    data_bytes: jax.Array,
    ways: jax.Array,
    n_pages: int = 512,
    batched: bool = False,
) -> jax.Array:
    """Vectorised bandwidth over a batch of design points (all arrays [N])."""

    def one(cmd, pre, slot, lo, hi, nbytes, w):
        end = _channel_end_time(cmd, pre, slot, lo, hi, w, n_pages, batched)
        return (n_pages * nbytes) / end

    return jax.vmap(one)(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, data_bytes, ways)
