"""Plain-Python oracle for the channel event simulation.

Used by unit/property tests to validate both the ``lax.scan`` engine and
the Pallas (max,+) kernel.  Deliberately written as an explicit event loop
with no vectorisation tricks.
"""

from __future__ import annotations

from repro.core.sim import MAX_WAYS, PageOpParams


def simulate_channel_ref(
    op: PageOpParams,
    ways: int,
    n_pages: int,
    batched: bool = False,
) -> float:
    """Completion time (us) of n_pages round-robin page ops on one channel."""
    assert 1 <= ways <= MAX_WAYS
    bus_free = 0.0
    chip_free = [0.0] * ways
    round_start = 0.0
    for i in range(n_pages):
        w = i % ways
        rnd = i // ways
        if w == 0:
            round_start = bus_free
        if batched:
            ready = round_start + (w + 1) * op.cmd_us + op.pre_us
        else:
            ready = chip_free[w] + op.cmd_us + op.pre_us
        start = max(bus_free, ready)
        bus_free = start + op.slot_us
        post = op.post_lo_us if rnd % 2 == 0 else op.post_hi_us
        chip_free[w] = bus_free + post
    return max(bus_free, max(chip_free))


def bandwidth_ref_mb_s(
    op: PageOpParams, ways: int, n_pages: int = 512, batched: bool = False
) -> float:
    end = simulate_channel_ref(op, ways, n_pages, batched)
    return n_pages * op.data_bytes / end
