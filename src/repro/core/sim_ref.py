"""Plain-Python oracle for the multi-channel trace simulation.

Used by unit/property tests to validate both the ``lax.scan`` engine and
the Pallas (max,+) kernel.  Deliberately written as explicit event loops
with no vectorisation tricks.

``simulate_trace_ref`` is the general oracle: it walks a heterogeneous
``OpTrace`` against an ``OpClassTable`` with per-channel buses, the
shared-controller occupancy row and the firmware arbitration charge
(DESIGN.md §2-3).  ``simulate_channel_ref`` is the original
single-channel homogeneous-stream loop, kept verbatim as an independent
cross-check that the trace machinery did not drift.
``simulate_trace_matfold_ref`` is the oracle for the log-depth engines
(DESIGN.md §2.3): it evaluates the same trace through explicit numpy
(max,+) segment products combined pairwise — the combine math of the
segmented parallel-prefix fold, with none of its jax machinery.
"""

from __future__ import annotations

import numpy as np

from repro.core.sim import MAX_WAYS, PageOpParams, policy_is_batched


def _trace_event_loop(table, trace, policy, per_op=None) -> float:
    """The one explicit event loop behind both trace oracles.  Calls
    ``per_op(k, parity, completion_us)`` after each op's state update
    when given.  Request arrivals (``trace.arrival_us``) lower-bound the
    ready base: an op's command cannot issue before its request arrives
    (absent/zero arrivals reproduce the back-to-back loop exactly).
    The per-op reliability surcharge (``trace.extra_us``, read retries +
    jitter, DESIGN.md §2.8) extends the op's *chip* occupancy — retries
    re-run the sense inside the die, so neither the channel bus nor the
    serial controller is held, and a retry storm only delays its own
    request and later ops on the same chip (absent/zero extras add
    +0.0 — exact)."""
    batched = policy_is_batched(policy)   # typos raise, never fall through
    c_count, w_count = trace.channels, trace.ways
    arrival = trace.arrival_us
    extra = trace.extra_us
    bus_free = [0.0] * c_count
    chip_free = [[0.0] * w_count for _ in range(c_count)]
    ctrl_free = 0.0
    round_start = [0.0] * c_count
    for t in range(trace.n_ops):
        k = int(trace.cls[t])
        c = int(trace.channel[t])
        w = int(trace.way[t])
        par = int(trace.parity[t])
        arr = 0.0 if arrival is None else float(arrival[t])
        ext = 0.0 if extra is None else float(extra[t])
        if w == 0:
            round_start[c] = bus_free[c]
        if batched:
            ready = (max(round_start[c], arr)
                     + (w + 1) * table.cmd_us[k] + table.pre_us[k])
        else:
            ready = (max(chip_free[c][w], arr)
                     + table.cmd_us[k] + table.pre_us[k])
        start = max(bus_free[c], ready, ctrl_free) + table.arb_us[k]
        bus_free[c] = start + table.slot_us[k]
        ctrl_free = start + table.ctrl_us[k]
        post = table.post_lo_us[k] if par % 2 == 0 else table.post_hi_us[k]
        chip_free[c][w] = bus_free[c] + post + ext
        if per_op is not None:
            per_op(k, par, chip_free[c][w])
    return float(max(max(bus_free), max(max(row) for row in chip_free)))


def simulate_trace_ref(table, trace, policy: str = "eager") -> float:
    """Completion time (us) of an OpTrace on C channels (trace oracle)."""
    return _trace_event_loop(table, trace, policy)


def simulate_trace_completions_ref(table, trace, policy: str = "eager"
                                   ) -> tuple[float, np.ndarray]:
    """(end_us, [T] per-op completion times) — the oracle twin of
    ``repro.core.sim.trace_completions`` (latency extraction for
    arrival-aware request workloads)."""
    comp: list[float] = []

    def per_op(k, par, done_us):
        comp.append(float(done_us))

    end = _trace_event_loop(table, trace, policy, per_op)
    return end, np.asarray(comp, np.float64)


def trace_bandwidth_ref_mb_s(table, trace, policy: str = "eager") -> float:
    return trace.total_bytes(table) / simulate_trace_ref(table, trace, policy)


def simulate_trace_energy_ref(table, trace, kind,
                              policy: str = "eager"
                              ) -> tuple[float, np.ndarray]:
    """(end_us, [N_OP_PHASES] phase-energy sums in uJ): the event-loop
    oracle accumulating each op's phase energies alongside the recurrence
    (DESIGN.md §2.4).  Pure python floats, no vectorisation."""
    from repro.core.energy import N_OP_PHASES, op_phase_energy_uj

    e_op = np.asarray(op_phase_energy_uj(table, kind), np.float64)
    acc = np.zeros((N_OP_PHASES,), np.float64)

    def per_op(k, par, done_us):
        acc[:] += e_op[k, par % 2]

    end = _trace_event_loop(table, trace, policy, per_op)
    return end, acc


def maxplus_matmul_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(max,+) matrix product in plain numpy (oracle building block)."""
    return np.max(a[..., :, :, None] + b[..., None, :, :], axis=-2)


def simulate_trace_matfold_ref(table, trace, policy: str = "eager",
                               segment_len: int = 64) -> float:
    """Completion time (us) of an OpTrace via explicit (max,+) segment
    products — the oracle for the segmented parallel-prefix engines.

    Each length-``segment_len`` chunk of the trace folds into one step
    matrix with sequential numpy matmuls; the chunk products then
    combine in a pairwise tree (the log-depth combine), and the total
    product applies to the all-free initial state.  Arrivals ride the
    per-op matrices through the origin column (one matrix per op when
    the trace carries them; the shared combo dictionary otherwise)."""
    from repro.core.maxplus_form import (StateLayout, combo_matrices,
                                         end_time_from_state, init_state,
                                         maxplus_eye, op_matrix, trace_combos)

    layout = StateLayout(trace.channels, trace.ways)
    combos, idx = trace_combos(trace)
    if trace.arrival_us is None and trace.extra_us is None:
        mats = combo_matrices(table, combos, layout, policy)
        per_op = [mats[int(m)] for m in idx]
    else:
        per_op = []
        for t in range(trace.n_ops):
            k, c, w = (int(trace.cls[t]), int(trace.channel[t]),
                       int(trace.way[t]))
            par = int(trace.parity[t]) % 2
            per_op.append(op_matrix(
                layout, cmd_us=float(table.cmd_us[k]),
                pre_us=float(table.pre_us[k]),
                slot_us=float(table.slot_us[k]),
                ctrl_us=float(table.ctrl_us[k]),
                arb_us=float(table.arb_us[k]),
                post_us=float(table.post_lo_us[k] if par == 0
                              else table.post_hi_us[k]),
                channel=c, way=w, policy=policy,
                arrival_us=(0.0 if trace.arrival_us is None
                            else float(trace.arrival_us[t])),
                extra_us=(0.0 if trace.extra_us is None
                          else float(trace.extra_us[t]))))
    prods = []
    for lo in range(0, trace.n_ops, segment_len):
        p = maxplus_eye(layout.n_state).astype(np.float64)
        for a in per_op[lo:lo + segment_len]:
            p = maxplus_matmul_np(a.astype(np.float64), p)
        prods.append(p)
    while len(prods) > 1:          # pairwise tree: prods[i+1] is later
        nxt = [maxplus_matmul_np(prods[i + 1], prods[i])
               for i in range(0, len(prods) - 1, 2)]
        if len(prods) % 2:
            nxt.append(prods[-1])
        prods = nxt
    state = np.max(prods[0] + init_state(layout)[None, :], axis=-1)
    return float(end_time_from_state(state, layout))


def simulate_channel_ref(
    op: PageOpParams,
    ways: int,
    n_pages: int,
    batched: bool = False,
) -> float:
    """Completion time (us) of n_pages round-robin page ops on one channel.

    Single-channel homogeneous special case: the shared controller never
    binds (ctrl_us <= slot_us, arb_us = 0), so the original pre-trace loop
    is unchanged."""
    assert 1 <= ways <= MAX_WAYS
    bus_free = 0.0
    chip_free = [0.0] * ways
    round_start = 0.0
    for i in range(n_pages):
        w = i % ways
        rnd = i // ways
        if w == 0:
            round_start = bus_free
        if batched:
            ready = round_start + (w + 1) * op.cmd_us + op.pre_us
        else:
            ready = chip_free[w] + op.cmd_us + op.pre_us
        start = max(bus_free, ready)
        bus_free = start + op.slot_us
        post = op.post_lo_us if rnd % 2 == 0 else op.post_hi_us
        chip_free[w] = bus_free + post
    return max(bus_free, max(chip_free))


def bandwidth_ref_mb_s(
    op: PageOpParams, ways: int, n_pages: int = 512, batched: bool = False
) -> float:
    end = simulate_channel_ref(op, ways, n_pages, batched)
    return n_pages * op.data_bytes / end
