"""Wear-dependent reliability model: read-retry, fault injection, hedging.

DESIGN.md §2.8.  A :class:`FaultSpec` describes a drive's degradation
state — wear level, raw-bit-error-rate (RBER) curve, read-retry step
latencies, program/erase failure probabilities, and an optional
hedged-read mitigation policy.  A :class:`FaultSampler` turns a spec
into concrete per-op effects:

* **read retries** — each read op draws a geometric retry count with
  per-step success probability derived from the wear-scaled RBER
  (arxiv 2104.09611: retry count grows with RBER/ECC margin), paying
  either the spec's explicit ``retry_step_us`` ladder or, when the
  ladder is ``None``, one full re-read (cmd + pre + slot) of its own
  op class per retry;
* **latency jitter** — a uniform ``[0, jitter_us)`` add-on per op;
* **program faults** — each write fails with ``prog_fail_prob`` and is
  remapped: a duplicate write is inserted right after it targeting the
  next non-retired way on the same channel (the failed op keeps its
  bus/cell cost but loses its payload byte credit to the remap);
* **bad-block retirement** — each (channel, way) is retired up front
  with ``erase_fail_prob`` (at least one way per channel survives);
  retired ways are a dispatch constraint for the dynamic policies.

Everything is sampled **outside** the (max,+) fold from PCG64 streams
keyed on ``spec.seed``, so every engine is bit-deterministic given
``(trace, FaultSpec, seed)``: the sampled effects reduce to a per-op
additive latency vector (``OpTrace.extra_us``) plus a trace rewrite,
and the fold itself stays engine-agnostic.  Chunked consumption (the
streaming engine) draws from the *same* streams: NumPy's PCG64 fills
``random((n, 3))`` row-major, so concatenated per-chunk draws are
bit-identical to one one-shot draw — a single carried sampler makes
chunked == one-shot exactly.

This module deliberately imports nothing from ``repro.core.trace`` or
``repro.core.sched`` (both consume it); it works on raw NumPy arrays.

On FTL-translated streams (DESIGN.md §2.10) the ownership splits:
``repro.core.ftl.translate`` owns *block-level* program/erase failure
and bad-block retirement (its own PCG64 stream, disjoint from this
sampler's), because retirement must feed back into the allocator that
chooses the next frontier block.  The query layer then runs this
sampler with those probabilities zeroed, on a READ/WRITE *class view*
of the 7-class op stream, so per-op retry and jitter surcharges still
price host and GC traffic alike.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Mirror trace.READ / trace.WRITE without the circular import; pinned
# by a regression test against repro.core.trace.
READ, WRITE = 0, 1

__all__ = ["FaultSpec", "FaultSampler"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Drive degradation + mitigation policy (all effects optional).

    ``wear`` interpolates the RBER geometrically from ``rber_fresh``
    (wear 0) to ``rber_worn`` (wear 1); the per-retry-step failure
    probability is ``min(rber / rber_ecc_limit, 0.95)``.  A spec whose
    every effect is off (``is_zero``) rewrites any trace to itself plus
    an all-zero ``extra_us`` — bit-identical results on every engine.
    """

    wear: float = 0.0
    rber_fresh: float = 1e-8
    rber_worn: float = 1e-4
    rber_ecc_limit: float = 1e-3
    retry_step_us: tuple[float, ...] | None = None
    max_retries: int = 8
    jitter_us: float = 0.0
    prog_fail_prob: float = 0.0
    erase_fail_prob: float = 0.0
    hedge_fraction: float = 0.0
    hedge_after_us: float | None = None
    seed: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.wear:
            raise ValueError(f"wear must be >= 0, got {self.wear}")
        for name in ("rber_fresh", "rber_worn", "rber_ecc_limit",
                     "jitter_us", "hedge_fraction"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        for name in ("prog_fail_prob", "erase_fail_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.retry_step_us is not None:
            steps = tuple(float(s) for s in self.retry_step_us)
            if any(s < 0 for s in steps):
                raise ValueError("retry_step_us entries must be >= 0")
            object.__setattr__(self, "retry_step_us", steps)
        if self.hedge_after_us is not None and self.hedge_after_us < 0:
            raise ValueError("hedge_after_us must be >= 0")

    def rber(self) -> float:
        """Raw bit error rate at this wear level (geometric in wear)."""
        if self.rber_fresh <= 0.0:
            return 0.0
        return float(self.rber_fresh
                     * (self.rber_worn / self.rber_fresh) ** self.wear)

    def p_retry_step(self) -> float:
        """Per-retry-step failure probability (capped at 0.95)."""
        return float(np.clip(self.rber() / self.rber_ecc_limit, 0.0, 0.95))

    @property
    def is_zero(self) -> bool:
        """True when the rewrite is guaranteed to be a no-op + zeros."""
        return (self.p_retry_step() == 0.0 and self.jitter_us == 0.0
                and self.prog_fail_prob == 0.0
                and self.erase_fail_prob == 0.0 and self.max_retries >= 0)


def _cumcount(key: np.ndarray) -> np.ndarray:
    """Occurrence index of each element within its value group."""
    n = len(key)
    order = np.argsort(key, kind="stable")
    sk = key[order]
    first = np.r_[True, sk[1:] != sk[:-1]]
    grp = np.maximum.accumulate(np.where(first, np.arange(n), 0))
    occ = np.empty(n, np.int64)
    occ[order] = np.arange(n) - grp
    return occ


class FaultSampler:
    """Stateful per-op fault sampler; one instance spans a whole stream.

    Two independent PCG64 streams are derived from ``spec.seed``:
    ``SeedSequence([seed, 0])`` feeds the per-op draws (3 uniforms per
    op: retry, jitter, program-fault) and ``SeedSequence([seed, 1])``
    is consumed once at construction for bad-block retirement — so a
    sampler fed the same ops in any chunking produces bit-identical
    rewrites.  Accumulates ``retry_hist`` / ``n_remap_ops`` across
    chunks.
    """

    def __init__(self, spec: FaultSpec, channels: int, ways: int,
                 table=None) -> None:
        if channels < 1 or ways < 1:
            raise ValueError("channels and ways must be >= 1")
        self.spec = spec
        self.channels = int(channels)
        self.ways = int(ways)
        self._rng = np.random.default_rng(
            np.random.PCG64(np.random.SeedSequence([spec.seed, 0])))
        rng_ret = np.random.default_rng(
            np.random.PCG64(np.random.SeedSequence([spec.seed, 1])))
        retired = rng_ret.random((channels, ways)) < spec.erase_fail_prob
        # every channel keeps at least one live way (a fully-retired
        # channel would make its ops undispatchable)
        retired[retired.all(axis=1), 0] = False
        self.retired = retired
        self._next_way = self._build_next_way(retired)
        if spec.retry_step_us is not None:
            self._cum = np.concatenate(
                [[0.0], np.cumsum(np.asarray(spec.retry_step_us,
                                             np.float64))])
            self._r_cap = min(spec.max_retries, len(spec.retry_step_us))
            self._reread = None
        else:
            if table is None and spec.p_retry_step() > 0.0 \
                    and spec.max_retries > 0:
                raise ValueError(
                    "FaultSpec.retry_step_us is None: pass the OpClassTable "
                    "so retries can charge a per-class re-read")
            self._cum = None
            self._r_cap = spec.max_retries
            self._reread = (None if table is None else np.asarray(
                np.asarray(table.cmd_us, np.float64)
                + np.asarray(table.pre_us, np.float64)
                + np.asarray(table.slot_us, np.float64)))
        self._counts = np.zeros((channels, ways), np.int64)
        self._dirty = False
        self.retry_hist = np.zeros(spec.max_retries + 1, np.int64)
        self.n_remap_ops = 0

    @staticmethod
    def _build_next_way(retired: np.ndarray) -> np.ndarray:
        channels, ways = retired.shape
        nw = np.empty((channels, ways), np.int64)
        for c in range(channels):
            alive = np.flatnonzero(~retired[c])
            for w in range(ways):
                later = alive[alive > w]
                nw[c, w] = later[0] if len(later) else alive[0]
        return nw

    def sample(self, cls: np.ndarray):
        """Draw per-op effects for ``cls`` (consumes 3 uniforms per op).

        Returns ``(extra_us float32, write_fail bool, retries int64)``.
        """
        cls = np.asarray(cls)
        n = len(cls)
        u = self._rng.random((n, 3))
        spec = self.spec
        p = spec.p_retry_step()
        if p > 0.0 and self._r_cap > 0 and n:
            # geometric: P(R >= k) = p^k, truncated at the retry cap;
            # u == 0.0 gives log(0) = -inf -> +inf ratio, caught by the
            # cap before the integer cast
            with np.errstate(divide="ignore"):
                raw = np.floor(np.log(u[:, 0]) / np.log(p))
            r = np.minimum(raw, float(self._r_cap)).astype(np.int64)
        else:
            r = np.zeros(n, np.int64)
        r = np.where(cls == READ, r, 0)
        if self._cum is not None:
            extra = self._cum[r]
        elif self._reread is not None:
            extra = r * self._reread[cls]
        else:                       # table-free: p == 0 so r is all zero
            extra = np.zeros(n)
        if spec.jitter_us > 0.0:
            extra = extra + u[:, 1] * spec.jitter_us
        write_fail = (cls == WRITE) & (u[:, 2] < spec.prog_fail_prob)
        if n:
            self.retry_hist += np.bincount(
                r[cls == READ], minlength=len(self.retry_hist))
        return extra.astype(np.float32), write_fail, r

    def rewrite(self, cls, channel, way, parity, arrival=None, payload=None,
                request_id=None):
        """Sample faults for one chunk of ops and apply the rewrite.

        Inserts a remap write right after each failed write (same
        channel, next non-retired way, zero extra, inheriting the
        payload byte and request id; the failed original keeps its cost
        but drops its payload credit), and recomputes plane parity from
        the first remap onward (per-chip op order shifts there).  All
        arrays are returned rewritten; ``arrival`` / ``payload`` /
        ``request_id`` may be ``None`` and stay ``None``.
        """
        cls = np.asarray(cls, np.int64)
        channel = np.asarray(channel, np.int64)
        way = np.asarray(way, np.int64)
        parity = np.asarray(parity, np.int64)
        extra, write_fail, _ = self.sample(cls)
        fail_idx = np.flatnonzero(write_fail)
        if len(fail_idx):
            ins = fail_idx + 1
            new_of_old = np.arange(len(cls)) + np.searchsorted(
                ins, np.arange(len(cls)), side="right")
            cls2 = np.insert(cls, ins, cls[fail_idx])
            channel2 = np.insert(channel, ins, channel[fail_idx])
            way2 = np.insert(way, ins,
                             self._next_way[channel[fail_idx],
                                            way[fail_idx]])
            parity2 = np.insert(parity, ins, 0)
            extra2 = np.insert(extra.astype(np.float64), ins,
                               0.0).astype(np.float32)
            arrival2 = (None if arrival is None
                        else np.insert(np.asarray(arrival, np.float64), ins,
                                       np.asarray(arrival,
                                                  np.float64)[fail_idx]))
            if payload is None:
                payload2 = None
            else:
                payload2 = np.insert(np.asarray(payload, bool), ins,
                                     np.asarray(payload, bool)[fail_idx])
                payload2[new_of_old[fail_idx]] = False
            request_id2 = (None if request_id is None
                           else np.insert(np.asarray(request_id, np.int64),
                                          ins,
                                          np.asarray(request_id,
                                                     np.int64)[fail_idx]))
            recompute_from = (0 if self._dirty
                              else int(new_of_old[fail_idx[0]]))
            self._dirty = True
            self.n_remap_ops += len(fail_idx)
        else:
            cls2, channel2, way2, parity2, extra2 = (cls, channel, way,
                                                     parity, extra)
            arrival2, payload2, request_id2 = arrival, payload, request_id
            recompute_from = 0 if self._dirty else len(cls2)
        if recompute_from < len(cls2):
            # plane parity = per-chip occurrence count % 2, carried
            # across chunks; untouched before the first remap so a
            # zero-fault spec is bit-identical
            occ = _cumcount(channel2 * self.ways + way2)
            par_new = (self._counts[channel2, way2] + occ) % 2
            mask = np.arange(len(cls2)) >= recompute_from
            parity2 = np.where(mask, par_new, parity2)
        np.add.at(self._counts, (channel2, way2), 1)
        return (cls2, channel2, way2, parity2, arrival2, extra2, payload2,
                request_id2)
