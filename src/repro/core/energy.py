"""Controller energy model (paper §5.3.3, Table 5 / Fig. 10).

The paper measures average SSD-controller power per interface design
(synthesised at 130 nm, worst case: IO 2.7 V / core 1.35 V / 125 C) and
reports energy-per-byte = power / bandwidth.  The three power draws are
recoverable exactly from Table 5 x Table 3 products (E/B * MB/s = mW) and
are constant per design across modes and way counts:

    CONV       22.67 mW @ 50 MHz  SDR
    SYNC_ONLY  42.27 mW @ 83 MHz  SDR
    PROPOSED   47.04 mW @ 83 MHz  DDR

We model them as P = C_eff * V^2 * f with an effective switched
capacitance fitted per design (the DDR datapath toggles the duplicated
FIFO pairs, hence C_eff(PROPOSED) > C_eff(SYNC_ONLY)).

Phase-resolved accounting (DESIGN.md §2.4)
------------------------------------------
``ControllerEnergyModel`` above is the paper's closed form: one constant
power divided by sustained bandwidth.  It cannot price mixed workloads
or say *where* the energy goes, so this module also exposes a
**trace-level decomposition**: every op of an ``OpTrace`` charges energy
to the phases

    cmd    command/address latch cycles on the NAND_IF
    io     data burst on the bus at the interface's toggle rate
           (DDR moves 2 bytes/cycle, so its io *time* halves)
    ecc    cycle-scaled per-channel ECC datapath
    ctrl   clock-independent FTL/firmware occupancy (+ arbitration)
    array  NAND cell array busy (t_R fetch / t_PROG program) — NAND-side
           power, *excluded* from the paper's controller-only metric
    idle   controller powered but not driving an op (derived from the
           simulated makespan, never accumulated per op)

Each controller phase is priced at the design's full power P: the 130 nm
controller is synchronous and never clock-gates, so the free-running
interface clock toggles the datapath whether or not data moves — which
is exactly why the paper measures a *constant* power across way counts
and utilisations.  The phase split therefore partitions the makespan,
not the power, and the controller total recovers the paper's
``P x wall-time`` by construction (up to a <0.5 % sliver where command
latching overlaps another way's data burst on a saturated bus; the idle
remainder is clamped at zero rather than charged negatively).

Per-op phase energies are scalar gathers from the op-class table, so
every simulation engine accumulates them alongside the (max,+) end-time
recurrence (``repro.core.sim.trace_end_time_energy``, the segment sums
of ``repro.core.maxplus_form``, the Pallas fold of
``repro.kernels.maxplus``) and the totals are engine-independent.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.interface import InterfaceKind

V_CORE = 1.35        # volts (paper §5.1 worst-case corner)
FREQ_HZ = {
    InterfaceKind.CONV: 50e6,
    InterfaceKind.SYNC_ONLY: 83e6,
    InterfaceKind.PROPOSED: 83e6,
}

# Controller power (W), recovered from Table 5 x Table 3 (see module doc).
POWER_W = {
    InterfaceKind.CONV: 22.67e-3,
    InterfaceKind.SYNC_ONLY: 42.27e-3,
    InterfaceKind.PROPOSED: 47.04e-3,
}

# NAND array power while the cell array is busy (t_R fetch / t_PROG
# program).  Datasheet-typical active current ~15 mA at Vcc 3.3 V for the
# paper's chips (K9F1G08U0B / K9GAG08U0M); the paper measures controller
# power only, so these never enter the Table 5 metric — they let the
# storage tier price total device energy for mixed workloads.
NAND_ARRAY_READ_W = 0.050
NAND_ARRAY_PROG_W = 0.050

#: Per-op phases, in accumulator order; ``idle`` is derived from the
#: makespan afterwards and is deliberately NOT part of this tuple.
OP_PHASES = ("cmd", "io", "ecc", "ctrl", "array")
N_OP_PHASES = len(OP_PHASES)


@dataclasses.dataclass(frozen=True)
class ControllerEnergyModel:
    kind: InterfaceKind

    @property
    def power_w(self) -> float:
        return POWER_W[self.kind]

    @property
    def c_eff_farad(self) -> float:
        """Effective switched capacitance implied by P = C V^2 f."""
        return self.power_w / (V_CORE**2 * FREQ_HZ[self.kind])

    def energy_nj_per_byte(self, bandwidth_mb_s: float) -> float:
        """nJ per transferred byte at the given sustained bandwidth."""
        if bandwidth_mb_s <= 0:
            raise ValueError("bandwidth must be positive")
        joules_per_byte = self.power_w / (bandwidth_mb_s * 1e6)
        return joules_per_byte * 1e9

    def energy_joules(self, nbytes: int, bandwidth_mb_s: float) -> float:
        """Energy to move ``nbytes`` at the given bandwidth (controller only)."""
        if bandwidth_mb_s <= 0:
            raise ValueError("bandwidth must be positive")
        return self.power_w * (nbytes / (bandwidth_mb_s * 1e6))


def energy_nj_per_byte(kind: InterfaceKind | str, bandwidth_mb_s: float) -> float:
    return ControllerEnergyModel(InterfaceKind(kind)).energy_nj_per_byte(bandwidth_mb_s)


# ---------------------------------------------------------------------------
# Phase-resolved trace accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    """Phase-resolved energy of one simulated trace window (joules).

    ``cmd/io/ecc/ctrl`` are controller phases accumulated per op by the
    engines; ``idle_j`` is the remainder of the constant-power envelope
    ``channels * P * end_us``; ``array_j`` is NAND-side and excluded
    from the paper's controller-only metric."""

    cmd_j: float
    io_j: float
    ecc_j: float
    ctrl_j: float
    idle_j: float
    array_j: float
    end_us: float
    payload_bytes: int
    kind: InterfaceKind
    channels: int = 1

    @property
    def controller_j(self) -> float:
        """Controller energy — the paper's Table 5 / Fig. 10 quantity."""
        return self.cmd_j + self.io_j + self.ecc_j + self.ctrl_j + self.idle_j

    @property
    def total_j(self) -> float:
        return self.controller_j + self.array_j

    @property
    def nj_per_byte(self) -> float:
        """Controller nJ per *payload* byte (hedged duplicates burn
        energy but deliver no payload, so they raise this)."""
        if self.payload_bytes <= 0:
            raise ValueError("no payload bytes to amortise energy over")
        return self.controller_j / self.payload_bytes * 1e9

    def op_sums_uj(self) -> np.ndarray:
        """[N_OP_PHASES] accumulator the engines produced (microjoules)."""
        return np.array([self.cmd_j, self.io_j, self.ecc_j, self.ctrl_j,
                         self.array_j], np.float64) * 1e6

    def extrapolated(self, scale: float, end_us: float) -> "EnergyBreakdown":
        """Scale the simulated window to a longer steady run: per-op
        phases scale by op count (``scale``), idle re-derives from the
        extrapolated wall time ``end_us`` (so e.g. a SATA-capped stream
        converts the extra wall-clock into idle energy)."""
        if scale < 0 or end_us < 0:
            raise ValueError("extrapolation must be non-negative")
        return breakdown_from_sums(
            self.op_sums_uj() * scale, end_us=end_us,
            payload_bytes=int(round(self.payload_bytes * scale)),
            kind=self.kind, channels=self.channels)

    def describe(self) -> str:
        mj = 1e3
        return (f"{self.kind.value}: {self.controller_j * mj:.2f} mJ ctrl "
                f"(cmd {self.cmd_j * mj:.3f} / io {self.io_j * mj:.3f} / "
                f"ecc {self.ecc_j * mj:.3f} / fw {self.ctrl_j * mj:.3f} / "
                f"idle {self.idle_j * mj:.3f}) + {self.array_j * mj:.2f} mJ "
                f"array over {self.end_us / 1e3:.2f} ms")


def op_phase_energy_uj(table, kind: InterfaceKind | str) -> np.ndarray:
    """[K, 2, N_OP_PHASES] per-op phase energies (microjoules = W * us).

    Axis 1 is MLC page parity (lower/upper program time differ); only
    the ``array`` phase depends on it.  Requires the table's ``io_us``
    column (the bus data-burst time) to split the slot into
    io / cycle-scaled ecc / firmware parts:

        slot_us = io_us + ecc_scaled_us + ctrl_us      (both op classes)
    """
    kind = InterfaceKind(kind)
    p_w = POWER_W[kind]
    if getattr(table, "io_us", None) is None:
        raise ValueError(
            "op-class table carries no io_us column; build it with "
            "repro.core.trace.op_class_table")
    cmd = np.asarray(table.cmd_us, np.float64)
    io = np.asarray(table.io_us, np.float64)
    slot = np.asarray(table.slot_us, np.float64)
    ctrl = np.asarray(table.ctrl_us, np.float64)
    arb = np.asarray(table.arb_us, np.float64)
    ecc_scaled = np.maximum(slot - io - ctrl, 0.0)
    pre = np.asarray(table.pre_us, np.float64)
    post = np.stack([np.asarray(table.post_lo_us, np.float64),
                     np.asarray(table.post_hi_us, np.float64)], axis=1)
    array = (NAND_ARRAY_READ_W * pre)[:, None] + NAND_ARRAY_PROG_W * post
    static = np.stack([p_w * cmd, p_w * io, p_w * ecc_scaled,
                       p_w * (ctrl + arb)], axis=1)          # [K, 4]
    e = np.concatenate(
        [np.broadcast_to(static[:, None, :], (len(cmd), 2, 4)),
         array[:, :, None]], axis=2)
    return np.ascontiguousarray(e, dtype=np.float32)


def breakdown_from_sums(op_sums_uj, end_us: float, payload_bytes: int,
                        kind: InterfaceKind | str,
                        channels: int = 1) -> EnergyBreakdown:
    """Assemble an ``EnergyBreakdown`` from engine accumulator sums.

    ``op_sums_uj`` is the [N_OP_PHASES] per-op accumulator (microjoules)
    every engine produces alongside the end-time recurrence; ``idle`` is
    the remainder of the constant-power envelope
    ``channels * P * end_us`` after the controller phases (clamped at
    zero for the saturated-bus overlap sliver, see module doc)."""
    kind = InterfaceKind(kind)
    s = np.asarray(op_sums_uj, np.float64)
    if s.shape != (N_OP_PHASES,):
        raise ValueError(f"expected [{N_OP_PHASES}] phase sums, got {s.shape}")
    cmd, io, ecc, ctrl, array = (float(x) for x in s)
    busy = cmd + io + ecc + ctrl
    idle = max(0.0, channels * POWER_W[kind] * float(end_us) - busy)
    uj = 1e-6
    return EnergyBreakdown(
        cmd_j=cmd * uj, io_j=io * uj, ecc_j=ecc * uj, ctrl_j=ctrl * uj,
        idle_j=idle * uj, array_j=array * uj,
        end_us=float(end_us), payload_bytes=int(payload_bytes),
        kind=kind, channels=channels)
