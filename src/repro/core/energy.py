"""Controller energy model (paper §5.3.3, Table 5 / Fig. 10).

The paper measures average SSD-controller power per interface design
(synthesised at 130 nm, worst case: IO 2.7 V / core 1.35 V / 125 C) and
reports energy-per-byte = power / bandwidth.  The three power draws are
recoverable exactly from Table 5 x Table 3 products (E/B * MB/s = mW) and
are constant per design across modes and way counts:

    CONV       22.67 mW @ 50 MHz  SDR
    SYNC_ONLY  42.27 mW @ 83 MHz  SDR
    PROPOSED   47.04 mW @ 83 MHz  DDR

We model them as P = C_eff * V^2 * f with an effective switched
capacitance fitted per design (the DDR datapath toggles the duplicated
FIFO pairs, hence C_eff(PROPOSED) > C_eff(SYNC_ONLY)).
"""

from __future__ import annotations

import dataclasses

from repro.core.interface import InterfaceKind

V_CORE = 1.35        # volts (paper §5.1 worst-case corner)
FREQ_HZ = {
    InterfaceKind.CONV: 50e6,
    InterfaceKind.SYNC_ONLY: 83e6,
    InterfaceKind.PROPOSED: 83e6,
}

# Controller power (W), recovered from Table 5 x Table 3 (see module doc).
POWER_W = {
    InterfaceKind.CONV: 22.67e-3,
    InterfaceKind.SYNC_ONLY: 42.27e-3,
    InterfaceKind.PROPOSED: 47.04e-3,
}


@dataclasses.dataclass(frozen=True)
class ControllerEnergyModel:
    kind: InterfaceKind

    @property
    def power_w(self) -> float:
        return POWER_W[self.kind]

    @property
    def c_eff_farad(self) -> float:
        """Effective switched capacitance implied by P = C V^2 f."""
        return self.power_w / (V_CORE**2 * FREQ_HZ[self.kind])

    def energy_nj_per_byte(self, bandwidth_mb_s: float) -> float:
        """nJ per transferred byte at the given sustained bandwidth."""
        if bandwidth_mb_s <= 0:
            raise ValueError("bandwidth must be positive")
        joules_per_byte = self.power_w / (bandwidth_mb_s * 1e6)
        return joules_per_byte * 1e9

    def energy_joules(self, nbytes: int, bandwidth_mb_s: float) -> float:
        """Energy to move ``nbytes`` at the given bandwidth (controller only)."""
        return self.power_w * (nbytes / (bandwidth_mb_s * 1e6))


def energy_nj_per_byte(kind: InterfaceKind | str, bandwidth_mb_s: float) -> float:
    return ControllerEnergyModel(InterfaceKind(kind)).energy_nj_per_byte(bandwidth_mb_s)
