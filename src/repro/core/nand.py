"""NAND flash chip models (behavioural timing level).

Datasheet-derived parameters for the two cell types used in the paper:

* SLC — modelled after Samsung K9F1G08U0B [26]: 2 KiB + 64 B pages,
  t_R = 25 us.
* MLC — modelled after Samsung K9GAG08U0M [27]: 4 KiB + 128 B pages,
  t_R = 60 us.

``t_prog_eff`` is the *effective* per-page program occupancy seen by the
interface (cell programming + program-verify + status handshake as one
lump).  Datasheet "typical" values (200 us SLC / 800 us MLC) underestimate
what the paper's RTL co-simulation charges per page; we calibrate one
scalar per cell type against Table 3 (see ``repro.core.calibrate``) and
disclose the fitted value here.  Everything else is straight datasheet.
"""

from __future__ import annotations

import dataclasses
import enum


class CellType(str, enum.Enum):
    SLC = "slc"
    MLC = "mlc"


@dataclasses.dataclass(frozen=True)
class NandChipParams:
    cell: CellType
    page_data_bytes: int      # user data per page
    page_spare_bytes: int     # spare (ECC/meta) bytes transferred with the page
    t_r_us: float             # cell array -> page register fetch time
    t_prog_lo_us: float       # effective program time, even pages (SLC: all)
    t_prog_hi_us: float       # effective program time, odd pages (MLC upper)
    t_poll_cycles: float      # per-way status-poll occupancy per write slot,
                              # charged in BUS CYCLES (ready/busy polling runs
                              # at the interface clock, so the DDR interface
                              # polls proportionally faster)
    t_byte_ns: float = 12.0   # page register <-> latch transfer time [28]
    t_bers_us: float = 1500.0  # block erase time (t_BERS) — consumed by the
                               # FTL stage's ERASE op class (DESIGN.md §2.10)

    @property
    def page_total_bytes(self) -> int:
        return self.page_data_bytes + self.page_spare_bytes

    @property
    def t_prog_eff_us(self) -> float:
        return 0.5 * (self.t_prog_lo_us + self.t_prog_hi_us)


# t_prog_*/t_poll calibrated on Table 3 (see calibrate.py; datasheet
# typicals are 200/800 us mean program time).  MLC programs paired pages
# with strongly asymmetric lower/upper times; the alternation (not just the
# mean) is what limits MLC write interleaving (paper §5.3.1 Case III).
SLC = NandChipParams(
    cell=CellType.SLC,
    page_data_bytes=2048,
    page_spare_bytes=64,
    t_r_us=25.0,
    t_prog_lo_us=218.0,
    t_prog_hi_us=218.0,
    t_poll_cycles=0.0,
)

MLC = NandChipParams(
    cell=CellType.MLC,
    page_data_bytes=4096,
    page_spare_bytes=128,
    t_r_us=60.0,
    t_prog_lo_us=200.0,
    t_prog_hi_us=1500.0,
    t_poll_cycles=65.0,
    t_bers_us=2000.0,
)

CHIPS = {CellType.SLC: SLC, CellType.MLC: MLC}


def chip(cell: CellType | str) -> NandChipParams:
    return CHIPS[CellType(cell)]
