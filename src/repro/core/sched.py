"""Scheduler / dispatch layer: maps request workloads onto flash
geometry (DESIGN.md §2.6).

The paper's firmware decides *statically* where every page lands (the
builders' round-robin).  FMMU (PAPERS.md) argues the map/dispatch layer
is what gates SSD scalability; this module makes it a policy axis:

* **Static policies** decide placement offline from the op sequence
  alone and lower a :class:`repro.core.workload.RequestStream` to an
  ``OpTrace`` — so they reach *every* engine (scan / prefix / squaring /
  pallas / oracle), including the log-depth and batched forms:

  - ``stripe``       — channel-first round-robin (channel = t mod C,
    way advances after a channel sweep).  Exactly the retired builders'
    ``_round_robin``; the zero-arrival lowering is regression-pinned
    equal to the old trace builders.
  - ``round_robin``  — way-first round-robin (way = t mod W, channel
    advances after a way sweep): fills one channel's ways before moving
    on, the other canonical firmware loop.

  Hedged duplicate requests (``payload=False``) mirror their primary's
  placement shifted one channel — the datapipe hedging rule.

* **Dynamic policies** cannot be lowered offline — the assignment
  depends on simulated occupancy, so they run as a joint
  dispatch+simulate fold (``repro.core.sim.dispatch_trace``) whose
  carried occupancy row drives the decision:

  - ``least_loaded``   — op goes to the chip whose busy horizon ends
    first (global greedy);
  - ``earliest_ready`` — op goes to the channel whose bus drains first,
    then its least-loaded way.

Engines advertise dynamic support through the ``dispatch`` capability
in the ``repro.core.api`` registry.

The reliability layer (DESIGN.md §2.8) enters here as a trace-rewrite
pass: :func:`apply_faults` samples a :class:`repro.core.faults.FaultSpec`
against a placed ``OpTrace`` — read-retry/jitter surcharges land in
``extra_us`` and program faults insert remap writes targeting the next
non-retired way (bad-block retirement is also a dispatch constraint for
the dynamic policies, which never place an op on a retired way).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.faults import FaultSampler, FaultSpec
from repro.core.trace import OpTrace, _finalize
from repro.core.workload import RequestStream, request_ops

STATIC_POLICIES: tuple[str, ...] = ("stripe", "round_robin")
DYNAMIC_POLICIES: tuple[str, ...] = ("least_loaded", "earliest_ready")
SCHED_POLICIES: tuple[str, ...] = STATIC_POLICIES + DYNAMIC_POLICIES


def policy_is_dynamic(policy: str) -> bool:
    """Validate a scheduler-policy literal once and return whether it
    needs the in-fold dispatch engine (mirrors
    ``sim.policy_is_batched`` for issue policies)."""
    if policy not in SCHED_POLICIES:
        raise ValueError(
            f"unknown sched policy {policy!r} (static: "
            f"{', '.join(STATIC_POLICIES)}; dynamic: "
            f"{', '.join(DYNAMIC_POLICIES)})")
    return policy in DYNAMIC_POLICIES


@dataclasses.dataclass(frozen=True)
class LoweredWorkload:
    """A request stream lowered onto a geometry: the placed ``OpTrace``
    plus the op→request map latency accounting needs.  ``trace`` keeps
    ``arrival_us=None`` when every arrival is zero, so zero-arrival
    lowerings are field-for-field identical to the retired builders."""

    trace: OpTrace
    request_id: np.ndarray          # int32 [T] op -> request index
    request_arrival_us: np.ndarray  # float32 [R]

    def request_latencies(self, completion_us) -> np.ndarray:
        """[R] request latency: last page-op completion − arrival, for
        *every* request including non-payload hedge duplicates — the
        query layer filters to payload requests before reporting
        percentiles (a duplicate is transport, not a request)."""
        comp = np.asarray(completion_us, np.float64)
        done = np.zeros(len(self.request_arrival_us), np.float64)
        np.maximum.at(done, self.request_id, comp)
        return done - np.asarray(self.request_arrival_us, np.float64)


def lower_static(stream: RequestStream, channels: int, ways: int,
                 policy: str = "stripe") -> LoweredWorkload:
    """Lower a request stream to a placed ``OpTrace`` under a static
    policy (see module docstring).  Placement slots advance over
    *payload* ops only; non-payload (hedged duplicate) ops copy their
    primary's placement shifted one channel."""
    if policy_is_dynamic(policy):
        raise ValueError(
            f"sched policy {policy!r} is dynamic — it cannot be lowered "
            "offline; run it through Simulator.run(workload=...) / "
            "sim.dispatch_trace (engines with the 'dispatch' capability)")
    cls, arrival, req_id, payload = request_ops(stream)
    slots = np.cumsum(payload) - 1                  # payload-op slot index
    if policy == "stripe":
        chan = slots % channels
        way = (slots // channels) % ways
    else:                                           # "round_robin": way-first
        way = slots % ways
        chan = (slots // ways) % channels
    if not payload.all():
        hof = (np.full(stream.n_requests, -1, np.int64)
               if stream.hedge_of is None
               else np.asarray(stream.hedge_of, np.int64))
        h = hof[req_id]                             # primary request per op
        is_h = h >= 0
        # duplicates without an explicit primary link: legacy adjacency
        # rule (their stagnant slot is the preceding payload op's)
        chan = np.where(~payload & ~is_h, (chan + 1) % channels, chan)
        if is_h.any():
            # hedge_of-linked duplicates mirror op j of their primary
            # request shifted one channel AND one way.  The channel
            # shift is the replica-read rule; the way shift keeps the
            # duplicate off the chip the stripe is about to reuse for
            # the *next* payload op — without it every duplicate queues
            # on exactly that chip and (FCFS issue being serial through
            # the controller) convoys the whole stream, inverting the
            # mitigation it exists to provide.
            reps = np.asarray(stream.n_pages, np.int64)
            starts = np.cumsum(reps) - reps         # [R] first-op index
            pos = np.arange(len(cls)) - starts[req_id]
            src = starts[np.clip(h, 0, None)] + pos
            chan = np.where(is_h, (chan[src] + 1) % channels, chan)
            way = np.where(is_h, (way[src] + 1) % ways, way)
    # _finalize owns the MLC per-chip page-parity derivation (the one
    # definition every trace builder shares); arrivals ride on top
    trace = dataclasses.replace(
        _finalize(cls, chan, way, channels, ways,
                  payload=None if payload.all() else payload),
        arrival_us=None if not np.any(arrival) else arrival)
    return LoweredWorkload(
        trace=trace, request_id=req_id,
        request_arrival_us=np.asarray(stream.arrival_us, np.float32))


def lower_ops(cls, arrival_us, channels: int, ways: int,
              policy: str = "stripe", payload=None) -> OpTrace:
    """Lower an already-expanded *op* stream (per-op class/arrival
    arrays) to a placed ``OpTrace`` under a static policy.

    This is the lowering the FTL stage uses (DESIGN.md §2.10): its
    translated stream interleaves host ops with GC relocation ops, and
    every op — payload or not — advances the placement slot, so GC
    traffic competes with host traffic for channels and ways exactly
    like the dynamic dispatch fold makes it compete for occupancy.
    (``lower_static`` differs deliberately: there, non-payload ops are
    hedged *duplicates* that mirror their primary's placement instead
    of consuming a slot.)"""
    if policy_is_dynamic(policy):
        raise ValueError(
            f"sched policy {policy!r} is dynamic — it cannot be lowered "
            "offline; run it through Simulator.run(workload=...) / "
            "sim.dispatch_trace (engines with the 'dispatch' capability)")
    cls = np.asarray(cls, np.int32)
    arrival = np.asarray(arrival_us, np.float32)
    slots = np.arange(len(cls))
    if policy == "stripe":
        chan = slots % channels
        way = (slots // channels) % ways
    else:                                           # "round_robin": way-first
        way = slots % ways
        chan = (slots // ways) % channels
    if payload is not None:
        payload = np.asarray(payload, bool)
        if payload.all():
            payload = None
    return dataclasses.replace(
        _finalize(cls, chan, way, channels, ways, payload=payload),
        arrival_us=None if not np.any(arrival) else arrival)


def apply_faults(trace: OpTrace, spec: FaultSpec, table=None, *,
                 sampler: FaultSampler | None = None,
                 request_id: np.ndarray | None = None
                 ) -> tuple[OpTrace, np.ndarray | None, FaultSampler]:
    """Rewrite a placed ``OpTrace`` under a :class:`FaultSpec`
    (DESIGN.md §2.8): read-retry + jitter surcharges land in
    ``extra_us`` and each program fault inserts a remap write right
    after the failed op, targeting the next non-retired way on the same
    channel (the failed original keeps its bus/cell cost but loses its
    payload byte credit to the remap, so byte totals are conserved).

    Returns ``(trace2, request_id2, sampler)`` — ``request_id2`` is the
    op→request map with remap ops inheriting their request (None in,
    None out), and the returned sampler carries the accumulated
    ``retry_hist`` / ``n_remap_ops`` / ``retired`` state (pass it back
    in for chunked streams so every chunk draws from the same PCG64
    position).  ``table`` (the OpClassTable) is required only when
    ``spec.retry_step_us`` is None, to price a retry as one re-read of
    its own op class."""
    if trace.extra_us is not None:
        raise ValueError(
            "trace already carries extra_us — faults were already applied "
            "(apply_faults must run once per stream)")
    if sampler is None:
        sampler = FaultSampler(spec, trace.channels, trace.ways, table)
    payload = trace.payload
    if payload is None and spec.prog_fail_prob > 0.0:
        # byte conservation needs an explicit mask once remaps can strip
        # a failed write's credit (None means "all payload")
        payload = np.ones(trace.n_ops, bool)
    cls2, ch2, w2, par2, arr2, ext2, pay2, rid2 = sampler.rewrite(
        np.asarray(trace.cls), np.asarray(trace.channel),
        np.asarray(trace.way), np.asarray(trace.parity),
        arrival=trace.arrival_us, payload=payload, request_id=request_id)
    trace2 = OpTrace(
        cls=cls2.astype(np.int32), channel=ch2.astype(np.int32),
        way=w2.astype(np.int32), parity=par2.astype(np.int32),
        channels=trace.channels, ways=trace.ways,
        payload=(None if pay2 is None or pay2.all()
                 else np.asarray(pay2, bool)),
        arrival_us=(None if arr2 is None
                    else np.asarray(arr2, np.float32)),
        extra_us=np.asarray(ext2, np.float32))
    return trace2, rid2, sampler


def lower_ops_chunk(cls, arrival_us, channels: int, ways: int,
                    policy: str = "stripe", payload=None,
                    slot_offset: int = 0) -> tuple[OpTrace, int]:
    """Chunked form of :func:`lower_ops`: lower one slice of an op
    stream whose earlier ops already consumed ``slot_offset`` placement
    slots, so concatenating the per-chunk traces is field-for-field
    identical to lowering the whole stream at once.

    Placement at a nonzero offset needs the page parity in closed form
    (``_finalize`` counts per-chip ops from zero): under both static
    policies every op advances the slot, each chip sees every
    ``channels * ways``-th slot, so op ``s``'s per-chip ordinal is
    ``s // (channels * ways)`` and its MLC parity is that ordinal mod 2
    — regression-pinned against ``_finalize`` in the sched tests.

    Returns ``(trace, next_offset)``; feed ``next_offset`` to the next
    chunk.  This is what lets the FTL translation stream through
    ``trace_chunk_fold`` (DESIGN.md §2.11) without materialising the
    full aged op trace."""
    if policy_is_dynamic(policy):
        raise ValueError(
            f"sched policy {policy!r} is dynamic — it cannot be lowered "
            "offline; run it through Simulator.run(workload=...) / "
            "sim.dispatch_trace (engines with the 'dispatch' capability)")
    cls = np.asarray(cls, np.int32)
    arrival = np.asarray(arrival_us, np.float32)
    slots = slot_offset + np.arange(len(cls))
    if policy == "stripe":
        chan = slots % channels
        way = (slots // channels) % ways
    else:                                           # "round_robin": way-first
        way = slots % ways
        chan = (slots // ways) % channels
    parity = (slots // (channels * ways)) % 2
    if payload is not None:
        payload = np.asarray(payload, bool)
        if payload.all():
            payload = None
    trace = OpTrace(
        cls=cls, channel=chan.astype(np.int32), way=way.astype(np.int32),
        parity=parity.astype(np.int32), channels=channels, ways=ways,
        payload=payload,
        arrival_us=None if not np.any(arrival) else arrival)
    return trace, slot_offset + len(cls)
