"""Scheduler / dispatch layer: maps request workloads onto flash
geometry (DESIGN.md §2.6).

The paper's firmware decides *statically* where every page lands (the
builders' round-robin).  FMMU (PAPERS.md) argues the map/dispatch layer
is what gates SSD scalability; this module makes it a policy axis:

* **Static policies** decide placement offline from the op sequence
  alone and lower a :class:`repro.core.workload.RequestStream` to an
  ``OpTrace`` — so they reach *every* engine (scan / prefix / squaring /
  pallas / oracle), including the log-depth and batched forms:

  - ``stripe``       — channel-first round-robin (channel = t mod C,
    way advances after a channel sweep).  Exactly the retired builders'
    ``_round_robin``; the zero-arrival lowering is regression-pinned
    equal to the old trace builders.
  - ``round_robin``  — way-first round-robin (way = t mod W, channel
    advances after a way sweep): fills one channel's ways before moving
    on, the other canonical firmware loop.

  Hedged duplicate requests (``payload=False``) mirror their primary's
  placement shifted one channel — the datapipe hedging rule.

* **Dynamic policies** cannot be lowered offline — the assignment
  depends on simulated occupancy, so they run as a joint
  dispatch+simulate fold (``repro.core.sim.dispatch_trace``) whose
  carried occupancy row drives the decision:

  - ``least_loaded``   — op goes to the chip whose busy horizon ends
    first (global greedy);
  - ``earliest_ready`` — op goes to the channel whose bus drains first,
    then its least-loaded way.

Engines advertise dynamic support through the ``dispatch`` capability
in the ``repro.core.api`` registry.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.trace import OpTrace, _finalize
from repro.core.workload import RequestStream, request_ops

STATIC_POLICIES: tuple[str, ...] = ("stripe", "round_robin")
DYNAMIC_POLICIES: tuple[str, ...] = ("least_loaded", "earliest_ready")
SCHED_POLICIES: tuple[str, ...] = STATIC_POLICIES + DYNAMIC_POLICIES


def policy_is_dynamic(policy: str) -> bool:
    """Validate a scheduler-policy literal once and return whether it
    needs the in-fold dispatch engine (mirrors
    ``sim.policy_is_batched`` for issue policies)."""
    if policy not in SCHED_POLICIES:
        raise ValueError(
            f"unknown sched policy {policy!r} (static: "
            f"{', '.join(STATIC_POLICIES)}; dynamic: "
            f"{', '.join(DYNAMIC_POLICIES)})")
    return policy in DYNAMIC_POLICIES


@dataclasses.dataclass(frozen=True)
class LoweredWorkload:
    """A request stream lowered onto a geometry: the placed ``OpTrace``
    plus the op→request map latency accounting needs.  ``trace`` keeps
    ``arrival_us=None`` when every arrival is zero, so zero-arrival
    lowerings are field-for-field identical to the retired builders."""

    trace: OpTrace
    request_id: np.ndarray          # int32 [T] op -> request index
    request_arrival_us: np.ndarray  # float32 [R]

    def request_latencies(self, completion_us) -> np.ndarray:
        """[R] request latency: last page-op completion − arrival, for
        *every* request including non-payload hedge duplicates — the
        query layer filters to payload requests before reporting
        percentiles (a duplicate is transport, not a request)."""
        comp = np.asarray(completion_us, np.float64)
        done = np.zeros(len(self.request_arrival_us), np.float64)
        np.maximum.at(done, self.request_id, comp)
        return done - np.asarray(self.request_arrival_us, np.float64)


def lower_static(stream: RequestStream, channels: int, ways: int,
                 policy: str = "stripe") -> LoweredWorkload:
    """Lower a request stream to a placed ``OpTrace`` under a static
    policy (see module docstring).  Placement slots advance over
    *payload* ops only; non-payload (hedged duplicate) ops copy their
    primary's placement shifted one channel."""
    if policy_is_dynamic(policy):
        raise ValueError(
            f"sched policy {policy!r} is dynamic — it cannot be lowered "
            "offline; run it through Simulator.run(workload=...) / "
            "sim.dispatch_trace (engines with the 'dispatch' capability)")
    cls, arrival, req_id, payload = request_ops(stream)
    slots = np.cumsum(payload) - 1                  # payload-op slot index
    if policy == "stripe":
        chan = slots % channels
        way = (slots // channels) % ways
    else:                                           # "round_robin": way-first
        way = slots % ways
        chan = (slots // ways) % channels
    if not payload.all():
        # hedged duplicates: primary's placement, one channel over
        chan = np.where(payload, chan, (chan + 1) % channels)
    # _finalize owns the MLC per-chip page-parity derivation (the one
    # definition every trace builder shares); arrivals ride on top
    trace = dataclasses.replace(
        _finalize(cls, chan, way, channels, ways,
                  payload=None if payload.all() else payload),
        arrival_us=None if not np.any(arrival) else arrival)
    return LoweredWorkload(
        trace=trace, request_id=req_id,
        request_arrival_us=np.asarray(stream.arrival_us, np.float32))
