"""Controller<->NAND interface models: CONV, SYNC_ONLY, PROPOSED.

Each interface is reduced to the parameters the SSD-level simulator needs:

* ``cycle_ns``          — bus clock period (from §5.2: 20 ns / 12 ns).
* ``bytes_per_cycle``   — 1 for SDR, 2 for DDR.
* ``cmd_cycles``        — command+address cycles per page op (2 CMD + 5 ADDR).
* ``ecc_cycles/ecc_fixed_us`` — controller-side ECC/FTL occupancy per page,
  modelled as ``cycles * t_P + fixed`` and calibrated per cell type on the
  paper's saturated-bandwidth cells (see calibrate.py).  MLC ECC is heavier
  (§2.2.1: "The ECC block is essential ... especially when the MLC flash is
  used").
* ``poll_fixed_us``     — constant per-page status/poll overhead charged in
  the write path (ready/busy handshake + firmware loop).

The derived per-page bus times are exact functions of these.
"""

from __future__ import annotations

import dataclasses
import enum

from repro.core import timing
from repro.core.nand import CellType, NandChipParams


class InterfaceKind(str, enum.Enum):
    CONV = "conv"            # asynchronous SDR (paper §3)
    SYNC_ONLY = "sync_only"  # synchronous SDR, DVS of [23] (paper §5.3)
    PROPOSED = "proposed"    # synchronous DDR (paper §4)


@dataclasses.dataclass(frozen=True)
class EccParams:
    cycles: float       # part of ECC occupancy that scales with the bus clock
    fixed_us: float     # clock-independent part (firmware / FTL per page)


# Calibrated on Table 3 saturated cells (see calibrate.py).
ECC = {
    CellType.SLC: EccParams(cycles=117.0, fixed_us=3.26),
    CellType.MLC: EccParams(cycles=312.0, fixed_us=7.86),
}

WRITE_POLL_FIXED_US = 3.7  # constant status-poll overhead per written page


@dataclasses.dataclass(frozen=True)
class InterfaceParams:
    kind: InterfaceKind
    cycle_ns: float
    bytes_per_cycle: int
    cmd_cycles: int = 7  # 2 command + 5 address latch cycles

    @property
    def cmd_us(self) -> float:
        return self.cmd_cycles * self.cycle_ns * 1e-3

    def data_us(self, nbytes: int) -> float:
        """Bus occupancy of an n-byte burst."""
        return nbytes * self.cycle_ns * 1e-3 / self.bytes_per_cycle

    def ecc_us(self, cell: CellType) -> float:
        e = ECC[cell]
        return e.cycles * self.cycle_ns * 1e-3 + e.fixed_us

    def ecc_fixed_us(self, cell: CellType) -> float:
        """Clock-independent FTL/firmware share of the ECC occupancy.

        The cycle-scaled part runs on the per-channel ECC block (§2.2.1:
        every channel carries its own NAND_IF + ECC hardware); only this
        fixed firmware part occupies the single shared controller thread
        in the multi-channel simulation (DESIGN.md §3)."""
        return ECC[cell].fixed_us

    def read_slot_us(self, chip: NandChipParams) -> float:
        """Bus+controller occupancy of one page read (excl. t_R)."""
        return self.cmd_us + self.data_us(chip.page_total_bytes) + self.ecc_us(chip.cell)

    def write_slot_us(self, chip: NandChipParams) -> float:
        """Bus+controller occupancy of one page write (excl. t_PROG)."""
        return (
            self.cmd_us
            + self.data_us(chip.page_total_bytes)
            + self.ecc_us(chip.cell)
            + WRITE_POLL_FIXED_US
        )


def make_interface(kind: InterfaceKind | str) -> InterfaceParams:
    """Build interface params at the paper's derived operating points.

    CONV runs at 50 MHz SDR, SYNC_ONLY at 83 MHz SDR, PROPOSED at 83 MHz
    DDR — exactly the §5.2 derivation (Eqs. 6 and 9 + 1 MHz flooring).
    """
    kind = InterfaceKind(kind)
    clocks = timing.derive_paper_clocks()
    if kind == InterfaceKind.CONV:
        return InterfaceParams(kind, cycle_ns=clocks.conv_cycle_ns, bytes_per_cycle=1)
    if kind == InterfaceKind.SYNC_ONLY:
        return InterfaceParams(kind, cycle_ns=clocks.prop_cycle_ns, bytes_per_cycle=1)
    return InterfaceParams(kind, cycle_ns=clocks.prop_cycle_ns, bytes_per_cycle=2)


ALL_INTERFACES = tuple(InterfaceKind)
