"""Flash translation layer: L2P mapping, garbage collection, write
amplification (DESIGN.md §2.10).

Every engine in this repo simulates *physical* page ops.  A real drive
inserts a firmware stage between the host and the flash: the FTL keeps
a logical→physical page map, writes out-of-place into an append-only
frontier, and — when the free-block pool runs low — relocates the still
-valid pages of a victim block and erases it.  That relocation traffic
(GC) is what makes a sustained-overwrite ("aged") drive slower than a
fresh one, and the ratio of physical to host page writes is the write
amplification factor (WAF) every overprovisioning decision trades
against.

This module is the stage between ``repro.core.workload`` and
``repro.core.sched``:

* :class:`FTLSpec` — geometry (blocks × pages/block), overprovisioning
  ratio, GC victim policy, per-op L2P firmware charge, preconditioning;
* :func:`translate` — deterministically expands a placement-free
  :class:`~repro.core.workload.RequestStream` into the *physical* op
  stream the drive executes: host reads/writes re-classed to their
  map-charged FTL classes, GC relocation ops (victim reads + remap
  writes + a block erase) injected at the triggering host op's arrival
  time, all as ordinary trace ops — so the translated stream lowers
  through the existing scheduler and reaches every engine unchanged,
  and all five heterogeneous engines stay bit-agreeing on it;
* :func:`ftl_op_class_table` — the 7-class timing table the translated
  stream indexes (host read/write, map-charged FTL read/write, GC
  read/write, block erase).  The L2P lookup/update cost is charged as
  *controller* time per op (FMMU, arxiv 1704.03168: map management is
  firmware work that serialises through the controller, not free);
* :func:`analytic_waf` — the steady-state greedy/FIFO write
  amplification fixed point the WAF pin tests check against;
* a victim-policy registry (``GC_POLICIES``) mirroring
  ``workload.build_workload``: ``greedy`` (min valid count — EagleTree's
  ``Garbage_Collector_Greedy``) and ``lru`` (coldest = oldest-opened
  block).

Reliability integration (DESIGN.md §2.8): on the FTL path, program and
erase failures retire *blocks* through the same valid/free accounting —
a failed program wastes its frontier slot, re-programs at the next slot
and marks the block bad (it retires at its next erase instead of
returning to the pool); a failed erase retires the block outright,
shrinking the overprovisioning pool.  The per-op retry/jitter
surcharges still ride ``OpTrace.extra_us`` exactly as before; the
way-level retirement and ad-hoc remap inserts of
``sched.apply_faults`` are superseded here by block-level accounting
(the query layer zeroes ``prog_fail_prob`` / ``erase_fail_prob`` before
sampling surcharges so nothing double-applies).

Everything is host-side NumPy sampled outside the (max,+) folds —
translation is bit-deterministic given ``(stream, spec, fault seed)``,
which is what keeps every engine's answer reproducible.
"""

from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np

from repro.core.interface import make_interface
from repro.core.nand import chip as nand_chip
from repro.core.sim import SSDConfig, controller_arb_us
from repro.core.trace import READ, WRITE, OpClassTable, op_class_table
from repro.core.workload import RequestStream, request_lpns, request_ops

#: Op-class indices of the FTL-extended table (rows 0/1 stay the plain
#: host read/write of ``trace.op_class_table`` so non-FTL traces price
#: identically on either table).
FTL_READ, FTL_WRITE, GC_READ, GC_WRITE, ERASE = 2, 3, 4, 5, 6

FTL_LABELS: tuple[str, ...] = ("read", "write", "ftl_read", "ftl_write",
                               "gc_read", "gc_write", "erase")

#: Registered GC victim-selection policies (see ``select_victim``).
GC_POLICIES: tuple[str, ...] = ("greedy", "lru")


def _greedy_victim(valid_count, candidates, fill_seq):
    """Min valid-count victim (ties: oldest fill, then lowest id)."""
    idx = np.flatnonzero(candidates)
    order = np.lexsort((idx, fill_seq[idx], valid_count[idx]))
    return int(idx[order[0]])


def _lru_victim(valid_count, candidates, fill_seq):
    """Coldest-block victim: the least recently *opened* full block
    (ties: lowest id) — its data has had the longest time to decay."""
    idx = np.flatnonzero(candidates)
    order = np.lexsort((idx, fill_seq[idx]))
    return int(idx[order[0]])


_VICTIM_SELECTORS = {"greedy": _greedy_victim, "lru": _lru_victim}


def select_victim(policy: str, valid_count, candidates, fill_seq) -> int:
    """Pick a GC victim among ``candidates`` (bool [blocks]) under a
    registered policy.  Unknown policies raise a ValueError naming the
    valid kinds (the ``build_workload`` registry contract)."""
    if policy not in _VICTIM_SELECTORS:
        raise ValueError(f"unknown GC policy {policy!r} "
                         f"(one of {', '.join(GC_POLICIES)})")
    return _VICTIM_SELECTORS[policy](np.asarray(valid_count),
                                     np.asarray(candidates),
                                     np.asarray(fill_seq))


@dataclasses.dataclass(frozen=True)
class FTLSpec:
    """One drive's translation-layer design point.

    ``overprovision`` is the spare fraction: physical capacity equals
    ``logical * (1 + overprovision)``, i.e. utilisation
    ``u = 1 / (1 + overprovision)`` — the axis the analytic WAF model
    is parameterised on.  ``map_us`` is the per-op L2P lookup/update
    firmware charge (controller time, FMMU); ``erase_us`` overrides the
    cell type's datasheet block-erase time (None = t_BERS).  With
    ``precondition`` the drive is silently filled and randomly
    overwritten ``precondition_passes`` logical passes before the
    measured stream, so the measured window sits at steady state."""

    blocks: int = 128
    pages_per_block: int = 64
    overprovision: float = 0.25
    gc_policy: str = "greedy"
    gc_free_blocks: int = 2          # GC while free blocks <= this
    map_us: float = 0.5              # L2P firmware charge per op (us)
    erase_us: float | None = None    # None -> cell t_BERS
    precondition: bool = False
    precondition_passes: float = 2.0
    seed: int = 0                    # preconditioning overwrite order

    def __post_init__(self):
        if self.blocks < 4:
            raise ValueError(f"blocks must be >= 4, got {self.blocks}")
        if self.pages_per_block < 1:
            raise ValueError("pages_per_block must be >= 1")
        if self.overprovision <= 0.0:
            raise ValueError(
                f"overprovision must be > 0 (an FTL with zero spare "
                f"capacity cannot collect garbage), got {self.overprovision}")
        if not 1 <= self.gc_free_blocks <= self.blocks // 2:
            raise ValueError(
                f"gc_free_blocks must be in [1, blocks//2], got "
                f"{self.gc_free_blocks}")
        if self.map_us < 0:
            raise ValueError("map_us must be >= 0")
        if self.erase_us is not None and self.erase_us < 0:
            raise ValueError("erase_us must be >= 0")
        if self.precondition_passes < 0:
            raise ValueError("precondition_passes must be >= 0")
        if self.gc_policy not in GC_POLICIES:
            raise ValueError(f"unknown GC policy {self.gc_policy!r} "
                             f"(one of {', '.join(GC_POLICIES)})")
        if self.logical_pages < 1:
            raise ValueError(
                "FTLSpec geometry leaves no logical capacity "
                f"({self.blocks} x {self.pages_per_block} pages at "
                f"overprovision {self.overprovision})")

    @property
    def total_pages(self) -> int:
        return self.blocks * self.pages_per_block

    @property
    def logical_pages(self) -> int:
        return int(self.total_pages / (1.0 + self.overprovision))

    @property
    def utilization(self) -> float:
        """Logical / physical page ratio (the analytic model's ``u``)."""
        return self.logical_pages / self.total_pages

    def describe(self) -> str:
        return (f"{self.blocks}blk x {self.pages_per_block}pg, "
                f"OP {self.overprovision:.2f} (u={self.utilization:.2f}), "
                f"gc={self.gc_policy}")


def analytic_waf(utilization: float) -> float:
    """Steady-state write amplification of greedy GC under uniform
    random overwrites.

    Under a uniform overwrite stream block validity decays monotonically
    with age, so greedy victim selection coincides with FIFO/LRU order
    and the steady-state WAF ``W`` solves the fixed point (Bux & Iliadis
    2010; Desnoyers 2012)::

        W = 1 / (1 - exp(-1 / (u * W)))

    where ``u`` is the logical/physical utilisation.  Finite
    pages-per-block lets measured greedy land a few percent below this
    (it skims slightly emptier-than-FIFO victims); the pin tests allow
    10%.
    """
    u = float(utilization)
    if not 0.0 < u < 1.0:
        raise ValueError(f"utilization must be in (0, 1), got {u}")
    w = 2.0
    for _ in range(500):
        w_next = 1.0 / (1.0 - math.exp(-1.0 / (u * w)))
        if abs(w_next - w) < 1e-12:
            break
        w = w_next
    return w


def ftl_op_class_table(cfg: SSDConfig, spec: FTLSpec) -> OpClassTable:
    """The 7-class timing table FTL-translated streams index.

    Rows 0/1 are exactly ``trace.op_class_table(cfg)`` (host read/write
    — a non-FTL trace prices identically on either table).  The FTL
    rows re-use the host timings with the L2P map charge ``spec.map_us``
    added to the *controller* occupancy (``ctrl_us``, with ``arb_us``
    re-derived): translation serialises through the firmware, it does
    not hold the NAND bus (FMMU).  GC read/write share the FTL timings
    but move no user payload; ERASE holds the bus only for its command
    handshake and then occupies the die for the block-erase time
    (t_BERS), moving zero bytes."""
    base = op_class_table(cfg)
    iface = make_interface(cfg.interface)
    nand = nand_chip(cfg.cell)
    m = float(spec.map_us)
    erase_us = float(spec.erase_us if spec.erase_us is not None
                     else nand.t_bers_us)

    def col(name, extra_rows):
        return np.concatenate(
            [np.asarray(getattr(base, name)),
             np.asarray(extra_rows, np.asarray(getattr(base, name)).dtype)])

    r, w = 0, 1                       # base-row indices
    ctrl = np.asarray(base.ctrl_us, np.float64)
    ftl_ctrl = [ctrl[r] + m, ctrl[w] + m, ctrl[r] + m, ctrl[w] + m, m]
    return OpClassTable(
        cmd_us=col("cmd_us", [base.cmd_us[r], base.cmd_us[w],
                              base.cmd_us[r], base.cmd_us[w],
                              iface.cmd_us]),
        pre_us=col("pre_us", [base.pre_us[r], base.pre_us[w],
                              base.pre_us[r], base.pre_us[w], 0.0]),
        slot_us=col("slot_us", [base.slot_us[r], base.slot_us[w],
                                base.slot_us[r], base.slot_us[w], m]),
        post_lo_us=col("post_lo_us", [base.post_lo_us[r], base.post_lo_us[w],
                                      base.post_lo_us[r], base.post_lo_us[w],
                                      erase_us]),
        post_hi_us=col("post_hi_us", [base.post_hi_us[r], base.post_hi_us[w],
                                      base.post_hi_us[r], base.post_hi_us[w],
                                      erase_us]),
        ctrl_us=col("ctrl_us", ftl_ctrl),
        arb_us=col("arb_us", [controller_arb_us(c, cfg.channels)
                              for c in ftl_ctrl]),
        data_bytes=col("data_bytes", [base.data_bytes[r], base.data_bytes[w],
                                      base.data_bytes[r], base.data_bytes[w],
                                      0]),
        io_us=col("io_us", [base.io_us[r], base.io_us[w],
                            base.io_us[r], base.io_us[w], 0.0]),
        labels=FTL_LABELS,
    )


@dataclasses.dataclass
class FTLStats:
    """Accounting the translation accumulates (DESIGN.md §2.10)."""

    host_pages_written: int = 0
    total_pages_written: int = 0     # host + GC relocation + reprograms
    gc_pages_moved: int = 0
    gc_reads: int = 0
    gc_writes: int = 0
    erases: int = 0
    prog_fails: int = 0
    blocks_retired: int = 0
    free_page_low_watermark: int = 0
    # per-block wear summary (ROADMAP wear leveling): computed from the
    # drive state's erase_count array when translate() returns, so it
    # covers the whole drive lifetime (preconditioning included) even
    # though the counters above reset to the measured window
    max_erase_count: int = 0
    mean_erase_count: float = 0.0

    @property
    def gc_op_count(self) -> int:
        """GC-injected trace ops (victim reads + remap writes + erases)."""
        return self.gc_reads + self.gc_writes + self.erases

    @property
    def waf(self) -> float:
        """Write amplification: physical / host page writes (1.0 when
        the window wrote nothing — a read-only stream amplifies
        nothing)."""
        if self.host_pages_written == 0:
            return 1.0
        return self.total_pages_written / self.host_pages_written


class FTLState:
    """Mutable translation state: the L2P/P2L maps, per-block valid
    counts and the free-block pool.  One instance spans a whole stream
    (and its preconditioning), so chunked translation would see the
    same drive the one-shot call does."""

    def __init__(self, spec: FTLSpec):
        self.spec = spec
        ppb = spec.pages_per_block
        self.l2p = np.full(spec.logical_pages, -1, np.int64)
        self.p2l = np.full(spec.total_pages, -1, np.int64)
        self.valid_count = np.zeros(spec.blocks, np.int64)
        self.full = np.zeros(spec.blocks, bool)
        self.bad = np.zeros(spec.blocks, bool)       # retire at next erase
        self.retired = np.zeros(spec.blocks, bool)   # out of the pool
        self.fill_seq = np.full(spec.blocks, -1, np.int64)
        self.erase_count = np.zeros(spec.blocks, np.int64)
        self._seq = 1
        self.free = collections.deque(range(1, spec.blocks))
        self.open_block = 0
        self.fill_seq[0] = 0
        self.next_page = 0
        self._ppb = ppb
        self.stats = FTLStats(
            free_page_low_watermark=self.free_pages)

    @property
    def free_pages(self) -> int:
        """Unwritten pages: the free pool plus the open block's tail."""
        return len(self.free) * self._ppb + (self._ppb - self.next_page)

    def _advance_frontier(self):
        self.full[self.open_block] = True
        if not self.free:
            raise RuntimeError(
                "FTL out of free blocks mid-allocation — geometry too "
                f"small for GC to keep up ({self.spec.describe()})")
        self.open_block = self.free.popleft()
        self.fill_seq[self.open_block] = self._seq
        self._seq += 1
        self.next_page = 0

    def alloc(self) -> int:
        """Claim the next frontier page; returns its physical number."""
        if self.next_page >= self._ppb:
            self._advance_frontier()
        ppn = self.open_block * self._ppb + self.next_page
        self.next_page += 1
        return ppn

    def map_write(self, lpn: int, ppn: int):
        """Point ``lpn`` at ``ppn``, invalidating any older copy."""
        old = self.l2p[lpn]
        if old >= 0:
            self.p2l[old] = -1
            self.valid_count[old // self._ppb] -= 1
        self.l2p[lpn] = ppn
        self.p2l[ppn] = lpn
        self.valid_count[ppn // self._ppb] += 1

    def gc_candidates(self) -> np.ndarray:
        return self.full & ~self.retired

    def note_watermark(self):
        fp = self.free_pages
        if fp < self.stats.free_page_low_watermark:
            self.stats.free_page_low_watermark = fp


@dataclasses.dataclass(frozen=True)
class FTLTranslation:
    """The physical op stream one host stream translates to.

    ``request_id`` maps each op back to its host request (-1 for GC
    relocation/erase ops); ``gc`` marks exactly those injected ops, so
    dropping them reconstructs the fresh-drive (no-aging) run the
    steady-state bandwidth cliff is measured against.  ``payload``
    carries the host byte credit: GC ops and failed programs move
    flash-internal bytes only."""

    op_cls: np.ndarray        # int32 [T'] indices into ftl_op_class_table
    arrival_us: np.ndarray    # float32 [T'] nondecreasing
    payload: np.ndarray       # bool [T']
    request_id: np.ndarray    # int32 [T'] host request, -1 for GC ops
    gc: np.ndarray            # bool [T'] GC-injected (reloc reads/writes,
                              # erases)
    stats: FTLStats
    state: FTLState           # final drive state (chained aging studies)

    @property
    def n_ops(self) -> int:
        return len(self.op_cls)


class _Emitter:
    """Append-only op-stream builder (list-backed; packs once)."""

    __slots__ = ("cls", "arrival", "payload", "rid", "gc")

    def __init__(self):
        self.cls: list[int] = []
        self.arrival: list[float] = []
        self.payload: list[bool] = []
        self.rid: list[int] = []
        self.gc: list[bool] = []

    def emit(self, cls, arrival, payload, rid, gc):
        self.cls.append(cls)
        self.arrival.append(arrival)
        self.payload.append(payload)
        self.rid.append(rid)
        self.gc.append(gc)


class _NullEmitter(_Emitter):
    """Preconditioning sink: the drive ages, nothing is simulated."""

    def emit(self, cls, arrival, payload, rid, gc):
        pass


def _program(state: FTLState, emitter, lpn: int, arrival: float,
             payload: bool, rid: int, cls: int, gc: bool,
             rng, prog_fail_prob: float):
    """Program one logical page at the write frontier, emitting the op
    (plus re-program attempts on program failure: the failed attempt
    wastes its frontier slot, keeps its bus/cell cost, loses the
    payload credit to the successful re-program, and marks its block
    bad — it retires at its next erase)."""
    for _ in range(64):
        ppn = state.alloc()
        if prog_fail_prob > 0.0 and rng.random() < prog_fail_prob:
            emitter.emit(cls, arrival, False, rid, gc)
            state.stats.total_pages_written += 1
            state.stats.prog_fails += 1
            state.bad[ppn // state._ppb] = True
            if gc:
                state.stats.gc_writes += 1
            continue
        emitter.emit(cls, arrival, payload, rid, gc)
        state.stats.total_pages_written += 1
        if gc:
            state.stats.gc_writes += 1
        state.map_write(lpn, ppn)
        return
    raise RuntimeError("64 consecutive program failures — "
                       "prog_fail_prob is unphysically high")


def _gc_cycle(state: FTLState, emitter, arrival: float,
              rng, prog_fail_prob: float, erase_fail_prob: float):
    """Relocate one victim's valid pages and erase it."""
    spec = state.spec
    candidates = state.gc_candidates()
    if not candidates.any():
        raise RuntimeError(
            "GC triggered with no collectable block "
            f"({spec.describe()}) — grow blocks or gc_free_blocks")
    victim = select_victim(spec.gc_policy, state.valid_count, candidates,
                           state.fill_seq)
    lo = victim * state._ppb
    lpns = state.p2l[lo: lo + state._ppb]
    valid = np.flatnonzero(lpns >= 0)
    if len(valid) >= state._ppb:
        # an age-ordered policy (lru) may reach a still-fully-valid cold
        # block: relocating it is net-zero but legal — the scan advances
        # to a decayed block next cycle.  Only a pool where NO candidate
        # has a single invalid page is a true deadlock.
        cand_idx = np.flatnonzero(candidates)
        if int(state.valid_count[cand_idx].min()) >= state._ppb:
            raise RuntimeError(
                "every collectable block is fully valid — the logical "
                "footprint has consumed the overprovisioning pool "
                f"({spec.describe()}); raise overprovision or shrink "
                "the workload footprint")
    for off in valid:
        lpn = int(lpns[off])
        emitter.emit(GC_READ, arrival, False, -1, True)
        state.stats.gc_reads += 1
        _program(state, emitter, lpn, arrival, False, -1, GC_WRITE, True,
                 rng, prog_fail_prob)
        state.stats.gc_pages_moved += 1
    # relocation emptied the victim (map_write invalidated each old copy)
    state.full[victim] = False
    state.fill_seq[victim] = -1
    emitter.emit(ERASE, arrival, False, -1, True)
    state.stats.erases += 1
    state.erase_count[victim] += 1
    erase_failed = (erase_fail_prob > 0.0
                    and rng.random() < erase_fail_prob)
    if erase_failed or state.bad[victim]:
        state.retired[victim] = True
        state.stats.blocks_retired += 1
    else:
        state.free.append(victim)
    state.note_watermark()


def _run_ops(state: FTLState, emitter, cls, arrival, rid, payload, lpns,
             rng, prog_fail_prob: float, erase_fail_prob: float):
    """Feed expanded host ops through the map, injecting GC on free-pool
    pressure.  GC ops inherit the triggering host op's arrival time, so
    the translated arrivals stay nondecreasing and the stream lowers
    through the unmodified scheduler."""
    spec = state.spec
    for i in range(len(cls)):
        a = float(arrival[i])
        if cls[i] == READ:
            emitter.emit(FTL_READ, a, bool(payload[i]), int(rid[i]), False)
            continue
        state.stats.host_pages_written += 1
        _program(state, emitter, int(lpns[i]), a, bool(payload[i]),
                 int(rid[i]), FTL_WRITE, False, rng, prog_fail_prob)
        guard = 0
        while len(state.free) <= spec.gc_free_blocks:
            _gc_cycle(state, emitter, a, rng, prog_fail_prob,
                      erase_fail_prob)
            guard += 1
            if guard > 4 * spec.blocks:
                raise RuntimeError(
                    "GC cannot reclaim space — overprovisioning too "
                    f"small for the footprint ({spec.describe()})")
        state.note_watermark()


def precondition_lpns(spec: FTLSpec) -> np.ndarray:
    """The preconditioning overwrite order: sequential fill of the whole
    logical space, then ``precondition_passes`` passes of uniform random
    overwrites seeded by ``spec.seed``.  One definition shared by the
    host translator and the ``lax.scan`` translation engine
    (``repro.core.ftl_scan``), so both age the same drive."""
    n = spec.logical_pages
    rng = np.random.default_rng(spec.seed)
    fill = np.arange(n, dtype=np.int64)
    over = rng.integers(0, n, int(round(spec.precondition_passes * n)))
    return np.concatenate([fill, over])


def _precondition(state: FTLState, rng_faults, prog_fail_prob: float,
                  erase_fail_prob: float):
    """Silently age the drive to steady state (``precondition_lpns``)
    with GC running.  Stats are reset afterwards, so the measured window
    reports steady-state WAF only."""
    spec = state.spec
    sink = _NullEmitter()
    lpns = precondition_lpns(spec)
    zeros_f = np.zeros(len(lpns), np.float32)
    _run_ops(state, sink, np.full(len(lpns), WRITE, np.int32), zeros_f,
             np.full(len(lpns), -1, np.int32), np.zeros(len(lpns), bool),
             lpns, rng_faults, prog_fail_prob, erase_fail_prob)
    retired = state.stats.blocks_retired
    state.stats = FTLStats(free_page_low_watermark=state.free_pages,
                           blocks_retired=retired)


def translate(stream: RequestStream, spec: FTLSpec, *,
              prog_fail_prob: float = 0.0, erase_fail_prob: float = 0.0,
              fault_seed: int = 0,
              state: FTLState | None = None) -> FTLTranslation:
    """Translate a host request stream into the physical op stream the
    drive executes (module docstring).  ``state`` chains aging across
    calls (None = a fresh drive, optionally preconditioned per the
    spec).  Program/erase failure sampling uses a PCG64 stream keyed
    ``SeedSequence([fault_seed, 2])`` — disjoint from the FaultSampler's
    per-op (``[seed, 0]``) and retirement (``[seed, 1]``) streams, so
    the retry/jitter surcharges the query layer samples afterwards stay
    bit-identical with or without FTL-owned failures."""
    if stream.n_requests == 0:
        raise ValueError("empty workload: no requests to translate")
    if int(np.max(stream.op_cls)) > WRITE:
        raise ValueError(
            "FTL translation consumes host READ/WRITE streams only "
            f"(got op class {int(np.max(stream.op_cls))})")
    rng_faults = np.random.default_rng(
        np.random.PCG64(np.random.SeedSequence([fault_seed, 2])))
    if state is None:
        state = FTLState(spec)
        if spec.precondition:
            _precondition(state, rng_faults, prog_fail_prob,
                          erase_fail_prob)
    cls, arrival, rid, payload = request_ops(stream)
    lpns = request_lpns(stream, spec.logical_pages)
    emitter = _Emitter()
    _run_ops(state, emitter, cls, arrival, rid, payload, lpns,
             rng_faults, prog_fail_prob, erase_fail_prob)
    state.stats.max_erase_count = int(state.erase_count.max())
    state.stats.mean_erase_count = float(state.erase_count.mean())
    return FTLTranslation(
        op_cls=np.asarray(emitter.cls, np.int32),
        arrival_us=np.asarray(emitter.arrival, np.float32),
        payload=np.asarray(emitter.payload, bool),
        request_id=np.asarray(emitter.rid, np.int32),
        gc=np.asarray(emitter.gc, bool),
        stats=state.stats, state=state)


__all__ = [
    "ERASE", "FTLSpec", "FTLState", "FTLStats", "FTLTranslation",
    "FTL_LABELS", "FTL_READ", "FTL_WRITE", "GC_POLICIES", "GC_READ",
    "GC_WRITE", "analytic_waf", "ftl_op_class_table", "precondition_lpns",
    "select_victim", "translate",
]
