"""Request-level workloads for the SSD simulator (DESIGN.md §2.6).

The trace layer (``repro.core.trace``) describes *what the flash sees*:
per-op class/channel/way arrays with the placement already decided.
This module describes *what the host asks for*: a :class:`RequestStream`
of (arrival time, read/write, size-in-pages, tenant) tuples with **no
placement** — deciding which channel/way serves each page is the
scheduler's job (``repro.core.sched``), either offline (static policies
lower a stream to an ``OpTrace`` that reaches every engine) or inside
the simulation fold (dynamic policies; ``repro.core.sim.dispatch_trace``).

Builders cover the arrival processes queueing behaviour actually depends
on (Park et al. and the FMMU scalability argument, PAPERS.md):

* :func:`poisson_stream`   — open-loop Poisson arrivals at an offered load;
* :func:`bursty_stream`    — on/off bursts (checkpoint-like traffic);
* :func:`closed_loop_stream` — a queue-depth-N client that admits request
  i when its model of request i-N completes (fio-style QD sweeps);
* :func:`multi_tenant`     — merge streams into one arrival-ordered
  multi-tenant workload, preserving per-stream ids.

The storage tier emits its workloads here (``checkpoint_requests`` /
``datapipe_requests`` / ``kvoffload_requests``); their static-stripe
lowerings are regression-pinned equal to the pre-request-layer trace
builders.  ``build_workload`` is the named registry behind the
deprecated ``trace.workload_trace`` shim.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.nand import chip as nand_chip
from repro.core.sim import SSDConfig
from repro.core.trace import (OpTrace, READ, WRITE, hot_cold_trace,
                              mixed_trace, steady_trace)


@dataclasses.dataclass(frozen=True)
class RequestStream:
    """Placement-free request workload: arrays [R], arrival-ordered.

    ``payload`` marks requests that deliver user bytes (False = hedged
    duplicates: they occupy resources but the first response wins).
    ``hedge_of`` links each hedged duplicate to its primary request
    (-1 = not a hedge): the static lowering mirrors the primary's
    placement and the query layer resolves first-response-wins latency
    through it (None = no hedges, or legacy adjacent-duplicate streams).
    ``stream`` is the issuing client/tenant id — latency percentiles
    can be split per tenant after simulation.
    ``lpn`` is each request's starting *logical* page number — the
    address the FTL stage (``repro.core.ftl``) translates; requests
    span ``lpn .. lpn + n_pages - 1``.  None means address-free (the
    FTL synthesises a sequential layout; non-FTL queries never read
    it)."""

    arrival_us: np.ndarray          # float32 [R], non-decreasing
    op_cls: np.ndarray              # int32 [R], READ/WRITE
    n_pages: np.ndarray             # int32 [R], >= 1
    stream: np.ndarray              # int32 [R]
    payload: np.ndarray | None = None   # bool [R]; None = all payload
    hedge_of: np.ndarray | None = None  # int32 [R]; -1 = not a hedge
    lpn: np.ndarray | None = None       # int64 [R]; None = address-free

    def __post_init__(self):
        r = len(self.arrival_us)
        for name in ("op_cls", "n_pages", "stream"):
            if len(getattr(self, name)) != r:
                raise ValueError(f"RequestStream.{name} has length "
                                 f"{len(getattr(self, name))}, "
                                 f"arrival_us has {r}")
        for name in ("payload", "hedge_of", "lpn"):
            arr = getattr(self, name)
            if arr is not None and len(arr) != r:
                raise ValueError(f"RequestStream.{name} length mismatch")
        if r == 0:
            return
        if self.lpn is not None and int(np.min(self.lpn)) < 0:
            raise ValueError("lpn must be non-negative")
        if float(np.min(self.arrival_us)) < 0:
            raise ValueError("arrival_us must be non-negative")
        if np.any(np.diff(np.asarray(self.arrival_us, np.float64)) < 0):
            raise ValueError("arrival_us must be non-decreasing (FCFS "
                             "dispatch order is the array order)")
        if int(np.min(self.n_pages)) < 1:
            raise ValueError("n_pages must be >= 1")
        if int(np.min(self.op_cls)) < 0:
            raise ValueError("op_cls must be non-negative")
        if self.hedge_of is not None:
            h = np.asarray(self.hedge_of, np.int64)
            bad = (h < -1) | (h >= r) | (h == np.arange(r))
            if bad.any():
                raise ValueError(
                    "hedge_of entries must be -1 or another request index")
            linked = h >= 0
            if linked.any():
                n_pages = np.asarray(self.n_pages, np.int64)
                if np.any(n_pages[linked] != n_pages[h[linked]]):
                    raise ValueError(
                        "a hedge duplicate must match its primary's "
                        "n_pages (it mirrors the primary op-for-op)")

    def hedge_mask(self) -> np.ndarray:
        """[R] True where the request is a linked hedge duplicate."""
        if self.hedge_of is None:
            return np.zeros(self.n_requests, bool)
        return np.asarray(self.hedge_of, np.int64) >= 0

    @property
    def n_requests(self) -> int:
        return len(self.arrival_us)

    @property
    def total_pages(self) -> int:
        return int(np.sum(self.n_pages))

    def payload_mask(self) -> np.ndarray:
        if self.payload is None:
            return np.ones(self.n_requests, bool)
        return self.payload.astype(bool)

    def describe(self) -> str:
        arr = np.asarray(self.arrival_us, np.float64)
        span = float(arr[-1]) if self.n_requests else 0.0
        reads = float(np.mean(self.op_cls == READ)) if self.n_requests else 0.0
        return (f"{self.n_requests} reqs / {self.total_pages} pages over "
                f"{span / 1e3:.2f} ms, read_frac={reads:.2f}, "
                f"{len(np.unique(self.stream))} stream(s)")


def _stream(arrival, op_cls, n_pages, stream, payload=None,
            lpn=None) -> RequestStream:
    r = len(arrival)
    return RequestStream(
        arrival_us=np.asarray(arrival, np.float32),
        op_cls=np.asarray(op_cls, np.int32),
        n_pages=(np.full(r, n_pages, np.int32)
                 if np.isscalar(n_pages) else np.asarray(n_pages, np.int32)),
        stream=(np.full(r, stream, np.int32)
                if np.isscalar(stream) else np.asarray(stream, np.int32)),
        payload=None if payload is None else np.asarray(payload, bool),
        lpn=None if lpn is None else np.asarray(lpn, np.int64))


def _classes(n: int, read_fraction: float, rng) -> np.ndarray:
    return np.where(rng.random(n) < read_fraction, READ, WRITE)


# ---------------------------------------------------------------------------
# Arrival-process builders
# ---------------------------------------------------------------------------


def poisson_stream(n_requests: int, mean_interarrival_us: float, *,
                   read_fraction: float = 1.0, pages_per_request: int = 1,
                   seed: int = 0, stream: int = 0) -> RequestStream:
    """Open-loop Poisson arrivals: offered load = pages_per_request /
    mean_interarrival_us pages/us, independent of service progress."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_interarrival_us, n_requests)
    if n_requests:
        gaps[0] = 0.0                   # the stream starts at t = 0
    return _stream(np.cumsum(gaps), _classes(n_requests, read_fraction, rng),
                   pages_per_request, stream)


def bursty_stream(n_requests: int, burst_len: int, gap_us: float, *,
                  intra_us: float = 0.0, read_fraction: float = 1.0,
                  pages_per_request: int = 1, seed: int = 0,
                  stream: int = 0) -> RequestStream:
    """On/off bursts: ``burst_len`` requests ``intra_us`` apart, then an
    idle ``gap_us`` before the next burst — checkpoint-save-like traffic
    that exercises queue build-up and drain."""
    if burst_len < 1:
        raise ValueError("burst_len must be >= 1")
    i = np.arange(n_requests)
    arrival = (i // burst_len) * (burst_len * intra_us + gap_us) \
        + (i % burst_len) * intra_us
    rng = np.random.default_rng(seed)
    return _stream(arrival, _classes(n_requests, read_fraction, rng),
                   pages_per_request, stream)


def closed_loop_stream(n_requests: int, queue_depth: int, service_us: float,
                       *, read_fraction: float = 1.0,
                       pages_per_request: int = 1, seed: int = 0,
                       stream: int = 0) -> RequestStream:
    """Closed-loop queue-depth-N client (fio-style): request i is
    admitted when the client's single-server model of request i-N
    completes.  ``service_us`` is the client's per-request service
    estimate — the *simulated* device may be faster (queue drains,
    latency ≈ service) or slower (queue builds, latency grows), which
    is exactly the knee a QD sweep looks for."""
    if queue_depth < 1:
        raise ValueError("queue_depth must be >= 1")
    arrival = np.zeros(n_requests, np.float64)
    done = np.zeros(n_requests, np.float64)
    prev_done = 0.0
    for i in range(n_requests):
        arrival[i] = 0.0 if i < queue_depth else done[i - queue_depth]
        prev_done = max(arrival[i], prev_done) + service_us
        done[i] = prev_done
    rng = np.random.default_rng(seed)
    return _stream(arrival, _classes(n_requests, read_fraction, rng),
                   pages_per_request, stream)


def multi_tenant(streams) -> RequestStream:
    """Merge streams into one arrival-ordered workload.  Stream ids are
    re-tagged by position so per-tenant latency splits stay unambiguous
    even when inputs share an id.  Merge is stable: equal arrivals keep
    the input order (earlier stream first).  ``hedge_of`` links are
    remapped through the merge permutation (they never cross streams)."""
    streams = list(streams)
    if not streams:
        raise ValueError("multi_tenant needs at least one stream")
    arrival = np.concatenate([s.arrival_us for s in streams])
    order = np.argsort(arrival, kind="stable")
    cat = lambda xs: np.concatenate(xs)[order]  # noqa: E731
    hedge_of = None
    if any(s.hedge_of is not None for s in streams):
        # local primary index -> global pre-sort index -> post-sort index
        offsets = np.cumsum([0] + [s.n_requests for s in streams])
        h_g = np.concatenate([
            np.where(np.asarray(s.hedge_of, np.int64) >= 0,
                     np.asarray(s.hedge_of, np.int64) + off, -1)
            if s.hedge_of is not None
            else np.full(s.n_requests, -1, np.int64)
            for s, off in zip(streams, offsets)])
        inv = np.empty(len(order), np.int64)
        inv[order] = np.arange(len(order))
        h_s = h_g[order]
        hedge_of = np.where(h_s >= 0, inv[np.clip(h_s, 0, None)],
                            -1).astype(np.int32)
    with_lpn = [s.lpn is not None for s in streams]
    if any(with_lpn) and not all(with_lpn):
        raise ValueError(
            "cannot merge streams with and without logical addresses "
            "(lpn): give every tenant an lpn array or none")
    return RequestStream(
        arrival_us=np.asarray(arrival, np.float32)[order],
        op_cls=cat([s.op_cls for s in streams]),
        n_pages=cat([s.n_pages for s in streams]),
        stream=cat([np.full(s.n_requests, i, np.int32)
                    for i, s in enumerate(streams)]),
        payload=(None if all(s.payload is None for s in streams)
                 else cat([s.payload_mask() for s in streams])),
        hedge_of=hedge_of,
        lpn=None if not all(with_lpn) else cat([s.lpn for s in streams]))


def with_hedges(stream: RequestStream, fraction: float,
                after_us: float = 0.0, seed: int = 0) -> RequestStream:
    """Hedge a fraction of payload reads: each selected request gets a
    non-payload duplicate (``hedge_of`` = its primary) arriving
    ``after_us`` later — the straggler-mitigation knob of DESIGN.md
    §2.8.  First response wins, so the duplicate delivers no new bytes;
    the query layer takes the min over {primary, duplicate} completion.
    ``after_us=0`` inserts each duplicate right after its primary,
    reproducing the legacy adjacent-duplicate layout bit-for-bit."""
    if fraction <= 0.0 or stream.n_requests == 0:
        return stream
    r = stream.n_requests
    rng = np.random.default_rng(seed)
    draw = rng.random(r)
    hedged = ((draw < fraction) & (np.asarray(stream.op_cls) == READ)
              & stream.payload_mask() & ~stream.hedge_mask())
    if not hedged.any():
        return stream
    reps = 1 + hedged.astype(np.int64)
    new_of_old = np.cumsum(reps) - reps             # old idx -> new idx
    r2 = int(reps.sum())
    src = np.repeat(np.arange(r), reps)             # source request/slot
    is_dup = np.zeros(r2, bool)
    is_dup[new_of_old[hedged] + 1] = True
    arrival = np.asarray(stream.arrival_us, np.float64)[src]
    arrival[is_dup] += float(after_us)
    hedge_of = np.where(is_dup, new_of_old[src], -1)
    if stream.hedge_of is not None:                 # carry existing links
        old = np.asarray(stream.hedge_of, np.int64)[src]
        hedge_of = np.where(~is_dup & (old >= 0),
                            new_of_old[np.clip(old, 0, None)], hedge_of)
    payload = np.asarray(stream.payload_mask())[src] & ~is_dup
    # restore arrival order (after_us can push a duplicate past later
    # arrivals); the stable sort keeps a zero-offset duplicate glued
    # right after its primary, and hedge_of rides the permutation
    order = np.argsort(arrival, kind="stable")
    inv = np.empty(r2, np.int64)
    inv[order] = np.arange(r2)
    h_s = hedge_of[order]
    return RequestStream(
        arrival_us=arrival[order].astype(np.float32),
        op_cls=np.asarray(stream.op_cls, np.int32)[src][order],
        n_pages=np.asarray(stream.n_pages, np.int32)[src][order],
        stream=np.asarray(stream.stream, np.int32)[src][order],
        payload=None if payload.all() else payload[order],
        hedge_of=np.where(h_s >= 0, inv[np.clip(h_s, 0, None)],
                          -1).astype(np.int32),
        lpn=(None if stream.lpn is None
             else np.asarray(stream.lpn, np.int64)[src][order]))


def request_ops(stream: RequestStream
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Expand requests to page ops: (cls, arrival_us, request_id,
    payload), each [T = total_pages].  Every page op inherits its
    request's arrival and id — the shared front half of both the static
    lowering and the dynamic dispatch fold."""
    reps = np.asarray(stream.n_pages, np.int64)
    return (np.repeat(np.asarray(stream.op_cls, np.int32), reps),
            np.repeat(np.asarray(stream.arrival_us, np.float32), reps),
            np.repeat(np.arange(stream.n_requests, dtype=np.int32), reps),
            np.repeat(stream.payload_mask(), reps))


def request_lpns(stream: RequestStream, n_logical: int) -> np.ndarray:
    """Per-page-op logical page numbers [T = total_pages], wrapped into
    ``[0, n_logical)`` — the address half of :func:`request_ops`, which
    the FTL stage (``repro.core.ftl``) translates through the L2P map.
    Requests span ``lpn .. lpn + n_pages - 1``; address-free streams
    (``lpn is None``) synthesise a sequential layout (op ``t`` touches
    logical page ``t mod n_logical``), so legacy streams age a drive
    like a pure sequential writer."""
    if n_logical < 1:
        raise ValueError(f"n_logical must be >= 1, got {n_logical}")
    reps = np.asarray(stream.n_pages, np.int64)
    t = np.arange(int(reps.sum()), dtype=np.int64)
    if stream.lpn is None:
        return t % n_logical
    starts = np.cumsum(reps) - reps
    pos = t - np.repeat(starts, reps)          # op offset within request
    return (np.repeat(np.asarray(stream.lpn, np.int64), reps)
            + pos) % n_logical


def iter_request_chunks(stream: RequestStream, chunk_requests: int):
    """Slice a request stream into contiguous chunks of at most
    ``chunk_requests`` requests — the feeder for the streaming FTL path
    (``Simulator.run_stream(ftl=...)``), which translates and lowers
    chunk by chunk while carrying drive state.

    Address-free streams (``lpn is None``) synthesise their logical
    layout from the *global* op index inside :func:`request_lpns`, so
    naive slicing would restart every chunk at logical page 0; this
    helper materialises each request's unwrapped starting lpn first
    (``request_lpns`` wraps modulo the footprint later), making the
    chunked translation identical to the one-shot stream for any
    logical size."""
    if chunk_requests < 1:
        raise ValueError(
            f"chunk_requests must be >= 1, got {chunk_requests}")
    if stream.hedge_of is not None:
        raise ValueError(
            "hedged streams cannot be chunked (hedge_of links cross "
            "chunk boundaries) — hedging is one-shot-only")
    if stream.lpn is None and stream.n_requests:
        reps = np.asarray(stream.n_pages, np.int64)
        stream = dataclasses.replace(stream, lpn=np.cumsum(reps) - reps)
    arrays = {f.name: getattr(stream, f.name)
              for f in dataclasses.fields(stream)
              if isinstance(getattr(stream, f.name), np.ndarray)}
    for lo in range(0, stream.n_requests, chunk_requests):
        yield dataclasses.replace(
            stream, **{k: v[lo:lo + chunk_requests]
                       for k, v in arrays.items()})


# ---------------------------------------------------------------------------
# Logically-addressed builders (the FTL aging workload class)
# ---------------------------------------------------------------------------


def _arrivals(n: int, mean_interarrival_us: float, rng) -> np.ndarray:
    """Zero arrivals (a saturating burst) or Poisson at the given mean."""
    if mean_interarrival_us <= 0.0:
        return np.zeros(n)
    gaps = rng.exponential(mean_interarrival_us, n)
    if n:
        gaps[0] = 0.0
    return np.cumsum(gaps)


def overwrite_stream(n_requests: int, footprint_pages: int, *,
                     read_fraction: float = 0.0,
                     mean_interarrival_us: float = 0.0,
                     pages_per_request: int = 1, seed: int = 0,
                     stream: int = 0) -> RequestStream:
    """Uniform-random overwrites of a ``footprint_pages`` logical
    region — the steady-state aging workload the analytic greedy-GC
    WAF model describes (``repro.core.ftl.analytic_waf``).  Defaults to
    a pure-write saturating burst; ``mean_interarrival_us`` switches to
    Poisson arrivals and ``read_fraction`` mixes reads over the same
    footprint."""
    if footprint_pages < 1:
        raise ValueError(
            f"footprint_pages must be >= 1, got {footprint_pages}")
    if not 0.0 <= read_fraction <= 1.0:
        raise ValueError(
            f"read_fraction must be in [0, 1], got {read_fraction}")
    rng = np.random.default_rng(seed)
    return _stream(_arrivals(n_requests, mean_interarrival_us, rng),
                   _classes(n_requests, read_fraction, rng),
                   pages_per_request, stream,
                   lpn=rng.integers(0, footprint_pages, n_requests))


def aging_stream(n_requests: int, footprint_pages: int, *,
                 hot_fraction: float = 0.2, hot_traffic: float = 0.8,
                 read_fraction: float = 0.0,
                 mean_interarrival_us: float = 0.0,
                 pages_per_request: int = 1, seed: int = 0,
                 stream: int = 0) -> RequestStream:
    """Skewed (hot/cold) overwrites: a ``hot_fraction`` slice of the
    logical footprint receives ``hot_traffic`` of the requests — the
    locality real aging exhibits.  Cold data pins valid pages inside GC
    victims, so a single-frontier FTL amplifies *more* than under the
    uniform stream at the same overprovisioning (the hot/cold
    separation motivation)."""
    if footprint_pages < 2:
        raise ValueError(
            f"footprint_pages must be >= 2 (a hot and a cold page), "
            f"got {footprint_pages}")
    if not 0.0 < hot_fraction < 1.0:
        raise ValueError(
            f"hot_fraction must be in (0, 1), got {hot_fraction}")
    if not 0.0 <= hot_traffic <= 1.0:
        raise ValueError(
            f"hot_traffic must be in [0, 1], got {hot_traffic}")
    if not 0.0 <= read_fraction <= 1.0:
        raise ValueError(
            f"read_fraction must be in [0, 1], got {read_fraction}")
    rng = np.random.default_rng(seed)
    n_hot = min(footprint_pages - 1,
                max(1, int(round(hot_fraction * footprint_pages))))
    hot = rng.random(n_requests) < hot_traffic
    lpn = np.where(hot, rng.integers(0, n_hot, n_requests),
                   rng.integers(n_hot, footprint_pages, n_requests))
    return _stream(_arrivals(n_requests, mean_interarrival_us, rng),
                   _classes(n_requests, read_fraction, rng),
                   pages_per_request, stream, lpn=lpn)


# ---------------------------------------------------------------------------
# Storage-tier request emitters (stripe-lowered twins of the retired
# trace builders; regression-pinned numerically identical)
# ---------------------------------------------------------------------------


def _pages(nbytes: int, page_bytes: int) -> int:
    return max(1, -(-int(nbytes) // page_bytes))


def _bucket(n: int, max_ops: int) -> int:
    """Round a window length up to a power of two (bounded by max_ops) so
    byte-extrapolated estimates reuse jit cache entries across sizes."""
    return min(max_ops, 1 << (n - 1).bit_length())


def checkpoint_requests(nbytes: int, cfg: SSDConfig,
                        max_ops: int = 4096) -> RequestStream:
    """Checkpoint save: a zero-arrival pure write burst (the writer
    thread queues every chunk at once), one request per page.  Long
    bursts truncate to ``max_ops``; callers extrapolate by bytes."""
    n = _bucket(_pages(nbytes, nand_chip(cfg.cell).page_data_bytes), max_ops)
    return _stream(np.zeros(n), np.full(n, WRITE), 1, 0)


def datapipe_requests(nbytes: int, cfg: SSDConfig,
                      hedge_fraction: float = 0.0, seed: int = 0,
                      max_ops: int = 4096,
                      hedge_after_us: float = 0.0) -> RequestStream:
    """Data-pipeline refill: one read request per page; a
    ``hedge_fraction`` of reads gets a non-payload duplicate
    (straggler hedging — first response wins, so the duplicate delivers
    no new bytes and the static lowering mirrors its primary's
    placement shifted one channel).  ``hedge_after_us`` delays each
    duplicate's arrival past its primary's (0 = fire together, the
    legacy layout bit-for-bit — see ``with_hedges``)."""
    n = _bucket(_pages(nbytes, nand_chip(cfg.cell).page_data_bytes), max_ops)
    base = _stream(np.zeros(n), np.full(n, READ), 1, 0)
    return with_hedges(base, hedge_fraction, after_us=hedge_after_us,
                       seed=seed)


def kvoffload_requests(read_bytes_per_token: int, cfg: SSDConfig,
                       n_tokens: int = 8, append_bytes_per_token: int = 0,
                       max_ops: int = 4096) -> RequestStream:
    """Long-context decode: per token, a cold-KV read burst with the KV
    append writes interleaved evenly (write-back caching overlaps the
    append with the read stream).  Interleaving keeps the read/write
    mix representative when a huge per-token burst is truncated to the
    ``max_ops`` simulation window."""
    page = nand_chip(cfg.cell).page_data_bytes
    reads = _pages(read_bytes_per_token, page)
    writes = (_pages(append_bytes_per_token, page)
              if append_bytes_per_token > 0 else 0)
    # build only the simulated window: a GiB-scale burst is represented
    # by a max_ops-sized pattern with the same read/write mix
    per_tok = reads + writes
    if per_tok > max_ops:
        writes = round(writes * max_ops / per_tok) if writes else 0
        reads = max_ops - writes
    token = np.full(reads, READ, np.int32)
    if writes:
        at = np.linspace(0, reads, writes, endpoint=False).astype(int)
        token = np.insert(token, np.sort(at), WRITE)
    reps = min(n_tokens, -(-max_ops // len(token)))
    cls = np.tile(token, reps)[:max_ops]
    return _stream(np.zeros(cls.size), cls, 1, 0)


# ---------------------------------------------------------------------------
# Named registry (the workload-layer home of trace.workload_trace)
# ---------------------------------------------------------------------------


def _lowered(requests_fn):
    def build(cfg: SSDConfig, *args, **kw) -> OpTrace:
        from repro.core.sched import lower_static
        return lower_static(requests_fn(*args, cfg=cfg, **kw),
                            cfg.channels, cfg.ways).trace
    return build


WORKLOAD_KINDS: tuple[str, ...] = (
    "steady_read", "steady_write", "mixed", "hot_cold",
    "checkpoint", "datapipe", "kvoffload",
    "poisson", "bursty", "closed_loop",
    "overwrite", "aging",
)

_BUILDERS = {
    "steady_read": lambda cfg, n_pages=512: steady_trace(
        n_pages, cfg.channels, cfg.ways, READ),
    "steady_write": lambda cfg, n_pages=512: steady_trace(
        n_pages, cfg.channels, cfg.ways, WRITE),
    "mixed": lambda cfg, n_ops=None, read_fraction=0.7, seed=0: mixed_trace(
        n_ops or 512 * cfg.channels, cfg.channels, cfg.ways,
        read_fraction, seed),
    "hot_cold": lambda cfg, n_ops=None, **kw: hot_cold_trace(
        n_ops or 512 * cfg.channels, cfg.channels, cfg.ways, **kw),
    "checkpoint": _lowered(
        lambda nbytes, cfg, **kw: checkpoint_requests(nbytes, cfg, **kw)),
    "datapipe": _lowered(
        lambda nbytes, cfg, **kw: datapipe_requests(nbytes, cfg, **kw)),
    "kvoffload": _lowered(
        lambda read_bytes_per_token, cfg, **kw: kvoffload_requests(
            read_bytes_per_token, cfg, **kw)),
    "poisson": _lowered(
        lambda cfg, n_requests=512, mean_interarrival_us=50.0, **kw:
        poisson_stream(n_requests, mean_interarrival_us, **kw)),
    "bursty": _lowered(
        lambda cfg, n_requests=512, burst_len=32, gap_us=2000.0, **kw:
        bursty_stream(n_requests, burst_len, gap_us, **kw)),
    "closed_loop": _lowered(
        lambda cfg, n_requests=512, queue_depth=8, service_us=50.0, **kw:
        closed_loop_stream(n_requests, queue_depth, service_us, **kw)),
    "overwrite": _lowered(
        lambda cfg, n_requests=512, footprint_pages=2048, **kw:
        overwrite_stream(n_requests, footprint_pages, **kw)),
    "aging": _lowered(
        lambda cfg, n_requests=512, footprint_pages=2048, **kw:
        aging_stream(n_requests, footprint_pages, **kw)),
}


def build_workload(kind: str, cfg: SSDConfig, **kw) -> OpTrace:
    """Named workload registry (benchmarks / examples / sweeps): the
    op-level kinds build traces directly; the request-level kinds build
    a ``RequestStream`` and lower it with the static stripe scheduler
    (pass the stream to ``Simulator.run(workload=..., sched_policy=...)``
    instead to pick a policy).  Unknown kinds raise a ValueError naming
    the valid kinds; unknown kwargs raise TypeError from the builder."""
    if kind not in _BUILDERS:
        raise ValueError(
            f"unknown workload kind {kind!r} "
            f"(one of {', '.join(WORKLOAD_KINDS)})")
    return _BUILDERS[kind](cfg, **kw)
