"""Compiled FTL translation: the host translator of ``repro.core.ftl``
re-expressed as a ``jax.lax.scan`` state machine (DESIGN.md §2.11).

``ftl.translate`` walks the host stream with a per-op Python loop over
dicts and deques — correct, but the one stage of the pipeline that is
neither jittable nor batchable, so aged design-space sweeps pay serial
Python per point and stop at the FTL boundary.  This module compiles
the *same* translation:

* the L2P/P2L maps, per-block valid counts, fill sequence, erase
  counts and the free-block FIFO (a ring buffer with monotonic
  head/tail cursors) are dense ``int32`` arrays carried through one
  ``lax.scan``;
* the scan runs in **fused steps**.  A one-op-per-step machine is the
  natural shape, but its wall time is linear in the *physical* op
  count — GC relocations included — which on an aged drive is several
  times the host stream.  Instead each step is either a **host
  burst** (up to ``pages_per_block`` host ops, cut before the first
  op that would need a block allocation or fire the GC trigger — both
  are prefix-closed conditions, so the burst length is one masked
  ``cumsum``), a **single allocating write** (the old scalar path,
  taken when the burst would be empty), or a **whole GC cycle**
  (every valid page of the victim relocated by one vectorised
  scatter pass — at most one block opens per cycle since a victim
  holds at most ``pages_per_block`` valid pages — then the erase,
  the guard and the trigger re-check).  Step count is then the burst
  count plus the GC cycle count, ~an order less than the op count;
* each step emits into one row of a bounded ``[t_max, 2*ppb + 1]``
  output buffer: burst ops in lanes ``0..ppb-1``, a GC cycle's
  read/write pairs at ``(2i, 2i+1)`` with the erase at lane ``2k`` —
  disjoint by construction, padding lanes payload-masked (the §2.5
  masked-fold identity), so flattening rows in order recovers the
  exact host op sequence and the whole translate→lower→simulate
  chain is one jittable closure;
* victim selection is a cascaded masked argmin reproducing the host's
  ``np.lexsort`` tie-break exactly: greedy = (valid count, fill seq,
  block id), lru = (fill seq, block id);
* the host translator survives as the **oracle**: the scan path agrees
  with it op-for-op — same op classes, arrivals, payload flags,
  request ids and stats — on every fault-free translation, and its
  jaxpr joins the §2.9 invariant gates (RNG-free, f32 floats,
  primitive budget).

Block-level fault injection (``prog_fail_prob`` / ``erase_fail_prob``)
stays on the host path: its per-attempt RNG draws would put RNG
primitives inside the fold, which the determinism contract forbids —
``repro.core.api`` falls back to ``ftl.translate`` whenever those
probabilities are nonzero.

Error handling is deferred: the machine latches an error *bit* and
freezes (all later steps are state no-ops), and ``translate_scan``
raises the matching host ``RuntimeError`` after the fold returns.  An
output buffer that proves too short is not an error — the caller
doubles ``t_max`` and re-runs from the same (functional) input state.
"""

from __future__ import annotations

import functools
import math
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ftl import (ERASE, FTL_READ, FTL_WRITE, FTLSpec, FTLState,
                            FTLStats, FTLTranslation, GC_READ, GC_WRITE,
                            analytic_waf, precondition_lpns)
from repro.core.trace import READ, WRITE
from repro.core.workload import RequestStream, request_lpns, request_ops

#: ``mode`` register values (HOST bursts host ops; GC drains one whole
#: relocation cycle per step until the trigger clears).
MODE_HOST, MODE_GC = 0, 1

#: Latched error bits (decoded to the host translator's RuntimeErrors).
ERR_NO_FREE, ERR_GUARD, ERR_NO_CAND, ERR_ALL_VALID = 1, 2, 4, 8

_BIG = 2 ** 30


class ScanFTLState(typing.NamedTuple):
    """The dense-array drive state one translation scan carries.  All
    integers are ``int32`` (the x64-retrace gate keeps them that way),
    floats are ``float32``.  ``l2p`` is padded to ``total_pages`` so
    overprovisioning sweeps at fixed geometry share one compiled fold
    (entries past ``logical_pages`` stay -1 forever)."""

    l2p: jax.Array          # int32 [total_pages] lpn -> ppn, -1 unmapped
    p2l: jax.Array          # int32 [total_pages] ppn -> lpn, -1 invalid
    valid_count: jax.Array  # int32 [blocks]
    full: jax.Array         # bool  [blocks]
    fill_seq: jax.Array     # int32 [blocks] open order, -1 = not filled
    erase_count: jax.Array  # int32 [blocks] lifetime erases (wear)
    free_q: jax.Array       # int32 [blocks] FIFO ring of free block ids
    free_head: jax.Array    # int32 [] monotonic pop cursor
    free_tail: jax.Array    # int32 [] monotonic push cursor
    open_block: jax.Array   # int32 []
    next_page: jax.Array    # int32 [] frontier offset in the open block
    seq: jax.Array          # int32 [] next fill_seq value
    h: jax.Array            # int32 [] host ops consumed *this fold*
    mode: jax.Array         # int32 [] MODE_*
    victim: jax.Array       # int32 [] current GC victim block
    guard: jax.Array        # int32 [] GC cycles since the last host write
    arrival: jax.Array      # f32   [] triggering host arrival (GC inherits)
    watermark: jax.Array    # int32 [] free-page low watermark
    host_w: jax.Array       # int32 [] stats: host pages written
    total_w: jax.Array      # int32 [] stats: physical pages written
    gc_pages: jax.Array     # int32 [] stats: pages relocated
    gc_reads: jax.Array     # int32 [] stats: GC reads emitted
    gc_writes: jax.Array    # int32 [] stats: GC writes emitted
    erases: jax.Array       # int32 [] stats: erases emitted
    err: jax.Array          # int32 [] latched ERR_* bits (0 = healthy)


def _i32(x) -> jax.Array:
    return jnp.asarray(x, jnp.int32)


def scan_state_fresh(spec: FTLSpec) -> ScanFTLState:
    """A fresh drive in scan form — field-for-field the state
    ``ftl.FTLState(spec)`` starts from (block 0 open, blocks 1.. free)."""
    blocks, total = spec.blocks, spec.total_pages
    free_q = np.zeros(blocks, np.int32)
    free_q[: blocks - 1] = np.arange(1, blocks, dtype=np.int32)
    fill_seq = np.full(blocks, -1, np.int32)
    fill_seq[0] = 0
    z = _i32(0)
    return ScanFTLState(
        l2p=jnp.full((total,), -1, jnp.int32),
        p2l=jnp.full((total,), -1, jnp.int32),
        valid_count=jnp.zeros((blocks,), jnp.int32),
        full=jnp.zeros((blocks,), bool),
        fill_seq=jnp.asarray(fill_seq),
        erase_count=jnp.zeros((blocks,), jnp.int32),
        free_q=jnp.asarray(free_q), free_head=z, free_tail=_i32(blocks - 1),
        open_block=z, next_page=z, seq=_i32(1), h=z, mode=z, victim=z,
        guard=z, arrival=jnp.float32(0.0),
        watermark=_i32(total), host_w=z, total_w=z, gc_pages=z,
        gc_reads=z, gc_writes=z, erases=z, err=z)


def scan_state_from_host(state: FTLState) -> ScanFTLState:
    """Convert a host ``FTLState`` (chained aging) into scan form.
    Rejects states carrying block-level fault history — the scan path
    is the fault-free translation engine."""
    if state.bad.any() or state.retired.any():
        raise ValueError(
            "scan translation requires a fault-free drive state "
            "(bad/retired blocks present — use ftl.translate)")
    spec = state.spec
    blocks, total = spec.blocks, spec.total_pages
    l2p = np.full(total, -1, np.int32)
    l2p[: spec.logical_pages] = state.l2p
    free = np.fromiter(state.free, np.int32, len(state.free))
    free_q = np.zeros(blocks, np.int32)
    free_q[: len(free)] = free
    st = state.stats
    return ScanFTLState(
        l2p=jnp.asarray(l2p), p2l=jnp.asarray(state.p2l, jnp.int32),
        valid_count=jnp.asarray(state.valid_count, jnp.int32),
        full=jnp.asarray(state.full, bool),
        fill_seq=jnp.asarray(state.fill_seq, jnp.int32),
        erase_count=jnp.asarray(state.erase_count, jnp.int32),
        free_q=jnp.asarray(free_q), free_head=_i32(0),
        free_tail=_i32(len(free)), open_block=_i32(state.open_block),
        next_page=_i32(state.next_page), seq=_i32(state._seq),
        h=_i32(0), mode=_i32(0), victim=_i32(0),
        guard=_i32(0), arrival=jnp.float32(0.0),
        watermark=_i32(st.free_page_low_watermark),
        host_w=_i32(st.host_pages_written),
        total_w=_i32(st.total_pages_written),
        gc_pages=_i32(st.gc_pages_moved), gc_reads=_i32(st.gc_reads),
        gc_writes=_i32(st.gc_writes), erases=_i32(st.erases), err=_i32(0))


def scan_state_to_host(fs: ScanFTLState, spec: FTLSpec) -> FTLState:
    """Materialise a scan state back into the host ``FTLState`` form, so
    chained aging studies and the existing result plumbing are agnostic
    to which translator ran."""
    st = FTLState(spec)
    st.l2p = np.asarray(fs.l2p, np.int64)[: spec.logical_pages].copy()
    st.p2l = np.asarray(fs.p2l, np.int64).copy()
    st.valid_count = np.asarray(fs.valid_count, np.int64).copy()
    st.full = np.asarray(fs.full, bool).copy()
    st.fill_seq = np.asarray(fs.fill_seq, np.int64).copy()
    st.erase_count = np.asarray(fs.erase_count, np.int64).copy()
    head, tail = int(fs.free_head), int(fs.free_tail)
    q = np.asarray(fs.free_q)
    idx = (head + np.arange(tail - head)) % spec.blocks
    st.free.clear()
    st.free.extend(int(b) for b in q[idx])
    st.open_block = int(fs.open_block)
    st.next_page = int(fs.next_page)
    st._seq = int(fs.seq)
    st.stats = _stats_from(fs)
    return st


def _stats_from(fs: ScanFTLState) -> FTLStats:
    ec = np.asarray(fs.erase_count)
    return FTLStats(
        host_pages_written=int(fs.host_w),
        total_pages_written=int(fs.total_w),
        gc_pages_moved=int(fs.gc_pages), gc_reads=int(fs.gc_reads),
        gc_writes=int(fs.gc_writes), erases=int(fs.erases),
        free_page_low_watermark=int(fs.watermark),
        max_erase_count=int(ec.max()), mean_erase_count=float(ec.mean()))


def make_translate_fold(blocks: int, ppb: int, n_host: int, t_max: int,
                        unroll: int = 1):
    """Build the translation scan for a static ``(blocks, ppb, n_host,
    t_max)`` shape.  The returned function is pure and traceable (the
    §2.9 gates trace it directly)::

        fold(cls_h, arr_h, pay_h, rid_h, lpn_h, n_eff, gc_free, is_lru,
             state) -> (state', (op_cls, arrival, payload, rid, valid))

    Host arrays are ``[n_host]`` (padded; ``n_eff`` ops are real, and
    ``n_host >= n_eff + ppb`` so the per-step host window never
    clamps).  The emitted arrays are ``[t_max, 2*ppb + 1]`` rows —
    one fused step each; flattening row-major and keeping ``valid``
    lanes recovers the host op order (GC membership needs no lane of
    its own: it is exactly ``op_cls >= GC_READ``).  ``gc_free`` /
    ``is_lru`` are traced scalars so GC-trigger and policy sweeps at
    fixed geometry share one compile; steps past the stream idle
    (every lane payload-masked), so an incomplete run is detected from
    ``(h, mode)`` and re-run with a doubled buffer."""
    total = blocks * ppb
    S = 2 * ppb + 1
    lanes = jnp.arange(ppb, dtype=jnp.int32)
    not_eye = ~jnp.eye(ppb, dtype=bool)
    barange = jnp.arange(blocks, dtype=jnp.int32)
    jlanes = jnp.arange(S, dtype=jnp.int32)
    gc_pat = jnp.where(jlanes % 2 == 0, _i32(GC_READ), _i32(GC_WRITE))

    def fold(cls_h, arr_h, pay_h, rid_h, lpn_h, n_eff, gc_free, is_lru,
             state):
        cls_h = jnp.asarray(cls_h, jnp.int32)
        arr_h = jnp.asarray(arr_h, jnp.float32)
        pay_h = jnp.asarray(pay_h, bool)
        rid_h = jnp.asarray(rid_h, jnp.int32)
        lpn_h = jnp.asarray(lpn_h, jnp.int32)
        n_eff = _i32(n_eff)
        gc_free = _i32(gc_free)
        is_lru = jnp.asarray(is_lru, bool)

        def step(s, _):
            # One branchless fused step: both paths (host burst / GC
            # cycle) run every step as predicated vector math — a
            # vmapped `lax.switch` would run all branches anyway, so a
            # single shared code path costs the same batched or not,
            # and every scatter below self-gates with a drop index.
            active = (s.err == 0) & ~((s.mode == MODE_HOST)
                                      & (s.h >= n_eff))
            in_host = active & (s.mode == MODE_HOST)
            in_gc = active & (s.mode == MODE_GC)

            # -- host burst: the next ppb-op window, cut at the first
            # op needing a block allocation (cumulative writes exceed
            # the open block's room) or — when the free pool already
            # sits at the trigger — at the first write, whose landing
            # must re-check GC.  Both cuts are prefix-closed, so the
            # burst length is the popcount of one mask.
            hc = jnp.clip(s.h, 0, n_host - ppb)
            wcls = jax.lax.dynamic_slice(cls_h, (hc,), (ppb,))
            warr = jax.lax.dynamic_slice(arr_h, (hc,), (ppb,))
            wpay = jax.lax.dynamic_slice(pay_h, (hc,), (ppb,))
            wrid = jax.lax.dynamic_slice(rid_h, (hc,), (ppb,))
            wlpn = jax.lax.dynamic_slice(lpn_h, (hc,), (ppb,))
            stream_ok = in_host & (hc + lanes < n_eff)
            w_lane = stream_ok & (wcls == WRITE)
            room = ppb - s.next_page
            w_cum = jnp.cumsum(w_lane.astype(jnp.int32))
            fits = stream_ok & (w_cum <= room)
            low = (s.free_tail - s.free_head) <= gc_free
            any_w = jnp.any(w_lane)
            fw = jnp.argmax(w_lane).astype(jnp.int32)
            allow = fits & (~low | ~any_w | (lanes <= fw))
            K = jnp.sum(allow, dtype=jnp.int32)
            b_open = in_host & (K == 0)      # head write needs a block
            take = in_host & (lanes < jnp.where(b_open, _i32(1), K))
            wtake = take & w_lane
            w_tk = jnp.sum(wtake, dtype=jnp.int32)

            # -- GC cycle: every valid page of the victim relocates in
            # this one step (k <= ppb, so at most one block opens)
            v = s.victim
            win = jax.lax.dynamic_slice(s.p2l, (v * ppb,), (ppb,))
            vmask = in_gc & (win >= 0)
            k = jnp.sum(vmask, dtype=jnp.int32)
            r_idx = jnp.cumsum(vmask.astype(jnp.int32)) - 1
            glpn = jnp.clip(win, 0)

            # -- allocation (either path pops at most one free block)
            need_g = in_gc & (k > room)
            pop = b_open | need_g
            no_free = pop & (s.free_tail <= s.free_head)
            popped = s.free_q[s.free_head % blocks]
            open2 = jnp.where(pop, popped, s.open_block)
            np0 = jnp.where(b_open, _i32(0), s.next_page)
            next_page = jnp.where(
                in_host, np0 + w_tk,
                jnp.where(in_gc,
                          jnp.where(need_g, k - room, s.next_page + k),
                          s.next_page))
            free_head = s.free_head + pop.astype(jnp.int32)
            free_tail = s.free_tail + in_gc.astype(jnp.int32)
            seq = s.seq + pop.astype(jnp.int32)
            # block-array updates are dense predicated selects over
            # [blocks]: XLA:CPU lowers scatter to a scalar loop, so
            # rewriting a whole block-sized array elementwise beats
            # touching two elements by index.  The pop target and the
            # erased victim are always distinct blocks (a victim is
            # full — never the open block or a free one).
            was_open = pop & (barange == s.open_block)
            at_victim = in_gc & (barange == v)
            full = (s.full | was_open) & ~at_victim
            fill_seq = jnp.where(pop & (barange == popped), s.seq,
                                 jnp.where(at_victim, _i32(-1),
                                           s.fill_seq))
            erase_count = s.erase_count + at_victim.astype(jnp.int32)
            free_q = jnp.where(
                in_gc & (barange == s.free_tail % blocks), v, s.free_q)

            # -- burst mapping (`FTLState.map_write`, vectorised): the
            # last write of each lpn owns the final L2P entry; every
            # write invalidates its predecessor — the pre-burst mapping
            # for a first occurrence, the previous duplicate's in-burst
            # page otherwise
            nowhere = _i32(total)
            wppn = open2 * ppb + np0 + (w_cum - 1)
            eqm = ((wlpn[:, None] == wlpn[None, :])
                   & wtake[:, None] & wtake[None, :])
            after = lanes[:, None] < lanes[None, :]
            is_last = wtake & ~jnp.any(eqm & after, axis=1)
            is_first = wtake & ~jnp.any(eqm & ~after & not_eye, axis=1)
            prev = jnp.max(jnp.where(eqm & after.T, lanes[None, :], -1),
                           axis=1)
            old_lane = jnp.where(is_first, s.l2p[wlpn],
                                 wppn[jnp.clip(prev, 0)])
            has_old = wtake & (old_lane >= 0)
            old_c = jnp.clip(old_lane, 0)

            # -- cycle mapping: relocations fill the frontier, spilling
            # into the popped block.  XLA:CPU pays scatter cost *per
            # update row* (~50 ns each, batched or not — measured), so
            # the map updates are organised to minimise rows: burst and
            # cycle predicates are disjoint (`in_host` vs `in_gc`), so
            # each map takes one [ppb]-row lane-wise-select scatter for
            # new entries, and P2L's invalidations (host predecessors /
            # the victim window wipe) share a second.  Valid counts go
            # dense instead: a [ppb, blocks] one-hot histogram of
            # invalidated blocks plus predicated adds on [blocks] (the
            # victim zeroes by subtracting k — its count *is* k, the
            # popcount of its P2L window).
            gr_in = r_idx < room
            gppn = (jnp.where(gr_in, s.open_block, popped) * ppb
                    + jnp.where(gr_in, s.next_page + r_idx,
                                r_idx - room))
            l2p = s.l2p.at[jnp.where(
                in_gc, jnp.where(vmask, glpn, nowhere),
                jnp.where(is_last, wlpn, nowhere))].set(
                jnp.where(in_gc, gppn, wppn), mode="drop")
            p2l = s.p2l.at[jnp.where(
                in_gc, jnp.where(vmask, gppn, nowhere),
                jnp.where(wtake, wppn, nowhere))].set(
                jnp.where(in_gc, glpn, wlpn), mode="drop")
            p2l = p2l.at[jnp.where(
                in_gc, v * ppb + lanes,
                jnp.where(has_old, old_c, nowhere))].set(
                _i32(-1), mode="drop")
            old_hist = jnp.sum(
                has_old[:, None] & ((old_c // ppb)[:, None] == barange),
                axis=0, dtype=jnp.int32)
            vc = (s.valid_count - old_hist
                  + jnp.where(in_host & (barange == open2), w_tk, 0)
                  - jnp.where(at_victim, k, 0)
                  + jnp.where(in_gc & (barange == s.open_block),
                              jnp.minimum(k, room), 0)
                  + jnp.where(need_g & (barange == popped),
                              k - room, 0))

            in_gc_i = in_gc.astype(jnp.int32)
            guard = jnp.where(w_tk > 0, _i32(0), s.guard + in_gc_i)
            err = s.err | jnp.where(no_free, _i32(ERR_NO_FREE), _i32(0))
            err = err | jnp.where(in_gc & (guard > 4 * blocks),
                                  _i32(ERR_GUARD), _i32(0))

            # -- GC trigger + victim selection on the post-step arrays
            # (exactly the state the host's `while` loop re-tests: a
            # burst only triggers through its final write when the pool
            # already sat at the threshold, an allocating write or a
            # finished cycle re-checks the live pool).  The cascaded
            # masked argmin reproduces `np.lexsort`: min valid (greedy
            # only), then min fill_seq, then lowest block id.
            free_blocks = free_tail - free_head
            trigger = ((in_host & (w_tk > 0)) | in_gc) \
                & (free_blocks <= gc_free)
            any_c = jnp.any(full)
            m_valid = jnp.min(jnp.where(full, vc, _i32(_BIG)))
            c2 = full & (is_lru | (vc == m_valid))
            m_fill = jnp.min(jnp.where(c2, fill_seq, _i32(_BIG)))
            new_victim = jnp.argmax(c2 & (fill_seq == m_fill)).astype(
                jnp.int32)
            err = err | jnp.where(trigger & ~any_c, _i32(ERR_NO_CAND),
                                  _i32(0))
            err = err | jnp.where(trigger & any_c & (m_valid >= ppb),
                                  _i32(ERR_ALL_VALID), _i32(0))
            mode = jnp.where(active,
                             jnp.where(trigger, _i32(MODE_GC),
                                       _i32(MODE_HOST)), s.mode)
            victim = jnp.where(trigger, new_victim, s.victim)

            # -- counters + watermark (the host samples it after each
            # write that starts no GC drain, and after each erase)
            lastw = jnp.max(jnp.where(wtake, lanes, _i32(-1)))
            arrival = jnp.where(w_tk > 0, warr[jnp.clip(lastw, 0)],
                                s.arrival)
            free_now = free_blocks * ppb + (ppb - next_page)
            watermark = jnp.where(
                (in_host & (w_tk > 0) & ~trigger) | in_gc,
                jnp.minimum(s.watermark, free_now), s.watermark)
            kk = jnp.where(in_gc, k, _i32(0))
            s2 = ScanFTLState(
                l2p=l2p, p2l=p2l, valid_count=vc, full=full,
                fill_seq=fill_seq, erase_count=erase_count,
                free_q=free_q, free_head=free_head, free_tail=free_tail,
                open_block=open2, next_page=next_page, seq=seq,
                h=s.h + jnp.where(in_host,
                                  jnp.where(b_open, _i32(1), K), _i32(0)),
                mode=mode, victim=victim, guard=guard, arrival=arrival,
                watermark=watermark, host_w=s.host_w + w_tk,
                total_w=s.total_w + w_tk + kk,
                gc_pages=s.gc_pages + kk, gc_reads=s.gc_reads + kk,
                gc_writes=s.gc_writes + kk,
                erases=s.erases + in_gc_i, err=err)

            # -- emit one row: burst ops in lanes 0..ppb-1, the cycle's
            # read/write pairs at (2i, 2i+1) and its erase at lane 2k.
            # GC lanes carry no per-page payload — just op class, the
            # cycle's arrival and a valid bit — and host/GC predicates
            # are disjoint, so the whole row is elementwise selects on
            # the lane index (no scatter); idle lanes are the
            # payload-masked identity.
            gc_val = in_gc & (jlanes <= 2 * k)
            gc_cls = jnp.where(jlanes < 2 * k, gc_pat, _i32(ERASE))
            h_cls = jnp.concatenate([
                jnp.where(take,
                          jnp.where(w_lane, _i32(FTL_WRITE),
                                    _i32(FTL_READ)), _i32(0)),
                jnp.zeros((ppb + 1,), jnp.int32)])
            h_arr = jnp.concatenate([
                jnp.where(take, warr, jnp.float32(0.0)),
                jnp.zeros((ppb + 1,), jnp.float32)])
            row_cls = jnp.where(gc_val, gc_cls, h_cls)
            row_arr = jnp.where(gc_val, s.arrival, h_arr)
            row_pay = jnp.concatenate([take & wpay,
                                       jnp.zeros((ppb + 1,), bool)])
            row_rid = jnp.concatenate([
                jnp.where(take, wrid, _i32(-1)),
                jnp.full((ppb + 1,), -1, jnp.int32)])
            row_val = gc_val | jnp.concatenate(
                [take, jnp.zeros((ppb + 1,), bool)])
            return s2, (row_cls, row_arr, row_pay, row_rid, row_val)

        state = state._replace(
            l2p=jnp.asarray(state.l2p, jnp.int32),
            p2l=jnp.asarray(state.p2l, jnp.int32))
        return jax.lax.scan(step, state, None, length=t_max,
                            unroll=unroll)

    return fold


#: Scan unroll factor for the jitted folds.  Measured on XLA:CPU the
#: fold is dispatch-dominated *inside* the step (scatter/gather ops),
#: so unrolling the scan body buys nothing (424 us/step at unroll 1,
#: 2 and 4 alike) — keep 1 for the smallest compile.
_UNROLL = 1


@functools.lru_cache(maxsize=128)
def _jitted_fold(blocks: int, ppb: int, n_host: int, t_max: int):
    return jax.jit(make_translate_fold(blocks, ppb, n_host, t_max,
                                       unroll=_UNROLL))


def _bucket(n: int, floor: int = 64) -> int:
    """Quantise ``n`` up to an eight-steps-per-octave ladder (multiples
    of ``2^(ceil(log2 n) - 3)``, <= ~14% slack).  Power-of-two buckets
    would waste up to 2x: the fold's wall time is linear in ``t_max``,
    so buffer slack is pure cost, while each extra ladder point is at
    most one more compile (``_jitted_fold`` keys on bucketed shapes)."""
    n = max(n, floor)
    base = 1 << max((n - 1).bit_length() - 3, 0)
    return -(-n // base) * base


def _est_waf(spec: FTLSpec) -> float:
    """Estimated steady-state WAF with a policy safety margin (lru
    decays worse than the greedy fixed point)."""
    return analytic_waf(spec.utilization) * (
        1.15 if spec.gc_policy == "greedy" else 2.5)


def estimate_t_max(spec: FTLSpec, n_reads: int, n_writes: int, *,
                   precondition: bool = False) -> int:
    """Initial output-buffer length in fused *steps*.  Calibrated
    against measured machine runs: at steady state every GC cycle
    costs ~3 rows on a mixed stream (the cycle itself, the allocating
    write that fired it, and the burst fragment it cut), while the
    preconditioning stream (a sequential fill, then uniform
    overwrites, ``precondition=True``) fragments less (~2 rows per
    cycle — its fill phase runs GC-free).  An underestimate is
    detected, not wrong — the caller doubles and re-runs, and the
    sweep path remembers the realised row count per shape."""
    ppb = spec.pages_per_block
    n = n_reads + n_writes
    cycles = math.ceil(n_writes * _est_waf(spec) / ppb)
    rows_per_cycle = 2 if precondition else 3
    return _bucket(-(-n // ppb) + -(-n_writes // ppb)
                   + rows_per_cycle * cycles + spec.blocks // ppb + 32)


def estimate_ops(spec: FTLSpec, n_reads: int, n_writes: int) -> int:
    """Physical op-count estimate for one translated stream (host ops
    plus GC read/write pairs plus erases) — the sweep path's initial
    compacted end-time buffer length (unbucketed; the caller pads and
    buckets, and doubles on overflow)."""
    w = _est_waf(spec)
    ppb = spec.pages_per_block
    gc_pages = math.ceil(n_writes * max(w - 1.0, 0.0))
    erases = math.ceil(n_writes * w / ppb) + spec.blocks
    return n_reads + n_writes + 2 * gc_pages + erases


_ERR_ORDER = (ERR_NO_FREE, ERR_GUARD, ERR_NO_CAND, ERR_ALL_VALID)


def _raise_scan_error(err: int, spec: FTLSpec):
    """Decode a latched error bit to the host translator's message,
    verbatim (the check order mirrors which raise the host loop
    reaches first)."""
    msgs = {
        ERR_NO_FREE: "FTL out of free blocks mid-allocation — geometry "
                     f"too small for GC to keep up ({spec.describe()})",
        ERR_GUARD: "GC cannot reclaim space — overprovisioning too "
                   f"small for the footprint ({spec.describe()})",
        ERR_NO_CAND: "GC triggered with no collectable block "
                     f"({spec.describe()}) — grow blocks or "
                     "gc_free_blocks",
        ERR_ALL_VALID: "every collectable block is fully valid — the "
                       "logical footprint has consumed the "
                       f"overprovisioning pool ({spec.describe()}); "
                       "raise overprovision or shrink the workload "
                       "footprint",
    }
    for bit in _ERR_ORDER:
        if err & bit:
            raise RuntimeError(msgs[bit])
    raise RuntimeError(f"unknown FTL scan error bits {err}")


def _run_machine(fs: ScanFTLState, spec: FTLSpec, cls, arr, pay, rid,
                 lpns, t_hint: int):
    """Run the translation machine over one host-op batch, doubling the
    output buffer until the stream is fully consumed.  Returns
    ``(final_state, ys)`` with ``ys`` the raw ``[t_max, 2*ppb+1]``
    emission rows (``_trim`` flattens and masks them)."""
    n = len(cls)
    ppb = spec.pages_per_block
    n_b = _bucket(n + ppb)      # window slack: the ppb-op host slice
    pad = n_b - n               # at h never clamps or misaligns
    cls_p = np.pad(np.asarray(cls, np.int32), (0, pad))
    arr_p = np.pad(np.asarray(arr, np.float32), (0, pad))
    pay_p = np.pad(np.asarray(pay, bool), (0, pad))
    rid_p = np.pad(np.asarray(rid, np.int32), (0, pad))
    lpn_p = np.pad(np.asarray(lpns, np.int32), (0, pad))
    gc_free = np.int32(spec.gc_free_blocks)
    is_lru = spec.gc_policy == "lru"
    t_max = _bucket(t_hint)
    # hard ceiling: the guard bounds GC cycles per host write and every
    # step consumes a host op or runs a cycle, so a complete run can
    # never need more steps than this
    cap = 2 * _bucket(n * (4 * spec.blocks + 2) + 64)
    fs = fs._replace(h=_i32(0))
    while True:
        fold = _jitted_fold(spec.blocks, ppb, n_b, t_max)
        out, ys = fold(cls_p, arr_p, pay_p, rid_p, lpn_p, np.int32(n),
                       gc_free, is_lru, fs)
        err = int(out.err)
        if err:
            _raise_scan_error(err, spec)
        if int(out.h) >= n and int(out.mode) == MODE_HOST:
            return out, ys
        if t_max >= cap:     # pragma: no cover - guard catches first
            raise RuntimeError(
                "FTL scan translation failed to terminate "
                f"({spec.describe()})")
        t_max *= 2


def _trim(ys) -> tuple[np.ndarray, ...]:
    op_cls, arrival, payload, rid, valid = ys
    m = np.asarray(valid).reshape(-1)
    cls = np.asarray(op_cls, np.int32).reshape(-1)[m]
    return (cls,
            np.asarray(arrival, np.float32).reshape(-1)[m],
            np.asarray(payload, bool).reshape(-1)[m],
            np.asarray(rid, np.int32).reshape(-1)[m],
            cls >= GC_READ)


def _reset_window(fs: ScanFTLState, ppb: int) -> ScanFTLState:
    """Zero the measured-window counters after preconditioning (wear —
    ``erase_count`` — persists), mirroring ``ftl._precondition``.
    Shape-polymorphic: works on a single state or a stacked ``[P]``
    batch of them (the sweep path's cached pre-states)."""
    free_now = ((fs.free_tail - fs.free_head) * ppb
                + (ppb - fs.next_page))
    z = jnp.zeros_like(fs.host_w)
    return fs._replace(host_w=z, total_w=z, gc_pages=z, gc_reads=z,
                       gc_writes=z, erases=z,
                       watermark=_i32(free_now), h=z)


def translate_scan(stream: RequestStream, spec: FTLSpec, *,
                   state: FTLState | None = None) -> FTLTranslation:
    """``ftl.translate`` compiled: identical op sequence, stats and
    final drive state for every fault-free translation, via the
    ``lax.scan`` machine instead of the per-op host loop.  ``state``
    chains aging exactly like the host path, except the input state is
    *not* mutated — use the returned ``FTLTranslation.state``.  Block-
    level fault probabilities are not accepted here (RNG stays outside
    the folds); ``repro.core.api`` routes faulty translations to the
    host oracle."""
    if stream.n_requests == 0:
        raise ValueError("empty workload: no requests to translate")
    if int(np.max(stream.op_cls)) > WRITE:
        raise ValueError(
            "FTL translation consumes host READ/WRITE streams only "
            f"(got op class {int(np.max(stream.op_cls))})")
    if state is None:
        fs = scan_state_fresh(spec)
        if spec.precondition:
            lp = precondition_lpns(spec)
            npre = len(lp)
            fs, _ = _run_machine(
                fs, spec, np.full(npre, WRITE, np.int32),
                np.zeros(npre, np.float32), np.zeros(npre, bool),
                np.full(npre, -1, np.int32), lp,
                estimate_t_max(spec, 0, npre, precondition=True))
            fs = _reset_window(fs, spec.pages_per_block)
    else:
        fs = scan_state_from_host(state)
    # the machine runs the state's own spec (a chained state owns the
    # drive); the host-facing address space stays the caller's, exactly
    # like the host path's request_lpns call
    mspec = spec if state is None else state.spec
    cls, arrival, rid, payload = request_ops(stream)
    lpns = request_lpns(stream, spec.logical_pages)
    n_writes = int(np.sum(cls == WRITE))
    fs, ys = _run_machine(fs, mspec, cls, arrival, payload, rid, lpns,
                          estimate_t_max(mspec, len(cls) - n_writes,
                                         n_writes))
    op_cls, arr, pay, rid_o, gc = _trim(ys)
    out_state = scan_state_to_host(fs, mspec)
    return FTLTranslation(op_cls=op_cls, arrival_us=arr, payload=pay,
                          request_id=rid_o, gc=gc,
                          stats=out_state.stats, state=out_state)


__all__ = [
    "ERR_ALL_VALID", "ERR_GUARD", "ERR_NO_CAND", "ERR_NO_FREE",
    "MODE_GC", "MODE_HOST",
    "ScanFTLState", "estimate_ops", "estimate_t_max",
    "make_translate_fold",
    "scan_state_fresh", "scan_state_from_host", "scan_state_to_host",
    "translate_scan",
]
