"""Calibration of the four write-path parameters against paper Table 3.

What is calibrated and why
--------------------------
The paper's simulator is an RTL/behavioural co-simulation whose firmware
and NAND internals are not fully published.  Read-path parameters are
derived analytically (DESIGN.md §5): bus clocks come from Eqs. (6)/(9),
data bursts from page+spare sizes, and the per-cell-type ECC occupancy
(``cycles * t_P + fixed``) is solved exactly from the 1-way and saturated
read cells.  That leaves the write path, where we fit:

* SLC: effective page program time ``t_prog`` (datasheet typ. 200 us) and
  per-way status-poll occupancy ``t_poll``;
* MLC: paired-page program times ``(t_prog_lo, t_prog_hi)`` (datasheet
  mean 800 us) and ``t_poll``.

The fit minimises mean |error| over the 15 write cells per cell type
(5 way counts x 3 interfaces) of Table 3 with the ``eager`` policy.
Run ``python -m repro.core.calibrate`` to reproduce the constants frozen
in ``repro.core.nand``.
"""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import nand as nand_mod
from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, NandChipParams
from repro.core.paper_tables import INTERFACE_ORDER, TABLE3
from repro.core.sim import PageOpParams, page_op_params
from repro.core.sim_ref import bandwidth_ref_mb_s

WAYS = (1, 2, 4, 8, 16)


def _write_errors(chip: NandChipParams, n_pages: int = 512) -> list[float]:
    errs = []
    cell = chip.cell.value
    for ways in WAYS:
        paper_row = TABLE3[cell]["write"][ways]
        for idx, kind in enumerate(INTERFACE_ORDER):
            iface = make_interface(InterfaceKind(kind))
            op = page_op_params(iface, chip, "write", ways)
            sim = bandwidth_ref_mb_s(op, ways, n_pages)
            errs.append((sim - paper_row[idx]) / paper_row[idx])
    return errs


def fit_slc() -> tuple[float, float, float]:
    best = (1e9, None)
    for t_prog in np.arange(205, 235, 1.0):
        for t_poll in np.arange(0.0, 1.0, 0.04):
            chip = nand_mod.SLC.__class__(
                cell=CellType.SLC, page_data_bytes=2048, page_spare_bytes=64,
                t_r_us=25.0, t_prog_lo_us=t_prog, t_prog_hi_us=t_prog,
                t_poll_us=t_poll,
            )
            mae = float(np.mean(np.abs(_write_errors(chip))))
            if mae < best[0]:
                best = (mae, (t_prog, t_poll))
    (t_prog, t_poll) = best[1]
    return t_prog, t_poll, best[0]


def fit_mlc() -> tuple[float, float, float, float]:
    best = (1e9, None)
    for lo in np.arange(150, 450, 25.0):
        for hi in np.arange(1100, 1700, 25.0):
            for t_poll in np.arange(0.0, 3.0, 0.25):
                chip = NandChipParams(
                    cell=CellType.MLC, page_data_bytes=4096, page_spare_bytes=128,
                    t_r_us=60.0, t_prog_lo_us=lo, t_prog_hi_us=hi,
                    t_poll_us=t_poll,
                )
                mae = float(np.mean(np.abs(_write_errors(chip))))
                if mae < best[0]:
                    best = (mae, (lo, hi, t_poll))
    lo, hi, t_poll = best[1]
    return lo, hi, t_poll, best[0]


def main() -> None:
    t_prog, t_poll, mae = fit_slc()
    print(f"SLC : t_prog={t_prog:.1f}us t_poll={t_poll:.2f}us  write-MAE={mae*100:.2f}%")
    lo, hi, poll, mae = fit_mlc()
    print(f"MLC : t_prog_lo={lo:.0f}us t_prog_hi={hi:.0f}us (mean {0.5*(lo+hi):.0f}) "
          f"t_poll={poll:.2f}us  write-MAE={mae*100:.2f}%")
    print("Frozen constants live in repro.core.nand — update them if these differ.")


if __name__ == "__main__":
    main()
