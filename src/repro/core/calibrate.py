"""Calibration of the four write-path parameters against paper Table 3.

What is calibrated and why
--------------------------
The paper's simulator is an RTL/behavioural co-simulation whose firmware
and NAND internals are not fully published.  Read-path parameters are
derived analytically (DESIGN.md §5): bus clocks come from Eqs. (6)/(9),
data bursts from page+spare sizes, and the per-cell-type ECC occupancy
(``cycles * t_P + fixed``) is solved exactly from the 1-way and saturated
read cells.  That leaves the write path, where we fit:

* SLC: effective page program time ``t_prog`` (datasheet typ. 200 us) and
  per-way status-poll occupancy ``t_poll``;
* MLC: paired-page program times ``(t_prog_lo, t_prog_hi)`` (datasheet
  mean 800 us) and ``t_poll``.

The fit minimises mean |error| over the 15 write cells per cell type
(5 way counts x 3 interfaces) of Table 3 with the ``eager`` policy.
Run ``python -m repro.core.calibrate`` to reproduce the constants frozen
in ``repro.core.nand``.

Multi-channel arbitration (DESIGN.md §3.2): the two firmware arbitration
fractions in ``repro.core.sim`` (``CTRL_ARB_SWITCH_FRAC`` /
``CTRL_ARB_SCAN_FRAC``) are fitted the same way against Table 4's 2ch/4ch
cells.  ``stripe_crosscheck`` verifies that the *simulated* joint
multi-channel path still exhibits sub-linear power-law aggregate scaling
in the neighbourhood of the retired ``STRIPE_EFFICIENCY_EXP`` fudge
(measured ~C**0.95 vs the fudge's hard-coded C**0.92; the residual sits
inside Table 4's reproduction tolerance).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import nand as nand_mod
from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, NandChipParams
from repro.core.paper_tables import INTERFACE_ORDER, TABLE3
from repro.core.sim import page_op_params

WAYS = (1, 2, 4, 8, 16)

_OP_FIELDS = ("cmd_us", "pre_us", "slot_us", "post_lo_us", "post_hi_us",
              "ctrl_us", "data_bytes")


def _write_errors(chip: NandChipParams, n_pages: int = 512) -> list[float]:
    """Relative write-bandwidth errors over the 15 Table 3 cells
    (5 way counts × 3 interfaces), evaluated as ONE batched
    ``api.sweep_steady_bandwidth_mb_s`` design-point sweep per candidate
    chip — vectorised (and device-sharded when a multi-device mesh is
    up) instead of 15 sequential reference-oracle event loops, which is
    what lets the fitting grids below ride the fleet path."""
    from repro.core.api import sweep_steady_bandwidth_mb_s

    cell = chip.cell.value
    cols: dict[str, list[float]] = {f: [] for f in _OP_FIELDS}
    ways_col, paper = [], []
    for ways in WAYS:
        paper_row = TABLE3[cell]["write"][ways]
        for idx, kind in enumerate(INTERFACE_ORDER):
            op = page_op_params(make_interface(InterfaceKind(kind)),
                                chip, "write", ways)
            for f in _OP_FIELDS:
                cols[f].append(float(getattr(op, f)))
            ways_col.append(ways)
            paper.append(paper_row[idx])
    sim = np.asarray(sweep_steady_bandwidth_mb_s(
        *(np.asarray(cols[f]) for f in _OP_FIELDS),
        np.asarray(ways_col, np.int32), n_pages=n_pages), np.float64)
    paper_arr = np.asarray(paper, np.float64)
    return list((sim - paper_arr) / paper_arr)


def fit_slc(n_pages: int = 256) -> tuple[float, float, float]:
    best = (1e9, None)
    for t_prog in np.arange(205, 235, 1.0):
        for t_poll_cycles in np.arange(0.0, 50.0, 5.0):
            chip = dataclasses.replace(
                nand_mod.SLC, t_prog_lo_us=t_prog, t_prog_hi_us=t_prog,
                t_poll_cycles=t_poll_cycles)
            mae = float(np.mean(np.abs(_write_errors(chip, n_pages))))
            if mae < best[0]:
                best = (mae, (t_prog, t_poll_cycles))
    (t_prog, t_poll_cycles) = best[1]
    return t_prog, t_poll_cycles, best[0]


def fit_mlc(n_pages: int = 256) -> tuple[float, float, float, float]:
    best = (1e9, None)
    for lo in np.arange(150, 450, 25.0):
        for hi in np.arange(1100, 1700, 25.0):
            for t_poll_cycles in np.arange(0.0, 150.0, 5.0):
                chip = dataclasses.replace(
                    nand_mod.MLC, t_prog_lo_us=lo, t_prog_hi_us=hi,
                    t_poll_cycles=t_poll_cycles)
                mae = float(np.mean(np.abs(_write_errors(chip, n_pages))))
                if mae < best[0]:
                    best = (mae, (lo, hi, t_poll_cycles))
    lo, hi, t_poll_cycles = best[1]
    return lo, hi, t_poll_cycles, best[0]


RETIRED_STRIPE_EFFICIENCY_EXP = 0.92  # the seed's calibrated fudge


def stripe_crosscheck() -> dict[tuple[str, str], float]:
    """Fit aggregate = per_channel * C**x to the *simulated* joint
    multi-channel path and report x per (cell, mode).

    The seed multiplied a single-channel simulation by C**0.92; the joint
    simulation with shared-controller occupancy + firmware arbitration
    lands at ~C**0.95 on the paper's Table 4 geometries — sub-linear
    power-law scaling in the fudge's neighbourhood, produced by a
    mechanism instead of a hard-coded exponent."""
    from repro.core.api import steady_bandwidth_mb_s
    from repro.core.sim import SSDConfig

    out = {}
    for cell in ("slc", "mlc"):
        for mode in ("read", "write"):
            xs = []
            for channels, ways in ((2, 8), (4, 4)):
                one = steady_bandwidth_mb_s(
                    SSDConfig(cell=CellType(cell), interface=InterfaceKind.CONV,
                              channels=1, ways=ways), mode)
                many = steady_bandwidth_mb_s(
                    SSDConfig(cell=CellType(cell), interface=InterfaceKind.CONV,
                              channels=channels, ways=ways), mode)
                xs.append(np.log(many / one) / np.log(channels))
            out[(cell, mode)] = float(np.mean(xs))
    return out


def main() -> None:
    t_prog, t_poll, mae = fit_slc()
    print(f"SLC : t_prog={t_prog:.1f}us t_poll={t_poll:.0f}cyc  write-MAE={mae*100:.2f}%")
    lo, hi, poll, mae = fit_mlc()
    print(f"MLC : t_prog_lo={lo:.0f}us t_prog_hi={hi:.0f}us (mean {0.5*(lo+hi):.0f}) "
          f"t_poll={poll:.0f}cyc  write-MAE={mae*100:.2f}%")
    print("Frozen constants live in repro.core.nand — update them if these differ.")
    for (cell, mode), x in stripe_crosscheck().items():
        print(f"stripe cross-check {cell}/{mode}: simulated scaling ~ C**{x:.3f} "
              f"(retired fudge: C**{RETIRED_STRIPE_EFFICIENCY_EXP})")


if __name__ == "__main__":
    main()
