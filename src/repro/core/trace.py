"""Heterogeneous op traces for the multi-channel SSD simulator.

The paper's evaluation (§5.3) covers only homogeneous steady streams —
pure reads or pure writes on one channel.  Real SSD traffic is mixed and
contention-dominated, so every engine in this repo consumes an
``OpTrace``: per-op arrays of op-class index, channel, way and page
parity, plus an ``OpClassTable`` mapping class indices to scalar timing
(DESIGN.md §2.2).  Builders cover steady streams, mixed read/write
ratios, hot/cold skew, and the access patterns of the storage-tier
consumers (checkpoint / datapipe / KV-offload).

The homogeneous builders reproduce the original single-stream engines
bit-for-bit (regression-pinned in ``tests/test_trace_engines.py``); the
heterogeneous ones are what the paper's simulator could not express.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import chip as nand_chip
from repro.core.sim import (MAX_CHANNELS, MAX_WAYS, Engine, Policy,
                            SSDConfig, controller_arb_us, page_op_params)

READ, WRITE = 0, 1


@dataclasses.dataclass(frozen=True)
class OpClassTable:
    """Timing table of the op classes a trace indexes into (arrays [K])."""

    cmd_us: np.ndarray
    pre_us: np.ndarray
    slot_us: np.ndarray
    post_lo_us: np.ndarray
    post_hi_us: np.ndarray
    ctrl_us: np.ndarray       # shared-controller (FTL/firmware) share of slot
    arb_us: np.ndarray        # per-op firmware arbitration charge
    data_bytes: np.ndarray
    io_us: np.ndarray | None = None  # bus data-burst share of slot
                                     # (phase-resolved energy accounting)
    labels: tuple[str, ...] = ()

    @property
    def n_classes(self) -> int:
        return len(self.cmd_us)


@dataclasses.dataclass(frozen=True)
class OpTrace:
    """One op per entry; arrays [T] int32.  ``parity`` is the MLC
    lower/upper page alternation index of the op on its chip.
    ``payload`` marks ops that deliver user bytes — hedged duplicate
    reads occupy the bus/controller but are not counted as payload."""

    cls: np.ndarray
    channel: np.ndarray
    way: np.ndarray
    parity: np.ndarray
    channels: int
    ways: int
    payload: np.ndarray | None = None   # bool [T]; None = all payload

    @property
    def n_ops(self) -> int:
        return len(self.cls)

    def payload_mask(self) -> np.ndarray:
        if self.payload is None:
            return np.ones(self.n_ops, bool)
        return self.payload.astype(bool)

    def total_bytes(self, table: OpClassTable) -> int:
        return int(table.data_bytes[self.cls[self.payload_mask()]].sum())

    def read_fraction(self) -> float:
        """Fraction of *payload* ops that are reads — hedged duplicates
        are excluded, matching the byte accounting of ``total_bytes``."""
        mask = self.payload_mask()
        if not mask.any():
            return 0.0
        return float(np.mean(self.cls[mask] == READ))

    def describe(self) -> str:
        return (f"{self.n_ops} ops, {self.channels}ch x {self.ways}way, "
                f"read_frac={self.read_fraction():.2f}")


def op_class_table(cfg: SSDConfig) -> OpClassTable:
    """READ/WRITE op classes for one SSD design point."""
    iface = make_interface(cfg.interface)
    nand = nand_chip(cfg.cell)
    ops = [page_op_params(iface, nand, mode, cfg.ways)
           for mode in ("read", "write")]
    return OpClassTable(
        cmd_us=np.array([o.cmd_us for o in ops], np.float32),
        pre_us=np.array([o.pre_us for o in ops], np.float32),
        slot_us=np.array([o.slot_us for o in ops], np.float32),
        post_lo_us=np.array([o.post_lo_us for o in ops], np.float32),
        post_hi_us=np.array([o.post_hi_us for o in ops], np.float32),
        ctrl_us=np.array([o.ctrl_us for o in ops], np.float32),
        arb_us=np.array(
            [controller_arb_us(o.ctrl_us, cfg.channels) for o in ops],
            np.float32),
        data_bytes=np.array([o.data_bytes for o in ops], np.int64),
        io_us=np.array([o.io_us for o in ops], np.float32),
        labels=("read", "write"),
    )


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def _finalize(cls, channel, way, channels, ways, payload=None):
    """Derive per-chip page parity: the i-th op on a chip programs the
    lower (even i) or upper (odd i) page of an MLC pair."""
    assert 1 <= channels <= MAX_CHANNELS, \
        f"channels must be in [1, {MAX_CHANNELS}], got {channels}"
    assert 1 <= ways <= MAX_WAYS, \
        f"ways must be in [1, {MAX_WAYS}], got {ways}"
    cls = np.asarray(cls, np.int32)
    channel = np.asarray(channel, np.int32)
    way = np.asarray(way, np.int32)
    parity = np.zeros_like(cls)
    counts = np.zeros((channels, ways), np.int64)
    for t in range(len(cls)):
        c, w = channel[t], way[t]
        parity[t] = counts[c, w] % 2
        counts[c, w] += 1
    return OpTrace(cls=cls, channel=channel, way=way, parity=parity,
                   channels=channels, ways=ways,
                   payload=(None if payload is None
                            else np.asarray(payload, bool)))


def _round_robin(n_ops: int, channels: int, ways: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """(channel, way) placement of ``n_ops`` sequential pages: stripe
    round-robin over channels first, then over a channel's ways — the
    single definition every sequential builder (and the Table 3/4
    regression baseline) shares."""
    t = np.arange(n_ops)
    return t % channels, (t // channels) % ways


def steady_trace(n_pages_per_channel: int, channels: int, ways: int,
                 op_cls: int = READ) -> OpTrace:
    """Homogeneous stream, striped round-robin over channels then ways —
    the paper's §5.3 workload; reproduces the retired single-stream
    engines exactly at channels=1."""
    n = n_pages_per_channel * channels
    chan, way = _round_robin(n, channels, ways)
    return _finalize(np.full(n, op_cls), chan, way, channels, ways)


def mixed_trace(n_ops: int, channels: int, ways: int, read_fraction: float,
                seed: int = 0) -> OpTrace:
    """Mixed read/write traffic, channel/way round-robin placement."""
    rng = np.random.default_rng(seed)
    cls = np.where(rng.random(n_ops) < read_fraction, READ, WRITE)
    chan, way = _round_robin(n_ops, channels, ways)
    return _finalize(cls, chan, way, channels, ways)


def hot_cold_trace(n_ops: int, channels: int, ways: int,
                   read_fraction: float = 0.7, hot_fraction: float = 0.8,
                   hot_share: float = 0.25, seed: int = 0) -> OpTrace:
    """Skewed placement: ``hot_fraction`` of ops land on the ``hot_share``
    hottest chips (FTL hot/cold separation stress; no round-robin)."""
    rng = np.random.default_rng(seed)
    n_chips = channels * ways
    n_hot = max(1, int(round(hot_share * n_chips)))
    hot = rng.random(n_ops) < hot_fraction
    chip = np.where(hot, rng.integers(0, n_hot, n_ops),
                    rng.integers(0, n_chips, n_ops))
    cls = np.where(rng.random(n_ops) < read_fraction, READ, WRITE)
    return _finalize(cls, chip % channels, (chip // channels) % ways,
                     channels, ways)


def _pages(nbytes: int, page_bytes: int) -> int:
    return max(1, -(-int(nbytes) // page_bytes))


def _bucket(n: int, max_ops: int) -> int:
    """Round a window length up to a power of two (bounded by max_ops) so
    byte-extrapolated estimates reuse jit cache entries across sizes."""
    return min(max_ops, 1 << (n - 1).bit_length())


def checkpoint_trace(nbytes: int, cfg: SSDConfig,
                     max_ops: int = 4096) -> OpTrace:
    """Checkpoint save: a pure write burst, chunk-striped across channels
    (mirrors ``CheckpointEngine``'s round-robin chunk placement).  Long
    bursts are truncated to ``max_ops``; callers extrapolate by bytes
    (the stream is steady-state)."""
    n = _bucket(_pages(nbytes, nand_chip(cfg.cell).page_data_bytes), max_ops)
    chan, way = _round_robin(n, cfg.channels, cfg.ways)
    return _finalize(np.full(n, WRITE), chan, way, cfg.channels, cfg.ways)


def datapipe_trace(nbytes: int, cfg: SSDConfig, hedge_fraction: float = 0.0,
                   seed: int = 0, max_ops: int = 4096) -> OpTrace:
    """Data-pipeline refill: way-interleaved shard reads; a
    ``hedge_fraction`` of reads is re-issued on the next channel
    (straggler hedging duplicates traffic, it does not replace it)."""
    n = _bucket(_pages(nbytes, nand_chip(cfg.cell).page_data_bytes), max_ops)
    rng = np.random.default_rng(seed)
    chan, way = _round_robin(n, cfg.channels, cfg.ways)
    cls, channel, ways_, payload = [], [], [], []
    hedged = rng.random(n) < hedge_fraction
    for i in range(n):
        cls.append(READ); channel.append(chan[i]); ways_.append(way[i])
        payload.append(True)
        if hedged[i]:
            # duplicate occupies a neighbouring channel but delivers no
            # *new* payload bytes (first response wins)
            cls.append(READ)
            channel.append((chan[i] + 1) % cfg.channels)
            ways_.append(way[i])
            payload.append(False)
    return _finalize(cls, channel, ways_, cfg.channels, cfg.ways,
                     payload=payload)


def kvoffload_trace(read_bytes_per_token: int, cfg: SSDConfig,
                    n_tokens: int = 8, append_bytes_per_token: int = 0,
                    max_ops: int = 4096) -> OpTrace:
    """Long-context decode: per token, a cold-KV read burst with the KV
    append writes interleaved evenly (write-back caching overlaps the
    append with the read stream), striped across channels.  Interleaving
    keeps the read/write mix representative when a huge per-token burst
    is truncated to the ``max_ops`` simulation window."""
    page = nand_chip(cfg.cell).page_data_bytes
    reads = _pages(read_bytes_per_token, page)
    writes = (_pages(append_bytes_per_token, page)
              if append_bytes_per_token > 0 else 0)
    # build only the simulated window: a GiB-scale burst is represented
    # by a max_ops-sized pattern with the same read/write mix
    per_tok = reads + writes
    if per_tok > max_ops:
        writes = round(writes * max_ops / per_tok) if writes else 0
        reads = max_ops - writes
    token = np.full(reads, READ, np.int32)
    if writes:
        at = np.linspace(0, reads, writes, endpoint=False).astype(int)
        token = np.insert(token, np.sort(at), WRITE)
    reps = min(n_tokens, -(-max_ops // len(token)))
    cls = np.tile(token, reps)[:max_ops]
    chan, way = _round_robin(cls.size, cfg.channels, cfg.ways)
    return _finalize(cls, chan, way, cfg.channels, cfg.ways)


# ---------------------------------------------------------------------------
# Deprecated query shims (the dispatch now lives in repro.core.api)
# ---------------------------------------------------------------------------


def simulate(table: OpClassTable, trace: OpTrace, policy: Policy = "eager",
             engine: Engine = "scan", segment_len: int | None = 64) -> float:
    """Deprecated shim: use ``repro.api.Simulator.run`` — every
    registered engine (scan / prefix / squaring / pallas / oracle) is
    reachable there through one request surface.  Numerically
    identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.simulate is deprecated; use "
        "repro.api.Simulator.run", DeprecationWarning, stacklevel=2)
    return api.Simulator(table=table).run(
        trace, policy=policy, engine=engine,
        segment_len=segment_len).end_us


def simulate_batch(tables: list[OpClassTable], trace: OpTrace,
                   policy: Policy = "eager", engine: Engine = "prefix",
                   segment_len: int | None = 64,
                   combine: str = "chain") -> np.ndarray:
    """Deprecated shim: use ``repro.api.sweep_tables`` (or
    ``Simulator.sweep``).  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.simulate_batch is deprecated; use "
        "repro.api.sweep_tables", DeprecationWarning, stacklevel=2)
    return np.asarray(api.sweep_tables(
        list(tables), trace, policy=policy, engine=engine,
        segment_len=segment_len, combine=combine))


def simulate_energy(table: OpClassTable, trace: OpTrace,
                    kind: InterfaceKind | str, policy: Policy = "eager",
                    engine: str = "scan", segment_len: int | None = 64):
    """Deprecated shim: use ``repro.api.Simulator.run`` with
    ``objective="energy"`` (returns a ``SimResult`` whose ``energy`` is
    this ``EnergyBreakdown``).  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.simulate_energy is deprecated; use "
        "repro.api.Simulator.run(objective='energy')",
        DeprecationWarning, stacklevel=2)
    return api.Simulator(table=table, kind=kind).run(
        trace, policy=policy, engine=engine, segment_len=segment_len,
        objective="energy").energy


def trace_bandwidth_mb_s(table: OpClassTable, trace: OpTrace,
                         policy: Policy = "eager",
                         engine: Engine = "scan") -> float:
    """Deprecated shim: use ``repro.api.Simulator.run`` with
    ``objective="bandwidth"`` (``SimResult.mb_s``).  Rejects empty or
    payload-free traces like the original.  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.trace_bandwidth_mb_s is deprecated; use "
        "repro.api.Simulator.run(objective='bandwidth')",
        DeprecationWarning, stacklevel=2)
    if trace.n_ops == 0:
        raise ValueError("empty trace: no ops to simulate")
    if trace.total_bytes(table) <= 0:
        raise ValueError("trace delivers no payload bytes")
    return api.Simulator(table=table).run(
        trace, policy=policy, engine=engine, objective="bandwidth").mb_s


_WORKLOADS = {
    "steady_read": lambda cfg, n_pages=512: steady_trace(
        n_pages, cfg.channels, cfg.ways, READ),
    "steady_write": lambda cfg, n_pages=512: steady_trace(
        n_pages, cfg.channels, cfg.ways, WRITE),
    "mixed": lambda cfg, n_ops=None, read_fraction=0.7, seed=0: mixed_trace(
        n_ops or 512 * cfg.channels, cfg.channels, cfg.ways,
        read_fraction, seed),
    "hot_cold": lambda cfg, n_ops=None, **kw: hot_cold_trace(
        n_ops or 512 * cfg.channels, cfg.channels, cfg.ways, **kw),
    "checkpoint": lambda cfg, nbytes, **kw: checkpoint_trace(
        nbytes, cfg, **kw),
    "datapipe": lambda cfg, nbytes, **kw: datapipe_trace(nbytes, cfg, **kw),
    "kvoffload": lambda cfg, read_bytes_per_token, **kw: kvoffload_trace(
        read_bytes_per_token, cfg, **kw),
}


def workload_trace(kind: str, cfg: SSDConfig, **kw) -> OpTrace:
    """Named workload registry (benchmarks / examples / sweeps).
    Unknown kwargs raise TypeError from the underlying builder."""
    if kind not in _WORKLOADS:
        raise KeyError(
            f"unknown workload {kind!r}; one of {sorted(_WORKLOADS)}")
    return _WORKLOADS[kind](cfg, **kw)
