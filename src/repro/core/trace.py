"""Heterogeneous op traces for the multi-channel SSD simulator.

The paper's evaluation (§5.3) covers only homogeneous steady streams —
pure reads or pure writes on one channel.  Real SSD traffic is mixed and
contention-dominated, so every engine in this repo consumes an
``OpTrace``: per-op arrays of op-class index, channel, way and page
parity, plus an ``OpClassTable`` mapping class indices to scalar timing
(DESIGN.md §2.2).  Builders cover steady streams, mixed read/write
ratios, hot/cold skew, and the access patterns of the storage-tier
consumers (checkpoint / datapipe / KV-offload).

The homogeneous builders reproduce the original single-stream engines
bit-for-bit (regression-pinned in ``tests/test_trace_engines.py``); the
heterogeneous ones are what the paper's simulator could not express.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import chip as nand_chip
from repro.core.sim import (MAX_CHANNELS, MAX_WAYS, Engine, Policy,
                            SSDConfig, controller_arb_us, page_op_params)

READ, WRITE = 0, 1


@dataclasses.dataclass(frozen=True)
class OpClassTable:
    """Timing table of the op classes a trace indexes into (arrays [K])."""

    cmd_us: np.ndarray
    pre_us: np.ndarray
    slot_us: np.ndarray
    post_lo_us: np.ndarray
    post_hi_us: np.ndarray
    ctrl_us: np.ndarray       # shared-controller (FTL/firmware) share of slot
    arb_us: np.ndarray        # per-op firmware arbitration charge
    data_bytes: np.ndarray
    io_us: np.ndarray | None = None  # bus data-burst share of slot
                                     # (phase-resolved energy accounting)
    labels: tuple[str, ...] = ()

    @property
    def n_classes(self) -> int:
        return len(self.cmd_us)


@dataclasses.dataclass(frozen=True)
class OpTrace:
    """One op per entry; arrays [T] int32.  ``parity`` is the MLC
    lower/upper page alternation index of the op on its chip.
    ``payload`` marks ops that deliver user bytes — hedged duplicate
    reads occupy the bus/controller but are not counted as payload.
    ``arrival_us`` carries per-op request arrival times (float32 us;
    None = back-to-back, the pre-request-layer behaviour): every engine
    lower-bounds an op's ready time by its arrival (DESIGN.md §2.6).
    ``extra_us`` carries per-op additive reliability latency — read
    retries and jitter sampled outside the fold by ``repro.core.faults``
    (float32 us; None = fault-free): every engine extends the op's chip
    occupancy — and hence its completion — by it (DESIGN.md §2.8; the
    channel bus and serial controller are not extended, because retries
    re-run the sense inside the die).

    Construction validates the geometry indices: an out-of-range
    channel/way used to scatter silently with ``mode="drop"`` semantics
    in the prefix path (the op vanished from the product) while the
    scan engine clamped — now it raises here, once, for every engine."""

    cls: np.ndarray
    channel: np.ndarray
    way: np.ndarray
    parity: np.ndarray
    channels: int
    ways: int
    payload: np.ndarray | None = None      # bool [T]; None = all payload
    arrival_us: np.ndarray | None = None   # float32 [T]; None = all zero
    extra_us: np.ndarray | None = None     # float32 [T]; None = all zero

    def __post_init__(self):
        n = len(self.cls)
        for name in ("channel", "way", "parity"):
            if len(getattr(self, name)) != n:
                raise ValueError(f"OpTrace.{name} has length "
                                 f"{len(getattr(self, name))}, cls has {n}")
        for name in ("payload", "arrival_us", "extra_us"):
            arr = getattr(self, name)
            if arr is not None and len(arr) != n:
                raise ValueError(f"OpTrace.{name} has length {len(arr)}, "
                                 f"cls has {n}")
        if n == 0:
            return
        for name, arr, bound in (("cls", self.cls, None),
                                 ("channel", self.channel, self.channels),
                                 ("way", self.way, self.ways),
                                 ("parity", self.parity, None)):
            lo, hi = int(np.min(arr)), int(np.max(arr))
            if lo < 0 or (bound is not None and hi >= bound):
                raise ValueError(
                    f"OpTrace.{name} out of range: [{lo}, {hi}] does not "
                    f"fit {name} bounds [0, {bound})" if bound is not None
                    else f"OpTrace.{name} must be non-negative, got {lo}")
        if self.arrival_us is not None and float(np.min(self.arrival_us)) < 0:
            raise ValueError("OpTrace.arrival_us must be non-negative")
        if self.extra_us is not None and float(np.min(self.extra_us)) < 0:
            raise ValueError("OpTrace.extra_us must be non-negative")

    @property
    def n_ops(self) -> int:
        return len(self.cls)

    def payload_mask(self) -> np.ndarray:
        if self.payload is None:
            return np.ones(self.n_ops, bool)
        return self.payload.astype(bool)

    def total_bytes(self, table: OpClassTable) -> int:
        return int(table.data_bytes[self.cls[self.payload_mask()]].sum())

    def read_fraction(self) -> float:
        """Fraction of *payload* ops that are reads — hedged duplicates
        are excluded, matching the byte accounting of ``total_bytes``."""
        mask = self.payload_mask()
        if not mask.any():
            return 0.0
        return float(np.mean(self.cls[mask] == READ))

    def validate_against(self, table: OpClassTable) -> None:
        """Geometry bounds are checked at construction; the op-class
        bound needs the timing table, so query layers call this before
        simulating (an out-of-range class used to gather garbage
        timings silently)."""
        if self.n_ops and int(np.max(self.cls)) >= table.n_classes:
            raise ValueError(
                f"OpTrace.cls out of range: max {int(np.max(self.cls))} "
                f">= table.n_classes {table.n_classes}")

    def describe(self) -> str:
        return (f"{self.n_ops} ops, {self.channels}ch x {self.ways}way, "
                f"read_frac={self.read_fraction():.2f}")


def op_class_table(cfg: SSDConfig) -> OpClassTable:
    """READ/WRITE op classes for one SSD design point."""
    iface = make_interface(cfg.interface)
    nand = nand_chip(cfg.cell)
    ops = [page_op_params(iface, nand, mode, cfg.ways)
           for mode in ("read", "write")]
    return OpClassTable(
        cmd_us=np.array([o.cmd_us for o in ops], np.float32),
        pre_us=np.array([o.pre_us for o in ops], np.float32),
        slot_us=np.array([o.slot_us for o in ops], np.float32),
        post_lo_us=np.array([o.post_lo_us for o in ops], np.float32),
        post_hi_us=np.array([o.post_hi_us for o in ops], np.float32),
        ctrl_us=np.array([o.ctrl_us for o in ops], np.float32),
        arb_us=np.array(
            [controller_arb_us(o.ctrl_us, cfg.channels) for o in ops],
            np.float32),
        data_bytes=np.array([o.data_bytes for o in ops], np.int64),
        io_us=np.array([o.io_us for o in ops], np.float32),
        labels=("read", "write"),
    )


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def _finalize(cls, channel, way, channels, ways, payload=None):
    """Derive per-chip page parity: the i-th op on a chip programs the
    lower (even i) or upper (odd i) page of an MLC pair."""
    assert 1 <= channels <= MAX_CHANNELS, \
        f"channels must be in [1, {MAX_CHANNELS}], got {channels}"
    assert 1 <= ways <= MAX_WAYS, \
        f"ways must be in [1, {MAX_WAYS}], got {ways}"
    cls = np.asarray(cls, np.int32)
    channel = np.asarray(channel, np.int32)
    way = np.asarray(way, np.int32)
    parity = np.zeros_like(cls)
    counts = np.zeros((channels, ways), np.int64)
    for t in range(len(cls)):
        c, w = channel[t], way[t]
        parity[t] = counts[c, w] % 2
        counts[c, w] += 1
    return OpTrace(cls=cls, channel=channel, way=way, parity=parity,
                   channels=channels, ways=ways,
                   payload=(None if payload is None
                            else np.asarray(payload, bool)))


def _round_robin(n_ops: int, channels: int, ways: int
                 ) -> tuple[np.ndarray, np.ndarray]:
    """(channel, way) placement of ``n_ops`` sequential pages: stripe
    round-robin over channels first, then over a channel's ways — the
    single definition every sequential builder (and the Table 3/4
    regression baseline) shares."""
    t = np.arange(n_ops)
    return t % channels, (t // channels) % ways


def steady_trace(n_pages_per_channel: int, channels: int, ways: int,
                 op_cls: int = READ) -> OpTrace:
    """Homogeneous stream, striped round-robin over channels then ways —
    the paper's §5.3 workload; reproduces the retired single-stream
    engines exactly at channels=1."""
    n = n_pages_per_channel * channels
    chan, way = _round_robin(n, channels, ways)
    return _finalize(np.full(n, op_cls), chan, way, channels, ways)


def mixed_trace(n_ops: int, channels: int, ways: int, read_fraction: float,
                seed: int = 0) -> OpTrace:
    """Mixed read/write traffic, channel/way round-robin placement."""
    rng = np.random.default_rng(seed)
    cls = np.where(rng.random(n_ops) < read_fraction, READ, WRITE)
    chan, way = _round_robin(n_ops, channels, ways)
    return _finalize(cls, chan, way, channels, ways)


def _rewrite_chunk(sampler, cls, channel, way, parity, channels, ways,
                   payload, arrival) -> OpTrace:
    """Run one chunk of op arrays through a carried ``FaultSampler`` and
    pack the rewrite into an ``OpTrace`` (chunked == one-shot because the
    sampler draws from one PCG64 stream regardless of chunk boundaries,
    DESIGN.md §2.8)."""
    if payload is None and sampler.spec.prog_fail_prob > 0.0:
        # byte conservation needs an explicit mask once remaps can strip
        # a failed write's credit — mirror sched.apply_faults exactly
        payload = np.ones(len(cls), bool)
    c2, ch2, w2, par2, arr2, ext2, pay2, _ = sampler.rewrite(
        cls, channel, way, parity, arrival=arrival, payload=payload)
    return OpTrace(
        cls=np.asarray(c2, np.int32), channel=np.asarray(ch2, np.int32),
        way=np.asarray(w2, np.int32), parity=np.asarray(par2, np.int32),
        channels=channels, ways=ways, payload=pay2,
        arrival_us=(None if arr2 is None
                    else np.asarray(arr2, np.float32)),
        extra_us=np.asarray(ext2, np.float32))


def iter_trace_chunks(trace: OpTrace, chunk_len: int, *, faults=None,
                      table: OpClassTable | None = None):
    """Yield ``trace`` as consecutive ``OpTrace`` chunks of at most
    ``chunk_len`` ops — the materialised-trace adapter for the
    constant-memory streaming engine (DESIGN.md §2.7).  Chunks carry the
    same geometry and slice ``payload``/``arrival_us``/``extra_us``
    alongside the op arrays, so concatenating them reconstructs the
    trace exactly.

    With ``faults`` (a :class:`repro.core.faults.FaultSpec`), each chunk
    is rewritten through one carried sampler: the concatenated chunks
    are bit-identical to ``repro.core.sched.apply_faults`` applied to
    the whole trace (remap inserts may make a chunk longer than
    ``chunk_len``).  ``table`` is required when the spec charges retries
    as per-class re-reads (``retry_step_us=None``)."""
    if chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    sampler = None
    if faults is not None:
        if trace.extra_us is not None:
            raise ValueError("trace already carries extra_us; refusing to "
                             "re-apply faults")
        from repro.core.faults import FaultSampler
        sampler = FaultSampler(faults, trace.channels, trace.ways,
                               table=table)
    for lo in range(0, trace.n_ops, chunk_len):
        hi = min(lo + chunk_len, trace.n_ops)
        payload = None if trace.payload is None else trace.payload[lo:hi]
        arrival = (None if trace.arrival_us is None
                   else trace.arrival_us[lo:hi])
        if sampler is not None:
            yield _rewrite_chunk(sampler, trace.cls[lo:hi],
                                 trace.channel[lo:hi], trace.way[lo:hi],
                                 trace.parity[lo:hi], trace.channels,
                                 trace.ways, payload, arrival)
            continue
        yield OpTrace(
            cls=trace.cls[lo:hi], channel=trace.channel[lo:hi],
            way=trace.way[lo:hi], parity=trace.parity[lo:hi],
            channels=trace.channels, ways=trace.ways,
            payload=payload, arrival_us=arrival,
            extra_us=(None if trace.extra_us is None
                      else trace.extra_us[lo:hi]))


def mixed_trace_chunks(n_ops: int, channels: int, ways: int,
                       read_fraction: float, *, chunk_len: int = 65536,
                       seed: int = 0, faults=None,
                       table: OpClassTable | None = None):
    """Generator twin of :func:`mixed_trace`: yields the *identical* op
    stream (same rng draws, same round-robin placement, same per-chip
    parity) in ``OpTrace`` chunks without ever materialising the whole
    trace — million-op streaming-engine inputs in O(chunk_len) memory.

    The PCG64 stream draws doubles sequentially, so chunked ``random``
    calls reproduce the single-shot draw; round-robin placement revisits
    a chip every ``channels * ways`` ops, so the per-chip parity counter
    of ``_finalize`` closes to ``(t // (channels * ways)) % 2``.

    With ``faults`` attached, every chunk is additionally rewritten
    through one carried :class:`repro.core.faults.FaultSampler` — the
    fault draws come from ``faults.seed``'s own PCG64 streams (disjoint
    from the op-mix stream above), so the concatenated output is
    bit-identical to ``apply_faults(mixed_trace(...), faults, table)``."""
    if chunk_len < 1:
        raise ValueError(f"chunk_len must be >= 1, got {chunk_len}")
    rng = np.random.default_rng(seed)
    sampler = None
    if faults is not None:
        from repro.core.faults import FaultSampler
        sampler = FaultSampler(faults, channels, ways, table=table)
    period = channels * ways
    for lo in range(0, n_ops, chunk_len):
        hi = min(lo + chunk_len, n_ops)
        t = np.arange(lo, hi)
        cls = np.where(rng.random(hi - lo) < read_fraction, READ, WRITE)
        chan = (t % channels).astype(np.int32)
        way = ((t // channels) % ways).astype(np.int32)
        par = ((t // period) % 2).astype(np.int32)
        if sampler is not None:
            yield _rewrite_chunk(sampler, cls.astype(np.int32), chan, way,
                                 par, channels, ways, None, None)
            continue
        yield OpTrace(cls=cls.astype(np.int32), channel=chan, way=way,
                      parity=par, channels=channels, ways=ways)


def hot_cold_trace(n_ops: int, channels: int, ways: int,
                   read_fraction: float = 0.7, hot_fraction: float = 0.8,
                   hot_share: float = 0.25, seed: int = 0) -> OpTrace:
    """Skewed placement: ``hot_fraction`` of ops land on the ``hot_share``
    hottest chips (FTL hot/cold separation stress; no round-robin)."""
    rng = np.random.default_rng(seed)
    n_chips = channels * ways
    n_hot = max(1, int(round(hot_share * n_chips)))
    hot = rng.random(n_ops) < hot_fraction
    chip = np.where(hot, rng.integers(0, n_hot, n_ops),
                    rng.integers(0, n_chips, n_ops))
    cls = np.where(rng.random(n_ops) < read_fraction, READ, WRITE)
    return _finalize(cls, chip % channels, (chip // channels) % ways,
                     channels, ways)


def checkpoint_trace(nbytes: int, cfg: SSDConfig,
                     max_ops: int = 4096) -> OpTrace:
    """Checkpoint save: a pure write burst, chunk-striped across channels
    (mirrors ``CheckpointEngine``'s round-robin chunk placement).  Long
    bursts are truncated to ``max_ops``; callers extrapolate by bytes
    (the stream is steady-state).  Emits the request stream of
    ``repro.core.workload.checkpoint_requests`` lowered by the static
    ``stripe`` policy — numerically identical to the pre-request-layer
    builder (regression-pinned)."""
    from repro.core import sched, workload
    return sched.lower_static(
        workload.checkpoint_requests(nbytes, cfg, max_ops=max_ops),
        cfg.channels, cfg.ways).trace


def datapipe_trace(nbytes: int, cfg: SSDConfig, hedge_fraction: float = 0.0,
                   seed: int = 0, max_ops: int = 4096,
                   hedge_after_us: float = 0.0) -> OpTrace:
    """Data-pipeline refill: way-interleaved shard reads; a
    ``hedge_fraction`` of reads is re-issued on the next channel after
    ``hedge_after_us`` (straggler hedging duplicates traffic, it does
    not replace it).  Request stream from
    ``repro.core.workload.datapipe_requests`` lowered by ``stripe``
    (regression-pinned at ``hedge_after_us=0``)."""
    from repro.core import sched, workload
    return sched.lower_static(
        workload.datapipe_requests(nbytes, cfg,
                                   hedge_fraction=hedge_fraction,
                                   seed=seed, max_ops=max_ops,
                                   hedge_after_us=hedge_after_us),
        cfg.channels, cfg.ways).trace


def kvoffload_trace(read_bytes_per_token: int, cfg: SSDConfig,
                    n_tokens: int = 8, append_bytes_per_token: int = 0,
                    max_ops: int = 4096) -> OpTrace:
    """Long-context decode: per token, a cold-KV read burst with the KV
    append writes interleaved evenly (write-back caching overlaps the
    append with the read stream), striped across channels.  Request
    stream from ``repro.core.workload.kvoffload_requests`` lowered by
    ``stripe`` (regression-pinned)."""
    from repro.core import sched, workload
    return sched.lower_static(
        workload.kvoffload_requests(
            read_bytes_per_token, cfg, n_tokens=n_tokens,
            append_bytes_per_token=append_bytes_per_token, max_ops=max_ops),
        cfg.channels, cfg.ways).trace


# ---------------------------------------------------------------------------
# Deprecated query shims (the dispatch now lives in repro.core.api)
# ---------------------------------------------------------------------------


def simulate(table: OpClassTable, trace: OpTrace, policy: Policy = "eager",
             engine: Engine = "scan", segment_len: int | None = 64) -> float:
    """Deprecated shim: use ``repro.api.Simulator.run`` — every
    registered engine (scan / prefix / squaring / pallas / oracle) is
    reachable there through one request surface.  Numerically
    identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.simulate is deprecated; use "
        "repro.api.Simulator.run", DeprecationWarning, stacklevel=2)
    return api.Simulator(table=table).run(
        trace, policy=policy, engine=engine,
        segment_len=segment_len).end_us


def simulate_batch(tables: list[OpClassTable], trace: OpTrace,
                   policy: Policy = "eager", engine: Engine = "prefix",
                   segment_len: int | None = 64,
                   combine: str = "chain") -> np.ndarray:
    """Deprecated shim: use ``repro.api.sweep_tables`` (or
    ``Simulator.sweep``).  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.simulate_batch is deprecated; use "
        "repro.api.sweep_tables", DeprecationWarning, stacklevel=2)
    return np.asarray(api.sweep_tables(
        list(tables), trace, policy=policy, engine=engine,
        segment_len=segment_len, combine=combine))


def simulate_energy(table: OpClassTable, trace: OpTrace,
                    kind: InterfaceKind | str, policy: Policy = "eager",
                    engine: str = "scan", segment_len: int | None = 64):
    """Deprecated shim: use ``repro.api.Simulator.run`` with
    ``objective="energy"`` (returns a ``SimResult`` whose ``energy`` is
    this ``EnergyBreakdown``).  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.simulate_energy is deprecated; use "
        "repro.api.Simulator.run(objective='energy')",
        DeprecationWarning, stacklevel=2)
    return api.Simulator(table=table, kind=kind).run(
        trace, policy=policy, engine=engine, segment_len=segment_len,
        objective="energy").energy


def trace_bandwidth_mb_s(table: OpClassTable, trace: OpTrace,
                         policy: Policy = "eager",
                         engine: Engine = "scan") -> float:
    """Deprecated shim: use ``repro.api.Simulator.run`` with
    ``objective="bandwidth"`` (``SimResult.mb_s``).  Rejects empty or
    payload-free traces like the original.  Numerically identical."""
    from repro.core import api
    warnings.warn(
        "repro.core.trace.trace_bandwidth_mb_s is deprecated; use "
        "repro.api.Simulator.run(objective='bandwidth')",
        DeprecationWarning, stacklevel=2)
    if trace.n_ops == 0:
        raise ValueError("empty trace: no ops to simulate")
    if trace.total_bytes(table) <= 0:
        raise ValueError("trace delivers no payload bytes")
    return api.Simulator(table=table).run(
        trace, policy=policy, engine=engine, objective="bandwidth").mb_s


def workload_trace(kind: str, cfg: SSDConfig, **kw) -> OpTrace:
    """Deprecated shim: use ``repro.core.workload.build_workload`` — the
    named registry now lives in the request-level workload layer
    (DESIGN.md §2.6), where the storage kinds are built as
    ``RequestStream``s and lowered by the static stripe scheduler.
    Numerically identical.  Unknown kinds raise a ValueError naming the
    valid kinds; unknown kwargs still raise TypeError from the
    underlying builder."""
    from repro.core import workload
    warnings.warn(
        "repro.core.trace.workload_trace is deprecated; use "
        "repro.core.workload.build_workload", DeprecationWarning,
        stacklevel=2)
    return workload.build_workload(kind, cfg, **kw)
