"""Unified ``Simulator`` session API: one request/response surface over
every simulation engine (DESIGN.md §2.5).

After the engine work of PRs 1-3 the query surface had fragmented into
seven entry points with incompatible knobs (``engine=`` on
``trace.simulate``, ``strategy=`` on the Pallas ops, ``combine=`` on the
prefix folds, ``engine="squaring"`` only on the homogeneous sweeps) and
incompatible result types (bare floats, arrays, ``EnergyBreakdown``,
``IOEstimate``).  This module absorbs that dispatch into three pieces:

* an **engine registry** — every evaluation strategy registers once
  under a name (``scan`` / ``prefix`` / ``squaring`` / ``pallas`` /
  ``oracle``) with a declared :class:`EngineCaps` capability row
  (heterogeneous traces?  batched design-point tables?  energy?
  jit-able?).  Unknown names raise one ``ValueError`` listing the
  registered engines; a registered engine asked for something outside
  its capability row raises :class:`CapabilityError` (a ``ValueError``)
  naming the engines that can serve it.  This is the FMMU argument
  (Woo & Min 2017) in software: a uniform request interface in front of
  heterogeneous engines is what makes the pool schedulable.

* a **session object** — :class:`Simulator` binds an ``SSDConfig`` /
  ``OpClassTable`` once, converts the timing table to device arrays
  once, and caches jitted engine closures keyed on
  ``(engine, table geometry, trace-length bucket, policy, ...)`` so
  repeated queries never re-trace or re-convert.  The scan engine runs
  through a masked fold padded to power-of-two length buckets —
  identical results (masked ops are bitwise no-ops on the carried
  state), one compile per bucket instead of one per trace length.
  :meth:`Simulator.run_many` packs heterogeneous traces into those
  buckets and evaluates each bucket group in a single vmapped call —
  the serving path for sweep traffic.  ``Simulator.for_config`` memoises
  sessions per design point so the storage tier and the planners share
  compiled closures process-wide.

* one **request/response pair** — :class:`SimRequest` (trace, policy,
  objective ∈ {end_time, bandwidth, energy, all}, optional engine
  override) in, :class:`SimResult` (end_us, per-channel bus occupancy,
  MB/s, optional ``EnergyBreakdown``) out, for every engine and every
  entry point.  The ``Policy`` literal is validated once, here, in the
  request layer — a typo like ``"bathced"`` raises instead of silently
  simulating ``"eager"``.

Above the trace layer, requests are first-class (DESIGN.md §2.6): a
``SimRequest`` may carry a placement-free
``repro.core.workload.RequestStream`` plus a ``sched_policy`` — static
policies lower offline through ``repro.core.sched`` and reach every
engine; dynamic policies require the ``dispatch`` capability and run
the joint dispatch+simulate fold, attaching per-request latency
percentiles (``SimResult.p50_us`` / ``p99_us``) to the answer.

The legacy functions (``trace.simulate[_batch]``, ``simulate_energy``,
``trace_bandwidth_mb_s``, ``sim.channel_bandwidth_mb_s`` /
``sweep_bandwidth_mb_s`` / ``ssd_bandwidth_mb_s``,
``trace.workload_trace``) survive as thin
shims that emit ``DeprecationWarning`` and delegate here; a
``filterwarnings = error::DeprecationWarning:repro\\.`` rule in
pytest.ini (and the same programmatic filter in ``benchmarks/run_all``)
turns shim calls *from repro-internal modules* into errors, so internal
code can never call its own deprecated surface.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import warnings
from typing import Literal, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ftl as _ftl
from repro.core import ftl_scan as _ftl_scan
from repro.core import sched as _sched
from repro.core import sim as _sim
from repro.core import trace as _trace
from repro.core import workload as _workload
from repro.core.energy import (EnergyBreakdown, breakdown_from_sums,
                               op_phase_energy_uj)
from repro.core.faults import FaultSampler, FaultSpec
from repro.core.interface import InterfaceKind
from repro.core.sched import LoweredWorkload
from repro.core.sim import (MAX_WAYS, PageOpParams, Policy, SSDConfig,
                            policy_is_batched)
from repro.core.trace import OpClassTable, OpTrace, op_class_table
from repro.core.workload import RequestStream, request_ops

Objective = Literal["end_time", "bandwidth", "energy", "all"]
OBJECTIVES: tuple[str, ...] = ("end_time", "bandwidth", "energy", "all")

#: Op-class table columns, in the positional order the jitted engines take.
_TABLE_FIELDS = ("cmd_us", "pre_us", "slot_us", "post_lo_us", "post_hi_us",
                 "ctrl_us", "arb_us")


class CapabilityError(ValueError):
    """A *registered* engine was asked for a query outside its declared
    capability row (vs plain ``ValueError`` for unknown engine names)."""


# ---------------------------------------------------------------------------
# Engine protocol + registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineCaps:
    """Declared capability row of one registered engine."""

    name: str
    heterogeneous: bool   # arbitrary OpTrace (vs homogeneous periodic only)
    batched_tables: bool  # one trace x stacked design-point tables
    energy: bool          # phase-resolved energy accumulation
    jittable: bool        # pure-jax: Simulator caches jitted closures
    arrivals: bool = False  # arrival-aware traces (request workloads)
    dispatch: bool = False  # joint dispatch+simulate (dynamic sched policies)
    ftl: bool = False       # FTL-translated streams (GC/erase op classes)

    def describe(self) -> str:
        flags = [k for k in ("heterogeneous", "batched_tables", "energy",
                             "jittable", "arrivals", "dispatch", "ftl")
                 if getattr(self, k)]
        return f"{self.name}: {', '.join(flags) or 'none'}"


@runtime_checkable
class Engine(Protocol):
    """What a registered engine must answer.  ``sim`` is the session —
    it supplies the bound table, device-array conversions and the
    jit-closure cache; engines that declare ``jittable`` use it to keep
    repeated queries compile-free.  Optional capabilities
    (``end_time_batch``, ``steady_channel_end``, ``sweep_steady``) raise
    :class:`CapabilityError` on the base class.  Every engine must also
    answer ``canonical_folds`` — the traceable canonical-request hook
    the ``repro.analysis`` jaxpr layer enforces engine contracts through
    (DESIGN.md §2.9)."""

    caps: EngineCaps

    def end_time(self, sim: "Simulator", trace: OpTrace, *, batched: bool,
                 segment_len: int | None) -> float: ...

    def energy_sums(self, sim: "Simulator", trace: OpTrace,
                    kind: InterfaceKind, *, batched: bool,
                    segment_len: int | None) -> tuple[float, np.ndarray]: ...


_REGISTRY: dict[str, Engine] = {}


def register_engine(name: str, *, heterogeneous: bool, batched_tables: bool,
                    energy: bool, jittable: bool, arrivals: bool = False,
                    dispatch: bool = False, ftl: bool = False):
    """Class decorator: instantiate and register an engine under ``name``
    with its declared capability row.  Names are unique."""

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"engine {name!r} is already registered")
        inst = cls()
        inst.caps = EngineCaps(name=name, heterogeneous=heterogeneous,
                               batched_tables=batched_tables, energy=energy,
                               jittable=jittable, arrivals=arrivals,
                               dispatch=dispatch, ftl=ftl)
        _REGISTRY[name] = inst
        return cls

    return deco


def registered_engines() -> tuple[str, ...]:
    """Sorted names of every registered engine."""
    return tuple(sorted(_REGISTRY))


def engine_capabilities() -> dict[str, EngineCaps]:
    """The full declared capability table, by engine name."""
    return {name: _REGISTRY[name].caps for name in registered_engines()}


def get_engine(name: str) -> Engine:
    """Look up a registered engine; unknown names raise the one shared
    ``ValueError`` every entry point now emits."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r} (registered engines: "
            f"{', '.join(registered_engines())})") from None


def _policy_name(batched: bool) -> str:
    return "batched" if batched else "eager"


def _bucket_len(n: int, floor: int = 64) -> int:
    """Trace lengths round up to power-of-two buckets so jitted closures
    (and compiles) are shared across nearby lengths."""
    return max(floor, 1 << max(0, (n - 1).bit_length()))


def _payload_latencies(lowered: LoweredWorkload, completion_us,
                       stream: RequestStream) -> np.ndarray:
    """Per-request latencies restricted to *payload* requests: hedged
    duplicates are transport, not requests — a duplicate queueing
    behind its primary must not inflate the reported tail.  When the
    stream links duplicates to their primaries (``hedge_of``, the
    ``with_hedges`` builder), the first response wins: the primary is
    credited with ``min(own done, duplicate done)`` — the whole point
    of hedging (DESIGN.md §2.8).  Unlinked legacy duplicates keep the
    conservative bound (the primary's own completion)."""
    comp = np.asarray(completion_us, np.float64)
    done = np.zeros(len(lowered.request_arrival_us), np.float64)
    np.maximum.at(done, lowered.request_id, comp)
    if stream.hedge_of is not None:
        h = np.asarray(stream.hedge_of, np.int64)
        link = h >= 0
        if link.any():
            np.minimum.at(done, h[link], done[link])
    lat = done - np.asarray(lowered.request_arrival_us, np.float64)
    pay = stream.payload_mask()
    return lat if pay.all() else lat[pay]


def _op_arrivals(trace: OpTrace) -> np.ndarray:
    """Per-op arrival array for the engines (zeros = back-to-back)."""
    if trace.arrival_us is None:
        return np.zeros(trace.n_ops, np.float32)
    return np.asarray(trace.arrival_us, np.float32)


def _op_extras(trace: OpTrace) -> np.ndarray:
    """Per-op reliability surcharge array (zeros = fault-free)."""
    if trace.extra_us is None:
        return np.zeros(trace.n_ops, np.float32)
    return np.asarray(trace.extra_us, np.float32)


def _trace_args(trace: OpTrace):
    return (jnp.asarray(trace.cls), jnp.asarray(trace.channel),
            jnp.asarray(trace.way), jnp.asarray(trace.parity),
            jnp.asarray(_op_arrivals(trace)),
            jnp.asarray(_op_extras(trace)))


def _pad_trace_np(trace: OpTrace, t_bucket: int):
    """Zero-pad the per-op arrays to ``t_bucket`` plus the validity mask
    consumed by the masked scan folds (padding ops are state no-ops) —
    the one padding contract ``run`` and ``run_many`` share."""
    pad = t_bucket - trace.n_ops
    valid = np.zeros(t_bucket, bool)
    valid[: trace.n_ops] = True
    return (np.pad(np.asarray(trace.cls), (0, pad)),
            np.pad(np.asarray(trace.channel), (0, pad)),
            np.pad(np.asarray(trace.way), (0, pad)),
            np.pad(np.asarray(trace.parity), (0, pad)),
            np.pad(_op_arrivals(trace), (0, pad)),
            np.pad(_op_extras(trace), (0, pad)),
            valid)


def _padded_trace_args(trace: OpTrace, t_bucket: int):
    return tuple(jnp.asarray(x) for x in _pad_trace_np(trace, t_bucket))


def _steady_channel_args(op: PageOpParams, ways, n_pages: int):
    """(table columns, cls zeros, way, parity, arrival zeros, extra
    zeros) of a single-channel round-robin stream over one op class —
    shared by every engine with the homogeneous-pattern capability."""
    scalars = _op_scalars(op)
    way, parity = _sim._steady_pattern(n_pages, jnp.asarray(ways, jnp.int32))
    zeros = jnp.zeros((n_pages,), jnp.int32)
    zeros_f = jnp.zeros((n_pages,), jnp.float32)
    table = tuple(x[None] for x in scalars) + (jnp.zeros((1,), jnp.float32),)
    return table, zeros, way, parity, zeros_f, zeros_f


def _stacked_table_args(tables: list[OpClassTable]):
    return tuple(jnp.asarray(np.stack([getattr(t, f) for t in tables]))
                 for f in _TABLE_FIELDS)


def _canonical_trace(arrivals: bool = True) -> OpTrace:
    """The canonical small request the analysis layer traces every
    engine on (DESIGN.md §2.9): a fixed-seed mixed read/write trace on
    2 channels x 4 ways.  With ``arrivals=True`` it carries nonzero
    per-op arrivals *and* reliability surcharges, so the origin row and
    the ``extra_us`` side-channel are part of the traced fold and the
    dtype/RNG invariants cover them."""
    t = _trace.mixed_trace(48, 2, 4, read_fraction=0.5, seed=7)
    if not arrivals:
        return t
    n = t.n_ops
    return dataclasses.replace(
        t,
        arrival_us=np.linspace(0.0, 40.0, n, dtype=np.float32),
        extra_us=np.where(np.arange(n) % 7 == 0, 3.0, 0.0
                          ).astype(np.float32))


class _EngineBase:
    """Shared defaults: optional capabilities raise ``CapabilityError``
    naming the registered engines that *do* implement them (derived
    from the registry, so new engines appear automatically)."""

    caps: EngineCaps

    def _unsupported(self, what: str, method: str):
        base = getattr(_EngineBase, method)
        supported = sorted(
            name for name, eng in _REGISTRY.items()
            if getattr(type(eng), method, base) is not base)
        raise CapabilityError(
            f"engine {self.caps.name!r} does not support {what} "
            f"(engines that do: {', '.join(supported)})")

    def end_time_batch(self, tables, trace, *, batched, segment_len,
                       combine="chain") -> np.ndarray:
        self._unsupported("batched design-point tables", "end_time_batch")

    def steady_channel_end(self, op: PageOpParams, ways, *, n_pages: int,
                           batched: bool):
        self._unsupported("homogeneous single-channel patterns",
                          "steady_channel_end")

    def sweep_steady(self, scalars, data_bytes, ways, *, n_pages: int,
                     batched: bool):
        self._unsupported("homogeneous design-point sweeps", "sweep_steady")

    def completions(self, sim: "Simulator", trace: OpTrace, *,
                    batched: bool,
                    segment_len: int | None = None
                    ) -> tuple[float, np.ndarray]:
        """(end_us, [T] per-op completion times) — what request-latency
        percentiles are computed from.  ``segment_len`` is the chunk
        length for chunked engines (others ignore it)."""
        self._unsupported("per-op completion times", "completions")

    def dispatch_run(self, sim: "Simulator", cls, arrival_us, *,
                     n_channels: int, n_ways: int, rule: str,
                     extra_us=None, retired=None):
        """Joint dispatch+simulate under a dynamic sched policy; returns
        (end_us, completion[T], channel[T], way[T], parity[T]).
        ``extra_us`` / ``retired`` are the reliability-layer inputs:
        per-op surcharges and the bad-block mask the dispatch rule must
        never place an op on (DESIGN.md §2.8)."""
        self._unsupported("dynamic dispatch policies", "dispatch_run")

    def canonical_folds(self, sim: "Simulator"):
        """label -> (fn, args): jax-traceable closures evaluating this
        engine's folds on the canonical request — the hook behind the
        ``repro.analysis`` jaxpr invariant layer (DESIGN.md §2.9), which
        statically asserts per fold: no RNG primitives, f32 dtype
        stability, and a primitive-count budget against the committed
        baseline.  Pure host-Python engines return ``None`` (the AST
        layer still lints their source).  Every registered engine MUST
        override this — the analyzer fails loudly on engines that
        don't, so a new engine cannot land outside the contract net."""
        raise NotImplementedError(
            f"engine {self.caps.name!r} exposes no canonical fold hook "
            "(repro.analysis traces every registered engine; override "
            "canonical_folds, returning None only for host-Python "
            "engines)")


@register_engine("scan", heterogeneous=True, batched_tables=True,
                 energy=True, jittable=True, arrivals=True, dispatch=True,
                 ftl=True)
class ScanEngine(_EngineBase):
    """O(T) ``lax.scan`` fold (DESIGN.md §2.2) — the default engine.
    Session queries run the masked fold padded to length buckets, so
    repeated nearby-length queries share one compile."""

    def end_time(self, sim, trace, *, batched, segment_len):
        t_b = _bucket_len(trace.n_ops)
        fn = sim._closure(
            ("scan", trace.channels, t_b, batched),
            lambda: functools.partial(
                _sim.trace_end_time_masked, *sim._targs,
                n_channels=trace.channels, batched=batched))
        return float(fn(*_padded_trace_args(trace, t_b)))

    def completions(self, sim, trace, *, batched, segment_len=None):
        t_b = _bucket_len(trace.n_ops)
        fn = sim._closure(
            ("scan-completions", trace.channels, t_b, batched),
            lambda: functools.partial(
                _sim.trace_completions_masked, *sim._targs,
                n_channels=trace.channels, batched=batched))
        end, comp = fn(*_padded_trace_args(trace, t_b))
        return float(end), np.asarray(comp, np.float64)[: trace.n_ops]

    def dispatch_run(self, sim, cls, arrival_us, *, n_channels, n_ways,
                     rule, extra_us=None, retired=None):
        fn = sim._closure(
            ("scan-dispatch", n_channels, n_ways, len(cls), rule,
             extra_us is not None, retired is not None),
            lambda: functools.partial(
                _sim.dispatch_trace, *sim._targs,
                n_channels=n_channels, n_ways=n_ways, rule=rule))
        kw = {}
        if extra_us is not None:
            kw["extra_us"] = jnp.asarray(extra_us, jnp.float32)
        if retired is not None:
            kw["retired"] = jnp.asarray(retired, bool)
        end, comp, chan, way, par = fn(jnp.asarray(cls, jnp.int32),
                                       jnp.asarray(arrival_us, jnp.float32),
                                       **kw)
        return (float(end), np.asarray(comp, np.float64),
                np.asarray(chan), np.asarray(way), np.asarray(par))

    def energy_sums(self, sim, trace, kind, *, batched, segment_len):
        fn = sim._closure(
            ("scan-energy", trace.channels, trace.n_ops, batched, kind),
            lambda: functools.partial(
                _sim.trace_end_time_energy, *sim._targs,
                sim._energy_table(kind),
                n_channels=trace.channels, batched=batched))
        end, sums = fn(*_trace_args(trace))
        return float(end), np.asarray(sums, np.float64)

    def end_time_batch(self, tables, trace, *, batched, segment_len,
                       combine="chain"):
        end = _sim.trace_end_time_batch(
            *_stacked_table_args(tables), *_trace_args(trace),
            n_channels=trace.channels, batched=batched)
        return np.asarray(end)

    def steady_channel_end(self, op, ways, *, n_pages, batched):
        table, zeros, way, parity, arr, ext = _steady_channel_args(
            op, ways, n_pages)
        return _sim.trace_end_time(
            *table, zeros, zeros, way, parity, arr, ext,
            n_channels=1, batched=batched)

    def sweep_steady(self, scalars, data_bytes, ways, *, n_pages, batched):
        return _sim._sweep_scan_jit(*scalars, data_bytes, ways,
                                    n_pages=n_pages, batched=batched)

    def canonical_folds(self, sim):
        t = _canonical_trace()
        end = functools.partial(
            _sim.trace_end_time_masked, *sim._targs,
            n_channels=t.channels, batched=False)
        disp = functools.partial(
            _sim.dispatch_trace, *sim._targs, n_channels=t.channels,
            n_ways=t.ways, rule="least_loaded")
        folds = {
            "end_time": (end, _padded_trace_args(t, _bucket_len(t.n_ops))),
            "dispatch": (disp, (jnp.asarray(t.cls, jnp.int32),
                                jnp.asarray(_op_arrivals(t)))),
        }
        if sim.config is not None:
            # the FTL stage (DESIGN.md §2.10) reuses this same fold over
            # the extended 7-class table: trace a small deterministic
            # GC-injected stream so the invariant net covers it
            spec = _ftl.FTLSpec(blocks=8, pages_per_block=8,
                                overprovision=0.5)
            ftab = _ftl.ftl_op_class_table(sim.config, spec)
            ftargs = tuple(jnp.asarray(getattr(ftab, f))
                           for f in _TABLE_FIELDS)
            tr = _ftl.translate(
                _workload.overwrite_stream(48, 24, seed=3), spec)
            ft = _sched.lower_ops(tr.op_cls, tr.arrival_us,
                                  sim.config.channels, sim.config.ways,
                                  payload=tr.payload)
            fend = functools.partial(
                _sim.trace_end_time_masked, *ftargs,
                n_channels=ft.channels, batched=False)
            folds["ftl_end_time"] = (
                fend, _padded_trace_args(ft, _bucket_len(ft.n_ops)))
            # the compiled translation engine itself (DESIGN.md §2.11):
            # trace the scan FTL fold over the same small stream, so
            # the invariant net (RNG-free, f32, primitive budget) gates
            # the machine that now feeds every fault-free FTL query
            st = _workload.overwrite_stream(48, 24, seed=3)
            cls, arr, rid, pay = _workload.request_ops(st)
            lpns = _workload.request_lpns(st, spec.logical_pages)
            n_b = 64
            pad = n_b - len(cls)
            tfold = _ftl_scan.make_translate_fold(
                spec.blocks, spec.pages_per_block, n_b, 256)
            folds["ftl_translate"] = (tfold, (
                jnp.asarray(np.pad(cls, (0, pad)), jnp.int32),
                jnp.asarray(np.pad(arr, (0, pad)), jnp.float32),
                jnp.asarray(np.pad(pay, (0, pad)), bool),
                jnp.asarray(np.pad(rid, (0, pad)), jnp.int32),
                jnp.asarray(np.pad(lpns, (0, pad)), jnp.int32),
                jnp.int32(len(cls)), jnp.int32(spec.gc_free_blocks),
                jnp.asarray(False),
                _ftl_scan.scan_state_fresh(spec)))
        return folds


@register_engine("prefix", heterogeneous=True, batched_tables=True,
                 energy=True, jittable=True, arrivals=True, ftl=True)
class PrefixEngine(_EngineBase):
    """Segmented parallel-prefix (max,+) fold, O(L + log T) depth
    (DESIGN.md §2.3); energy rides the same chunking as segment sums."""

    def end_time(self, sim, trace, *, batched, segment_len):
        fn = sim._closure(
            ("prefix", trace.channels, trace.ways, trace.n_ops, batched,
             segment_len),
            lambda: functools.partial(
                _sim.trace_end_time_prefix, *sim._targs,
                n_channels=trace.channels, n_ways=trace.ways,
                batched=batched, segment_len=segment_len))
        return float(fn(*_trace_args(trace)))

    def energy_sums(self, sim, trace, kind, *, batched, segment_len):
        fn = sim._closure(
            ("prefix-energy", trace.channels, trace.ways, trace.n_ops,
             batched, segment_len, kind),
            lambda: functools.partial(
                _sim.trace_end_time_prefix_energy, *sim._targs,
                sim._energy_table(kind),
                n_channels=trace.channels, n_ways=trace.ways,
                batched=batched, segment_len=segment_len))
        end, sums = fn(*_trace_args(trace))
        return float(end), np.asarray(sums, np.float64)

    def end_time_batch(self, tables, trace, *, batched, segment_len,
                       combine="chain"):
        end = _sim.trace_end_time_prefix_batch(
            *_stacked_table_args(tables), *_trace_args(trace),
            n_channels=trace.channels, n_ways=trace.ways, batched=batched,
            segment_len=segment_len, combine=combine)
        return np.asarray(end)

    def steady_channel_end(self, op, ways, *, n_pages, batched):
        table, zeros, way, parity, arr, ext = _steady_channel_args(
            op, ways, n_pages)
        return _sim.trace_end_time_prefix(
            *table, zeros, zeros, way, parity, arr, ext,
            n_channels=1, n_ways=MAX_WAYS, batched=batched)

    def canonical_folds(self, sim):
        t = _canonical_trace()
        fn = functools.partial(
            _sim.trace_end_time_prefix, *sim._targs,
            n_channels=t.channels, n_ways=t.ways, batched=False,
            segment_len=16)
        return {"end_time": (fn, _trace_args(t))}


@register_engine("squaring", heterogeneous=False, batched_tables=False,
                 energy=True, jittable=True)
class SquaringEngine(_EngineBase):
    """Periodic (max,+) matrix squaring, O(log T) matmuls (DESIGN.md
    §2.3).  Homogeneous only: the trace must be a single-class,
    single-channel round-robin stream with ways | MAX_WAYS.  Energy is
    (+,+)-linear in the ops, so on that domain the accumulator is the
    exact per-op sum — engine-independent by construction."""

    def _periodic_form(self, sim, trace) -> tuple[int, int]:
        t = np.arange(trace.n_ops)
        cls = np.asarray(trace.cls)
        if trace.arrival_us is not None and np.any(trace.arrival_us > 0):
            okay = ", ".join(sorted(
                n for n, e in _REGISTRY.items() if e.caps.arrivals))
            raise CapabilityError(
                "engine 'squaring' folds a fixed period matrix — per-op "
                f"arrivals break periodicity (arrival-aware engines: {okay})")
        if trace.extra_us is not None and np.any(trace.extra_us > 0):
            okay = ", ".join(sorted(
                n for n, e in _REGISTRY.items() if e.caps.arrivals))
            raise CapabilityError(
                "engine 'squaring' folds a fixed period matrix — per-op "
                "reliability surcharges (extra_us) break periodicity "
                f"(fault-aware engines: {okay})")
        if (trace.channels != 1
                or np.any(cls != cls[0])
                or np.any(np.asarray(trace.channel) != 0)
                or np.any(np.asarray(trace.way) != t % trace.ways)
                or np.any(np.asarray(trace.parity)
                          != (t // trace.ways) % 2)):
            hetero = ", ".join(sorted(
                n for n, e in _REGISTRY.items() if e.caps.heterogeneous))
            raise CapabilityError(
                "engine 'squaring' needs a homogeneous single-channel "
                f"round-robin stream (heterogeneous engines: {hetero})")
        _sim._validate_squaring_ways(trace.ways)
        k = int(cls[0])
        if float(np.asarray(sim.table.arb_us)[k]) != 0.0:
            raise CapabilityError(
                "engine 'squaring' models a dedicated single-channel "
                "firmware loop (arb_us must be zero)")
        return k, trace.ways

    def end_time(self, sim, trace, *, batched, segment_len):
        k, ways = self._periodic_form(sim, trace)
        fn = sim._closure(
            ("squaring", k, ways, trace.n_ops, batched),
            lambda: functools.partial(
                _sim._squaring_end_time,
                *(sim._targs[i][k] for i in range(6)),
                jnp.asarray(ways, jnp.int32),
                n_pages=trace.n_ops, batched=batched))
        return float(fn())

    def energy_sums(self, sim, trace, kind, *, batched, segment_len):
        end = self.end_time(sim, trace, batched=batched,
                            segment_len=segment_len)
        return end, sim._linear_energy_sums(trace, kind)

    def steady_channel_end(self, op, ways, *, n_pages, batched):
        _sim._validate_squaring_ways(ways)
        return _sim._squaring_end_time(
            *_op_scalars(op), jnp.asarray(ways, jnp.int32),
            n_pages=n_pages, batched=batched)

    def sweep_steady(self, scalars, data_bytes, ways, *, n_pages, batched):
        _sim._validate_squaring_ways(ways)
        return _sim._sweep_squaring_jit(*scalars, data_bytes, ways,
                                        n_pages=n_pages, batched=batched)

    def canonical_folds(self, sim):
        # canonical *periodic* domain: one op class, single channel,
        # 4-way round robin (arrivals/extras break periodicity and are
        # rejected by this engine, so the canonical request has none)
        fn = functools.partial(
            _sim._squaring_end_time,
            *(sim._targs[i][_trace.READ] for i in range(6)),
            jnp.asarray(4, jnp.int32), n_pages=64, batched=False)
        return {"end_time": (fn, ())}


@register_engine("pallas", heterogeneous=True, batched_tables=True,
                 energy=True, jittable=False, arrivals=True, ftl=True)
class PallasEngine(_EngineBase):
    """The (max,+) Pallas matrix-fold kernel (TPU-native; interpret on
    CPU).  The step-matrix dictionary is built host-side per query, so
    the session closure cache does not apply."""

    def end_time(self, sim, trace, *, batched, segment_len):
        from repro.kernels.maxplus.ops import trace_end_time_maxplus
        return float(trace_end_time_maxplus(
            sim.table, trace, policy=_policy_name(batched)))

    def energy_sums(self, sim, trace, kind, *, batched, segment_len):
        from repro.kernels.maxplus.ops import trace_energy_maxplus
        end, sums = trace_energy_maxplus(
            sim.table, trace, kind, policy=_policy_name(batched))
        return float(end), np.asarray(sums, np.float64)

    def end_time_batch(self, tables, trace, *, batched, segment_len,
                       combine="chain"):
        from repro.kernels.maxplus.ops import trace_end_time_maxplus
        return np.asarray(trace_end_time_maxplus(
            list(tables), trace, policy=_policy_name(batched)))

    def canonical_folds(self, sim):
        from repro.kernels.maxplus.ops import trace_fold_closure
        return {"end_time": trace_fold_closure(
            sim.table, _canonical_trace(), policy="eager")}


@register_engine("streaming", heterogeneous=True, batched_tables=False,
                 energy=True, jittable=True, arrivals=True, ftl=True)
class StreamingEngine(_EngineBase):
    """Constant-memory chunked fold (DESIGN.md §2.7): the trace streams
    through ``sim.trace_chunk_fold`` in fixed-size masked chunks, with
    the occupancy state tuple, the arrival origin row and the
    phase-energy accumulator carried between chunks — the segment-product
    recurrence of §2.3 specialised to its concrete carried state, so any
    chunking reproduces the scan engine *bit-for-bit* while peak live
    memory stays O(chunk) regardless of trace length.  ``segment_len``
    is the chunk length; :meth:`Simulator.run_stream` feeds this engine
    chunk iterators that never materialise the trace at all."""

    def _fold(self, sim, chunks, *, batched, kind=None, want_comp=False):
        """Fold an iterator of ``OpTrace`` chunks; returns
        ``(end_us, [P] energy sums, comp list | None, channels)``.
        Chunks are padded to power-of-two length buckets, so a stream of
        equal-size chunks compiles exactly once (plus once for a ragged
        tail bucket)."""
        e_tab = None if kind is None else sim._energy_table(kind)
        carry = None
        channels = None
        comps = [] if want_comp else None
        end = None
        for chunk in chunks:
            if chunk.n_ops == 0:
                continue
            if channels is None:
                channels = chunk.channels
                if e_tab is None:
                    e_tab = jnp.zeros((sim.table.n_classes, 2, 1),
                                      jnp.float32)
                carry = _sim.trace_chunk_init(channels, e_tab.shape[-1])
            elif chunk.channels != channels:
                raise ValueError(
                    f"streaming chunks switched geometry mid-stream: "
                    f"{chunk.channels} channels after {channels}")
            l_b = _bucket_len(chunk.n_ops)
            fn = sim._closure(
                ("stream", channels, l_b, batched, kind is not None),
                lambda channels=channels: functools.partial(
                    _sim.trace_chunk_fold, *sim._targs,
                    n_channels=channels, batched=batched))
            state, acc, end, comp = fn(
                e_tab, *_padded_trace_args(chunk, l_b),
                *_carry_args(carry))
            carry = (state, acc)
            if want_comp:
                comps.append(np.asarray(comp, np.float64)[: chunk.n_ops])
        if channels is None:
            raise ValueError("empty trace: no ops to simulate")
        return float(end), np.asarray(carry[1], np.float64), comps, channels

    def end_time(self, sim, trace, *, batched, segment_len):
        end, _, _, _ = self._fold(
            sim, _trace.iter_trace_chunks(trace, segment_len or 64),
            batched=batched)
        return end

    def energy_sums(self, sim, trace, kind, *, batched, segment_len):
        end, sums, _, _ = self._fold(
            sim, _trace.iter_trace_chunks(trace, segment_len or 64),
            batched=batched, kind=kind)
        return end, sums

    def completions(self, sim, trace, *, batched, segment_len=None):
        end, _, comps, _ = self._fold(
            sim, _trace.iter_trace_chunks(trace, segment_len or 64),
            batched=batched, want_comp=True)
        return end, np.concatenate(comps)

    def canonical_folds(self, sim):
        t = _canonical_trace()
        e_tab = jnp.zeros((sim.table.n_classes, 2, 1), jnp.float32)
        fn = functools.partial(_sim.trace_chunk_fold, *sim._targs,
                               n_channels=t.channels, batched=False)
        args = ((e_tab,) + _padded_trace_args(t, 64)
                + _carry_args(_sim.trace_chunk_init(t.channels, 1)))
        return {"chunk_fold": (fn, args)}


def _carry_args(carry):
    """Flatten the ``trace_chunk_fold`` carry back into its positional
    argument order ``(bus, chip, ctrl, round_start, energy_acc)``."""
    (bus_free, chip_free, ctrl_free, round_start), acc = carry
    return bus_free, chip_free, ctrl_free, round_start, acc


@register_engine("oracle", heterogeneous=True, batched_tables=False,
                 energy=True, jittable=False, arrivals=True, ftl=True)
class OracleEngine(_EngineBase):
    """The plain-Python event loop (``repro.core.sim_ref``) — the test
    oracle, now first-class behind the same request surface."""

    def end_time(self, sim, trace, *, batched, segment_len):
        from repro.core.sim_ref import simulate_trace_ref
        return float(simulate_trace_ref(sim.table, trace,
                                        _policy_name(batched)))

    def completions(self, sim, trace, *, batched, segment_len=None):
        from repro.core.sim_ref import simulate_trace_completions_ref
        end, comp = simulate_trace_completions_ref(
            sim.table, trace, _policy_name(batched))
        return float(end), comp

    def energy_sums(self, sim, trace, kind, *, batched, segment_len):
        from repro.core.sim_ref import simulate_trace_energy_ref
        end, sums = simulate_trace_energy_ref(
            sim.table, trace, kind, _policy_name(batched))
        return float(end), np.asarray(sums, np.float64)

    def canonical_folds(self, sim):
        # plain-Python event loop: nothing to trace — the AST layer
        # lints repro.core.sim_ref instead (DESIGN.md §2.9)
        return None


def _op_scalars(op: PageOpParams):
    return tuple(jnp.asarray(x, jnp.float32)
                 for x in (op.cmd_us, op.pre_us, op.slot_us, op.post_lo_us,
                           op.post_hi_us, op.ctrl_us))


# ---------------------------------------------------------------------------
# Request / response types
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """One simulation query.  Validation happens here, once: the policy
    literals (issue *and* scheduler), the objective and the engine name
    are all checked at request construction, so no entry point can
    silently fall through on a typo.

    Exactly one of ``trace`` (placed ops) or ``workload`` (a
    placement-free ``RequestStream``) must be given.  A workload query
    also accepts ``sched_policy``: static policies lower offline to a
    trace any engine can evaluate; dynamic policies need an engine with
    the ``dispatch`` capability (enforced by the registry) and produce
    per-request latency percentiles on the result.

    ``faults`` attaches a :class:`repro.core.faults.FaultSpec`
    (DESIGN.md §2.8): read-retry/jitter surcharges and program-fault
    remap ops are sampled once, host-side, and rewritten into the
    placed trace before the engine fold, so every engine answers the
    same faulty trace bit-deterministically given ``(query, spec)``.
    On workload queries a spec with ``hedge_fraction > 0`` also hedges
    the stream (``workload.with_hedges``) before lowering; a bare-trace
    query has no requests to hedge, so only the per-op fault channel
    applies.

    ``ftl`` attaches a :class:`repro.core.ftl.FTLSpec` (DESIGN.md
    §2.10): the workload's logical addresses run through the L2P map
    first, GC relocation and erase ops are injected into the stream,
    and the translated stream lowers through the same scheduler and
    engines as everything else — the result additionally reports
    ``waf`` / ``gc_op_count`` / ``free_page_low_watermark`` /
    ``fresh_mb_s``.  FTL queries need the ``ftl`` capability (the
    translated stream uses the extended 7-class op table)."""

    trace: OpTrace | None = None
    policy: Policy | None = None        # None -> the session's default
    objective: Objective = "end_time"
    engine: str | None = None           # None -> "scan"
    segment_len: int | None = 64        # prefix-engine chunk size
    workload: RequestStream | None = None
    sched_policy: str | None = None     # None -> "stripe" (workload only)
    faults: FaultSpec | None = None     # None -> fault-free
    ftl: "_ftl.FTLSpec | None" = None   # None -> address-free (no FTL)

    def __post_init__(self):
        if (self.trace is None) == (self.workload is None):
            raise ValueError("SimRequest needs exactly one of trace= or "
                             "workload=")
        if self.ftl is not None:
            if self.workload is None:
                raise ValueError(
                    "ftl= applies to workload requests (a placed trace "
                    "has no logical addresses left to translate)")
            if not isinstance(self.ftl, _ftl.FTLSpec):
                raise ValueError(
                    f"ftl= takes an FTLSpec, got {type(self.ftl).__name__}")
        if self.sched_policy is not None:
            if self.workload is None:
                raise ValueError("sched_policy applies to workload "
                                 "requests (the trace is already placed)")
            _sched.policy_is_dynamic(self.sched_policy)   # validates
        if self.faults is not None and not isinstance(self.faults,
                                                      FaultSpec):
            raise ValueError(
                f"faults= takes a FaultSpec, got {type(self.faults).__name__}")
        if (self.faults is not None and self.trace is not None
                and self.trace.extra_us is not None):
            raise ValueError(
                "trace already carries extra_us — faults were already "
                "applied (attach the FaultSpec OR pre-apply, not both)")
        if self.policy is not None:
            policy_is_batched(self.policy)
        if self.objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {self.objective!r} "
                             f"(one of {', '.join(OBJECTIVES)})")
        if self.engine is not None:
            get_engine(self.engine)


@dataclasses.dataclass(frozen=True, eq=False)
class SimResult:
    """One simulation answer — the same shape for every engine and
    objective.  ``energy`` is populated for objective "energy"/"all";
    ``mb_s`` is user-payload bandwidth (None for payload-free traces,
    e.g. all-hedged duplicates).  Workload queries additionally carry
    per-request latencies (when the serving engine emits per-op
    completions — scan / oracle / every dynamic dispatch; the log-depth
    engines answer makespan-only and leave it None).  Fault-injected
    queries additionally carry the sampled ``retry_hist`` (retry-count
    histogram over read ops) and ``n_remap_ops`` (program-fault remap
    writes inserted by the rewrite pass).

    Percentile properties are guarded: a pN on fewer than
    ``100 / (100 - N)`` requests (e.g. p99 on < 100, p99.9 on < 1000)
    is below the percentile resolution — it clamps to the max observed
    latency and emits a ``RuntimeWarning`` instead of silently
    interpolating a tail that was never sampled; an empty latency
    stream answers NaN."""

    end_us: float
    mb_s: float | None
    channel_busy_us: np.ndarray          # [channels] bus occupancy (us)
    energy: EnergyBreakdown | None
    engine: str
    n_ops: int
    payload_bytes: int
    request_lat_us: np.ndarray | None = None   # [R] per-request latency
    sched_policy: str | None = None            # workload queries only
    retry_hist: np.ndarray | None = None       # [max_retries+1] counts
    n_remap_ops: int = 0                       # program-fault remap writes
    # FTL queries only (DESIGN.md §2.10): write amplification, injected
    # GC traffic, the free-pool low watermark, and the fresh-drive
    # bandwidth of the same host stream (mb_s is the aged/steady-state
    # number once GC competes for the bus)
    waf: float | None = None                   # pages written / host pages
    gc_op_count: int | None = None             # GC reads + writes + erases
    free_page_low_watermark: int | None = None
    fresh_mb_s: float | None = None            # host-only (GC-free) MB/s
    ftl_stats: "_ftl.FTLStats | None" = None   # full FTL counter block

    @property
    def channel_occupancy(self) -> np.ndarray:
        """Per-channel bus busy fraction of the makespan."""
        return self.channel_busy_us / max(self.end_us, 1e-30)

    def _latency_percentile(self, q: float) -> float | None:
        """Guarded percentile (see class docstring): clamps to the max
        latency (with a RuntimeWarning) when the stream is too short to
        resolve the requested tail; NaN on an empty stream."""
        if self.request_lat_us is None:
            return None
        lat = np.asarray(self.request_lat_us, np.float64)
        if lat.size == 0:
            return float("nan")
        # resolving pN needs >= 100/(100-N) samples: below that the
        # order statistic for the tail does not exist yet
        if lat.size * (100.0 - q) < 100.0:
            warnings.warn(
                f"p{q:g} on {lat.size} request(s) is below the percentile "
                "resolution — clamping to the max observed latency",
                RuntimeWarning, stacklevel=3)
            return float(np.max(lat))
        return float(np.percentile(lat, q))

    @property
    def p50_us(self) -> float | None:
        """Median request latency (workload queries with completions)."""
        return self._latency_percentile(50)

    @property
    def p99_us(self) -> float | None:
        """99th-percentile request latency."""
        return self._latency_percentile(99)

    @property
    def p99_9_us(self) -> float | None:
        """99.9th-percentile request latency — the retry-storm tail the
        reliability layer exists to measure (DESIGN.md §2.8)."""
        return self._latency_percentile(99.9)

    def describe(self) -> str:
        occ = "/".join(f"{x:.2f}" for x in self.channel_occupancy)
        bw = f"{self.mb_s:.1f} MB/s" if self.mb_s is not None else "no payload"
        lat = ("" if self.request_lat_us is None else
               f", p50/p99 {self.p50_us:.0f}/{self.p99_us:.0f} us")
        ftl = ("" if self.waf is None else
               f", WAF {self.waf:.2f} ({self.gc_op_count} GC ops)")
        return (f"[{self.engine}] {self.n_ops} ops in "
                f"{self.end_us / 1e3:.2f} ms, {bw}, occ {occ}{lat}{ftl}")


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    hits: int
    misses: int
    entries: int
    evictions: int = 0
    max_entries: int | None = None


# ---------------------------------------------------------------------------
# The session
# ---------------------------------------------------------------------------


class Simulator:
    """A simulation session bound to one design point.

    Binds an ``SSDConfig`` (or a raw ``OpClassTable``) once: the timing
    table is converted to device arrays at construction, and every
    jittable engine's closures are cached on
    ``(engine, geometry, trace-length bucket, policy, ...)`` so repeated
    queries are compile- and conversion-free.  All five registered
    engines answer through :meth:`run`; :meth:`run_many` is the batched
    serving path (length-bucketed, vmapped); :meth:`sweep` fans one
    trace out over a batch of design-point tables.
    """

    def __init__(self, config: SSDConfig | None = None, *,
                 table: OpClassTable | None = None,
                 kind: InterfaceKind | str | None = None,
                 max_cache_entries: int | None = 512,
                 max_ftl_sessions: int | None = 8):
        if config is None and table is None:
            raise ValueError("Simulator needs an SSDConfig or an "
                             "OpClassTable")
        if max_cache_entries is not None and max_cache_entries < 1:
            raise ValueError("max_cache_entries must be >= 1 or None "
                             f"(unbounded), got {max_cache_entries}")
        if max_ftl_sessions is not None and max_ftl_sessions < 1:
            raise ValueError("max_ftl_sessions must be >= 1 or None "
                             f"(unbounded), got {max_ftl_sessions}")
        self.config = config
        self.table = table if table is not None else op_class_table(config)
        if kind is not None:
            self.kind: InterfaceKind | None = InterfaceKind(kind)
        else:
            self.kind = config.interface if config is not None else None
        self.default_policy: Policy = (config.policy if config is not None
                                       else "eager")
        self._targs = tuple(jnp.asarray(getattr(self.table, f))
                            for f in _TABLE_FIELDS)
        self._e_tables: dict[InterfaceKind, jax.Array] = {}
        self._e_tables_np: dict[InterfaceKind, np.ndarray] = {}
        self.max_cache_entries = max_cache_entries
        self.max_ftl_sessions = max_ftl_sessions
        self._ftl_sessions: collections.OrderedDict[tuple, "Simulator"] \
            = collections.OrderedDict()
        self._ftl_hits = 0
        self._ftl_misses = 0
        self._ftl_evictions = 0
        self._closures: collections.OrderedDict[tuple, object] = \
            collections.OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        # fused aged-sweep memos (DESIGN.md §2.11): preconditioned scan
        # states per spec batch (a pure function of the specs — the
        # host translator re-ages per call by design, the compiled
        # sweep ages once) and learned (t_max, t2) buffer sizes per
        # (specs, stream) so warm sweeps run exactly-sized folds with
        # no grow-and-retry replay.
        self._ftl_pre_states: collections.OrderedDict[tuple, object] = \
            collections.OrderedDict()
        self._ftl_sweep_sizes: collections.OrderedDict[
            tuple, tuple[int, int]] = collections.OrderedDict()

    # -- shared per-config sessions ----------------------------------------

    @classmethod
    def for_config(cls, config: SSDConfig) -> "Simulator":
        """Process-wide memoised session for a design point — the
        storage tier, planners and benchmarks all share closures."""
        return simulator_for(config)

    # -- closure cache ------------------------------------------------------

    def _closure(self, key: tuple, build):
        """LRU-bounded jit-closure cache: hits refresh recency, misses
        build and (past ``max_cache_entries``) evict the least recently
        used closure — a long-lived session sweeping many geometries and
        length buckets holds a bounded working set instead of growing
        without limit."""
        fn = self._closures.get(key)
        if fn is None:
            self._misses += 1
            fn = self._closures[key] = build()
            if (self.max_cache_entries is not None
                    and len(self._closures) > self.max_cache_entries):
                self._closures.popitem(last=False)
                self._evictions += 1
        else:
            self._hits += 1
            self._closures.move_to_end(key)
        return fn

    def cache_info(self) -> CacheInfo:
        return CacheInfo(self._hits, self._misses, len(self._closures),
                         self._evictions, self.max_cache_entries)

    def cache_clear(self) -> None:
        self._closures.clear()
        self._hits = self._misses = self._evictions = 0

    def _energy_table(self, kind: InterfaceKind) -> jax.Array:
        e = self._e_tables.get(kind)
        if e is None:
            e = self._e_tables[kind] = jnp.asarray(
                op_phase_energy_uj(self.table, kind))
        return e

    def _linear_energy_sums(self, trace: OpTrace,
                            kind: InterfaceKind) -> np.ndarray:
        """[P] phase sums (uJ) by direct per-op summation — energy is
        (+,+)-linear, so this is the engine-free evaluation the packed
        serving path uses.  The float64 phase table is memoised per
        interface kind like its device-array twin."""
        e = self._e_tables_np.get(kind)
        if e is None:
            e = self._e_tables_np[kind] = np.asarray(
                op_phase_energy_uj(self.table, kind), np.float64)
        return e[np.asarray(trace.cls),
                 np.asarray(trace.parity) % 2].sum(axis=0)

    # -- queries ------------------------------------------------------------

    def _resolve(self, request: SimRequest, trace: OpTrace | None = None):
        policy = request.policy or self.default_policy
        batched = policy_is_batched(policy)
        eng = get_engine(request.engine or "scan")
        if request.objective in ("energy", "all"):
            if not eng.caps.energy:
                raise CapabilityError(
                    f"engine {eng.caps.name!r} does not accumulate energy")
            if self.kind is None:
                raise ValueError(
                    "energy query on a Simulator with no interface kind "
                    "(pass kind= or bind an SSDConfig)")
        if (trace is not None and trace.arrival_us is not None
                and np.any(trace.arrival_us > 0) and not eng.caps.arrivals):
            okay = ", ".join(n for n in registered_engines()
                             if _REGISTRY[n].caps.arrivals)
            raise CapabilityError(
                f"engine {eng.caps.name!r} cannot consume arrival-aware "
                f"traces (engines that can: {okay})")
        # faults ride the same per-op side-channel machinery as arrivals,
        # so the capability row is shared
        if ((request.faults is not None and not request.faults.is_zero
             or trace is not None and trace.extra_us is not None
             and np.any(trace.extra_us > 0)) and not eng.caps.arrivals):
            okay = ", ".join(n for n in registered_engines()
                             if _REGISTRY[n].caps.arrivals)
            raise CapabilityError(
                f"engine {eng.caps.name!r} cannot consume fault-extended "
                f"traces (engines that can: {okay})")
        if request.ftl is not None and not eng.caps.ftl:
            okay = ", ".join(n for n in registered_engines()
                             if _REGISTRY[n].caps.ftl)
            raise CapabilityError(
                f"engine {eng.caps.name!r} cannot consume FTL-translated "
                f"streams (engines that can: {okay})")
        return eng, batched

    def _result(self, trace: OpTrace, end_us: float, engine: str,
                energy: EnergyBreakdown | None,
                request_lat_us: np.ndarray | None = None,
                sched_policy: str | None = None,
                sampler: FaultSampler | None = None) -> SimResult:
        table = self.table
        payload = trace.total_bytes(table)
        busy = np.bincount(
            np.asarray(trace.channel),
            weights=np.asarray(table.slot_us, np.float64)[
                np.asarray(trace.cls)],
            minlength=trace.channels)
        return SimResult(
            end_us=end_us,
            mb_s=(payload / end_us) if payload > 0 else None,
            channel_busy_us=busy, energy=energy, engine=engine,
            n_ops=trace.n_ops, payload_bytes=payload,
            request_lat_us=request_lat_us, sched_policy=sched_policy,
            retry_hist=(None if sampler is None
                        else sampler.retry_hist.copy()),
            n_remap_ops=0 if sampler is None else sampler.n_remap_ops)

    def _breakdown(self, sums, end_us: float, trace: OpTrace):
        return breakdown_from_sums(
            sums, end_us=end_us,
            payload_bytes=trace.total_bytes(self.table),
            kind=self.kind, channels=trace.channels)

    def run(self, request: SimRequest | OpTrace | RequestStream, /,
            **overrides) -> SimResult:
        """Answer one query.  Accepts a :class:`SimRequest`, a bare
        ``OpTrace``, or a bare ``RequestStream`` (a workload query under
        ``sched_policy``, default static stripe) plus request fields as
        keywords."""
        if isinstance(request, RequestStream):
            request = SimRequest(workload=request, **overrides)
        elif not isinstance(request, SimRequest):
            request = SimRequest(trace=request, **overrides)
        elif overrides:
            request = dataclasses.replace(request, **overrides)
        if request.workload is not None:
            return self._run_workload(request)
        trace = request.trace
        if trace.n_ops == 0:
            raise ValueError("empty trace: no ops to simulate")
        trace.validate_against(self.table)
        eng, batched = self._resolve(request, trace)
        sampler = None
        if request.faults is not None:
            trace, _, sampler = _sched.apply_faults(
                trace, request.faults, self.table)
        energy = None
        if request.objective in ("energy", "all"):
            end, sums = eng.energy_sums(
                self, trace, self.kind, batched=batched,
                segment_len=request.segment_len)
            energy = self._breakdown(sums, end, trace)
            end_us = end
        else:
            end_us = eng.end_time(self, trace, batched=batched,
                                  segment_len=request.segment_len)
        return self._result(trace, end_us, eng.caps.name, energy,
                            sampler=sampler)

    def _ftl_session(self, spec: "_ftl.FTLSpec") -> "Simulator":
        """Memoised sibling session over the 7-class FTL op table
        (DESIGN.md §2.10) — keyed on the fields that shape the table, so
        GC-policy / overprovisioning sweeps at fixed timing share one
        session's jitted closures."""
        key = (float(spec.map_us),
               None if spec.erase_us is None else float(spec.erase_us))
        sess = self._ftl_sessions.get(key)
        if sess is None:
            self._ftl_misses += 1
            sess = self._ftl_sessions[key] = Simulator(
                self.config,
                table=_ftl.ftl_op_class_table(self.config, spec),
                max_cache_entries=self.max_cache_entries)
            if (self.max_ftl_sessions is not None
                    and len(self._ftl_sessions) > self.max_ftl_sessions):
                self._ftl_sessions.popitem(last=False)
                self._ftl_evictions += 1
        else:
            self._ftl_hits += 1
            self._ftl_sessions.move_to_end(key)
        return sess

    def ftl_cache_info(self) -> CacheInfo:
        """Counters for the FTL sub-session cache — same shape as
        :meth:`cache_info`, but one entry here is a whole sibling
        ``Simulator`` (its own 7-class table device arrays and closure
        cache), so the bound is deliberately small."""
        return CacheInfo(self._ftl_hits, self._ftl_misses,
                         len(self._ftl_sessions), self._ftl_evictions,
                         self.max_ftl_sessions)

    def _run_workload_ftl(self, request: SimRequest) -> SimResult:
        """FTL workload queries (DESIGN.md §2.10): the host stream runs
        through the L2P translation stage first — GC relocation and
        erase ops are injected on free-pool pressure and every op gets
        an FTL op class carrying the firmware map cost — then the
        translated stream lowers through the same scheduler / engine
        machinery as any other workload (all ops, GC included, compete
        for placement slots and bus time).  A second host-only pass over
        the same translation prices the fresh-drive bandwidth, so the
        aged-vs-fresh cliff is part of the one answer.

        Block-level program/erase failures are *owned by the FTL
        accounting* (bad blocks retire through the same valid-count
        bookkeeping GC uses); the fault sampler here only prices the
        per-op retry/jitter surcharges, against a read/write view of the
        translated classes."""
        spec = request.ftl
        stream = request.workload
        fspec = request.faults
        if fspec is not None and fspec.hedge_fraction > 0.0:
            stream = _workload.with_hedges(
                stream, fspec.hedge_fraction,
                after_us=fspec.hedge_after_us or 0.0, seed=fspec.seed)
        sess = self._ftl_session(spec)
        eng, batched = sess._resolve(request)
        policy_s = request.sched_policy or "stripe"
        dynamic = _sched.policy_is_dynamic(policy_s)
        if dynamic and batched:
            raise ValueError(
                "dynamic dispatch is FCFS under the eager issue "
                "policy; 'batched' rounds are fixed at build time "
                "and only exist for static lowerings")
        channels, ways = self.config.channels, self.config.ways
        if fspec is None or (fspec.prog_fail_prob == 0.0
                             and fspec.erase_fail_prob == 0.0):
            # default path: the compiled lax.scan translation engine
            # (DESIGN.md §2.11) — exact-agreement twin of the host
            # translator, regression-pinned op-for-op in the tests
            translation = _ftl_scan.translate_scan(stream, spec)
        else:
            # block-level program/erase failures draw RNG per attempt —
            # host-oracle territory (the scan folds stay RNG-free)
            translation = _ftl.translate(
                stream, spec,
                prog_fail_prob=fspec.prog_fail_prob,
                erase_fail_prob=fspec.erase_fail_prob,
                fault_seed=fspec.seed)
        extra = None
        sampler = None
        if fspec is not None:
            # block-level failures were consumed by translate() above;
            # the per-op channel prices retries/jitter on a host-class
            # view of the translated stream (GC reads retry like reads)
            neutered = dataclasses.replace(
                fspec, prog_fail_prob=0.0, erase_fail_prob=0.0)
            if not neutered.is_zero:
                sampler = FaultSampler(neutered, channels, ways, sess.table)
                cls_view = np.where(
                    np.isin(translation.op_cls,
                            (_ftl.FTL_READ, _ftl.GC_READ)),
                    _trace.READ, _trace.WRITE).astype(np.int32)
                extra, _, _ = sampler.sample(cls_view)

        def evaluate(mask=None, want_comp=False):
            cls = translation.op_cls
            arr = translation.arrival_us
            pay = translation.payload
            ext = extra
            if mask is not None:
                cls, arr, pay = cls[mask], arr[mask], pay[mask]
                ext = None if ext is None else ext[mask]
            if dynamic:
                end, comp, chan, way, par = eng.dispatch_run(
                    sess, cls, arr, n_channels=channels, n_ways=ways,
                    rule=policy_s, extra_us=ext, retired=None)
                tr = OpTrace(
                    cls=np.asarray(cls, np.int32), channel=chan, way=way,
                    parity=par, channels=channels, ways=ways,
                    payload=None if pay.all() else pay,
                    arrival_us=np.asarray(arr, np.float32),
                    extra_us=(None if ext is None
                              else np.asarray(ext, np.float32)))
                return tr, end, comp
            tr = _sched.lower_ops(cls, arr, channels, ways, policy_s,
                                  payload=pay)
            if ext is not None:
                tr = dataclasses.replace(
                    tr, extra_us=np.asarray(ext, np.float32))
            tr.validate_against(sess.table)
            base = getattr(_EngineBase, "completions")
            if want_comp and getattr(type(eng), "completions",
                                     base) is not base:
                end, comp = eng.completions(
                    sess, tr, batched=batched,
                    segment_len=request.segment_len)
                return tr, end, comp
            end = eng.end_time(sess, tr, batched=batched,
                               segment_len=request.segment_len)
            return tr, end, None

        trace, end_us, comp = evaluate(want_comp=True)
        lat = None
        if comp is not None:
            # GC ops belong to no request (request_id -1): latency
            # accounting sees host ops only — but over the *aged*
            # completion times, so GC queueing is in the tail
            host = translation.request_id >= 0
            lowered = LoweredWorkload(
                trace=trace, request_id=translation.request_id[host],
                request_arrival_us=np.asarray(stream.arrival_us,
                                              np.float32))
            lat = _payload_latencies(lowered, np.asarray(comp)[host],
                                     stream)
        energy = None
        if request.objective in ("energy", "all"):
            # energy is (+,+)-linear, so the engine-free per-op sum is
            # exact for the translated trace too (DESIGN.md §2.4)
            energy = sess._breakdown(
                sess._linear_energy_sums(trace, sess.kind), end_us, trace)
        fresh_mb_s = None
        if bool(translation.gc.any()):
            # fresh-drive reference: the host ops alone (map cost still
            # charged — FTL classes are kept), no GC competition
            _, fresh_end, _ = evaluate(mask=~translation.gc)
            fresh_payload = trace.total_bytes(sess.table)
            if fresh_payload > 0:
                fresh_mb_s = fresh_payload / fresh_end
        stats = translation.stats
        res = sess._result(trace, end_us, eng.caps.name, energy,
                           request_lat_us=lat, sched_policy=policy_s,
                           sampler=sampler)
        return dataclasses.replace(
            res, waf=stats.waf, gc_op_count=stats.gc_op_count,
            free_page_low_watermark=stats.free_page_low_watermark,
            fresh_mb_s=fresh_mb_s, ftl_stats=stats)

    def _run_workload(self, request: SimRequest) -> SimResult:
        """Workload queries: lower the request stream through the
        scheduler (static policies offline, dynamic policies as the
        joint dispatch fold) and attach per-request latencies when the
        engine emits per-op completions (DESIGN.md §2.6)."""
        if self.config is None:
            raise ValueError(
                "workload queries need a Simulator bound to an SSDConfig "
                "(the scheduler needs the channel/way geometry)")
        stream = request.workload
        if stream.n_requests == 0:
            raise ValueError("empty workload: no requests to simulate")
        if request.ftl is not None:
            return self._run_workload_ftl(request)
        if int(np.max(stream.op_cls)) >= self.table.n_classes:
            # checked before the dispatch fold runs: a clamped-garbage
            # simulation followed by a numpy IndexError is not a report
            raise ValueError(
                f"RequestStream.op_cls out of range: max "
                f"{int(np.max(stream.op_cls))} >= table.n_classes "
                f"{self.table.n_classes}")
        spec = request.faults
        if spec is not None and spec.hedge_fraction > 0.0:
            # the spec's mitigation half: hedge payload reads before the
            # scheduler sees the stream, so duplicates flow through the
            # same lowering/dispatch as everything else
            stream = _workload.with_hedges(
                stream, spec.hedge_fraction,
                after_us=spec.hedge_after_us or 0.0, seed=spec.seed)
        policy_s = request.sched_policy or "stripe"
        eng, batched = self._resolve(request)
        channels, ways = self.config.channels, self.config.ways
        if _sched.policy_is_dynamic(policy_s):
            # registry-enforced: engines without the dispatch capability
            # raise CapabilityError naming the ones that have it
            if batched:
                raise ValueError(
                    "dynamic dispatch is FCFS under the eager issue "
                    "policy; 'batched' rounds are fixed at build time "
                    "and only exist for static lowerings")
            cls, arrival, req_id, payload = request_ops(stream)
            extra = retired = sampler = None
            if spec is not None:
                # dynamic faults sample on the op-class sequence alone
                # (placement is decided in-fold): retry/jitter surcharges
                # ride extra_us, a program fault inserts its remap write
                # right after the failed op, and retired blocks become a
                # dispatch constraint via the retired mask
                sampler = FaultSampler(spec, channels, ways, self.table)
                extra, write_fail, _ = sampler.sample(cls)
                fail = np.flatnonzero(write_fail)
                if len(fail):
                    ins = fail + 1
                    n = len(cls)
                    new_of_old = np.arange(n) + np.searchsorted(
                        ins, np.arange(n), "right")
                    cls = np.insert(cls, ins, cls[fail])
                    arrival = np.insert(arrival, ins, arrival[fail])
                    req_id = np.insert(req_id, ins, req_id[fail])
                    extra = np.insert(extra, ins, 0.0).astype(np.float32)
                    pay2 = np.insert(payload, ins, payload[fail])
                    # the failed original keeps its bus/cell cost but the
                    # byte credit moves to the remap — totals conserved
                    pay2[new_of_old[fail]] = False
                    payload = pay2
                    sampler.n_remap_ops += len(fail)
                if sampler.retired.any():
                    retired = sampler.retired
            end, comp, chan, way, par = eng.dispatch_run(
                self, cls, arrival, n_channels=channels, n_ways=ways,
                rule=policy_s, extra_us=extra, retired=retired)
            trace = OpTrace(
                cls=np.asarray(cls, np.int32), channel=chan, way=way,
                parity=par, channels=channels, ways=ways,
                payload=None if payload.all() else payload,
                arrival_us=arrival,
                extra_us=(None if extra is None
                          else np.asarray(extra, np.float32)))
            lowered = LoweredWorkload(
                trace=trace, request_id=req_id,
                request_arrival_us=np.asarray(stream.arrival_us,
                                              np.float32))
            lat = _payload_latencies(lowered, comp, stream)
            energy = None
            if request.objective in ("energy", "all"):
                # energy is (+,+)-linear: the dispatched placement fixes
                # the parity sequence, so the engine-free per-op sum is
                # exact (DESIGN.md §2.4)
                energy = self._breakdown(
                    self._linear_energy_sums(trace, self.kind), end, trace)
            return self._result(trace, end, eng.caps.name, energy,
                                request_lat_us=lat, sched_policy=policy_s,
                                sampler=sampler)
        lowered = _sched.lower_static(stream, channels, ways, policy_s)
        trace = lowered.trace
        sampler = None
        if spec is not None:
            trace, rid2, sampler = _sched.apply_faults(
                trace, spec, self.table, request_id=lowered.request_id)
            lowered = LoweredWorkload(
                trace=trace, request_id=rid2,
                request_arrival_us=lowered.request_arrival_us)
        trace.validate_against(self.table)
        energy = None
        lat = None
        base = getattr(_EngineBase, "completions")
        if getattr(type(eng), "completions", base) is not base:
            end_us, comp = eng.completions(self, trace, batched=batched,
                                           segment_len=request.segment_len)
            lat = _payload_latencies(lowered, comp, stream)
        else:   # makespan-only engines (log-depth forms)
            end_us = eng.end_time(self, trace, batched=batched,
                                  segment_len=request.segment_len)
        if request.objective in ("energy", "all"):
            end_e, sums = eng.energy_sums(
                self, trace, self.kind, batched=batched,
                segment_len=request.segment_len)
            energy = self._breakdown(sums, end_e, trace)
        return self._result(trace, end_us, eng.caps.name, energy,
                            request_lat_us=lat, sched_policy=policy_s,
                            sampler=sampler)

    def run_many(self, traces, *, policy: Policy | None = None,
                 objective: Objective = "end_time",
                 engine: str | None = None,
                 segment_len: int | None = 64,
                 shard: bool | None = None) -> list[SimResult]:
        """The batched serving path: pack heterogeneous traces into
        power-of-two length buckets per (channels, bucket) group and
        evaluate each group in one vmapped masked fold — results are
        identical to per-trace :meth:`run` (masked padding is a state
        no-op).  The bucket grid is derived from the traces actually
        present: empty power-of-two buckets are never compiled, and each
        group's *batch* dimension also rounds up to a power of two (with
        all-invalid padding rows) so batch-size jitter between calls
        reuses the compiled fold instead of recompiling per group size.

        ``engine="pallas"`` evaluates each (channels, ways) group as ONE
        fused megakernel launch over the union combo dictionary and all
        length buckets (``repro.kernels.maxplus.ops.
        run_many_end_time_maxplus``); other engines fall back to a
        per-trace loop through the same session cache.  With more than
        one device present (``shard=None`` auto / ``shard=True``), the
        scan groups additionally shard their batch rows across devices
        with ``jax.shard_map``; ``shard=False`` forces the single-device
        vmap path."""
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r} "
                             f"(one of {', '.join(OBJECTIVES)})")
        policy = policy or self.default_policy
        batched = policy_is_batched(policy)
        name = engine or "scan"
        get_engine(name)            # raises on unknown engine names
        traces = list(traces)
        for t in traces:
            if t.n_ops == 0:
                raise ValueError("empty trace: no ops to simulate")
            t.validate_against(self.table)
        if name not in ("scan", "pallas"):
            return [self.run(SimRequest(trace=t, policy=policy,
                                        objective=objective, engine=name,
                                        segment_len=segment_len))
                    for t in traces]
        if objective in ("energy", "all") and self.kind is None:
            raise ValueError(
                "energy query on a Simulator with no interface kind "
                "(pass kind= or bind an SSDConfig)")
        ends = np.empty(len(traces), np.float64)
        if name == "pallas":
            from repro.kernels.maxplus.ops import run_many_end_time_maxplus
            pgroups: dict[tuple[int, int], list[int]] = {}
            for i, t in enumerate(traces):
                pgroups.setdefault((t.channels, t.ways), []).append(i)
            for _, idxs in pgroups.items():
                ends[idxs] = run_many_end_time_maxplus(
                    self.table, [traces[i] for i in idxs],
                    policy=_policy_name(batched))
            return self._many_results(traces, ends, name, objective)
        mesh = _points_mesh() if shard is not False else None
        groups: dict[tuple[int, int], list[int]] = {}
        for i, t in enumerate(traces):
            groups.setdefault((t.channels, _bucket_len(t.n_ops)),
                              []).append(i)
        for (channels, t_b), idxs in groups.items():
            b_pad = _bucket_len(len(idxs), floor=1)
            if mesh is not None:        # whole rows per device shard
                n_dev = int(mesh.devices.size)
                b_pad = max(b_pad, -(-b_pad // n_dev) * n_dev)
            rows = [_pad_trace_np(traces[i], t_b) for i in idxs]
            pad_row = tuple(np.zeros_like(col) for col in rows[0])
            rows += [pad_row] * (b_pad - len(idxs))
            stacked = [np.stack(cols) for cols in zip(*rows)]
            if mesh is None:
                fn = self._closure(
                    ("scan-many", channels, t_b, batched, b_pad),
                    lambda channels=channels: functools.partial(
                        _sim.trace_end_time_masked_many, *self._targs,
                        n_channels=channels, batched=batched))
            else:
                fn = self._closure(
                    ("scan-many-shard", channels, t_b, batched, b_pad,
                     mesh.devices.size),
                    lambda channels=channels: _shard_points(
                        mesh, functools.partial(
                            _sim.trace_end_time_masked_many, *self._targs,
                            n_channels=channels, batched=batched),
                        n_sharded=7))
            ends[idxs] = np.asarray(
                fn(*(jnp.asarray(s) for s in stacked)))[: len(idxs)]
        return self._many_results(traces, ends, name, objective)

    def _many_results(self, traces, ends, name: str,
                      objective: Objective) -> list[SimResult]:
        """Assemble per-trace results for the packed serving paths:
        energy is (+,+)-linear, so the engine-free per-op sum is exact
        for every serving engine (DESIGN.md §2.4)."""
        results = []
        for t, end in zip(traces, ends):
            energy = None
            if objective in ("energy", "all"):
                energy = breakdown_from_sums(
                    self._linear_energy_sums(t, self.kind),
                    end_us=float(end),
                    payload_bytes=t.total_bytes(self.table),
                    kind=self.kind, channels=t.channels)
            results.append(self._result(t, float(end), name, energy))
        return results

    def run_stream(self, chunks, *, policy: Policy | None = None,
                   objective: Objective = "end_time", ftl=None,
                   faults: FaultSpec | None = None,
                   sched_policy: str = "stripe") -> SimResult:
        """Constant-memory streaming query (DESIGN.md §2.7): fold an
        *iterator of OpTrace chunks* (``trace.iter_trace_chunks``, a
        generator builder like ``trace.mixed_trace_chunks``, or any
        iterable) through the streaming engine without ever holding the
        full trace — payload bytes, per-channel occupancy and the op
        count accumulate chunk-by-chunk, so a million-op trace costs
        O(chunk) memory end to end.

        With ``ftl=`` (an :class:`FTLSpec`), ``chunks`` is instead an
        iterator of host :class:`RequestStream` chunks: each chunk runs
        the scan translation engine carrying the drive state
        (DESIGN.md §2.11), lowers at the carried placement-slot offset
        (``sched.lower_ops_chunk``) and feeds the same streaming fold —
        so a million-request aging trace is translated, placed and
        simulated without ever materialising the aged op stream, and
        the result (stats included) is bit-identical to the one-shot
        path.  ``faults`` prices per-op retry/jitter surcharges with
        one sequential sampler across chunks (§2.8); hedging and
        block-level program/erase failures are one-shot-only."""
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r} "
                             f"(one of {', '.join(OBJECTIVES)})")
        if ftl is not None:
            return self._run_stream_ftl(
                chunks, ftl, policy=policy, objective=objective,
                faults=faults, sched_policy=sched_policy)
        if faults is not None:
            raise ValueError(
                "run_stream(faults=...) needs ftl= (op-trace chunks are "
                "already placed; apply sched.apply_faults per chunk "
                "instead)")
        policy = policy or self.default_policy
        batched = policy_is_batched(policy)
        kind = None
        if objective in ("energy", "all"):
            if self.kind is None:
                raise ValueError(
                    "energy query on a Simulator with no interface kind "
                    "(pass kind= or bind an SSDConfig)")
            kind = self.kind
        eng = get_engine("streaming")
        stats = {"n_ops": 0, "payload": 0, "busy": None}
        slot = np.asarray(self.table.slot_us, np.float64)

        def tap(cs):
            for c in cs:
                if c.n_ops == 0:
                    continue
                c.validate_against(self.table)
                if stats["busy"] is None:
                    stats["busy"] = np.zeros(c.channels)
                elif len(stats["busy"]) != c.channels:
                    raise ValueError(
                        f"streaming chunks switched geometry mid-stream: "
                        f"{c.channels} channels after {len(stats['busy'])}")
                stats["n_ops"] += c.n_ops
                stats["payload"] += c.total_bytes(self.table)
                stats["busy"] += np.bincount(
                    np.asarray(c.channel),
                    weights=slot[np.asarray(c.cls)],
                    minlength=c.channels)
                yield c

        end, sums, _, channels = eng._fold(self, tap(chunks),
                                           batched=batched, kind=kind)
        energy = None
        if kind is not None:
            energy = breakdown_from_sums(
                sums, end_us=end, payload_bytes=stats["payload"],
                kind=kind, channels=channels)
        payload = stats["payload"]
        return SimResult(
            end_us=end, mb_s=(payload / end) if payload > 0 else None,
            channel_busy_us=stats["busy"], energy=energy,
            engine="streaming", n_ops=stats["n_ops"],
            payload_bytes=payload)

    def _run_stream_ftl(self, chunks, spec, *, policy: Policy | None,
                        objective: Objective, faults: FaultSpec | None,
                        sched_policy: str) -> SimResult:
        """FTL-translating adapter for :meth:`run_stream`: a generator
        turns each host ``RequestStream`` chunk into a placed
        ``OpTrace`` chunk — translation state, placement-slot offset
        and the fault sampler all carry across chunks, so the chunked
        answer equals the one-shot ``run(SimRequest(ftl=...))`` stream
        op-for-op.  The fold itself is delegated to the FTL
        sub-session, whose 7-class table owns chunk validation and
        byte accounting."""
        if self.config is None:
            raise ValueError(
                "workload queries need a Simulator bound to an SSDConfig "
                "(the scheduler needs the channel/way geometry)")
        if _sched.policy_is_dynamic(sched_policy):
            raise ValueError(
                f"sched policy {sched_policy!r} is dynamic — streaming "
                "chunks lower offline at a carried slot offset; dynamic "
                "dispatch needs the one-shot run(SimRequest(ftl=...)) "
                "path")
        if faults is not None and (faults.hedge_fraction > 0.0
                                   or faults.prog_fail_prob > 0.0
                                   or faults.erase_fail_prob > 0.0):
            raise ValueError(
                "run_stream(ftl=...) prices per-op retry/jitter "
                "surcharges only — hedging and block-level program/"
                "erase failures rewrite the whole stream and need the "
                "one-shot run(SimRequest(ftl=...)) path")
        sess = self._ftl_session(spec)
        C, W = self.config.channels, self.config.ways
        carry: dict = {"state": None, "off": 0, "sampler": None,
                       "stats": None}
        if faults is not None and not faults.is_zero:
            carry["sampler"] = FaultSampler(faults, C, W, sess.table)

        def translated():
            for st in chunks:
                if st.n_requests == 0:
                    continue
                tr = _ftl_scan.translate_scan(st, spec,
                                              state=carry["state"])
                carry["state"] = tr.state
                carry["stats"] = tr.stats
                ot, carry["off"] = _sched.lower_ops_chunk(
                    tr.op_cls, tr.arrival_us, C, W, sched_policy,
                    tr.payload, carry["off"])
                if carry["sampler"] is not None:
                    cls_view = np.where(
                        np.isin(tr.op_cls, (_ftl.FTL_READ, _ftl.GC_READ)),
                        _trace.READ, _trace.WRITE).astype(np.int32)
                    extra, _, _ = carry["sampler"].sample(cls_view)
                    ot = dataclasses.replace(
                        ot, extra_us=np.asarray(extra, np.float32))
                yield ot

        try:
            res = sess.run_stream(translated(), policy=policy,
                                  objective=objective)
        except ValueError:
            if carry["stats"] is None:     # no chunk carried a request
                raise ValueError(
                    "empty workload: no requests to translate") from None
            raise
        stats = carry["stats"]
        return dataclasses.replace(
            res, waf=stats.waf, gc_op_count=stats.gc_op_count,
            free_page_low_watermark=stats.free_page_low_watermark,
            ftl_stats=stats)

    def sweep(self, tables, trace, *,
              policy: Policy | None = None, engine: str = "prefix",
              segment_len: int | None = 64, combine: str = "chain",
              shard: bool | None = None, ftl=None,
              sched_policy: str = "stripe") -> np.ndarray:
        """[B] completion times of one trace under a batch of
        design-point tables (``tables=None`` sweeps the bound table
        alone) — the design-space fan-out direction of the serving
        path.  With more than one device the table batch shards across
        devices via ``jax.shard_map`` (``shard=None`` auto / ``True``;
        ``False`` forces the vmap path).

        ``ftl=`` switches to the *aged* design-space direction
        (DESIGN.md §2.11): ``trace`` is then a host
        :class:`RequestStream` and ``ftl`` a sequence of
        :class:`FTLSpec` design points sharing one geometry and timing
        — each point runs the whole translate→lower→simulate chain as
        one fused scan fold (preconditioning included), vmapped across
        points and sharded over devices like every other sweep.
        ``tables`` must be None (the FTL spec owns the 7-class table)
        and ``engine``/``segment_len``/``combine`` are ignored — the
        fused chain is the masked scan fold by construction."""
        if ftl is not None:
            if tables is not None:
                raise ValueError(
                    "sweep(ftl=...) sweeps FTL design points — the "
                    "7-class table comes from the spec; tables must be "
                    "None")
            return self._sweep_ftl(trace, ftl,
                                   policy=policy or self.default_policy,
                                   sched_policy=sched_policy, shard=shard)
        return sweep_tables(
            [self.table] if tables is None else tables, trace,
            policy=policy or self.default_policy, engine=engine,
            segment_len=segment_len, combine=combine, shard=shard)

    def _sweep_ftl(self, stream: RequestStream, specs, *, policy: Policy,
                   sched_policy: str, shard: bool | None) -> np.ndarray:
        """Fused aged sweep: precondition fold → window reset →
        translation fold → compaction → closed-form static lowering →
        masked end-time fold, with the batch of FTL design points
        riding vmap (plus ``shard_map`` with >1 device).  Exactness
        leans on two §2.11 invariants: the scan translator is op-for-op
        the host translator, and the closed-form slot/parity lowering
        is field-for-field ``lower_ops`` — so each lane's end time is
        the same chain the per-point ``run(SimRequest(ftl=...))`` path
        computes.

        Two memos make repeated sweeps cheap where the per-call host
        path cannot be: the *preconditioned state* is a pure function
        of the spec batch, so it folds once and is reused across calls
        (``_ftl_pre_states`` — the host translator re-ages on every
        call by design), and the row/op counts observed on a
        successful sweep are remembered per (specs, stream) so warm
        sweeps run exactly-sized buffers with no grow-and-retry replay
        (``_ftl_sweep_sizes``).  Emission rows compact into the op
        bucket through a searchsorted gather (XLA:CPU pays scatter
        cost per update row while gathers vectorise), so the masked
        end-time fold runs over ``t2 ≈ n_ops`` lanes instead of the
        raw ``t_max * (2*ppb+1)`` emission buffer."""
        if self.config is None:
            raise ValueError(
                "workload queries need a Simulator bound to an SSDConfig "
                "(the scheduler needs the channel/way geometry)")
        specs = list(specs)
        if not specs:
            raise ValueError("sweep(ftl=...) needs at least one FTLSpec")
        if stream.n_requests == 0:
            raise ValueError("empty workload: no requests to translate")
        g0 = (specs[0].blocks, specs[0].pages_per_block,
              float(specs[0].map_us), specs[0].erase_us)
        for s in specs[1:]:
            if (s.blocks, s.pages_per_block, float(s.map_us),
                    s.erase_us) != g0:
                raise ValueError(
                    "sweep(ftl=...) points must share geometry and "
                    "timing (blocks, pages_per_block, map_us, erase_us) "
                    "— vary overprovision / gc_policy / gc_free_blocks / "
                    "precondition per point")
        if _sched.policy_is_dynamic(sched_policy):
            raise ValueError(
                f"sched policy {sched_policy!r} is dynamic — the fused "
                "FTL sweep lowers placement in closed form; use "
                "run(SimRequest(ftl=...)) per point")
        batched = policy_is_batched(policy)
        sess = self._ftl_session(specs[0])
        blocks, ppb = specs[0].blocks, specs[0].pages_per_block
        C, W = self.config.channels, self.config.ways
        cls, arr, rid, pay = _workload.request_ops(stream)
        if int(np.max(stream.op_cls)) > _trace.WRITE:
            raise ValueError(
                "FTL translation consumes host READ/WRITE streams only "
                f"(got op class {int(np.max(stream.op_cls))})")
        n = len(cls)
        n_b = _ftl_scan._bucket(n + ppb)   # burst-window slack
        dpad = n_b - n
        cls_p = jnp.asarray(np.pad(cls, (0, dpad)), jnp.int32)
        arr_p = jnp.asarray(np.pad(arr, (0, dpad)), jnp.float32)
        pay_p = jnp.asarray(np.pad(pay, (0, dpad)), bool)
        rid_p = jnp.asarray(np.pad(rid, (0, dpad)), jnp.int32)
        lpn_rows = np.stack([
            np.pad(_workload.request_lpns(stream, s.logical_pages),
                   (0, dpad)).astype(np.int32) for s in specs])
        pre_lists = [(_ftl.precondition_lpns(s) if s.precondition
                      else np.zeros(0, np.int64)) for s in specs]
        has_pre = any(len(p) for p in pre_lists)
        p_b = _ftl_scan._bucket(max(len(p) for p in pre_lists) + ppb,
                                floor=1) if has_pre else 1
        pre_rows = np.stack([
            np.pad(p, (0, p_b - len(p))).astype(np.int32)
            for p in pre_lists])
        pre_n = np.asarray([len(p) for p in pre_lists], np.int32)
        gc_free = np.asarray([s.gc_free_blocks for s in specs], np.int32)
        is_lru = np.asarray([s.gc_policy == "lru" for s in specs], bool)
        n_w = int(np.sum(cls == _trace.WRITE))
        mesh = _points_mesh() if shard is not False else None
        mesh_sz = None if mesh is None else mesh.devices.size
        S = 2 * ppb + 1

        # ---- stage 1: preconditioned states (a pure function of the
        # spec batch — fold once, reuse across calls)
        skey = (tuple(specs), mesh_sz)
        st0 = self._ftl_pre_states.get(skey)
        if st0 is None and not has_pre:
            fs0 = _ftl_scan.scan_state_fresh(specs[0])
            st0 = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    jnp.asarray(x),
                    (len(specs),) + jnp.shape(jnp.asarray(x))), fs0)
        elif st0 is None:
            t_pre = max(_ftl_scan.estimate_t_max(s, 0, len(p),
                                                 precondition=True)
                        for s, p in zip(specs, pre_lists) if len(p))

            def build_pre(t_pre):
                fold_p = _ftl_scan.make_translate_fold(blocks, ppb,
                                                       p_b, t_pre)
                fs0 = _ftl_scan.scan_state_fresh(specs[0])
                cls_pre = jnp.full((p_b,), _trace.WRITE, jnp.int32)
                arr_pre = jnp.zeros((p_b,), jnp.float32)
                pay_pre = jnp.zeros((p_b,), bool)
                rid_pre = jnp.full((p_b,), -1, jnp.int32)

                def point(pre_lpn, n_pre, gfree, lru):
                    fs, _ = fold_p(cls_pre, arr_pre, pay_pre, rid_pre,
                                   pre_lpn, n_pre, gfree, lru, fs0)
                    pre_done = ((fs.h >= n_pre)
                                & (fs.mode == _ftl_scan.MODE_HOST))
                    return _ftl_scan._reset_window(fs, ppb), pre_done

                vm = jax.vmap(point)
                if mesh is not None:
                    return _shard_points(mesh, vm, n_sharded=4)
                return jax.jit(vm)

            while True:
                fn = self._closure(
                    ("ftl-sweep-pre", blocks, ppb, p_b, t_pre, mesh_sz),
                    functools.partial(build_pre, t_pre))
                st0, pre_done = fn(pre_rows, pre_n, gc_free, is_lru)
                err = np.asarray(st0.err)
                if err.any():
                    i = int(np.flatnonzero(err)[0])
                    _ftl_scan._raise_scan_error(int(err[i]), specs[i])
                if np.asarray(pre_done).all():
                    break
                t_pre *= 2
        self._ftl_pre_states[skey] = st0
        self._ftl_pre_states.move_to_end(skey)
        while len(self._ftl_pre_states) > 4:
            self._ftl_pre_states.popitem(last=False)

        # ---- stage 2: translate → compact → lower → simulate, with
        # learned buffer sizes per (specs, stream)
        digest = hashlib.blake2b(digest_size=8)
        for a in (cls, arr, lpn_rows):
            digest.update(np.ascontiguousarray(a).tobytes())
        wkey = (tuple(specs), n, n_w, digest.hexdigest(), sched_policy,
                batched, mesh_sz)
        sizes = self._ftl_sweep_sizes.get(wkey)
        if sizes is not None:
            t_max, t2 = sizes
            self._ftl_sweep_sizes.move_to_end(wkey)
        else:
            t_max = max(_ftl_scan.estimate_t_max(s, n - n_w, n_w)
                        for s in specs)
            t2 = _ftl_scan._bucket(
                max(_ftl_scan.estimate_ops(s, n - n_w, n_w)
                    for s in specs))

        def build(t_max, t2):
            fold_m = _ftl_scan.make_translate_fold(blocks, ppb, n_b,
                                                   t_max)
            T = t_max * S
            slot1 = jnp.arange(1, t2 + 1, dtype=jnp.int32)
            # compacted op i sits at slot i, so the closed-form static
            # placement (`lower_ops` field-for-field) is a closure
            # constant shared by every design point
            slot = jnp.arange(t2, dtype=jnp.int32)
            if sched_policy == "stripe":
                chan_c, way_c = slot % C, (slot // C) % W
            else:                       # "round_robin": way-first
                way_c, chan_c = slot % W, (slot // W) % C
            par_c = (slot // (C * W)) % 2
            extra_c = jnp.zeros((t2,), jnp.float32)

            def point(fs, lpn, gfree, lru,
                      h_cls, h_arr, h_pay, h_rid, n_eff):
                fs, ys = fold_m(h_cls, h_arr, h_pay, h_rid, lpn, n_eff,
                                gfree, lru, fs)
                # compact the [t_max, 2*ppb+1] emission rows into the
                # op bucket: position of the i-th valid lane via binary
                # search on the running popcount (gathers, no scatter)
                op_cls, arrival, valid = (ys[0].reshape(-1),
                                          ys[1].reshape(-1),
                                          ys[4].reshape(-1))
                cum = jnp.cumsum(valid.astype(jnp.int32))
                n_ops = cum[-1]
                pos = jnp.minimum(
                    jnp.searchsorted(cum, slot1, side="left"), T - 1)
                end = _sim._trace_end_time_masked_impl(
                    *sess._targs, op_cls[pos], chan_c, way_c, par_c,
                    arrival[pos], extra_c, slot1 <= n_ops, C, batched)
                done = ((fs.h >= n_eff)
                        & (fs.mode == _ftl_scan.MODE_HOST))
                rows = jnp.sum(jnp.any(ys[4], axis=1).astype(jnp.int32))
                return end, fs.err, done, n_ops, rows

            vm = jax.vmap(point, in_axes=(0, 0, 0, 0,
                                          None, None, None, None, None))
            if mesh is not None:
                return _shard_points(mesh, vm, n_sharded=4)
            return jax.jit(vm)

        while True:
            fn = self._closure(
                ("ftl-sweep", blocks, ppb, n_b, t_max, t2, C, W,
                 sched_policy, batched, mesh_sz),
                functools.partial(build, t_max, t2))
            end, err, done, n_ops, rows = fn(
                st0, lpn_rows, gc_free, is_lru,
                cls_p, arr_p, pay_p, rid_p, jnp.int32(n))
            err = np.asarray(err)
            if err.any():
                i = int(np.flatnonzero(err)[0])
                _ftl_scan._raise_scan_error(int(err[i]), specs[i])
            n_ops = np.asarray(n_ops)
            grow = False
            if not np.asarray(done).all():
                t_max *= 2           # emission buffer overflowed
                grow = True
            if int(n_ops.max()) > t2:
                # op bucket overflowed; n_ops from an overflowed
                # emission buffer is a lower bound, which only means
                # one more growth round
                t2 = _ftl_scan._bucket(int(n_ops.max()))
                grow = True
            if not grow:
                self._ftl_sweep_sizes[wkey] = (
                    _ftl_scan._bucket(int(np.asarray(rows).max()) + 1),
                    t2)
                while len(self._ftl_sweep_sizes) > 32:
                    self._ftl_sweep_sizes.popitem(last=False)
                return np.asarray(end, np.float64)


@functools.lru_cache(maxsize=128)
def simulator_for(config: SSDConfig) -> Simulator:
    """Memoised :class:`Simulator` per design point (``SSDConfig`` is a
    frozen dataclass, so it is the cache key)."""
    return Simulator(config)


# ---------------------------------------------------------------------------
# Module-level query functions (what the deprecated shims delegate to)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _points_mesh():
    """Process-wide 1-D ``("points",)`` sweep mesh over every device;
    None with a single device, which drops every sharded entry point
    back to its plain vmap path."""
    from repro.launch.mesh import make_points_mesh
    return make_points_mesh()


def _shard_points(mesh, fn, *, n_sharded: int):
    from repro.distributed.partitioning import shard_points
    return shard_points(mesh, fn, n_sharded=n_sharded)


@functools.lru_cache(maxsize=64)
def _sharded_batch_fn(mesh, engine: str, n_channels: int, n_ways: int,
                      batched: bool, segment_len, combine):
    """Memoised shard_map wrapper for the table-batched sweep folds:
    the 7 stacked table columns shard their leading (design-point) axis
    over the mesh, the trace arrays replicate — repeated sweeps over the
    same geometry reuse one compiled sharded program."""
    if engine == "scan":
        fn = functools.partial(_sim.trace_end_time_batch,
                               n_channels=n_channels, batched=batched)
    else:
        fn = functools.partial(
            _sim.trace_end_time_prefix_batch, n_channels=n_channels,
            n_ways=n_ways, batched=batched, segment_len=segment_len,
            combine=combine)
    return _shard_points(mesh, fn, n_sharded=7)


def sweep_tables(tables, trace: OpTrace, *, policy: Policy = "eager",
                 engine: str = "prefix", segment_len: int | None = 64,
                 combine: str = "chain",
                 shard: bool | None = None) -> np.ndarray:
    """[B] completion times (us) of one trace under a batch of
    design-point tables, dispatched through the registry.  With more
    than one device the stacked tables shard across devices via
    ``jax.shard_map`` (scan/prefix engines; the batch pads to a device
    multiple and slices back); ``shard=False`` forces the vmap path,
    one device always falls back to it."""
    batched = policy_is_batched(policy)
    eng = get_engine(engine)
    if trace.n_ops == 0:
        raise ValueError("empty trace: no ops to simulate")
    tables = list(tables)
    mesh = _points_mesh() if shard is not False else None
    if (mesh is not None and len(tables) > 1 and engine in ("scan", "prefix")
            and eng.caps.jittable and eng.caps.batched_tables):
        fn = _sharded_batch_fn(mesh, engine, trace.channels, trace.ways,
                               batched, segment_len, combine)
        return np.asarray(fn(*_stacked_table_args(tables),
                             *_trace_args(trace)))
    return eng.end_time_batch(tables, trace, batched=batched,
                              segment_len=segment_len, combine=combine)


@functools.lru_cache(maxsize=256)
def _steady_trace_cached(n_pages: int, channels: int, ways: int,
                         op_cls: int) -> OpTrace:
    return _trace.steady_trace(n_pages, channels, ways, op_cls)


def steady_bandwidth_mb_s(cfg: SSDConfig, mode: str,
                          n_pages: int = 512) -> float:
    """SSD-level steady-stream bandwidth (MB/s): all channels simulated
    jointly against the shared controller, capped by the SATA host link.
    ``n_pages`` is per channel.  (The session-API home of the old
    ``sim.ssd_bandwidth_mb_s``.)"""
    if mode not in ("read", "write"):
        raise ValueError(f"unknown mode {mode!r} (one of 'read', 'write')")
    trace = _steady_trace_cached(
        n_pages, cfg.channels, cfg.ways,
        _trace.READ if mode == "read" else _trace.WRITE)
    res = Simulator.for_config(cfg).run(trace, policy=cfg.policy)
    return float(min(res.mb_s, cfg.sata_mb_s))


def steady_channel_bandwidth_mb_s(op: PageOpParams, ways,
                                  policy: Policy = "eager",
                                  n_pages: int = 512,
                                  engine: str = "scan") -> jax.Array:
    """Steady-stream bandwidth of a single channel (MB/s) for one
    op-class design point, via any engine with the homogeneous-pattern
    capability (scan / prefix / squaring)."""
    batched = policy_is_batched(policy)
    end = get_engine(engine).steady_channel_end(
        op, ways, n_pages=n_pages, batched=batched)
    return (n_pages * op.data_bytes) / end


@functools.lru_cache(maxsize=64)
def _sharded_sweep_steady_fn(mesh, engine: str, n_pages: int,
                             batched: bool):
    """Memoised shard_map wrapper for the homogeneous design-point
    sweep: all 8 per-point arrays shard their leading axis."""
    base = (_sim._sweep_scan_jit if engine == "scan"
            else _sim._sweep_squaring_jit)
    fn = functools.partial(base, n_pages=n_pages, batched=batched)
    return _shard_points(mesh, fn, n_sharded=8)


def sweep_steady_bandwidth_mb_s(cmd_us, pre_us, slot_us, post_lo_us,
                                post_hi_us, ctrl_us, data_bytes, ways,
                                n_pages: int = 512, batched: bool = False,
                                engine: str = "scan",
                                shard: bool | None = None) -> jax.Array:
    """Vectorised single-channel steady bandwidth over design points
    (arrays [N]), via an engine with the sweep capability
    (scan / squaring).  With more than one device the design points
    shard across devices via ``jax.shard_map`` (``shard=False`` forces
    the vmap path) — the fan-out the ``calibrate`` fitting grids ride."""
    scalars = (cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us)
    mesh = _points_mesh() if shard is not False else None
    if mesh is not None and engine in ("scan", "squaring"):
        if engine == "squaring":
            _sim._validate_squaring_ways(ways)
        args = tuple(jnp.asarray(x) for x in scalars + (data_bytes, ways))
        if args[0].ndim == 1 and int(args[0].shape[0]) > 1:
            fn = _sharded_sweep_steady_fn(mesh, engine, n_pages, batched)
            return fn(*args)
    return get_engine(engine).sweep_steady(
        scalars, data_bytes, ways, n_pages=n_pages, batched=batched)


__all__ = [
    "CacheInfo", "CapabilityError", "Engine", "EngineCaps", "FaultSpec",
    "OBJECTIVES", "Objective", "Policy", "RequestStream", "SimRequest",
    "SimResult", "Simulator", "engine_capabilities", "get_engine",
    "register_engine", "registered_engines", "simulator_for",
    "steady_bandwidth_mb_s", "steady_channel_bandwidth_mb_s",
    "sweep_steady_bandwidth_mb_s", "sweep_tables",
]
