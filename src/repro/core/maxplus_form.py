"""The SSD trace event recurrence as (max,+) linear algebra.

The per-op update of the trace simulator (``repro.core.sim``)

    ready    = chip_free[c,w] + cmd + pre               (eager)
               round_start[c] + (w+1)·cmd + pre         (batched)
    start    = max(bus_free[c], ready, ctrl_free) + arb
    bus'_c   = start + slot ;  ctrl' = start + ctrl
    chip'_cw = bus'_c + post(parity)

is affine in the (max,+) semiring over the state vector

    s = [bus_0..bus_{C-1},
         chip_00..chip_{C-1,W-1},
         ctrl_free,
         round_start_0..round_start_{C-1}]

so one op is a matvec  s' = A ⊗ s  with (A ⊗ s)_r = max_c (A_rc + s_c).
Each *distinct* (op-class, channel, way, parity) combination appearing in
a trace gets one matrix; the trace compiles to a **matrix dictionary**
``mats [M, N, N]`` plus an index sequence ``idx [T]``, and the whole
trace is the fold  s_T = A_{idx[T-1]} ⊗ … ⊗ A_{idx[0]} ⊗ s_0 — the
TPU-native replacement for the paper's sequential RTL co-simulation
(DESIGN.md §2.1).  A homogeneous single-channel stream degenerates to the
old periodic form: M = 2·ways matrices (way round-robin × MLC page
parity) and idx[t] = t mod 2·ways.  ``repro.kernels.maxplus`` evaluates
the fold for thousands of design points in parallel, gathering
``A[idx[t]]`` inside its ``fori_loop``.

Because ⊗ is associative, the fold need not be evaluated sequentially
(DESIGN.md §2.3).  This module also provides the **log-depth
evaluation strategies**:

* ``structured_segment_products`` — chunk the trace into S segments and
  fold every segment's matrix product **concurrently**.  One op matrix
  is the identity plus ≤ 4 rewritten rows, so ``A_t ⊗ P`` only rewrites
  those rows of P: the segment fold is the *scan recurrence itself with
  each scalar resource time replaced by an N-row of the evolving
  product* (initialised to identity basis rows) — O(T·N) work instead
  of the O(T·N³) of dense matmuls, with sequential depth L = T/S;
* ``maxplus_fold_segmented`` — the dense twin over a matrix dictionary
  (blocked (max,+) matmuls; the MXU-shaped form for TPUs), with
  ``segment_len=None`` dispatching to ``maxplus_fold_assoc``, the pure
  O(log T)-depth ``associative_scan`` fold;
* ``maxplus_matrix_power`` / ``periodic_fold_squaring`` — a homogeneous
  periodic stream folds one period into ``A_period`` and reaches
  ``n_pages`` ops via repeated squaring: O(log n_pages) matmuls total.

``StateLayout`` fixes (channels, ways) per batch so design points with
different geometries stay batchable; unused rows are (max,+) identity.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sim import MAX_WAYS, PageOpParams, policy_is_batched

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class StateLayout:
    """Row indexing of the (max,+) state vector for a (C, W) geometry.

    The last row is the **origin** — a constant-zero row no op ever
    rewrites (its step-matrix row is the identity basis row).  Request
    arrival times enter the recurrence through its *column*: an op with
    arrival a contributes ``a + offset`` to the start-time max via
    ``A[row, origin] = a + offset`` and ``s[origin] = 0``, so
    arrival-aware traces stay inside the (max,+) algebra and compose
    across segment products exactly like every other source
    (DESIGN.md §2.6)."""

    channels: int = 1
    ways: int = MAX_WAYS

    @property
    def n_state(self) -> int:
        c, w = self.channels, self.ways
        return c + c * w + 1 + c + 1

    def bus(self, c: int) -> int:
        return c

    def chip(self, c: int, w: int) -> int:
        return self.channels + c * self.ways + w

    @property
    def ctrl(self) -> int:
        return self.channels * (1 + self.ways)

    def rs(self, c: int) -> int:
        return self.ctrl + 1 + c

    @property
    def origin(self) -> int:
        """The constant-zero (time-origin) row arrivals enter through."""
        return self.ctrl + 1 + self.channels

    @property
    def n_completion_rows(self) -> int:
        """bus + chip rows participate in the completion time; the ctrl,
        round_start and origin helpers never exceed them."""
        return self.channels * (1 + self.ways)


DEFAULT_LAYOUT = StateLayout(1, MAX_WAYS)
N_STATE = DEFAULT_LAYOUT.n_state   # bus, chips 0..15, ctrl, round_start, origin
PERIOD = 2 * MAX_WAYS              # homogeneous: round-robin × page parity


def ready_offset_us(cmd_us: float, pre_us: float, way: int,
                    batched: bool) -> float:
    """Command-issue latency between the ready *base* (chip free or round
    start — or the request arrival, whichever is later) and the op being
    ready for the bus: cmd+pre eager, (w+1)·cmd+pre batched.  The single
    definition the scan step, the structured fold, the step matrices and
    the oracles all share."""
    return ((way + 1) * cmd_us + pre_us) if batched else (cmd_us + pre_us)


def op_matrix(layout: StateLayout, *, cmd_us: float, pre_us: float,
              slot_us: float, ctrl_us: float, arb_us: float, post_us: float,
              channel: int, way: int, policy: str = "eager",
              arrival_us: float = 0.0, extra_us: float = 0.0) -> np.ndarray:
    """(max,+) step matrix of one op on (channel, way).

    ``arrival_us`` enters through the origin column: the op's ready time
    is max(base, arrival) + ready_offset, so the origin source carries
    ``arrival + ready_offset``.  At arrival 0 the origin candidate is
    dominated by every real source (state values are >= 0), leaving
    zero-arrival traces numerically identical to the pre-arrival form.

    ``extra_us`` is the op's reliability surcharge (DESIGN.md §2.8): it
    extends the op's *chip* occupancy (chip = bus' + post + extra) — an
    additive per-op shift that stays inside the (max,+) algebra.
    Retries re-run the sense inside the die, so neither the channel bus
    nor the serial controller is held: one retry-stormed read delays
    its own request and later ops on the same chip, never the channel
    or the FCFS issue stage."""
    n = layout.n_state
    a = np.full((n, n), NEG, np.float32)
    for r in range(n):
        a[r, r] = 0.0                       # untouched resources persist
    bus, chip = layout.bus(channel), layout.chip(channel, way)
    ctrl, rs, origin = layout.ctrl, layout.rs(channel), layout.origin
    batched = policy_is_batched(policy)
    ready_off = ready_offset_us(cmd_us, pre_us, way, batched)
    # start = max over these source columns (+ per-column offsets) + arb:
    if batched:
        if way == 0:
            sources = {bus: cmd_us + pre_us}
            a[rs, :] = NEG
            a[rs, bus] = 0.0                # round_start' = old bus_free
        else:
            sources = {bus: 0.0, rs: (way + 1) * cmd_us + pre_us}
    else:
        sources = {bus: 0.0, chip: cmd_us + pre_us}
    sources[ctrl] = max(sources.get(ctrl, NEG), 0.0)
    sources[origin] = arrival_us + ready_off
    for row, tail in ((bus, slot_us), (ctrl, ctrl_us),
                      (chip, slot_us + extra_us + post_us)):
        a[row, :] = NEG
        for col, off in sources.items():
            a[row, col] = arb_us + off + tail
    return a


def transition_matrices(op: PageOpParams, ways: int, policy: str = "eager",
                        arb_us: float = 0.0) -> np.ndarray:
    """[PERIOD, N_STATE, N_STATE] periodic matrices of a homogeneous
    single-channel stream (back-compat design-point batching form)."""
    assert MAX_WAYS % ways == 0, f"kernel path needs ways | {MAX_WAYS}, got {ways}"
    mats = np.stack([
        op_matrix(DEFAULT_LAYOUT, cmd_us=op.cmd_us, pre_us=op.pre_us,
                  slot_us=op.slot_us, ctrl_us=op.ctrl_us, arb_us=arb_us,
                  post_us=(op.post_lo_us if (i // ways) % 2 == 0
                           else op.post_hi_us),
                  channel=0, way=i % ways, policy=policy)
        for i in range(PERIOD)])
    return mats


def trace_combos(trace) -> tuple[list[tuple[int, int, int, int]], np.ndarray]:
    """Distinct (class, channel, way, parity) combos of a trace, in order
    of first appearance, plus the per-op index into them.  Depends only on
    the trace — shareable across a batch of timing tables."""
    combos: dict[tuple[int, int, int, int], int] = {}
    idx = np.empty(trace.n_ops, np.int32)
    for t in range(trace.n_ops):
        key = (int(trace.cls[t]), int(trace.channel[t]),
               int(trace.way[t]), int(trace.parity[t]) % 2)
        m = combos.get(key)
        if m is None:
            m = combos[key] = len(combos)
        idx[t] = m
    return list(combos), idx


def combo_matrices(table, combos, layout: StateLayout,
                   policy: str = "eager") -> np.ndarray:
    """[M, N, N] step matrices for one timing table over shared combos.

    Arrivals are *not* baked in (they vary per op, not per combo): the
    matrices carry the zero-arrival origin column, and arrival-aware
    folds max the per-op ``combo_arrival_offsets`` row + arrival into
    the state each step — algebraically the same augmented matrix,
    without exploding the dictionary to one matrix per op."""
    return np.stack([
        op_matrix(
            layout,
            cmd_us=float(table.cmd_us[k]), pre_us=float(table.pre_us[k]),
            slot_us=float(table.slot_us[k]), ctrl_us=float(table.ctrl_us[k]),
            arb_us=float(table.arb_us[k]),
            post_us=float(table.post_lo_us[k] if par == 0
                          else table.post_hi_us[k]),
            channel=c, way=w, policy=policy)
        for k, c, w, par in combos])


def combo_arrival_offsets(table, combos, layout: StateLayout,
                          policy: str = "eager") -> np.ndarray:
    """[M, N] origin-column templates per combo: row r of op combo m
    holds the offset the op's arrival contributes to state row r
    (NEG for rows the op does not rewrite).  The per-op augmented
    matrix is ``mats[m]`` with its origin column maxed against
    ``arrival + g[m]`` — equivalently, a fold step is
    ``s' = max(A_m (x) s, arrival + g[m])`` since ``s[origin] = 0``."""
    batched = policy_is_batched(policy)
    g = np.full((len(combos), layout.n_state), NEG, np.float32)
    for m, (k, c, w, par) in enumerate(combos):
        ready_off = ready_offset_us(float(table.cmd_us[k]),
                                    float(table.pre_us[k]), w, batched)
        arb = float(table.arb_us[k])
        slot = float(table.slot_us[k])
        post = float(table.post_lo_us[k] if par == 0
                     else table.post_hi_us[k])
        g[m, layout.bus(c)] = arb + ready_off + slot
        g[m, layout.ctrl] = arb + ready_off + float(table.ctrl_us[k])
        g[m, layout.chip(c, w)] = arb + ready_off + slot + post
    return g


def combo_written_rows(combos, layout: StateLayout) -> np.ndarray:
    """[M, N] float32 mask: 1.0 on the state rows the per-op reliability
    surcharge *shifts* (op combo m's chip only — retries re-run the
    sense in the die, so the bus, serial-ctrl and round-start rows are
    never extended), 0.0 elsewhere.

    This is how the surcharge (``OpTrace.extra_us``, DESIGN.md §2.8)
    enters the dictionary-matrix folds without exploding the dictionary
    to one matrix per op: a fold step becomes
    ``s' = max(A_m (x) s, arr + g[m]) + wrows[m] * extra_t`` — the
    shifted chip row moves by the op's extra (exactly the scan
    recurrence, where chip = bus' + post + extra), untouched rows add
    0.0 (exact)."""
    wr = np.zeros((len(combos), layout.n_state), np.float32)
    for m, (_, c, w, _) in enumerate(combos):
        wr[m, layout.chip(c, w)] = 1.0
    return wr




# ---------------------------------------------------------------------------
# Log-depth evaluation: (max,+) matmul algebra (DESIGN.md §2.3)
# ---------------------------------------------------------------------------


def maxplus_eye(n: int) -> np.ndarray:
    """(max,+) identity: 0 on the diagonal, -inf (NEG) elsewhere."""
    return np.where(np.eye(n, dtype=bool), 0.0, NEG).astype(np.float32)


def maxplus_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """(max,+) matrix product C[..., r, c] = max_k (a[..., r, k] + b[..., k, c]).

    Saturates at NEG so identity rows stay exactly NEG under repeated
    squaring instead of drifting towards float -inf/overflow."""
    c = jnp.max(a[..., :, :, None] + b[..., None, :, :], axis=-2)
    return jnp.maximum(c, NEG)


def maxplus_matvec(a: jax.Array, s: jax.Array) -> jax.Array:
    """(A ⊗ s)[..., r] = max_c (a[..., r, c] + s[..., c])."""
    return jnp.max(a + s[..., None, :], axis=-1)


def maxplus_matrix_power(a: jax.Array, n: int) -> jax.Array:
    """a^⊗n by binary exponentiation — O(log n) (max,+) matmuls.

    ``n`` is static (python int >= 0); n == 0 returns the identity."""
    assert n >= 0
    dim = a.shape[-1]
    result = jnp.broadcast_to(jnp.asarray(maxplus_eye(dim)), a.shape)
    while n:
        if n & 1:
            result = maxplus_matmul(a, result)
        n >>= 1
        if n:
            a = maxplus_matmul(a, a)
    return result


def _chain_product(g: jax.Array) -> jax.Array:
    """Sequential fold P = g[-1] ⊗ … ⊗ g[0] over leading axis (small T)."""

    def step(p, a):
        return maxplus_matmul(a, p), None

    eye = jnp.broadcast_to(jnp.asarray(maxplus_eye(g.shape[-1])),
                           g.shape[1:])
    p, _ = jax.lax.scan(step, eye, g)
    return p


def maxplus_fold_assoc(g: jax.Array, s0: jax.Array) -> jax.Array:
    """Pure log-depth fold: s_T = g[T-1] ⊗ … ⊗ g[0] ⊗ s0.

    ``g`` [T, ..., N, N] per-op matrices (already gathered), ``s0``
    [..., N].  ``associative_scan`` evaluates all T prefixes in O(log T)
    depth; we keep only the total product.  O(T·N³) work — the form to
    use when the accelerator has FLOPs to burn (TPU MXU)."""
    pref = jax.lax.associative_scan(
        lambda x, y: maxplus_matmul(y, x), g, axis=0)
    return maxplus_matvec(pref[-1], s0)


def maxplus_fold_segmented(
    mats: jax.Array,         # [..., M, N, N] matrix dictionary
    idx: jax.Array,          # [T] int32 per-op matrix index (shared)
    s0: jax.Array,           # [..., N]
    *,
    segment_len: int | None = 64,
) -> jax.Array:
    """Segmented parallel-prefix fold of a trace-indexed matrix product.

    The [T] trace is chunked into S = ceil(T/L) segments of length
    L = ``segment_len``; all S segment products fold concurrently (one
    ``lax.scan`` over L steps carrying [..., S, N, N]), then the S
    products combine with ``associative_scan`` — O(L + log S) depth vs
    the O(T) sequential matvec fold.  The tail pads with the (max,+)
    identity (index M), which is a no-op on the product.  This is the
    dense (MXU-shaped) strategy over a matrix dictionary; the O(T·N)
    structured twin is ``structured_segment_products``.
    ``segment_len=None`` gathers all T matrices and runs the pure
    O(log T)-depth ``maxplus_fold_assoc``."""
    mats = jnp.asarray(mats)
    idx = jnp.asarray(idx, jnp.int32)
    if segment_len is None:
        g = jnp.moveaxis(jnp.take(mats, idx, axis=-3), -3, 0)
        return maxplus_fold_assoc(g, s0)
    n = mats.shape[-1]
    t_steps = idx.shape[0]
    seg = max(1, min(segment_len, t_steps))
    n_seg = -(-t_steps // seg)
    eye = jnp.asarray(maxplus_eye(n))
    # index M = identity padding for the ragged tail
    mats_ext = jnp.concatenate(
        [mats, jnp.broadcast_to(eye, mats.shape[:-3] + (1, n, n))], axis=-3)
    pad = n_seg * seg - t_steps
    idx_ext = jnp.pad(idx, (0, pad), constant_values=mats.shape[-3])
    idx_cols = idx_ext.reshape(n_seg, seg).T          # [L, S]

    def step(p, cols):
        # gather this step's matrix for every segment: [..., S, N, N]
        a = jnp.take(mats_ext, cols, axis=-3)
        return maxplus_matmul(a, p), None

    p0 = jnp.broadcast_to(eye, mats.shape[:-3] + (n_seg, n, n))
    prods, _ = jax.lax.scan(step, p0, idx_cols)
    # combine segment products in log depth; segment axis is -3
    prods = jnp.moveaxis(prods, -3, 0)                # [S, ..., N, N]
    pref = jax.lax.associative_scan(
        lambda x, y: maxplus_matmul(y, x), prods, axis=0)
    return maxplus_matvec(pref[-1], s0)


def structured_segment_products(
    cmd_us: jax.Array,       # [K] op-class timing table
    pre_us: jax.Array,       # [K]
    slot_us: jax.Array,      # [K]
    post_lo_us: jax.Array,   # [K]
    post_hi_us: jax.Array,   # [K]
    ctrl_us: jax.Array,      # [K]
    arb_us: jax.Array,       # [K]
    cls: jax.Array,          # [T] int32
    channel: jax.Array,      # [T] int32
    way: jax.Array,          # [T] int32
    parity: jax.Array,       # [T] int32
    arrival_us: jax.Array | None = None,   # [T] float32 request arrivals
    extra_us: jax.Array | None = None,     # [T] float32 reliability add-on
    *,
    channels: int,
    ways: int,
    batched: bool,
    segment_len: int,
    valid: jax.Array | None = None,        # [T] bool op mask
) -> jax.Array:
    """[S, N, N] (max,+) products of the trace's S = ceil(T/L) segments.

    Exploits the structure of the step matrices: one op rewrites only
    the bus/ctrl/chip (and round-start) rows, each a max of ≤ 3 source
    rows plus offsets — so ``A_t ⊗ P`` is the scan-engine recurrence
    applied to *N-row-valued* resource times.  Every segment runs that
    recurrence from identity basis rows, all segments advancing in one
    vectorised scan step: O(T·N) work, sequential depth L, versus
    O(T·N³) / depth T for the dense fold.

    ``arrival_us`` rides the same recurrence: the ready base is maxed
    with the constant origin basis row shifted by the op's arrival
    (DESIGN.md §2.6), so the segment products compose arrival effects
    across segments exactly like every other (max,+) source.  None (or
    all-zero) arrivals reproduce the pre-arrival products bit-for-bit
    (state rows dominate the zero-shifted origin row).

    ``extra_us`` (the per-op reliability surcharge, DESIGN.md §2.8)
    extends the op's chip row only (chip = bus' + post + extra); the
    bus and serial-ctrl rows are never extended — retries re-run the
    sense inside the die.  None / all-zero extras add +0.0 — exact,
    bit-for-bit.

    ``valid`` masks ops out *exactly*: a False lane rides the same
    drop-sentinel path as the ragged tail — no row is written, so the
    op is the (max,+) identity on the product, not a zero-timing op
    (which would still serialise the bus).  This is how sparsely
    padded traces — the fused FTL sweep's ``[t_max, 2*ppb+1]``
    emission rows (DESIGN.md §2.11) — evaluate without compaction."""
    layout = StateLayout(channels, ways)
    n = layout.n_state
    t_steps = cls.shape[0]
    seg = max(1, min(segment_len, t_steps))
    n_seg = -(-t_steps // seg)
    pad = n_seg * seg - t_steps
    if arrival_us is None:
        arrival_us = jnp.zeros((t_steps,), jnp.float32)
    if extra_us is None:
        extra_us = jnp.zeros((t_steps,), jnp.float32)

    def cols(x, fill=0):
        x = jnp.pad(jnp.asarray(x), (0, pad), constant_values=fill)
        return x.reshape(n_seg, seg).T                 # [L, S]

    # hoist every per-op quantity out of the scan: class-table gathers,
    # parity-resolved post times, and the row indices each op touches.
    # Padding ops in the ragged tail get out-of-range indices and write
    # with mode="drop" (a zero-timing op is *not* the identity map, so
    # padding must skip, not no-op).  The step body then touches only
    # the O(S·N) rows an op actually rewrites — gathers/scatters, never
    # a full pass over the [S, C·W, N] chip block.
    k = cols(jnp.asarray(cls, jnp.int32))
    c = cols(jnp.asarray(channel, jnp.int32))
    w = cols(jnp.asarray(way, jnp.int32))
    par = cols(jnp.asarray(parity, jnp.int32))
    arr = cols(jnp.asarray(arrival_us, jnp.float32))
    ext = cols(jnp.asarray(extra_us, jnp.float32))
    if valid is None:
        valid = jnp.ones((t_steps,), bool)
    valid = cols(jnp.asarray(valid, bool), fill=False)
    ready_off = ((w + 1).astype(jnp.float32) * cmd_us[k] if batched
                 else cmd_us[k]) + pre_us[k]
    xs = (c, c * ways + w,
          jnp.where(valid, c, channels),               # drop-sentinels
          jnp.where(valid, c * ways + w, channels * ways),
          (w == 0) & valid, valid, ready_off, arr, ext,
          slot_us[k], ctrl_us[k], arb_us[k],
          jnp.where(par % 2 == 0, post_lo_us[k], post_hi_us[k]))

    basis = jnp.asarray(maxplus_eye(n))                # basis rows
    init = tuple(jnp.broadcast_to(x, (n_seg,) + x.shape) for x in (
        basis[:channels],                              # bus  [S,C,N]
        basis[channels:channels * (1 + ways)],         # chip [S,C·W,N]
        basis[layout.ctrl],                            # ctrl [S,N]
        basis[layout.ctrl + 1:layout.origin]))         # rs   [S,C,N]
    origin_row = basis[layout.origin]                  # constant: never written
    lane = jnp.arange(n_seg)

    def step(state, op):
        bus, chip, ctl, rs = state
        (c, cw, ci, cwi, first, ok, rd, arr_t, ext_t, slot, ctru, arb,
         post) = op
        bus_c = jnp.take_along_axis(bus, c[:, None, None], axis=1)[:, 0]
        arr_row = origin_row[None, :] + arr_t[:, None]   # [S, N]
        if batched:
            rs_c = jnp.take_along_axis(rs, c[:, None, None], axis=1)[:, 0]
            rs_row = jnp.where(first[:, None], bus_c, rs_c)
            rs = rs.at[lane, jnp.where(first, ci, channels)].set(
                bus_c, mode="drop")
            ready = jnp.maximum(rs_row, arr_row) + rd[:, None]
        else:                          # rs rows stay identity
            chip_cw = jnp.take_along_axis(
                chip, cw[:, None, None], axis=1)[:, 0]
            ready = jnp.maximum(chip_cw, arr_row) + rd[:, None]
        start = jnp.maximum(jnp.maximum(bus_c, ready), ctl) + arb[:, None]
        new_bus = start + slot[:, None]
        bus = bus.at[lane, ci].set(new_bus, mode="drop")
        chip = chip.at[lane, cwi].set(
            new_bus + post[:, None] + ext_t[:, None], mode="drop")
        ctl = jnp.where(ok[:, None], start + ctru[:, None], ctl)
        return (bus, chip, ctl, rs), None

    (bus, chip, ctl, rs), _ = jax.lax.scan(step, init, xs)
    origin = jnp.broadcast_to(origin_row, (n_seg, 1, n))
    return jnp.concatenate([bus, chip, ctl[:, None, :], rs, origin], axis=1)


def structured_segment_energy(
    e_op_uj: jax.Array,      # [K, 2, P] per-op phase energies (parity axis)
    cls: jax.Array,          # [T] int32
    parity: jax.Array,       # [T] int32
    *,
    segment_len: int,
) -> jax.Array:
    """[S, P] per-segment phase-energy sums (uJ) of the trace's
    S = ceil(T/L) segments — the energy twin of
    ``structured_segment_products`` (DESIGN.md §2.4).

    Energy is (+, +)-linear in the ops, so where the end time needs a
    (max,+) matrix product per segment, the phase accumulator needs only
    a segment *sum* over the same chunking: gather each op's [P] phase
    vector (parity-resolved for the MLC array phase), pad the ragged
    tail with zeros (a true no-op for +, unlike the end-time fold where
    padding must scatter-drop), and reduce per segment."""
    t_steps = cls.shape[0]
    seg = max(1, min(segment_len, t_steps))
    n_seg = -(-t_steps // seg)
    pad = n_seg * seg - t_steps
    e = e_op_uj[jnp.asarray(cls, jnp.int32),
                jnp.asarray(parity, jnp.int32) % 2]        # [T, P]
    e = jnp.pad(e, ((0, pad), (0, 0)))
    return jnp.sum(e.reshape(n_seg, seg, e.shape[-1]), axis=1)


def periodic_fold_squaring(period_mats: jax.Array, s0: jax.Array,
                           n_steps: int) -> jax.Array:
    """Homogeneous stream: fold one period, then square to ``n_steps``.

    ``period_mats`` [..., P, N, N] (op order along axis -3); the fold
        s_T = R ⊗ A_period^q ⊗ s0,  n_steps = q·P + r,
    needs the P-step period product, ~log2(q) squarings and an r-step
    remainder prefix — O(P + log n_steps) matmuls vs O(n_steps) matvecs.
    ``n_steps`` is static."""
    period_mats = jnp.asarray(period_mats)
    p = period_mats.shape[-3]
    q, r = divmod(int(n_steps), p)
    lead = jnp.moveaxis(period_mats, -3, 0)           # [P, ..., N, N]
    a_period = _chain_product(lead)
    total = maxplus_matrix_power(a_period, q)
    if r:
        total = maxplus_matmul(_chain_product(lead[:r]), total)
    return maxplus_matvec(total, s0)


def init_state(layout: StateLayout = DEFAULT_LAYOUT) -> np.ndarray:
    """All resources free at t=0 (controller and round_starts included)."""
    return np.zeros((layout.n_state,), np.float32)


def end_time_from_state(state: np.ndarray,
                        layout: StateLayout = DEFAULT_LAYOUT) -> np.ndarray:
    """Completion = max(bus, chip frees); excludes the ctrl/round_start
    helper rows (they never exceed the issuing op's bus row)."""
    return state[..., :layout.n_completion_rows].max(axis=-1)
