"""The SSD trace event recurrence as (max,+) linear algebra.

The per-op update of the trace simulator (``repro.core.sim``)

    ready    = chip_free[c,w] + cmd + pre               (eager)
               round_start[c] + (w+1)·cmd + pre         (batched)
    start    = max(bus_free[c], ready, ctrl_free) + arb
    bus'_c   = start + slot ;  ctrl' = start + ctrl
    chip'_cw = bus'_c + post(parity)

is affine in the (max,+) semiring over the state vector

    s = [bus_0..bus_{C-1},
         chip_00..chip_{C-1,W-1},
         ctrl_free,
         round_start_0..round_start_{C-1}]

so one op is a matvec  s' = A ⊗ s  with (A ⊗ s)_r = max_c (A_rc + s_c).
Each *distinct* (op-class, channel, way, parity) combination appearing in
a trace gets one matrix; the trace compiles to a **matrix dictionary**
``mats [M, N, N]`` plus an index sequence ``idx [T]``, and the whole
trace is the fold  s_T = A_{idx[T-1]} ⊗ … ⊗ A_{idx[0]} ⊗ s_0 — the
TPU-native replacement for the paper's sequential RTL co-simulation
(DESIGN.md §2.1).  A homogeneous single-channel stream degenerates to the
old periodic form: M = 2·ways matrices (way round-robin × MLC page
parity) and idx[t] = t mod 2·ways.  ``repro.kernels.maxplus`` evaluates
the fold for thousands of design points in parallel, gathering
``A[idx[t]]`` inside its ``fori_loop``.

``StateLayout`` fixes (channels, ways) per batch so design points with
different geometries stay batchable; unused rows are (max,+) identity.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.sim import MAX_WAYS, PageOpParams

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class StateLayout:
    """Row indexing of the (max,+) state vector for a (C, W) geometry."""

    channels: int = 1
    ways: int = MAX_WAYS

    @property
    def n_state(self) -> int:
        c, w = self.channels, self.ways
        return c + c * w + 1 + c

    def bus(self, c: int) -> int:
        return c

    def chip(self, c: int, w: int) -> int:
        return self.channels + c * self.ways + w

    @property
    def ctrl(self) -> int:
        return self.channels * (1 + self.ways)

    def rs(self, c: int) -> int:
        return self.ctrl + 1 + c

    @property
    def n_completion_rows(self) -> int:
        """bus + chip rows participate in the completion time; the ctrl
        and round_start helpers never exceed them."""
        return self.channels * (1 + self.ways)


DEFAULT_LAYOUT = StateLayout(1, MAX_WAYS)
N_STATE = DEFAULT_LAYOUT.n_state   # bus, chips 0..15, ctrl, round_start
PERIOD = 2 * MAX_WAYS              # homogeneous: round-robin × page parity


def op_matrix(layout: StateLayout, *, cmd_us: float, pre_us: float,
              slot_us: float, ctrl_us: float, arb_us: float, post_us: float,
              channel: int, way: int, policy: str = "eager") -> np.ndarray:
    """(max,+) step matrix of one op on (channel, way)."""
    n = layout.n_state
    a = np.full((n, n), NEG, np.float32)
    for r in range(n):
        a[r, r] = 0.0                       # untouched resources persist
    bus, chip = layout.bus(channel), layout.chip(channel, way)
    ctrl, rs = layout.ctrl, layout.rs(channel)
    # start = max over these source columns (+ per-column offsets) + arb:
    if policy == "batched":
        if way == 0:
            sources = {bus: cmd_us + pre_us}
            a[rs, :] = NEG
            a[rs, bus] = 0.0                # round_start' = old bus_free
        else:
            sources = {bus: 0.0, rs: (way + 1) * cmd_us + pre_us}
    else:
        sources = {bus: 0.0, chip: cmd_us + pre_us}
    sources[ctrl] = max(sources.get(ctrl, NEG), 0.0)
    for row, extra in ((bus, slot_us), (ctrl, ctrl_us),
                       (chip, slot_us + post_us)):
        a[row, :] = NEG
        for col, off in sources.items():
            a[row, col] = arb_us + off + extra
    return a


def transition_matrices(op: PageOpParams, ways: int, policy: str = "eager",
                        arb_us: float = 0.0) -> np.ndarray:
    """[PERIOD, N_STATE, N_STATE] periodic matrices of a homogeneous
    single-channel stream (back-compat design-point batching form)."""
    assert MAX_WAYS % ways == 0, f"kernel path needs ways | {MAX_WAYS}, got {ways}"
    mats = np.stack([
        op_matrix(DEFAULT_LAYOUT, cmd_us=op.cmd_us, pre_us=op.pre_us,
                  slot_us=op.slot_us, ctrl_us=op.ctrl_us, arb_us=arb_us,
                  post_us=(op.post_lo_us if (i // ways) % 2 == 0
                           else op.post_hi_us),
                  channel=0, way=i % ways, policy=policy)
        for i in range(PERIOD)])
    return mats


def trace_combos(trace) -> tuple[list[tuple[int, int, int, int]], np.ndarray]:
    """Distinct (class, channel, way, parity) combos of a trace, in order
    of first appearance, plus the per-op index into them.  Depends only on
    the trace — shareable across a batch of timing tables."""
    combos: dict[tuple[int, int, int, int], int] = {}
    idx = np.empty(trace.n_ops, np.int32)
    for t in range(trace.n_ops):
        key = (int(trace.cls[t]), int(trace.channel[t]),
               int(trace.way[t]), int(trace.parity[t]) % 2)
        m = combos.get(key)
        if m is None:
            m = combos[key] = len(combos)
        idx[t] = m
    return list(combos), idx


def combo_matrices(table, combos, layout: StateLayout,
                   policy: str = "eager") -> np.ndarray:
    """[M, N, N] step matrices for one timing table over shared combos."""
    return np.stack([
        op_matrix(
            layout,
            cmd_us=float(table.cmd_us[k]), pre_us=float(table.pre_us[k]),
            slot_us=float(table.slot_us[k]), ctrl_us=float(table.ctrl_us[k]),
            arb_us=float(table.arb_us[k]),
            post_us=float(table.post_lo_us[k] if par == 0
                          else table.post_hi_us[k]),
            channel=c, way=w, policy=policy)
        for k, c, w, par in combos])




def init_state(layout: StateLayout = DEFAULT_LAYOUT) -> np.ndarray:
    """All resources free at t=0 (controller and round_starts included)."""
    return np.zeros((layout.n_state,), np.float32)


def end_time_from_state(state: np.ndarray,
                        layout: StateLayout = DEFAULT_LAYOUT) -> np.ndarray:
    """Completion = max(bus, chip frees); excludes the ctrl/round_start
    helper rows (they never exceed the issuing op's bus row)."""
    return state[..., :layout.n_completion_rows].max(axis=-1)
