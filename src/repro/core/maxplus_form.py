"""The SSD channel event recurrence as (max,+) linear algebra.

The per-page-op update of the event simulator (``repro.core.sim``)

    ready   = chip_free[w] + cmd + pre                (eager)
              round_start + (w+1)·cmd + pre           (batched)
    bus'    = max(bus + slot, ready + slot)
    chip'_w = bus' + post ;  chip'_j = chip_j ;  rs' = rs / bus

is affine in the (max,+) semiring over the state vector

    s = [bus_free, chip_free_0 .. chip_free_{W-1}, round_start]

so one page op is a matvec  s' = A_i ⊗ s  with (A ⊗ s)_r = max_c (A_rc + s_c).
The matrices are periodic in i with period 2·ways (way round-robin ×
MLC lower/upper-page parity), so a whole trace is a fold over a periodic
matrix sequence — the TPU-native replacement for the paper's sequential
RTL co-simulation (DESIGN.md §2.1).  ``repro.kernels.maxplus`` evaluates
the fold for thousands of design points in parallel.

Fixed state size ``N_STATE`` (= MAX_WAYS + 2) keeps design points with
different way counts batchable; unused chip rows are (max,+) identity.
"""

from __future__ import annotations

import numpy as np

from repro.core.sim import MAX_WAYS, PageOpParams

NEG = -1e30
N_STATE = MAX_WAYS + 2      # bus, chips 0..15, round_start
PERIOD = 2 * MAX_WAYS       # covers way round-robin × page parity for ways | 16


def transition_matrices(op: PageOpParams, ways: int, policy: str = "eager",
                        ) -> np.ndarray:
    """[PERIOD, N_STATE, N_STATE] float32 (max,+) step matrices."""
    assert MAX_WAYS % ways == 0, f"kernel path needs ways | {MAX_WAYS}, got {ways}"
    bus, rs = 0, N_STATE - 1
    mats = np.full((PERIOD, N_STATE, N_STATE), NEG, np.float32)
    for i in range(PERIOD):
        w = i % ways
        post = op.post_lo_us if (i // ways) % 2 == 0 else op.post_hi_us
        a = mats[i]
        chip = 1 + w
        if policy == "batched":
            if w == 0:
                a[bus, bus] = op.cmd_us + op.pre_us + op.slot_us
                a[rs, bus] = 0.0
            else:
                a[bus, bus] = op.slot_us
                a[bus, rs] = (w + 1) * op.cmd_us + op.pre_us + op.slot_us
                a[rs, rs] = 0.0
        else:  # eager
            a[bus, bus] = op.slot_us
            a[bus, chip] = op.cmd_us + op.pre_us + op.slot_us
            a[rs, rs] = 0.0
        # chip'_w = bus' + post  (same row as bus, shifted by post)
        for c in range(N_STATE):
            if a[bus, c] > NEG / 2:
                a[chip, c] = a[bus, c] + post
        for j in range(ways):
            if j != w:
                a[1 + j, 1 + j] = max(a[1 + j, 1 + j], 0.0)
        for j in range(ways, MAX_WAYS):
            a[1 + j, 1 + j] = 0.0
    return mats


def init_state() -> np.ndarray:
    """All resources free at t=0 (round_start included)."""
    return np.zeros((N_STATE,), np.float32)


def end_time_from_state(state: np.ndarray) -> np.ndarray:
    """Completion = max(bus, chip frees); exclude the round_start helper."""
    return state[..., :N_STATE - 1].max(axis=-1)
