"""Published experimental data from the paper (oracles for tests/benches).

Table 3 — single-channel SSDs, way-interleaving sweep (MB/s).
Table 4 — constant-capacity channel/way trade-off (MB/s).
Table 5 — controller energy per transferred byte (nJ/B), SLC designs.

Columns are (CONV, SYNC_ONLY, PROPOSED) throughout.
"""

from __future__ import annotations

# --- Table 3: {cell: {mode: {ways: (C, S, P)}}} ---------------------------
TABLE3 = {
    "slc": {
        "write": {
            1: (7.77, 8.38, 8.50),
            2: (15.22, 16.59, 17.52),
            4: (28.94, 31.90, 34.30),
            8: (39.78, 55.36, 63.00),
            16: (39.76, 60.44, 97.35),
        },
        "read": {
            1: (27.78, 36.66, 47.89),
            2: (42.78, 67.16, 70.47),
            4: (42.75, 67.13, 117.68),
            8: (42.72, 67.11, 117.64),
            16: (42.69, 67.11, 117.59),
        },
    },
    "mlc": {
        "write": {
            1: (4.43, 4.55, 4.65),
            2: (8.36, 8.85, 9.24),
            4: (15.24, 16.75, 18.13),
            8: (25.86, 29.72, 34.08),
            16: (32.45, 45.99, 57.23),
        },
        "read": {
            1: (26.04, 33.58, 42.69),
            2: (41.59, 60.41, 77.19),
            4: (41.55, 64.76, 101.61),
            8: (41.52, 64.75, 110.56),
            16: (41.50, 64.73, 110.52),
        },
    },
}

# --- Table 4: {cell: {mode: {(channels, ways): (C, S, P)}}} ----------------
# "max" in the paper = hit the SATA2 cap (300 MB/s); encoded as None.
TABLE4 = {
    "slc": {
        "write": {
            (1, 16): (39.76, 60.44, 97.35),
            (2, 8): (74.07, 101.99, 114.83),
            (4, 4): (103.76, 115.68, 123.52),
        },
        "read": {
            (1, 16): (42.69, 67.11, 117.59),
            (2, 8): (81.44, 126.70, 224.82),
            (4, 4): (155.35, 237.61, None),
        },
    },
    "mlc": {
        "write": {
            (1, 16): (32.45, 45.99, 57.23),
            (2, 8): (48.72, 56.83, 64.75),
            (4, 4): (57.46, 63.55, 68.49),
        },
        "read": {
            (1, 16): (41.50, 64.73, 110.52),
            (2, 8): (79.32, 122.48, 201.42),
            (4, 4): (150.94, 230.17, None),
        },
    },
}

# --- Table 5: SLC energy per byte, nJ/B: {mode: {ways: (C, S, P)}} ---------
TABLE5 = {
    "write": {
        1: (2.90, 5.01, 5.47),
        2: (1.48, 2.53, 2.65),
        4: (0.78, 1.32, 1.36),
        8: (0.57, 0.76, 0.74),
        16: (0.57, 0.69, 0.48),
    },
    "read": {
        1: (0.81, 1.15, 0.97),
        2: (0.53, 0.63, 0.66),
        4: (0.53, 0.63, 0.40),
        8: (0.53, 0.63, 0.40),
        16: (0.53, 0.63, 0.40),
    },
}

# Headline speedup ranges from the abstract / §6 (PROPOSED over CONV).
CLAIMS = {
    ("slc", "read"): (1.65, 2.76),
    ("slc", "write"): (1.09, 2.45),
    ("mlc", "read"): (1.64, 2.66),
    ("mlc", "write"): (1.05, 1.76),
}

INTERFACE_ORDER = ("conv", "sync_only", "proposed")
