"""Timing model of the conventional and proposed NAND flash interfaces.

Implements the closed-form timing analysis of the paper:

* Eq. (1):  t_D = alpha * t_P
* Eq. (2):  t_DLL = t_IOD_max - t_RWEBD_min + t_IOS
* Eq. (3)-(6): minimum clock period of the CONVentional asynchronous
  single-data-rate interface.
* Eq. (7)-(9): minimum clock period of the PROPOSED synchronous
  double-data-rate interface.

All times are expressed in **nanoseconds** in this module (the paper's
Table 2 unit).  The SSD-level simulator (`repro.core.sim`) works in
microseconds and converts via the derived per-interface cycle times.
"""

from __future__ import annotations

import dataclasses
import math

NS = 1.0
US = 1e3  # ns per us


@dataclasses.dataclass(frozen=True)
class BoardTimings:
    """Measured / datasheet timing parameters (paper Table 2, ns)."""

    t_OUT: float = 7.82   # controller FF -> NAND strobe pad (CONV only)
    t_IN: float = 1.65    # controller IO pad -> W/RFIFO (CONV only)
    t_S: float = 0.25     # setup time of W/RFIFO
    t_H: float = 0.02     # hold  time of W/RFIFO
    t_DIFF: float = 4.69  # DVS-vs-IO board arrival-time difference (PROPOSED)
    t_REA: float = 20.0   # RLAT -> controller IO pad (CONV only)
    t_BYTE: float = 12.0  # page register <-> W/RLAT transfer time


PAPER_BOARD = BoardTimings()


def t_d(alpha: float, t_p: float) -> float:
    """Eq. (1): the D_CON delay of CLK."""
    if not 0.0 <= alpha <= 0.5:
        raise ValueError(f"alpha must be in [0, 1/2], got {alpha}")
    return alpha * t_p


def t_dll(t_iod_max: float, t_rwebd_min: float, t_ios: float) -> float:
    """Eq. (2): delay inserted by the in-chip DLL to generate DVS."""
    return t_iod_max - t_rwebd_min + t_ios


def t_p_min_conventional(b: BoardTimings = PAPER_BOARD, alpha: float = 0.5) -> float:
    """Eq. (6): minimum clock period of the conventional interface.

    t_P,min = max{ (t_OUT + t_REA + t_IN + t_S) / (1 + alpha), t_BYTE }

    With the paper's Table 2 values and alpha = 1/2 this evaluates to
    19.81 ns (the paper then sets the clock to a round 50 MHz).
    """
    serial_path = (b.t_OUT + b.t_REA + b.t_IN + b.t_S) / (1.0 + alpha)
    return max(serial_path, b.t_BYTE)


def t_p_min_proposed(b: BoardTimings = PAPER_BOARD) -> float:
    """Eq. (9): minimum clock period of the proposed DDR interface.

    t_P,min = max{ (t_S + t_H + t_DIFF) * 2, t_BYTE }

    With Table 2 values: max{9.92, 12} = 12 ns -> 83 MHz.  The cycle is
    limited purely by the device-level t_BYTE, as §6 of the paper notes.
    """
    return max((b.t_S + b.t_H + b.t_DIFF) * 2.0, b.t_BYTE)


def t_p_min_proposed_io(t_ios: float, t_ioh: float, t_byte: float) -> float:
    """Eq. (8): alternative form using pad-level setup/hold constraints."""
    return max((t_ios + t_ioh) * 2.0, t_byte)


def max_frequency_mhz(t_p_min_ns: float, granularity_mhz: float = 1.0) -> float:
    """Round the implied maximum frequency down to a realizable clock.

    The paper turns 19.81 ns into 50 MHz and 12 ns into 83 MHz; i.e. it
    floors 1/t_P,min (50.47 -> 50, 83.33 -> 83) at 1 MHz granularity.
    """
    f = 1e3 / t_p_min_ns  # MHz
    return math.floor(f / granularity_mhz) * granularity_mhz


@dataclasses.dataclass(frozen=True)
class DerivedClocks:
    """Operating points derived exactly as in paper §5.2."""

    conv_t_p_ns: float
    conv_mhz: float
    prop_t_p_ns: float
    prop_mhz: float

    @property
    def conv_cycle_ns(self) -> float:
        return 1e3 / self.conv_mhz

    @property
    def prop_cycle_ns(self) -> float:
        return 1e3 / self.prop_mhz


def derive_paper_clocks(b: BoardTimings = PAPER_BOARD) -> DerivedClocks:
    tc = t_p_min_conventional(b)
    tp = t_p_min_proposed(b)
    return DerivedClocks(
        conv_t_p_ns=tc,
        conv_mhz=max_frequency_mhz(tc),
        prop_t_p_ns=tp,
        prop_mhz=max_frequency_mhz(tp),
    )
