"""``repro.api`` — the unified simulation surface (re-export of
``repro.core.api`` plus the types a query needs).

Quickstart::

    from repro.api import Simulator, SSDConfig, workload_trace

    cfg = SSDConfig(channels=4, ways=8)
    sim = Simulator.for_config(cfg)             # shared, jit-cached session
    res = sim.run(workload_trace("mixed", cfg, read_fraction=0.7),
                  objective="all")
    print(res.describe(), res.energy.nj_per_byte)

See DESIGN.md §2.5 for the request/response model, the engine registry
and the cache keying.
"""

from repro.core.api import (CacheInfo, CapabilityError, Engine, EngineCaps,
                            OBJECTIVES, Objective, Policy, SimRequest,
                            SimResult, Simulator, engine_capabilities,
                            get_engine, register_engine, registered_engines,
                            simulator_for, steady_bandwidth_mb_s,
                            steady_channel_bandwidth_mb_s,
                            sweep_steady_bandwidth_mb_s, sweep_tables)
from repro.core.energy import EnergyBreakdown
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.sim import PageOpParams, SSDConfig
from repro.core.trace import (OpClassTable, OpTrace, READ, WRITE,
                              op_class_table, workload_trace)

__all__ = [
    # the session API proper
    "CacheInfo", "CapabilityError", "Engine", "EngineCaps", "OBJECTIVES",
    "Objective", "Policy", "SimRequest", "SimResult", "Simulator",
    "engine_capabilities", "get_engine", "register_engine",
    "registered_engines", "simulator_for", "steady_bandwidth_mb_s",
    "steady_channel_bandwidth_mb_s", "sweep_steady_bandwidth_mb_s",
    "sweep_tables",
    # the types a request/result is made of
    "CellType", "EnergyBreakdown", "InterfaceKind", "OpClassTable",
    "OpTrace", "PageOpParams", "READ", "SSDConfig", "WRITE",
    "op_class_table", "workload_trace",
]
