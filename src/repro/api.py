"""``repro.api`` — the unified simulation surface (re-export of
``repro.core.api`` plus the types a query needs).

Quickstart::

    from repro.api import Simulator, SSDConfig, build_workload

    cfg = SSDConfig(channels=4, ways=8)
    sim = Simulator.for_config(cfg)             # shared, jit-cached session
    res = sim.run(build_workload("mixed", cfg, read_fraction=0.7),
                  objective="all")
    print(res.describe(), res.energy.nj_per_byte)

Latency under load (request-level workloads, DESIGN.md §2.6)::

    from repro.api import poisson_stream

    load = poisson_stream(512, mean_interarrival_us=40.0, seed=0)
    res = sim.run(load, sched_policy="least_loaded")   # dynamic dispatch
    print(res.p50_us, res.p99_us)

Reliability and tail latency (DESIGN.md §2.8)::

    from repro.api import FaultSpec

    worn = FaultSpec(wear=0.8, hedge_fraction=0.3, seed=7)
    res = sim.run(load, faults=worn)            # retries, remaps, hedges
    print(res.p99_9_us, res.n_remap_ops, res.retry_hist)

Aging and garbage collection (the FTL stage, DESIGN.md §2.10)::

    from repro.api import FTLSpec, overwrite_stream

    aged = sim.run(overwrite_stream(4096, footprint_pages=2048),
                   ftl=FTLSpec(overprovision=0.25, precondition=True))
    print(aged.waf, aged.mb_s, aged.fresh_mb_s)    # steady vs fresh

Aged design-space sweeps ride the compiled translation engine
(DESIGN.md §2.11) — one fused translate→lower→simulate fold per point,
vmapped across points (sharded over devices when there are several)::

    import dataclasses

    points = [FTLSpec(overprovision=op, gc_policy=g, precondition=True)
              for op in (0.12, 0.25, 0.5) for g in ("greedy", "lru")]
    ends = sim.sweep(None, overwrite_stream(4096, 2048), ftl=points)

See DESIGN.md §2.5 for the request/response model, the engine registry
and the cache keying; §2.6 for workloads and scheduling policies; §2.8
for the fault model and its determinism contract; §2.10 for the FTL
translation stage, WAF accounting and the GC policy registry; §2.11
for the compiled (lax.scan) translation engine behind the fault-free
default path, the fused sweep and the streaming chunked variant.
"""

from repro.core.api import (CacheInfo, CapabilityError, Engine, EngineCaps,
                            OBJECTIVES, Objective, Policy, SimRequest,
                            SimResult, Simulator, engine_capabilities,
                            get_engine, register_engine, registered_engines,
                            simulator_for, steady_bandwidth_mb_s,
                            steady_channel_bandwidth_mb_s,
                            sweep_steady_bandwidth_mb_s, sweep_tables)
from repro.core.energy import EnergyBreakdown
from repro.core.faults import FaultSampler, FaultSpec
from repro.core.ftl import (FTLSpec, FTLStats, FTLTranslation, FTL_LABELS,
                            GC_POLICIES, analytic_waf, ftl_op_class_table,
                            precondition_lpns, select_victim)
from repro.core.ftl import translate as ftl_translate
from repro.core.ftl_scan import translate_scan as ftl_translate_scan
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.sched import (DYNAMIC_POLICIES, LoweredWorkload,
                              SCHED_POLICIES, STATIC_POLICIES, apply_faults,
                              lower_ops, lower_ops_chunk, lower_static,
                              policy_is_dynamic)
from repro.core.sim import PageOpParams, SSDConfig
from repro.core.trace import (OpClassTable, OpTrace, READ, WRITE,
                              op_class_table, workload_trace)
from repro.core.workload import (RequestStream, aging_stream, build_workload,
                                 bursty_stream, checkpoint_requests,
                                 closed_loop_stream, datapipe_requests,
                                 iter_request_chunks, kvoffload_requests,
                                 multi_tenant, overwrite_stream,
                                 poisson_stream, request_lpns, with_hedges)

__all__ = [
    # the session API proper
    "CacheInfo", "CapabilityError", "Engine", "EngineCaps", "OBJECTIVES",
    "Objective", "Policy", "SimRequest", "SimResult", "Simulator",
    "engine_capabilities", "get_engine", "register_engine",
    "registered_engines", "simulator_for", "steady_bandwidth_mb_s",
    "steady_channel_bandwidth_mb_s", "sweep_steady_bandwidth_mb_s",
    "sweep_tables",
    # the request-level workload + scheduler layer (DESIGN.md §2.6)
    "DYNAMIC_POLICIES", "LoweredWorkload", "RequestStream",
    "SCHED_POLICIES", "STATIC_POLICIES", "build_workload", "bursty_stream",
    "checkpoint_requests", "closed_loop_stream", "datapipe_requests",
    "iter_request_chunks", "kvoffload_requests", "lower_static",
    "multi_tenant", "policy_is_dynamic", "poisson_stream", "aging_stream",
    "overwrite_stream", "request_lpns",
    # the reliability layer (DESIGN.md §2.8)
    "FaultSampler", "FaultSpec", "apply_faults", "with_hedges",
    # the FTL stage (DESIGN.md §2.10-§2.11)
    "FTLSpec", "FTLStats", "FTLTranslation", "FTL_LABELS", "GC_POLICIES",
    "analytic_waf", "ftl_op_class_table", "ftl_translate",
    "ftl_translate_scan", "lower_ops", "lower_ops_chunk",
    "precondition_lpns", "select_victim",
    # the types a request/result is made of
    "CellType", "EnergyBreakdown", "InterfaceKind", "OpClassTable",
    "OpTrace", "PageOpParams", "READ", "SSDConfig", "WRITE",
    "op_class_table", "workload_trace",
]
