import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input-shape × mesh) cell against
512 placeholder host devices — the first two lines above MUST precede any
other import (JAX locks the device count at first initialisation).

For each cell we record to ``benchmarks/results/dryrun/<cell>.json``:

* ``memory_analysis()``  — per-device argument/output/temp/peak bytes
  (proves the cell fits the 16 GiB v5e HBM);
* ``cost_analysis()``    — HLO FLOPs / bytes accessed;
* collective traffic     — parsed from the optimized per-device HLO
  (``repro.launch.hlo_analysis``), loop trip counts included;
* model FLOPs (6·N·D train / 2·N·D prefill / 2·N·B decode, MoE-active-
  aware) for the usefulness ratio in EXPERIMENTS.md §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--force]
"""

import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.launch.steps import lower_cell
from repro.models.transformer import ModelConfig, init_params
from repro.train.optimizer import OptConfig

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def opt_config_for(cfg: ModelConfig) -> OptConfig:
    # 8-bit moments let the 400B config fit one v5e-256 pod (DESIGN.md §6).
    override = os.environ.get("REPRO_MOMENT_DTYPE")
    if cfg.fsdp_units:
        return OptConfig(moment_dtype=override or "int8")
    return OptConfig(moment_dtype=override or "f32")


def active_param_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total_params, active_non_embedding_params) from abstract shapes."""
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    total = active = 0
    moe_frac = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe else 1.0

    def visit(key_path, leaf):
        nonlocal total, active
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in key_path)
        total += leaf.size
        if path.startswith("embed/"):
            return
        if "/ffn/" in path and re.search(r"/ffn/(wi|wg|wo)$", path) and cfg.moe \
                and leaf.ndim == 4:  # stacked [U, E, ...] expert weights
            active += int(leaf.size * moe_frac)
            return
        active += leaf.size

    jax.tree_util.tree_map_with_path(visit, shapes)
    return int(total), int(active)


def model_flops(cfg: ModelConfig, kind: str, seq: int, batch: int) -> float:
    _, n_active = active_param_count(cfg)
    if kind == "train":
        return 6.0 * n_active * seq * batch
    if kind == "prefill":
        return 2.0 * n_active * seq * batch
    return 2.0 * n_active * batch  # decode: one token per sequence


def _mem_dict(mem) -> dict:
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        try:
            v = getattr(mem, name)
            out[name] = int(v() if callable(v) else v)
        except Exception:
            pass
    return out


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             outdir: pathlib.Path, force: bool = False,
             grad_accum: int = 1, remat: str | None = None,
             moe_mode: str | None = None, tag: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch_name}__{shape_name}__{mesh_name}{tag}"
    outfile = outdir / f"{cell_id}.json"
    if outfile.exists() and not force:
        return json.loads(outfile.read_text())

    arch = get_arch(arch_name)
    cfg, shape = arch.config, arch.shape(shape_name)
    if remat is not None or moe_mode is not None:
        import dataclasses as _dc
        kw = {}
        if remat is not None:
            kw["remat"] = remat
        if moe_mode is not None:
            kw["moe_shard_mode"] = moe_mode
        cfg = _dc.replace(cfg, **kw)
    rec: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind, "seq_len": shape.seq_len,
                 "global_batch": shape.global_batch}
    if shape.skip:
        rec.update(status="skipped", reason=shape.skip)
        outfile.write_text(json.dumps(rec, indent=1))
        return rec

    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh_chip_count(mesh)
        total, active = active_param_count(cfg)
        rec.update(chips=chips, params_total=total, params_active=active)

        t0 = time.time()
        lowered, _ = lower_cell(cfg, shape, mesh, ocfg=opt_config_for(cfg),
                                grad_accum=grad_accum)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        cost = compiled.cost_analysis() or {}
        mem = _mem_dict(compiled.memory_analysis())
        text = compiled.as_text()
        stats = hlo_analysis.analyze_module(text)

        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            # loop-aware per-device terms (repro.launch.hlo_analysis)
            dot_flops_per_device=stats.dot_flops,
            traffic_bytes_per_device=stats.traffic_bytes,
            collective_bytes_per_device=stats.collective_bytes,
            collective_bytes_by_kind=stats.bytes_by_kind,
            collective_counts=stats.count_by_kind,
            loop_trip_counts=stats.trip_counts,
            # raw XLA numbers for cross-checking (while bodies counted once!)
            xla_flops_per_device=float(cost.get("flops", -1.0)),
            xla_bytes_accessed_per_device=float(cost.get("bytes accessed", -1.0)),
            memory=mem,
            model_flops_global=model_flops(cfg, shape.kind, shape.seq_len,
                                           shape.global_batch),
            hlo_bytes=len(text),
        )
    except Exception as e:  # a failed cell is a bug — record it loudly
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    outfile.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--outdir", default=str(RESULTS_DIR))
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default=None, choices=(None, "none", "full", "dots"))
    ap.add_argument("--moe-mode", default=None)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in get_arch(a).shapes:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    n_ok = n_skip = n_err = 0
    for arch_name, shape_name in cells:
        for multi in meshes:
            rec = run_cell(arch_name, shape_name, multi, outdir, force=args.force,
                           grad_accum=args.grad_accum, remat=args.remat,
                           moe_mode=args.moe_mode, tag=args.tag)
            status = rec["status"]
            n_ok += status == "ok"
            n_skip += status == "skipped"
            n_err += status == "error"
            msg = (f"[{status:7s}] {arch_name:28s} {shape_name:12s} "
                   f"{'multi ' if multi else 'single'}")
            if status == "ok":
                gib = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
                ratio = (rec["model_flops_global"] /
                         max(rec["dot_flops_per_device"] * rec["chips"], 1.0))
                msg += (f" compile={rec['compile_s']:7.1f}s temp={gib:6.2f}GiB "
                        f"coll={rec['collective_bytes_per_device']/2**30:7.2f}GiB "
                        f"useful={ratio:5.2f}")
            elif status == "error":
                msg += " " + rec["error"][:120]
            print(msg, flush=True)
    print(f"dry-run: ok={n_ok} skipped={n_skip} error={n_err}", flush=True)
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
