"""Roofline-term extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` is insufficient for scanned models: XLA
counts a ``while`` body **once**, so a 24-unit ``lax.scan`` under-reports
FLOPs/bytes/collectives by 24×.  This module parses the per-device HLO
module into computations + a call graph, recovers loop trip counts from
the loop-condition comparison constants, and accumulates:

* ``dot_flops``        — 2·M·N·K per dot (batch dims included), loop-
  multiplied, fusion-internal dots included with their caller's
  multiplier;
* ``traffic_bytes``    — Σ (operand + result bytes) over *memory-level*
  ops (fusions, dots, copies, gathers/scatters, DUS, collectives) in
  non-fused computations — an HBM-traffic estimate under the "fusions
  touch memory once" model;
* ``collective_bytes`` — Σ operand bytes per collective kind.

All values are per-device (the module is the per-device SPMD program).
"""

from __future__ import annotations

import collections
import dataclasses
import re

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

# memory-level opcodes counted in traffic_bytes.  Deliberately restricted
# to ops that stay memory-level after TPU-grade fusion (raw elementwise /
# broadcast / reshape ops at the CPU top level would be fused on TPU and
# would otherwise inflate the estimate severalfold).
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES} | {"all-reduce-done"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_CONST_RE = re.compile(r"[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")


def _parse_instr(ln: str) -> tuple[str, str, str] | None:
    """(name, type_str, opcode) from an instruction line, else None."""
    m = _ASSIGN_RE.match(ln)
    if not m:
        return None
    name = m.group(1)
    rest = ln[m.end():]
    if rest.startswith("("):          # tuple type: balanced parens
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = rest[:i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    mo = _OPCODE_RE.match(rest)
    if not mo:
        return None
    return name, type_str, mo.group(1)


def _shape_numel_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool = False
    instrs: dict[str, Instr] = dataclasses.field(default_factory=dict)
    consts: list[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ModuleStats:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: float
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]
    trip_counts: dict[str, int]

    # kept for backwards compat with earlier records
    @property
    def total_bytes(self) -> float:
        return self.collective_bytes


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    entry_name = ""
    for ln in text.splitlines():
        if ln and not ln[0].isspace():       # computation headers at column 0
            hdr = _COMP_HDR_RE.match(ln)
            if hdr and ln.rstrip().endswith("{"):
                current = Computation(hdr.group(2), is_entry=bool(hdr.group(1)))
                comps[current.name] = current
                if current.is_entry:
                    entry_name = current.name
                continue
        if current is None:
            continue
        parsed = _parse_instr(ln)
        if parsed:
            name, type_str, opcode = parsed
            current.instrs[name] = Instr(name, type_str.strip(), opcode, ln)
        for c in _CONST_RE.findall(ln):
            current.consts.append(int(c))
    return comps, entry_name


_CALL_ATTRS = (
    ("body", True), ("calls", False), ("to_apply", False),
    ("branch_computations", False), ("condition", None),
)


def _call_edges(comps: dict[str, Computation]):
    """Yields (caller, callee, trip, fused) per call-graph edge."""
    for comp in comps.values():
        for ins in comp.instrs.values():
            ln = ins.line
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                trip = 1
                if mc and mc.group(1) in comps:
                    big = [c for c in comps[mc.group(1)].consts if c > 1]
                    trip = max(big) if big else 1
                if mb:
                    yield comp.name, mb.group(1), trip, False
                if mc:
                    yield comp.name, mc.group(1), trip, True  # cond: tiny, fused-ish
            elif ins.opcode in ("fusion", "reduce", "sort", "map", "scatter",
                                "reduce-window", "select-and-scatter", "call",
                                "all-reduce", "all-reduce-start", "reduce-scatter"):
                for attr in ("calls", "to_apply"):
                    m = re.search(attr + r"=%?([\w\.\-]+)", ln)
                    if m:
                        fused = ins.opcode != "call"
                        yield comp.name, m.group(1), 1, fused
            elif ins.opcode == "conditional":
                for m in re.finditer(r"%?([\w\.\-]+)", ln.split("branch_computations", 1)[-1]):
                    if m.group(1) in comps:
                        yield comp.name, m.group(1), 1, False


def _first_operand(ins: Instr) -> str:
    """Text of operand 0 (up to the first top-level comma / close paren)."""
    args = ins.line.split(ins.opcode + "(", 1)[-1]
    depth, buf = 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                break
            depth -= 1
        elif ch == "," and depth == 0:
            break
        buf.append(ch)
    return "".join(buf)


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = 1
    for d in _shape_dims(ins.type_str):
        out_elems *= d
    mk = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    if not mk:
        return 2.0 * out_elems  # degenerate
    # lhs dims: prefer the inline operand type (post-optimization HLO
    # prints `dot(f32[64,64]{1,0} %name, ...)`); fall back to name lookup
    arg0 = _first_operand(ins)
    lhs_dims = _shape_dims(arg0)
    if not lhs_dims:
        m0 = re.match(r"\s*%?([\w\.\-]+)", arg0)
        if m0 and m0.group(1) in comp.instrs:
            lhs_dims = _shape_dims(comp.instrs[m0.group(1)].type_str)
    contract = 1
    for idx in mk.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _operand_list(comp: Computation, ins: Instr) -> list[int]:
    args = ins.line.split(ins.opcode + "(", 1)[-1]
    depth, buf = 1, []
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    names = re.findall(r"%?([\w\.\-]+)", "".join(buf))
    return [_shape_numel_bytes(comp.instrs[n].type_str)
            for n in names if n in comp.instrs]


def _operand_bytes(comp: Computation, ins: Instr) -> int:
    return sum(_operand_list(comp, ins))


def _op_traffic_bytes(comp: Computation, ins: Instr) -> int:
    """HBM traffic estimate for one op.  Slicing ops move only the slice,
    not the buffer they index into (a dynamic-slice inside a 10k-trip scan
    must not be charged the whole carried buffer every iteration)."""
    ops = _operand_list(comp, ins)
    res = _shape_numel_bytes(ins.type_str)
    if ins.opcode == "dynamic-slice":
        return 2 * res                       # read slice + write result
    if ins.opcode == "dynamic-update-slice":
        upd = sum(ops[1:])                   # update (+ tiny indices)
        return 2 * upd                       # read-modify-write of the region
    if ins.opcode == "gather":
        return sum(ops[1:]) + 2 * res        # indices + gathered rows + result
    if ins.opcode == "scatter":
        return sum(ops[1:]) * 2              # indices + updates r/w
    return sum(ops) + res


def analyze_module(text: str) -> ModuleStats:
    comps, entry = parse_module(text)
    edges = list(_call_edges(comps))

    # accumulate multipliers from the entry down the call DAG (Kahn order
    # so multi-caller computations see every contribution exactly once)
    children = collections.defaultdict(list)
    indeg = collections.Counter()
    for caller, callee, trip, fz in edges:
        children[caller].append((callee, trip, fz))
        indeg[callee] += 1
    mult: dict[str, float] = collections.defaultdict(float)
    fused: dict[str, bool] = {}
    if entry:
        mult[entry] = 1.0
        fused[entry] = False
    ready = [c for c in comps if indeg[c] == 0]
    topo = []
    while ready:
        cur = ready.pop()
        topo.append(cur)
        for callee, _t, _f in children.get(cur, ()):
            indeg[callee] -= 1
            if indeg[callee] == 0:
                ready.append(callee)
    for cur in topo:
        for callee, trip, fz in children.get(cur, ()):
            mult[callee] += mult[cur] * trip
            callee_fused = fused.get(cur, True) or fz
            fused[callee] = fused.get(callee, True) and callee_fused

    dot_flops = 0.0
    traffic = 0.0
    coll_bytes: dict[str, float] = collections.defaultdict(float)
    coll_counts: dict[str, int] = collections.defaultdict(int)
    trips = {callee: trip for _, callee, trip, _ in edges if trip > 1}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs.values():
            if ins.opcode in ("dot", "convolution"):
                dot_flops += _dot_flops(comp, ins) * m
            kind = ins.opcode.removesuffix("-start")
            if kind in COLLECTIVES:
                ob = _operand_bytes(comp, ins) or _shape_numel_bytes(ins.type_str)
                coll_bytes[kind] += ob * m
                coll_counts[kind] += 1
            if not fused.get(comp.name, True) and ins.opcode in _TRAFFIC_OPS:
                traffic += _op_traffic_bytes(comp, ins) * m

    return ModuleStats(
        dot_flops=dot_flops,
        traffic_bytes=traffic,
        collective_bytes=sum(coll_bytes.values()),
        bytes_by_kind=dict(coll_bytes),
        count_by_kind=dict(coll_counts),
        trip_counts=trips,
    )


def analyze_collectives(text: str):  # backwards-compatible alias
    return analyze_module(text)
