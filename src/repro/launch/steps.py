"""jit-able train / prefill / decode steps with full sharding specs.

These are the exact computations the dry-run lowers and the trainer /
serving engine execute: ``train_step`` is forward + backward + AdamW
update (donated state), ``serve_decode`` one token against the cache,
``serve_prefill`` the batched prompt pass.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeSpec, input_specs
from repro.distributed import ctx
from repro.distributed import partitioning as part
from repro.models.transformer import (ModelConfig, decode_step, init_cache,
                                      init_params, loss_fn, prefill)
from repro.train.optimizer import OptConfig, adamw_init, adamw_update
from repro.train.schedules import constant

Params = Any


# ---------------------------------------------------------------------------
# state construction
# ---------------------------------------------------------------------------


def init_train_state(cfg: ModelConfig, ocfg: OptConfig, key: jax.Array) -> Params:
    params = init_params(cfg, key)
    return {"params": params, "opt": adamw_init(ocfg, params)}


def abstract_train_state(cfg: ModelConfig, ocfg: OptConfig) -> Params:
    return jax.eval_shape(
        lambda: init_train_state(cfg, ocfg, jax.random.PRNGKey(0)))


def _flat_with_paths(tree) -> dict[str, Any]:
    out = {}

    def record(key_path, leaf):
        out[part._path_str(key_path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(
        record, tree, is_leaf=lambda x: isinstance(x, P))
    return out


def train_state_pspecs(cfg: ModelConfig, ocfg: OptConfig, mesh, state_shape: Params,
                       *, zero1: bool = True) -> Params:
    """PartitionSpecs for the {'params', 'opt'} train state."""
    pspecs = part.param_pspecs(cfg, mesh, state_shape["params"])
    zdiv = part.axis_size(mesh, part.FSDP_AXIS)
    flat_specs = _flat_with_paths(pspecs)
    flat_shapes = {k: v.shape for k, v in _flat_with_paths(state_shape["params"]).items()}

    def opt_spec(key_path, leaf):
        path = part._path_str(key_path)
        if path == "count":
            return P()
        head, rest = path.split("/", 1)
        suffix = None
        if rest not in flat_specs and (rest.endswith("/q") or rest.endswith("/scale")):
            rest, suffix = rest.rsplit("/", 1)  # int8 moment {'q','scale'} leaves
        base = flat_specs[rest]
        parts = list(base) + [None] * (len(flat_shapes[rest]) - len(base))
        if suffix == "scale":
            parts[-1] = None  # scale dim is size-1
        spec = P(*parts)
        return part.zero1_spec(spec, leaf.shape, zdiv) if zero1 else spec

    opt_specs = jax.tree_util.tree_map_with_path(opt_spec, state_shape["opt"])
    return {"params": pspecs, "opt": opt_specs}


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, ocfg: OptConfig,
                    schedule: Callable[[jax.Array], jax.Array] | None = None,
                    grad_accum: int = 1):
    """forward+backward (+ microbatch accumulation) + AdamW update.

    With ``grad_accum > 1`` the global batch is split into microbatches
    scanned sequentially; gradients accumulate in fp32.  Under pjit the
    per-microbatch gradient reduce-scatter overlaps the next
    microbatch's backward — the standard comm/compute overlap trick
    (and the collective-level analogue of the paper's decoupled
    control/data timing, DESIGN.md §2.1).
    """
    schedule = schedule or constant(3e-4)

    def train_step(state: Params, batch: dict[str, jax.Array]):
        def lossf(params, mb):
            return loss_fn(cfg, params, mb)

        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(lossf, has_aux=True)(
                state["params"], batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def mb_step(acc, mb):
                (l, m), g = jax.value_and_grad(lossf, has_aux=True)(
                    state["params"], mb)
                gacc, lacc, ceacc = acc
                gacc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l, ceacc + m["ce"]), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  state["params"])
            (gsum, lsum, cesum), _ = jax.lax.scan(
                mb_step, (zero_g, jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {"ce": cesum / grad_accum,
                       "moe_aux": jnp.zeros((), jnp.float32),
                       "tokens": jnp.asarray(batch["labels"].size, jnp.int32)}

        new_params, new_opt, info = adamw_update(
            ocfg, schedule, state["params"], grads, state["opt"])
        metrics = dict(metrics)
        metrics.update(info)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_serve_decode(cfg: ModelConfig):
    def serve_decode(params, cache, inputs, index, position_ids=None):
        return decode_step(cfg, params, cache, inputs, index, position_ids)
    return serve_decode


def make_serve_prefill(cfg: ModelConfig, max_seq: int):
    def serve_prefill(params, inputs, position_ids=None):
        return prefill(cfg, params, inputs, max_seq=max_seq, position_ids=position_ids)
    return serve_prefill


# ---------------------------------------------------------------------------
# jit assembly per (arch × shape × mesh) cell — used by dry-run & trainer
# ---------------------------------------------------------------------------


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, *,
               ocfg: OptConfig | None = None, zero1: bool = True,
               grad_accum: int = 1):
    """Lower one (arch × shape) cell on ``mesh``. Returns (lowered, meta)."""
    ocfg = ocfg or OptConfig()
    specs = input_specs(cfg, shape)
    ns = functools.partial(part.shardings, mesh)

    rules = part.activation_rules(cfg, mesh, shape.global_batch)
    if shape.kind == "train":
        state_shape = abstract_train_state(cfg, ocfg)
        state_specs = train_state_pspecs(cfg, ocfg, mesh, state_shape, zero1=zero1)
        batch_specs = part.batch_pspecs(cfg, mesh, specs["batch"])
        metric_specs = {"ce": P(), "moe_aux": P(), "tokens": P(),
                        "lr": P(), "grad_norm": P(), "loss": P()}
        step = make_train_step(cfg, ocfg, grad_accum=grad_accum)
        jitted = jax.jit(step,
                         in_shardings=(ns(state_specs), ns(batch_specs)),
                         out_shardings=(ns(state_specs), ns(metric_specs)),
                         donate_argnums=(0,))
        with ctx.activation_sharding(mesh, rules):
            lowered = jitted.lower(state_shape, specs["batch"])
        return lowered, {"state_shape": state_shape, "state_specs": state_specs}

    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    param_specs = part.param_pspecs(cfg, mesh, params_shape)

    if shape.kind == "prefill":
        step = make_serve_prefill(cfg, shape.seq_len)
        in_specs = [ns(param_specs),
                    ns(part.batch_pspecs(
                        cfg, mesh, {"inputs": specs["inputs"]}))["inputs"]]
        args = [params_shape, specs["inputs"]]
        if "position_ids" in specs:
            in_specs.append(ns(part.batch_pspecs(
                cfg, mesh,
                {"position_ids": specs["position_ids"]}))["position_ids"])
            args.append(specs["position_ids"])
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        cache_specs = part.cache_pspecs(cfg, mesh, cache_shape)
        blogit = part.batch_axes(mesh, shape.global_batch)
        out_specs = (NamedSharding(mesh, P(blogit, None, "model")), ns(cache_specs))
        jitted = jax.jit(step, in_shardings=tuple(in_specs), out_shardings=out_specs)
        with ctx.activation_sharding(mesh, rules):
            lowered = jitted.lower(*args)
        return lowered, {}

    # decode
    cache_shape = specs["cache"]
    cache_specs = part.cache_pspecs(cfg, mesh, cache_shape)
    step = make_serve_decode(cfg)
    binp = part.batch_axes(mesh, shape.global_batch)
    inp_spec = NamedSharding(
        mesh, P(binp, None, None) if specs["inputs"].ndim == 3 else P(binp, None))
    in_specs = [ns(param_specs), ns(cache_specs), inp_spec,
                NamedSharding(mesh, P())]
    args = [params_shape, cache_shape, specs["inputs"], specs["index"]]
    if "position_ids" in specs:
        in_specs.append(NamedSharding(mesh, P(None, binp, None)))
        args.append(specs["position_ids"])
    out_specs = (NamedSharding(mesh, P(binp, None, "model")), ns(cache_specs))
    jitted = jax.jit(step, in_shardings=tuple(in_specs), out_shardings=out_specs,
                     donate_argnums=(1,))
    with ctx.activation_sharding(mesh, rules):
        lowered = jitted.lower(*args)
    return lowered, {}
