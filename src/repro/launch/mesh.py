"""Production mesh definitions (TPU v5e-256 pods).

``make_production_mesh`` is a function (not a module constant) so that
importing this module never touches JAX device state — required because
the dry-run must set ``XLA_FLAGS=--xla_force_host_platform_device_count``
before the first JAX initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Development mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_points_mesh() -> jax.sharding.Mesh | None:
    """1-D ``("points",)`` mesh over every device — the design-point /
    batch-row sharding axis of the simulator sweeps (DESIGN.md §2.7).
    Returns None with a single device so the sweep entry points fall
    back to their plain vmap path instead of paying shard_map overhead
    for nothing.  A function, like the meshes above, so importing never
    touches JAX device state (``--xla_force_host_platform_device_count``
    must win the race)."""
    n = len(jax.devices())
    if n < 2:
        return None
    return jax.make_mesh((n,), ("points",))


def mesh_chip_count(mesh: jax.sharding.Mesh) -> int:
    return int(mesh.devices.size)
