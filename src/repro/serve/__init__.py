from repro.serve.engine import GenerationResult, ServingEngine  # noqa: F401
from repro.serve.sampler import SamplerConfig, sample  # noqa: F401
