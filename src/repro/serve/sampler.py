"""Token samplers: greedy / temperature / top-k."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0     # 0 = greedy
    top_k: int = 0               # 0 = full softmax


def sample(logits: jax.Array, key: jax.Array, cfg: SamplerConfig) -> jax.Array:
    """logits: [B, V] fp32 -> tokens [B] int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits >= kth, logits, -1e30)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
