"""Batched serving engine: prefill + decode with a persistent KV cache.

Wave-batched execution: requests are grouped into aligned waves (one
shared position counter per wave — matching the production cells, where
``decode_32k`` runs 128 aligned streams).  The decode step is jit'd once
per (batch, cache-length) bucket; prompts are left-padded into the
bucket so a wave admits mixed prompt lengths (per-row validity comes
from the cache's position array).

KV paging for long contexts is *planned* (not executed on CPU) by the
SSD tier model — see ``repro.storage.kvoffload``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import ModelConfig, decode_step, prefill
from repro.serve.sampler import SamplerConfig, sample


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # [B, n_new]
    prefill_logits: np.ndarray   # [B, vocab]
    steps: int


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int,
                 sampler: SamplerConfig | None = None):
        self.cfg, self.params, self.max_seq = cfg, params, max_seq
        self.sampler = sampler or SamplerConfig()
        self._prefill = jax.jit(
            lambda p, x: prefill(cfg, p, x, max_seq=max_seq))
        self._decode = jax.jit(
            lambda p, c, x, i: decode_step(cfg, p, c, x, i))

    def _pad_prompts(self, prompts: Sequence[Sequence[int]]) -> np.ndarray:
        width = max(len(p) for p in prompts)
        out = np.zeros((len(prompts), width), np.int32)
        for r, p in enumerate(prompts):
            out[r, width - len(p):] = p        # left-pad (aligned wave)
        return out

    def generate(self, prompts: Sequence[Sequence[int]], n_new: int,
                 seed: int = 0) -> GenerationResult:
        """Greedy/temperature generation for one aligned wave."""
        toks = self._pad_prompts(prompts)
        b, s = toks.shape
        assert s + n_new <= self.max_seq, (s, n_new, self.max_seq)
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        key = jax.random.PRNGKey(seed)
        out = []
        last = sample(logits[:, -1], key, self.sampler)
        out.append(np.asarray(last))
        for i in range(n_new - 1):
            key, sub = jax.random.split(key)
            step_logits, cache = self._decode(
                self.params, cache, last[:, None], jnp.asarray(s + i, jnp.int32))
            last = sample(step_logits[:, -1], sub, self.sampler)
            out.append(np.asarray(last))
        return GenerationResult(
            tokens=np.stack(out, axis=1),
            prefill_logits=np.asarray(logits[:, -1]),
            steps=n_new)

    def score(self, tokens: np.ndarray) -> np.ndarray:
        """Log-prob of each next token under the model (batch scoring)."""
        from repro.models.transformer import forward
        logits, _ = forward(self.cfg, self.params, jnp.asarray(tokens),
                            mode="eval")
        logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        gold = jnp.take_along_axis(logp, jnp.asarray(tokens)[:, 1:, None],
                                   axis=-1)[..., 0]
        return np.asarray(gold)
