"""Rotary position embeddings: classic RoPE and Qwen2-VL M-RoPE.

M-RoPE (multimodal RoPE, arXiv:2409.12191) splits the head dimension into
``sections`` (temporal / height / width); each section consumes a different
row of a ``[3, B, S]`` position-id tensor.  Text tokens carry identical
(t, h, w) ids, so M-RoPE degenerates to RoPE for pure-text inputs — the
property tests assert this.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2], float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _expand(a: jax.Array, ndim: int) -> jax.Array:
    """Insert singleton head axes: [B, S, D/2] -> [B, S, 1..., D/2]."""
    return a.reshape(a.shape[:2] + (1,) * (ndim - 3) + a.shape[-1:])


def apply_rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """x: [B, S, ..., D] (any head axes); positions: [B, S] int32."""
    freqs = rope_freqs(x.shape[-1], theta)  # [D/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, D/2]
    cos = _expand(jnp.cos(angles), x.ndim)
    sin = _expand(jnp.sin(angles), x.ndim)
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jax.Array, position_ids: jax.Array, sections: tuple[int, int, int],
                *, theta: float = 10000.0) -> jax.Array:
    """M-RoPE. x: [B, S, H, D]; position_ids: [3, B, S] (t, h, w).

    ``sections`` gives the number of *frequency pairs* per modality section
    (sum == D // 2), mirroring HF's ``mrope_section``.
    """
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_freqs(x.shape[-1], theta)  # [D/2]
    # angles per modality: [3, B, S, D/2]
    angles = position_ids.astype(jnp.float32)[..., None] * freqs
    # pick section s for frequency slots belonging to that section
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d_half
    )  # [D/2]
    angles = jnp.take_along_axis(
        jnp.moveaxis(angles, 0, -1),  # [B, S, D/2, 3]
        sec_id[None, None, :, None],
        axis=-1,
    )[..., 0]  # [B, S, D/2]
    cos = _expand(jnp.cos(angles), x.ndim)
    sin = _expand(jnp.sin(angles), x.ndim)
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """Degenerate (t == h == w) M-RoPE ids for pure-text tokens: [3, B, S]."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
