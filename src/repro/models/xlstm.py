"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

(arXiv:2405.04517.)  Both cells use the paper's exp-gate stabilisation
(running max ``m``).  The mLSTM (matrix memory C ∈ R^{Dk×Dv}) is computed
in a **chunkwise-parallel form**: intra-chunk attention-like scores with
cumulative log-decay, inter-chunk linear recurrence on the (C, n, m)
state — the same decomposition the Pallas kernel (``repro.kernels.mlstm``)
tiles for VMEM.  The sLSTM has a true hidden-state feedback (block-diagonal
per-head recurrent matrices) and is inherently sequential: ``lax.scan``
over time.

mLSTM block:   x ─→ up(×2) ─→ conv4 ─→ silu ─→ (q,k) ; u ─→ v ; gates(u)
               h = mLSTM(q,k,v,i,f) ─→ per-head RMSNorm ─⊙ silu(gate) ─→ down
sLSTM block:   conv4/silu feeds (i,f); z,o from x; post GN + GeGLU(4/3) FF.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init
from repro.models.rglru import causal_conv, causal_conv_step, _blockdiag


@dataclasses.dataclass(frozen=True)
class MLSTMSpec:
    d_inner: int
    n_heads: int
    conv_width: int = 4
    chunk: int = 256

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


@dataclasses.dataclass(frozen=True)
class SLSTMSpec:
    d: int
    n_heads: int
    conv_width: int = 4
    d_ff: int = 0  # gated FF width after the cell (0 = none)

    @property
    def head_dim(self) -> int:
        return self.d // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel
# ---------------------------------------------------------------------------


def mlstm_chunkwise(q, k, v, i_raw, f_raw, *, chunk: int, initial=None):
    """q,k,v: [B, S, H, D]; i_raw,f_raw: [B, S, H] (pre-activation gates).

    Returns (h [B, S, H, D], final_state (C, n, m)).  fp32 internally.
    """
    b, s, h, d = q.shape
    scale = 1.0 / math.sqrt(d)
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    logi = i_raw.astype(jnp.float32)

    pad = (-s) % chunk
    if pad:
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zpad) for a in (q, k, v))
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))          # f=1: keeps state
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
    nc = q.shape[1] // chunk
    L = chunk

    def to_chunks(a, feat):
        a = a.reshape((b, nc, L, h) + ((d,) if feat else ()))
        return jnp.moveaxis(a, 1, 0)  # [NC, B, L, H, ...]

    qc, kc, vc = to_chunks(q, True), to_chunks(k, True), to_chunks(v, True)
    lfc, lic = to_chunks(logf, False), to_chunks(logi, False)

    if initial is None:
        C0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = initial

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, xs):
        C, n, m = carry
        qx, kx, vx, lf, li = xs  # [B, L, H(, D)]
        bcum = jnp.cumsum(lf, axis=1)                     # [B, L, H]
        g = bcum[:, -1]                                   # [B, H]
        # log weights: intra (i attends j<=i) and inter (state)
        intra = (bcum[:, :, None] - bcum[:, None, :] + li[:, None, :, :])  # [B,L,L,H]
        intra = jnp.where(causal[None, :, :, None], intra, -1e30)
        m_intra = jnp.max(intra, axis=2)                  # [B, L, H]
        m_inter = m[:, None] + bcum                       # [B, L, H]
        m_i = jnp.maximum(m_intra, m_inter)
        A = jnp.exp(intra - m_i[:, :, None, :])           # [B, L, L, H]
        rho = jnp.exp(m_inter - m_i)                      # [B, L, H]

        s_qk = jnp.einsum("blhd,bjhd->bljh", qx, kx)      # [B, L, L, H]
        num = (
            jnp.einsum("bljh,bjhd->blhd", A * s_qk, vx)
            + rho[..., None] * jnp.einsum("blhd,bhde->blhe", qx, C)
        )
        nv = (
            jnp.einsum("bljh,bjhd->blhd", A, kx)
            + rho[..., None] * n[:, None]
        )
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("blhd,blhd->blh", qx, nv)), jnp.exp(-m_i))
        h_out = num / denom[..., None]

        # state update to end of chunk
        m_new = jnp.maximum(m + g, jnp.max(g[:, None] - bcum + li, axis=1))
        w_state = jnp.exp(m[:, None] + g[:, None] - m_new[:, None])      # not used per-pos
        decay_j = jnp.exp(g[:, None] - bcum + li - m_new[:, None])        # [B, L, H]
        C_new = (
            jnp.exp(m + g - m_new)[:, :, None, None] * C
            + jnp.einsum("blh,blhd,blhe->bhde", decay_j, kx, vx)
        )
        n_new = (
            jnp.exp(m + g - m_new)[:, :, None] * n
            + jnp.einsum("blh,blhd->bhd", decay_j, kx)
        )
        del w_state
        return (C_new, n_new, m_new), h_out

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, lfc, lic))
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, nc * L, h, d)[:, :s]
    return hs, (C, n, m)


def mlstm_step(q, k, v, i_raw, f_raw, state):
    """Single decode step. q,k,v: [B, H, D]; gates: [B, H]."""
    C, n, m = state
    scale = 1.0 / math.sqrt(q.shape[-1])
    q = q.astype(jnp.float32) * scale
    k, v = k.astype(jnp.float32), v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    logi = i_raw.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(logi - m_new)
    C_new = fp[..., None, None] * C + ip[..., None, None] * (k[..., :, None] * v[..., None, :])
    n_new = fp[..., None] * n + ip[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)), jnp.exp(-m_new))
    return num / denom[..., None], (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm_block(key: jax.Array, d: int, spec: MLSTMSpec, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 9)
    di, h, hd = spec.d_inner, spec.n_heads, spec.head_dim
    return {
        "w_up_v": dense_init(ks[0], d, di, dtype=dtype),
        "w_up_g": dense_init(ks[1], d, di, dtype=dtype),
        "conv_w": (0.1 * jax.random.truncated_normal(
            ks[2], -2, 2, (spec.conv_width, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        # per-head block-diagonal q/k/v maps (keeps the 350M budget; the
        # matrix memory mixes within heads only, as in the paper's cell)
        "wq": dense_init(ks[3], hd, hd, shape=(h, hd, hd), dtype=dtype),
        "wk": dense_init(ks[4], hd, hd, shape=(h, hd, hd), dtype=dtype),
        "wv": dense_init(ks[5], hd, hd, shape=(h, hd, hd), dtype=dtype),
        "wi": dense_init(ks[6], di, h, dtype=jnp.float32),
        "bi": jnp.zeros((h,), jnp.float32),
        "wf": dense_init(ks[7], di, h, dtype=jnp.float32),
        # positive f bias => long memory at init (paper's init)
        "bf": jnp.linspace(3.0, 6.0, h).astype(jnp.float32),
        "gn_scale": jnp.ones((di,), jnp.float32),
        "w_down": dense_init(ks[8], di, d, dtype=dtype),
    }


def _headwise_rmsnorm(x: jax.Array, scale: jax.Array, n_heads: int) -> jax.Array:
    b, s, di = x.shape
    xh = x.astype(jnp.float32).reshape(b, s, n_heads, di // n_heads)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + 1e-6)
    return (xh.reshape(b, s, di) * scale).astype(x.dtype)


def _mlstm_qkv_gates(p: Params, spec: MLSTMSpec, u, c, dtype):
    h, hd = spec.n_heads, spec.head_dim
    ch = c.reshape(c.shape[0], c.shape[1], h, hd)
    uh = u.reshape(u.shape[0], u.shape[1], h, hd)
    q = jnp.einsum("bshd,hde->bshe", ch, p["wq"].astype(dtype))
    k = jnp.einsum("bshd,hde->bshe", ch, p["wk"].astype(dtype))
    v = jnp.einsum("bshd,hde->bshe", uh, p["wv"].astype(dtype))
    i_raw = jnp.einsum("bsd,dh->bsh", u.astype(jnp.float32), p["wi"]) + p["bi"]
    f_raw = jnp.einsum("bsd,dh->bsh", u.astype(jnp.float32), p["wf"]) + p["bf"]
    return q, k, v, i_raw, f_raw


def mlstm_block(p: Params, spec: MLSTMSpec, x: jax.Array, *,
                compute_dtype=jnp.bfloat16) -> jax.Array:
    x = x.astype(compute_dtype)
    u = x @ p["w_up_v"].astype(compute_dtype)
    z = x @ p["w_up_g"].astype(compute_dtype)
    c = jax.nn.silu(causal_conv(u, p["conv_w"].astype(compute_dtype),
                                p["conv_b"].astype(compute_dtype)))
    q, k, v, i_raw, f_raw = _mlstm_qkv_gates(p, spec, u, c, compute_dtype)
    h, _ = mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk=spec.chunk)
    h = h.reshape(x.shape[0], x.shape[1], spec.d_inner).astype(compute_dtype)
    h = _headwise_rmsnorm(h, p["gn_scale"], spec.n_heads)
    return (h * jax.nn.silu(z)) @ p["w_down"].astype(compute_dtype)


def init_mlstm_cache(batch: int, spec: MLSTMSpec, dtype=jnp.bfloat16) -> Params:
    h, hd = spec.n_heads, spec.head_dim
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.d_inner), dtype),
    }


def mlstm_block_step(p: Params, spec: MLSTMSpec, x: jax.Array, cache: Params, *,
                     compute_dtype=jnp.bfloat16) -> tuple[jax.Array, Params]:
    x = x.astype(compute_dtype)  # [B, 1, d]
    u = x @ p["w_up_v"].astype(compute_dtype)
    z = x @ p["w_up_g"].astype(compute_dtype)
    c, new_tail = causal_conv_step(u, cache["conv"], p["conv_w"].astype(compute_dtype),
                                   p["conv_b"].astype(compute_dtype))
    c = jax.nn.silu(c)
    q, k, v, i_raw, f_raw = _mlstm_qkv_gates(p, spec, u, c, compute_dtype)
    h, (C, n, m) = mlstm_step(q[:, 0], k[:, 0], v[:, 0], i_raw[:, 0], f_raw[:, 0],
                              (cache["C"], cache["n"], cache["m"]))
    h = h.reshape(x.shape[0], 1, spec.d_inner).astype(compute_dtype)
    h = _headwise_rmsnorm(h, p["gn_scale"], spec.n_heads)
    y = (h * jax.nn.silu(z)) @ p["w_down"].astype(compute_dtype)
    return y, {"C": C, "n": n, "m": m, "conv": new_tail.astype(cache["conv"].dtype)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm_block(key: jax.Array, d: int, spec: SLSTMSpec, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 12)
    h, hd = spec.n_heads, spec.head_dim
    p: Params = {
        "conv_w": (0.1 * jax.random.truncated_normal(
            ks[0], -2, 2, (spec.conv_width, d))).astype(dtype),
        "conv_b": jnp.zeros((d,), dtype),
        "gn_scale": jnp.ones((d,), jnp.float32),
    }
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = dense_init(ks[1 + i], d, d, dtype=dtype)
        p[f"r{g}"] = dense_init(ks[5 + i], hd, hd, shape=(h, hd, hd), dtype=dtype)
        p[f"b{g}"] = (jnp.linspace(3.0, 6.0, d).astype(jnp.float32) if g == "f"
                      else jnp.zeros((d,), jnp.float32))
    if spec.d_ff:
        p["ff_w1"] = dense_init(ks[9], d, spec.d_ff, dtype=dtype)
        p["ff_w2"] = dense_init(ks[10], d, spec.d_ff, dtype=dtype)
        p["ff_w3"] = dense_init(ks[11], spec.d_ff, d, dtype=dtype)
    return p


def _slstm_cell(p: Params, spec: SLSTMSpec, xz, xi, xf, xo, state):
    """One timestep; all args [B, d] fp32; state = (c, n, h, m)."""
    c, n, h_prev, m = state
    nh = spec.n_heads
    f32 = jnp.float32

    def rec(g):
        return _blockdiag(h_prev, p[f"r{g}"].astype(f32), 0.0, nh)

    z = jnp.tanh(xz + rec("z") + p["bz"])
    o = jax.nn.sigmoid(xo + rec("o") + p["bo"])
    i_raw = xi + rec("i") + p["bi"]
    f_raw = xf + rec("f") + p["bf"]
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    ip = jnp.exp(i_raw - m_new)
    fp = jnp.exp(logf + m - m_new)
    c_new = fp * c + ip * z
    n_new = fp * n + ip
    h_new = o * c_new / jnp.maximum(n_new, jnp.exp(-m_new))
    return c_new, n_new, h_new, m_new


def _slstm_scan(p: Params, spec: SLSTMSpec, x: jax.Array, xc: jax.Array, state):
    """x (for z/o), xc (conv'd, for i/f): [B, S, d]. Returns h [B,S,d], state."""
    f32 = jnp.float32
    xz = x.astype(f32) @ p["wz"].astype(f32)
    xo = x.astype(f32) @ p["wo"].astype(f32)
    xi = xc.astype(f32) @ p["wi"].astype(f32)
    xf = xc.astype(f32) @ p["wf"].astype(f32)

    def step(carry, xs):
        new = _slstm_cell(p, spec, xs[0], xs[1], xs[2], xs[3], carry)
        return new, new[2]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xz, xi, xf, xo))
    state, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), state


def init_slstm_cache(batch: int, spec: SLSTMSpec, dtype=jnp.bfloat16) -> Params:
    zeros = jnp.zeros((batch, spec.d), jnp.float32)
    return {
        "c": zeros, "n": zeros, "h": zeros,
        "m": jnp.full((batch, spec.d), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.d), dtype),
    }


def _slstm_out(p: Params, spec: SLSTMSpec, h: jax.Array, dtype) -> jax.Array:
    h = _headwise_rmsnorm(h.astype(dtype), p["gn_scale"], spec.n_heads)
    if spec.d_ff:
        a = jax.nn.gelu(h @ p["ff_w1"].astype(dtype), approximate=True)
        h = (a * (h @ p["ff_w2"].astype(dtype))) @ p["ff_w3"].astype(dtype)
    return h


def slstm_block(p: Params, spec: SLSTMSpec, x: jax.Array, *,
                compute_dtype=jnp.bfloat16) -> jax.Array:
    x = x.astype(compute_dtype)
    xc = jax.nn.silu(causal_conv(x, p["conv_w"].astype(compute_dtype),
                                 p["conv_b"].astype(compute_dtype)))
    b = x.shape[0]
    zeros = jnp.zeros((b, spec.d), jnp.float32)
    state = (zeros, zeros, zeros, jnp.full((b, spec.d), -1e30, jnp.float32))
    h, _ = _slstm_scan(p, spec, x, xc, state)
    return _slstm_out(p, spec, h, compute_dtype)


def slstm_block_step(p: Params, spec: SLSTMSpec, x: jax.Array, cache: Params, *,
                     compute_dtype=jnp.bfloat16) -> tuple[jax.Array, Params]:
    x = x.astype(compute_dtype)  # [B, 1, d]
    xc, new_tail = causal_conv_step(x, cache["conv"], p["conv_w"].astype(compute_dtype),
                                    p["conv_b"].astype(compute_dtype))
    xc = jax.nn.silu(xc)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    h, state = _slstm_scan(p, spec, x, xc, state)
    c, n, hst, m = state
    y = _slstm_out(p, spec, h, compute_dtype)
    return y, {"c": c, "n": n, "h": hst, "m": m,
               "conv": new_tail.astype(cache["conv"].dtype)}
