"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (temporal-mixing half of a residual block):

    x ──→ Wx ──→ causal depthwise conv (w=4) ──→ RG-LRU ──┐
      └─→ Wy ──→ GeLU ───────────────────────────────────⊙─→ Wo → out

RG-LRU recurrence (fp32):

    r_t = sigmoid(blockdiag(x_t, A_gate))          # recurrence gate
    i_t = sigmoid(blockdiag(x_t, X_gate))          # input gate
    log a_t = -c · softplus(Λ) · r_t               # c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The full-sequence path uses ``jax.lax.associative_scan`` over the affine
maps (a, b) — O(S log S) work, log-depth, TPU friendly — and is the
oracle for the Pallas blocked-scan kernel (``repro.kernels.rglru``).
Decode is the O(1) single-step update with a (state, conv-tail) cache.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init

RGLRU_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_rnn: int
    n_heads: int
    conv_width: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_rnn % self.n_heads == 0
        return self.d_rnn // self.n_heads


def init_rglru_block(key: jax.Array, d: int, spec: RGLRUSpec, dtype=jnp.float32) -> Params:
    kx, ky, ko, kc, ka, kg, kl = jax.random.split(key, 7)
    r, h, hd = spec.d_rnn, spec.n_heads, spec.head_dim
    # Λ init so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix).
    u = jax.random.uniform(kl, (r,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * RGLRU_C)))  # softplus^-1
    return {
        "wx": dense_init(kx, d, r, dtype=dtype),
        "wy": dense_init(ky, d, r, dtype=dtype),
        "wo": dense_init(ko, r, d, dtype=dtype),
        "conv_w": (0.1 * jax.random.truncated_normal(
            kc, -2, 2, (spec.conv_width, r))).astype(dtype),
        "conv_b": jnp.zeros((r,), dtype),
        "a_gate": dense_init(ka, hd, hd, shape=(h, hd, hd), dtype=dtype),
        "a_bias": jnp.zeros((r,), dtype),
        "x_gate": dense_init(kg, hd, hd, shape=(h, hd, hd), dtype=dtype),
        "x_bias": jnp.zeros((r,), dtype),
        "lambda": lam,  # fp32 always
    }


def _blockdiag(x: jax.Array, w: jax.Array, b: jax.Array, n_heads: int) -> jax.Array:
    """x: [..., R] -> [..., R] via per-head dense (block-diagonal) map."""
    shape = x.shape
    xh = x.reshape(*shape[:-1], n_heads, shape[-1] // n_heads)
    yh = jnp.einsum("...hd,hde->...he", xh, w)
    return yh.reshape(shape) + b


def _gates(p: Params, spec: RGLRUSpec, x: jax.Array):
    """fp32 (log_a, beta·i·x) for the recurrence; x: [..., R]."""
    xf = x.astype(jnp.float32)
    r_gate = jax.nn.sigmoid(_blockdiag(
        xf, p["a_gate"].astype(jnp.float32),
        p["a_bias"].astype(jnp.float32), spec.n_heads))
    i_gate = jax.nn.sigmoid(_blockdiag(
        xf, p["x_gate"].astype(jnp.float32),
        p["x_bias"].astype(jnp.float32), spec.n_heads))
    log_a = -RGLRU_C * jax.nn.softplus(p["lambda"]) * r_gate
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, beta * i_gate * xf


def rglru_scan(p: Params, spec: RGLRUSpec, x: jax.Array) -> jax.Array:
    """Full sequence. x: [B, S, R] -> h: [B, S, R] (same dtype as x)."""
    log_a, b = _gates(p, spec, x)
    a = jnp.exp(log_a)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype)


def rglru_step(p: Params, spec: RGLRUSpec, x: jax.Array, h_prev: jax.Array):
    """One step. x: [B, 1, R]; h_prev: [B, R] fp32."""
    log_a, b = _gates(p, spec, x)
    h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
    return h.astype(x.dtype)[:, None], h


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, S, R]; w: [W, R]."""
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x[:, :-i], ((0, 0), (i, 0), (0, 0)))
        out = out + shifted * w[-1 - i]
    return out + b


def causal_conv_step(x: jax.Array, tail: jax.Array, w: jax.Array, b: jax.Array):
    """x: [B, 1, R]; tail: [B, W-1, R] (previous inputs). Returns (y, new_tail)."""
    window = jnp.concatenate([tail, x], axis=1)               # [B, W, R]
    y = jnp.einsum("bwr,wr->br", window, w)[:, None] + b
    return y, window[:, 1:]


def init_rglru_cache(batch: int, spec: RGLRUSpec, dtype=jnp.bfloat16) -> Params:
    return {
        "h": jnp.zeros((batch, spec.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.d_rnn), dtype),
    }


def rglru_block(p: Params, spec: RGLRUSpec, x: jax.Array, *,
                compute_dtype=jnp.bfloat16) -> jax.Array:
    """Full-sequence temporal-mixing block. x: [B, S, d] -> [B, S, d]."""
    x = x.astype(compute_dtype)
    xb = x @ p["wx"].astype(compute_dtype)
    gb = jax.nn.gelu(x @ p["wy"].astype(compute_dtype))
    xb = causal_conv(xb, p["conv_w"].astype(compute_dtype), p["conv_b"].astype(compute_dtype))
    h = rglru_scan(p, spec, xb)
    return (h * gb) @ p["wo"].astype(compute_dtype)


def rglru_block_step(p: Params, spec: RGLRUSpec, x: jax.Array, cache: Params, *,
                     compute_dtype=jnp.bfloat16) -> tuple[jax.Array, Params]:
    """One decode step. x: [B, 1, d]."""
    x = x.astype(compute_dtype)
    xb = x @ p["wx"].astype(compute_dtype)
    gb = jax.nn.gelu(x @ p["wy"].astype(compute_dtype))
    xb, new_tail = causal_conv_step(
        xb, cache["conv"], p["conv_w"].astype(compute_dtype), p["conv_b"].astype(compute_dtype))
    hseq, h_state = rglru_step(p, spec, xb, cache["h"])
    y = (hseq * gb) @ p["wo"].astype(compute_dtype)
    return y, {"h": h_state, "conv": new_tail.astype(cache["conv"].dtype)}
