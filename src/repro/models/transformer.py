"""Composable decoder-only LM covering all ten assigned architectures.

A model is a repeating **pattern** of layers (e.g. RecurrentGemma's
``(rglru, rglru, local-attn)``, Llama-4's ``(dense-ffn, moe-ffn)``,
xLSTM's ``(mlstm×7, slstm)``) applied ``num_units`` times.  Per-layer
parameters are stacked on a leading unit axis and the stack runs as a
single ``jax.lax.scan`` over units (optionally ``jax.checkpoint``-ed for
remat) — one compiled unit body regardless of depth, which keeps HLO size
and compile time flat across the zoo.

Three execution modes share the same layer code:

* ``forward``      — full-sequence training/scoring forward (logits).
* ``prefill``      — full sequence + per-layer cache extraction.
* ``decode_step``  — single token against the cache (serving).

Parameters are plain pytrees; sharding is applied externally by
``repro.distributed.partitioning`` (path-based rules), so this module is
completely mesh-agnostic.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import AttnSpec
from repro.models.layers import (Params, apply_norm, embed, init_embedding,
                                 init_head, init_mlp, init_norm, logits_head, mlp)
from repro.models.moe import MoESpec
from repro.models.rglru import RGLRUSpec
from repro.models.xlstm import MLSTMSpec, SLSTMSpec
from repro.models.rope import text_mrope_positions

DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"        # 'attn' | 'rglru' | 'mlstm' | 'slstm'
    ffn: str = "dense"         # 'dense' | 'moe' | 'none'
    window: int | None = None  # sliding window for 'attn'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    tail: tuple[LayerSpec, ...] = ()   # trailing layers when depth % pattern != 0
    # attention
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int | None = None
    qkv_bias: bool = False
    rope_kind: str = "rope"           # 'rope' | 'mrope' | 'none'
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    attn_softcap: float | None = None
    # dense ffn
    d_ff: int = 0
    act: str = "silu"
    ffn_gated: bool = True
    mlp_bias: bool = False
    # sub-block specs (None when unused)
    moe: MoESpec | None = None
    rglru: RGLRUSpec | None = None
    mlstm: MLSTMSpec | None = None
    slstm: SLSTMSpec | None = None
    # embeddings / head
    tie_embeddings: bool = False
    input_mode: str = "tokens"        # 'tokens' | 'embeddings' (modality stub)
    emb_scale: float | None = None
    logit_scale: float | None = None
    logit_softcap: float | None = None
    residual_scale: float | None = None   # MiniCPM-style depth scaling
    norm: str = "rms"
    # numerics
    param_dtype: str = "bf16"
    compute_dtype: str = "bf16"
    remat: str = "full"               # 'none' | 'full' | 'dots'
    vocab_pad_to: int = 256           # Megatron-style vocab padding (TP divisibility)
    # losses
    moe_aux_weight: float = 0.01
    # distribution hints (consumed by repro.distributed.partitioning)
    fsdp_units: bool = False   # shard the stacked unit axis over 'data' (ZeRO-3)
    moe_shard_mode: str = "auto"   # 'auto' | 'e_data_f_model' (perf variant)
    # misc notes (e.g. applicability of paper technique)
    supports_kv_offload: bool = True

    def __post_init__(self):
        assert (self.n_layers - len(self.tail)) % len(self.pattern) == 0, \
            (self.name, self.n_layers)

    @property
    def num_units(self) -> int:
        return (self.n_layers - len(self.tail)) // len(self.pattern)

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab_size + p - 1) // p * p

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return DTYPES[self.compute_dtype]

    def attn_spec(self, window: int | None) -> AttnSpec:
        return AttnSpec(
            n_heads=self.n_heads, n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            qkv_bias=self.qkv_bias, rope_kind=self.rope_kind,
            rope_theta=self.rope_theta, mrope_sections=self.mrope_sections,
            window=window, softcap=self.attn_softcap)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, spec: LayerSpec, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"norm1": init_norm(cfg.norm, cfg.d_model, jnp.float32)}
    if spec.mixer == "attn":
        p["mixer"] = attn_mod.init_attention(
            k1, cfg.d_model, cfg.attn_spec(spec.window), cfg.pdtype)
    elif spec.mixer == "rglru":
        p["mixer"] = rglru_mod.init_rglru_block(k1, cfg.d_model, cfg.rglru, cfg.pdtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm_block(k1, cfg.d_model, cfg.mlstm, cfg.pdtype)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm_mod.init_slstm_block(k1, cfg.d_model, cfg.slstm, cfg.pdtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, jnp.float32)
        if spec.ffn == "dense":
            p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, gated=cfg.ffn_gated,
                                bias=cfg.mlp_bias, dtype=cfg.pdtype)
        elif spec.ffn == "moe":
            p["ffn"] = moe_mod.init_moe(k3, cfg.d_model, cfg.moe, cfg.pdtype)
        else:
            raise ValueError(spec.ffn)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, kh, ku = jax.random.split(key, 3)
    params: Params = {}
    if cfg.input_mode == "tokens":
        params["embed"] = init_embedding(ke, cfg.padded_vocab, cfg.d_model, cfg.pdtype)
    if not cfg.tie_embeddings:
        params["head"] = init_head(kh, cfg.d_model, cfg.padded_vocab, cfg.pdtype)

    def init_unit(k):
        ks = jax.random.split(k, len(cfg.pattern))
        return {f"layer{i}": _init_layer(cfg, spec, ks[i])
                for i, spec in enumerate(cfg.pattern)}

    params["unit"] = jax.vmap(init_unit)(jax.random.split(ku, cfg.num_units))
    if cfg.tail:
        kt = jax.random.split(jax.random.fold_in(ku, 1), len(cfg.tail))
        params["tail"] = {f"tail{i}": _init_layer(cfg, spec, kt[i])
                          for i, spec in enumerate(cfg.tail)}
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model, jnp.float32)
    return params


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# layer application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_mixer(cfg: ModelConfig, spec: LayerSpec, p: Params, h: jax.Array,
                 positions, position_ids, mode: str, cache, index):
    cd = cfg.cdtype
    if spec.mixer == "attn":
        aspec = cfg.attn_spec(spec.window)
        if mode == "decode":
            return attn_mod.attn_decode(p, aspec, h, cache, index,
                                        position_ids=position_ids, compute_dtype=cd)
        out = attn_mod.attn_full(p, aspec, h, positions,
                                 position_ids=position_ids, compute_dtype=cd)
        return out, None
    if spec.mixer == "rglru":
        if mode == "decode":
            return rglru_mod.rglru_block_step(p, cfg.rglru, h, cache, compute_dtype=cd)
        return rglru_mod.rglru_block(p, cfg.rglru, h, compute_dtype=cd), None
    if spec.mixer == "mlstm":
        if mode == "decode":
            return xlstm_mod.mlstm_block_step(p, cfg.mlstm, h, cache, compute_dtype=cd)
        return xlstm_mod.mlstm_block(p, cfg.mlstm, h, compute_dtype=cd), None
    if spec.mixer == "slstm":
        if mode == "decode":
            return xlstm_mod.slstm_block_step(p, cfg.slstm, h, cache, compute_dtype=cd)
        return xlstm_mod.slstm_block(p, cfg.slstm, h, compute_dtype=cd), None
    raise ValueError(spec.mixer)


def _apply_layer(cfg: ModelConfig, spec: LayerSpec, p: Params, x: jax.Array,
                 positions, position_ids, mode: str, cache, index):
    rs = cfg.residual_scale if cfg.residual_scale is not None else 1.0
    h = apply_norm(cfg.norm, p["norm1"], x)
    h, new_cache = _apply_mixer(cfg, spec, p["mixer"], h, positions, position_ids,
                                mode, cache, index)
    x = x + rs * h
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn != "none":
        h = apply_norm(cfg.norm, p["norm2"], x)
        if spec.ffn == "dense":
            h = mlp(p["ffn"], h, act=cfg.act, compute_dtype=cfg.cdtype)
        else:
            aux = moe_mod.aux_load_balance_loss(p["ffn"]["router"], h, cfg.moe) \
                if mode == "train" else aux
            h = moe_mod.apply_moe(p["ffn"], cfg.moe, h, compute_dtype=cfg.cdtype)
        x = x + rs * h
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# full-sequence forward (train / score)
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params: Params, inputs: jax.Array) -> jax.Array:
    if cfg.input_mode == "tokens":
        x = embed(params["embed"], inputs, compute_dtype=cfg.cdtype)
    else:
        x = inputs.astype(cfg.cdtype)
    if cfg.emb_scale is not None:
        x = x * jnp.asarray(cfg.emb_scale, cfg.cdtype)
    return x


def _head(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    w = params["embed"]["table"] if cfg.tie_embeddings else params["head"]["w"]
    logits = logits_head(w, x, softcap=cfg.logit_softcap, compute_dtype=cfg.cdtype,
                         valid_vocab=cfg.vocab_size)
    if cfg.logit_scale is not None:
        logits = logits * cfg.logit_scale
    return logits


def forward(cfg: ModelConfig, params: Params, inputs: jax.Array,
            positions: jax.Array | None = None,
            position_ids: jax.Array | None = None,
            mode: str = "train") -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits [B,S,V] fp32, moe_aux scalar)."""
    b, s = inputs.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope_kind == "mrope" and position_ids is None:
        position_ids = text_mrope_positions(positions)
    x = _embed_inputs(cfg, params, inputs)

    def unit_fn(carry, unit_p):
        x, aux = carry
        for i, spec in enumerate(cfg.pattern):
            x, _, a = _apply_layer(cfg, spec, unit_p[f"layer{i}"], x,
                                   positions, position_ids, mode, None, None)
            aux = aux + a
        return (x, aux), None

    if cfg.remat != "none":
        policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
                  else jax.checkpoint_policies.checkpoint_dots)
        unit_fn = jax.checkpoint(unit_fn, policy=policy, prevent_cse=False)

    (x, aux), _ = jax.lax.scan(unit_fn, (x, jnp.zeros((), jnp.float32)), params["unit"])
    for i, spec in enumerate(cfg.tail):
        x, _, a = _apply_layer(cfg, spec, params["tail"][f"tail{i}"], x,
                               positions, position_ids, mode, None, None)
        aux = aux + a
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return _head(cfg, params, x), aux


def loss_fn(cfg: ModelConfig, params: Params, batch: dict[str, jax.Array]
            ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Next-token cross entropy (+ MoE aux). batch: inputs, labels[, mask]."""
    logits, aux = forward(cfg, params, batch["inputs"],
                          batch.get("positions"), batch.get("position_ids"),
                          mode="train")
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    total = ce + cfg.moe_aux_weight * aux
    return total, {"ce": ce, "moe_aux": aux,
                   "tokens": jnp.sum(mask).astype(jnp.int32)}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def _init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_seq: int):
    cd = cfg.cdtype
    if spec.mixer == "attn":
        return attn_mod.init_attn_cache(batch, cfg.attn_spec(spec.window), max_seq, cd)
    if spec.mixer == "rglru":
        return rglru_mod.init_rglru_cache(batch, cfg.rglru, cd)
    if spec.mixer == "mlstm":
        return xlstm_mod.init_mlstm_cache(batch, cfg.mlstm, cd)
    if spec.mixer == "slstm":
        return xlstm_mod.init_slstm_cache(batch, cfg.slstm, cd)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Params:
    """{'unit': stacked per-unit cache, 'tail': per-tail-layer cache}."""
    unit = {f"layer{i}": _init_layer_cache(cfg, spec, batch, max_seq)
            for i, spec in enumerate(cfg.pattern)}
    u = cfg.num_units
    cache: Params = {
        "unit": jax.tree.map(lambda a: jnp.broadcast_to(a[None], (u,) + a.shape), unit)
    }
    if cfg.tail:
        cache["tail"] = {f"tail{i}": _init_layer_cache(cfg, spec, batch, max_seq)
                         for i, spec in enumerate(cfg.tail)}
    return cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                inputs: jax.Array, index: jax.Array,
                position_ids: jax.Array | None = None
                ) -> tuple[jax.Array, Params]:
    """One decode step. inputs: [B, 1] tokens (or [B, 1, d] embeddings);
    index: scalar int32 absolute position. Returns (logits [B,1,V], cache)."""
    if cfg.rope_kind == "mrope" and position_ids is None:
        b = inputs.shape[0]
        pos = jnp.broadcast_to(index[None, None], (b, 1)).astype(jnp.int32)
        position_ids = text_mrope_positions(pos)
    x = _embed_inputs(cfg, params, inputs)

    def unit_fn(x, scanned):
        unit_p, unit_c = scanned
        new_c = {}
        for i, spec in enumerate(cfg.pattern):
            x, new_c[f"layer{i}"], _ = _apply_layer(
                cfg, spec, unit_p[f"layer{i}"], x, None, position_ids,
                "decode", unit_c[f"layer{i}"], index)
        return x, new_c

    x, new_unit_cache = jax.lax.scan(unit_fn, x, (params["unit"], cache["unit"]))
    new_cache: Params = {"unit": new_unit_cache}
    if cfg.tail:
        new_cache["tail"] = {}
        for i, spec in enumerate(cfg.tail):
            x, c, _ = _apply_layer(cfg, spec, params["tail"][f"tail{i}"], x,
                                   None, position_ids, "decode",
                                   cache["tail"][f"tail{i}"], index)
            new_cache["tail"][f"tail{i}"] = c
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return _head(cfg, params, x), new_cache


def prefill(cfg: ModelConfig, params: Params, inputs: jax.Array,
            max_seq: int | None = None,
            position_ids: jax.Array | None = None
            ) -> tuple[jax.Array, Params]:
    """Full-sequence prefill: logits for the last position + a filled cache.

    Implemented as forward + cache reconstruction per layer; attention
    layers re-project K/V into the cache layout (ring-aligned for
    windowed layers), recurrent layers keep their final state.
    """
    b, s = inputs.shape[:2]
    max_seq = max_seq or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope_kind == "mrope" and position_ids is None:
        position_ids = text_mrope_positions(positions)
    x = _embed_inputs(cfg, params, inputs)

    def unit_fn(x, unit_p):
        caches = {}
        for i, spec in enumerate(cfg.pattern):
            name = f"layer{i}"
            h = apply_norm(cfg.norm, unit_p[name]["norm1"], x)
            out, c = _prefill_mixer(cfg, spec, unit_p[name]["mixer"], h,
                                    positions, position_ids, max_seq)
            rs = cfg.residual_scale if cfg.residual_scale is not None else 1.0
            x = x + rs * out
            if spec.ffn != "none":
                h = apply_norm(cfg.norm, unit_p[name]["norm2"], x)
                if spec.ffn == "dense":
                    h = mlp(unit_p[name]["ffn"], h, act=cfg.act, compute_dtype=cfg.cdtype)
                else:
                    h = moe_mod.apply_moe(unit_p[name]["ffn"], cfg.moe, h,
                                          compute_dtype=cfg.cdtype)
                x = x + rs * h
            caches[name] = c
        return x, caches

    x, unit_cache = jax.lax.scan(unit_fn, x, params["unit"])
    cache: Params = {"unit": unit_cache}
    if cfg.tail:
        cache["tail"] = {}
        for i, spec in enumerate(cfg.tail):
            name = f"tail{i}"
            p = params["tail"][name]
            h = apply_norm(cfg.norm, p["norm1"], x)
            out, c = _prefill_mixer(cfg, spec, p["mixer"], h,
                                    positions, position_ids, max_seq)
            rs = cfg.residual_scale if cfg.residual_scale is not None else 1.0
            x = x + rs * out
            if spec.ffn != "none":
                h = apply_norm(cfg.norm, p["norm2"], x)
                if spec.ffn == "dense":
                    h = mlp(p["ffn"], h, act=cfg.act, compute_dtype=cfg.cdtype)
                else:
                    h = moe_mod.apply_moe(p["ffn"], cfg.moe, h, compute_dtype=cfg.cdtype)
                x = x + rs * h
            cache["tail"][name] = c
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return _head(cfg, params, x[:, -1:]), cache


def _ring_align(k: jax.Array, v: jax.Array, positions: jax.Array, slots: int):
    """Pack the last ≤slots (k, v) pairs into ring layout (pos % slots)."""
    b, s = positions.shape
    if s <= slots:
        padk = jnp.zeros((b, slots - s) + k.shape[2:], k.dtype)
        kr = jnp.concatenate([k, padk], axis=1)
        vr = jnp.concatenate([v, padk], axis=1)
        pr = jnp.concatenate(
            [positions, jnp.full((b, slots - s), -1, jnp.int32)], axis=1)
        return kr, vr, pr
    idx = s - 1 - (s - 1 - jnp.arange(slots)) % slots  # source row per slot
    return k[:, idx], v[:, idx], positions[:, idx]


def _prefill_mixer(cfg: ModelConfig, spec: LayerSpec, p: Params, h: jax.Array,
                   positions, position_ids, max_seq: int):
    cd = cfg.cdtype
    if spec.mixer == "attn":
        aspec = cfg.attn_spec(spec.window)
        q, k, v = attn_mod._project_qkv(p, aspec, h.astype(cd), cd)
        q, k = attn_mod._apply_positional(aspec, q, k, positions, position_ids)
        if h.shape[1] >= aspec.blockwise_threshold:
            out = attn_mod._attn_blockwise(aspec, q, k, v, positions, positions)
        else:
            out = attn_mod._attn_plain(aspec, q, k, v, positions, positions)
        y = attn_mod._out_proj(p, out, cd)
        slots = min(max_seq, aspec.window) if aspec.window else max_seq
        kr, vr, pr = _ring_align(k, v, positions, slots)
        cache = {"k": kr.transpose(0, 2, 1, 3), "v": vr.transpose(0, 2, 1, 3), "pos": pr}
        return y, cache
    if spec.mixer == "rglru":
        sp = cfg.rglru
        x = h.astype(cd)
        xb_raw = x @ p["wx"].astype(cd)
        gb = jax.nn.gelu(x @ p["wy"].astype(cd))
        xb = rglru_mod.causal_conv(xb_raw, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
        hs = rglru_mod.rglru_scan(p, sp, xb)
        tail = _conv_tail(xb_raw, sp.conv_width)   # decode consumes PRE-conv inputs
        y = (hs * gb) @ p["wo"].astype(cd)
        return y, {"h": hs[:, -1].astype(jnp.float32), "conv": tail}
    if spec.mixer == "mlstm":
        sp = cfg.mlstm
        x = h.astype(cd)
        u = x @ p["w_up_v"].astype(cd)
        z = x @ p["w_up_g"].astype(cd)
        c = jax.nn.silu(rglru_mod.causal_conv(u, p["conv_w"].astype(cd), p["conv_b"].astype(cd)))
        q, k, v, i_raw, f_raw = xlstm_mod._mlstm_qkv_gates(p, sp, u, c, cd)
        hs, (C, n, m) = xlstm_mod.mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk=sp.chunk)
        hs = hs.reshape(x.shape[0], x.shape[1], sp.d_inner).astype(cd)
        hs = xlstm_mod._headwise_rmsnorm(hs, p["gn_scale"], sp.n_heads)
        y = (hs * jax.nn.silu(z)) @ p["w_down"].astype(cd)
        return y, {"C": C, "n": n, "m": m, "conv": _conv_tail(u, sp.conv_width)}
    if spec.mixer == "slstm":
        sp = cfg.slstm
        x = h.astype(cd)
        xc = jax.nn.silu(rglru_mod.causal_conv(x, p["conv_w"].astype(cd), p["conv_b"].astype(cd)))
        b = x.shape[0]
        zeros = jnp.zeros((b, sp.d), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, sp.d), -1e30, jnp.float32))
        hs, (cst, nst, hst, mst) = xlstm_mod._slstm_scan(p, sp, x, xc, state)
        y = xlstm_mod._slstm_out(p, sp, hs, cd)
        return y, {"c": cst, "n": nst, "h": hst, "m": mst,
                   "conv": _conv_tail(x, sp.conv_width)}
    raise ValueError(spec.mixer)


def _conv_tail(x: jax.Array, width: int) -> jax.Array:
    b, s, d = x.shape
    tail = width - 1
    if s >= tail:
        return x[:, s - tail:]
    return jnp.concatenate([jnp.zeros((b, tail - s, d), x.dtype), x], axis=1)
