"""Core neural-net layers shared by every architecture in the zoo.

Pure-JAX (no flax/optax in this environment): parameters are plain pytrees
of ``jnp.ndarray``; every layer is an ``init_*`` function returning a param
dict plus an ``apply``-style pure function.  All matmul-bearing layers take
an explicit ``compute_dtype`` so the stack runs mixed-precision (bf16
compute / configurable param dtype) exactly like a production trainer.

Initialization follows standard LM practice: truncated-normal fan-in
scaling for projections, ones for norm scales, zeros for biases.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, d_in: int, d_out: int, *, scale: float | None = None,
               dtype=jnp.float32, shape: tuple[int, ...] | None = None) -> jax.Array:
    """Fan-in scaled truncated normal; optional explicit leading shape."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    shape = shape if shape is not None else (d_in, d_out)
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, *, dtype=jnp.float32) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d))).astype(dtype)


# ---------------------------------------------------------------------------
# normalisation
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(orig_dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(orig_dtype)


def init_norm(kind: str, d: int, dtype=jnp.float32) -> Params:
    return init_rmsnorm(d, dtype) if kind == "rms" else init_layernorm(d, dtype)


def apply_norm(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rms" else layernorm(p, x)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu_exact": lambda x: jax.nn.gelu(x, approximate=False),
    "relu": jax.nn.relu,
}


# ---------------------------------------------------------------------------
# feed-forward (gated SwiGLU/GeGLU or classic 2-layer MLP)
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, d: int, d_ff: int, *, gated: bool, bias: bool = False,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"wi": dense_init(ks[0], d, d_ff, dtype=dtype),
                 "wo": dense_init(ks[1], d_ff, d, dtype=dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d, d_ff, dtype=dtype)
    if bias:
        p["bi"] = jnp.zeros((d_ff,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def mlp(p: Params, x: jax.Array, *, act: str, compute_dtype=jnp.bfloat16) -> jax.Array:
    x = x.astype(compute_dtype)
    h = x @ p["wi"].astype(compute_dtype)
    if "bi" in p:
        h = h + p["bi"].astype(compute_dtype)
    h = ACTIVATIONS[act](h)
    if "wg" in p:
        h = h * (x @ p["wg"].astype(compute_dtype))
    out = h @ p["wo"].astype(compute_dtype)
    if "bo" in p:
        out = out + p["bo"].astype(compute_dtype)
    return out


# ---------------------------------------------------------------------------
# logits head / embedding
# ---------------------------------------------------------------------------


def init_embedding(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": embed_init(key, vocab, d, dtype=dtype)}


def embed(p: Params, ids: jax.Array, *, compute_dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0).astype(compute_dtype)


def init_head(key: jax.Array, d: int, vocab: int, dtype=jnp.float32) -> Params:
    return {"w": dense_init(key, d, vocab, dtype=dtype)}


def logits_head(w: jax.Array, x: jax.Array, *, softcap: float | None = None,
                compute_dtype=jnp.bfloat16,
                valid_vocab: int | None = None) -> jax.Array:
    """``w`` is ``[V, d]`` (tied-embedding layout) or ``[d, V]``.

    ``valid_vocab`` masks Megatron-style vocab-padding columns to -inf so
    padded entries never receive probability mass.
    """
    w = w.astype(compute_dtype)
    if w.shape[0] != x.shape[-1]:  # [V, d] tied layout
        logits = jnp.einsum("...d,vd->...v", x.astype(compute_dtype), w)
    else:
        logits = x.astype(compute_dtype) @ w
    logits = logits.astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < valid_vocab, logits, -1e30)
    return logits
