"""Mixture-of-Experts FFN with capacity-based token dropping.

Design notes (scalability — see DESIGN.md §6):

* **Gather dispatch / scatter-add combine.**  The classic Mesh-TF one-hot
  ``einsum`` dispatch costs O(T·E·C·d) FLOPs and would dominate the real
  expert compute for top-8/small-expert configs (granite-moe: ~1000×
  overcount).  We instead build an integer routing table ``src[b, e, c]``
  (token index feeding expert e's slot c) with a scatter, *gather* expert
  inputs (zero FLOPs), run the batched expert FFN
  ``[G, E, C, d] × [E, d, f]``, and *scatter-add* weighted outputs back.
  Under GSPMD with experts sharded over the ``model`` mesh axis this
  yields per-shard partial outputs + one all-reduce per MoE layer —
  the same collective cost as a Megatron FFN.

* **Grouping.**  Capacity is allocated per token *group*.  For training /
  prefill a group is one sequence row (aligned with the batch sharding so
  the routing cumsum stays local); for single-token decode the whole batch
  forms one group (otherwise capacity would round up to ≥1 slot per
  expert per token — an E× compute overcount).

* **Router.**  Softmax top-k with renormalised weights (+ optional
  sigmoid scaling, Llama-4 style) and an optional always-on shared
  expert.  Dropped tokens (capacity overflow) fall through on the
  residual path, standard for capacity-based MoE.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.layers import ACTIVATIONS, Params, dense_init


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden width
    shared_d_ff: int = 0            # 0 = no shared expert
    capacity_factor: float = 1.25
    router_scale: str = "softmax"   # 'softmax' | 'sigmoid' (llama4-style)
    gated: bool = True
    act: str = "silu"


def init_moe(key: jax.Array, d: int, spec: MoESpec, dtype=jnp.float32) -> Params:
    kr, ki, kg, ko, s1, s2, s3 = jax.random.split(key, 7)
    e, f = spec.n_experts, spec.d_ff
    p: Params = {
        "router": dense_init(kr, d, e, dtype=jnp.float32),  # router kept fp32
        "wi": dense_init(ki, d, f, shape=(e, d, f), dtype=dtype),
        "wo": dense_init(ko, f, d, shape=(e, f, d), dtype=dtype),
    }
    if spec.gated:
        p["wg"] = dense_init(kg, d, f, shape=(e, d, f), dtype=dtype)
    if spec.shared_d_ff:
        p["shared_wi"] = dense_init(s1, d, spec.shared_d_ff, dtype=dtype)
        p["shared_wg"] = dense_init(s2, d, spec.shared_d_ff, dtype=dtype)
        p["shared_wo"] = dense_init(s3, spec.shared_d_ff, d, dtype=dtype)
    return p


def capacity_per_group(group_tokens: int, spec: MoESpec) -> int:
    c = math.ceil(group_tokens * spec.top_k * spec.capacity_factor / spec.n_experts)
    return max(1, c)


def _route(router_w: jax.Array, x: jax.Array, spec: MoESpec):
    """x: [G, T, d] -> (weights [G, T, K] fp32, ids [G, T, K] int32)."""
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32), router_w)
    if spec.router_scale == "sigmoid":
        weights, ids = jax.lax.top_k(logits, spec.top_k)
        weights = jax.nn.sigmoid(weights)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        weights, ids = jax.lax.top_k(probs, spec.top_k)
        weights = weights / jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9)
    return weights, ids


def _routing_tables(ids, weights, spec: MoESpec, capacity: int):
    """Build src-token and weight tables per expert slot.

    ids/weights: [G, T, K]  ->  src [G, E, C] int32 (T*K = dropped sentinel),
                               w   [G, E, C] fp32.
    """
    g, t, k = ids.shape
    e, c = spec.n_experts, capacity
    ids_f = ids.reshape(g, t * k)
    w_f = weights.reshape(g, t * k)
    tok_f = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None], (t, k)).reshape(t * k)

    onehot = jax.nn.one_hot(ids_f, e, dtype=jnp.int32)            # [G, TK, E]
    pos = (jnp.cumsum(onehot, axis=1) - 1) * onehot               # slot at the hot position
    pos_f = jnp.sum(pos, axis=-1)                                 # [G, TK]
    keep = pos_f < c

    slot = jnp.where(keep, pos_f, c)                              # overflow -> OOB (dropped)
    g_idx = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[:, None], (g, t * k))
    src = jnp.full((g, e, c + 1), t, jnp.int32)                   # sentinel token = t
    src = src.at[g_idx, ids_f, slot].set(tok_f[None, :], mode="drop")
    wtab = jnp.zeros((g, e, c + 1), jnp.float32)
    wtab = wtab.at[g_idx, ids_f, slot].set(w_f, mode="drop")
    return src[:, :, :c], wtab[:, :, :c]


def _expert_ffn(p: Params, spec: MoESpec, xe: jax.Array, dtype) -> jax.Array:
    """xe: [G, E, C, d] -> [G, E, C, d]; experts stay on their mesh shard."""
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(dtype))
    h = ACTIVATIONS[spec.act](h)
    if spec.gated:
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dtype))
    return jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dtype))


def apply_moe(p: Params, spec: MoESpec, x: jax.Array, *,
              compute_dtype=jnp.bfloat16) -> jax.Array:
    """x: [B, S, d]. Groups = rows (S > 1) or the whole batch (decode)."""
    b, s, d = x.shape
    xg = x if s > 1 else x.reshape(1, b, d)           # [G, T, d]
    g, t, _ = xg.shape
    cap = capacity_per_group(t, spec)
    if s == 1:
        # decode: near-dropless (serving must not drop whole FFN outputs;
        # a ≥4·k floor makes expert collisions at batch scale negligible)
        cap = min(t * spec.top_k, max(cap, 4 * spec.top_k))

    weights, ids = _route(p["router"], xg, spec)
    src, wtab = _routing_tables(ids, weights, spec, cap)
    # capacity-slot parallelism (non-divisible expert counts): shard the
    # slot axis of the dispatch buffers over 'model' — expert einsums stay
    # local, only the combine all-reduces (no-op outside a sharding ctx)
    src = constrain(src, ("batch", None, "moe_cap"))
    wtab = constrain(wtab, ("batch", None, "moe_cap"))

    x_pad = jnp.concatenate(
        [xg.astype(compute_dtype), jnp.zeros((g, 1, d), compute_dtype)], axis=1)
    g_idx = jnp.arange(g, dtype=jnp.int32)[:, None, None]
    xe = x_pad[g_idx, src]                            # [G, E, C, d] gather
    xe = constrain(xe, ("batch", None, "moe_cap", None))
    ye = _expert_ffn(p, spec, xe, compute_dtype)
    ye = ye * wtab[..., None].astype(compute_dtype)
    ye = constrain(ye, ("batch", None, "moe_cap", None))

    out = jnp.zeros((g, t + 1, d), compute_dtype)
    out = out.at[g_idx, src].add(ye, mode="drop")     # scatter-add combine
    out = out[:, :t]

    if spec.shared_d_ff:
        hs = xg.astype(compute_dtype) @ p["shared_wi"].astype(compute_dtype)
        hs = ACTIVATIONS[spec.act](hs)
        hs = hs * (xg.astype(compute_dtype) @ p["shared_wg"].astype(compute_dtype))
        out = out + hs @ p["shared_wo"].astype(compute_dtype)

    return out.reshape(b, s, d)


def aux_load_balance_loss(router_w: jax.Array, x: jax.Array, spec: MoESpec) -> jax.Array:
    """Switch-style load-balance auxiliary loss (fraction · probability)."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, spec.n_experts, dtype=jnp.float32), axis=(0, 1))
    imp = jnp.mean(probs, axis=(0, 1))
    return spec.n_experts * jnp.sum(frac * imp)
