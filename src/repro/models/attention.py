"""Attention: GQA/MHA, RoPE/M-RoPE, sliding-window, prefill + decode paths.

Grouped-native projection layout (sharding-critical design decision):
``wq`` is ``[d, kvH, G, Dh]`` (kv-head × group factored out **in the
parameter**, never by reshape) and K/V are ``[d, kvH, Dh]``.  GSPMD can
then shard either the ``kvH`` axis (GQA with ≥8 kv heads) or the ``G``
axis (kv=1/2 archs) over the ``model`` mesh axis without any reshape of
a sharded dimension — reshapes across padded sharded dims would force
all-gathers.  See ``repro.distributed.partitioning``.

Two full-sequence implementations:

* ``_attn_plain``     — materialises [B, kvH, G, Sq, Sk] scores (fp32
  softmax).  Used for short sequences.
* ``_attn_blockwise`` — streaming log-sum-exp over KV blocks (the
  flash-attention recurrence in pure jnp, ``lax.scan`` over blocks).
  Peak activation memory O(S · kv_block) instead of O(S²); also the
  reference semantics for the Pallas kernel
  (``repro.kernels.flash_attention``), which replaces it on TPU.

Decode (``attn_decode``) is a single-token query against a KV cache laid
out ``[B, kvH, S_cache, Dh]``; sliding-window layers use a ring buffer
with an explicit per-slot absolute-position array so RoPE and masking
stay correct after wrap-around.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import rope as rope_mod
from repro.models.layers import Params, dense_init

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    out_bias: bool = False
    rope_kind: str = "rope"           # 'rope' | 'mrope' | 'none'
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    window: int | None = None         # sliding-window size (None = global)
    softcap: float | None = None      # attention-logit soft cap
    kv_block: int = 1024              # blockwise KV tile
    blockwise_threshold: int = 8192   # use blockwise when Sk >= this

    @property
    def q_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads


def init_attention(key: jax.Array, d: int, spec: AttnSpec, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, kvh, g, hd = spec.n_heads, spec.n_kv_heads, spec.q_groups, spec.head_dim
    p: Params = {
        "wq": dense_init(kq, d, h * hd, shape=(d, kvh, g, hd), dtype=dtype),
        "wk": dense_init(kk, d, kvh * hd, shape=(d, kvh, hd), dtype=dtype),
        "wv": dense_init(kv, d, kvh * hd, shape=(d, kvh, hd), dtype=dtype),
        "wo": dense_init(ko, h * hd, d, shape=(kvh, g, hd, d), dtype=dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((kvh, g, hd), dtype)
        p["bk"] = jnp.zeros((kvh, hd), dtype)
        p["bv"] = jnp.zeros((kvh, hd), dtype)
    if spec.out_bias:
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def _project_qkv(p: Params, spec: AttnSpec, x: jax.Array, dtype):
    """q: [B, S, kvH, G, Dh]; k, v: [B, S, kvH, Dh]."""
    q = jnp.einsum("bsd,dhgk->bshgk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if spec.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    return q, k, v


def _apply_positional(spec: AttnSpec, q, k, positions, position_ids):
    if spec.rope_kind == "rope":
        q = rope_mod.apply_rope(q, positions, theta=spec.rope_theta)
        k = rope_mod.apply_rope(k, positions, theta=spec.rope_theta)
    elif spec.rope_kind == "mrope":
        q = rope_mod.apply_mrope(q, position_ids, spec.mrope_sections, theta=spec.rope_theta)
        k = rope_mod.apply_mrope(k, position_ids, spec.mrope_sections, theta=spec.rope_theta)
    return q, k


def _mask_bias(q_pos, k_pos, window):
    """[B, Sq, Sk] additive bias from causal (+ optional window) mask."""
    ok = q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        ok &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _softcap(scores, cap):
    return cap * jnp.tanh(scores / cap) if cap is not None else scores


def _out_proj(p: Params, out: jax.Array, dtype) -> jax.Array:
    """out: [B, S, kvH, G, Dh] -> [B, S, d]."""
    y = jnp.einsum("bshgk,hgkd->bsd", out.astype(dtype), p["wo"].astype(dtype))
    if "bo" in p:
        y = y + p["bo"].astype(dtype)
    return y


def _attn_plain(spec: AttnSpec, q, k, v, q_pos, k_pos):
    hd = spec.head_dim
    scores = jnp.einsum("bqhgk,bshk->bhgqs", q, k).astype(jnp.float32)
    scores = _softcap(scores * (1.0 / math.sqrt(hd)), spec.softcap)
    scores = scores + _mask_bias(q_pos, k_pos, spec.window)[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqs,bshk->bqhgk", probs, v)


def _attn_blockwise(spec: AttnSpec, q, k, v, q_pos, k_pos):
    """Streaming softmax over KV blocks; O(S·kv_block) live memory."""
    b, sq, kvh, g, hd = q.shape
    sk = k.shape[1]
    blk = min(spec.kv_block, sk)
    pad = (-sk) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=jnp.iinfo(jnp.int32).max)
    n_blk = k.shape[1] // blk
    scale = 1.0 / math.sqrt(hd)

    k_blocks = jnp.moveaxis(k.reshape(b, n_blk, blk, kvh, hd), 1, 0)
    v_blocks = jnp.moveaxis(v.reshape(b, n_blk, blk, kvh, hd), 1, 0)
    p_blocks = jnp.moveaxis(k_pos.reshape(b, n_blk, blk), 1, 0)

    def step(carry, blk_in):
        m, l, acc = carry
        kb, vb, pb = blk_in
        s = jnp.einsum("bqhgk,bshk->bhgqs", q, kb).astype(jnp.float32) * scale
        s = _softcap(s, spec.softcap)
        s = s + _mask_bias(q_pos, pb, spec.window)[:, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(pexp, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqs,bshk->bhgqk", pexp.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, kvh, g, sq), jnp.float32),
        jnp.zeros((b, kvh, g, sq, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(step, init, (k_blocks, v_blocks, p_blocks))
    out = acc / jnp.maximum(l, 1e-37)[..., None]          # [B, kvH, G, Sq, Dh]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)   # [B, Sq, kvH, G, Dh]


def attn_full(
    p: Params,
    spec: AttnSpec,
    x: jax.Array,
    positions: jax.Array,
    *,
    position_ids: jax.Array | None = None,
    compute_dtype=jnp.bfloat16,
) -> jax.Array:
    """Full-sequence (training / prefill) attention. x: [B, S, d]."""
    x = x.astype(compute_dtype)
    q, k, v = _project_qkv(p, spec, x, compute_dtype)
    q, k = _apply_positional(spec, q, k, positions, position_ids)
    # context-parallel fallback: when heads don't divide the TP axis the
    # launcher's activation rules shard the *query sequence* instead
    # (no-op outside an activation_sharding context / when seq % tp != 0)
    q = constrain(q, ("batch", "seq", None, None, None))
    q_pos = constrain(positions, ("batch", "seq"))
    if x.shape[1] >= spec.blockwise_threshold:
        out = _attn_blockwise(spec, q, k, v, q_pos, positions)
    else:
        out = _attn_plain(spec, q, k, v, q_pos, positions)
    return _out_proj(p, out, compute_dtype)


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def init_attn_cache(
    batch: int, spec: AttnSpec, max_seq: int, dtype=jnp.bfloat16
) -> Params:
    """KV cache. Windowed layers get a ring buffer of ``window`` slots with
    an absolute-position side array (-1 = empty)."""
    slots = min(max_seq, spec.window) if spec.window is not None else max_seq
    return {
        "k": jnp.zeros((batch, spec.n_kv_heads, slots, spec.head_dim), dtype),
        "v": jnp.zeros((batch, spec.n_kv_heads, slots, spec.head_dim), dtype),
        "pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def attn_decode(
    p: Params,
    spec: AttnSpec,
    x: jax.Array,
    cache: Params,
    index: jax.Array,
    *,
    position_ids: jax.Array | None = None,
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, Params]:
    """One decode step. x: [B, 1, d]; index: scalar int32 absolute position."""
    b = x.shape[0]
    x = x.astype(compute_dtype)
    q, k, v = _project_qkv(p, spec, x, compute_dtype)   # q: [B,1,kvH,G,Dh]
    positions = jnp.broadcast_to(index[None, None], (b, 1)).astype(jnp.int32)
    q, k = _apply_positional(spec, q, k, positions, position_ids)

    slots = cache["k"].shape[2]
    slot = (index % slots).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype), slot, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype), slot, axis=2)
    pos_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], positions, slot, axis=1)

    hd = spec.head_dim
    scores = jnp.einsum("bqhgk,bhsk->bhgqs", q, k_cache.astype(q.dtype))
    scores = scores.astype(jnp.float32) * (1.0 / math.sqrt(hd))
    scores = _softcap(scores, spec.softcap)
    ok = (pos_cache >= 0) & (pos_cache <= index)
    if spec.window is not None:
        ok &= (index - pos_cache) < spec.window
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(compute_dtype)
    out = jnp.einsum("bhgqs,bhsk->bqhgk", probs, v_cache.astype(compute_dtype))
    y = _out_proj(p, out, compute_dtype)
    return y, {"k": k_cache, "v": v_cache, "pos": pos_cache}
