"""Model zoo: composable decoder stacks for the ten assigned architectures."""

from repro.models.transformer import (  # noqa: F401
    LayerSpec, ModelConfig, decode_step, forward, init_cache, init_params,
    loss_fn, param_count, prefill)
