"""Causal / sliding-window GQA flash attention — Pallas TPU kernel.

Layout ``[B, H, S, D]``.  Grid ``(B, H, Sq/BQ, Sk/BK)``: the innermost
(kv) grid dimension is sequential on TPU, so the online-softmax state
(m, l, acc) lives in VMEM scratch and survives across kv steps; the
output tile is written once, on the final kv block of each q row.

BlockSpecs keep one (BQ × D) query tile, one (BK × D) key/value tile and
the (BQ × D) fp32 accumulator in VMEM — the classic flash working set.
GQA maps query head ``h`` to kv head ``h // group`` in the k/v index
maps, so no key/value replication is ever materialised.

Causal masking is positional (``q_offset`` allows decode-style partial
query windows); kv tiles strictly above the causal diagonal are skipped
with ``pl.when`` — on TPU this halves causal-prefill MXU work, which the
pure-jnp blockwise path cannot express.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bq: int, bk: int, kv_blocks: int,
            causal: bool, window: int | None, q_offset: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if causal:
        # newest query in this tile vs oldest key in the kv tile
        block_needed = kb * bk <= q_offset + (qb + 1) * bq - 1
    else:
        block_needed = (kb >= -1)  # trivially true, as a traced value

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [BQ, BK]
        q_pos = (q_offset + qb * bq
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
        k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kb == kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "q_offset",
                     "interpret"))
def flash_attention_bhsd(
    q: jax.Array,           # [B, H, Sq, D]
    k: jax.Array,           # [B, KVH, Sk, D]
    v: jax.Array,           # [B, KVH, Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    kv_blocks = sk // bk

    kern = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), bq=bq, bk=bk, kv_blocks=kv_blocks,
        causal=causal, window=window, q_offset=q_offset)

    return pl.pallas_call(
        kern,
        grid=(b, h, sq // bq, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qb, kb: (b_, h_, qb, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qb, kb: (b_, h_ // group, kb, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qb, kb: (b_, h_ // group, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qb, kb: (b_, h_, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
