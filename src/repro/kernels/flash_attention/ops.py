"""jit'd public wrapper around the flash-attention kernel.

Accepts the model's ``[B, S, kvH, G, D]`` grouped-query layout and the
plain ``[B, H, S, D]`` layout; dispatches to the Pallas kernel
(interpret=True on CPU — the TPU path just flips the flag).
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, causal=True, window=None, block_q=128,
                    block_k=128, q_offset=0, interpret=None):
    """q: [B,S,kvH,G,D] or [B,H,S,D]; k/v: [B,S,kvH,D] or [B,KVH,S,D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    grouped = q.ndim == 5
    if grouped:
        b, s, kvh, g, d = q.shape
        qx = q.transpose(0, 2, 3, 1, 4).reshape(b, kvh * g, s, d)
        kx = k.transpose(0, 2, 1, 3)
        vx = v.transpose(0, 2, 1, 3)
    else:
        qx, kx, vx = q, k, v
    out = flash_attention_bhsd(qx, kx, vx, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               q_offset=q_offset, interpret=interpret)
    if grouped:
        b, s, kvh, g, d = q.shape
        return out.reshape(b, kvh, g, s, d).transpose(0, 3, 1, 2, 4)
    return out
