"""Pure-jnp oracle for the flash-attention kernel (GQA, causal, window)."""

from __future__ import annotations

import math

import jax.numpy as jnp


def attention_reference(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: [B, H, Sq, D]; k, v: [B, KVH, Sk, D] -> [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    group = h // kvh
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p_sum = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", (p / jnp.maximum(p_sum, 1e-30)),
                     v.astype(jnp.float32))
    return out.astype(q.dtype)
