"""Pure-jnp oracle for the (max,+) trace-indexed fold."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_fold_ref(mats: jax.Array, s0: jax.Array, *, t_steps: int,
                     idx: jax.Array | None = None,
                     arrivals: jax.Array | None = None,
                     gvec: jax.Array | None = None) -> jax.Array:
    """mats: [B, M, N, N]; s0: [B, N] -> [B, N] after t_steps ops.

    ``idx`` [t_steps] selects the matrix per step; None = periodic.
    ``arrivals`` [t_steps] + ``gvec`` [B, M, N] add the per-op
    origin-column max-in of arrival-aware traces (DESIGN.md §2.6):
    ``s' = max(A_i (x) s, gvec[i] + arrivals[t])``."""
    m = mats.shape[1]
    if idx is None:
        idx = jnp.arange(t_steps, dtype=jnp.int32) % m
    idx = idx.astype(jnp.int32)
    if arrivals is None:
        def step(s, i):
            a = mats[:, i]                                   # [B, N, N]
            s = jnp.max(a + s[:, None, :], axis=-1)
            return s, None

        s, _ = jax.lax.scan(step, s0, idx[:t_steps])
        return s

    def step_arr(s, op):
        i, arr = op
        a = mats[:, i]                                       # [B, N, N]
        s = jnp.max(a + s[:, None, :], axis=-1)
        return jnp.maximum(s, gvec[:, i] + arr), None

    s, _ = jax.lax.scan(step_arr, s0,
                        (idx[:t_steps],
                         arrivals.astype(s0.dtype)[:t_steps]))
    return s


def maxplus_product_ref(mats: jax.Array, idx: jax.Array) -> jax.Array:
    """Sequential (max,+) *matrix* fold P = A_{idx[-1]} ⊗ … ⊗ A_{idx[0]}.

    mats: [B, M, N, N] -> [B, N, N].  Independent reference for the
    segmented/squaring engines' matmul algebra: the product is computed
    one matmul at a time with no chunking or squaring tricks."""
    from repro.core.maxplus_form import NEG   # shared -inf sentinel

    b, _, n, _ = mats.shape
    eye = jnp.where(jnp.eye(n, dtype=bool), 0.0, NEG).astype(mats.dtype)

    def step(p, i):
        a = mats[:, i]                                       # [B, N, N]
        p = jnp.max(a[:, :, :, None] + p[:, None, :, :], axis=-2)
        return p, None

    p0 = jnp.broadcast_to(eye, (b, n, n))
    p, _ = jax.lax.scan(step, p0, idx.astype(jnp.int32))
    return p
