"""Pure-jnp oracle for the (max,+) trace-indexed fold."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_fold_ref(mats: jax.Array, s0: jax.Array, *, t_steps: int,
                     idx: jax.Array | None = None,
                     arrivals: jax.Array | None = None,
                     gvec: jax.Array | None = None,
                     extras: jax.Array | None = None,
                     wvec: jax.Array | None = None) -> jax.Array:
    """mats: [B, M, N, N]; s0: [B, N] -> [B, N] after t_steps ops.

    ``idx`` [t_steps] selects the matrix per step; None = periodic.
    ``arrivals`` [t_steps] + ``gvec`` [B, M, N] add the per-op
    origin-column max-in of arrival-aware traces (DESIGN.md §2.6):
    ``s' = max(A_i (x) s, gvec[i] + arrivals[t])``.
    ``extras`` [t_steps] + ``wvec`` [B, M, N] add the per-op
    reliability surcharge of faulty traces (DESIGN.md §2.8): after the
    max-in, the op's written rows (wvec = 1.0 there) shift by the
    surcharge, ``s'' = s' + wvec[i] * extras[t]``."""
    m = mats.shape[1]
    if idx is None:
        idx = jnp.arange(t_steps, dtype=jnp.int32) % m
    idx = idx.astype(jnp.int32)
    if arrivals is None and extras is None:
        def step(s, i):
            a = mats[:, i]                                   # [B, N, N]
            s = jnp.max(a + s[:, None, :], axis=-1)
            return s, None

        s, _ = jax.lax.scan(step, s0, idx[:t_steps])
        return s

    zeros = jnp.zeros((t_steps,), s0.dtype)
    arr2 = zeros if arrivals is None else arrivals.astype(s0.dtype)[:t_steps]
    ext2 = zeros if extras is None else extras.astype(s0.dtype)[:t_steps]
    if gvec is None:           # extras-only: arrival max-in must be inert
        from repro.core.maxplus_form import NEG   # shared -inf sentinel
        gvec = jnp.full(mats.shape[:3], NEG, s0.dtype)
    if wvec is None:
        wvec = jnp.zeros(mats.shape[:3], s0.dtype)

    def step_arr(s, op):
        i, arr, ext = op
        a = mats[:, i]                                       # [B, N, N]
        s = jnp.max(a + s[:, None, :], axis=-1)
        s = jnp.maximum(s, gvec[:, i] + arr)
        return s + wvec[:, i] * ext, None

    s, _ = jax.lax.scan(step_arr, s0, (idx[:t_steps], arr2, ext2))
    return s


def maxplus_product_ref(mats: jax.Array, idx: jax.Array) -> jax.Array:
    """Sequential (max,+) *matrix* fold P = A_{idx[-1]} ⊗ … ⊗ A_{idx[0]}.

    mats: [B, M, N, N] -> [B, N, N].  Independent reference for the
    segmented/squaring engines' matmul algebra: the product is computed
    one matmul at a time with no chunking or squaring tricks."""
    from repro.core.maxplus_form import NEG   # shared -inf sentinel

    b, _, n, _ = mats.shape
    eye = jnp.where(jnp.eye(n, dtype=bool), 0.0, NEG).astype(mats.dtype)

    def step(p, i):
        a = mats[:, i]                                       # [B, N, N]
        p = jnp.max(a[:, :, :, None] + p[:, None, :, :], axis=-2)
        return p, None

    p0 = jnp.broadcast_to(eye, (b, n, n))
    p, _ = jax.lax.scan(step, p0, idx.astype(jnp.int32))
    return p
