"""Pure-jnp oracle for the (max,+) trace-indexed fold."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_fold_ref(mats: jax.Array, s0: jax.Array, *, t_steps: int,
                     idx: jax.Array | None = None) -> jax.Array:
    """mats: [B, M, N, N]; s0: [B, N] -> [B, N] after t_steps ops.

    ``idx`` [t_steps] selects the matrix per step; None = periodic."""
    m = mats.shape[1]
    if idx is None:
        idx = jnp.arange(t_steps, dtype=jnp.int32) % m
    idx = idx.astype(jnp.int32)

    def step(s, i):
        a = mats[:, i]                                       # [B, N, N]
        s = jnp.max(a + s[:, None, :], axis=-1)
        return s, None

    s, _ = jax.lax.scan(step, s0, idx[:t_steps])
    return s
