"""Pure-jnp oracle for the (max,+) periodic fold."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_fold_ref(mats: jax.Array, s0: jax.Array, *, t_steps: int) -> jax.Array:
    """mats: [B, P, N, N]; s0: [B, N] -> [B, N] after t_steps ops."""
    p = mats.shape[1]

    def step(s, t):
        a = mats[:, t % p]                                   # [B, N, N]
        s = jnp.max(a + s[:, None, :], axis=-1)
        return s, None

    s, _ = jax.lax.scan(step, s0, jnp.arange(t_steps))
    return s
