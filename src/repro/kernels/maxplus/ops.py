"""Public ops: SSD completion times via the (max,+) Pallas kernel.

Two entry points mirror the two scan-engine paths in ``repro.core``:

* ``channel_end_time_maxplus`` — homogeneous single-channel design-point
  batches (periodic matrix form; ways must divide MAX_WAYS — the
  power-of-two sweep grid of the paper);
* ``trace_end_time_maxplus`` — one heterogeneous ``OpTrace`` evaluated
  for a batch of design-point ``OpClassTable``s (the matrix-dictionary
  form; DESIGN.md §2.1).

Both take a ``strategy`` (DESIGN.md §2.3):

* ``"sequential"`` — the O(T) Pallas ``fori_loop`` matvec fold
  (``repro.kernels.maxplus.kernel``; compiles on TPU for both the
  periodic and the scalar-prefetch trace-indexed path);
* ``"segmented"`` — the segmented parallel-prefix matmul fold,
  O(segment_len + log T) depth;
* ``"squaring"`` (homogeneous only) — periodic matrix squaring,
  O(log n_pages) matmuls.

``trace_energy_maxplus`` additionally accumulates the phase-resolved
per-op energies ``E[idx[t]]`` inside the kernel's fold (DESIGN.md §2.4).

Engine-level dispatch lives in ``repro.core.api``: this module is the
``"pallas"`` entry of the registry, and ``strategy`` remains a
kernel-local knob selecting the fold shape.  Policy strings are
validated by ``repro.core.sim.policy_is_batched`` on the matrix-build
path, so typos raise instead of silently simulating ``eager``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maxplus_form import (NEG, StateLayout, combo_arrival_offsets,
                                     combo_matrices, combo_written_rows,
                                     end_time_from_state, init_state,
                                     maxplus_eye, maxplus_fold_segmented,
                                     periodic_fold_squaring, trace_combos,
                                     transition_matrices)
from repro.core.sim import PageOpParams
from repro.kernels.maxplus.kernel import (maxplus_fold_kernel,
                                          maxplus_fold_many_kernel)
from repro.kernels.maxplus.ref import maxplus_fold_ref


def _augment_arrivals(mats, gvec, idx, arrivals, wvec=None, extras=None):
    """[B, T, N, N] per-op matrices with the arrival origin column maxed
    in and the fault surcharge added to the written rows — the dense
    expansion the segmented strategy folds when a trace carries arrivals
    or per-op extras (the sequential kernel keeps the compact per-combo
    dictionary and applies ``gvec[idx[t]] + arrivals[t]`` /
    ``wvec[idx[t]] * extras[t]`` per step instead).  The origin row is
    the last layout row by construction.  Adding ``extras[t]`` uniformly
    across a written row commutes bit-exactly with the row max (rounding
    is monotone), so the dense form reproduces the per-step one."""
    per = jnp.take(mats, idx, axis=1)                       # [B, T, N, N]
    if arrivals is not None:
        cand = jnp.take(gvec, idx, axis=1) + arrivals[None, :, None]
        per = per.at[..., -1].set(jnp.maximum(per[..., -1], cand))
    if extras is not None:
        shift = jnp.take(wvec, idx, axis=1) * extras[None, :, None]
        per = per + shift[..., None]                        # all columns
    return per


def maxplus_fold(mats, s0, *, t_steps: int, idx=None, use_kernel: bool = True,
                 interpret: bool | None = None, strategy: str = "sequential",
                 segment_len: int = 64, arrivals=None, gvec=None,
                 extras=None, wvec=None):
    """Fold dispatch: ``strategy`` picks the evaluation shape (see module
    docstring); ``use_kernel=False`` runs the jnp sequential reference.
    ``arrivals`` [T] + ``gvec`` [B, M, N] make the fold arrival-aware;
    ``extras`` [T] + ``wvec`` [B, M, N] add per-op reliability
    surcharges on the written rows (trace-indexed path only; DESIGN.md
    §2.6 / §2.8)."""
    if (arrivals is not None or extras is not None) and idx is None:
        raise ValueError("arrivals/extras need the trace-indexed path "
                         "(pass idx)")
    if strategy == "segmented":
        if idx is None:
            idx = jnp.arange(t_steps, dtype=jnp.int32) % mats.shape[-3]
        idx = idx[:t_steps]
        if arrivals is not None or extras is not None:
            mats = _augment_arrivals(
                mats, gvec, idx,
                None if arrivals is None else jnp.asarray(arrivals,
                                                          jnp.float32),
                wvec,
                None if extras is None else jnp.asarray(extras,
                                                        jnp.float32))
            idx = jnp.arange(t_steps, dtype=jnp.int32)
        return maxplus_fold_segmented(mats, idx, s0,
                                      segment_len=segment_len)
    if strategy == "squaring":
        if idx is not None:
            raise ValueError(
                "strategy='squaring' needs a periodic (homogeneous) "
                "stream — got an explicit idx sequence")
        return periodic_fold_squaring(mats, s0, t_steps)
    if strategy != "sequential":
        raise ValueError(f"unknown strategy {strategy!r} (one of "
                         "'sequential', 'segmented', 'squaring')")
    if interpret is None:
        # both kernel paths compile on TPU (the trace-indexed one via
        # SMEM scalar prefetch); interpret only off-TPU
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return maxplus_fold_kernel(mats, s0, t_steps=t_steps, idx=idx,
                                   arrivals=arrivals, gvec=gvec,
                                   extras=extras, wvec=wvec,
                                   interpret=interpret)
    return maxplus_fold_ref(mats, s0, t_steps=t_steps, idx=idx,
                            arrivals=arrivals, gvec=gvec,
                            extras=extras, wvec=wvec)


def channel_end_time_maxplus(
    ops: list[PageOpParams],
    ways: list[int],
    *,
    n_pages: int,
    policy: str = "eager",
    use_kernel: bool = True,
    interpret: bool | None = None,
    strategy: str = "sequential",
) -> jax.Array:
    """Completion times (us) for a batch of homogeneous design points."""
    mats = np.stack([transition_matrices(op, w, policy)
                     for op, w in zip(ops, ways)])
    s0 = np.broadcast_to(init_state(), (mats.shape[0],
                                        init_state().shape[0])).copy()
    final = maxplus_fold(jnp.asarray(mats), jnp.asarray(s0),
                         t_steps=n_pages, use_kernel=use_kernel,
                         interpret=interpret, strategy=strategy)
    return end_time_from_state(np.asarray(final))


def bandwidth_maxplus_mb_s(ops, ways, *, n_pages: int = 512,
                           policy: str = "eager", **kw) -> np.ndarray:
    end = channel_end_time_maxplus(ops, ways, n_pages=n_pages, policy=policy, **kw)
    data = np.array([op.data_bytes for op in ops], np.float64)
    return data * n_pages / np.asarray(end)


def _combo_setup(tables, trace, policy):
    """(layout, combos, idx, mats [B,M,N,N], s0 [B,N], arrivals, gvec,
    extras, wvec) shared by the trace-indexed end-time and energy entry
    points.  ``arrivals``/``gvec`` are None for back-to-back traces; an
    arrival-aware trace additionally gets the per-combo origin-column
    templates of ``combo_arrival_offsets`` (DESIGN.md §2.6).
    ``extras``/``wvec`` (None for fault-free traces) carry the per-op
    reliability surcharges and the per-combo written-rows masks they
    shift (DESIGN.md §2.8)."""
    layout = StateLayout(trace.channels, trace.ways)
    combos, idx = trace_combos(trace)   # trace-only: shared by the batch
    mats = np.stack([combo_matrices(table, combos, layout, policy)
                     for table in tables])
    s0 = np.broadcast_to(init_state(layout),
                         (mats.shape[0], layout.n_state)).copy()
    arrivals = gvec = None
    if trace.arrival_us is not None:
        arrivals = jnp.asarray(trace.arrival_us, jnp.float32)
        gvec = jnp.asarray(np.stack([
            combo_arrival_offsets(table, combos, layout, policy)
            for table in tables]))
    extras = wvec = None
    if trace.extra_us is not None:
        extras = jnp.asarray(trace.extra_us, jnp.float32)
        w = combo_written_rows(combos, layout)          # combo-only: shared
        wvec = jnp.asarray(np.broadcast_to(w, (mats.shape[0],) + w.shape))
    return layout, combos, idx, mats, s0, arrivals, gvec, extras, wvec


def trace_end_time_maxplus(
    tables,                    # OpClassTable | list[OpClassTable]
    trace,                     # OpTrace (shared across the batch)
    *,
    policy: str = "eager",
    use_kernel: bool = True,
    interpret: bool | None = None,
    strategy: str = "sequential",
    segment_len: int = 64,
) -> np.ndarray:
    """Completion times (us) of one heterogeneous trace under a batch of
    design-point timing tables ([B], or scalar for a single table)."""
    single = not isinstance(tables, (list, tuple))
    if single:
        tables = [tables]
    layout, _, idx, mats, s0, arrivals, gvec, extras, wvec = _combo_setup(
        tables, trace, policy)
    final = maxplus_fold(jnp.asarray(mats), jnp.asarray(s0),
                         t_steps=trace.n_ops, idx=jnp.asarray(idx),
                         use_kernel=use_kernel, interpret=interpret,
                         strategy=strategy, segment_len=segment_len,
                         arrivals=arrivals, gvec=gvec,
                         extras=extras, wvec=wvec)
    end = end_time_from_state(np.asarray(final), layout)
    return end[0] if single else end


def trace_fold_closure(table, trace, *, policy: str = "eager"):
    """(fn, args): the jax-traceable core of the trace-indexed kernel
    path — what the ``repro.analysis`` jaxpr layer traces for the
    ``pallas`` engine (DESIGN.md §2.9).  The host-side combo-dictionary
    build happens here, *outside* the returned closure, exactly as in
    :func:`trace_end_time_maxplus`; the closure itself is the pure
    ``maxplus_fold`` the registry entry folds per query (interpret
    mode, so the pallas_call traces off-TPU)."""
    _, _, idx, mats, s0, arrivals, gvec, extras, wvec = _combo_setup(
        [table], trace, policy)
    fold = functools.partial(
        maxplus_fold, t_steps=trace.n_ops, interpret=True,
        strategy="sequential", arrivals=arrivals, gvec=gvec,
        extras=extras, wvec=wvec)

    def fn(mats, s0, idx):
        return fold(mats, s0, idx=idx)

    return fn, (jnp.asarray(mats), jnp.asarray(s0), jnp.asarray(idx))


def run_many_end_time_maxplus(
    table,                     # OpClassTable (one design point)
    traces,                    # list[OpTrace], one shared (C, W) geometry
    *,
    policy: str = "eager",
    block_lanes: int = 128,
    interpret: bool | None = None,
) -> np.ndarray:
    """End times (us) of B independent heterogeneous traces in ONE fused
    Pallas launch (``maxplus_fold_many_kernel``): lanes are whole traces
    rather than design points, folding their own op sequences against the
    *union* combo dictionary of the fleet.  An appended (max,+) identity
    combo (NEG origin template, zero arrival) pads short lanes as an
    exact no-op, so mixed-length fleets need no per-bucket launches —
    lanes sort longest-first and each lane block folds only to its own
    longest member.  Lane count and fold length round up to the next
    block / power-of-two so jittered fleet sizes reuse the compiled
    program."""
    if not traces:
        return np.zeros((0,), np.float64)
    geom = (traces[0].channels, traces[0].ways)
    for tr in traces:
        if (tr.channels, tr.ways) != geom:
            raise ValueError(
                "fused run_many needs one shared (channels, ways) geometry "
                f"per call — got {geom} and {(tr.channels, tr.ways)}")
    layout = StateLayout(*geom)
    # union combo dictionary across the fleet, vectorised: pack each
    # op's (class, channel, way, parity) into one integer key and let
    # np.unique build the dictionary + per-op indices in one pass — the
    # per-trace Python loop of ``trace_combos`` would dominate the
    # megakernel's own wall time at fleet scale
    keys = np.concatenate([
        (np.asarray(tr.cls, np.int64) << 24)
        | (np.asarray(tr.channel, np.int64) << 16)
        | (np.asarray(tr.way, np.int64) << 8)
        | (np.asarray(tr.parity, np.int64) & 1)
        for tr in traces])
    uniq, inv = np.unique(keys, return_inverse=True)
    combos = [(int(k >> 24), int((k >> 16) & 0xFF),
               int((k >> 8) & 0xFF), int(k & 1)) for k in uniq]
    bounds = np.cumsum([0] + [tr.n_ops for tr in traces])
    lane_idx = [inv[bounds[i]:bounds[i + 1]].astype(np.int32)
                for i in range(len(traces))]
    m = len(combos)
    mats = np.concatenate([combo_matrices(table, combos, layout, policy),
                           maxplus_eye(layout.n_state)[None]])
    gvec = np.concatenate([combo_arrival_offsets(table, combos, layout,
                                                 policy),
                           np.full((1, layout.n_state), NEG, np.float32)])
    order = sorted(range(len(traces)), key=lambda i: -traces[i].n_ops)
    t_max = 1 << max(6, (traces[order[0]].n_ops - 1).bit_length())
    b = len(traces)
    idx = np.full((b, t_max), m, np.int32)
    arr = np.zeros((b, t_max), np.float32)
    ext = np.zeros((b, t_max), np.float32)
    lengths = np.zeros((b,), np.int32)
    for lane, i in enumerate(order):
        tr = traces[i]
        idx[lane, :tr.n_ops] = lane_idx[i]
        if tr.arrival_us is not None:
            arr[lane, :tr.n_ops] = np.asarray(tr.arrival_us, np.float32)
        if tr.extra_us is not None:
            ext[lane, :tr.n_ops] = np.asarray(tr.extra_us, np.float32)
        lengths[lane] = tr.n_ops
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # fault surcharges ride a written-rows mask over the union dictionary
    # (zero row for the padding identity); all-zero fleets compile the
    # shift out so fault-free runs stay bit-identical
    with_faults = bool(ext.any())
    if with_faults:
        wvec = np.concatenate([combo_written_rows(combos, layout),
                               np.zeros((1, layout.n_state), np.float32)])
        extras_arg, wvec_arg = jnp.asarray(ext), jnp.asarray(wvec)
    else:
        extras_arg = wvec_arg = None
    final = maxplus_fold_many_kernel(
        jnp.asarray(mats), jnp.asarray(gvec), jnp.asarray(idx),
        jnp.asarray(arr), jnp.asarray(init_state(layout)),
        jnp.asarray(lengths), extras=extras_arg, wvec=wvec_arg,
        block_lanes=block_lanes, interpret=interpret,
        with_arrivals=bool(arr.any()))
    end = end_time_from_state(np.asarray(final), layout)
    out = np.empty((b,), np.float64)
    out[np.asarray(order)] = end
    return out


def combo_energy_uj(table, combos, kind) -> np.ndarray:
    """[M, P] phase-energy vector per (class, channel, way, parity) combo
    — the energy twin of ``combo_matrices`` (parity resolved here, so the
    kernel's per-step gather index serves both)."""
    from repro.core.energy import op_phase_energy_uj

    e = op_phase_energy_uj(table, kind)            # [K, 2, P]
    return np.stack([e[k, par] for k, _c, _w, par in combos])


def trace_energy_maxplus(
    tables,                    # OpClassTable | list[OpClassTable]
    trace,                     # OpTrace (shared across the batch)
    kinds,                     # InterfaceKind | list[InterfaceKind]
    *,
    policy: str = "eager",
    use_kernel: bool = True,
    interpret: bool | None = None,
    strategy: str = "sequential",
    segment_len: int = 64,
) -> tuple[np.ndarray, np.ndarray]:
    """(end_us, phase-energy sums in uJ) of one trace under a batch of
    design points ([B] / [B, P], or scalar / [P] for a single table).

    ``strategy="sequential"`` accumulates ``E[idx[t]]`` inside the Pallas
    ``fori_loop`` next to the (max,+) matvec (DESIGN.md §2.4); the
    segmented strategy folds the end time as usual and reduces the
    energy as the plain segment sum it is."""
    single = not isinstance(tables, (list, tuple))
    if single:
        tables, kinds = [tables], [kinds]
    if len(kinds) != len(tables):
        raise ValueError("need one interface kind per op-class table")
    layout, combos, idx, mats, s0, arrivals, gvec, extras, wvec = \
        _combo_setup(tables, trace, policy)
    e = np.stack([combo_energy_uj(table, combos, kind)
                  for table, kind in zip(tables, kinds)])
    if strategy == "sequential":
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if use_kernel:
            final, acc = maxplus_fold_kernel(
                jnp.asarray(mats), jnp.asarray(s0), t_steps=trace.n_ops,
                idx=jnp.asarray(idx), energy=jnp.asarray(e),
                arrivals=arrivals, gvec=gvec, extras=extras, wvec=wvec,
                interpret=interpret)
        else:
            final = maxplus_fold_ref(jnp.asarray(mats), jnp.asarray(s0),
                                     t_steps=trace.n_ops,
                                     idx=jnp.asarray(idx),
                                     arrivals=arrivals, gvec=gvec,
                                     extras=extras, wvec=wvec)
            acc = jnp.sum(jnp.asarray(e)[:, idx, :], axis=1)
    elif strategy == "segmented":
        final = maxplus_fold(
            jnp.asarray(mats), jnp.asarray(s0), t_steps=trace.n_ops,
            idx=jnp.asarray(idx), strategy="segmented",
            segment_len=segment_len, arrivals=arrivals, gvec=gvec,
            extras=extras, wvec=wvec)
        acc = jnp.sum(jnp.asarray(e)[:, idx, :], axis=1)
    else:
        raise ValueError(f"unknown trace energy strategy {strategy!r} "
                         "(one of 'sequential', 'segmented')")
    end = end_time_from_state(np.asarray(final), layout)
    acc = np.asarray(acc)
    return (end[0], acc[0]) if single else (end, acc)


def trace_bandwidth_maxplus_mb_s(tables, trace, **kw) -> np.ndarray:
    """Aggregate payload bandwidth (MB/s) of a trace per design point."""
    single = not isinstance(tables, (list, tuple))
    end = trace_end_time_maxplus(tables, trace, **kw)
    if single:
        return trace.total_bytes(tables) / end
    data = np.array([trace.total_bytes(t) for t in tables], np.float64)
    return data / np.asarray(end)
