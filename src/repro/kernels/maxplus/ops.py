"""Public op: SSD-channel completion time via the (max,+) Pallas kernel.

``channel_end_time_maxplus`` is a drop-in alternative engine to
``repro.core.sim._channel_end_time`` for batches of design points
(ways must divide MAX_WAYS — the power-of-two sweep grid of the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maxplus_form import (N_STATE, end_time_from_state, init_state,
                                     transition_matrices)
from repro.core.sim import PageOpParams
from repro.kernels.maxplus.kernel import maxplus_fold_kernel
from repro.kernels.maxplus.ref import maxplus_fold_ref


def maxplus_fold(mats, s0, *, t_steps: int, use_kernel: bool = True,
                 interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_kernel:
        return maxplus_fold_kernel(mats, s0, t_steps=t_steps, interpret=interpret)
    return maxplus_fold_ref(mats, s0, t_steps=t_steps)


def channel_end_time_maxplus(
    ops: list[PageOpParams],
    ways: list[int],
    *,
    n_pages: int,
    policy: str = "eager",
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Completion times (us) for a batch of design points."""
    mats = np.stack([transition_matrices(op, w, policy)
                     for op, w in zip(ops, ways)])
    s0 = np.broadcast_to(init_state(), (mats.shape[0], N_STATE)).copy()
    final = maxplus_fold(jnp.asarray(mats), jnp.asarray(s0),
                         t_steps=n_pages, use_kernel=use_kernel,
                         interpret=interpret)
    return end_time_from_state(np.asarray(final))


def bandwidth_maxplus_mb_s(ops, ways, *, n_pages: int = 512,
                           policy: str = "eager", **kw) -> np.ndarray:
    end = channel_end_time_maxplus(ops, ways, n_pages=n_pages, policy=policy, **kw)
    data = np.array([op.data_bytes for op in ops], np.float64)
    return data * n_pages / np.asarray(end)
