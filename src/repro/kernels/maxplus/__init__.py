from repro.kernels.maxplus.ops import (channel_end_time_maxplus,  # noqa: F401
                                       maxplus_fold,
                                       trace_bandwidth_maxplus_mb_s,
                                       trace_end_time_maxplus)
