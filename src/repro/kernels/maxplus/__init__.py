from repro.kernels.maxplus.ops import channel_end_time_maxplus, maxplus_fold  # noqa: F401
