"""Blocked (max,+) trace-indexed matrix fold — Pallas TPU kernel.

Evaluates ``s_T = A_{idx[T-1]} ⊗ … ⊗ A_{idx[1]} ⊗ A_{idx[0]} ⊗ s_0`` for a
batch of independent design points, where the A_i form a per-op-class
matrix dictionary and ``idx`` is the op-class index sequence of a
heterogeneous trace (``repro.core.maxplus_form.trace_combos`` /
``combo_matrices``).
A homogeneous stream passes ``idx=None`` and falls back to the periodic
gather ``idx[t] = t mod M``.  Layout puts the design-point batch in the
128-wide lane dimension:

    mats: [B, M, N, N]  →  kernel block [M, N, N, BL] (lanes = points)
    s:    [B, N]        →  [N, BL]
    idx:  [T] int32     →  SMEM scalar-prefetch operand (whole sequence)

One grid step owns BL=128 design points; the T-step fold runs as a
``fori_loop`` of VPU max/add ops entirely in VMEM, gathering
``A[idx[t]]`` each step (working set M·N²·BL·4B ≈ 5.9 MiB at M=32,
N=19).  This replaces the sequential event loop of the paper's RTL
co-simulation with a data-parallel tensor program — the TPU-native form
of the paper's contribution.  The homogeneous path (``idx=None``)
computes ``t % period`` inline; the trace-indexed path hands ``idx`` to
the grid as a ``pltpu.PrefetchScalarGridSpec`` scalar-prefetch operand,
so the per-step matrix index is read from SMEM and **both paths compile
on TPU** (the previous build fed ``idx`` as a plain VMEM operand, which
lowered only in interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _maxplus_step(mats, i, s):
    a = jax.lax.dynamic_index_in_dim(mats, i, 0, keepdims=False)
    # (max,+) matvec: out[r, b] = max_c (a[r, c, b] + s[c, b])
    return jnp.max(a + s[None, :, :], axis=1)


def _kernel_periodic(mats_ref, s0_ref, out_ref, *, t_steps: int, period: int):
    """Homogeneous stream: matrix index is t % period, computed inline."""
    mats = mats_ref[...]          # [P, N, N, BL]
    out_ref[...] = jax.lax.fori_loop(
        0, t_steps, lambda t, s: _maxplus_step(mats, t % period, s),
        s0_ref[...])


def _kernel_indexed(idx_ref, mats_ref, s0_ref, out_ref, *, t_steps: int):
    """Heterogeneous trace: gather A[idx[t]] per step.  ``idx_ref`` is the
    scalar-prefetch operand — it lives in SMEM and is available before
    the body runs, so the dynamic gather index is a scalar load."""
    mats = mats_ref[...]          # [M, N, N, BL]
    out_ref[...] = jax.lax.fori_loop(
        0, t_steps, lambda t, s: _maxplus_step(mats, idx_ref[t], s),
        s0_ref[...])


@functools.partial(jax.jit, static_argnames=("t_steps", "block_lanes", "interpret"))
def maxplus_fold_kernel(
    mats: jax.Array,     # [B, M, N, N] float32 matrix dictionary
    s0: jax.Array,       # [B, N] float32
    *,
    t_steps: int,
    idx: jax.Array | None = None,   # [t_steps] int32 per-op matrix index
    block_lanes: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, m, n, _ = mats.shape
    bl = min(block_lanes, b)
    pad = (-b) % bl
    if pad:
        mats = jnp.pad(mats, ((0, pad), (0, 0), (0, 0), (0, 0)))
        s0 = jnp.pad(s0, ((0, pad), (0, 0)))
    bp = mats.shape[0]
    mats_l = jnp.moveaxis(mats, 0, -1)   # [M, N, N, B]
    s0_l = jnp.moveaxis(s0, 0, -1)       # [N, B]

    out_shape = jax.ShapeDtypeStruct((n, bp), jnp.float32)
    if idx is None:                      # periodic: no index operand
        kernel = functools.partial(_kernel_periodic, t_steps=t_steps,
                                   period=m)
        out = pl.pallas_call(
            kernel,
            grid=(bp // bl,),
            in_specs=[pl.BlockSpec((m, n, n, bl), lambda i: (0, 0, 0, i)),
                      pl.BlockSpec((n, bl), lambda i: (0, i))],
            out_specs=pl.BlockSpec((n, bl), lambda i: (0, i)),
            out_shape=out_shape,
            interpret=interpret,
        )(mats_l, s0_l)
    else:                                # trace-indexed: idx via SMEM
        kernel = functools.partial(_kernel_indexed, t_steps=t_steps)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bp // bl,),
            in_specs=[pl.BlockSpec((m, n, n, bl),
                                   lambda i, idx_ref: (0, 0, 0, i)),
                      pl.BlockSpec((n, bl), lambda i, idx_ref: (0, i))],
            out_specs=pl.BlockSpec((n, bl), lambda i, idx_ref: (0, i)),
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(idx.astype(jnp.int32), mats_l, s0_l)
    return jnp.moveaxis(out, -1, 0)[:b]  # [B, N]
