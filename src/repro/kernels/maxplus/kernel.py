"""Blocked (max,+) periodic matrix fold — Pallas TPU kernel.

Evaluates ``s_T = A_{T-1} ⊗ … ⊗ A_1 ⊗ A_0 ⊗ s_0`` for a batch of
independent design points, where the A_i repeat with period P
(``repro.core.maxplus_form``).  Layout puts the design-point batch in
the 128-wide lane dimension:

    mats: [B, P, N, N]  →  kernel block [P, N, N, BL] (lanes = points)
    s:    [B, N]        →  [N, BL]

One grid step owns BL=128 design points; the T-step fold runs as a
``fori_loop`` of VPU max/add ops entirely in VMEM (working set
P·N²·BL·4B ≈ 5.3 MiB at P=32, N=18).  This replaces the sequential
event loop of the paper's RTL co-simulation with a data-parallel tensor
program — the TPU-native form of the paper's contribution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.maxplus_form import N_STATE, PERIOD


def _kernel(mats_ref, s0_ref, out_ref, *, t_steps: int, period: int):
    mats = mats_ref[...]          # [P, N, N, BL]
    s0 = s0_ref[...]              # [N, BL]

    def body(t, s):
        a = jax.lax.dynamic_index_in_dim(mats, t % period, 0, keepdims=False)
        # (max,+) matvec: out[r, b] = max_c (a[r, c, b] + s[c, b])
        return jnp.max(a + s[None, :, :], axis=1)

    out_ref[...] = jax.lax.fori_loop(0, t_steps, body, s0)


@functools.partial(jax.jit, static_argnames=("t_steps", "block_lanes", "interpret"))
def maxplus_fold_kernel(
    mats: jax.Array,     # [B, P, N, N] float32
    s0: jax.Array,       # [B, N] float32
    *,
    t_steps: int,
    block_lanes: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, p, n, _ = mats.shape
    bl = min(block_lanes, b)
    pad = (-b) % bl
    if pad:
        mats = jnp.pad(mats, ((0, pad), (0, 0), (0, 0), (0, 0)))
        s0 = jnp.pad(s0, ((0, pad), (0, 0)))
    bp = mats.shape[0]
    mats_l = jnp.moveaxis(mats, 0, -1)   # [P, N, N, B]
    s0_l = jnp.moveaxis(s0, 0, -1)       # [N, B]

    out = pl.pallas_call(
        functools.partial(_kernel, t_steps=t_steps, period=p),
        grid=(bp // bl,),
        in_specs=[
            pl.BlockSpec((p, n, n, bl), lambda i: (0, 0, 0, i)),
            pl.BlockSpec((n, bl), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, bl), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, bp), jnp.float32),
        interpret=interpret,
    )(mats_l, s0_l)
    return jnp.moveaxis(out, -1, 0)[:b]  # [B, N]
