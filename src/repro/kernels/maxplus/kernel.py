"""Blocked (max,+) trace-indexed matrix fold — Pallas TPU kernel.

Evaluates ``s_T = A_{idx[T-1]} ⊗ … ⊗ A_{idx[1]} ⊗ A_{idx[0]} ⊗ s_0`` for a
batch of independent design points, where the A_i form a per-op-class
matrix dictionary and ``idx`` is the op-class index sequence of a
heterogeneous trace (``repro.core.maxplus_form.trace_combos`` /
``combo_matrices``).
A homogeneous stream passes ``idx=None`` and falls back to the periodic
gather ``idx[t] = t mod M``.  Layout puts the design-point batch in the
128-wide lane dimension:

    mats: [B, M, N, N]  →  kernel block [M, N, N, BL] (lanes = points)
    s:    [B, N]        →  [N, BL]
    idx:  [T] int32     →  SMEM scalar-prefetch operand (whole sequence)

One grid step owns BL=128 design points; the T-step fold runs as a
``fori_loop`` of VPU max/add ops entirely in VMEM, gathering
``A[idx[t]]`` each step (working set M·N²·BL·4B ≈ 5.9 MiB at M=32,
N=19).  This replaces the sequential event loop of the paper's RTL
co-simulation with a data-parallel tensor program — the TPU-native form
of the paper's contribution.  The homogeneous path (``idx=None``)
computes ``t % period`` inline; the trace-indexed path hands ``idx`` to
the grid as a ``pltpu.PrefetchScalarGridSpec`` scalar-prefetch operand,
so the per-step matrix index is read from SMEM and **both paths compile
on TPU** (the previous build fed ``idx`` as a plain VMEM operand, which
lowered only in interpret mode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _maxplus_step(mats, i, s):
    a = jax.lax.dynamic_index_in_dim(mats, i, 0, keepdims=False)
    # (max,+) matvec: out[r, b] = max_c (a[r, c, b] + s[c, b])
    return jnp.max(a + s[None, :, :], axis=1)


def _kernel_periodic(mats_ref, s0_ref, out_ref, *, t_steps: int, period: int):
    """Homogeneous stream: matrix index is t % period, computed inline."""
    mats = mats_ref[...]          # [P, N, N, BL]
    out_ref[...] = jax.lax.fori_loop(
        0, t_steps, lambda t, s: _maxplus_step(mats, t % period, s),
        s0_ref[...])


def _arrival_step(mats, g, arr, w, ext, i, t, s):
    """One trace-indexed step with the arrival max-in and the fault
    surcharge: the (max,+) matvec, then
    ``s' = max(A_i ⊗ s, g[i] + arrival[t]) + w[i] * extra[t]`` — the
    augmented origin-column contribution of DESIGN.md §2.6 plus the
    written-rows shift of §2.8 (read-retry/jitter latency extends the
    op's chip occupancy; the bus and serial-ctrl rows are never
    extended).  Zero arrivals are
    the identity of the extra max (A_i already bakes the zero-arrival
    origin column); zero extras add +0.0 to every row — exact, NEG
    included — so fault-free traces stay bit-identical."""
    s = _maxplus_step(mats, i, s)
    gt = jax.lax.dynamic_index_in_dim(g, i, 0, keepdims=False)   # [N, BL]
    at = jax.lax.dynamic_index_in_dim(arr, t, 0, keepdims=False)  # [1]
    s = jnp.maximum(s, gt + at)
    wt = jax.lax.dynamic_index_in_dim(w, i, 0, keepdims=False)   # [N, BL]
    et = jax.lax.dynamic_index_in_dim(ext, t, 0, keepdims=False)  # [1]
    return s + wt * et


def _kernel_indexed(idx_ref, mats_ref, g_ref, arr_ref, w_ref, ext_ref,
                    s0_ref, out_ref, *, t_steps: int):
    """Heterogeneous trace: gather A[idx[t]] per step.  ``idx_ref`` is the
    scalar-prefetch operand — it lives in SMEM and is available before
    the body runs, so the dynamic gather index is a scalar load.
    ``g_ref`` [M, N, BL] holds the per-combo origin-column templates,
    ``arr_ref`` [T, 1] the per-op arrivals, ``w_ref`` [M, N, BL] the
    per-combo written-rows masks and ``ext_ref`` [T, 1] the per-op
    fault surcharges (see ``_arrival_step``)."""
    mats = mats_ref[...]          # [M, N, N, BL]
    g = g_ref[...]                # [M, N, BL]
    arr = arr_ref[...]            # [T, 1]
    w = w_ref[...]                # [M, N, BL]
    ext = ext_ref[...]            # [T, 1]
    out_ref[...] = jax.lax.fori_loop(
        0, t_steps,
        lambda t, s: _arrival_step(mats, g, arr, w, ext, idx_ref[t], t, s),
        s0_ref[...])


def _energy_step(energy, i, acc):
    e = jax.lax.dynamic_index_in_dim(energy, i, 0, keepdims=False)
    return acc + e                # [P, BL] phase accumulator, plain (+)


def _kernel_periodic_energy(mats_ref, e_ref, s0_ref, out_ref, acc_ref, *,
                            t_steps: int, period: int):
    """Periodic fold carrying the phase-energy accumulator per step."""
    mats = mats_ref[...]          # [P, N, N, BL]
    energy = e_ref[...]           # [P, NP, BL]
    s, acc = jax.lax.fori_loop(
        0, t_steps,
        lambda t, c: (_maxplus_step(mats, t % period, c[0]),
                      _energy_step(energy, t % period, c[1])),
        (s0_ref[...], jnp.zeros(acc_ref.shape, acc_ref.dtype)))
    out_ref[...] = s
    acc_ref[...] = acc


def _kernel_indexed_energy(idx_ref, mats_ref, g_ref, arr_ref, w_ref, ext_ref,
                           e_ref, s0_ref, out_ref, acc_ref, *, t_steps: int):
    """Trace-indexed fold accumulating ``E[idx[t]]`` next to the (max,+)
    matvec — matrix, origin-template, written-rows and energy gathers
    all share the same SMEM scalar index."""
    mats = mats_ref[...]          # [M, N, N, BL]
    g = g_ref[...]                # [M, N, BL]
    arr = arr_ref[...]            # [T, 1]
    w = w_ref[...]                # [M, N, BL]
    ext = ext_ref[...]            # [T, 1]
    energy = e_ref[...]           # [M, NP, BL]
    s, acc = jax.lax.fori_loop(
        0, t_steps,
        lambda t, c: (_arrival_step(mats, g, arr, w, ext, idx_ref[t], t,
                                    c[0]),
                      _energy_step(energy, idx_ref[t], c[1])),
        (s0_ref[...], jnp.zeros(acc_ref.shape, acc_ref.dtype)))
    out_ref[...] = s
    acc_ref[...] = acc


def _kernel_fused(nsteps_ref, mats_ref, g_ref, w_ref, idx_ref, arr_ref,
                  ext_ref, s0_ref, out_ref, *, gather: bool,
                  with_arrivals: bool, with_faults: bool):
    """Fused many-trace megakernel: lanes are whole *traces* (one design
    point), not design points of one trace.  Every lane folds its own
    op-class sequence ``idx[:, lane]`` against the one shared matrix
    dictionary, so a fleet of traces is a single ``pallas_call``:

    * per-step per-lane matrix selection is either a row gather
      (``gather=True``, the interpret/CPU path — O(N²·BL) per step) or
      a one-hot ``dot_general`` against the flattened dictionary
      (``gather=False``, the MXU-friendly TPU form, where vector-index
      gathers do not lower).  Both are *exact*: the one-hot contraction
      reproduces the gathered matrix bit-for-bit because its products
      are 1·x and 0·x = ±0.0 and x + (-0.0) = x;
    * index M (the appended (max,+) identity with a NEG origin template,
      a zero written-rows mask and zero arrival/extra) is the padding
      op: shorter lanes run it past their own length as an exact state
      no-op, so no masking is needed;
    * ``with_faults`` gates the per-op fault-surcharge shift
      ``s += w[idx[t]] * extra[t]`` on the written rows (DESIGN.md
      §2.8); fault-free fleets skip the ops entirely, and zero extras
      are exact (+0.0) when only some lanes carry faults;
    * ``nsteps_ref`` (SMEM scalar prefetch, one entry per lane block)
      bounds the fold at the longest lane *in this block* — lanes sorted
      longest-first mean short-trace blocks exit early instead of
      spinning the global maximum.
    """
    mats = mats_ref[...]          # [M1, N, N] shared dictionary
    g = g_ref[...]                # [M1, N] origin templates (NEG at M)
    w = w_ref[...]                # [M1, N] written-rows masks (0 at M)
    idx = idx_ref[...]            # [T, BL] per-lane op-class sequence
    arr = arr_ref[...]            # [T, BL] per-lane arrivals (0 padded)
    ext = ext_ref[...]            # [T, BL] per-lane surcharges (0 padded)
    m1, n, _ = mats.shape
    bl = idx.shape[-1]
    t_steps = nsteps_ref[pl.program_id(0)]

    if gather:
        # lane-major state [BL, N]: the per-step gather lands directly in
        # the layout the matvec consumes, so the only transposes are one
        # on entry and one on exit.  Folding past t_steps up to the next
        # unroll multiple is exact (padding op = (max,+) identity, NEG
        # origin template, zero written rows), so the loop body unrolls
        # to amortise the interpret-mode per-iteration dispatch.
        unroll = 4

        def step(t, s):
            it = jax.lax.dynamic_index_in_dim(idx, t, 0, keepdims=False)
            a = jnp.take(mats, it, axis=0)                    # [BL, N, N]
            s2 = jnp.max(a + s[:, None, :], axis=2)
            if with_arrivals:  # all-zero arrivals are dominated by the
                # baked origin column: skip the ops when absent
                gt = jnp.take(g, it, axis=0)                  # [BL, N]
                at = jax.lax.dynamic_index_in_dim(arr, t, 0,
                                                  keepdims=False)
                s2 = jnp.maximum(s2, gt + at[:, None])
            if with_faults:
                wt = jnp.take(w, it, axis=0)                  # [BL, N]
                et = jax.lax.dynamic_index_in_dim(ext, t, 0,
                                                  keepdims=False)
                s2 = s2 + wt * et[:, None]
            return s2

        def block(k, s):
            for u in range(unroll):
                s = step(k * unroll + u, s)
            return s

        n_blocks = (t_steps + unroll - 1) // unroll
        out_ref[...] = jax.lax.fori_loop(0, n_blocks, block,
                                         s0_ref[...].T).T
        return

    flat = mats.reshape(m1, n * n)
    lanes_iota = jax.lax.broadcasted_iota(jnp.int32, (m1, bl), 0)

    def select(table, it):
        """[M1, D] table -> [D, BL] per-lane rows via one-hot contraction
        (the MXU-friendly TPU form, where vector-index gathers do not
        lower).  Exact: the products are 1*x and 0*x = +/-0.0 and
        x + (-0.0) = x, so it reproduces the gathered rows bit-for-bit."""
        onehot = (lanes_iota == it[None, :]).astype(jnp.float32)
        return jax.lax.dot_general(table, onehot, (((0,), (0,)), ((), ())),
                                   precision=jax.lax.Precision.HIGHEST)

    def step(t, s):
        it = jax.lax.dynamic_index_in_dim(idx, t, 0, keepdims=False)  # [BL]
        a = select(flat, it).reshape(n, n, bl)
        s2 = jnp.max(a + s[None, :, :], axis=1)
        if with_arrivals:
            gt = select(g, it)                                        # [N, BL]
            at = jax.lax.dynamic_index_in_dim(arr, t, 0, keepdims=False)
            s2 = jnp.maximum(s2, gt + at[None, :])
        if with_faults:
            wt = select(w, it)                                        # [N, BL]
            et = jax.lax.dynamic_index_in_dim(ext, t, 0, keepdims=False)
            s2 = s2 + wt * et[None, :]
        return s2

    out_ref[...] = jax.lax.fori_loop(0, t_steps, step, s0_ref[...])


@functools.partial(jax.jit, static_argnames=("block_lanes", "interpret",
                                             "with_arrivals"))
def maxplus_fold_many_kernel(
    mats: jax.Array,      # [M+1, N, N] shared dictionary, identity at M
    gvec: jax.Array,      # [M+1, N] origin templates, NEG row at M
    idx: jax.Array,       # [B, T] int32 per-lane sequence (M = pad no-op)
    arrivals: jax.Array,  # [B, T] float32 per-lane arrivals (0 = none)
    s0: jax.Array,        # [N] shared initial state
    lengths: jax.Array,   # [B] int32 true op count per lane
    *,
    extras: jax.Array | None = None,  # [B, T] per-lane fault surcharges
    wvec: jax.Array | None = None,    # [M+1, N] written-rows, 0 row at M
    block_lanes: int = 128,
    interpret: bool = True,
    with_arrivals: bool = True,
) -> jax.Array:
    """Folded states [B, N] for B independent traces in one launch (see
    ``_kernel_fused``).  Lanes should arrive sorted longest-first so the
    per-block fold bound ``max(lengths[block])`` tracks each block's own
    longest lane.  ``extras`` (with its ``wvec`` written-rows mask)
    carries per-op reliability surcharges; omitted, the fault shift is
    compiled out and fault-free fleets are untouched."""
    m1, n, _ = mats.shape
    b, t = idx.shape
    with_faults = extras is not None
    if extras is None:
        extras = jnp.zeros((b, t), jnp.float32)
    if wvec is None:
        wvec = jnp.zeros((m1, n), jnp.float32)
    tpad = (-t) % 4   # the unrolled fold may read past t_steps up to the
    if tpad:          # next multiple of 4 — pad time with the identity op
        idx = jnp.pad(idx, ((0, 0), (0, tpad)), constant_values=m1 - 1)
        arrivals = jnp.pad(arrivals, ((0, 0), (0, tpad)))
        extras = jnp.pad(extras, ((0, 0), (0, tpad)))
        t += tpad
    bl = min(block_lanes, b)
    pad = (-b) % bl
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)), constant_values=m1 - 1)
        arrivals = jnp.pad(arrivals, ((0, pad), (0, 0)))
        extras = jnp.pad(extras, ((0, pad), (0, 0)))
        lengths = jnp.pad(lengths, (0, pad))
    bp = b + pad
    nsteps = jnp.max(lengths.reshape(bp // bl, bl), axis=1).astype(jnp.int32)

    def tile(block):
        return pl.BlockSpec(block, lambda i, ns: (0,) * (len(block) - 1) + (i,))

    def whole(block):
        return pl.BlockSpec(block, lambda i, ns: (0,) * len(block))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(bp // bl,),
        in_specs=[whole((m1, n, n)), whole((m1, n)), whole((m1, n)),
                  tile((t, bl)), tile((t, bl)), tile((t, bl)),
                  tile((n, bl))],
        out_specs=tile((n, bl)))
    out = pl.pallas_call(
        functools.partial(_kernel_fused, gather=interpret,
                          with_arrivals=with_arrivals,
                          with_faults=with_faults),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, bp), jnp.float32),
        interpret=interpret)(
            nsteps,
            mats.astype(jnp.float32), gvec.astype(jnp.float32),
            wvec.astype(jnp.float32),
            jnp.moveaxis(idx.astype(jnp.int32), 0, -1),
            jnp.moveaxis(arrivals.astype(jnp.float32), 0, -1),
            jnp.moveaxis(extras.astype(jnp.float32), 0, -1),
            jnp.broadcast_to(s0.astype(jnp.float32)[:, None], (n, bp)))
    return jnp.moveaxis(out, -1, 0)[:b]


from repro.core.maxplus_form import NEG  # noqa: E402  the one (max,+) -inf sentinel


@functools.partial(jax.jit, static_argnames=("t_steps", "block_lanes", "interpret"))
def maxplus_fold_kernel(
    mats: jax.Array,     # [B, M, N, N] float32 matrix dictionary
    s0: jax.Array,       # [B, N] float32
    *,
    t_steps: int,
    idx: jax.Array | None = None,   # [t_steps] int32 per-op matrix index
    energy: jax.Array | None = None,  # [B, M, P] per-op phase energies (uJ)
    arrivals: jax.Array | None = None,  # [t_steps] per-op request arrivals
    gvec: jax.Array | None = None,      # [B, M, N] origin-column templates
    extras: jax.Array | None = None,    # [t_steps] per-op fault surcharges
    wvec: jax.Array | None = None,      # [B, M, N] written-rows masks
    block_lanes: int = 128,
    interpret: bool = True,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Returns the folded state [B, N]; with ``energy`` given, also the
    [B, P] phase-energy accumulator ``sum_t energy[idx[t]]`` computed in
    the same ``fori_loop`` (the per-step matrix gather index doubles as
    the energy gather index — DESIGN.md §2.4).

    ``arrivals``/``gvec`` (trace-indexed path only) carry request
    arrival times: each step additionally maxes ``gvec[idx[t]] +
    arrivals[t]`` into the state — the augmented origin-column form of
    DESIGN.md §2.6, keeping the matrix dictionary per-combo instead of
    per-op.  ``extras``/``wvec`` carry per-op reliability surcharges
    shifting each op's written rows after the max-in (DESIGN.md §2.8).
    Omitted, they default to identity values (zero arrivals / NEG
    templates / zero extras / zero masks)."""
    b, m, n, _ = mats.shape
    if (arrivals is not None or gvec is not None or extras is not None
            or wvec is not None) and idx is None:
        raise ValueError("arrivals/gvec/extras/wvec need the trace-indexed "
                         "path (pass idx)")
    bl = min(block_lanes, b)
    pad = (-b) % bl
    if pad:
        mats = jnp.pad(mats, ((0, pad), (0, 0), (0, 0), (0, 0)))
        s0 = jnp.pad(s0, ((0, pad), (0, 0)))
        if energy is not None:
            energy = jnp.pad(energy, ((0, pad), (0, 0), (0, 0)))
        if gvec is not None:
            gvec = jnp.pad(gvec, ((0, pad), (0, 0), (0, 0)))
        if wvec is not None:
            wvec = jnp.pad(wvec, ((0, pad), (0, 0), (0, 0)))
    bp = mats.shape[0]
    mats_l = jnp.moveaxis(mats, 0, -1)   # [M, N, N, B]
    s0_l = jnp.moveaxis(s0, 0, -1)       # [N, B]
    e_l = None if energy is None else jnp.moveaxis(energy, 0, -1)  # [M, P, B]
    np_ = None if energy is None else e_l.shape[1]

    # one spec/operand list per path; the energy operand (and its [P, BL]
    # accumulator output) slot in conditionally so each path is a single
    # pallas_call
    if idx is None:                      # periodic: no index operand
        def spec(block):
            return pl.BlockSpec(block, lambda i: (0,) * (len(block) - 1) + (i,))
        scalar_args = ()
    else:                                # trace-indexed: idx via SMEM
        def spec(block):
            return pl.BlockSpec(
                block, lambda i, idx_ref: (0,) * (len(block) - 1) + (i,))

        def spec_whole(block):           # un-tiled operand (per-op arrivals)
            return pl.BlockSpec(block, lambda i, idx_ref: (0,) * len(block))
        scalar_args = (idx.astype(jnp.int32),)

    in_specs = [spec((m, n, n, bl))]
    operands = [mats_l]
    if idx is not None:
        # the arrival max-in and fault shift run unconditionally on the
        # indexed path — identity defaults keep zero-arrival/zero-fault
        # traces bit-identical
        if gvec is None:
            g_l = jnp.full((m, n, bp), NEG, jnp.float32)
        else:
            g_l = jnp.moveaxis(gvec, 0, -1)            # [M, N, B]
        arr2d = (jnp.zeros((t_steps, 1), jnp.float32) if arrivals is None
                 else arrivals.astype(jnp.float32).reshape(t_steps, 1))
        if wvec is None:
            w_l = jnp.zeros((m, n, bp), jnp.float32)
        else:
            w_l = jnp.moveaxis(wvec, 0, -1)            # [M, N, B]
        ext2d = (jnp.zeros((t_steps, 1), jnp.float32) if extras is None
                 else extras.astype(jnp.float32).reshape(t_steps, 1))
        in_specs += [spec((m, n, bl)), spec_whole((t_steps, 1)),
                     spec((m, n, bl)), spec_whole((t_steps, 1))]
        operands += [g_l, arr2d, w_l, ext2d]
    if energy is not None:
        in_specs.append(spec((m, np_, bl)))
        operands.append(e_l)
    in_specs.append(spec((n, bl)))
    operands.append(s0_l)
    out_specs = spec((n, bl))
    out_shape = jax.ShapeDtypeStruct((n, bp), jnp.float32)
    if energy is not None:
        out_specs = [out_specs, spec((np_, bl))]
        out_shape = [out_shape, jax.ShapeDtypeStruct((np_, bp), jnp.float32)]

    body = {(True, False): _kernel_periodic,
            (True, True): _kernel_periodic_energy,
            (False, False): _kernel_indexed,
            (False, True): _kernel_indexed_energy}[
                (idx is None, energy is not None)]
    kw = {"period": m} if idx is None else {}
    kernel = functools.partial(body, t_steps=t_steps, **kw)
    if idx is None:
        call = pl.pallas_call(kernel, grid=(bp // bl,), in_specs=in_specs,
                              out_specs=out_specs, out_shape=out_shape,
                              interpret=interpret)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(bp // bl,), in_specs=in_specs,
            out_specs=out_specs)
        call = pl.pallas_call(kernel, grid_spec=grid_spec,
                              out_shape=out_shape, interpret=interpret)
    res = call(*scalar_args, *operands)
    if energy is None:
        return jnp.moveaxis(res, -1, 0)[:b]  # [B, N]
    out, acc = res
    return (jnp.moveaxis(out, -1, 0)[:b],
            jnp.moveaxis(acc, -1, 0)[:b])    # [B, N], [B, P]
