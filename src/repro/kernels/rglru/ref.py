"""Pure-jnp oracle: jax.lax.associative_scan over the affine maps."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t · h_{t-1} + b_t with h_0 = 0; a, b: [B, S, R]."""

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(
        combine, (a.astype(jnp.float32), b.astype(jnp.float32)), axis=1)
    return h.astype(a.dtype)
