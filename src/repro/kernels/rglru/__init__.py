from repro.kernels.rglru.ops import rglru_linear_scan  # noqa: F401
