"""Public op for the RG-LRU blocked linear scan."""

from __future__ import annotations

import jax

from repro.kernels.rglru.kernel import rglru_scan_kernel
from repro.kernels.rglru.ref import rglru_scan_ref


def rglru_linear_scan(a, b, *, use_kernel: bool = True, block_s: int = 256,
                      interpret: bool | None = None):
    """h_t = a_t h_{t-1} + b_t over axis 1; a, b: [B, S, R]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if not use_kernel:
        return rglru_scan_ref(a, b)
    return rglru_scan_kernel(a, b, block_s=block_s, interpret=interpret)
