"""RG-LRU linear recurrence ``h_t = a_t·h_{t-1} + b_t`` — Pallas TPU kernel.

Grid ``(B, R/BL, S/BS)`` with the sequence dimension innermost
(sequential on TPU).  Each step scans one ``[BS, BL]`` tile:

* intra-tile: Hillis–Steele inclusive scan over the affine maps
  ``(a, b)`` — log₂(BS) fully-vectorised VPU passes (no per-row loop);
* inter-tile: the 128-wide carry ``h`` lives in VMEM scratch and chains
  tiles, exactly like the flash-attention accumulator.

This is the TPU-native blocked form of ``jax.lax.associative_scan``
(the pure-jnp oracle in ``ref.py``) with an O(S·log BS / BS) depth
instead of O(S) — and it is the same shape the mLSTM/SSM family needs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_ref, *, bs: int, bl: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)      # [BS, BL]
    b = b_ref[0].astype(jnp.float32)

    # Hillis–Steele inclusive scan of affine maps along the tile rows:
    # (a, b)[i] <- (a, b)[i-d] ⊕ (a, b)[i]  with ⊕ = compose-later
    d = 1
    while d < bs:
        a_sh = jnp.concatenate([jnp.ones((d, bl), jnp.float32), a[:-d]], axis=0)
        b_sh = jnp.concatenate([jnp.zeros((d, bl), jnp.float32), b[:-d]], axis=0)
        b = b_sh * a + b
        a = a_sh * a
        d *= 2

    h0 = h_ref[...]
    h = a * h0[None, :] + b               # apply carry to every row
    o_ref[0] = h.astype(o_ref.dtype)
    h_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("block_s", "block_l", "interpret"))
def rglru_scan_kernel(
    a: jax.Array,   # [B, S, R] decay in (0, 1]
    b: jax.Array,   # [B, S, R] input term
    *,
    block_s: int = 256,
    block_l: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bsz, s, r = a.shape
    bs = min(block_s, s)
    bl = min(block_l, r)
    assert s % bs == 0 and r % bl == 0, (s, bs, r, bl)

    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, bl=bl),
        grid=(bsz, r // bl, s // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bl), lambda b_, rb, sb: (b_, sb, rb)),
            pl.BlockSpec((1, bs, bl), lambda b_, rb, sb: (b_, sb, rb)),
        ],
        out_specs=pl.BlockSpec((1, bs, bl), lambda b_, rb, sb: (b_, sb, rb)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, r), a.dtype),
        scratch_shapes=[pltpu.VMEM((bl,), jnp.float32)],
        interpret=interpret,
    )(a, b)
