"""StarCoder2-3B [arXiv:2402.19173; hf]. GQA kv=2, RoPE, LayerNorm+bias, GELU MLP."""

from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    d_model=3072, n_layers=30, vocab_size=49152,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=24, n_kv_heads=2, head_dim=128, qkv_bias=True,
    rope_kind="rope", rope_theta=999999.44,
    d_ff=12288, act="gelu", ffn_gated=False, mlp_bias=True,
    tie_embeddings=True, norm="ln",
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke",
    d_model=64, n_layers=2, vocab_size=256,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True,
    d_ff=256, act="gelu", ffn_gated=False, mlp_bias=True,
    tie_embeddings=True, norm="ln", remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="arXiv:2402.19173 / hf:bigcode/starcoder2-3b",
            notes="GQA kv=2; classic GELU MLP (non-gated) + LayerNorm with bias.")
