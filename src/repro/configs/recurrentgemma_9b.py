"""RecurrentGemma-9B [arXiv:2402.19427; unverified]. Griffin: RG-LRU + local attn.

38 layers in the Griffin 1:2 pattern (recurrent, recurrent, local-MQA):
12 full (R, R, L) units + a trailing (R, R) — expressed with the model's
``tail`` mechanism so the 36 patterned layers still run as one scan.
Local attention window 2048, MQA (kv=1), GeGLU MLP, Gemma-style
sqrt(d) embedding scaling, tied embeddings.

``long_500k`` RUNS for this arch: decode is O(1) per step for RG-LRU
layers and O(window) for local attention.
"""

import math

from repro.configs.base import Arch, lm_shapes
from repro.models.rglru import RGLRUSpec
from repro.models.transformer import LayerSpec, ModelConfig

WINDOW = 2048

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    d_model=4096, n_layers=38, vocab_size=256000,
    pattern=(LayerSpec(mixer="rglru", ffn="dense"),
             LayerSpec(mixer="rglru", ffn="dense"),
             LayerSpec(mixer="attn", ffn="dense", window=WINDOW)),
    tail=(LayerSpec(mixer="rglru", ffn="dense"),
          LayerSpec(mixer="rglru", ffn="dense")),
    n_heads=16, n_kv_heads=1, head_dim=256,
    rope_kind="rope", rope_theta=10000.0,
    d_ff=12288, act="gelu", ffn_gated=True,
    rglru=RGLRUSpec(d_rnn=4096, n_heads=16, conv_width=4),
    tie_embeddings=True, emb_scale=math.sqrt(4096.0),
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    d_model=64, n_layers=5, vocab_size=256,
    pattern=(LayerSpec(mixer="rglru", ffn="dense"),
             LayerSpec(mixer="rglru", ffn="dense"),
             LayerSpec(mixer="attn", ffn="dense", window=8)),
    tail=(LayerSpec(mixer="rglru", ffn="dense"),
          LayerSpec(mixer="rglru", ffn="dense")),
    n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, act="gelu", ffn_gated=True,
    rglru=RGLRUSpec(d_rnn=64, n_heads=4, conv_width=4),
    tie_embeddings=True, emb_scale=8.0, remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=True),
            source="arXiv:2402.19427 / hf:google/recurrentgemma-9b",
            notes="[hybrid] RG-LRU + local MQA (window 2048) 2:1; tail=(R,R); "
                  "sub-quadratic => long_500k runs.")
