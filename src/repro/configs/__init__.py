from repro.configs.base import Arch, ShapeSpec, input_specs, smoke_batch  # noqa: F401
from repro.configs.registry import ARCH_IDS, all_arches, get_arch  # noqa: F401
