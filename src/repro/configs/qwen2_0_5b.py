"""Qwen2-0.5B [arXiv:2407.10671; hf]. Dense, GQA kv=2, QKV bias, tied embeddings."""

from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    d_model=896, n_layers=24, vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=14, n_kv_heads=2, head_dim=64, qkv_bias=True,
    rope_kind="rope", rope_theta=1e6,
    d_ff=4864, act="silu", ffn_gated=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke",
    d_model=64, n_layers=2, vocab_size=256,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True,
    rope_kind="rope", rope_theta=1e6,
    d_ff=128, act="silu", ffn_gated=True,
    tie_embeddings=True, remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="arXiv:2407.10671 / hf:Qwen/Qwen2-0.5B",
            notes="GQA kv=2; QKV bias; RoPE theta 1e6; tied embeddings.")
