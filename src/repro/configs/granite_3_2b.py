"""Granite-3.0-2B-base [hf:ibm-granite/granite-3.0-2b-base]. Dense GQA kv=8."""

from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    d_model=2048, n_layers=40, vocab_size=49155,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=32, n_kv_heads=8, head_dim=64,
    rope_kind="rope", rope_theta=10000.0,
    d_ff=8192, act="silu", ffn_gated=True,
    tie_embeddings=True,
    emb_scale=12.0, residual_scale=0.22, logit_scale=1.0 / 8.0,
)

SMOKE = ModelConfig(
    name="granite-3-2b-smoke",
    d_model=64, n_layers=2, vocab_size=256,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=256, act="silu", ffn_gated=True,
    tie_embeddings=True, emb_scale=12.0, residual_scale=0.22,
    logit_scale=1.0 / 8.0, remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="hf:ibm-granite/granite-3.0-2b-base",
            notes="GQA kv=8; Granite power-scaling multipliers.")
