"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib

from repro.configs.base import Arch

_MODULES = {
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(name: str) -> Arch:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {', '.join(ARCH_IDS)}")
    return importlib.import_module(_MODULES[name]).ARCH


def all_arches() -> dict[str, Arch]:
    return {name: get_arch(name) for name in ARCH_IDS}
