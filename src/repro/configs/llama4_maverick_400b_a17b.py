"""Llama-4-Maverick-400B-A17B [hf:meta-llama (Scout/Maverick family); unverified].

MoE every other layer (interleave step 2): 24 MoE layers × 128 routed
experts (top-1, sigmoid router) + 1 shared expert, dense layers with the
larger ``intermediate_size_mlp``.  ≈400B total / ≈17B active parameters.
The modality "early fusion" frontend is out of scope for the LM backbone
cell (text path only), per the assignment.
"""

from repro.configs.base import Arch, lm_shapes
from repro.models.moe import MoESpec
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    d_model=5120, n_layers=48, vocab_size=202048,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),
             LayerSpec(mixer="attn", ffn="moe")),
    n_heads=40, n_kv_heads=8, head_dim=128,
    rope_kind="rope", rope_theta=500000.0,
    d_ff=16384,  # dense-layer MLP width (intermediate_size_mlp)
    act="silu", ffn_gated=True,
    moe=MoESpec(n_experts=128, top_k=1, d_ff=8192, shared_d_ff=8192,
                capacity_factor=1.25, router_scale="sigmoid"),
    fsdp_units=True,   # ~400B params: stacked-unit axis sharded over 'data' (ZeRO-3)
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    d_model=64, n_layers=2, vocab_size=256,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),
             LayerSpec(mixer="attn", ffn="moe")),
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, act="silu", ffn_gated=True,
    moe=MoESpec(n_experts=8, top_k=1, d_ff=96, shared_d_ff=96,
                capacity_factor=8.0, router_scale="sigmoid"),  # dropless at smoke scale
    remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="hf:meta-llama/Llama-4-Scout-17B-16E (family); assignment sheet",
            notes="MoE 128e top-1 sigmoid router + shared expert; interleaved "
                  "dense/MoE (period 2); GQA kv=8. ~400B total / ~17B active.")
