"""MusicGen-medium [arXiv:2306.05284; hf]. Decoder-only over EnCodec tokens.

Backbone only, per the assignment: the EnCodec frontend is a STUB —
``input_specs()`` supplies precomputed frame embeddings
(``input_mode='embeddings'``, [B, S, d_model]); the four codebooks are
assumed already flattened by the delay-pattern into a single stream, so
the output head predicts one 2048-way codebook per step.  Positional
information is carried by the (precomputed) frame embeddings
(MusicGen uses sinusoidal embeddings added at input — frontend side).
"""

from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    d_model=1536, n_layers=48, vocab_size=2048,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=24, n_kv_heads=24, head_dim=64,
    rope_kind="none",
    d_ff=6144, act="gelu", ffn_gated=False, mlp_bias=True,
    norm="ln", input_mode="embeddings",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    d_model=64, n_layers=2, vocab_size=64,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=4, n_kv_heads=4, head_dim=16,
    rope_kind="none", d_ff=128, act="gelu", ffn_gated=False, mlp_bias=True,
    norm="ln", input_mode="embeddings", remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="arXiv:2306.05284 / hf:facebook/musicgen-medium",
            notes="[audio] backbone-only; EnCodec frontend stubbed as "
                  "precomputed frame embeddings; MHA; vocab=2048 codes.")
