"""Config substrate: shape grid, arch bundles, and dry-run input specs.

Every assigned architecture file exposes:

* ``CONFIG``  — the exact published configuration (full scale),
* ``SMOKE``   — a reduced same-family config for CPU smoke tests,
* ``ARCH``    — an :class:`Arch` bundle tying config + shape grid + notes.

``input_specs`` builds ``jax.ShapeDtypeStruct`` stand-ins for every model
input of a (config × shape) cell — the dry-run lowers against these, so
no real allocation ever happens for full-scale configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, init_cache

# The assigned LM shape grid (seq_len, global_batch).
TRAIN_4K = ("train_4k", "train", 4096, 256)
PREFILL_32K = ("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ("decode_32k", "decode", 32768, 128)
LONG_500K = ("long_500k", "decode", 524288, 1)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int
    skip: str | None = None  # reason string when the cell is N/A


@dataclasses.dataclass(frozen=True)
class Arch:
    config: ModelConfig
    smoke: ModelConfig
    shapes: tuple[ShapeSpec, ...]
    source: str = ""
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.config.name} has no shape {name}")


def lm_shapes(*, long_context: bool, skip_reason: str = "full-attention O(S²) "
              "— long_500k scoped to SSM/hybrid archs per assignment"
              ) -> tuple[ShapeSpec, ...]:
    cells = [ShapeSpec(*TRAIN_4K), ShapeSpec(*PREFILL_32K), ShapeSpec(*DECODE_32K)]
    cells.append(ShapeSpec(*LONG_500K) if long_context
                 else ShapeSpec(*LONG_500K[:4], skip=skip_reason))
    return tuple(cells)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct only — never allocates)
# ---------------------------------------------------------------------------


def _token_spec(b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of this (arch × shape)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.input_mode == "embeddings":
            batch = {"inputs": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype),
                     "labels": _token_spec(b, s)}
        else:
            batch = {"inputs": _token_spec(b, s), "labels": _token_spec(b, s)}
        if cfg.rope_kind == "mrope":
            batch["position_ids"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        if cfg.input_mode == "embeddings":
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdtype)
        else:
            inputs = _token_spec(b, s)
        out = {"inputs": inputs}
        if cfg.rope_kind == "mrope":
            out["position_ids"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
        return out
    # decode: one new token against a cache of seq_len positions
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    if cfg.input_mode == "embeddings":
        inputs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.cdtype)
    else:
        inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    out = {"inputs": inputs, "cache": cache,
           "index": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.rope_kind == "mrope":
        out["position_ids"] = jax.ShapeDtypeStruct((3, b, 1), jnp.int32)
    return out


def smoke_batch(cfg: ModelConfig, *, batch: int = 2, seq: int = 16,
                seed: int = 0) -> dict[str, jax.Array]:
    """A real (allocated) tiny batch for smoke tests."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(k1, (batch, seq, cfg.d_model), cfg.cdtype)
    else:
        inputs = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    labels = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size, jnp.int32)
    out = {"inputs": inputs, "labels": labels}
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
        out["position_ids"] = jnp.broadcast_to(pos[None], (3, batch, seq))
    return out
