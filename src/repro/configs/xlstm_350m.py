"""xLSTM-350M [arXiv:2405.04517; unverified]. mLSTM:sLSTM 7:1 blocks.

24 blocks, d=1024, 4 heads.  ``d_ff=0`` per the assignment: there is no
separate FFN — the mLSTM block carries its own ×2 up/down projection and
the sLSTM block a 4/3-factor gated FF (width rounded to 1408 for mesh
divisibility).  Pattern: 7 mLSTM + 1 sLSTM per unit, 3 units.

Attention-free ⇒ ``long_500k`` runs (O(1)-state decode); the KV-offload
tier of the storage substrate is inapplicable by construction
(``supports_kv_offload=False``) — see DESIGN.md §Arch-applicability.
"""

from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LayerSpec, ModelConfig
from repro.models.xlstm import MLSTMSpec, SLSTMSpec

_M = LayerSpec(mixer="mlstm", ffn="none")
_S = LayerSpec(mixer="slstm", ffn="none")

CONFIG = ModelConfig(
    name="xlstm-350m",
    d_model=1024, n_layers=24, vocab_size=50304,
    pattern=(_M, _M, _M, _M, _M, _M, _M, _S),
    n_heads=4, n_kv_heads=4,
    mlstm=MLSTMSpec(d_inner=2048, n_heads=4, conv_width=4, chunk=256),
    slstm=SLSTMSpec(d=1024, n_heads=4, conv_width=4, d_ff=1408),
    tie_embeddings=False,
    supports_kv_offload=False,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke",
    d_model=64, n_layers=4, vocab_size=256,
    pattern=(_M, _M, _M, _S),
    n_heads=2, n_kv_heads=2,
    mlstm=MLSTMSpec(d_inner=128, n_heads=2, conv_width=4, chunk=8),
    slstm=SLSTMSpec(d=64, n_heads=2, conv_width=4, d_ff=96),
    tie_embeddings=False, supports_kv_offload=False,
    remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=True),
            source="arXiv:2405.04517 (xLSTM[7:1] 350M class)",
            notes="[ssm] attention-free; matrix-memory mLSTM (chunkwise "
                  "parallel) + sequential sLSTM; long_500k runs.")
