"""Qwen2-VL-2B [arXiv:2409.12191; hf]. M-RoPE; vision frontend stubbed.

Backbone-only per the assignment: the dynamic-resolution ViT frontend is
a STUB — ``input_specs()`` provides token ids plus a precomputed
``position_ids [3, B, S]`` tensor (temporal/height/width M-RoPE ids, as
the frontend's patch-merger would emit).  head_dim=128 → M-RoPE sections
(16, 24, 24) frequency pairs.
"""

from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    d_model=1536, n_layers=28, vocab_size=151936,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=12, n_kv_heads=2, head_dim=128, qkv_bias=True,
    rope_kind="mrope", rope_theta=1e6, mrope_sections=(16, 24, 24),
    d_ff=8960, act="silu", ffn_gated=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    d_model=64, n_layers=2, vocab_size=256,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True,
    rope_kind="mrope", rope_theta=1e6, mrope_sections=(2, 3, 3),
    d_ff=128, act="silu", ffn_gated=True,
    tie_embeddings=True, remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="arXiv:2409.12191 / hf:Qwen/Qwen2-VL-2B",
            notes="[vlm] backbone-only; ViT frontend stubbed (position_ids "
                  "provided); M-RoPE (16,24,24); GQA kv=2; QKV bias.")
