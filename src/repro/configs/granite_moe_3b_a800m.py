"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-*-base; assignment].

The assignment sheet specifies **40 experts top-8** (the hf 1b card lists
32; we follow the assignment).  Tiny per-expert FFN (d_ff=512).
"""

from repro.configs.base import Arch, lm_shapes
from repro.models.moe import MoESpec
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    d_model=1536, n_layers=32, vocab_size=49155,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    n_heads=24, n_kv_heads=8, head_dim=64,
    rope_kind="rope", rope_theta=10000.0,
    act="silu", ffn_gated=True,
    moe=MoESpec(n_experts=40, top_k=8, d_ff=512, capacity_factor=1.25),
    tie_embeddings=True,
    emb_scale=12.0, residual_scale=0.22, logit_scale=1.0 / 6.0,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    d_model=64, n_layers=2, vocab_size=256,
    pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    n_heads=4, n_kv_heads=2, head_dim=16,
    act="silu", ffn_gated=True,
    moe=MoESpec(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0),  # dropless at smoke scale
    tie_embeddings=True, emb_scale=12.0, residual_scale=0.22,
    logit_scale=1.0 / 6.0, remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="hf:ibm-granite/granite-3.0-1b-a400m-base (family); assignment sheet",
            notes="MoE 40e top-8, tiny experts (d_ff=512); GQA kv=8.")
