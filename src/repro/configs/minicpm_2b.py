"""MiniCPM-2B [arXiv:2404.06395; hf]. Llama-like dense MHA + mup-style scaling.

The paper's WSD (warmup-stable-decay) LR schedule is wired into the
training recipe (``repro.train.schedules.wsd``) and selected by this
arch's train preset.
"""

import math

from repro.configs.base import Arch, lm_shapes
from repro.models.transformer import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    d_model=2304, n_layers=40, vocab_size=122753,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=36, n_kv_heads=36, head_dim=64,
    rope_kind="rope", rope_theta=10000.0,
    d_ff=5760, act="silu", ffn_gated=True,
    tie_embeddings=True,
    emb_scale=12.0,                           # scale_emb
    residual_scale=1.4 / math.sqrt(40),       # scale_depth / sqrt(L)
    logit_scale=256.0 / 2304.0,               # 1 / (d / dim_model_base)
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke",
    d_model=64, n_layers=2, vocab_size=256,
    pattern=(LayerSpec(mixer="attn", ffn="dense"),),
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=160, act="silu", ffn_gated=True,
    tie_embeddings=True, emb_scale=12.0,
    residual_scale=1.4 / math.sqrt(2), logit_scale=0.25,
    remat="none", param_dtype="f32",
)

ARCH = Arch(config=CONFIG, smoke=SMOKE, shapes=lm_shapes(long_context=False),
            source="arXiv:2404.06395 / hf:openbmb/MiniCPM-2B",
            notes="MHA (kv=36); mup-style emb/residual/logit scaling; WSD schedule.")
