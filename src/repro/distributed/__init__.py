# Keep this package __init__ empty: repro.models.attention imports
# repro.distributed.ctx at module load, and eagerly importing
# partitioning here (which imports repro.models.transformer) would cycle.
