"""Activation-sharding context for model-internal constraints.

Model code is mesh-agnostic; the launcher installs an
:class:`ActivationSharding` context before tracing and blocks like
attention call :func:`constrain` with *logical* dims ('batch', 'seq',
None...).  Outside a context this is a no-op, so unit tests and CPU
examples never touch mesh machinery.
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_RULES: contextvars.ContextVar[tuple[Mesh, dict] | None] = \
    contextvars.ContextVar("activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    """rules: logical dim name -> mesh axis (or axes tuple) or None."""
    token = _RULES.set((mesh, rules))
    try:
        yield
    finally:
        _RULES.reset(token)


def constrain(x: jax.Array, dims: tuple) -> jax.Array:
    """Constrain ``x`` so logical dim i maps per the installed rules."""
    state = _RULES.get()
    if state is None:
        return x
    mesh, rules = state
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axes_for(dim_name, dim_size):
        axes = rules.get(dim_name) if dim_name is not None else None
        if axes is None:
            return None
        axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
        prod = 1
        for a in axes_t:
            prod *= sizes[a]
        return axes if dim_size % prod == 0 else None

    spec = P(*(axes_for(d, s) for d, s in zip(dims, x.shape)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
