"""Fault tolerance: restartable failures, straggler watchdog, elastic mesh.

Production posture on a 1000+-node fleet:

* any step may die (preemption, ICI flap, host OOM) — the trainer
  catches :class:`RestartableFailure`, restores the latest checkpoint
  and replays the data cursor (deterministic pipeline state rides in
  the checkpoint manifest);
* slow steps are detected by :class:`StepWatchdog` (EMA + multiplicative
  threshold; clock injectable for unit tests).  The shipped mitigation
  policy is *skip-and-redistribute*: the event is recorded, the step
  budget extended once, and a persistent straggler escalates to a
  restartable failure so the scheduler can replace the node;
* mesh-shape changes are pure *respecification*: checkpoints are saved
  host-side, so restoring onto a different device count/mesh is just
  ``place_on_mesh`` with the new shardings (tested 8→4→8).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


class RestartableFailure(RuntimeError):
    """A failure the trainer should recover from via checkpoint restart."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    ema_s: float
    action: str


class StepWatchdog:
    """Flags steps slower than ``factor × EMA``; escalates after ``patience``."""

    def __init__(self, *, factor: float = 3.0, patience: int = 3,
                 ema_alpha: float = 0.1, clock: Callable[[], float] = time.monotonic):
        self.factor, self.patience, self.alpha = factor, patience, ema_alpha
        self.clock = clock
        self.ema: float | None = None
        self.strikes = 0
        self.events: list[StragglerEvent] = []
        self._t0: float | None = None

    def start(self) -> None:
        self._t0 = self.clock()

    def stop(self, step: int) -> StragglerEvent | None:
        assert self._t0 is not None
        dt = self.clock() - self._t0
        self._t0 = None
        if self.ema is None:
            self.ema = dt
            return None
        slow = dt > self.factor * self.ema
        # slow steps don't poison the baseline estimate
        if not slow:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
            self.strikes = 0
            return None
        self.strikes += 1
        action = "skip-and-redistribute" if self.strikes < self.patience \
            else "escalate-restart"
        ev = StragglerEvent(step, dt, self.ema, action)
        self.events.append(ev)
        if action == "escalate-restart":
            self.strikes = 0
            raise RestartableFailure(
                f"persistent straggler at step {step}: {dt:.2f}s vs EMA {self.ema:.2f}s")
        return ev


class FailureInjector:
    """Deterministic failure schedule for integration tests / chaos drills."""

    def __init__(self, fail_at_steps: tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RestartableFailure(f"injected failure at step {step}")
