"""Path- and config-aware parameter / activation / cache partitioning.

Maps every leaf of the model state onto the production mesh
(``(pod, data, model)`` multi-pod or ``(data, model)`` single-pod).

**Divisibility-first**: explicit jit shardings must divide exactly (no
GSPMD padding for arguments), and the assigned archs have awkward head /
expert / vocab counts.  Every rule therefore checks divisibility against
the mesh and falls back along a documented chain:

* attention — Megatron head-parallel when the kv-head or query-group
  axis divides the ``model`` axis (recurrentgemma: G=16); otherwise the
  weights replicate over ``model`` and the *sequence* axis of attention
  activations is model-sharded instead (context-parallel style, applied
  by a ``ctx.constrain`` inside the block).  Decode shards the KV-cache
  *sequence* dimension over ``model`` (flash-decode with GSPMD-inserted
  LSE combine).
* MoE — expert-parallel over ``model`` when E divides; otherwise
  Megatron *within* each expert (per-expert d_ff sharded).
* FFN / RG-LRU — classic column/row (Megatron) over ``model``.
* embeddings — vocab padded to a multiple of 256 in-model
  (``ModelConfig.padded_vocab``) then vocab-sharded over ``model``.
* ``fsdp_units`` (llama4) — stacked unit params additionally shard their
  first free divisible dim over ``data`` (ZeRO-3 storage; the scan body
  all-gathers one unit per step, overlapping layer compute).
* ZeRO-1 — optimizer moments/master shard their first free divisible
  dim over ``data``.
* xLSTM mixers — pure DP (tiny weights replicate; 4 heads over 16 would
  not divide anyway); ZeRO-1 still applies.

Design rule inherited from the paper (DESIGN.md §2.1): don't serialise
independent resources on one budget — FSDP weight-gather rides ``data``
while tensor-parallel collectives ride ``model``; the two overlap.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import LayerSpec, ModelConfig

MODEL_AXIS = "model"
FSDP_AXIS = "data"


def axis_size(mesh, name: str) -> int:
    return dict(mesh.shape)[name]   # works for Mesh and AbstractMesh


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n != MODEL_AXIS)


def _layer_spec_for(cfg: ModelConfig, path: str) -> LayerSpec | None:
    m = re.search(r"unit/layer(\d+)", path)
    if m:
        return cfg.pattern[int(m.group(1))]
    m = re.search(r"tail/tail(\d+)", path)
    if m:
        return cfg.tail[int(m.group(1))]
    return None


def _attn_param_spec(cfg: ModelConfig, name: str, tp: int) -> P:
    kvh, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    if kvh % tp == 0:
        kv, gq = MODEL_AXIS, None
    elif g % tp == 0:
        kv, gq = None, MODEL_AXIS
    else:  # replicated weights; sequence-sharded activations instead
        kv = gq = None
    return {
        "wq": P(None, kv, gq, None),
        "wk": P(None, kv, None),
        "wv": P(None, kv, None),
        "wo": P(kv, gq, None, None),
        "bq": P(kv, gq, None),
        "bk": P(kv, None),
        "bv": P(kv, None),
        "bo": P(None),
    }[name]


def _rglru_spec(cfg: ModelConfig, name: str, tp: int) -> P:
    r = cfg.rglru.d_rnn if cfg.rglru else 0
    h = cfg.rglru.n_heads if cfg.rglru else 0
    rm = MODEL_AXIS if r % tp == 0 else None
    hm = MODEL_AXIS if h % tp == 0 else None
    return {
        "wx": P(None, rm), "wy": P(None, rm), "wo": P(rm, None),
        "conv_w": P(None, rm), "conv_b": P(rm),
        "a_gate": P(hm, None, None), "x_gate": P(hm, None, None),
        "a_bias": P(rm), "x_bias": P(rm), "lambda": P(rm),
    }[name]


def _ffn_spec(cfg: ModelConfig, name: str, tp: int) -> P:
    fm = MODEL_AXIS if cfg.d_ff % tp == 0 else None
    return {
        "wi": P(None, fm), "wg": P(None, fm), "wo": P(fm, None),
        "bi": P(fm), "bo": P(None),
    }[name]


def _moe_spec(cfg: ModelConfig, name: str, tp: int) -> P:
    """Expert-parallel when E divides the TP axis; otherwise *capacity-slot*
    parallel: weights replicate (non-divisible expert counts are small
    models) and the [G, E, C, d] dispatch buffer shards its slot axis over
    ``model`` via an activation constraint in ``apply_moe`` — every expert
    einsum stays local and the only collective is the post-combine
    all-reduce of [G, T, d] (same cost as a Megatron FFN).  The previous
    megatron-within-expert fallback (d_ff sharded) forced GSPMD to
    all-reduce the [G, E, C, f] intermediate — ~60× more collective bytes
    (EXPERIMENTS.md §Perf, hillclimb H1)."""
    e = cfg.moe.n_experts
    sf = cfg.moe.shared_d_ff
    sm = MODEL_AXIS if sf % tp == 0 and sf else None
    if cfg.moe_shard_mode == "e_data_f_model":
        # perf variant: experts sharded over 'data' in storage AND compute;
        # GSPMD moves tokens (a2a) instead of gathering expert weights.
        return {
            "router": P(None, None),
            "wi": P(FSDP_AXIS, None, MODEL_AXIS),
            "wg": P(FSDP_AXIS, None, MODEL_AXIS),
            "wo": P(FSDP_AXIS, MODEL_AXIS, None),
            "shared_wi": P(None, sm), "shared_wg": P(None, sm),
            "shared_wo": P(sm, None),
        }[name]
    if cfg.moe_shard_mode == "f_model":
        # legacy megatron-within-expert fallback, kept selectable so the
        # H1 hillclimb baseline stays reproducible (EXPERIMENTS.md §Perf)
        fm = MODEL_AXIS if cfg.moe.d_ff % tp == 0 else None
        return {
            "router": P(None, None),
            "wi": P(None, None, fm), "wg": P(None, None, fm), "wo": P(None, fm, None),
            "shared_wi": P(None, sm), "shared_wg": P(None, sm),
            "shared_wo": P(sm, None),
        }[name]
    ew = MODEL_AXIS if e % tp == 0 else None
    return {
        "router": P(None, None),
        "wi": P(ew, None, None), "wg": P(ew, None, None), "wo": P(ew, None, None),
        "shared_wi": P(None, sm), "shared_wg": P(None, sm), "shared_wo": P(sm, None),
    }[name]


def _leaf_param_spec(cfg: ModelConfig, path: str, ndim: int, tp: int) -> P:
    """Spec for the *unstacked* view of the leaf (``ndim`` excludes any
    leading unit axis)."""
    name = path.rsplit("/", 1)[-1]
    if path.startswith("embed/"):
        return P(MODEL_AXIS, None)   # vocab padded to ×256 => always divides
    if path.startswith("head/"):
        return P(None, MODEL_AXIS)
    if "norm" in path or path.startswith("final_norm"):
        return P(*([None] * ndim))
    spec = _layer_spec_for(cfg, path)
    if spec is None:
        return P(*([None] * ndim))
    if "/mixer/" in path:
        if spec.mixer == "attn":
            return _attn_param_spec(cfg, name, tp)
        if spec.mixer == "rglru":
            return _rglru_spec(cfg, name, tp)
        return P(*([None] * ndim))   # mlstm/slstm: replicated (pure DP)
    if "/ffn/" in path:
        if spec.ffn == "moe":
            return _moe_spec(cfg, name, tp)
        return _ffn_spec(cfg, name, tp)
    return P(*([None] * ndim))


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _insert_axis(spec: P, shape: tuple[int, ...], axis: str, divisor: int,
                 start_dim: int = 0) -> P:
    """Add ``axis`` on the first free exactly-divisible dim ≥ start_dim.
    No-op if the axis already shards some dim (a mesh axis may appear in
    at most one position of a PartitionSpec)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for e in parts:
        used = (e,) if isinstance(e, str) or e is None else tuple(e)
        if axis in used:
            return P(*parts)
    for i in range(start_dim, len(shape)):
        if parts[i] is None and shape[i] % divisor == 0 and shape[i] > 1:
            parts[i] = axis
            return P(*parts)
    return P(*parts)


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shape: Any) -> Any:
    """PartitionSpec pytree matching ``params_shape`` (arrays or SDS)."""
    tp = axis_size(mesh, MODEL_AXIS)
    fsdp = axis_size(mesh, FSDP_AXIS)

    def spec_of(key_path, leaf):
        path = _path_str(key_path)
        stacked = path.startswith("unit/")
        base = _leaf_param_spec(cfg, path, leaf.ndim - (1 if stacked else 0), tp)
        if stacked:
            base = P(None, *base)     # stacked unit axis in front
            if cfg.fsdp_units:
                base = _insert_axis(base, leaf.shape, FSDP_AXIS, fsdp, start_dim=1)
        elif cfg.fsdp_units and not path.startswith(("embed/", "head/")):
            base = _insert_axis(base, leaf.shape, FSDP_AXIS, fsdp)
        return base

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def zero1_spec(spec: P, shape: tuple[int, ...], divisor: int) -> P:
    """Extra 'data' sharding for optimizer state (first free divisible dim)."""
    return _insert_axis(spec, shape, FSDP_AXIS, divisor)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------


def batch_axes(mesh, batch_size: int) -> tuple[str, ...] | None:
    """DP axes to shard a batch dim over (largest prefix that divides)."""
    axes = dp_axes(mesh)
    sizes = dict(mesh.shape)
    for cand in (axes, axes[1:] if len(axes) > 1 else ()):
        if not cand:
            continue
        prod = 1
        for a in cand:
            prod *= sizes[a]
        if batch_size % prod == 0:
            return cand
    return None


def activation_rules(cfg: ModelConfig, mesh: Mesh, batch_size: int) -> dict:
    """Logical-dim rules consumed by repro.distributed.ctx.

    'seq' maps to the model axis only when attention weights could NOT be
    head-sharded (context-parallel fallback); otherwise constraining the
    sequence would conflict with Megatron head parallelism.
    """
    tp = axis_size(mesh, MODEL_AXIS)
    g = cfg.n_heads // cfg.n_kv_heads
    head_tp = (cfg.n_kv_heads % tp == 0) or (g % tp == 0)
    moe_slot = cfg.moe is not None and cfg.moe.n_experts % tp != 0
    return {"batch": batch_axes(mesh, batch_size),
            "seq": None if head_tp else MODEL_AXIS,
            "moe_cap": MODEL_AXIS if moe_slot else None}


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch: Any) -> Any:
    """Specs for a train/prefill batch dict (leading batch dim sharded).
    ``position_ids`` has layout [3, B, S] — batch on axis 1."""

    def spec_of(key_path, leaf):
        path = _path_str(key_path)
        bdim = 1 if path.endswith("position_ids") else 0
        axes = batch_axes(mesh, leaf.shape[bdim])
        parts: list = [None] * leaf.ndim
        parts[bdim] = axes
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_of, batch)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_shape: Any) -> Any:
    """Decode-state specs: batch over DP; long (seq / width) dims over model.

    KV caches shard the *sequence* slot axis over ``model`` (flash-decode:
    GSPMD inserts the log-sum-exp style combine for the sharded-softmax);
    recurrent states shard their feature width when divisible.
    """
    tp = axis_size(mesh, MODEL_AXIS)

    def spec_of(key_path, leaf):
        path = _path_str(key_path)
        stacked = path.startswith("unit/")
        name = path.rsplit("/", 1)[-1]
        dims: list = [None] * leaf.ndim
        bdim = 1 if stacked else 0
        dims[bdim] = batch_axes(mesh, leaf.shape[bdim])
        if name in ("k", "v"):                       # [.., B, kvH, S, Dh]
            if leaf.shape[bdim + 2] % tp == 0:
                dims[bdim + 2] = MODEL_AXIS
        elif name == "pos":                          # [.., B, S]
            if leaf.shape[bdim + 1] % tp == 0:
                dims[bdim + 1] = MODEL_AXIS
        elif name in ("h", "c", "n", "m", "C", "conv"):
            if leaf.shape[-1] % tp == 0 and leaf.shape[-1] > 1:
                dims[-1] = MODEL_AXIS
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec_of, cache_shape)


def shardings(mesh: Mesh, pspecs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# design-point sweep sharding (simulator batches)
# ---------------------------------------------------------------------------

POINTS_AXIS = "points"


def points_spec(ndim: int) -> P:
    """Leading axis over ``points``, everything else replicated."""
    return P(POINTS_AXIS, *([None] * (ndim - 1)))


def shard_points(mesh: Mesh, fn, *, n_sharded: int):
    """Wrap a batched-over-leading-axis ``fn`` with ``jax.shard_map``
    over the 1-D ``("points",)`` sweep mesh (``launch.mesh.
    make_points_mesh``): the first ``n_sharded`` arguments shard their
    leading axis across devices, the rest (shared trace arrays)
    replicate, and the [B] output gathers back.

    The batch pads to a device multiple by repeating row 0 — padded
    rows simulate harmless garbage that is sliced off before returning,
    so callers see exactly their B results.  The shard_map program is
    built (and jitted) once per argument-rank signature and reused, so
    repeated sweeps through one wrapper stay retrace-free."""
    from jax.experimental.shard_map import shard_map

    size = axis_size(mesh, POINTS_AXIS)
    compiled: dict[tuple[int, ...], Any] = {}

    def call(*arrays):
        arrays = tuple(jnp.asarray(a) for a in arrays)
        n = int(arrays[0].shape[0])
        pad = -n % size
        if pad:
            head = arrays[:n_sharded]
            arrays = tuple(
                jnp.concatenate([a, jnp.repeat(a[:1], pad, axis=0)])
                for a in head) + arrays[n_sharded:]
        key = tuple(a.ndim for a in arrays)
        sm = compiled.get(key)
        if sm is None:
            specs = tuple(
                points_spec(a.ndim) if i < n_sharded
                else P(*([None] * a.ndim))
                for i, a in enumerate(arrays))
            sm = compiled[key] = jax.jit(shard_map(
                fn, mesh=mesh, in_specs=specs,
                out_specs=P(POINTS_AXIS)))
        return sm(*arrays)[:n]

    return call
