"""Gradient compression for data-parallel reduction.

``compressed_psum`` performs an exact-sum int8 all-reduce: a shared
scale is agreed via a (cheap, scalar) ``psum``-max of local absmaxes,
locals are quantised to int8, summed in int32, and descaled — wire
bytes drop 4× (fp32) / 2× (bf16) per gradient with *deterministic*
semantics (no per-shard scale mixing).

``ErrorFeedback`` implements EF21-style residual accumulation so the
quantisation error is re-injected next step — with it, compressed SGD
retains the uncompressed fixed points.  The trainer enables both with
``grad_compression='int8'`` (applied inside a ``shard_map`` over the DP
axes).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _q8_psum(g: jax.Array, axis) -> jax.Array:
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(absmax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return total.astype(jnp.float32) * scale


def compressed_psum(grads: Any, axis) -> Any:
    """int8-wire psum of a gradient pytree along a mapped axis name."""
    return jax.tree.map(lambda g: _q8_psum(g.astype(jnp.float32), axis), grads)


def make_dp_grad_sync(mesh: Mesh, axis: str = "data", compress: bool = True):
    """shard_map'd gradient synchroniser over the DP axis.

    Expects per-device *partial* gradients (replicated-shaped pytree with
    unsummed values); returns the synchronised mean.
    """

    def sync(grads):
        n = jax.lax.psum(jnp.ones(()), axis)
        if compress:
            summed = compressed_psum(grads, axis)
        else:
            summed = jax.tree.map(lambda g: jax.lax.psum(g, axis), grads)
        return jax.tree.map(lambda g: g / n, summed)

    def wrapped(grads):
        specs = jax.tree.map(lambda _: P(), grads)
        return shard_map(sync, mesh=mesh, in_specs=(specs,), out_specs=specs)(grads)

    return wrapped


class ErrorFeedback:
    """EF21 residual state: e' = g + e - C(g + e); apply C(g+e) instead of g."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def compress(grads: Any, residual: Any) -> tuple[Any, Any]:
        def one(g, e):
            x = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
            cx = jnp.round(x / scale).astype(jnp.int8).astype(jnp.float32) * scale
            return cx, x - cx

        pairs = jax.tree.map(one, grads, residual)
        compressed = jax.tree.map(lambda p: p[0], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_res = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return compressed, new_res
