"""KV-cache SSD-offload planning for long-context decode (paper tie-in).

For the 500k-token decode shape the KV/recurrent state may exceed HBM;
a production serving tier pages cold KV blocks to local SSD.  Whether
that is *feasible* is exactly the paper's question: per decoded token
the tier must stream ``bytes_per_token`` back under the latency budget,
so the sustained read bandwidth of the SSD interface bounds tokens/s.
This module sizes the state per architecture, emits the decode loop's
actual **op trace** — a cold-KV read burst plus a small KV-append write
burst per token, striped over the tier's channels — and prices it on the
joint multi-channel simulation (CONV / SYNC_ONLY / PROPOSED): the DDR
interface (PROPOSED) roughly doubles the feasible paging rate at equal
pin count (paper Table 3 read rows), and the mixed read/write contention
of the append stream is now simulated rather than ignored.

For attention-free architectures (xLSTM) the recurrent state is O(1)
per layer and never needs paging: ``plan.applicable = False``
(DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

from repro.core.nand import CellType
from repro.core.sched import lower_static
from repro.core.sim import SSDConfig
from repro.core.trace import OpTrace
from repro.core.workload import RequestStream, kvoffload_requests
from repro.models.transformer import ModelConfig
from repro.storage.ssd_model import estimate_trace_interfaces


@dataclasses.dataclass(frozen=True)
class KVOffloadPlan:
    applicable: bool
    state_bytes_per_seq: int          # total cached state for one sequence
    hot_bytes_per_seq: int            # must stay in HBM (windows, recur state)
    cold_bytes_per_seq: int           # pageable to SSD
    read_mb_per_token: float          # SSD traffic per decoded token
    tokens_per_s: dict[str, float]    # interface -> sustainable decode rate
    trace: OpTrace | None = None      # per-token op trace (window)
    requests: RequestStream | None = None   # placement-free workload window
    note: str = ""


def kv_bytes_per_token(cfg: ModelConfig) -> tuple[int, int]:
    """(hot, cold) cache bytes added per token for one sequence."""
    hot = cold = 0
    dtype_bytes = 2  # bf16 cache
    for spec in tuple(cfg.pattern) + tuple(cfg.tail):
        if spec.mixer != "attn":
            continue  # recurrent state is O(1), stays hot
        per_tok = 2 * cfg.n_kv_heads * cfg.hd * dtype_bytes
        if spec.window is not None:
            hot += 0          # ring buffer is O(window), not per-token
        else:
            cold += per_tok
    reps = cfg.num_units
    # pattern counts once per unit
    per_unit_cold = cold
    return hot, per_unit_cold * reps


def plan_kv_offload(cfg: ModelConfig, seq_len: int, *,
                    latency_budget_ms: float = 50.0,
                    channels: int = 4, ways: int = 8,
                    cell: CellType = CellType.MLC) -> KVOffloadPlan:
    hot_rate, cold_rate = kv_bytes_per_token(cfg)
    if cold_rate == 0:
        return KVOffloadPlan(
            applicable=False, state_bytes_per_seq=0, hot_bytes_per_seq=0,
            cold_bytes_per_seq=0, read_mb_per_token=0.0, tokens_per_s={},
            note=f"{cfg.name}: attention-free / windowed-only — state is "
                 f"O(1)/O(window) per layer; KV offload inapplicable.")
    cold_total = cold_rate * seq_len
    # decode touches the whole cold KV once per token (full-attention read)
    # and appends one token's KV — a mixed read/write trace per token
    read_mb = cold_total / 1e6
    per_token_mb = (cold_total + cold_rate) / 1e6   # read burst + KV append
    # the decode loop is a request-level workload (read burst + append
    # writes per token); the stripe lowering depends only on
    # geometry/cell, not on the interface kind, so one fan-out through
    # the cached Simulator sessions prices the mixed window's sustained
    # rate under all three interfaces
    base = SSDConfig(cell=cell, channels=channels, ways=ways)
    requests = kvoffload_requests(cold_total, base, n_tokens=2,
                                  append_bytes_per_token=cold_rate)
    trace = lower_static(requests, base.channels, base.ways).trace
    rates = {kind: est.bandwidth_mb_s / per_token_mb
             for kind, est in estimate_trace_interfaces(trace, base).items()}
    return KVOffloadPlan(
        applicable=True,
        state_bytes_per_seq=cold_total,
        hot_bytes_per_seq=hot_rate * seq_len,
        cold_bytes_per_seq=cold_total,
        read_mb_per_token=read_mb,
        tokens_per_s=rates,
        trace=trace,
        requests=requests,
        note=f"{cfg.name}: full-attention KV {cold_total/2**30:.1f} GiB/seq at "
             f"S={seq_len}; PROPOSED sustains "
             f"{rates['proposed']:.2f} tok/s vs CONV {rates['conv']:.2f}.")
