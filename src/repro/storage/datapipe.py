"""Training data pipeline with DDR-style double-buffered prefetch.

The pipeline mirrors the paper's interface stack one level up:

* **striping** — the token store is split across ``channels`` backing
  files, read by independent reader threads;
* **way interleaving** — each reader keeps ``ways`` outstanding chunk
  requests (round-robin over its shard list) so decode/copy overlaps IO;
* **DDR** — a ``2×ways``-deep prefetch queue feeds the training loop on
  both "edges" (producer and consumer never serialize on one buffer) —
  the loop's ``next()`` should never block on a healthy tier.

Deterministic resume: the cursor (global step) fully determines every
batch (synthetic: counter-keyed PRNG; file-backed: affine cursor →
offsets), so checkpoint manifests only carry ``{"cursor": int}``.
Hedged reads (straggler mitigation): if a chunk read exceeds
``hedge_ms``, the request is re-issued to a replica path and the first
response wins.
"""

from __future__ import annotations

import dataclasses
import pathlib
import queue
import threading
import time
from typing import Iterator

import numpy as np

from repro.core.sched import lower_static
from repro.core.sim import MAX_CHANNELS, SSDConfig
from repro.core.trace import OpTrace
from repro.core.workload import RequestStream, datapipe_requests


@dataclasses.dataclass
class PipeState:
    cursor: int


def _pipe_ssd(pipe, ssd: SSDConfig | None) -> SSDConfig:
    # a store may have more shards than the modeled SSD has channels
    return ssd or SSDConfig(channels=min(len(pipe.store.maps), MAX_CHANNELS),
                            ways=pipe.ways)


def pipeline_io_requests(pipe, n_batches: int,
                         ssd: SSDConfig | None = None
                         ) -> RequestStream | None:
    """The request-level workload behind ``n_batches`` of a pipeline's
    reads: one read request per page with the pipe's *observed* hedge
    rate as non-payload duplicate requests — the placement-free input
    the scheduler layer lowers (or dispatches dynamically) onto a tier
    geometry.  Synthetic pipelines do no I/O and return None."""
    if not isinstance(pipe, FileBackedTokens):
        return None
    ssd = _pipe_ssd(pipe, ssd)
    nbytes = n_batches * pipe.batch * (pipe.seq + 1) * 4   # int32 tokens
    served = max(1, pipe.cursor * pipe.batch)
    hedge = min(1.0, pipe.hedged_reads / served)
    return datapipe_requests(nbytes, ssd, hedge_fraction=hedge)


def pipeline_io_trace(pipe, n_batches: int,
                      ssd: SSDConfig | None = None) -> OpTrace | None:
    """``pipeline_io_requests`` lowered by the static stripe scheduler —
    the placed input for ``repro.storage.ssd_model.estimate_trace`` /
    trace-aware geometry planning (both served by the cached per-config
    ``repro.api.Simulator`` sessions, so re-pricing a live pipe every
    few batches is cheap).  Synthetic pipelines return None."""
    requests = pipeline_io_requests(pipe, n_batches, ssd)
    if requests is None:
        return None
    ssd = _pipe_ssd(pipe, ssd)
    return lower_static(requests, ssd.channels, ssd.ways).trace


class SyntheticTokens:
    """Counter-keyed deterministic token stream (CPU-cheap, resumable)."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.cursor = 0

    def state(self) -> PipeState:
        return PipeState(self.cursor)

    def restore(self, st: PipeState) -> None:
        self.cursor = st.cursor

    def _batch(self, idx: int) -> dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(key=self.seed, counter=[0, 0, 0, idx]))
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1), dtype=np.int32)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            b = self._batch(self.cursor)
            self.cursor += 1
            yield b


class StripedTokenStore:
    """File-backed store: tokens striped over ``channels`` .npy shards."""

    def __init__(self, directory: str | pathlib.Path):
        self.dir = pathlib.Path(directory)
        self.shards = sorted(self.dir.glob("shard_*.npy"))
        if not self.shards:
            raise FileNotFoundError(f"no shard_*.npy under {directory}")
        self.maps = [np.load(s, mmap_mode="r") for s in self.shards]
        self.tokens_per_shard = len(self.maps[0])

    @classmethod
    def write(cls, directory, tokens: np.ndarray, channels: int = 4):
        d = pathlib.Path(directory)
        d.mkdir(parents=True, exist_ok=True)
        per = len(tokens) // channels
        for c in range(channels):
            np.save(d / f"shard_{c:03d}.npy", tokens[c * per:(c + 1) * per])
        return cls(d)

    def read_chunk(self, shard: int, offset: int, n: int) -> np.ndarray:
        m = self.maps[shard % len(self.maps)]
        offset = offset % max(1, len(m) - n)
        return np.asarray(m[offset:offset + n])


class FileBackedTokens:
    """Batches from a striped store with interleaved, hedged, prefetched reads."""

    def __init__(self, store: StripedTokenStore, batch: int, seq: int, *,
                 ways: int = 4, hedge_ms: float = 50.0):
        self.store, self.batch, self.seq = store, batch, seq
        self.ways, self.hedge_ms = ways, hedge_ms
        self.cursor = 0
        self.hedged_reads = 0
        self._q: queue.Queue = queue.Queue(maxsize=2 * ways)  # DDR: 2 edges
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def state(self) -> PipeState:
        return PipeState(self.cursor)

    def restore(self, st: PipeState) -> None:
        self.cursor = st.cursor

    def _assemble(self, idx: int) -> dict[str, np.ndarray]:
        n_ch = len(self.store.maps)
        rows = []
        need = self.seq + 1
        for b in range(self.batch):
            g = idx * self.batch + b
            shard = g % n_ch                       # way-interleaved shard order
            off = (g // n_ch) * need
            rows.append(self._hedged_read(shard, off, need))
        toks = np.stack(rows).astype(np.int32)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def _hedged_read(self, shard: int, off: int, n: int) -> np.ndarray:
        t0 = time.time()
        out = self.store.read_chunk(shard, off, n)
        if (time.time() - t0) * 1e3 > self.hedge_ms:
            # straggling channel: hedge to the replica (next shard)
            self.hedged_reads += 1
            out = self.store.read_chunk(shard + 1, off, n)
        return out

    def _producer(self):
        idx = self.cursor
        while not self._stop.is_set():
            try:
                self._q.put(( idx, self._assemble(idx)), timeout=0.1)
                idx += 1
            except queue.Full:
                continue

    def __iter__(self):
        self._stop.clear()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()
        while True:
            idx, batch = self._q.get()
            self.cursor = idx + 1
            yield batch

    def close(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)
