"""Fault-tolerant sharded checkpointing with the paper's I/O principles.

Layout:
    <dir>/step_<N>/MANIFEST.json                 tree structure + meta
    <dir>/step_<N>/ch<k>/<leaf>__c<j>.npy        chunked leaf data

The writer applies the paper's three levers directly:

* **channel striping** — leaf chunks round-robin across ``channels``
  writer threads (independent files ≈ independent NAND channels);
* **way interleaving** — each channel keeps ``ways`` outstanding chunk
  buffers so serialization (host compute ≈ t_PROG) overlaps the write
  of other chunks — the paper's latency-*hiding* lever;
* **DDR pacing** — the whole save runs on a background thread
  (double-buffered against training compute), and the projected stall
  on a production SSD tier is priced by the paper's bandwidth/energy
  model (``repro.storage.ssd_model``), enabling checkpoint-interval
  planning (stall budget = bytes / modeled BW).

Restore is **elastic**: arrays are loaded host-side and re-placed with
``jax.device_put`` against whatever mesh/sharding the *new* job uses —
mesh-shape changes are pure respecification (tested 8→4→8 devices).
Data-pipeline state rides in the manifest for deterministic resume.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import json
import pathlib
import re
import threading
import time
from typing import Any

import jax
import ml_dtypes
import numpy as np

from repro.core.sched import lower_static
from repro.core.sim import SSDConfig
from repro.core.workload import checkpoint_requests
from repro.storage.ssd_model import estimate_trace_interfaces

CHUNK_BYTES = 16 << 20


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    out = {}

    def visit(key_path, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in key_path)
        out[path] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return out


def _safe(name: str) -> str:
    return re.sub(r"[^\w\.]", "_", name)


@dataclasses.dataclass
class SaveResult:
    step: int
    nbytes: int
    wall_s: float
    modeled: dict[str, float]    # interface -> projected SSD write seconds


class CheckpointEngine:
    def __init__(self, directory: str | pathlib.Path, *, channels: int = 4,
                 ways: int = 4, ssd: SSDConfig | None = None,
                 keep: int = 2):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.channels = channels
        self.ways = ways
        self.ssd = ssd or SSDConfig()
        self.keep = keep
        self._pending: threading.Thread | None = None
        self._last: SaveResult | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, *, extra: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        host = {k: np.asarray(v) for k, v in _flatten(state).items()}
        self.wait()
        t = threading.Thread(target=self._write, args=(step, host, extra or {}),
                             daemon=True)
        self._pending = t
        t.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host: dict[str, np.ndarray], extra: dict):
        t0 = time.time()
        out = self.dir / f"step_{step:08d}.tmp"
        out.mkdir(parents=True, exist_ok=True)
        chunks: list[tuple[pathlib.Path, np.ndarray]] = []
        manifest: dict[str, Any] = {"step": step, "extra": extra, "leaves": {}}
        for path, arr in host.items():
            flat = arr.reshape(-1)
            n_chunks = max(1, -(-arr.nbytes // CHUNK_BYTES))
            per = -(-flat.size // n_chunks)
            manifest["leaves"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "chunks": n_chunks}
            for j in range(n_chunks):
                ch = (len(chunks)) % self.channels   # channel striping
                d = out / f"ch{ch}"
                d.mkdir(exist_ok=True)
                chunks.append((d / f"{_safe(path)}__c{j}.npy",
                               flat[j * per:(j + 1) * per]))
        nbytes = sum(int(c.nbytes) for _, c in chunks)
        # ways = outstanding buffers per channel writer
        with cf.ThreadPoolExecutor(max_workers=self.channels * self.ways) as ex:
            list(ex.map(lambda fc: np.save(fc[0], fc[1]), chunks))
        (out / "MANIFEST.json").write_text(json.dumps(manifest))
        final = self.dir / f"step_{step:08d}"
        out.rename(final)
        wall = time.time() - t0
        # the save is a request-level workload (a zero-arrival write
        # burst: the writer queues every chunk at once), lowered by the
        # static stripe scheduler onto the tier's geometry and priced on
        # the joint multi-channel simulation; the placement depends only
        # on cell/geometry, not on the interface kind, so one
        # per-interface fan-out through the cached Simulator sessions
        # prices all three
        requests = checkpoint_requests(nbytes, self.ssd)
        tr = lower_static(requests, self.ssd.channels, self.ssd.ways).trace
        modeled = {kind: est.seconds for kind, est in
                   estimate_trace_interfaces(tr, self.ssd,
                                             total_bytes=nbytes).items()}
        self._last = SaveResult(step, nbytes, wall, modeled)
        self._gc()

    def _gc(self):
        steps = sorted(self.dir.glob("step_????????"))
        for old in steps[:-self.keep]:
            for f in sorted(old.rglob("*"), reverse=True):
                f.unlink() if f.is_file() else f.rmdir()
            old.rmdir()

    def wait(self) -> SaveResult | None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        return self._last

    # -- restore (elastic) ----------------------------------------------------

    def latest_step(self) -> int | None:
        steps = sorted(self.dir.glob("step_????????"))
        return int(steps[-1].name.split("_")[1]) if steps else None

    def restore(self, step: int | None = None,
                template: Any = None) -> tuple[int, Any, dict]:
        """Returns (step, host-side state pytree, extra).

        ``template`` (any pytree with the same structure, e.g. from
        ``jax.eval_shape``) rebuilds the tree; pass None to get the flat
        {path: array} dict.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        src = self.dir / f"step_{step:08d}"
        manifest = json.loads((src / "MANIFEST.json").read_text())
        flat: dict[str, np.ndarray] = {}
        idx = 0
        for path, meta in manifest["leaves"].items():
            parts = []
            for j in range(meta["chunks"]):
                ch = idx % self.channels
                f = src / f"ch{ch}" / f"{_safe(path)}__c{j}.npy"
                if not f.exists():   # channel count may differ across jobs
                    hits = list(src.glob(f"ch*/{_safe(path)}__c{j}.npy"))
                    f = hits[0]
                parts.append(np.load(f))
                idx += 1
            arr = np.concatenate(parts) if len(parts) > 1 else parts[0]
            if str(arr.dtype) != meta["dtype"]:
                # np.load returns raw-void views for ml_dtypes types (bf16...)
                arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
            flat[path] = arr.reshape(meta["shape"])
        if template is None:
            return step, flat, manifest["extra"]
        ref = _flatten(template)
        leaves_order = list(ref.keys())
        rebuilt = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template),
            [flat[k] for k in leaves_order])
        return step, rebuilt, manifest["extra"]


def place_on_mesh(host_state: Any, shardings: Any) -> Any:
    """Elastic re-placement: works for any mesh shape/sharding (ZeRO/TP/...)."""
    return jax.tree.map(jax.device_put, host_state, shardings)
