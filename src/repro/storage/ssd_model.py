"""SSD cost model: the paper's simulator as a capacity-planning service.

Every storage-tier component (checkpoint engine, data pipeline, KV
offload) prices its I/O against the paper's SSD model: given an
interface (CONV / SYNC_ONLY / PROPOSED), cell type and channel/way
geometry, we get sustained read/write bandwidth (Table 3/4 reproduction)
and controller energy (Table 5).  ``plan_geometry`` inverts the model:
find the cheapest (channels, ways) meeting a bandwidth target — the
design-space search runs on the (max,+) engine, i.e. the paper's §5.3.2
trade-off study automated.
"""

from __future__ import annotations

import dataclasses

from repro.core.energy import ControllerEnergyModel
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.sim import SSDConfig, ssd_bandwidth_mb_s


@dataclasses.dataclass(frozen=True)
class IOEstimate:
    seconds: float
    bandwidth_mb_s: float
    energy_joules: float
    config: SSDConfig

    def describe(self) -> str:
        return (f"{self.config.describe()}: {self.bandwidth_mb_s:.0f} MB/s, "
                f"{self.seconds:.2f} s, {self.energy_joules * 1e3:.1f} mJ")


def estimate_io(nbytes: int, cfg: SSDConfig, mode: str) -> IOEstimate:
    bw = ssd_bandwidth_mb_s(cfg, mode)
    seconds = nbytes / (bw * 1e6)
    energy = ControllerEnergyModel(cfg.interface).energy_joules(nbytes, bw) \
        * cfg.channels
    return IOEstimate(seconds, bw, energy, cfg)


def plan_geometry(nbytes: int, budget_s: float, mode: str,
                  interface: InterfaceKind = InterfaceKind.PROPOSED,
                  cell: CellType = CellType.MLC) -> IOEstimate | None:
    """Smallest (channels × ways) geometry that meets the time budget.

    Area cost model per the paper §2.2.1: a channel costs ~4× a way
    (NAND_IF + ECC block + pins), so we sort candidates by
    4·channels + ways and return the first that fits.
    """
    candidates = [(c, w) for c in (1, 2, 4, 8) for w in (1, 2, 4, 8, 16)]
    candidates.sort(key=lambda cw: (4 * cw[0] + cw[1], cw[0]))
    for channels, ways in candidates:
        cfg = SSDConfig(interface=interface, cell=cell,
                        channels=channels, ways=ways)
        est = estimate_io(nbytes, cfg, mode)
        if est.seconds <= budget_s:
            return est
    return None


def compare_interfaces(nbytes: int, mode: str, *, channels: int = 4,
                       ways: int = 8, cell: CellType = CellType.MLC
                       ) -> dict[str, IOEstimate]:
    """CONV vs SYNC_ONLY vs PROPOSED at a fixed geometry (paper Fig. 8)."""
    return {
        kind.value: estimate_io(
            nbytes, SSDConfig(interface=kind, cell=cell,
                              channels=channels, ways=ways), mode)
        for kind in InterfaceKind
    }
