"""SSD cost model: the paper's simulator as a capacity-planning service.

Every storage-tier component (checkpoint engine, data pipeline, KV
offload) prices its I/O against the paper's SSD model — no longer as a
single scalar bandwidth, but as an **op trace** (``repro.core.trace``)
simulated jointly across channels against the shared controller: given an
interface (CONV / SYNC_ONLY / PROPOSED), cell type and channel/way
geometry, ``estimate_trace`` returns wall time, aggregate bandwidth and
controller energy for arbitrary mixed read/write access patterns.
``estimate_io`` keeps the legacy bytes+mode interface (a homogeneous
steady trace).  All pricing flows through the shared per-design-point
``repro.api.Simulator`` sessions (jit-closure cached, DESIGN.md §2.5).
``plan_geometry`` inverts the model: find the cheapest
(channels, ways) meeting a time budget for a *workload* — the paper's
§5.3.2 trade-off study automated, extended beyond the paper's
homogeneous streams.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.api import Simulator, steady_bandwidth_mb_s
from repro.core.energy import ControllerEnergyModel, EnergyBreakdown
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.sim import SSDConfig
from repro.core.trace import OpTrace, READ

#: Candidate geometries for planning, cheapest first.  Area cost model per
#: the paper §2.2.1: a channel costs ~4x a way (NAND_IF + ECC block +
#: pins), so candidates sort by 4*channels + ways.
_CANDIDATES = sorted(
    [(c, w) for c in (1, 2, 4, 8) for w in (1, 2, 4, 8, 16)],
    key=lambda cw: (4 * cw[0] + cw[1], cw[0]))


@dataclasses.dataclass(frozen=True)
class IOEstimate:
    seconds: float
    bandwidth_mb_s: float
    energy_joules: float
    config: SSDConfig
    read_bytes: int = 0
    write_bytes: int = 0
    n_ops: int = 0
    energy: EnergyBreakdown | None = None  # phase-resolved (trace paths)

    def describe(self) -> str:
        return (f"{self.config.describe()}: {self.bandwidth_mb_s:.0f} MB/s, "
                f"{self.seconds:.2f} s, {self.energy_joules * 1e3:.1f} mJ")


def estimate_trace(trace: OpTrace, cfg: SSDConfig, *,
                   total_bytes: int | None = None,
                   policy: str | None = None) -> IOEstimate:
    """Price an op trace on a design point (joint multi-channel sim).

    ``total_bytes``: when the trace is a truncated window of a longer
    steady workload, extrapolate wall time by bytes at the simulated
    sustained bandwidth.  The returned ``energy`` is the phase-resolved
    trace-level breakdown (DESIGN.md §2.4); ``energy_joules`` is its
    controller total — the paper's constant-power quantity.

    Queries go through the shared per-config ``repro.api.Simulator``
    session, so repeated pricing of the same design point (planning
    loops, the storage tier's per-interface comparisons) reuses cached
    jitted closures."""
    assert trace.channels == cfg.channels and trace.ways == cfg.ways, \
        f"trace geometry {trace.channels}x{trace.ways} != config " \
        f"{cfg.channels}x{cfg.ways}"
    if trace.n_ops == 0:
        raise ValueError("empty trace: no ops to estimate")
    sim = Simulator.for_config(cfg)
    table = sim.table
    window_bytes = trace.total_bytes(table)
    if window_bytes <= 0:
        raise ValueError("trace delivers no payload bytes (every op is "
                         "payload-masked); nothing to price")
    breakdown = sim.run(trace, policy=policy or cfg.policy,
                        objective="all").energy
    end_us = breakdown.end_us
    bw = min(window_bytes / end_us, cfg.sata_mb_s)     # bytes/us == MB/s
    nbytes = window_bytes if total_bytes is None else int(total_bytes)
    seconds = nbytes / (bw * 1e6)
    scale = nbytes / window_bytes
    # per-op phases scale with the op count; idle re-derives from the
    # extrapolated wall time (a SATA-capped stream turns the extra
    # wall-clock into idle energy, not op energy)
    breakdown = breakdown.extrapolated(scale, end_us=seconds * 1e6)
    pay = trace.payload_mask()
    read_mask = (trace.cls == READ) & pay
    write_mask = (trace.cls != READ) & pay
    return IOEstimate(
        seconds=seconds, bandwidth_mb_s=bw,
        energy_joules=breakdown.controller_j, config=cfg,
        read_bytes=int(table.data_bytes[trace.cls[read_mask]].sum() * scale),
        write_bytes=int(table.data_bytes[trace.cls[write_mask]].sum() * scale),
        n_ops=trace.n_ops, energy=breakdown)


def estimate_io(nbytes: int, cfg: SSDConfig, mode: str) -> IOEstimate:
    """Legacy bytes+mode estimate — a homogeneous steady trace."""
    bw = steady_bandwidth_mb_s(cfg, mode)
    seconds = nbytes / (bw * 1e6)
    energy = ControllerEnergyModel(cfg.interface).energy_joules(nbytes, bw) \
        * cfg.channels
    return IOEstimate(
        seconds, bw, energy, cfg,
        read_bytes=nbytes if mode == "read" else 0,
        write_bytes=nbytes if mode == "write" else 0)


def _plan(estimator: Callable[[SSDConfig], IOEstimate], budget_s: float,
          interface: InterfaceKind, cell: CellType,
          objective: str) -> IOEstimate | None:
    """Shared planning loop: ``objective="area"`` returns the cheapest
    candidate (by the §2.2.1 area order) meeting the time budget;
    ``objective="energy"`` searches every candidate meeting the budget
    and returns the one with the lowest controller energy — the Fig. 10
    trade-off: more ways finish sooner, and with constant controller
    power sooner is cheaper, until SATA/controller saturation turns the
    extra geometry into idle burn."""
    if objective not in ("area", "energy"):
        raise ValueError(f"unknown objective {objective!r} "
                         "(one of 'area', 'energy')")
    fits = []
    for channels, ways in _CANDIDATES:
        cfg = SSDConfig(interface=interface, cell=cell,
                        channels=channels, ways=ways)
        est = estimator(cfg)
        if est.seconds <= budget_s:
            if objective == "area":
                return est
            fits.append(est)
    if fits:
        return min(fits, key=lambda e: e.energy_joules)
    return None


def plan_geometry(nbytes: int, budget_s: float, mode: str,
                  interface: InterfaceKind = InterfaceKind.PROPOSED,
                  cell: CellType = CellType.MLC,
                  objective: str = "area") -> IOEstimate | None:
    """Best (channels x ways) geometry meeting the time budget for a
    homogeneous byte stream — smallest area, or lowest controller energy
    with ``objective="energy"`` (see ``plan_geometry_for_trace`` for
    mixed workloads)."""
    return _plan(lambda cfg: estimate_io(nbytes, cfg, mode), budget_s,
                 interface, cell, objective)


def plan_geometry_for_trace(
        trace_builder: Callable[[SSDConfig], OpTrace],
        budget_s: float,
        interface: InterfaceKind = InterfaceKind.PROPOSED,
        cell: CellType = CellType.MLC,
        total_bytes: int | None = None,
        objective: str = "area") -> IOEstimate | None:
    """Trace-aware geometry planning: the workload is re-striped onto
    each candidate geometry by ``trace_builder(cfg)`` and simulated
    jointly, so mixed read/write contention and shared-controller
    arbitration decide the verdict — not a homogeneous proxy stream.
    ``objective="energy"`` picks the budget-feasible geometry with the
    lowest phase-resolved controller energy instead of the smallest
    area."""
    return _plan(
        lambda cfg: estimate_trace(trace_builder(cfg), cfg,
                                   total_bytes=total_bytes),
        budget_s, interface, cell, objective)


def estimate_trace_interfaces(trace: OpTrace, base_cfg: SSDConfig, *,
                              total_bytes: int | None = None
                              ) -> dict[str, IOEstimate]:
    """Price one trace under every interface kind at ``base_cfg``'s
    geometry/cell/policy — the per-interface fan-out the storage tier
    (checkpoint stall projection, KV-offload feasibility) runs on every
    save/plan, served from the per-config ``Simulator`` sessions."""
    return {
        kind.value: estimate_trace(
            trace, dataclasses.replace(base_cfg, interface=kind),
            total_bytes=total_bytes)
        for kind in InterfaceKind
    }


def compare_interfaces(nbytes: int, mode: str, *, channels: int = 4,
                       ways: int = 8, cell: CellType = CellType.MLC
                       ) -> dict[str, IOEstimate]:
    """CONV vs SYNC_ONLY vs PROPOSED at a fixed geometry (paper Fig. 8)."""
    return {
        kind.value: estimate_io(
            nbytes, SSDConfig(interface=kind, cell=cell,
                              channels=channels, ways=ways), mode)
        for kind in InterfaceKind
    }


def compare_interfaces_trace(trace: OpTrace, *, cell: CellType = CellType.MLC,
                             total_bytes: int | None = None
                             ) -> dict[str, IOEstimate]:
    """Interface comparison on an arbitrary op trace."""
    return estimate_trace_interfaces(
        trace,
        SSDConfig(cell=cell, channels=trace.channels, ways=trace.ways),
        total_bytes=total_bytes)
