from repro.storage.checkpoint import CheckpointEngine, place_on_mesh  # noqa: F401
from repro.storage.datapipe import (FileBackedTokens, PipeState,  # noqa: F401
                                    StripedTokenStore, SyntheticTokens,
                                    pipeline_io_requests, pipeline_io_trace)
from repro.storage.kvoffload import plan_kv_offload  # noqa: F401
from repro.storage.ssd_model import compare_interfaces, estimate_io, plan_geometry  # noqa: F401
