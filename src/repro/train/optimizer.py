"""AdamW with fp32 master weights and quantised moment storage.

Built in-repo (no optax in this environment) as a production trainer
would need it anyway:

* **fp32 master** — model params live in bf16 for compute; the optimizer
  keeps the fp32 copy (ZeRO-1-sharded via
  ``repro.distributed.partitioning.opt_state_pspecs``).
* **Moment dtypes** — ``f32`` (default), ``bf16``, or ``int8`` with
  per-row (last-axis) fp32 scales — the 8-bit-optimizer trick that lets
  the 400B llama4 config fit a single v5e-256 pod (see DESIGN.md §6).
  Quantisation is stateless (re-quantised each step): an extra
  dequant/quant pair per step, zero extra memory.
* Global-norm clipping, decoupled weight decay, bias correction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0
    moment_dtype: str = "f32"      # 'f32' | 'bf16' | 'int8'
    master: bool = True


# --- int8 per-row quantisation ---------------------------------------------


def _quantize(x: jax.Array) -> dict[str, jax.Array]:
    xf = x.astype(jnp.float32)
    if xf.ndim == 0:
        xf = xf[None]
        scale = jnp.maximum(jnp.abs(xf), 1e-20) / 127.0
        return {"q": jnp.round(xf / scale).astype(jnp.int8),
                "scale": scale.astype(jnp.float32)}
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True), 1e-20) / 127.0
    return {"q": jnp.round(xf / scale).astype(jnp.int8),
            "scale": scale.astype(jnp.float32)}


def _dequantize(d: dict[str, jax.Array]) -> jax.Array:
    return d["q"].astype(jnp.float32) * d["scale"]


def _store_moment(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.bfloat16 if dtype == "bf16" else jnp.float32)


def _load_moment(x, dtype: str) -> jax.Array:
    if dtype == "int8":
        return _dequantize(x)
    return x.astype(jnp.float32)


# --- state ------------------------------------------------------------------


def adamw_init(cfg: OptConfig, params: Params) -> Params:
    zeros = jax.tree.map(lambda p: _store_moment(jnp.zeros(p.shape, jnp.float32),
                                                 cfg.moment_dtype), params)
    state: dict[str, Any] = {
        "m": zeros,
        "v": jax.tree.map(lambda p: _store_moment(jnp.zeros(p.shape, jnp.float32),
                                                  cfg.moment_dtype), params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: OptConfig,
    schedule: Callable[[jax.Array], jax.Array],
    params: Params,
    grads: Params,
    state: Params,
) -> tuple[Params, Params, dict[str, jax.Array]]:
    """Returns (new_params, new_state, info)."""
    count = state["count"] + 1
    lr = schedule(count)

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    else:
        scale = jnp.ones((), jnp.float32)

    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    md = cfg.moment_dtype

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32) * scale
        mf = cfg.b1 * _load_moment(m, md) + (1 - cfg.b1) * g
        vf = cfg.b2 * _load_moment(v, md) + (1 - cfg.b2) * jnp.square(g)
        step = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        base = master if master is not None else p.astype(jnp.float32)
        new_master = base - lr * (step + cfg.weight_decay * base)
        return new_master.astype(p.dtype), _store_moment(mf, md), \
            _store_moment(vf, md), new_master

    def is_q(x):
        return isinstance(x, dict) and set(x) == {"q", "scale"}
    masters = state.get("master") or jax.tree.map(lambda p: None, params)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    flat_master = (jax.tree.flatten(state["master"])[0] if cfg.master
                   else [None] * len(flat_p))

    out = [upd(p, g, m, v, mm) for p, g, m, v, mm in
           zip(flat_p, flat_g, flat_m, flat_v, flat_master)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state: dict[str, Any] = {
        "m": tdef.unflatten([o[1] for o in out]),
        "v": tdef.unflatten([o[2] for o in out]),
        "count": count,
    }
    if cfg.master:
        new_state["master"] = tdef.unflatten([o[3] for o in out])
    del masters
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
