from repro.train.optimizer import OptConfig, adamw_init, adamw_update, global_norm  # noqa: F401
from repro.train.schedules import SCHEDULES, constant, warmup_cosine, wsd  # noqa: F401
