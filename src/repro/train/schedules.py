"""LR schedules: linear-warmup cosine and MiniCPM's WSD (warmup-stable-decay)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd(base_lr: float, warmup: int, stable: int, decay: int, min_ratio: float = 0.1):
    """Warmup-Stable-Decay (MiniCPM, arXiv:2404.06395 §4): flat plateau then
    a short exponential-ish decay to min_ratio·lr."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = base_lr * jnp.exp(jnp.log(jnp.maximum(min_ratio, 1e-6)) * frac)
        return jnp.where(step < warmup, warm,
                         jnp.where(step < warmup + stable, base_lr, dec))
    return lr


def constant(base_lr: float):
    def lr(step):
        return jnp.full((), base_lr, jnp.float32)
    return lr


SCHEDULES = {"cosine": warmup_cosine, "wsd": wsd, "constant": constant}
