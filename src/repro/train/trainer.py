"""Fault-tolerant training loop (the end-to-end driver).

Composes every substrate: jit'd train step (sharded via
``repro.distributed.partitioning``), deterministic data pipeline,
async SSD-priced checkpointing, straggler watchdog, failure-injection
drills and checkpoint-restart recovery — the same loop a multi-pod
deployment runs, exercised at laptop scale by the tests/examples.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.distributed import partitioning as part
from repro.distributed.fault import (FailureInjector, RestartableFailure,
                                     StepWatchdog)
from repro.launch.steps import (abstract_train_state, init_train_state,
                                make_train_step, train_state_pspecs)
from repro.models.transformer import ModelConfig
from repro.storage.checkpoint import CheckpointEngine, place_on_mesh
from repro.storage.datapipe import PipeState
from repro.train.optimizer import OptConfig
from repro.train.schedules import constant

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    grad_accum: int = 1
    zero1: bool = True
    max_restarts: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig, mesh, data, *,
                 ocfg: OptConfig | None = None,
                 schedule: Callable | None = None,
                 injector: FailureInjector | None = None,
                 watchdog: StepWatchdog | None = None):
        self.cfg, self.tcfg, self.mesh, self.data = cfg, tcfg, mesh, data
        self.ocfg = ocfg or OptConfig()
        self.schedule = schedule or constant(3e-4)
        self.injector = injector or FailureInjector()
        self.watchdog = watchdog or StepWatchdog()
        self.ckpt = CheckpointEngine(tcfg.ckpt_dir)
        self.restarts = 0
        self.metrics_history: list[dict] = []

        state_shape = abstract_train_state(cfg, self.ocfg)
        self.state_specs = train_state_pspecs(cfg, self.ocfg, mesh, state_shape,
                                              zero1=tcfg.zero1)
        self.state_shardings = part.shardings(mesh, self.state_specs)
        step_fn = make_train_step(cfg, self.ocfg, self.schedule,
                                  grad_accum=tcfg.grad_accum)
        self._jitted = jax.jit(
            step_fn,
            in_shardings=(self.state_shardings, None),
            out_shardings=(self.state_shardings, None),
            donate_argnums=(0,))

    # -- state lifecycle -----------------------------------------------------

    def _fresh_state(self):
        init = jax.jit(
            lambda k: init_train_state(self.cfg, self.ocfg, k),
            out_shardings=self.state_shardings)
        return init(jax.random.PRNGKey(self.tcfg.seed))

    def _resume_or_init(self):
        step = self.ckpt.latest_step()
        if step is None:
            return 0, self._fresh_state()
        shape = abstract_train_state(self.cfg, self.ocfg)
        step, host_state, extra = self.ckpt.restore(step, template=shape)
        state = place_on_mesh(host_state, self.state_shardings)
        if "pipe_cursor" in extra and hasattr(self.data, "restore"):
            self.data.restore(PipeState(extra["pipe_cursor"]))
        log.info("resumed from step %d", step)
        return step, state

    # -- main loop -------------------------------------------------------------

    def run(self) -> dict[str, Any]:
        while True:
            try:
                return self._run_once()
            except RestartableFailure as e:
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                log.warning("restart %d/%d after: %s",
                            self.restarts, self.tcfg.max_restarts, e)

    def _run_once(self) -> dict[str, Any]:
        step, state = self._resume_or_init()
        it = iter(self.data)
        t_start = time.time()
        last = {}
        while step < self.tcfg.steps:
            batch = next(it)
            self.injector.maybe_fail(step)
            self.watchdog.start()
            state, metrics = self._jitted(state, batch)
            jax.block_until_ready(metrics["loss"])
            self.watchdog.stop(step)
            step += 1
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                last = {k: float(np.asarray(v)) for k, v in metrics.items()}
                last["step"] = step
                self.metrics_history.append(last)
                log.info("step %d loss %.4f lr %.2e gnorm %.2f", step,
                         last["loss"], last["lr"], last["grad_norm"])
            if step % self.tcfg.ckpt_every == 0 or step == self.tcfg.steps:
                cursor = self.data.state().cursor if hasattr(self.data, "state") else 0
                self.ckpt.save(step, state, extra={"pipe_cursor": cursor})
        save = self.ckpt.wait()
        return {
            "final_step": step,
            "final_metrics": last,
            "wall_s": time.time() - t_start,
            "restarts": self.restarts,
            "straggler_events": len(self.watchdog.events),
            "last_ckpt": dataclasses.asdict(save) if save else None,
            "history": self.metrics_history,
        }
