"""Quickstart: the paper's result in 30 lines + a tiny training run.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.api import SSDConfig, steady_bandwidth_mb_s
from repro.core import timing
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.configs import get_arch, smoke_batch
from repro.models.transformer import init_params, loss_fn


def main():
    # 1) the paper's headline: CONV 50 MHz vs PROPOSED 83 MHz DDR ...
    clocks = timing.derive_paper_clocks()
    print(f"CONV     t_P,min = {clocks.conv_t_p_ns:.2f} ns -> {clocks.conv_mhz:.0f} MHz SDR")
    print(f"PROPOSED t_P,min = {clocks.prop_t_p_ns:.2f} ns -> {clocks.prop_mhz:.0f} MHz DDR")

    # ... and what it buys at SSD level (16-way SLC, paper Table 3)
    for kind in InterfaceKind:
        cfg = SSDConfig(interface=kind, cell=CellType.SLC, ways=16)
        print(f"  {kind.value:10s} 16-way SLC read : "
              f"{steady_bandwidth_mb_s(cfg, 'read'):7.1f} MB/s")

    # 2) one forward/backward through a zoo architecture (reduced config)
    arch = get_arch("qwen2-0.5b")
    params = init_params(arch.smoke, jax.random.PRNGKey(0))
    loss, metrics = loss_fn(arch.smoke, params, smoke_batch(arch.smoke))
    print(f"\nqwen2-0.5b (smoke config) loss: {float(loss):.3f} "
          f"({int(metrics['tokens'])} tokens)")


if __name__ == "__main__":
    main()
