"""End-to-end training driver: a ~100M-param qwen2-family model for a few
hundred steps on CPU, with checkpointing, WSD schedule, grad accumulation
and an injected failure + automatic restart along the way.

    PYTHONPATH=src python examples/train_100m.py [--steps 200] [--tiny]
"""

import argparse
import logging
import tempfile

from repro.configs import get_arch
from repro.distributed.fault import FailureInjector
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import ModelConfig, LayerSpec
from repro.storage.datapipe import SyntheticTokens
from repro.train.optimizer import OptConfig
from repro.train.schedules import wsd
from repro.train.trainer import Trainer, TrainerConfig
from repro.launch.dryrun import active_param_count


def model_100m() -> ModelConfig:
    """qwen2-family, ~100M params (d=512, 8L, vocab 32k)."""
    return ModelConfig(
        name="qwen2-100m",
        d_model=512, n_layers=8, vocab_size=32000,
        pattern=(LayerSpec(mixer="attn", ffn="dense"),),
        n_heads=8, n_kv_heads=2, head_dim=64, qkv_bias=True,
        rope_theta=1e6, d_ff=2048, tie_embeddings=True,
        param_dtype="f32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true",
                    help="use the smoke config (fast CI run)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")

    cfg = get_arch("qwen2-0.5b").smoke if args.tiny else model_100m()
    total, _ = active_param_count(cfg)
    print(f"model: {cfg.name}  params={total/1e6:.1f}M")

    mesh = make_host_mesh(model=1)
    data = SyntheticTokens(cfg.vocab_size, batch=8, seq=128 if not args.tiny else 16)
    ckpt_dir = tempfile.mkdtemp(prefix="repro_100m_")
    tcfg = TrainerConfig(steps=args.steps, log_every=max(args.steps // 10, 1),
                         ckpt_every=max(args.steps // 4, 1), ckpt_dir=ckpt_dir,
                         grad_accum=2)
    trainer = Trainer(
        cfg, tcfg, mesh, data,
        ocfg=OptConfig(weight_decay=0.1, clip_norm=1.0),
        schedule=wsd(3e-4, warmup=args.steps // 10,
                     stable=args.steps * 7 // 10, decay=args.steps // 5),
        injector=FailureInjector(fail_at_steps=(args.steps // 2,)))
    result = trainer.run()

    print(f"\nfinished step {result['final_step']} "
          f"(restarts={result['restarts']}, "
          f"straggler events={result['straggler_events']})")
    print(f"loss: {result['history'][0]['loss']:.3f} -> "
          f"{result['final_metrics']['loss']:.3f}")
    if result["last_ckpt"]:
        m = result["last_ckpt"]["modeled"]
        print(f"checkpoint {result['last_ckpt']['nbytes']/2**20:.0f} MiB; "
              f"projected SSD stall: conv={m['conv']:.2f}s "
              f"proposed={m['proposed']:.2f}s")


if __name__ == "__main__":
    main()
