"""Design-space exploration with the paper's SSD model (paper §5.3.2 +
capacity planning for the training stack).

    PYTHONPATH=src python examples/ssd_design_space.py
"""

from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.sim import SSDConfig, ssd_bandwidth_mb_s
from repro.storage.kvoffload import plan_kv_offload
from repro.storage.ssd_model import compare_interfaces, plan_geometry
from repro.configs import get_arch


def main():
    print("== constant-capacity channel/way trade-off (paper Table 4, SLC read) ==")
    for channels, ways in ((1, 16), (2, 8), (4, 4)):
        row = []
        for kind in InterfaceKind:
            cfg = SSDConfig(interface=kind, cell=CellType.SLC,
                            channels=channels, ways=ways)
            row.append(f"{kind.value}={ssd_bandwidth_mb_s(cfg, 'read'):6.1f}")
        print(f"  {channels}ch x {ways:2d}way : " + "  ".join(row) + " MB/s")

    print("\n== checkpoint-stall planning: 2.7B params (minicpm), bf16+opt ==")
    nbytes = int(2.7e9 * 2 * 3)
    for budget in (60.0, 20.0, 5.0):
        plan = plan_geometry(nbytes, budget_s=budget, mode="write")
        print(f"  budget {budget:5.1f}s -> "
              + (plan.describe() if plan else "no geometry fits"))

    print("\n== interface choice for a 10 GiB dataloader shard refill ==")
    for name, est in compare_interfaces(10 << 30, "read").items():
        print(f"  {name:10s}: {est.seconds:6.1f} s  {est.energy_joules*1e3:7.1f} mJ")

    print("\n== KV offload feasibility at 524288-token decode ==")
    for arch_id in ("qwen2-0.5b", "recurrentgemma-9b", "xlstm-350m"):
        plan = plan_kv_offload(get_arch(arch_id).config, 524288)
        print(f"  {plan.note}")


if __name__ == "__main__":
    main()
