"""Design-space exploration with the paper's SSD model (paper §5.3.2 +
capacity planning for the training stack), extended to the mixed
read/write op-trace workloads the paper could not express.

    PYTHONPATH=src python examples/ssd_design_space.py
"""

import time

from repro.api import (Simulator, build_workload, multi_tenant,
                       poisson_stream, bursty_stream, steady_bandwidth_mb_s,
                       sweep_tables)
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.sim import SSDConfig
from repro.core.trace import checkpoint_trace, datapipe_trace
from repro.storage.kvoffload import plan_kv_offload
from repro.storage.ssd_model import (compare_interfaces,
                                     compare_interfaces_trace, plan_geometry,
                                     plan_geometry_for_trace)
from repro.configs import get_arch


def main():
    print("== constant-capacity channel/way trade-off (paper Table 4, SLC read) ==")
    print("   (all channels simulated jointly against the shared controller)")
    for channels, ways in ((1, 16), (2, 8), (4, 4)):
        row = []
        for kind in InterfaceKind:
            cfg = SSDConfig(interface=kind, cell=CellType.SLC,
                            channels=channels, ways=ways)
            row.append(f"{kind.value}={steady_bandwidth_mb_s(cfg, 'read'):6.1f}")
        print(f"  {channels}ch x {ways:2d}way : " + "  ".join(row) + " MB/s")

    print("\n== mixed-workload design points (beyond paper §5.3: 70/30 r/w) ==")
    print("   (bandwidth + phase-resolved controller energy, DESIGN.md §2.4)")
    bd = None
    for channels, ways in ((1, 16), (2, 8), (4, 4)):
        tr = build_workload("mixed", SSDConfig(channels=channels, ways=ways),
                            read_fraction=0.7, seed=7)
        ests = compare_interfaces_trace(tr, cell=CellType.MLC)
        row = "  ".join(f"{k}={e.bandwidth_mb_s:6.1f}" for k, e in ests.items())
        nj = "  ".join(f"{k}={e.energy.nj_per_byte:5.2f}"
                       for k, e in ests.items())
        print(f"  {channels}ch x {ways:2d}way : {row} MB/s")
        print(f"  {'':14s}  {nj} nJ/B")
        if (channels, ways) == (2, 8):
            bd = ests["proposed"].energy
    print(f"  phase split (proposed, 2ch x 8way): {bd.describe()}")

    print("\n== log-depth engines: 2048-op mixed sweep (DESIGN.md §2.3) ==")
    print("   (one Simulator session per design point; same recurrence,")
    print("    O(segment+log T) depth instead of O(T))")
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=8)
    tr2k = build_workload("mixed", cfg, n_ops=2048, read_fraction=0.7, seed=3)
    sims = [Simulator.for_config(SSDConfig(interface=k, cell=c,
                                           channels=2, ways=8))
            for k in InterfaceKind for c in CellType]
    tables = [s.table for s in sims]
    scan_us = [s.run(tr2k).end_us for s in sims]         # compile + run
    px_us = sweep_tables(tables, tr2k, segment_len=128)
    t0 = time.perf_counter()
    scan_us = [s.run(tr2k).end_us for s in sims]
    t_scan = time.perf_counter() - t0
    t0 = time.perf_counter()
    px_us = sweep_tables(tables, tr2k, segment_len=128)
    t_px = time.perf_counter() - t0
    worst = max(abs(a - b) / b for a, b in zip(px_us, scan_us))
    print(f"  scan engine   : {t_scan * 1e3:6.1f} ms for {len(tables)} design points")
    print(f"  prefix engine : {t_px * 1e3:6.1f} ms  (segmented, batched; "
          f"max rel dev {worst:.1e})")

    print("\n== scheduler policy as a design axis (DESIGN.md §2.6) ==")
    print("   (hot/cold-skewed multi-tenant load: a bursty write tenant")
    print("    over a Poisson read trickle; p50/p99 request latency per")
    print("    policy x geometry — dynamic dispatch is the cheap lever")
    print("    when adding ways/channels is not on the table)")
    hot = bursty_stream(100, burst_len=20, gap_us=1500.0,
                        read_fraction=0.1, seed=0, stream=0)
    cold = poisson_stream(100, mean_interarrival_us=80.0,
                          read_fraction=0.9, seed=100, stream=1)
    load = multi_tenant([hot, cold])
    for channels, ways in ((2, 4), (2, 8), (4, 4)):
        sim = Simulator.for_config(
            SSDConfig(cell=CellType.MLC, channels=channels, ways=ways))
        row = []
        for policy in ("stripe", "round_robin", "least_loaded",
                       "earliest_ready"):
            res = sim.run(load, sched_policy=policy)
            row.append(f"{policy}={res.p50_us:5.0f}/{res.p99_us:5.0f}")
        print(f"  {channels}ch x {ways:2d}way : " + "  ".join(row)
              + "  (p50/p99 us)")

    print("\n== queue-depth sweep: closed-loop client, 2ch x 8way MLC ==")
    from repro.api import closed_loop_stream
    sim = Simulator.for_config(SSDConfig(cell=CellType.MLC, channels=2,
                                         ways=8))
    for qd in (1, 2, 4, 8, 16, 32):
        res = sim.run(closed_loop_stream(384, qd, service_us=60.0,
                                         read_fraction=0.7, seed=9),
                      sched_policy="least_loaded")
        print(f"  QD={qd:2d}: p50 {res.p50_us:7.1f} us   "
              f"p99 {res.p99_us:7.1f} us   {res.mb_s:6.1f} MB/s")

    print("\n== aging as a design axis: overprovisioning x GC policy ==")
    print("   (FTL stage, DESIGN.md §2.10: steady-state WAF and the")
    print("    fresh-vs-aged bandwidth cliff; overprovisioning trades")
    print("    usable capacity for sustained write bandwidth, the victim")
    print("    policy trades firmware complexity for WAF under skew)")
    from repro.api import FTLSpec, aging_stream, analytic_waf
    sim = Simulator.for_config(SSDConfig(cell=CellType.MLC, channels=2,
                                         ways=8))
    aged = None
    for op in (0.12, 0.25, 0.5):
        row = []
        for policy in ("greedy", "lru"):
            spec = FTLSpec(blocks=128, pages_per_block=32,
                           overprovision=op, gc_policy=policy,
                           precondition=True)
            aged = sim.run(aging_stream(6000,
                                        int(spec.logical_pages * 0.95),
                                        hot_fraction=0.2, hot_traffic=0.8,
                                        seed=11),
                           ftl=spec)
            row.append(f"{policy}: WAF {aged.waf:4.2f} "
                       f"{aged.mb_s:5.1f} MB/s")
        print(f"  OP {op:4.2f} (uniform analytic WAF "
              f"{analytic_waf(1.0 / (1.0 + op)):4.2f}) : " + "   ".join(row))
    print(f"  fresh-drive reference (OP 0.50): {aged.fresh_mb_s:5.1f} MB/s"
          f" -> the cliff is {aged.mb_s / aged.fresh_mb_s:4.2f}x")

    print("\n== fused aged sweep: 12 overprovisioning points, one closure ==")
    print("   (compiled scan translator, DESIGN.md §2.11: translate ->")
    print("    lower -> simulate rides vmap; preconditioned states and")
    print("    buffer sizes are memoised, so the warm sweep skips the")
    print("    aging ramp the per-point path re-pays on every call)")
    import numpy as np
    from repro.api import overwrite_stream
    specs = [FTLSpec(blocks=128, pages_per_block=32,
                     overprovision=float(op), precondition=True)
             for op in np.linspace(0.12, 0.5, 12)]
    mixed = overwrite_stream(4000, specs[-1].logical_pages,
                             read_fraction=0.5, seed=7)
    t0 = time.perf_counter()
    ends = sim.sweep(None, mixed, ftl=specs)          # compile + age
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    ends = sim.sweep(None, mixed, ftl=specs)
    t_warm = time.perf_counter() - t0
    span = ", ".join(f"{e / 1e3:.1f}" for e in
                     (ends[0], ends[len(ends) // 2], ends[-1]))
    print(f"  12-point 50/50 aged sweep: cold {t_cold:5.2f}s, "
          f"warm {t_warm * 1e3:6.1f} ms")
    print(f"  end times OP 0.12 / 0.29 / 0.50: {span} ms "
          f"(more spare blocks -> less GC -> earlier finish)")

    print("\n== checkpoint-stall planning: 2.7B params (minicpm), bf16+opt ==")
    print("   (MLC tier first; fall back to an SLC tier when contention-")
    print("    limited MLC writes cannot meet the stall budget)")
    nbytes = int(2.7e9 * 2 * 3)
    for budget in (150.0, 95.0, 30.0):
        plan = None
        for cell in (CellType.MLC, CellType.SLC):
            plan = plan_geometry_for_trace(
                lambda cfg: checkpoint_trace(nbytes, cfg),
                budget_s=budget, cell=cell, total_bytes=nbytes)
            if plan:
                break
        print(f"  budget {budget:5.1f}s -> "
              + (plan.describe() if plan else "no geometry fits"))

    print("\n== dataloader refill: 10 GiB, trace-planned vs byte-planned ==")
    ten_gib = 10 << 30
    t_plan = plan_geometry_for_trace(
        lambda cfg: datapipe_trace(ten_gib, cfg, hedge_fraction=0.05),
        budget_s=60.0, total_bytes=ten_gib)
    b_plan = plan_geometry(ten_gib, budget_s=60.0, mode="read")
    e_plan = plan_geometry_for_trace(
        lambda cfg: datapipe_trace(ten_gib, cfg, hedge_fraction=0.05),
        budget_s=60.0, total_bytes=ten_gib, objective="energy")
    print("  trace (5% hedged):", t_plan.describe() if t_plan else "none")
    print("  bytes (pure read):", b_plan.describe() if b_plan else "none")
    print("  min-energy fit   :", e_plan.describe() if e_plan else "none")
    for name, est in compare_interfaces(ten_gib, "read").items():
        print(f"  {name:10s}: {est.seconds:6.1f} s  {est.energy_joules*1e3:7.1f} mJ")

    print("\n== KV offload feasibility at 524288-token decode ==")
    for arch_id in ("qwen2-0.5b", "recurrentgemma-9b", "xlstm-350m"):
        plan = plan_kv_offload(get_arch(arch_id).config, 524288)
        print(f"  {plan.note}")


if __name__ == "__main__":
    main()
