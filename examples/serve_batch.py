"""Batched serving demo: prefill + greedy decode on a reduced-config model,
with SSD-tier KV-offload pricing for the long-context regime.

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve import SamplerConfig, ServingEngine
from repro.storage.kvoffload import plan_kv_offload


def main():
    arch = get_arch("granite-3-2b")
    cfg = arch.smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_seq=64,
                           sampler=SamplerConfig(temperature=0.0))

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, 8)) for _ in range(4)]
    t0 = time.time()
    result = engine.generate(prompts, n_new=24)
    dt = time.time() - t0
    print(f"generated {result.tokens.shape} tokens in {dt:.2f}s "
          f"({result.tokens.size / dt:.1f} tok/s on CPU, reduced config)")
    for r, row in enumerate(result.tokens[:2]):
        print(f"  seq{r}: {row[:12].tolist()} ...")

    scores = engine.score(np.concatenate(
        [np.array(prompts, np.int32), result.tokens], axis=1))
    print(f"mean generated-token logprob: {scores[:, -24:].mean():.3f}")

    plan = plan_kv_offload(arch.config, 524288)
    print(f"\nKV offload @500k ctx (full-scale {arch.config.name}): {plan.note}")


if __name__ == "__main__":
    main()
