"""Equivalence of the three simulator engines + structural properties."""


import pytest

pytest.importorskip("hypothesis", reason="property suite needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, chip
from repro.core.sim import (PageOpParams, channel_bandwidth_mb_s,
                            page_op_params, saturation_ways)
from repro.core.sim_ref import bandwidth_ref_mb_s, simulate_channel_ref
from repro.kernels.maxplus.ops import channel_end_time_maxplus

op_strategy = st.builds(
    PageOpParams,
    cmd_us=st.floats(0.01, 1.0),
    pre_us=st.floats(0.0, 100.0),
    slot_us=st.floats(1.0, 100.0),
    post_lo_us=st.floats(0.0, 500.0),
    post_hi_us=st.floats(0.0, 2000.0),
    data_bytes=st.just(2048),
)


@settings(deadline=None, max_examples=25)
@given(op_strategy, st.sampled_from([1, 2, 4, 8, 16]),
       st.booleans(), st.integers(16, 128))
def test_scan_engine_matches_oracle(op, ways, batched, n_pages):
    ref = simulate_channel_ref(op, ways, n_pages, batched=batched)
    bw = float(channel_bandwidth_mb_s(
        op, ways, "batched" if batched else "eager", n_pages=n_pages))
    assert bw == pytest.approx(n_pages * op.data_bytes / ref, rel=1e-4)


@settings(deadline=None, max_examples=15)
@given(op_strategy, st.sampled_from([1, 2, 4, 8, 16]), st.booleans())
def test_maxplus_engine_matches_oracle(op, ways, batched):
    policy = "batched" if batched else "eager"
    ref = simulate_channel_ref(op, ways, 64, batched=batched)
    end = channel_end_time_maxplus([op], [ways], n_pages=64, policy=policy)
    assert float(end[0]) == pytest.approx(ref, rel=1e-4)


@settings(deadline=None, max_examples=25)
@given(op_strategy, st.sampled_from([1, 2, 4, 8, 16]))
def test_bandwidth_bounded_by_bus_and_chip(op, ways):
    """The event sim can never beat the closed-form steady-state bound."""
    bw = bandwidth_ref_mb_s(op, ways, n_pages=256)
    bus_bound = op.data_bytes / op.slot_us
    assert bw <= bus_bound * 1.001
    # and interleaving helps monotonically up to the bus bound
    if ways > 1:
        bw1 = bandwidth_ref_mb_s(op, 1, n_pages=256)
        assert bw >= bw1 * 0.999


@settings(deadline=None, max_examples=25)
@given(op_strategy)
def test_saturation_ways_property(op):
    """At W = saturation_ways a symmetric-program channel nearly saturates
    the bus (MLC hi/lo alternation is tested separately)."""
    import dataclasses as dc
    op = dc.replace(op, post_hi_us=op.post_lo_us)
    w = min(saturation_ways(op), 16)
    bw = bandwidth_ref_mb_s(op, w, n_pages=512)
    assert bw <= op.data_bytes / op.slot_us * 1.001
    if saturation_ways(op) <= 16:
        assert bw >= 0.70 * op.data_bytes / op.slot_us


def test_mlc_write_alternation_matters():
    """Paper §5.3.1 Case III: asymmetric MLC paired-page programming limits
    interleaving more than the mean program time alone."""
    iface = make_interface(InterfaceKind.PROPOSED)
    mlc = chip(CellType.MLC)
    op = page_op_params(iface, mlc, "write", 8)
    sym = PageOpParams(op.cmd_us, op.pre_us, op.slot_us,
                       op.post_mean_us(), op.post_mean_us(), op.data_bytes)
    bw_alt = bandwidth_ref_mb_s(op, 8, 512)
    bw_sym = bandwidth_ref_mb_s(sym, 8, 512)
    assert bw_alt < bw_sym  # alternation is strictly worse at fixed mean


def test_vmapped_sweep_consistency():
    from repro.core.sim import sweep_bandwidth_mb_s
    import jax.numpy as jnp
    ops = [page_op_params(make_interface(k), chip(c), m, 4)
           for k in InterfaceKind for c in CellType for m in ("read", "write")]
    args = tuple(
        jnp.array([getattr(o, f) for o in ops], jnp.float32)
        for f in ("cmd_us", "pre_us", "slot_us", "post_lo_us", "post_hi_us",
                  "ctrl_us", "data_bytes"))
    bw = sweep_bandwidth_mb_s(*args, jnp.array([4] * len(ops), jnp.int32))
    for i, op in enumerate(ops):
        assert float(bw[i]) == pytest.approx(
            bandwidth_ref_mb_s(op, 4, 512), rel=1e-4)
