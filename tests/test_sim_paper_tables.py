"""Reproduction of the paper's experimental tables (the paper-faithful
baseline the rest of the framework builds on)."""

import numpy as np
import pytest

from repro.core.energy import energy_nj_per_byte
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.paper_tables import CLAIMS, INTERFACE_ORDER, TABLE3, TABLE4, TABLE5
from repro.core.sim import SSDConfig, ssd_bandwidth_mb_s

# The 2-way SLC PROPOSED read cell (70.47 MB/s, barely above SYNC_ONLY) is
# anomalous in the paper: the same interface saturates at 117.6 at 4-way and
# CONV/SYNC scale ~linearly 1->2 way.  Our simulator (either policy) cannot
# reproduce it without breaking every neighbouring cell; see EXPERIMENTS.md.
ANOMALIES = {("slc", "read", 2, "proposed")}


def _sim(cell, mode, ways, kind, channels=1):
    cfg = SSDConfig(interface=InterfaceKind(kind), cell=CellType(cell),
                    channels=channels, ways=ways)
    return ssd_bandwidth_mb_s(cfg, mode)


def test_table3_reproduction_tolerance():
    errs, worst = [], 0.0
    for cell, by_mode in TABLE3.items():
        for mode, by_ways in by_mode.items():
            for ways, row in by_ways.items():
                for kind, paper in zip(INTERFACE_ORDER, row):
                    if (cell, mode, ways, kind) in ANOMALIES:
                        continue
                    rel = abs(_sim(cell, mode, ways, kind) - paper) / paper
                    errs.append(rel)
                    worst = max(worst, rel)
    assert np.mean(errs) < 0.04, f"mean rel err {np.mean(errs):.3f}"
    assert worst < 0.16, f"worst rel err {worst:.3f}"


def test_table4_reproduction():
    errs = []
    for cell, by_mode in TABLE4.items():
        for mode, by_cw in by_mode.items():
            for (channels, ways), row in by_cw.items():
                for kind, paper in zip(INTERFACE_ORDER, row):
                    sim = _sim(cell, mode, ways, kind, channels)
                    if paper is None:  # 'max' = hit the SATA2 300 MB/s cap
                        assert sim >= 299.0
                        continue
                    if (cell, mode, ways, kind) in ANOMALIES:
                        continue
                    errs.append(abs(sim - paper) / paper)
    assert np.mean(errs) < 0.05, f"mean rel err {np.mean(errs):.3f}"


def test_headline_speedup_claims():
    """Abstract: SLC read 1.65-2.76x, write 1.09-2.45x; MLC 1.64-2.66 / 1.05-1.76."""
    for (cell, mode), (lo, hi) in CLAIMS.items():
        ratios = []
        for ways in (1, 2, 4, 8, 16):
            c = _sim(cell, mode, ways, "conv")
            p = _sim(cell, mode, ways, "proposed")
            ratios.append(p / c)
        assert min(ratios) == pytest.approx(lo, rel=0.12), (cell, mode)
        assert max(ratios) == pytest.approx(hi, rel=0.12), (cell, mode)


def test_saturation_structure():
    """§5.3.1: CONV read saturates at 2-way, PROPOSED at 4-way (SLC)."""
    conv = [_sim("slc", "read", w, "conv") for w in (1, 2, 4, 8, 16)]
    prop = [_sim("slc", "read", w, "proposed") for w in (1, 2, 4, 8, 16)]
    assert conv[1] / conv[0] > 1.4 and conv[2] / conv[1] < 1.05
    assert prop[2] / prop[1] > 1.2 and prop[3] / prop[2] < 1.05


def test_interface_ordering():
    """PROPOSED >= SYNC_ONLY >= CONV for every cell/mode/ways."""
    for cell in ("slc", "mlc"):
        for mode in ("read", "write"):
            for ways in (1, 2, 4, 8, 16):
                c = _sim(cell, mode, ways, "conv")
                s = _sim(cell, mode, ways, "sync_only")
                p = _sim(cell, mode, ways, "proposed")
                assert p >= s * 0.999 >= c * 0.995, (cell, mode, ways)


def test_table5_energy_reproduction():
    errs = []
    for mode, by_ways in TABLE5.items():
        for ways, row in by_ways.items():
            for kind, paper in zip(INTERFACE_ORDER, row):
                if ("slc", mode, ways, kind) in ANOMALIES:
                    continue
                bw = _sim("slc", mode, ways, kind)
                sim = energy_nj_per_byte(kind, bw)
                errs.append(abs(sim - paper) / paper)
    assert np.mean(errs) < 0.06, f"mean rel err {np.mean(errs):.3f}"


def test_energy_crossover():
    """§5.3.3: PROPOSED becomes the most energy-efficient at high way counts."""
    def e(kind, ways, mode):
        return energy_nj_per_byte(kind, _sim("slc", mode, ways, kind))
    assert e("proposed", 1, "write") > e("conv", 1, "write")
    assert e("proposed", 16, "write") < e("conv", 16, "write")
    assert e("proposed", 16, "read") < e("conv", 16, "read")
