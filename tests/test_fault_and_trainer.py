"""Fault tolerance: watchdog (mocked clock), failure injection, trainer
checkpoint-restart, serving-engine consistency."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.distributed.fault import (FailureInjector, RestartableFailure,
                                     StepWatchdog)
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import forward, init_params
from repro.serve.engine import ServingEngine
from repro.storage.datapipe import SyntheticTokens
from repro.train.trainer import Trainer, TrainerConfig


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_watchdog_detects_stragglers():
    clk = FakeClock()
    wd = StepWatchdog(factor=3.0, patience=3, clock=clk)
    # establish 1s baseline
    for step in range(5):
        wd.start(); clk.t += 1.0
        assert wd.stop(step) is None
    # one 5s straggler -> skip-and-redistribute event, EMA unpoisoned
    wd.start(); clk.t += 5.0
    ev = wd.stop(5)
    assert ev is not None and ev.action == "skip-and-redistribute"
    assert wd.ema == pytest.approx(1.0)
    # persistent straggler escalates to a restartable failure
    with pytest.raises(RestartableFailure):
        for step in range(6, 12):
            wd.start(); clk.t += 5.0
            wd.stop(step)


def test_failure_injector_fires_once():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.maybe_fail(2)
    with pytest.raises(RestartableFailure):
        inj.maybe_fail(3)
    inj.maybe_fail(3)  # second pass: already fired


def test_trainer_restart_and_resume(tmp_path):
    cfg = get_arch("qwen2-0.5b").smoke
    mesh = make_host_mesh(model=1)
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq=12)
    tr = Trainer(cfg, TrainerConfig(steps=12, log_every=4, ckpt_every=4,
                                    ckpt_dir=str(tmp_path)),
                 mesh, data, injector=FailureInjector(fail_at_steps=(6,)))
    res = tr.run()
    assert res["final_step"] == 12
    assert res["restarts"] == 1
    # mechanics are the assertion here (restart fired, checkpoint resumed,
    # run completed, training didn't diverge); monotone loss decrease over
    # 12 steps of random tokens is covered by test_train_step_decreases_loss
    losses = [h["loss"] for h in res["history"]]
    assert losses[-1] < 2.0 * losses[0] and all(np.isfinite(losses))


def test_trainer_grad_accum_equivalence(tmp_path):
    """accum=2 over batch 8 ≈ accum=1 over the same batch (same data)."""
    cfg = get_arch("qwen2-0.5b").smoke
    mesh = make_host_mesh(model=1)

    def run(accum, d):
        data = SyntheticTokens(cfg.vocab_size, batch=8, seq=8, seed=3)
        tr = Trainer(cfg, TrainerConfig(steps=3, log_every=1, ckpt_every=100,
                                        ckpt_dir=str(d), grad_accum=accum),
                     mesh, data)
        return [h["loss"] for h in tr.run()["history"]]

    l1 = run(1, tmp_path / "a")
    l2 = run(2, tmp_path / "b")
    assert np.allclose(l1, l2, rtol=2e-2), (l1, l2)


def test_serving_engine_greedy_matches_forward():
    cfg = get_arch("granite-3-2b").smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_seq=32)
    prompts = [[5, 6, 7, 8, 9, 10], [11, 12, 13, 14, 15, 16]]
    res = eng.generate(prompts, n_new=5)
    assert res.tokens.shape == (2, 5)
    # teacher-forced check: feed generated sequence through forward; argmax
    # of each prefix must reproduce the generated token
    for r, p in enumerate(prompts):
        seq = list(p) + list(res.tokens[r])
        logits, _ = forward(cfg, params, jnp.asarray([seq], jnp.int32), mode="eval")
        for i in range(len(p) - 1, len(seq) - 1):
            assert int(jnp.argmax(logits[0, i])) == seq[i + 1], (r, i)
