"""Phase-resolved trace energy accounting (DESIGN.md §2.4): Table 5
through the trace engines, cross-engine ``EnergyBreakdown`` agreement,
and the energy/estimate-path hardening regressions (divide-by-zero and
payload-mask bugs)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import trace as tr
from repro.core.energy import (ControllerEnergyModel, N_OP_PHASES, POWER_W,
                               breakdown_from_sums, op_phase_energy_uj)
from repro.core.interface import InterfaceKind
from repro.core.nand import CellType
from repro.core.paper_tables import INTERFACE_ORDER, TABLE5
from repro.core.sim import SSDConfig
from repro.core.sim_ref import simulate_trace_energy_ref
from repro.storage.ssd_model import (estimate_trace, plan_geometry,
                                     plan_geometry_for_trace)

ANOMALIES = {("slc", "read", 2, "proposed")}


def _steady_breakdown(mode, ways, kind, n_pages=256, engine="scan"):
    cfg = SSDConfig(interface=InterfaceKind(kind), cell=CellType.SLC,
                    channels=1, ways=ways)
    table = tr.op_class_table(cfg)
    trace = tr.steady_trace(n_pages, 1, ways,
                            tr.READ if mode == "read" else tr.WRITE)
    return tr.simulate_energy(table, trace, kind, engine=engine)


# --- Table 5 through the trace-level energy path ---------------------------


def test_table5_reproduction_via_trace_engines():
    """The phase-resolved trace path reproduces the paper's SLC
    energy-per-byte to the same tolerance as the closed-form
    power/bandwidth shortcut it replaces."""
    errs = []
    for mode, by_ways in TABLE5.items():
        for ways, row in by_ways.items():
            for kind, paper in zip(INTERFACE_ORDER, row):
                if ("slc", mode, ways, kind) in ANOMALIES:
                    continue
                sim = _steady_breakdown(mode, ways, kind).nj_per_byte
                errs.append(abs(sim - paper) / paper)
    assert np.mean(errs) < 0.06, f"mean rel err {np.mean(errs):.3f}"


def test_energy_crossover_via_trace():
    """§5.3.3 through the trace path: PROPOSED costs more per byte than
    CONV at 1 way, less at 16 ways."""
    def e(kind, ways, mode):
        return _steady_breakdown(mode, ways, kind).nj_per_byte
    assert e("proposed", 1, "write") > e("conv", 1, "write")
    assert e("proposed", 16, "write") < e("conv", 16, "write")
    assert e("proposed", 16, "read") < e("conv", 16, "read")


def test_constant_power_recovery():
    """The phase split partitions the makespan, not the power: the
    controller total recovers the paper's P x wall-time envelope (up to
    the documented <0.5% cmd-overlap sliver on a saturated bus)."""
    for kind in InterfaceKind:
        for mode in ("read", "write"):
            bd = _steady_breakdown(mode, 8, kind)
            envelope = POWER_W[kind] * bd.end_us * 1e-6
            assert bd.controller_j == pytest.approx(envelope, rel=5e-3)
            assert bd.idle_j >= 0.0
            assert bd.controller_j == pytest.approx(
                bd.cmd_j + bd.io_j + bd.ecc_j + bd.ctrl_j + bd.idle_j)
            assert bd.total_j == pytest.approx(
                bd.controller_j + bd.array_j)


# --- cross-engine agreement -------------------------------------------------


@pytest.mark.parametrize("channels,ways", [(1, 8), (2, 4), (4, 2)])
@pytest.mark.parametrize("policy", ["eager", "batched"])
def test_engine_agreement_on_breakdown(channels, ways, policy):
    """scan == prefix == Pallas == numpy oracle on every phase of the
    breakdown, for mixed MLC traffic (parity-asymmetric array energy)."""
    cfg = SSDConfig(cell=CellType.MLC, channels=channels, ways=ways)
    table = tr.op_class_table(cfg)
    trace = tr.mixed_trace(160, channels, ways, read_fraction=0.6,
                           seed=channels * 13 + ways)
    end, sums = simulate_trace_energy_ref(table, trace, cfg.interface,
                                          policy)
    ref = breakdown_from_sums(sums, end, trace.total_bytes(table),
                              cfg.interface, channels)
    for engine in ("scan", "prefix", "pallas"):
        bd = tr.simulate_energy(table, trace, cfg.interface, policy,
                                engine=engine)
        assert bd.end_us == pytest.approx(ref.end_us, rel=1e-5), engine
        np.testing.assert_allclose(bd.op_sums_uj(), ref.op_sums_uj(),
                                   rtol=1e-3, err_msg=engine)
        assert bd.controller_j == pytest.approx(ref.controller_j,
                                                rel=1e-3), engine
        assert bd.total_j == pytest.approx(ref.total_j, rel=1e-3), engine


def test_prefix_segment_lengths_sum_identically():
    """The segment-sum accumulator is chunking-invariant (the ragged
    zero-pad really is a no-op for +)."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    table = tr.op_class_table(cfg)
    trace = tr.mixed_trace(150, 2, 4, read_fraction=0.4, seed=9)
    want = tr.simulate_energy(table, trace, cfg.interface, engine="scan")
    for seg in (1, 7, 64, 4096, None):
        got = tr.simulate_energy(table, trace, cfg.interface,
                                 engine="prefix", segment_len=seg)
        np.testing.assert_allclose(got.op_sums_uj(), want.op_sums_uj(),
                                   rtol=1e-4, err_msg=str(seg))


def test_pallas_periodic_energy_accumulator():
    """The periodic kernel path carries the phase accumulator too:
    sum_t E[t % period] next to the (max,+) fold."""
    from repro.core.maxplus_form import maxplus_eye
    from repro.kernels.maxplus.kernel import maxplus_fold_kernel

    rng = np.random.default_rng(3)
    b, m, n, p, t_steps = 3, 4, 6, N_OP_PHASES, 37
    mats = np.broadcast_to(maxplus_eye(n), (b, m, n, n)).astype(np.float32)
    energy = rng.random((b, m, p)).astype(np.float32)
    s0 = np.zeros((b, n), np.float32)
    out, acc = maxplus_fold_kernel(jnp.asarray(mats), jnp.asarray(s0),
                                   t_steps=t_steps,
                                   energy=jnp.asarray(energy))
    idx = np.arange(t_steps) % m
    np.testing.assert_allclose(np.asarray(acc), energy[:, idx].sum(axis=1),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out), s0, atol=1e-6)


def test_simulate_energy_validates_engine():
    """Unknown engine names raise the registry's ValueError; a
    registered engine asked outside its capability row (squaring on a
    heterogeneous trace) raises too — but squaring *is* now reachable
    for energy on its periodic domain (the old scan/pallas asymmetry is
    gone)."""
    cfg = SSDConfig(cell=CellType.SLC, channels=1, ways=2)
    table = tr.op_class_table(cfg)
    hetero = tr.mixed_trace(16, 1, 2, read_fraction=0.5, seed=1)
    assert len(set(hetero.cls.tolist())) == 2   # genuinely heterogeneous
    with pytest.raises(ValueError):
        tr.simulate_energy(table, hetero, cfg.interface, engine="squaring")
    with pytest.raises(ValueError, match="registered engines"):
        tr.simulate_energy(table, hetero, cfg.interface, engine="sqauring")
    steady = tr.steady_trace(8, 1, 2)
    want = tr.simulate_energy(table, steady, cfg.interface, engine="scan")
    got = tr.simulate_energy(table, steady, cfg.interface, engine="squaring")
    assert got.controller_j == pytest.approx(want.controller_j, rel=1e-3)


# --- phase table structure --------------------------------------------------


def test_phase_table_shapes_and_slot_split():
    """cmd/io/ecc/ctrl phase times partition slot_us + cmd_us exactly
    (the array phase is NAND-side and parity-resolved)."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    table = tr.op_class_table(cfg)
    e = op_phase_energy_uj(table, cfg.interface)
    assert e.shape == (2, 2, N_OP_PHASES)
    p_w = POWER_W[cfg.interface]
    for k in range(2):
        t_phases = e[k, 0, :4].astype(np.float64) / p_w   # back to us
        want = (table.cmd_us[k] + table.slot_us[k] + table.arb_us[k])
        assert float(t_phases.sum()) == pytest.approx(float(want), rel=1e-5)
    # only the array phase may depend on parity
    np.testing.assert_array_equal(e[:, 0, :4], e[:, 1, :4])
    assert e[1, 1, 4] > e[1, 0, 4]          # MLC upper page costs more


def test_phase_table_requires_io_column():
    cfg = SSDConfig(cell=CellType.SLC)
    table = tr.op_class_table(cfg)
    import dataclasses
    stripped = dataclasses.replace(table, io_us=None)
    with pytest.raises(ValueError):
        op_phase_energy_uj(stripped, cfg.interface)


def test_breakdown_extrapolation():
    bd = _steady_breakdown("read", 4, "proposed")
    bd10 = bd.extrapolated(10.0, end_us=10 * bd.end_us)
    assert bd10.cmd_j == pytest.approx(10 * bd.cmd_j, rel=1e-6)
    assert bd10.array_j == pytest.approx(10 * bd.array_j, rel=1e-6)
    assert bd10.controller_j == pytest.approx(10 * bd.controller_j, rel=5e-3)
    assert bd10.payload_bytes == 10 * bd.payload_bytes
    with pytest.raises(ValueError):
        bd.extrapolated(-1.0, end_us=1.0)


def test_hedged_duplicates_raise_energy_per_byte():
    """Hedged duplicate reads burn bus/controller energy but deliver no
    payload, so energy-per-payload-byte must rise."""
    cfg = SSDConfig(cell=CellType.SLC, channels=2, ways=2)
    base = estimate_trace(tr.datapipe_trace(4 << 20, cfg, hedge_fraction=0.0,
                                            seed=1), cfg)
    hedged = estimate_trace(tr.datapipe_trace(4 << 20, cfg,
                                              hedge_fraction=0.5, seed=1),
                            cfg)
    assert hedged.energy.nj_per_byte > base.energy.nj_per_byte
    assert hedged.read_bytes == base.read_bytes      # payload unchanged


# --- hardening regressions (ISSUE 3 satellites) -----------------------------


def test_energy_joules_rejects_nonpositive_bandwidth():
    """``energy_joules`` used to divide by ``bandwidth * 1e6`` unguarded
    — zero bandwidth raised ZeroDivisionError and negative bandwidth
    returned negative energy."""
    m = ControllerEnergyModel(InterfaceKind.PROPOSED)
    with pytest.raises(ValueError):
        m.energy_joules(1 << 20, 0.0)
    with pytest.raises(ValueError):
        m.energy_joules(1 << 20, -5.0)
    with pytest.raises(ValueError):
        m.energy_nj_per_byte(0.0)
    assert m.energy_joules(1 << 20, 100.0) > 0


def _empty_trace(channels=2, ways=4):
    z = np.zeros(0, np.int32)
    return tr.OpTrace(cls=z, channel=z, way=z, parity=z,
                      channels=channels, ways=ways)


def test_estimate_trace_rejects_empty_and_payload_free():
    """``estimate_trace`` divided by ``end_us`` and ``window_bytes``
    with no guard — an empty trace hit 0/0 instead of a clear error."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    with pytest.raises(ValueError, match="empty trace"):
        estimate_trace(_empty_trace(), cfg)
    n = 4
    masked = tr.OpTrace(cls=np.zeros(n, np.int32),
                        channel=np.zeros(n, np.int32),
                        way=np.zeros(n, np.int32),
                        parity=np.zeros(n, np.int32),
                        channels=2, ways=4, payload=np.zeros(n, bool))
    with pytest.raises(ValueError, match="payload"):
        estimate_trace(masked, cfg)
    table = tr.op_class_table(cfg)
    with pytest.raises(ValueError, match="empty trace"):
        tr.trace_bandwidth_mb_s(table, _empty_trace())
    with pytest.raises(ValueError, match="payload"):
        tr.trace_bandwidth_mb_s(table, masked)
    with pytest.raises(ValueError, match="empty trace"):
        tr.simulate_energy(table, _empty_trace(), cfg.interface)


def test_read_fraction_applies_payload_mask():
    """``read_fraction`` counted payload-masked hedged duplicates while
    ``total_bytes`` excluded them, so ``describe()`` and downstream
    read/write splits disagreed with the byte accounting."""
    cls = np.array([tr.READ, tr.WRITE, tr.WRITE, tr.WRITE], np.int32)
    payload = np.array([True, True, False, False])
    z = np.zeros(4, np.int32)
    t = tr.OpTrace(cls=cls, channel=z, way=z, parity=z, channels=1, ways=1,
                   payload=payload)
    assert t.read_fraction() == pytest.approx(0.5)   # was 0.25 unmasked
    assert "read_frac=0.50" in t.describe()
    cfg = SSDConfig(cell=CellType.SLC, channels=1, ways=1)
    table = tr.op_class_table(cfg)
    # byte accounting and op accounting now agree on the split
    read_bytes = int(table.data_bytes[cls[payload & (cls == tr.READ)]].sum())
    assert read_bytes / t.total_bytes(table) == pytest.approx(
        t.read_fraction())
    assert _empty_trace().read_fraction() == 0.0     # no nan on empty


# --- energy-aware planning --------------------------------------------------


def test_plan_geometry_energy_objective():
    nbytes = 1 << 30
    area = plan_geometry(nbytes, 30.0, "read", objective="area")
    energy = plan_geometry(nbytes, 30.0, "read", objective="energy")
    assert area is not None and energy is not None
    assert energy.seconds <= 30.0
    assert energy.energy_joules <= area.energy_joules
    with pytest.raises(ValueError):
        plan_geometry(nbytes, 30.0, "read", objective="watts")
    # trace-aware variant: returns a feasible, breakdown-carrying plan
    plan = plan_geometry_for_trace(
        lambda cfg: tr.checkpoint_trace(nbytes, cfg), budget_s=60.0,
        total_bytes=nbytes, objective="energy")
    assert plan is not None and plan.seconds <= 60.0
    assert plan.energy is not None and plan.energy.idle_j >= 0.0
    assert plan_geometry_for_trace(
        lambda cfg: tr.checkpoint_trace(nbytes, cfg), budget_s=1e-5,
        total_bytes=nbytes, objective="energy") is None
