"""The unified ``repro.api`` surface (DESIGN.md §2.5): API snapshot,
engine registry/capability dispatch, Simulator jit-closure caching,
``run_many`` bucket packing, request-layer policy validation, and one
regression test per deprecated shim (DeprecationWarning + numerically
identical results)."""

import dataclasses
import inspect

import numpy as np
import pytest

import repro.api as api
from repro.core import api as capi
from repro.core import trace as tr
from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, chip as nand_chip
from repro.core.sim import (SSDConfig, channel_bandwidth_mb_s,
                            page_op_params, policy_is_batched,
                            ssd_bandwidth_mb_s, sweep_bandwidth_mb_s)
from repro.core.sim_ref import simulate_trace_energy_ref, simulate_trace_ref


def _cfg(channels=2, ways=4, cell=CellType.MLC):
    return SSDConfig(cell=cell, channels=channels, ways=ways)


# --- API-surface snapshot ---------------------------------------------------

#: Public names + signatures of the ``repro.core.api`` surface.  An
#: intentional API change must update this snapshot (and the DESIGN.md
#: §2.5 / README migration table alongside it).
API_SNAPSHOT = {
    "CacheInfo": "(hits: 'int', misses: 'int', entries: 'int', "
                 "evictions: 'int' = 0, max_entries: 'int | None' = None) "
                 "-> None",
    "CapabilityError": "<class>",
    "EngineCaps": "(name: 'str', heterogeneous: 'bool', "
                  "batched_tables: 'bool', energy: 'bool', "
                  "jittable: 'bool', arrivals: 'bool' = False, "
                  "dispatch: 'bool' = False, ftl: 'bool' = False) -> None",
    "OBJECTIVES": ("end_time", "bandwidth", "energy", "all"),
    "SimRequest": "(trace: 'OpTrace | None' = None, "
                  "policy: 'Policy | None' = None, "
                  "objective: 'Objective' = 'end_time', "
                  "engine: 'str | None' = None, "
                  "segment_len: 'int | None' = 64, "
                  "workload: 'RequestStream | None' = None, "
                  "sched_policy: 'str | None' = None, "
                  "faults: 'FaultSpec | None' = None, "
                  "ftl: \"'_ftl.FTLSpec | None'\" = None) -> None",
    "SimResult": "(end_us: 'float', mb_s: 'float | None', "
                 "channel_busy_us: 'np.ndarray', "
                 "energy: 'EnergyBreakdown | None', engine: 'str', "
                 "n_ops: 'int', payload_bytes: 'int', "
                 "request_lat_us: 'np.ndarray | None' = None, "
                 "sched_policy: 'str | None' = None, "
                 "retry_hist: 'np.ndarray | None' = None, "
                 "n_remap_ops: 'int' = 0, waf: 'float | None' = None, "
                 "gc_op_count: 'int | None' = None, "
                 "free_page_low_watermark: 'int | None' = None, "
                 "fresh_mb_s: 'float | None' = None, "
                 "ftl_stats: \"'_ftl.FTLStats | None'\" = None) -> None",
    "Simulator": "(config: 'SSDConfig | None' = None, *, "
                 "table: 'OpClassTable | None' = None, "
                 "kind: 'InterfaceKind | str | None' = None, "
                 "max_cache_entries: 'int | None' = 512, "
                 "max_ftl_sessions: 'int | None' = 8)",
    "engine_capabilities": "() -> 'dict[str, EngineCaps]'",
    "get_engine": "(name: 'str') -> 'Engine'",
    "register_engine": "(name: 'str', *, heterogeneous: 'bool', "
                       "batched_tables: 'bool', energy: 'bool', "
                       "jittable: 'bool', arrivals: 'bool' = False, "
                       "dispatch: 'bool' = False, ftl: 'bool' = False)",
    "registered_engines": "() -> 'tuple[str, ...]'",
    "simulator_for": "(config: 'SSDConfig') -> 'Simulator'",
    "steady_bandwidth_mb_s": "(cfg: 'SSDConfig', mode: 'str', "
                             "n_pages: 'int' = 512) -> 'float'",
    "steady_channel_bandwidth_mb_s":
        "(op: 'PageOpParams', ways, policy: 'Policy' = 'eager', "
        "n_pages: 'int' = 512, engine: 'str' = 'scan') -> 'jax.Array'",
    "sweep_steady_bandwidth_mb_s":
        "(cmd_us, pre_us, slot_us, post_lo_us, post_hi_us, ctrl_us, "
        "data_bytes, ways, n_pages: 'int' = 512, batched: 'bool' = False, "
        "engine: 'str' = 'scan', shard: 'bool | None' = None) "
        "-> 'jax.Array'",
    "sweep_tables": "(tables, trace: 'OpTrace', *, "
                    "policy: 'Policy' = 'eager', engine: 'str' = 'prefix', "
                    "segment_len: 'int | None' = 64, "
                    "combine: 'str' = 'chain', "
                    "shard: 'bool | None' = None) -> 'np.ndarray'",
}

SIMULATOR_METHODS = {
    "run": "(self, request: 'SimRequest | OpTrace | RequestStream', /, "
           "**overrides) -> 'SimResult'",
    "run_many": "(self, traces, *, policy: 'Policy | None' = None, "
                "objective: 'Objective' = 'end_time', "
                "engine: 'str | None' = None, "
                "segment_len: 'int | None' = 64, "
                "shard: 'bool | None' = None) -> 'list[SimResult]'",
    "run_stream": "(self, chunks, *, policy: 'Policy | None' = None, "
                  "objective: 'Objective' = 'end_time', ftl=None, "
                  "faults: 'FaultSpec | None' = None, "
                  "sched_policy: 'str' = 'stripe') -> 'SimResult'",
    "sweep": "(self, tables, trace, *, "
             "policy: 'Policy | None' = None, engine: 'str' = 'prefix', "
             "segment_len: 'int | None' = 64, combine: 'str' = 'chain', "
             "shard: 'bool | None' = None, ftl=None, "
             "sched_policy: 'str' = 'stripe') -> 'np.ndarray'",
    "cache_info": "(self) -> 'CacheInfo'",
}


def test_api_surface_snapshot():
    """Freeze the public request/response surface: any signature drift
    is an intentional, reviewed API change."""
    for name, want in API_SNAPSHOT.items():
        obj = getattr(api, name)
        if not callable(obj):
            assert obj == want, name
        elif want == "<class>":
            assert inspect.isclass(obj), name
        else:
            assert str(inspect.signature(obj)) == want, name
    for name, want in SIMULATOR_METHODS.items():
        got = str(inspect.signature(getattr(api.Simulator, name)))
        assert got == want, name
    # every snapshot name (plus the protocol/type re-exports) is exported
    assert set(API_SNAPSHOT) <= set(api.__all__)
    for extra in ("Engine", "Policy", "Objective", "SSDConfig", "OpTrace",
                  "OpClassTable", "EnergyBreakdown", "workload_trace",
                  "RequestStream", "poisson_stream", "closed_loop_stream",
                  "build_workload", "lower_static", "SCHED_POLICIES",
                  "FaultSpec", "FaultSampler", "apply_faults"):
        assert extra in api.__all__, extra


# --- registry + capability table --------------------------------------------


def test_registry_names_and_capabilities():
    caps = api.engine_capabilities()
    assert api.registered_engines() == ("oracle", "pallas", "prefix",
                                        "scan", "squaring", "streaming")
    assert caps["scan"].heterogeneous and caps["scan"].jittable
    assert caps["prefix"].batched_tables and caps["prefix"].energy
    assert not caps["squaring"].heterogeneous
    assert not caps["squaring"].batched_tables
    assert caps["pallas"].batched_tables and not caps["pallas"].jittable
    assert not caps["oracle"].batched_tables
    assert caps["streaming"].heterogeneous and caps["streaming"].jittable
    assert caps["streaming"].arrivals
    assert not caps["streaming"].batched_tables
    for cap in caps.values():          # every engine accumulates energy
        assert cap.energy
        assert cap.name in cap.describe()
    # the registry instances satisfy the Engine protocol
    for name in api.registered_engines():
        assert isinstance(api.get_engine(name), api.Engine)


def test_unknown_engine_one_error_everywhere():
    """Unknown names raise the same registry ValueError (naming the
    registered engines) from every entry point — the old asymmetry
    (simulate rejected 'pallas', simulate_energy accepted it) is gone."""
    cfg = _cfg()
    sim = api.Simulator.for_config(cfg)
    trace = tr.mixed_trace(32, 2, 4, 0.5, seed=0)
    msgs = set()
    for fn in (lambda: sim.run(trace, engine="sqaring"),
               lambda: api.SimRequest(trace=trace, engine="sqaring"),
               lambda: api.sweep_tables([sim.table], trace,
                                        engine="sqaring")):
        with pytest.raises(ValueError, match="registered engines") as ei:
            fn()
        msgs.add(str(ei.value))
    assert len(msgs) == 1              # literally the same message
    # end-time queries route to the Pallas fold now, matching energy
    end_pl = sim.run(trace, engine="pallas").end_us
    bd_pl = sim.run(trace, engine="pallas", objective="energy").energy
    assert end_pl == pytest.approx(sim.run(trace).end_us, rel=1e-4)
    assert bd_pl.end_us == pytest.approx(end_pl, rel=1e-4)


def test_capability_errors_name_alternatives():
    cfg = _cfg()
    sim = api.Simulator.for_config(cfg)
    hetero = tr.mixed_trace(32, 2, 4, 0.5, seed=1)
    with pytest.raises(api.CapabilityError,      # derived from the registry
                       match="pallas, prefix, scan"):
        api.sweep_tables([sim.table], hetero, engine="oracle")
    with pytest.raises(api.CapabilityError,
                       match="oracle, pallas, prefix, scan"):
        sim.run(hetero, engine="squaring")
    op = page_op_params(make_interface(InterfaceKind.PROPOSED),
                        nand_chip(CellType.SLC), "read", 4)
    with pytest.raises(api.CapabilityError, match="scan, squaring"):
        api.get_engine("prefix").sweep_steady(
            (None,) * 6, None, None, n_pages=8, batched=False)
    assert float(api.steady_channel_bandwidth_mb_s(op, 4, n_pages=32)) > 0


def test_register_engine_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        @api.register_engine("scan", heterogeneous=True,
                             batched_tables=True, energy=True, jittable=True)
        class Dup:
            pass
    try:
        @api.register_engine("test-dummy", heterogeneous=False,
                             batched_tables=False, energy=False,
                             jittable=False)
        class Dummy(capi._EngineBase):
            def end_time(self, sim, trace, *, batched, segment_len):
                return 1.0
        assert "test-dummy" in api.registered_engines()
        with pytest.raises(api.CapabilityError):
            api.Simulator.for_config(_cfg()).run(
                tr.mixed_trace(8, 2, 4, 0.5), engine="test-dummy",
                objective="energy")
    finally:
        capi._REGISTRY.pop("test-dummy")


# --- all five engines through one Simulator, end time AND energy ------------


@pytest.mark.parametrize("policy", ["eager", "batched"])
def test_all_engines_agree_through_simulator(policy):
    """The acceptance grid (sampled): every registered engine answers
    through `Simulator.run` and agrees with the oracle < 1e-3 on end
    time and controller energy — heterogeneous engines on mixed traces
    over channels 1-4 x ways 1-16, squaring on its periodic domain."""
    for channels, ways in ((1, 1), (1, 16), (2, 4), (4, 8)):
        cfg = _cfg(channels, ways)
        sim = api.Simulator.for_config(cfg)
        trace = tr.mixed_trace(96, channels, ways, 0.6,
                               seed=channels * 17 + ways)
        end_ref, sums = simulate_trace_energy_ref(sim.table, trace,
                                                  cfg.interface, policy)
        tol = 1e-3 * trace.n_ops + 1e-5 * end_ref
        for name, caps in api.engine_capabilities().items():
            t = trace
            if not caps.heterogeneous:   # squaring: periodic domain
                if channels != 1:
                    continue
                t = tr.steady_trace(96, 1, ways, tr.READ)
            res = sim.run(t, policy=policy, engine=name, objective="all")
            want = simulate_trace_ref(sim.table, t, policy)
            assert abs(res.end_us - want) <= tol, (name, channels, ways)
            _, ref_sums = simulate_trace_energy_ref(sim.table, t,
                                                    cfg.interface, policy)
            np.testing.assert_allclose(res.energy.op_sums_uj(), ref_sums,
                                       rtol=1e-3, err_msg=name)
            assert res.engine == name


def test_simresult_fields():
    cfg = _cfg()
    sim = api.Simulator.for_config(cfg)
    trace = tr.mixed_trace(64, 2, 4, 0.5, seed=2)
    res = sim.run(trace, objective="all")
    assert res.n_ops == 64
    assert res.payload_bytes == trace.total_bytes(sim.table)
    assert res.mb_s == pytest.approx(res.payload_bytes / res.end_us)
    assert res.channel_busy_us.shape == (2,)
    want_busy = np.bincount(trace.channel,
                            weights=sim.table.slot_us[trace.cls],
                            minlength=2)
    np.testing.assert_allclose(res.channel_busy_us, want_busy, rtol=1e-6)
    assert np.all(res.channel_occupancy <= 1.0 + 1e-6)
    assert res.energy is not None and res.energy.idle_j >= 0.0
    assert "MB/s" in res.describe()
    # payload-free traces: no bandwidth, still an end time
    masked = dataclasses.replace(trace,
                                 payload=np.zeros(trace.n_ops, bool))
    assert sim.run(masked).mb_s is None
    with pytest.raises(ValueError, match="empty trace"):
        sim.run(dataclasses.replace(
            trace, cls=np.zeros(0, np.int32),
            channel=np.zeros(0, np.int32), way=np.zeros(0, np.int32),
            parity=np.zeros(0, np.int32), payload=None))


# --- jit-closure cache -------------------------------------------------------


def test_jit_cache_hits_on_repeated_queries():
    sim = api.Simulator(_cfg())
    trace = tr.mixed_trace(100, 2, 4, 0.5, seed=3)
    r1 = sim.run(trace)
    assert sim.cache_info() == api.CacheInfo(hits=0, misses=1, entries=1,
                                             max_entries=512)
    r2 = sim.run(trace)
    assert sim.cache_info() == api.CacheInfo(hits=1, misses=1, entries=1,
                                             max_entries=512)
    assert r1.end_us == r2.end_us
    # a different length in the same power-of-two bucket is also a hit
    sim.run(tr.mixed_trace(120, 2, 4, 0.5, seed=4))
    assert sim.cache_info().hits == 2
    # a different policy is a distinct closure
    sim.run(trace, policy="batched")
    assert sim.cache_info().misses == 2
    sim.cache_clear()
    assert sim.cache_info() == api.CacheInfo(hits=0, misses=0, entries=0,
                                             max_entries=512)


def test_jit_cache_lru_bound():
    """The closure cache is LRU-bounded: ``max_cache_entries`` caps the
    live entries, evicting least-recently-used closures (a long-lived
    serving session over many geometries no longer grows without
    bound), and recently-hit entries survive eviction."""
    with pytest.raises(ValueError, match="max_cache_entries"):
        api.Simulator(_cfg(), max_cache_entries=0)
    sim = api.Simulator(_cfg(), max_cache_entries=2)
    t1 = tr.mixed_trace(16, 2, 4, 0.5, seed=1)    # bucket 64
    t2 = tr.mixed_trace(100, 2, 4, 0.5, seed=2)   # bucket 128
    t3 = tr.mixed_trace(300, 2, 4, 0.5, seed=3)   # bucket 512
    sim.run(t1)
    sim.run(t2)
    sim.run(t1)                                    # t1 now most-recent
    assert sim.cache_info() == api.CacheInfo(hits=1, misses=2, entries=2,
                                             evictions=0, max_entries=2)
    sim.run(t3)                                    # evicts t2's closure
    assert sim.cache_info().evictions == 1
    assert sim.cache_info().entries == 2
    sim.run(t1)                                    # survived (recently used)
    assert sim.cache_info().hits == 2
    sim.run(t2)                                    # was evicted: a miss
    assert sim.cache_info().misses == 4
    # unbounded sessions never evict
    unb = api.Simulator(_cfg(), max_cache_entries=None)
    for t in (t1, t2, t3):
        unb.run(t)
    assert unb.cache_info() == api.CacheInfo(hits=0, misses=3, entries=3,
                                             evictions=0, max_entries=None)


def test_simulator_for_config_is_shared():
    cfg = _cfg(channels=1, ways=2)
    assert api.Simulator.for_config(cfg) is api.simulator_for(cfg)
    assert api.simulator_for(cfg) is api.simulator_for(
        SSDConfig(cell=CellType.MLC, channels=1, ways=2))


# --- run_many bucket packing -------------------------------------------------


def test_run_many_matches_per_trace_run():
    """Heterogeneous lengths pack into padded buckets; every result is
    identical to a per-trace run (masked padding is a state no-op), for
    both objectives and both policies."""
    cfg = _cfg()
    sim = api.Simulator.for_config(cfg)
    traces = [tr.mixed_trace(n, 2, 4, 0.5, seed=i)
              for i, n in enumerate((33, 100, 257, 100, 64))]
    for policy in ("eager", "batched"):
        results = sim.run_many(traces, policy=policy, objective="all")
        assert len(results) == len(traces)
        for t, r in zip(traces, results):
            single = sim.run(t, policy=policy, objective="all")
            assert r.end_us == single.end_us, t.n_ops
            assert r.mb_s == pytest.approx(single.mb_s)
            assert abs(r.energy.controller_j - single.energy.controller_j) \
                <= 1e-3 * single.energy.controller_j
            oracle = simulate_trace_ref(sim.table, t, policy)
            assert abs(r.end_us - oracle) <= 1e-3 * t.n_ops + 1e-5 * oracle
    # non-scan engines serve run_many through the per-trace path
    px = sim.run_many(traces[:2], engine="prefix")
    assert px[0].end_us == pytest.approx(sim.run(traces[0]).end_us,
                                         rel=1e-5)
    # empty batches return empty for every objective (no index crash)
    assert sim.run_many([]) == []
    assert sim.run_many([], objective="energy") == []


def test_run_many_compiles_only_populated_buckets():
    """The bucket grid is derived from the traces present: only
    populated (channels, length-bucket) groups build a closure, and the
    batch dimension pads to a power of two so batch-size jitter between
    calls reuses the compiled fold instead of recompiling per group
    size."""
    sim = api.Simulator(_cfg(), max_cache_entries=None)
    # lengths 20/40/50 share bucket 64; 100 lands in bucket 128 — the
    # empty 256/512/... buckets must not cost a compile
    traces = [tr.mixed_trace(n, 2, 4, 0.5, seed=i)
              for i, n in enumerate((20, 40, 50, 100))]
    sim.run_many(traces, shard=False)
    info = sim.cache_info()
    assert info.misses == 2                 # exactly the populated groups
    assert info.hits == 0
    # same shape again: pure hits
    sim.run_many(traces, shard=False)
    assert sim.cache_info() == api.CacheInfo(hits=2, misses=2, entries=2)
    # growing a group within its padded power-of-two batch (3 -> 4
    # traces in bucket 64, both pad to batch 4) is still a hit
    sim.run_many(traces + [tr.mixed_trace(30, 2, 4, 0.5, seed=9)],
                 shard=False)
    assert sim.cache_info() == api.CacheInfo(hits=4, misses=2, entries=2)
    # crossing the power of two (5 traces in bucket 64 pad to batch 8)
    # is one new closure for that group only
    more = traces + [tr.mixed_trace(25 + i, 2, 4, 0.5, seed=20 + i)
                     for i in range(2)]
    sim.run_many(more, shard=False)
    assert sim.cache_info() == api.CacheInfo(hits=5, misses=3, entries=3)


def test_run_many_pallas_megakernel_single_launch():
    """``engine="pallas"`` serves a heterogeneous fleet as one fused
    megakernel launch per (channels, ways) geometry over the union
    combo dictionary — results match the per-trace runs across mixed
    lengths (identity-padded lanes) and both policies."""
    sim = api.Simulator.for_config(_cfg())
    traces = [tr.mixed_trace(n, 2, 4, 0.5, seed=i)
              for i, n in enumerate((33, 100, 257, 100, 64, 12))]
    for policy in ("eager", "batched"):
        results = sim.run_many(traces, policy=policy, engine="pallas")
        for t, r in zip(traces, results):
            want = simulate_trace_ref(sim.table, t, policy)
            assert abs(r.end_us - want) <= 1e-3 * t.n_ops + 1e-5 * want, \
                (t.n_ops, policy)
            assert r.engine == "pallas"
    # arrival-aware fleets run through the same fused launch
    rng = np.random.default_rng(11)
    atr = [dataclasses.replace(
               t, arrival_us=np.sort(rng.uniform(0, 2000.0, t.n_ops))
               .astype(np.float32))
           for t in traces[:3]]
    for t, r in zip(atr, sim.run_many(atr, engine="pallas")):
        single = sim.run(t)
        assert abs(r.end_us - single.end_us) <= 1e-3 * single.end_us
    # mixed geometries split into one launch per (channels, ways) group
    mixed = [tr.mixed_trace(48, 2, 4, 0.5, seed=1),
             tr.mixed_trace(48, 1, 8, 0.5, seed=2)]
    for t, r in zip(mixed, sim.run_many(mixed, engine="pallas")):
        assert r.end_us == pytest.approx(sim.run(t).end_us, rel=1e-4)


# --- policy validation (the silent-fallthrough fix) -------------------------


def test_policy_typo_raises_everywhere():
    """Every layer used to compare ``policy == "batched"`` — a typo
    silently simulated eager.  Now the request layer (and the frozen
    config) validate the literal once and raise."""
    cfg = _cfg()
    sim = api.Simulator.for_config(cfg)
    trace = tr.mixed_trace(16, 2, 4, 0.5, seed=5)
    with pytest.raises(ValueError, match="unknown policy"):
        policy_is_batched("bathced")
    with pytest.raises(ValueError, match="unknown policy"):
        sim.run(trace, policy="bathced")
    with pytest.raises(ValueError, match="unknown policy"):
        api.SimRequest(trace=trace, policy="bathced")
    with pytest.raises(ValueError, match="unknown policy"):
        sim.run_many([trace], policy="bathced")
    with pytest.raises(ValueError, match="unknown policy"):
        SSDConfig(policy="bathced")
    with pytest.raises(ValueError, match="unknown policy"):
        tr.simulate(sim.table, trace, policy="bathced")
    with pytest.raises(ValueError, match="unknown policy"):
        simulate_trace_ref(sim.table, trace, "bathced")
    with pytest.raises(ValueError, match="unknown objective"):
        sim.run(trace, objective="latency")
    # the two valid literals still route to genuinely different sims
    assert sim.run(trace, policy="eager").end_us \
        != sim.run(trace, policy="batched").end_us


# --- one regression test per deprecated shim --------------------------------


def _mixed():
    cfg = _cfg()
    sim = api.Simulator.for_config(cfg)
    return cfg, sim, tr.mixed_trace(80, 2, 4, 0.6, seed=6)


def test_shim_simulate():
    _, sim, trace = _mixed()
    with pytest.deprecated_call():
        old = tr.simulate(sim.table, trace, engine="prefix")
    assert old == sim.run(trace, engine="prefix").end_us


def test_shim_simulate_batch():
    _, sim, trace = _mixed()
    tables = [sim.table, sim.table]
    with pytest.deprecated_call():
        old = tr.simulate_batch(tables, trace)
    np.testing.assert_array_equal(old, api.sweep_tables(tables, trace))


def test_shim_simulate_energy():
    cfg, sim, trace = _mixed()
    with pytest.deprecated_call():
        old = tr.simulate_energy(sim.table, trace, cfg.interface)
    new = sim.run(trace, objective="energy").energy
    assert old.controller_j == new.controller_j
    np.testing.assert_array_equal(old.op_sums_uj(), new.op_sums_uj())


def test_shim_trace_bandwidth_mb_s():
    _, sim, trace = _mixed()
    with pytest.deprecated_call():
        old = tr.trace_bandwidth_mb_s(sim.table, trace)
    assert old == sim.run(trace, objective="bandwidth").mb_s


def test_shim_channel_bandwidth_mb_s():
    op = page_op_params(make_interface(InterfaceKind.PROPOSED),
                        nand_chip(CellType.MLC), "write", 4)
    for engine in ("scan", "prefix", "squaring"):
        with pytest.deprecated_call():
            old = float(channel_bandwidth_mb_s(op, 4, n_pages=64,
                                               engine=engine))
        new = float(api.steady_channel_bandwidth_mb_s(op, 4, n_pages=64,
                                                      engine=engine))
        assert old == new, engine


def test_shim_sweep_bandwidth_mb_s():
    import jax.numpy as jnp
    ops = [page_op_params(make_interface(k), nand_chip(c), m, 4)
           for k in InterfaceKind for c in CellType for m in ("read", "write")]
    args = tuple(jnp.asarray([getattr(o, f) for o in ops], jnp.float32)
                 for f in ("cmd_us", "pre_us", "slot_us", "post_lo_us",
                           "post_hi_us", "ctrl_us", "data_bytes"))
    wv = jnp.asarray([4] * len(ops), jnp.int32)
    for engine in ("scan", "squaring"):
        with pytest.deprecated_call():
            old = np.asarray(sweep_bandwidth_mb_s(*args, wv, n_pages=64,
                                                  engine=engine))
        new = np.asarray(api.sweep_steady_bandwidth_mb_s(
            *args, wv, n_pages=64, engine=engine))
        np.testing.assert_array_equal(old, new, err_msg=engine)


def test_shim_ssd_bandwidth_mb_s():
    cfg = SSDConfig(cell=CellType.SLC, channels=2, ways=8)
    with pytest.deprecated_call():
        old = ssd_bandwidth_mb_s(cfg, "read")
    assert old == api.steady_bandwidth_mb_s(cfg, "read")
