"""FTL query surface (DESIGN.md §2.10): GC-translated streams through
the full engine grid — the five heterogeneous engines must stay
bit-agreeing on traces carrying FTL/GC/erase op classes — plus the
SimRequest/SimResult plumbing, capability enforcement, the fresh-vs-
aged bandwidth cliff, and the fault-integration path."""

import dataclasses

import numpy as np
import pytest

import repro.api as api
from repro.core import ftl
from repro.core.nand import CellType
from repro.core.sim import SSDConfig
from repro.core.workload import overwrite_stream

ENGINES = ("scan", "prefix", "pallas", "streaming", "oracle")

SPEC = ftl.FTLSpec(blocks=64, pages_per_block=32, overprovision=0.25,
                   precondition=True)


def _tol(ref_us, n_ops):
    # <= 1e-3 us/op plus a float32 reassociation floor: the log-depth
    # engines fold multi-second GC traces (erase posts are milliseconds)
    # in a different order, so the ulp term is wider than the plain
    # workload grid's
    return 1e-3 * n_ops + 5e-5 * ref_us


def _sim(channels=2, ways=4):
    return api.Simulator(SSDConfig(cell=CellType.MLC, channels=channels,
                                   ways=ways))


# --- cross-engine agreement on GC-injected traces ---------------------------


@pytest.mark.parametrize("policy", ["eager", "batched"])
@pytest.mark.parametrize("channels,ways", [(1, 2), (2, 4), (4, 8)])
def test_gc_translated_engines_agree(policy, channels, ways):
    """GC ops are ordinary trace ops: every heterogeneous engine answers
    the translated stream within the shared tolerance (ISSUE acceptance
    gate: < 1e-3 relative)."""
    sim = _sim(channels, ways)
    stream = overwrite_stream(1500, 1200, read_fraction=0.2,
                              mean_interarrival_us=30.0,
                              seed=channels * 7 + ways)
    got = {eng: sim.run(stream, ftl=SPEC, engine=eng, policy=policy)
           for eng in ENGINES}
    assert got["scan"].gc_op_count > 0       # GC actually in the trace
    ref = got["oracle"].end_us
    tol = _tol(ref, got["oracle"].n_ops)
    for eng, res in got.items():
        assert abs(res.end_us - ref) <= tol, (eng, res.end_us, ref)
        assert abs(res.end_us - ref) / ref < 1e-3, (eng, res.end_us, ref)
        # translation is engine-independent: identical accounting
        assert res.waf == got["scan"].waf
        assert res.n_ops == got["scan"].n_ops


def test_dynamic_dispatch_consumes_gc_ops():
    """GC ops compete with host ops in dynamic dispatch: the run
    succeeds, keeps the FTL accounting, and beats the static stripe
    placement it is free to improve on."""
    sim = _sim()
    stream = overwrite_stream(1500, 1200, seed=3)
    dyn = sim.run(stream, ftl=SPEC, sched_policy="least_loaded")
    sta = sim.run(stream, ftl=SPEC)
    assert dyn.sched_policy == "least_loaded"
    assert dyn.waf == sta.waf and dyn.gc_op_count == sta.gc_op_count
    assert dyn.end_us <= sta.end_us * 1.001
    assert dyn.request_lat_us is not None
    with pytest.raises(ValueError, match="dynamic dispatch"):
        sim.run(stream, ftl=SPEC, sched_policy="least_loaded",
                policy="batched")


# --- fresh vs aged bandwidth (the cliff) ------------------------------------


def test_aged_slower_than_fresh():
    sim = _sim()
    stream = overwrite_stream(2500, 1500, seed=1)
    res = sim.run(stream, ftl=SPEC)
    assert res.gc_op_count > 0
    assert res.fresh_mb_s is not None
    assert res.mb_s < res.fresh_mb_s          # GC steals bus time
    assert res.waf > 1.0
    assert res.free_page_low_watermark >= 0
    assert res.ftl_stats.gc_pages_moved > 0
    assert "WAF" in res.describe()


def test_no_gc_means_no_cliff():
    sim = _sim()
    spec = ftl.FTLSpec(blocks=128, pages_per_block=64, overprovision=0.5)
    res = sim.run(overwrite_stream(200, 150, seed=2), ftl=spec)
    assert res.gc_op_count == 0
    assert res.fresh_mb_s is None             # nothing to compare against
    assert res.waf == 1.0


def test_non_ftl_results_carry_no_ftl_fields():
    sim = _sim()
    res = sim.run(overwrite_stream(100, 64, seed=0))
    assert res.waf is None and res.gc_op_count is None
    assert res.fresh_mb_s is None and res.ftl_stats is None


# --- request validation + capability enforcement ----------------------------


def test_simrequest_ftl_validation():
    t = api.build_workload("mixed", SSDConfig(channels=2, ways=4))
    with pytest.raises(ValueError, match="workload"):
        api.SimRequest(trace=t, ftl=SPEC)
    with pytest.raises(ValueError, match="FTLSpec"):
        api.SimRequest(workload=overwrite_stream(10, 8), ftl="greedy")


def test_squaring_lacks_ftl_capability():
    sim = _sim()
    stream = overwrite_stream(500, 400, seed=0)
    with pytest.raises(api.CapabilityError) as e:
        sim.run(stream, ftl=SPEC, engine="squaring")
    msg = str(e.value)
    for eng in ENGINES:
        assert eng in msg                    # names the capable engines
    caps = api.engine_capabilities()
    assert not caps["squaring"].ftl
    assert all(caps[e].ftl for e in ENGINES)
    assert "ftl" in caps["scan"].describe()


def test_ftl_session_memoised_per_table_shape():
    sim = _sim()
    s1 = sim._ftl_session(SPEC)
    s2 = sim._ftl_session(dataclasses.replace(SPEC, gc_policy="lru",
                                              overprovision=0.4))
    assert s1 is s2                           # same map/erase timing
    s3 = sim._ftl_session(dataclasses.replace(SPEC, map_us=2.0))
    assert s3 is not s1
    assert s1.table.n_classes == 7


# --- fault integration through the query layer ------------------------------


def test_faults_retire_blocks_and_price_retries():
    sim = _sim()
    spec = ftl.FTLSpec(blocks=128, pages_per_block=32, overprovision=0.3)
    stream = overwrite_stream(9000, 2048, read_fraction=0.2, seed=2)
    worn = api.FaultSpec(wear=0.6, prog_fail_prob=0.001,
                         erase_fail_prob=0.01, seed=3)
    res = sim.run(stream, ftl=spec, faults=worn)
    st = res.ftl_stats
    assert st.blocks_retired > 0 and st.prog_fails > 0
    # read retries still ride extra_us (sampled on the class view)
    assert res.retry_hist is not None and res.retry_hist[1:].sum() > 0
    # surcharges push the makespan past the fault-free run
    clean = sim.run(stream, ftl=spec)
    assert res.end_us > clean.end_us
    assert clean.retry_hist is None


def test_hedged_ftl_stream():
    sim = _sim()
    spec = ftl.FTLSpec(blocks=128, pages_per_block=32, overprovision=0.3)
    stream = overwrite_stream(2000, 1024, read_fraction=0.5, seed=5)
    res = sim.run(stream, ftl=spec,
                  faults=api.FaultSpec(wear=0.5, hedge_fraction=0.3,
                                       seed=4))
    # hedged duplicates expand the op stream but latency reporting stays
    # per payload request
    assert len(res.request_lat_us) == stream.n_requests
    assert np.isfinite(res.request_lat_us).all()


# --- energy + objective plumbing --------------------------------------------


def test_ftl_energy_objective():
    sim = _sim()
    stream = overwrite_stream(1200, 900, seed=7)
    res = sim.run(stream, ftl=SPEC, objective="all")
    assert res.energy is not None
    assert res.energy.total_j > 0
    assert res.waf > 1.0


def test_scan_canonical_folds_include_ftl():
    sim = _sim()
    folds = api.get_engine("scan").canonical_folds(sim)
    assert "ftl_end_time" in folds
    fn, args = folds["ftl_end_time"]
    end = float(fn(*args))
    assert end > 0.0
