"""Reliability layer (DESIGN.md §2.8): wear-dependent read-retry,
program/erase fault injection, hedged-read mitigation and degraded-mode
QoS.  The correctness story mirrors the arrival layer's: faults reduce
to a per-op additive surcharge plus a trace rewrite sampled *outside*
the fold, so every engine must agree on faulty inputs to the same
tolerance as fault-free ones, and everything must be bit-deterministic
given (trace, FaultSpec, seed).

Deliberately hypothesis-free (fixed seed grids), like
tests/test_workload_sched.py."""

import dataclasses
import warnings

import numpy as np
import pytest

import repro.api as api
from repro.core import faults as fl, sched, trace as tr, workload as wl
from repro.core.nand import CellType
from repro.core.sim import SSDConfig
from repro.core.sim_ref import simulate_trace_ref


def _sim(channels, ways):
    return api.Simulator.for_config(
        SSDConfig(cell=CellType.MLC, channels=channels, ways=ways))


def _tol(ref_us, n_ops):
    return 1e-3 * n_ops + 1e-5 * ref_us


ZERO = api.FaultSpec(rber_fresh=0.0, rber_worn=0.0)
# The retry-storm gate configuration (benchmarks/reliability_bench.py
# freezes the same numbers): ~3% of reads storm with >= 500 us retry
# ladders, load light enough that a cross-chip duplicate can overtake.
STORM = dict(wear=1.0, rber_worn=3e-5, max_retries=4,
             retry_step_us=(500.0, 1000.0, 2000.0, 4000.0))
STORM_LOAD = dict(n=400, mean_interarrival_us=600.0, seed=2)


def _storm_load():
    return api.poisson_stream(STORM_LOAD["n"],
                              STORM_LOAD["mean_interarrival_us"],
                              seed=STORM_LOAD["seed"])


# --- spec / sampler basics ---------------------------------------------------


def test_fault_constants_pin_trace_op_classes():
    # faults.py mirrors READ/WRITE to avoid the circular import
    assert fl.READ == tr.READ and fl.WRITE == tr.WRITE


def test_fault_spec_validation_and_rber_curve():
    with pytest.raises(ValueError, match="wear"):
        api.FaultSpec(wear=-0.1)
    with pytest.raises(ValueError, match="prog_fail_prob"):
        api.FaultSpec(prog_fail_prob=1.5)
    with pytest.raises(ValueError, match="retry_step_us"):
        api.FaultSpec(retry_step_us=(10.0, -1.0))
    with pytest.raises(ValueError, match="max_retries"):
        api.FaultSpec(max_retries=-1)
    with pytest.raises(ValueError, match="hedge_after_us"):
        api.FaultSpec(hedge_after_us=-5.0)
    # geometric interpolation: fresh at wear 0, worn at wear 1
    s = api.FaultSpec(wear=0.0, rber_fresh=1e-8, rber_worn=1e-4)
    assert s.rber() == pytest.approx(1e-8)
    assert dataclasses.replace(s, wear=1.0).rber() == pytest.approx(1e-4)
    mid = dataclasses.replace(s, wear=0.5).rber()
    assert 1e-8 < mid < 1e-4
    # per-step failure probability caps at 0.95 however worn
    assert api.FaultSpec(wear=5.0, rber_worn=1.0).p_retry_step() == 0.95
    # the default spec is NOT zero (a fresh drive still has rber > 0);
    # an explicitly zeroed curve is
    assert not api.FaultSpec().is_zero
    assert ZERO.is_zero
    assert not dataclasses.replace(ZERO, jitter_us=1.0).is_zero
    assert not dataclasses.replace(ZERO, prog_fail_prob=0.1).is_zero


def test_zero_fault_spec_is_bit_identical():
    """Acceptance pin: a zero FaultSpec reproduces the fault-free result
    bit-for-bit on every engine — the whole layer is +0.0 when off."""
    sim = _sim(2, 4)
    t = tr.mixed_trace(300, 2, 4, 0.6, seed=1)
    t2, rid, sampler = sched.apply_faults(t, ZERO, sim.table)
    for f in ("cls", "channel", "way", "parity"):
        np.testing.assert_array_equal(getattr(t2, f), getattr(t, f), f)
    assert np.all(np.asarray(t2.extra_us) == 0.0)
    assert sampler.n_remap_ops == 0 and not sampler.retired.any()
    for engine in ("scan", "prefix", "pallas", "streaming", "oracle"):
        assert sim.run(t, engine=engine, faults=ZERO).end_us == \
            sim.run(t, engine=engine).end_us, engine
    # ... and through the workload paths (static lowering + dispatch)
    load = api.poisson_stream(80, 50.0, seed=3)
    for policy in ("stripe", "least_loaded"):
        a = sim.run(load, sched_policy=policy, faults=ZERO)
        b = sim.run(load, sched_policy=policy)
        assert a.end_us == b.end_us, policy
        np.testing.assert_array_equal(a.request_lat_us, b.request_lat_us)


# --- cross-engine agreement + determinism on faulty inputs -------------------


@pytest.mark.parametrize("channels,ways", [(1, 2), (2, 4), (4, 4)])
def test_faulty_engines_agree_and_are_deterministic(channels, ways):
    """The faulty-trace extension of the <1e-3 cross-engine agreement
    gate: retry/jitter surcharges thread five independent recurrence
    implementations, and a second run must be bit-identical (all draws
    happen outside the fold)."""
    sim = _sim(channels, ways)
    spec = api.FaultSpec(wear=0.9, jitter_us=3.0, seed=channels + ways)
    t = tr.mixed_trace(240, channels, ways, 0.7, seed=ways)
    t2, _, _ = sched.apply_faults(t, spec, sim.table)
    assert np.any(np.asarray(t2.extra_us) > 0.0)   # the gate is real
    ref = simulate_trace_ref(sim.table, t2)
    tol = _tol(ref, t2.n_ops)
    for engine in ("scan", "prefix", "pallas", "streaming"):
        got = sim.run(t2, engine=engine).end_us
        assert abs(got - ref) <= tol, (engine, channels, ways)
        assert got == sim.run(t2, engine=engine).end_us, engine
    # the same spec resampled from the spec (not the pre-built trace)
    # is deterministic end to end
    a = sim.run(t, faults=spec)
    b = sim.run(t, faults=spec)
    assert a.end_us == b.end_us
    np.testing.assert_array_equal(a.retry_hist, b.retry_hist)
    assert int(a.retry_hist.sum()) == int(np.sum(np.asarray(t.cls)
                                                 == tr.READ))


def test_faults_and_extra_us_compose_exclusively():
    sim = _sim(2, 4)
    t = tr.mixed_trace(64, 2, 4, 0.5, seed=0)
    t2, _, _ = sched.apply_faults(t, api.FaultSpec(wear=1.0), sim.table)
    # double application is refused everywhere
    with pytest.raises(ValueError, match="already carries extra_us"):
        sched.apply_faults(t2, ZERO, sim.table)
    with pytest.raises(ValueError, match="already carries extra_us"):
        list(tr.iter_trace_chunks(t2, 16, faults=ZERO, table=sim.table))
    with pytest.raises(ValueError, match="already carries extra_us"):
        api.SimRequest(trace=t2, faults=ZERO)
    with pytest.raises(ValueError, match="FaultSpec"):
        api.SimRequest(trace=t, faults="worn")
    # negative surcharges are rejected at construction
    with pytest.raises(ValueError, match="extra_us"):
        dataclasses.replace(t, extra_us=np.full(64, -1.0, np.float32))


def test_squaring_rejects_faulty_traces_but_takes_zero_specs():
    sim = _sim(1, 4)
    steady = tr.steady_trace(32, 1, 4, tr.READ)
    with pytest.raises(api.CapabilityError, match="fault-extended"):
        sim.run(steady, engine="squaring",
                faults=api.FaultSpec(wear=1.0, seed=3))
    assert sim.run(steady, engine="squaring", faults=ZERO).end_us == \
        sim.run(steady, engine="squaring").end_us


# --- chunked sampling == one-shot (satellite: streaming determinism) ---------


def test_chunked_fault_sampling_is_bit_identical_to_one_shot():
    """A carried FaultSampler consumes one PCG64 stream regardless of
    chunk boundaries, so chunked rewrites concatenate to the one-shot
    rewrite bit-for-bit — including remap inserts that change chunk
    lengths."""
    sim = _sim(2, 4)
    spec = api.FaultSpec(wear=1.0, jitter_us=2.0, prog_fail_prob=0.1,
                         erase_fail_prob=0.2, seed=5)
    t = tr.mixed_trace(500, 2, 4, 0.4, seed=8)
    whole, _, _ = sched.apply_faults(t, spec, sim.table)
    for chunk_len in (33, 64, 499, 1024):
        parts = list(tr.iter_trace_chunks(t, chunk_len, faults=spec,
                                          table=sim.table))
        assert sum(p.n_ops for p in parts) == whole.n_ops
        for field in ("cls", "channel", "way", "parity", "extra_us"):
            cat = np.concatenate([np.asarray(getattr(p, field))
                                  for p in parts])
            np.testing.assert_array_equal(
                cat, np.asarray(getattr(whole, field)),
                err_msg=f"{field}@{chunk_len}")
        cat_pay = np.concatenate([p.payload_mask() for p in parts])
        np.testing.assert_array_equal(cat_pay, whole.payload_mask())
    # generator twin: mixed_trace_chunks(faults=) == apply_faults(mixed)
    for chunk_len in (100, 1000):
        parts = list(tr.mixed_trace_chunks(500, 2, 4, 0.4,
                                           chunk_len=chunk_len, seed=8,
                                           faults=spec, table=sim.table))
        for field in ("cls", "channel", "way", "parity", "extra_us"):
            cat = np.concatenate([np.asarray(getattr(p, field))
                                  for p in parts])
            np.testing.assert_array_equal(
                cat, np.asarray(getattr(whole, field)),
                err_msg=f"gen:{field}@{chunk_len}")


def test_incremental_sampler_matches_one_shot_draws():
    spec = api.FaultSpec(wear=1.0, jitter_us=1.0, prog_fail_prob=0.3,
                         retry_step_us=(100.0, 200.0), seed=9)
    cls = tr.mixed_trace(400, 2, 4, 0.5, seed=1).cls
    one = fl.FaultSampler(spec, 2, 4)
    e1, f1, r1 = one.sample(cls)
    chunked = fl.FaultSampler(spec, 2, 4)
    es, fs, rs = zip(*(chunked.sample(cls[lo:lo + 77])
                       for lo in range(0, 400, 77)))
    np.testing.assert_array_equal(np.concatenate(es), e1)
    np.testing.assert_array_equal(np.concatenate(fs), f1)
    np.testing.assert_array_equal(np.concatenate(rs), r1)
    np.testing.assert_array_equal(chunked.retry_hist, one.retry_hist)
    np.testing.assert_array_equal(chunked.retired, one.retired)


# --- program faults: remap conservation + retirement -------------------------


def test_program_fault_remaps_conserve_bytes_and_avoid_retired_ways():
    sim = _sim(4, 4)
    spec = api.FaultSpec(rber_fresh=0.0, rber_worn=0.0,
                         prog_fail_prob=1.0, erase_fail_prob=0.3, seed=4)
    t = tr.mixed_trace(200, 4, 4, 0.5, seed=2)
    n_writes = int(np.sum(np.asarray(t.cls) == tr.WRITE))
    t2, _, sampler = sched.apply_faults(t, spec, sim.table)
    # every write failed -> one remap each, inserted right after
    assert sampler.n_remap_ops == n_writes
    assert t2.n_ops == t.n_ops + n_writes
    # byte conservation: the failed original keeps its bus/cell cost but
    # its payload credit moves to the remap
    assert t2.total_bytes(sim.table) == t.total_bytes(sim.table)
    assert int(t2.payload_mask().sum()) == t.n_ops
    # the remap follows its failed original on the same channel, on a
    # non-retired way
    fail = np.flatnonzero(~t2.payload_mask())      # the stripped originals
    remap = fail + 1
    np.testing.assert_array_equal(np.asarray(t2.channel)[remap],
                                  np.asarray(t2.channel)[fail])
    assert not sampler.retired[np.asarray(t2.channel)[remap],
                               np.asarray(t2.way)[remap]].any()
    # retirement always leaves >= 1 live way per channel
    for seed in range(8):
        s = fl.FaultSampler(dataclasses.replace(spec, erase_fail_prob=0.9,
                                                seed=seed), 4, 4)
        assert (~s.retired).any(axis=1).all(), seed
    # the faulty trace still clears every engine's agreement gate
    ref = simulate_trace_ref(sim.table, t2)
    for engine in ("scan", "prefix", "pallas"):
        assert abs(sim.run(t2, engine=engine).end_us - ref) <= \
            _tol(ref, t2.n_ops), engine


def test_least_loaded_never_dispatches_to_a_retired_way():
    """Property over a seed grid: retired (channel, way) pairs are a
    hard dispatch constraint for both dynamic rules."""
    sim = _sim(2, 4)
    scan = api.get_engine("scan")
    for seed in range(5):
        sampler = fl.FaultSampler(
            dataclasses.replace(ZERO, erase_fail_prob=0.45, seed=seed),
            2, 4)
        if not sampler.retired.any():
            continue
        load = api.poisson_stream(120, 30.0, seed=seed)
        cls, arr, _, _ = wl.request_ops(load)
        for rule in ("least_loaded", "earliest_ready"):
            _, _, chan, way, _ = scan.dispatch_run(
                sim, cls, arr, n_channels=2, n_ways=4, rule=rule,
                retired=sampler.retired)
            hit = sampler.retired[np.asarray(chan), np.asarray(way)]
            assert not hit.any(), (seed, rule)


# --- percentile guard (satellite) --------------------------------------------


def test_percentile_guard_clamps_warns_and_nans():
    sim = _sim(2, 4)
    res = sim.run(api.poisson_stream(10, 50.0, seed=0),
                  sched_policy="stripe")
    lat = np.asarray(res.request_lat_us)
    with warnings.catch_warnings():
        warnings.simplefilter("error")             # p50 on 10: resolvable
        assert res.p50_us == pytest.approx(np.percentile(lat, 50))
    for q_attr in ("p99_us", "p99_9_us"):          # p99(.9) on 10: clamped
        with pytest.warns(RuntimeWarning, match="percentile resolution"):
            assert getattr(res, q_attr) == float(np.max(lat))
    # exactly at the resolution threshold: no warning
    res100 = sim.run(api.poisson_stream(100, 50.0, seed=1),
                     sched_policy="stripe")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert res100.p99_us == pytest.approx(
            np.percentile(np.asarray(res100.request_lat_us), 99))
    with pytest.warns(RuntimeWarning):
        res100.p99_9_us                            # p99.9 needs 1000
    # empty stream -> NaN; absent stream -> None
    empty = dataclasses.replace(res, request_lat_us=np.zeros(0))
    assert np.isnan(empty.p50_us) and np.isnan(empty.p99_9_us)
    none = dataclasses.replace(res, request_lat_us=None)
    assert none.p50_us is None and none.p99_us is None


# --- degraded-mode QoS: wear monotonicity + the hedging win ------------------


def test_p99_rises_monotonically_with_wear():
    sim = _sim(4, 4)
    load = _storm_load()
    prev = -1.0
    for wear in (0.0, 0.5, 0.75, 1.0):
        spec = api.FaultSpec(seed=7, **{**STORM, "wear": wear})
        r = sim.run(load, faults=spec)
        assert r.p99_us >= prev - 1e-9, wear
        prev = r.p99_us
    assert prev > 400.0                # worn tail is a >= 500 us storm


def test_hedged_reads_cut_the_retry_storm_p99():
    """The mitigation gate (same numbers as BENCH_7's hedging row): a
    hedged duplicate lands on the next (channel, way), so when the
    primary draws a >= 500 us retry storm the duplicate's completion
    wins the request's first-response credit."""
    sim = _sim(4, 4)
    load = _storm_load()
    unhedged = sim.run(load, faults=api.FaultSpec(seed=7, **STORM))
    hedged = sim.run(load, faults=api.FaultSpec(
        seed=7, hedge_fraction=1.0, hedge_after_us=250.0, **STORM))
    assert int(unhedged.retry_hist[1:].sum()) > 0  # storms happened
    assert len(hedged.request_lat_us) == load.n_requests  # payload only
    assert hedged.p99_us <= unhedged.p99_us
    assert hedged.p99_us < 0.75 * unhedged.p99_us  # and clearly, not by luck
    assert hedged.p50_us <= unhedged.p50_us * 1.25  # tail cut, not median tax


def test_workload_faults_end_to_end_static_and_dynamic():
    sim = _sim(2, 4)
    load = api.poisson_stream(150, 80.0, read_fraction=0.4, seed=6)
    spec = api.FaultSpec(wear=1.0, prog_fail_prob=0.1,
                         erase_fail_prob=0.2, seed=3)
    for policy in ("stripe", "least_loaded"):
        a = sim.run(load, sched_policy=policy, faults=spec)
        assert a.sched_policy == policy
        assert a.n_remap_ops > 0 and a.retry_hist is not None
        assert len(a.request_lat_us) == load.n_requests
        b = sim.run(load, sched_policy=policy, faults=spec)
        assert a.end_us == b.end_us, policy
        np.testing.assert_array_equal(a.request_lat_us, b.request_lat_us)
        assert a.n_remap_ops == b.n_remap_ops
    # remap writes cost time: the faulty run never finishes earlier
    clean = sim.run(load, sched_policy="stripe")
    faulty = sim.run(load, sched_policy="stripe", faults=spec)
    assert faulty.end_us >= clean.end_us
