"""Logically-addressed workload builders (DESIGN.md §2.10): the
overwrite / aging emitters, lpn threading through the stream
combinators, and ``request_lpns`` — the workload-side half of the FTL
stage."""

import numpy as np
import pytest

from repro.core.sim import SSDConfig
from repro.core.trace import READ, WRITE
from repro.core import workload as wl

CFG = SSDConfig(channels=2, ways=4)


# --- overwrite / aging emitters ---------------------------------------------


def test_overwrite_stream_uniform_over_footprint():
    s = wl.overwrite_stream(8000, 512, seed=0)
    assert s.n_requests == 8000
    assert s.lpn is not None and s.lpn.dtype == np.int64
    assert int(s.lpn.min()) >= 0 and int(s.lpn.max()) < 512
    assert (s.op_cls == WRITE).all()
    # uniform: every page of the footprint is hit, roughly evenly
    counts = np.bincount(s.lpn, minlength=512)
    assert (counts > 0).all()
    assert counts.max() < 10 * counts.mean()


def test_overwrite_stream_reads_and_arrivals():
    s = wl.overwrite_stream(2000, 256, read_fraction=0.4,
                            mean_interarrival_us=25.0, seed=1)
    frac = float(np.mean(s.op_cls == READ))
    assert 0.3 < frac < 0.5
    assert (np.diff(s.arrival_us) >= 0).all()
    assert s.arrival_us[0] == 0.0
    assert float(s.arrival_us[-1]) > 0.0


def test_aging_stream_hot_cold_skew():
    s = wl.aging_stream(20_000, 1000, hot_fraction=0.2, hot_traffic=0.8,
                        seed=2)
    n_hot = 200
    hot_hits = float(np.mean(s.lpn < n_hot))
    # 80% of traffic on the hottest 20% of the footprint
    assert 0.75 < hot_hits < 0.85
    assert int(s.lpn.max()) < 1000


def test_builder_validation():
    with pytest.raises(ValueError, match="footprint"):
        wl.overwrite_stream(10, 0)
    with pytest.raises(ValueError, match="read_fraction"):
        wl.overwrite_stream(10, 64, read_fraction=1.5)
    with pytest.raises(ValueError, match="hot_fraction"):
        wl.aging_stream(10, 64, hot_fraction=0.0)
    with pytest.raises(ValueError, match="hot_traffic"):
        wl.aging_stream(10, 64, hot_traffic=-0.1)
    with pytest.raises(ValueError, match="footprint"):
        wl.aging_stream(10, 1)


def test_registry_has_overwrite_and_aging():
    for kind in ("overwrite", "aging"):
        assert kind in wl.WORKLOAD_KINDS
        t = wl.build_workload(kind, CFG, n_requests=128,
                              footprint_pages=256)
        assert t.n_ops == 128           # request kinds lower to traces
    with pytest.raises(ValueError) as e:
        wl.build_workload("ftl", CFG)
    assert "overwrite" in str(e.value) and "aging" in str(e.value)


# --- lpn threading through the stream machinery -----------------------------


def test_request_lpns_explicit_and_round_robin():
    s = wl.overwrite_stream(100, 64, seed=3)
    lpns = wl.request_lpns(s, 64)
    assert np.array_equal(lpns, s.lpn)      # 1 page/request: verbatim
    # address-free streams fall back to round-robin over the space
    bare = wl.poisson_stream(10, 50.0, seed=0)
    assert bare.lpn is None
    got = wl.request_lpns(bare, 4)
    assert np.array_equal(got, np.arange(int(np.sum(bare.n_pages))) % 4)
    with pytest.raises(ValueError):
        wl.request_lpns(s, 0)


def test_request_lpns_multipage_requests_are_contiguous():
    s = wl.overwrite_stream(50, 256, pages_per_request=4, seed=4)
    lpns = wl.request_lpns(s, 256)
    reps = np.asarray(s.n_pages)
    assert len(lpns) == int(reps.sum())
    # each request covers lpn, lpn+1, ... (mod the logical space)
    pos = 0
    for r in range(s.n_requests):
        base = int(s.lpn[r])
        want = (base + np.arange(reps[r])) % 256
        assert np.array_equal(lpns[pos: pos + reps[r]], want)
        pos += reps[r]


def test_multi_tenant_merges_or_rejects_lpn():
    a = wl.overwrite_stream(50, 128, seed=0, stream=0)
    b = wl.aging_stream(50, 128, seed=1, stream=1)
    merged = wl.multi_tenant([a, b])
    assert merged.lpn is not None and len(merged.lpn) == 100
    bare = wl.poisson_stream(50, 10.0, seed=2)
    with pytest.raises(ValueError, match="lpn"):
        wl.multi_tenant([a, bare])
    # two address-free tenants still merge fine
    assert wl.multi_tenant(
        [bare, wl.poisson_stream(10, 10.0, seed=3)]).lpn is None


def test_with_hedges_carries_lpn():
    s = wl.overwrite_stream(400, 128, read_fraction=0.6, seed=5)
    h = wl.with_hedges(s, 0.5, seed=6)
    assert h.lpn is not None and len(h.lpn) == h.n_requests
    assert h.n_requests > s.n_requests      # duplicates appended
    hof = np.asarray(h.hedge_of)
    dup = hof >= 0
    # a duplicate re-reads its primary's logical page
    assert np.array_equal(h.lpn[dup], h.lpn[hof[dup]])


def test_stream_lpn_validation():
    with pytest.raises(ValueError):
        wl.RequestStream(
            arrival_us=np.zeros(2, np.float32),
            op_cls=np.zeros(2, np.int32),
            n_pages=np.ones(2, np.int64),
            stream=np.zeros(2, np.int32),
            lpn=np.array([0, -1], np.int64))
    with pytest.raises(ValueError):
        wl.RequestStream(
            arrival_us=np.zeros(2, np.float32),
            op_cls=np.zeros(2, np.int32),
            n_pages=np.ones(2, np.int64),
            stream=np.zeros(2, np.int32),
            lpn=np.zeros(3, np.int64))
