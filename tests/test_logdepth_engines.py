"""Log-depth (max,+) engines (DESIGN.md §2.3): segmented parallel-prefix
trace folds, periodic matrix squaring, the scalar-prefetch Pallas path,
and the sweep/channel ctrl_us regression pin."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, chip as nand_chip
from repro.core import maxplus_form as mf
from repro.core import trace as tr
from repro.core.sim import (SSDConfig, channel_bandwidth_mb_s,
                            page_op_params, sweep_bandwidth_mb_s)
from repro.core.sim_ref import (simulate_channel_ref,
                                simulate_trace_matfold_ref,
                                simulate_trace_ref)
from repro.kernels.maxplus.ops import (channel_end_time_maxplus,
                                       trace_end_time_maxplus)
from repro.kernels.maxplus.ref import maxplus_product_ref


def _tol(ref_us, n_ops):
    # <= 1e-3 us/op plus the float32 ulp floor at the end-time magnitude
    return 1e-3 * n_ops + 1e-5 * ref_us


# --- deterministic cross-engine equivalence ---------------------------------


@pytest.mark.parametrize("channels,ways", [(1, 1), (1, 16), (2, 4), (4, 8)])
@pytest.mark.parametrize("policy", ["eager", "batched"])
def test_prefix_engines_match_oracle(channels, ways, policy):
    """Segmented-prefix scan engine, segmented (max,+) fold, and the
    numpy matfold oracle all agree with the event-loop oracle on mixed
    MLC traffic (parity alternation exercised)."""
    cfg = SSDConfig(cell=CellType.MLC, channels=channels, ways=ways)
    table = tr.op_class_table(cfg)
    trace = tr.mixed_trace(192, channels, ways, read_fraction=0.6,
                           seed=channels * 7 + ways)
    ref = simulate_trace_ref(table, trace, policy)
    tol = _tol(ref, trace.n_ops)
    for seg in (1, 17, 64, 4096, None):
        got = tr.simulate(table, trace, policy, engine="prefix",
                          segment_len=seg)
        assert abs(got - ref) <= tol, (seg,)
    seg_mp = float(trace_end_time_maxplus(table, trace, policy=policy,
                                          strategy="segmented"))
    assert abs(seg_mp - ref) <= tol
    mat = simulate_trace_matfold_ref(table, trace, policy, segment_len=48)
    assert abs(mat - ref) <= tol


@pytest.mark.parametrize("ways", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("policy", ["eager", "batched"])
def test_squaring_matches_scan_and_oracle(ways, policy):
    """O(log T) squaring == O(T) scan == python loop, including ragged
    n_pages (remainder-prefix path) and MLC write asymmetry."""
    op = page_op_params(make_interface(InterfaceKind.PROPOSED),
                       nand_chip(CellType.MLC), "write", ways)
    for n_pages in (1, 31, 96, 512):
        ref = simulate_channel_ref(op, ways, n_pages,
                                   batched=(policy == "batched"))
        want = n_pages * op.data_bytes / ref
        scan = float(channel_bandwidth_mb_s(op, ways, policy, n_pages))
        sq = float(channel_bandwidth_mb_s(op, ways, policy, n_pages,
                                          engine="squaring"))
        assert scan == pytest.approx(want, rel=1e-3)
        assert sq == pytest.approx(want, rel=1e-3), n_pages
        end = channel_end_time_maxplus([op], [ways], n_pages=n_pages,
                                       policy=policy, strategy="squaring")
        assert float(end[0]) == pytest.approx(ref, rel=1e-3)


def test_scalar_prefetch_kernel_path():
    """The trace-indexed Pallas path (SMEM scalar prefetch) agrees with
    the jnp sequential reference on a batched heterogeneous fold."""
    trace = tr.mixed_trace(160, 2, 4, read_fraction=0.5, seed=5)
    tables = [tr.op_class_table(SSDConfig(interface=k, cell=c,
                                          channels=2, ways=4))
              for k in InterfaceKind for c in CellType]
    kern = trace_end_time_maxplus(tables, trace)
    ref = trace_end_time_maxplus(tables, trace, use_kernel=False)
    np.testing.assert_allclose(kern, ref, rtol=1e-5)
    for t, k in zip(tables, kern):
        want = simulate_trace_ref(t, trace)
        assert float(k) == pytest.approx(want, rel=1e-4)


def test_sweep_charges_ctrl_us_like_channel_path():
    """Regression pin for the silent zero-ctrl bug: the batched sweep and
    the per-point channel path must charge identical shared-controller
    occupancy (they were diverging via a zero_k placeholder)."""
    ops, ways = [], []
    for kind in InterfaceKind:
        for cell in CellType:
            for mode in ("read", "write"):
                for w in (1, 4, 16):
                    ops.append(page_op_params(make_interface(kind),
                                              nand_chip(cell), mode, w))
                    ways.append(w)
    args = tuple(
        jnp.asarray([getattr(o, f) for o in ops], jnp.float32)
        for f in ("cmd_us", "pre_us", "slot_us", "post_lo_us", "post_hi_us",
                  "ctrl_us", "data_bytes"))
    wv = jnp.asarray(ways, jnp.int32)
    for engine in ("scan", "squaring"):
        bw = np.asarray(sweep_bandwidth_mb_s(*args, wv, n_pages=128,
                                             engine=engine))
        want = np.asarray([
            float(channel_bandwidth_mb_s(o, w, n_pages=128))
            for o, w in zip(ops, ways)])
        np.testing.assert_allclose(bw, want, rtol=1e-3, err_msg=engine)


def test_engine_dispatch_is_validated():
    """Unknown engines and squaring's ways|MAX_WAYS precondition raise
    instead of silently falling back to the scan engine."""
    op = page_op_params(make_interface(InterfaceKind.PROPOSED),
                       nand_chip(CellType.SLC), "read", 4)
    with pytest.raises(ValueError):
        channel_bandwidth_mb_s(op, 6, n_pages=64, engine="squaring")
    with pytest.raises(ValueError):
        channel_bandwidth_mb_s(op, 4, n_pages=64, engine="sqaring")
    args = tuple(
        jnp.asarray([getattr(op, f)], jnp.float32)
        for f in ("cmd_us", "pre_us", "slot_us", "post_lo_us", "post_hi_us",
                  "ctrl_us", "data_bytes"))
    with pytest.raises(ValueError):
        sweep_bandwidth_mb_s(*args, jnp.asarray([12], jnp.int32),
                             n_pages=64, engine="squaring")
    with pytest.raises(ValueError):
        sweep_bandwidth_mb_s(*args, jnp.asarray([4], jnp.int32),
                             n_pages=64, engine="prefix")
    cfg = SSDConfig(cell=CellType.SLC, channels=1, ways=2)
    table = tr.op_class_table(cfg)
    hetero = tr.mixed_trace(16, 1, 2, read_fraction=0.5, seed=1)
    with pytest.raises(ValueError):        # outside squaring's capability
        tr.simulate(table, hetero, engine="squaring")
    with pytest.raises(ValueError):        # squaring has no batched tables
        tr.simulate_batch([table], tr.steady_trace(16, 1, 2),
                          engine="squaring")
    # ...but the registry now routes squaring's periodic domain through
    # the same entry point the other engines use (the old asymmetry)
    steady = tr.steady_trace(16, 1, 2)
    assert tr.simulate(table, steady, engine="squaring") == pytest.approx(
        tr.simulate(table, steady, engine="scan"), rel=1e-3)


# --- algebra invariants -----------------------------------------------------


def test_neg_identity_rows_survive_squaring():
    """NEG (= -inf) identity rows are idempotent under repeated squaring:
    no drift, no float overflow — unused layout rows stay exact."""
    eye = jnp.asarray(mf.maxplus_eye(8))
    p = mf.maxplus_matrix_power(eye, 1 << 20)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(eye))

    # a real op matrix with identity (unused-way) rows: high powers keep
    # those rows exactly at the identity and everything finite
    layout = mf.StateLayout(1, 4)
    a = mf.op_matrix(layout, cmd_us=0.1, pre_us=5.0, slot_us=20.0,
                     ctrl_us=2.0, arb_us=0.0, post_us=100.0,
                     channel=0, way=1)
    p = np.asarray(mf.maxplus_matrix_power(jnp.asarray(a), 4096))
    assert np.all(np.isfinite(p))
    unused_chip = layout.chip(0, 3)       # way 3 never touched by the op
    row = p[unused_chip]
    assert row[unused_chip] == 0.0
    assert np.all(row[np.arange(layout.n_state) != unused_chip] <= mf.NEG)


def test_matrix_power_matches_sequential_product():
    rng = np.random.default_rng(0)
    mats = (rng.random((2, 3, 6, 6)).astype(np.float32) * 5)
    for q in (0, 1, 2, 7, 33):
        idx = jnp.tile(jnp.arange(3, dtype=jnp.int32), q)[: 3 * q]
        want = np.asarray(maxplus_product_ref(jnp.asarray(mats), idx))
        # periodic_fold_squaring over q periods == sequential product
        got_state = np.asarray(mf.periodic_fold_squaring(
            jnp.asarray(mats), jnp.zeros((2, 6), jnp.float32), 3 * q))
        want_state = np.max(want + np.zeros((2, 1, 6)), axis=-1)
        np.testing.assert_allclose(got_state, want_state, rtol=1e-4,
                                   atol=1e-3)


# --- property suite (hypothesis when available, deterministic grid
# fallback otherwise — the deterministic tests above always run) -------------


def _check_segmented_property(channels, ways, read_fraction, batched,
                              segment_len, seed):
    """Random heterogeneous traces: the segmented-prefix engines equal
    the scan engine and the python oracle to 1e-3 (per-op) tolerance."""
    policy = "batched" if batched else "eager"
    cfg = SSDConfig(cell=CellType.MLC, channels=channels, ways=ways)
    table = tr.op_class_table(cfg)
    trace = tr.mixed_trace(128, channels, ways, read_fraction, seed=seed)
    ref = simulate_trace_ref(table, trace, policy)
    tol = _tol(ref, trace.n_ops)
    px = tr.simulate(table, trace, policy, engine="prefix",
                     segment_len=segment_len)
    assert abs(px - ref) <= tol
    mp = float(trace_end_time_maxplus(
        table, trace, policy=policy, strategy="segmented",
        segment_len=segment_len or 64))
    assert abs(mp - ref) <= tol


def _check_squaring_property(ways, batched, n_pages, kind, cell, mode):
    """Random homogeneous design points: squaring == python loop to 1e-3
    rtol at arbitrary (ragged) trace lengths."""
    op = page_op_params(make_interface(kind), nand_chip(cell), mode, ways)
    ref = simulate_channel_ref(op, ways, n_pages, batched=batched)
    policy = "batched" if batched else "eager"
    sq = float(channel_bandwidth_mb_s(op, ways, policy, n_pages,
                                      engine="squaring"))
    assert sq == pytest.approx(n_pages * op.data_bytes / ref, rel=1e-3)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    @pytest.mark.parametrize("channels,ways", [(1, 5), (2, 16), (3, 3),
                                               (4, 9)])
    @pytest.mark.parametrize("batched", [False, True])
    def test_property_segmented_prefix_matches_oracle(channels, ways,
                                                      batched):
        for seg in (1, 64, None):
            _check_segmented_property(channels, ways, 0.55, batched, seg,
                                      seed=channels * 131 + ways)

    @pytest.mark.parametrize("ways", [1, 2, 4, 8, 16])
    @pytest.mark.parametrize("batched", [False, True])
    def test_property_squaring_matches_oracle(ways, batched):
        for n_pages in (1, 53, 200):
            _check_squaring_property(ways, batched, n_pages,
                                     InterfaceKind.PROPOSED, CellType.MLC,
                                     "write")
else:
    @settings(deadline=None, max_examples=20)
    @given(channels=st.integers(1, 4), ways=st.integers(1, 16),
           read_fraction=st.floats(0.0, 1.0), batched=st.booleans(),
           segment_len=st.sampled_from([1, 8, 64, 512, None]),
           seed=st.integers(0, 1 << 16))
    def test_property_segmented_prefix_matches_oracle(
            channels, ways, read_fraction, batched, segment_len, seed):
        _check_segmented_property(channels, ways, read_fraction, batched,
                                  segment_len, seed)

    @settings(deadline=None, max_examples=20)
    @given(ways=st.sampled_from([1, 2, 4, 8, 16]), batched=st.booleans(),
           n_pages=st.integers(1, 300),
           kind=st.sampled_from(list(InterfaceKind)),
           cell=st.sampled_from(list(CellType)),
           mode=st.sampled_from(["read", "write"]))
    def test_property_squaring_matches_oracle(ways, batched, n_pages, kind,
                                              cell, mode):
        _check_squaring_property(ways, batched, n_pages, kind, cell, mode)
