"""Checkpoint engine, data pipeline, SSD pricing and KV-offload planning."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.interface import InterfaceKind
from repro.core.sim import SSDConfig
from repro.storage.checkpoint import CheckpointEngine
from repro.storage.datapipe import (FileBackedTokens, StripedTokenStore,
                                    SyntheticTokens, pipeline_io_trace)
from repro.storage.kvoffload import plan_kv_offload
from repro.storage.ssd_model import compare_interfaces, estimate_io, plan_geometry


def _state():
    k = jax.random.PRNGKey(0)
    return {"params": {"w": jax.random.normal(k, (64, 32)),
                       "b": jnp.zeros((32,), jnp.bfloat16)},
            "opt": {"count": jnp.ones((), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    eng = CheckpointEngine(tmp_path, channels=3, ways=2)
    state = _state()
    eng.save(10, state, extra={"pipe_cursor": 7}, blocking=True)
    step, restored, extra = eng.restore(template=state)
    assert step == 10 and extra["pipe_cursor"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_checkpoint_gc_and_latest(tmp_path):
    eng = CheckpointEngine(tmp_path, keep=2)
    st = _state()
    for step in (1, 2, 3):
        eng.save(step, st, blocking=True)
    assert eng.latest_step() == 3
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(steps) == 2  # keep=2


def test_checkpoint_modeled_ssd_stall(tmp_path):
    eng = CheckpointEngine(tmp_path)
    eng.save(1, _state(), blocking=True)
    res = eng.wait()
    assert res.nbytes > 0
    # DDR interface strictly reduces the projected stall (paper's headline)
    assert res.modeled["proposed"] < res.modeled["sync_only"] <= res.modeled["conv"]


def test_synthetic_pipeline_deterministic_resume():
    a = SyntheticTokens(1000, batch=2, seq=8, seed=1)
    it = iter(a)
    for _ in range(5):
        next(it)                    # advance past the first five batches
    st = a.state()
    more = [next(it) for _ in range(2)]
    b = SyntheticTokens(1000, batch=2, seq=8, seed=1)
    b.restore(st)
    it2 = iter(b)
    for expected in more:
        got = next(it2)
        assert np.array_equal(expected["inputs"], got["inputs"])


def test_file_backed_pipeline(tmp_path):
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 5000, 40_000, dtype=np.int32)
    store = StripedTokenStore.write(tmp_path, tokens, channels=4)
    pipe = FileBackedTokens(store, batch=4, seq=16, ways=2)
    it = iter(pipe)
    b1 = next(it)
    assert b1["inputs"].shape == (4, 16)
    assert np.array_equal(b1["inputs"][:, 1:], b1["labels"][:, :-1])
    pipe.close()


def test_ssd_model_ordering_and_planning():
    ests = compare_interfaces(10 << 30, "write", channels=2, ways=8)
    assert ests["proposed"].seconds < ests["sync_only"].seconds \
        < ests["conv"].seconds
    plan = plan_geometry(10 << 30, budget_s=120.0, mode="write")
    assert plan is not None and plan.seconds <= 120.0
    # impossible budget -> None
    assert plan_geometry(10 << 40, budget_s=0.1, mode="write") is None


def test_estimate_energy_scales_with_bytes():
    cfg = SSDConfig(interface=InterfaceKind.PROPOSED, channels=2, ways=8)
    e1 = estimate_io(1 << 30, cfg, "read")
    e2 = estimate_io(2 << 30, cfg, "read")
    assert e2.energy_joules == pytest.approx(2 * e1.energy_joules, rel=1e-6)
    assert e2.seconds == pytest.approx(2 * e1.seconds, rel=1e-6)


def test_pipeline_emits_priceable_trace(tmp_path):
    """The datapipe's access pattern is an SSD op trace the cost model
    can price directly (reads only; synthetic pipes do no I/O)."""
    from repro.storage.ssd_model import estimate_trace
    rng = np.random.default_rng(0)
    store = StripedTokenStore.write(
        tmp_path, rng.integers(0, 5000, 40_000, dtype=np.int32), channels=2)
    pipe = FileBackedTokens(store, batch=4, seq=16, ways=2)
    it = iter(pipe)
    next(it)
    pipe.close()
    tr = pipeline_io_trace(pipe, n_batches=64)
    assert tr is not None and tr.channels == 2
    est = estimate_trace(tr, SSDConfig(channels=2, ways=2),
                         total_bytes=64 * 4 * 17 * 4)
    assert est.seconds > 0 and est.write_bytes == 0 and est.read_bytes > 0
    assert pipeline_io_trace(SyntheticTokens(10, 1, 8), 4) is None


def test_kv_offload_planning():
    qwen = plan_kv_offload(get_arch("qwen2-0.5b").config, 524288)
    assert qwen.applicable
    assert qwen.tokens_per_s["proposed"] > 1.5 * qwen.tokens_per_s["conv"]
    xl = plan_kv_offload(get_arch("xlstm-350m").config, 524288)
    assert not xl.applicable                      # attention-free
    rg = plan_kv_offload(get_arch("recurrentgemma-9b").config, 524288)
    assert not rg.applicable                      # windowed-only attention
