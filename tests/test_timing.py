"""Paper §4.3/§5.2 closed-form timing equations."""


import pytest

pytest.importorskip("hypothesis", reason="property suite needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, strategies as st

from repro.core import timing


def test_eq6_conventional_matches_paper():
    # (7.82 + 20 + 1.65 + 0.25) / 1.5 = 19.81 ns  ->  50 MHz
    t = timing.t_p_min_conventional()
    assert t == pytest.approx(19.81, abs=0.01)
    assert timing.max_frequency_mhz(t) == 50


def test_eq9_proposed_matches_paper():
    # max{(0.25 + 0.02 + 4.69) * 2, 12} = 12 ns  ->  83 MHz
    t = timing.t_p_min_proposed()
    assert t == pytest.approx(12.0)
    assert timing.max_frequency_mhz(t) == 83


def test_proposed_is_t_byte_limited():
    """Paper §6: the proposed cycle is limited purely by t_BYTE."""
    b = timing.PAPER_BOARD
    assert (b.t_S + b.t_H + b.t_DIFF) * 2 < b.t_BYTE


def test_derive_paper_clocks():
    c = timing.derive_paper_clocks()
    assert (c.conv_mhz, c.prop_mhz) == (50, 83)
    assert c.conv_cycle_ns == pytest.approx(20.0)
    assert c.prop_cycle_ns == pytest.approx(1e3 / 83)


def test_eq2_dll():
    assert timing.t_dll(5.0, 1.0, 0.25) == pytest.approx(4.25)


@given(st.floats(0.0, 0.5))
def test_eq1_and_alpha_monotonicity(alpha):
    """Larger alpha (more D_CON delay budget) never hurts the CONV clock."""
    t = timing.t_p_min_conventional(alpha=alpha)
    t_half = timing.t_p_min_conventional(alpha=0.5)
    assert t >= t_half - 1e-12
    assert timing.t_d(alpha, 20.0) == pytest.approx(alpha * 20.0)


@given(st.floats(0.1, 50.0), st.floats(0.01, 10.0), st.floats(0.1, 40.0))
def test_eq8_lower_bound(t_ios, t_ioh, t_byte):
    t = timing.t_p_min_proposed_io(t_ios, t_ioh, t_byte)
    assert t >= (t_ios + t_ioh) * 2 - 1e-12
    assert t >= t_byte - 1e-12
    assert t == pytest.approx(max((t_ios + t_ioh) * 2, t_byte))


def test_alpha_validation():
    with pytest.raises(ValueError):
        timing.t_d(0.7, 10.0)
