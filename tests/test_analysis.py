"""repro.analysis: AST rules must trip on bad fixtures, jaxpr checks
must catch injected violations, the baseline must round-trip, and the
repo itself must be clean (DESIGN.md §2.9).

The fixture modules are written to tmp_path on purpose: the analyzer's
CI gate lints ``src/repro``/``benchmarks``/``examples`` but *not*
``tests/``, precisely so that violation fixtures can exist here.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import astlint, baseline as _baseline, jaxprs
from repro.analysis.cli import SCAN_ROOTS
from repro.analysis.findings import Finding, render_json, render_text

REPO = Path(__file__).resolve().parents[1]


def _lint_src(tmp_path, source, only=None):
    mod = tmp_path / "fixture.py"
    mod.write_text(textwrap.dedent(source))
    findings, n = astlint.lint_paths([mod], root=tmp_path, only=only)
    assert n == 1
    return findings


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Layer 1: each AST rule trips on a synthetic bad module
# ---------------------------------------------------------------------------


def test_rng_global_trips(tmp_path):
    findings = _lint_src(tmp_path, """
        import numpy as np
        x = np.random.rand(4)
        rng = np.random.default_rng()
        ok = np.random.default_rng(42)
    """)
    hits = [f for f in findings if f.rule == "rng-global"]
    assert {f.line for f in hits} == {3, 4}, findings
    assert all(f.is_error for f in hits)


def test_rng_global_stdlib_random(tmp_path):
    findings = _lint_src(tmp_path, """
        import random
        x = random.random()
    """)
    assert "rng-global" in _rules(findings)


def test_rng_in_fold_trips_even_when_seeded(tmp_path):
    findings = _lint_src(tmp_path, """
        import time
        import numpy as np
        import jax

        def fold(xs):
            def step(carry, op):
                r = np.random.default_rng(0).normal()
                t = time.time()
                return carry, op
            return jax.lax.scan(step, 0.0, xs)
    """)
    hits = [f for f in findings if f.rule == "rng-in-fold"]
    assert {f.line for f in hits} == {8, 9}, findings


def test_rng_in_fold_sees_lambda_bodies(tmp_path):
    findings = _lint_src(tmp_path, """
        import datetime
        import jax
        out = jax.lax.fori_loop(
            0, 4, lambda i, c: c + datetime.datetime.now().microsecond, 0)
    """)
    assert "rng-in-fold" in _rules(findings)


def test_engine_dispatch_trips_outside_registry(tmp_path):
    findings = _lint_src(tmp_path, """
        def pick(engine):
            if engine == "scan":
                return 1
            return engine in ("prefix", "squaring")
    """)
    hits = [f for f in findings if f.rule == "engine-dispatch"]
    assert len(hits) == 2, findings


def test_engine_dispatch_allowed_in_registry_module(tmp_path):
    api = tmp_path / "src" / "repro" / "core" / "api.py"
    api.parent.mkdir(parents=True)
    api.write_text('def pick(engine):\n    return engine == "scan"\n')
    findings, _ = astlint.lint_paths([api], root=tmp_path)
    assert "engine-dispatch" not in _rules(findings)


def test_shim_internal_trips(tmp_path):
    findings = _lint_src(tmp_path, """
        from repro.core.sim import ssd_bandwidth_mb_s
        from repro.core import trace

        def go():
            a = ssd_bandwidth_mb_s()
            b = trace.simulate()
            return a, b
    """)
    hits = [f for f in findings if f.rule == "shim-internal"]
    assert {f.line for f in hits} == {6, 7}, findings
    assert any("Simulator.run" in f.message for f in hits)


def test_host_in_fold_trips(tmp_path):
    findings = _lint_src(tmp_path, """
        import numpy as np
        import jax

        def fold(xs):
            def step(carry, op):
                v = float(carry)
                w = carry.item()
                u = np.asarray(op)
                return carry, op
            return jax.lax.scan(step, 0.0, xs)
    """)
    hits = [f for f in findings if f.rule == "host-in-fold"]
    assert {f.line for f in hits} == {7, 8, 9}, findings


def test_host_ops_fine_outside_folds(tmp_path):
    findings = _lint_src(tmp_path, """
        import numpy as np
        def summarise(end):
            return float(end), np.asarray(end)
    """)
    assert findings == []


def test_only_filter_restricts_rules(tmp_path):
    findings = _lint_src(tmp_path, """
        import numpy as np
        x = np.random.rand(4)
        def pick(engine):
            return engine == "scan"
    """, only={"engine-dispatch"})
    assert _rules(findings) == {"engine-dispatch"}


def test_rule_catalog_complete():
    assert set(astlint.registered_rules()) == {
        "rng-global", "rng-in-fold", "engine-dispatch",
        "shim-internal", "host-in-fold"}


# ---------------------------------------------------------------------------
# Layer 2: jaxpr checks on injected fake engines
# ---------------------------------------------------------------------------


class _FakeEngine:
    def __init__(self, folds):
        self._folds = folds

    def canonical_folds(self, sim):
        folds = self._folds
        if isinstance(folds, Exception):
            raise folds
        return folds


def test_jaxpr_dtype_catches_f64_promoting_engine():
    import jax.numpy as jnp
    import numpy as np

    def bad(x):
        # np.float64 scalar: demoted silently under the default config,
        # promotes the whole fold to f64 once x64 is enabled.
        return x * np.float64(2.0)

    folds, findings = jaxprs.collect_engine_folds(
        engines={"fake": _FakeEngine(
            {"bad": (bad, (jnp.ones((3,), jnp.float32),))})},
        sim=object())
    assert [f.key for f in folds] == ["fake/bad"]
    hits = [f for f in findings if f.rule == "jaxpr-dtype"]
    assert hits and "enable_x64" in hits[0].message


def test_jaxpr_rng_catches_in_fold_randomness():
    import jax

    def bad(key):
        return jax.random.uniform(key, (3,))

    _, findings = jaxprs.collect_engine_folds(
        engines={"fake": _FakeEngine(
            {"rng": (bad, (jax.random.PRNGKey(0),))})},
        sim=object())
    assert "jaxpr-rng" in _rules(findings)


def test_jaxpr_hook_missing_is_an_error():
    _, findings = jaxprs.collect_engine_folds(
        engines={"fake": _FakeEngine(NotImplementedError("no hook"))},
        sim=object())
    hits = [f for f in findings if f.rule == "jaxpr-hook"]
    assert hits and hits[0].path == "engine:fake"


def test_jaxpr_host_optout_is_recorded_not_traced():
    folds, findings = jaxprs.collect_engine_folds(
        engines={"fake": _FakeEngine(None)}, sim=object())
    assert findings == []
    assert folds[0].host and folds[0].n_primitives == 0


# ---------------------------------------------------------------------------
# Baseline round-trip
# ---------------------------------------------------------------------------


def _fold(key, n, host=False):
    engine, _, label = key.partition("/")
    return jaxprs.EngineFold(engine=engine, label=label or "host",
                             n_primitives=n, primitive_counts={},
                             host=host)


def test_baseline_round_trip(tmp_path):
    import jax

    path = tmp_path / "baseline.json"
    folds = [_fold("scan/end_time", 100), _fold("oracle/host", 0, True)]
    doc = _baseline.save_baseline(folds, path)
    assert doc["jax"] == jax.__version__
    loaded = _baseline.load_baseline(path)
    assert loaded == json.loads(path.read_text())
    assert _baseline.check_budgets(folds, loaded) == []


def test_baseline_budget_regression_and_improvement(tmp_path):
    base = {"jax": __import__("jax").__version__,
            "budgets": {"scan/end_time": 100}, "host_engines": []}
    over = _baseline.check_budgets([_fold("scan/end_time", 120)], base)
    assert [f.severity for f in over] == ["error"]
    under = _baseline.check_budgets([_fold("scan/end_time", 80)], base)
    assert [f.severity for f in under] == ["info"]
    within = _baseline.check_budgets([_fold("scan/end_time", 108)], base)
    assert within == []


def test_baseline_missing_fold_and_stale_entry(tmp_path):
    base = {"jax": __import__("jax").__version__,
            "budgets": {"gone/end_time": 50}, "host_engines": []}
    findings = _baseline.check_budgets([_fold("new/end_time", 10)], base)
    by_rule = {(f.path, f.severity) for f in findings}
    assert ("new/end_time", "error") in by_rule     # unbudgeted fold
    assert ("gone/end_time", "info") in by_rule     # stale entry


def test_baseline_jax_mismatch_downgrades_to_info():
    base = {"jax": "0.0.0", "budgets": {"scan/end_time": 100},
            "host_engines": []}
    findings = _baseline.check_budgets([_fold("scan/end_time", 200)], base)
    assert findings and all(not f.is_error for f in findings)


def test_no_baseline_is_an_error():
    findings = _baseline.check_budgets([_fold("scan/end_time", 1)], None)
    assert [f.is_error for f in findings] == [True]


# ---------------------------------------------------------------------------
# Findings / report plumbing
# ---------------------------------------------------------------------------


def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError):
        Finding(rule="r", path="p", line=1, message="m", severity="warn")


def test_render_text_and_json_agree():
    fs = [Finding(rule="r", path="b.py", line=2, message="m"),
          Finding(rule="r", path="a.py", line=1, message="m",
                  severity="info")]
    text = render_text(fs, n_files=2, n_engines=0)
    assert text.splitlines()[0].startswith("a.py:1")      # sorted
    assert "1 error(s), 1 info note(s)" in text
    doc = json.loads(render_json(fs, n_files=2, n_engines=0))
    assert (doc["errors"], doc["infos"]) == (1, 1)
    assert len(doc["findings"]) == 2


# ---------------------------------------------------------------------------
# CLI: exit codes and repo cleanliness
# ---------------------------------------------------------------------------


def _fixture_tree(tmp_path, source):
    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "mod.py").write_text(textwrap.dedent(source))
    return tmp_path


def test_cli_fails_on_bad_tree_names_the_rule(tmp_path, capsys):
    from repro.analysis.cli import main

    root = _fixture_tree(tmp_path, """
        import numpy as np
        x = np.random.rand(4)
    """)
    code = main(["--check", "--no-jaxpr", "--root", str(root)])
    out = capsys.readouterr().out
    assert code == 1
    assert "[rng-global]" in out


def test_cli_passes_on_clean_tree(tmp_path, capsys):
    from repro.analysis.cli import main

    root = _fixture_tree(tmp_path, "x = 1\n")
    code = main(["--check", "--no-jaxpr", "--root", str(root)])
    assert code == 0
    assert "0 error(s)" in capsys.readouterr().out


def test_cli_json_output(tmp_path, capsys):
    from repro.analysis.cli import main

    root = _fixture_tree(tmp_path, "import random\nx = random.random()\n")
    code = main(["--check", "--json", "--no-jaxpr", "--root", str(root)])
    doc = json.loads(capsys.readouterr().out)
    assert code == 1 and doc["errors"] == 1
    assert doc["findings"][0]["rule"] == "rng-global"


def test_module_entry_point_runs():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--help"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0 and "--baseline" in out.stdout


def test_repo_ast_layer_is_clean():
    paths = [REPO / sub for sub in SCAN_ROOTS if (REPO / sub).exists()]
    findings, n_files = astlint.lint_paths(paths, root=REPO)
    assert n_files > 50
    assert [f.format() for f in findings] == []


# ---------------------------------------------------------------------------
# The real registry: full jaxpr-layer pass (the regression pin for the
# weak-f64 fixes in sim.py's squaring table and chunk-fold energy path)
# ---------------------------------------------------------------------------


def test_repo_jaxpr_layer_covers_all_engines_and_is_clean():
    from repro.core import api

    folds, findings = jaxprs.collect_engine_folds()
    assert [f.format() for f in findings] == []
    covered = {f.engine for f in folds}
    assert covered == set(api.registered_engines())
    traced = {f.key for f in folds if not f.host}
    assert {"scan/end_time", "scan/dispatch", "prefix/end_time",
            "squaring/end_time", "pallas/end_time",
            "streaming/chunk_fold"} <= traced
    # Budgets against the committed baseline must hold as-committed.
    budget = _baseline.check_budgets(
        folds, _baseline.load_baseline())
    assert [f.format() for f in budget if f.is_error] == []


def test_repo_padding_identity_bitwise():
    assert [f.format() for f in jaxprs.check_padding_identity()] == []
