"""Trace layer: three-way engine equivalence on heterogeneous traces +
regression pins for the homogeneous Table 3/4 reproduction.

Deliberately hypothesis-free (plain numpy RNG) so the core trace suite
runs even in minimal environments."""

import numpy as np
import pytest

from repro.core.interface import InterfaceKind
from repro.core.nand import CellType, chip as nand_chip
from repro.core.paper_tables import INTERFACE_ORDER, TABLE3, TABLE4
from repro.core import trace as tr
from repro.core.sim import (SSDConfig, channel_bandwidth_mb_s,
                            controller_arb_us, make_interface,
                            page_op_params, ssd_bandwidth_mb_s)
from repro.core.sim_ref import (bandwidth_ref_mb_s, simulate_trace_ref,
                                trace_bandwidth_ref_mb_s)
from repro.kernels.maxplus.ops import trace_end_time_maxplus

ANOMALIES = {("slc", "read", 2, "proposed")}


def _random_trace(rng, channels, ways, n_ops=160):
    kind = rng.integers(0, 3)
    if kind == 0:
        return tr.mixed_trace(n_ops, channels, ways,
                              read_fraction=float(rng.random()),
                              seed=int(rng.integers(1 << 30)))
    if kind == 1:
        return tr.hot_cold_trace(n_ops, channels, ways,
                                 read_fraction=float(rng.random()),
                                 seed=int(rng.integers(1 << 30)))
    return tr.steady_trace(n_ops // channels, channels, ways,
                           int(rng.integers(0, 2)))


@pytest.mark.parametrize("ways", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("policy", ["eager", "batched"])
def test_three_way_equivalence_random_traces(ways, policy):
    """scan engine == python oracle == (max,+) Pallas kernel on randomized
    heterogeneous traces, for every way count and both policies."""
    rng = np.random.default_rng(ways * 31 + (policy == "batched"))
    for channels in (1, 2, 4):
        cfg = SSDConfig(cell=CellType.MLC, channels=channels, ways=ways,
                        interface=InterfaceKind.PROPOSED)
        table = tr.op_class_table(cfg)
        trace = _random_trace(rng, channels, ways)
        ref_us = simulate_trace_ref(table, trace, policy)
        scan_us = tr.simulate(table, trace, policy)
        mp_us = float(trace_end_time_maxplus(table, trace, policy=policy))
        # gate: <= 1e-3 us per op, plus the float32 ulp floor of the
        # (max,+) kernel at the trace's end-time magnitude
        tol = 1e-3 * trace.n_ops + 1e-5 * ref_us
        assert abs(scan_us - ref_us) <= tol, (channels, ways, policy)
        assert abs(mp_us - ref_us) <= tol, (channels, ways, policy)


def test_trace_engine_reproduces_legacy_single_channel():
    """The trace engine at channels=1 is bit-compatible with the original
    homogeneous-stream engine and its oracle."""
    for kind in InterfaceKind:
        for cell in CellType:
            for mode in ("read", "write"):
                cfg = SSDConfig(interface=kind, cell=cell, channels=1, ways=4)
                op = page_op_params(make_interface(kind), nand_chip(cell),
                                    mode, 4)
                legacy = float(channel_bandwidth_mb_s(op, 4, n_pages=256))
                table = tr.op_class_table(cfg)
                trace = tr.steady_trace(256, 1, 4,
                                        tr.READ if mode == "read" else tr.WRITE)
                via_trace = tr.trace_bandwidth_mb_s(table, trace)
                assert via_trace == pytest.approx(legacy, rel=1e-6)
                # table stores float32 timings; oracle runs in python floats
                assert trace_bandwidth_ref_mb_s(table, trace) == pytest.approx(
                    bandwidth_ref_mb_s(op, 4, 256), rel=1e-5)


def test_homogeneous_regression_table3():
    """Pin the Table 3 reproduction (single channel) to the seed's
    tolerances — the trace refactor must not move the paper-faithful
    baseline."""
    errs = []
    for cell, by_mode in TABLE3.items():
        for mode, by_ways in by_mode.items():
            for ways, row in by_ways.items():
                for kind, paper in zip(INTERFACE_ORDER, row):
                    if (cell, mode, ways, kind) in ANOMALIES:
                        continue
                    cfg = SSDConfig(interface=InterfaceKind(kind),
                                    cell=CellType(cell), channels=1, ways=ways)
                    errs.append(abs(ssd_bandwidth_mb_s(cfg, mode) - paper)
                                / paper)
    assert np.mean(errs) < 0.04
    assert max(errs) < 0.16


def test_homogeneous_regression_table4_no_fudge():
    """The multi-channel cells of Table 4 must come out of the *joint*
    simulation (shared controller + firmware arbitration), with no
    channel-striping efficiency fudge left in the code."""
    import repro.core.sim as sim

    assert not hasattr(sim, "STRIPE_EFFICIENCY_EXP"), \
        "striping fudge must stay retired"
    errs = []
    for cell, by_mode in TABLE4.items():
        for mode, by_cw in by_mode.items():
            for (channels, ways), row in by_cw.items():
                for kind, paper in zip(INTERFACE_ORDER, row):
                    cfg = SSDConfig(interface=InterfaceKind(kind),
                                    cell=CellType(cell), channels=channels,
                                    ways=ways)
                    got = ssd_bandwidth_mb_s(cfg, mode)
                    if paper is None:      # 'max' = hit the SATA2 cap
                        assert got >= 299.0
                        continue
                    if (cell, mode, ways, kind) in ANOMALIES:
                        continue
                    errs.append(abs(got - paper) / paper)
    assert np.mean(errs) < 0.05, f"mean rel err {np.mean(errs):.3f}"


def test_multi_channel_contention_structure():
    """Structural sanity of the shared-controller model: striping helps,
    but sub-linearly, and a single channel pays no arbitration."""
    assert controller_arb_us(5.0, 1) == 0.0
    assert controller_arb_us(5.0, 4) > controller_arb_us(5.0, 2) > 0.0
    for mode in ("read", "write"):
        one = ssd_bandwidth_mb_s(SSDConfig(cell=CellType.MLC, channels=1,
                                           ways=8, sata_mb_s=1e9), mode)
        two = ssd_bandwidth_mb_s(SSDConfig(cell=CellType.MLC, channels=2,
                                           ways=8, sata_mb_s=1e9), mode)
        assert one < two < 2 * one, mode


def test_trace_builders_structure():
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    table = tr.op_class_table(cfg)

    mixed = tr.mixed_trace(4000, 2, 4, read_fraction=0.7, seed=1)
    assert abs(mixed.read_fraction() - 0.7) < 0.05
    # parity alternates per chip: every chip's op sequence is 0,1,0,1,...
    for c in range(2):
        for w in range(4):
            mask = (mixed.channel == c) & (mixed.way == w)
            par = mixed.parity[mask]
            assert np.array_equal(par, np.arange(par.size) % 2)

    ck = tr.checkpoint_trace(10 << 20, cfg)
    assert set(np.unique(ck.cls)) == {tr.WRITE}
    assert set(np.unique(ck.channel)) == {0, 1}

    dp = tr.datapipe_trace(10 << 20, cfg, hedge_fraction=0.25, seed=0)
    base = tr.datapipe_trace(10 << 20, cfg, hedge_fraction=0.0, seed=0)
    assert set(np.unique(dp.cls)) == {tr.READ}
    assert dp.n_ops > base.n_ops          # hedging duplicates traffic...
    # ...but delivers no extra payload (duplicates are masked out)
    assert dp.total_bytes(table) == base.total_bytes(table)

    kv = tr.kvoffload_trace(1 << 20, cfg, n_tokens=4,
                            append_bytes_per_token=4096)
    assert tr.READ in kv.cls and tr.WRITE in kv.cls
    # a giant per-token burst truncated to the window keeps its r/w mix
    kv_big = tr.kvoffload_trace(1 << 30, cfg, n_tokens=2,
                                append_bytes_per_token=64 << 20)
    assert kv_big.n_ops == 4096
    got_wfrac = float(np.mean(kv_big.cls == tr.WRITE))
    assert got_wfrac == pytest.approx(64 / (1024 + 64), rel=0.1)
    hot = tr.hot_cold_trace(2000, 2, 4, hot_share=0.25, seed=2)
    chips = hot.channel * 4 + hot.way
    counts = np.bincount(chips, minlength=8)
    assert counts.max() > 3 * np.median(counts)   # skew is real

    est_bytes = mixed.total_bytes(table)
    assert est_bytes == int(np.sum(table.data_bytes[mixed.cls]))

    # named registry (now the workload layer): routes kwargs through,
    # names the valid kinds on unknown names, rejects unknown kwargs;
    # the old trace.workload_trace survives as a DeprecationWarning shim
    from repro.core.workload import build_workload
    wt = build_workload("mixed", cfg, read_fraction=0.3, seed=9)
    assert abs(wt.read_fraction() - 0.3) < 0.07
    with pytest.deprecated_call():
        wt_shim = tr.workload_trace("mixed", cfg, read_fraction=0.3, seed=9)
    assert np.array_equal(wt_shim.cls, wt.cls)
    with pytest.raises(ValueError, match="steady_read"):
        build_workload("nonsense", cfg)
    with pytest.deprecated_call():
        with pytest.raises(ValueError, match="valid|kind"):
            tr.workload_trace("nonsense", cfg)
    with pytest.raises(TypeError):
        build_workload("steady_read", cfg, bogus_kwarg=1)
    with pytest.raises(AssertionError):
        tr.steady_trace(8, channels=99, ways=4)


def test_estimate_trace_and_planning():
    from repro.core.trace import checkpoint_trace
    from repro.storage.ssd_model import (estimate_trace,
                                         plan_geometry_for_trace)
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=8)
    trace = tr.mixed_trace(512, 2, 8, read_fraction=0.5, seed=0)
    est = estimate_trace(trace, cfg)
    assert est.read_bytes > 0 and est.write_bytes > 0
    assert est.seconds > 0 and est.bandwidth_mb_s > 0
    # extrapolation scales time, not bandwidth
    est10 = estimate_trace(trace, cfg, total_bytes=10 * (est.read_bytes
                                                         + est.write_bytes))
    assert est10.bandwidth_mb_s == pytest.approx(est.bandwidth_mb_s)
    assert est10.seconds == pytest.approx(10 * est.seconds, rel=1e-6)

    nbytes = 2 << 30
    plan = plan_geometry_for_trace(
        lambda c: checkpoint_trace(nbytes, c), budget_s=120.0,
        total_bytes=nbytes)
    assert plan is not None and plan.seconds <= 120.0
    assert plan_geometry_for_trace(
        lambda c: checkpoint_trace(nbytes, c), budget_s=1e-4,
        total_bytes=nbytes) is None
