"""Property tests for MoE routing and rotary embeddings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property suite needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.models.layers import mlp
from repro.models.moe import MoESpec, apply_moe, capacity_per_group, init_moe
from repro.models.rope import apply_mrope, apply_rope, text_mrope_positions

KEY = jax.random.PRNGKey(3)


# --- MoE ---------------------------------------------------------------------


def test_single_expert_moe_equals_dense():
    """E=1, k=1, cf high => MoE must equal the dense expert exactly."""
    spec = MoESpec(n_experts=1, top_k=1, d_ff=32, capacity_factor=2.0)
    p = init_moe(KEY, 16, spec, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 8, 16), jnp.float32)
    out = apply_moe(p, spec, x, compute_dtype=jnp.float32)
    dense = {"wi": p["wi"][0], "wg": p["wg"][0], "wo": p["wo"][0]}
    ref = mlp(dense, x, act="silu", compute_dtype=jnp.float32)
    assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_identical_experts_invariant():
    """If all experts share weights, routing choice must not matter."""
    spec = MoESpec(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0)
    p = init_moe(KEY, 16, spec, jnp.float32)
    for w in ("wi", "wg", "wo"):
        p[w] = jnp.broadcast_to(p[w][:1], p[w].shape)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 8, 16), jnp.float32)
    out = apply_moe(p, spec, x, compute_dtype=jnp.float32)
    dense = {"wi": p["wi"][0], "wg": p["wg"][0], "wo": p["wo"][0]}
    ref = mlp(dense, x, act="silu", compute_dtype=jnp.float32)
    assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 64), st.integers(1, 8), st.integers(2, 64),
       st.floats(0.5, 4.0))
def test_capacity_formula(tokens, k, experts, cf):
    k = min(k, experts)
    c = capacity_per_group(tokens, MoESpec(n_experts=experts, top_k=k,
                                           d_ff=8, capacity_factor=cf))
    assert c >= 1
    assert c >= tokens * k * cf / experts - 1


def test_capacity_drops_tokens():
    """With capacity_factor << 1 most tokens fall through to the residual
    (output far smaller than with generous capacity)."""
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 64, 16), jnp.float32)
    big = MoESpec(n_experts=4, top_k=1, d_ff=32, capacity_factor=4.0)
    tiny = MoESpec(n_experts=4, top_k=1, d_ff=32, capacity_factor=0.05)
    p = init_moe(KEY, 16, big, jnp.float32)
    out_big = apply_moe(p, big, x, compute_dtype=jnp.float32)
    out_tiny = apply_moe(p, tiny, x, compute_dtype=jnp.float32)
    assert float(jnp.sum(jnp.abs(out_tiny))) < 0.6 * float(jnp.sum(jnp.abs(out_big)))


def test_decode_grouping_runs():
    """S=1 decode path groups the whole batch (no E× blowup, no crash)."""
    spec = MoESpec(n_experts=8, top_k=2, d_ff=32, capacity_factor=1.25)
    p = init_moe(KEY, 16, spec, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (16, 1, 16), jnp.float32)
    out = apply_moe(p, spec, x, compute_dtype=jnp.float32)
    assert out.shape == x.shape and bool(jnp.all(jnp.isfinite(out)))


# --- RoPE / M-RoPE ------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 3), st.integers(2, 32), st.sampled_from([32, 64, 128]))
def test_rope_preserves_norm(b, s, d):
    k = jax.random.fold_in(KEY, b * s + d)
    x = jax.random.normal(k, (b, s, 2, 2, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    y = apply_rope(x, pos, theta=1e4)
    assert np.allclose(np.linalg.norm(np.asarray(x), axis=-1),
                       np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


def test_mrope_degenerates_to_rope_for_text():
    """t == h == w position ids must reduce M-RoPE to plain RoPE."""
    b, s, d = 2, 16, 128
    x = jax.random.normal(KEY, (b, s, 2, 3, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    rope = apply_rope(x, pos, theta=1e6)
    mrope = apply_mrope(x, text_mrope_positions(pos), (16, 24, 24), theta=1e6)
    assert np.allclose(np.asarray(rope), np.asarray(mrope), atol=1e-5)


def test_rope_relative_property():
    """Attention scores under RoPE depend only on relative distance."""
    d = 64
    q = jax.random.normal(KEY, (1, 1, 1, 1, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 9), (1, 1, 1, 1, d), jnp.float32)

    def score(pq, pk):
        qq = apply_rope(q, jnp.array([[pq]], jnp.int32))
        kk = apply_rope(k, jnp.array([[pk]], jnp.int32))
        return float(jnp.sum(qq * kk))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
