"""Fleet-scale simulation paths (DESIGN.md §2.7): the constant-memory
streaming engine (chunk-size invariance by construction), the chunked
trace generators, and the shard_map sweep paths (multi-device parts run
in a subprocess with a forced 8-device host platform)."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro.api as api
from repro.core import trace as tr
from repro.core.nand import CellType
from repro.core.sim import SSDConfig


def _sim(channels, ways):
    return api.Simulator(SSDConfig(cell=CellType.MLC, channels=channels,
                                   ways=ways))


def _trace(channels, ways, *, arrivals, seed, n_ops=144):
    t = tr.mixed_trace(n_ops, channels, ways, 0.6, seed=seed)
    if arrivals:
        rng = np.random.default_rng(seed + 1)
        t = dataclasses.replace(
            t, arrival_us=np.sort(rng.uniform(0.0, 40.0 * n_ops, n_ops))
            .astype(np.float32))
    return t


# --- chunk-size invariance ---------------------------------------------------


@pytest.mark.parametrize("ways", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("policy", ["eager", "batched"])
def test_streaming_chunk_size_invariance(ways, policy):
    """The streaming fold carries the *concrete* scan state between
    fixed-size chunks, so end time, energy sums and per-op completions
    (hence p50/p99 request latency) are bit-identical across chunk
    sizes AND to the scan engine — invariance by construction, not by
    tolerance.  Grid: channels 1-4 x ways 1-16 x both policies x
    arrivals on/off."""
    for channels in (1, 2, 4):
        for arrivals in (False, True):
            sim = _sim(channels, ways)
            t = _trace(channels, ways, arrivals=arrivals,
                       seed=channels * 31 + ways)
            batched = policy == "batched"
            scan = api.get_engine("scan")
            stream = api.get_engine("streaming")
            end_ref, comp_ref = scan.completions(sim, t, batched=batched)
            _, sums_ref = scan.energy_sums(sim, t, sim.kind,
                                           batched=batched,
                                           segment_len=None)
            for chunk in (64, 256, 1024):
                end, comp = stream.completions(sim, t, batched=batched,
                                               segment_len=chunk)
                assert end == end_ref, (channels, arrivals, chunk)
                assert np.array_equal(comp, comp_ref), \
                    (channels, arrivals, chunk)
                for q in (50, 99):
                    assert np.percentile(comp, q) == \
                        np.percentile(comp_ref, q)
                end_e, sums = stream.energy_sums(sim, t, sim.kind,
                                                 batched=batched,
                                                 segment_len=chunk)
                assert end_e == end_ref
                assert np.array_equal(sums, sums_ref), \
                    (channels, arrivals, chunk)


def test_run_stream_matches_run():
    """``Simulator.run_stream`` over an iterator of chunks reproduces
    the one-shot ``run`` result exactly — end time, bandwidth, busy
    accounting and the energy breakdown — without ever materialising
    the full trace."""
    sim = _sim(2, 4)
    t = _trace(2, 4, arrivals=False, seed=7, n_ops=500)
    whole = sim.run(t, objective="all")
    for chunk in (64, 128, 499, 512):
        res = sim.run_stream(tr.iter_trace_chunks(t, chunk),
                             objective="all")
        assert res.end_us == whole.end_us, chunk
        assert res.mb_s == pytest.approx(whole.mb_s)
        assert res.n_ops == whole.n_ops
        assert res.payload_bytes == whole.payload_bytes
        np.testing.assert_allclose(res.channel_busy_us,
                                   whole.channel_busy_us, rtol=1e-9)
        assert res.energy.total_j == whole.energy.total_j
        assert res.engine == "streaming"
    # policy threads through; empty iterators raise like empty traces
    assert sim.run_stream(tr.iter_trace_chunks(t, 64),
                          policy="batched").end_us \
        == sim.run(t, policy="batched").end_us
    with pytest.raises(ValueError, match="empty trace"):
        sim.run_stream(iter(()))
    with pytest.raises(ValueError, match="unknown objective"):
        sim.run_stream(tr.iter_trace_chunks(t, 64), objective="latency")


def test_streaming_rejects_mid_stream_geometry_change():
    sim = _sim(2, 4)
    chunks = [tr.mixed_trace(32, 2, 4, 0.5, seed=0),
              tr.mixed_trace(32, 4, 4, 0.5, seed=1)]
    with pytest.raises(ValueError, match="channel"):
        sim.run_stream(iter(chunks))


def test_iter_trace_chunks_slices_faithfully():
    t = _trace(2, 4, arrivals=True, seed=3, n_ops=100)
    with pytest.raises(ValueError, match="chunk_len"):
        next(tr.iter_trace_chunks(t, 0))
    parts = list(tr.iter_trace_chunks(t, 33))
    assert [p.n_ops for p in parts] == [33, 33, 33, 1]
    for field in ("cls", "channel", "way", "parity", "arrival_us"):
        cat = np.concatenate([np.asarray(getattr(p, field))
                              for p in parts])
        np.testing.assert_array_equal(cat, np.asarray(getattr(t, field)),
                                      err_msg=field)


def test_mixed_trace_chunks_generator_matches_whole_trace():
    """The generator twin of ``mixed_trace`` draws the same rng stream
    chunk-by-chunk, so concatenating its chunks reproduces the one-shot
    trace bit-for-bit at any chunk length — million-op streaming inputs
    never need the whole trace in memory."""
    whole = tr.mixed_trace(1000, 2, 4, 0.3, seed=9)
    for chunk_len in (100, 256, 999, 2048):
        parts = list(tr.mixed_trace_chunks(1000, 2, 4, 0.3,
                                           chunk_len=chunk_len, seed=9))
        assert sum(p.n_ops for p in parts) == 1000
        for field in ("cls", "channel", "way", "parity"):
            cat = np.concatenate([np.asarray(getattr(p, field))
                                  for p in parts])
            np.testing.assert_array_equal(
                cat, np.asarray(getattr(whole, field)),
                err_msg=f"{field}@{chunk_len}")


# --- shard_map sweeps (forced 8-device host) --------------------------------


def test_shard_map_matches_vmap_subprocess_8dev():
    """Every sharded entry point equals its single-device vmap path on a
    forced 8-device host: sweep_tables (scan + prefix), the homogeneous
    steady sweep (scan + squaring), and the packed run_many batch —
    including batch sizes that do not divide the device count (the
    leading axis pads to a device multiple and slices back)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax
        assert len(jax.devices()) == 8
        import repro.api as api
        from repro.core import trace as tr
        from repro.core.nand import CellType
        from repro.core.sim import SSDConfig

        sim = api.Simulator(SSDConfig(cell=CellType.MLC, channels=2, ways=4))
        t = tr.mixed_trace(120, 2, 4, 0.5, seed=1)
        for b in (5, 8, 11):                 # non-multiples pad + slice
            tabs = [sim.table] * b
            for eng in ("scan", "prefix"):
                a = np.asarray(api.sweep_tables(tabs, t, engine=eng,
                                                shard=True))
                v = np.asarray(api.sweep_tables(tabs, t, engine=eng,
                                                shard=False))
                assert a.shape == (b,) and np.array_equal(a, v), (eng, b)
        n = 11
        args = (np.full(n, 0.2), np.full(n, 0.1), np.linspace(20, 40, n),
                np.full(n, 200.0), np.full(n, 600.0), np.full(n, 1.0),
                np.full(n, 4096.0), np.full(n, 4, np.int32))
        for eng in ("scan", "squaring"):
            a = np.asarray(api.sweep_steady_bandwidth_mb_s(
                *args, n_pages=64, engine=eng, shard=True))
            v = np.asarray(api.sweep_steady_bandwidth_mb_s(
                *args, n_pages=64, engine=eng, shard=False))
            assert np.array_equal(a, v), eng
        traces = [tr.mixed_trace(m, 2, 4, 0.5, seed=s)
                  for s, m in enumerate((37, 64, 100, 128, 200, 55, 90,
                                         10, 73, 44))]
        a = [r.end_us for r in sim.run_many(traces, shard=True)]
        v = [r.end_us for r in sim.run_many(traces, shard=False)]
        assert a == v, (a, v)
        # streaming smoke on the multi-device host (engine is per-chunk
        # jit, unsharded — must be unaffected by the device count)
        res = sim.run_stream(tr.mixed_trace_chunks(2048, 2, 4, 0.5,
                                                   chunk_len=256, seed=2))
        one = sim.run(tr.mixed_trace(2048, 2, 4, 0.5, seed=2))
        assert res.end_us == one.end_us
        print("SHARD_SWEEP_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARD_SWEEP_OK" in r.stdout, r.stdout + r.stderr


# --- faulty traces through the streaming paths (DESIGN.md §2.8) --------------


def test_streaming_chunk_invariance_extends_to_faulty_traces():
    """PR 6's invariance-by-construction gate on fault-extended traces:
    the streaming fold carries the surcharge alongside arrivals, so end
    time and per-op completions stay bit-identical across chunk sizes
    and to the scan engine."""
    from repro.core import sched
    for channels, ways in ((1, 4), (2, 4), (4, 8)):
        sim = _sim(channels, ways)
        spec = api.FaultSpec(wear=0.95, jitter_us=2.0,
                             seed=channels * 7 + ways)
        t, _, _ = sched.apply_faults(
            _trace(channels, ways, arrivals=True, seed=channels + ways),
            spec, sim.table)
        assert np.any(np.asarray(t.extra_us) > 0.0)
        scan = api.get_engine("scan")
        stream = api.get_engine("streaming")
        end_ref, comp_ref = scan.completions(sim, t, batched=False)
        for chunk in (32, 128, 1024):
            end, comp = stream.completions(sim, t, batched=False,
                                           segment_len=chunk)
            assert end == end_ref, (channels, ways, chunk)
            assert np.array_equal(comp, comp_ref), (channels, ways, chunk)


def test_run_stream_applies_faults_identically_to_one_shot():
    """Chunked fault sampling consumes the same PCG64 stream as the
    one-shot rewrite, so run_stream over fault-rewriting chunk iterators
    equals run(faults=...) exactly — end time, bandwidth (remaps strip
    payload credit) and energy — at any chunk length."""
    sim = _sim(2, 4)
    spec = api.FaultSpec(wear=1.0, prog_fail_prob=0.05,
                         erase_fail_prob=0.1, jitter_us=1.0, seed=13)
    t = _trace(2, 4, arrivals=False, seed=21, n_ops=600)
    whole = sim.run(t, faults=spec, objective="all")
    assert whole.n_ops > t.n_ops          # remaps actually inserted
    for chunk in (64, 256, 599):
        res = sim.run_stream(
            tr.iter_trace_chunks(t, chunk, faults=spec, table=sim.table),
            objective="all")
        assert res.end_us == whole.end_us, chunk
        assert res.n_ops == whole.n_ops
        assert res.payload_bytes == whole.payload_bytes
        assert res.energy.total_j == whole.energy.total_j
    # the generator twin streams the same faulty op stream
    gen = sim.run_stream(tr.mixed_trace_chunks(
        2048, 2, 4, 0.5, chunk_len=256, seed=2, faults=spec,
        table=sim.table))
    one = sim.run(tr.mixed_trace(2048, 2, 4, 0.5, seed=2), faults=spec)
    assert gen.end_us == one.end_us
    assert gen.payload_bytes == one.payload_bytes
