"""Request-level workloads + scheduler/dispatch layer (DESIGN.md §2.6):
arrival-aware cross-engine agreement, static-lowering regression pins,
dynamic dispatch via the registry, per-request latency percentiles, and
the OpTrace validation hardening.

Deliberately hypothesis-free (plain numpy RNG / fixed seed grids) so the
suite runs in minimal environments, like tests/test_trace_engines.py."""

import dataclasses

import numpy as np
import pytest

import repro.api as api
from repro.core import sched, trace as tr, workload as wl
from repro.core.nand import CellType
from repro.core.sim import SSDConfig, dispatch_trace
from repro.core.sim_ref import (simulate_trace_completions_ref,
                                simulate_trace_ref)


def _tol(ref_us, n_ops):
    # <= 1e-3 us/op plus the float32 ulp floor at the end-time magnitude
    return 1e-3 * n_ops + 1e-5 * ref_us


def _arrival_trace(channels, ways, seed, n_ops=144):
    """A mixed trace with sorted random arrivals attached — the raw
    arrival-aware input every engine must agree on."""
    rng = np.random.default_rng(seed)
    t = tr.mixed_trace(n_ops, channels, ways,
                       read_fraction=float(rng.random()), seed=seed)
    arr = np.sort(rng.uniform(0.0, 120.0 * n_ops, n_ops)).astype(np.float32)
    return dataclasses.replace(t, arrival_us=arr)


# --- cross-engine agreement on arrival-aware traces -------------------------


@pytest.mark.parametrize("ways", [1, 2, 4, 8, 16])
@pytest.mark.parametrize("policy", ["eager", "batched"])
def test_arrival_aware_engines_agree(ways, policy):
    """scan / prefix / pallas / oracle agree < 1e-3 on arrival-aware
    traces for channels 1-4 x ways 1-16 x both issue policies — the
    arrival threading touches four independent implementations of the
    recurrence, so agreement is the whole correctness story."""
    for channels in (1, 2, 4):
        cfg = SSDConfig(cell=CellType.MLC, channels=channels, ways=ways)
        sim = api.Simulator.for_config(cfg)
        trace = _arrival_trace(channels, ways, seed=ways * 31 + channels)
        ref = simulate_trace_ref(sim.table, trace, policy)
        tol = _tol(ref, trace.n_ops)
        for engine in ("scan", "prefix", "pallas", "oracle"):
            got = sim.run(trace, policy=policy, engine=engine).end_us
            assert abs(got - ref) <= tol, (engine, channels, ways, policy)
        # the arrival gate is real: zeroing arrivals finishes no later
        bare = simulate_trace_ref(
            sim.table, dataclasses.replace(trace, arrival_us=None), policy)
        assert bare <= ref + tol


def test_arrival_trace_through_batched_and_packed_paths():
    """The masked bucket fold (run / run_many) and the batched-tables
    sweeps carry arrivals identically to the per-trace scan."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    sim = api.Simulator.for_config(cfg)
    traces = [_arrival_trace(2, 4, seed=s, n_ops=n)
              for s, n in ((1, 60), (2, 100), (3, 100), (4, 33))]
    many = sim.run_many(traces)
    for t, r in zip(traces, many):
        ref = simulate_trace_ref(sim.table, t, "eager")
        assert abs(r.end_us - ref) <= _tol(ref, t.n_ops)
        assert r.end_us == sim.run(t).end_us
    # one arrival trace under stacked tables (prefix + scan + pallas)
    t0 = traces[1]
    tables = [sim.table, api.Simulator.for_config(
        SSDConfig(cell=CellType.SLC, channels=2, ways=4)).table]
    ref = [simulate_trace_ref(tab, t0, "eager") for tab in tables]
    for engine in ("scan", "prefix", "pallas"):
        got = api.sweep_tables(tables, t0, engine=engine)
        np.testing.assert_allclose(got, ref, rtol=1e-3, err_msg=engine)


def test_squaring_rejects_arrivals_naming_alternatives():
    cfg = SSDConfig(cell=CellType.MLC, channels=1, ways=4)
    sim = api.Simulator.for_config(cfg)
    steady = tr.steady_trace(32, 1, 4, tr.READ)
    witharr = dataclasses.replace(
        steady, arrival_us=np.linspace(0, 1e4, 32).astype(np.float32))
    with pytest.raises(api.CapabilityError, match="oracle, pallas"):
        sim.run(witharr, engine="squaring")
    # zero arrivals stay inside squaring's periodic domain
    zeroed = dataclasses.replace(steady,
                                 arrival_us=np.zeros(32, np.float32))
    assert sim.run(zeroed, engine="squaring").end_us == pytest.approx(
        sim.run(steady, engine="scan").end_us, rel=1e-3)


# --- workload builders -------------------------------------------------------


def test_workload_builders_structure():
    p = wl.poisson_stream(200, 50.0, read_fraction=0.5, seed=1)
    assert p.n_requests == 200 and p.arrival_us[0] == 0.0
    assert np.all(np.diff(p.arrival_us) >= 0)
    assert 0.3 < np.mean(p.op_cls == tr.READ) < 0.7

    b = wl.bursty_stream(64, burst_len=16, gap_us=1000.0, intra_us=2.0)
    gaps = np.diff(b.arrival_us.astype(np.float64))
    assert np.sum(gaps > 100.0) == 3          # 4 bursts -> 3 idle gaps

    c = wl.closed_loop_stream(40, queue_depth=4, service_us=100.0)
    assert np.all(c.arrival_us[:4] == 0.0)    # QD admits the first N at t0
    assert np.all(np.diff(c.arrival_us) >= 0)
    assert c.arrival_us[-1] > 0

    m = wl.multi_tenant([p, b, c])
    assert m.n_requests == 304
    assert np.all(np.diff(m.arrival_us) >= 0)
    assert set(np.unique(m.stream)) == {0, 1, 2}
    assert "3 stream(s)" in m.describe()
    with pytest.raises(ValueError, match="at least one"):
        wl.multi_tenant([])

    cls, arr, req, payload = wl.request_ops(
        wl.poisson_stream(10, 5.0, pages_per_request=3))
    assert len(cls) == 30 and np.all(payload)
    assert np.array_equal(req, np.repeat(np.arange(10), 3))

    with pytest.raises(ValueError, match="non-decreasing"):
        wl.RequestStream(arrival_us=np.array([5.0, 1.0], np.float32),
                         op_cls=np.zeros(2, np.int32),
                         n_pages=np.ones(2, np.int32),
                         stream=np.zeros(2, np.int32))
    with pytest.raises(ValueError, match="n_pages"):
        wl.RequestStream(arrival_us=np.zeros(2, np.float32),
                         op_cls=np.zeros(2, np.int32),
                         n_pages=np.zeros(2, np.int32),
                         stream=np.zeros(2, np.int32))


# --- static lowering: regression pins + the second static policy ------------


def test_static_stripe_lowering_pins_old_builders_per_engine():
    """Acceptance pin: the stripe lowering of a zero-arrival
    RequestStream is numerically identical to the retired builders'
    traces on every engine."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    sim = api.Simulator.for_config(cfg)
    ck = tr.checkpoint_trace(10 << 20, cfg)     # builder (itself lowered)
    low = sched.lower_static(wl.checkpoint_requests(10 << 20, cfg), 2, 4)
    assert low.trace.arrival_us is None         # zero arrivals normalise
    for name in ("channel", "way", "parity", "cls"):
        np.testing.assert_array_equal(getattr(low.trace, name),
                                      getattr(ck, name))
    for engine in ("scan", "prefix", "pallas", "oracle"):
        assert sim.run(low.trace, engine=engine).end_us == \
            sim.run(ck, engine=engine).end_us, engine
    # ... and through the workload path of the Simulator itself
    res = sim.run(wl.checkpoint_requests(10 << 20, cfg),
                  sched_policy="stripe")
    assert res.end_us == sim.run(ck).end_us
    assert res.sched_policy == "stripe" and res.request_lat_us is not None


def test_round_robin_static_policy_is_way_first():
    s = wl.poisson_stream(24, 10.0, seed=3)
    low = sched.lower_static(s, channels=2, ways=4, policy="round_robin")
    t = np.arange(24)
    np.testing.assert_array_equal(low.trace.way, t % 4)
    np.testing.assert_array_equal(low.trace.channel, (t // 4) % 2)
    st = sched.lower_static(s, channels=2, ways=4, policy="stripe")
    np.testing.assert_array_equal(st.trace.channel, t % 2)
    with pytest.raises(ValueError, match="unknown sched policy"):
        sched.lower_static(s, 2, 4, policy="striipe")
    with pytest.raises(ValueError, match="dynamic"):
        sched.lower_static(s, 2, 4, policy="least_loaded")


# --- dynamic dispatch --------------------------------------------------------


def test_dynamic_policies_produce_latency_percentiles():
    """Acceptance: both dynamic policies answer through Simulator.run
    with p50/p99 request latencies; the dispatch capability is enforced
    by the registry for engines that cannot dispatch."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    sim = api.Simulator.for_config(cfg)
    load = api.poisson_stream(200, mean_interarrival_us=40.0,
                              read_fraction=0.7, seed=2)
    for rule in ("least_loaded", "earliest_ready"):
        res = sim.run(load, sched_policy=rule, objective="all")
        assert res.sched_policy == rule and res.engine == "scan"
        assert res.request_lat_us is not None
        assert len(res.request_lat_us) == load.n_requests
        assert 0 < res.p50_us <= res.p99_us
        assert res.energy is not None and res.energy.controller_j > 0
    for engine in ("prefix", "pallas", "oracle", "squaring"):
        with pytest.raises(api.CapabilityError, match="engines that do"):
            sim.run(load, sched_policy="least_loaded", engine=engine)
    with pytest.raises(ValueError, match="eager"):
        sim.run(load, sched_policy="least_loaded", policy="batched")
    with pytest.raises(ValueError, match="exactly one"):
        api.SimRequest(trace=tr.mixed_trace(8, 2, 4, 0.5), workload=load)
    with pytest.raises(ValueError, match="sched_policy"):
        api.SimRequest(trace=tr.mixed_trace(8, 2, 4, 0.5),
                       sched_policy="stripe")


def test_dispatch_placement_replays_on_every_engine():
    """The dispatch fold returns a full placement; replaying it as a
    static OpTrace through any engine (and the oracle) reproduces the
    dispatched end time — dynamic dispatch is the same recurrence plus
    an argmin, not a different simulator."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    sim = api.Simulator.for_config(cfg)
    load = api.multi_tenant([
        api.bursty_stream(60, burst_len=12, gap_us=800.0,
                          read_fraction=0.2, seed=5, stream=0),
        api.poisson_stream(60, mean_interarrival_us=60.0, seed=6,
                           stream=1)])
    cls, arr, req, _ = wl.request_ops(load)
    end, comp, chan, way, par = (
        api.get_engine("scan").dispatch_run(
            sim, cls, arr, n_channels=2, n_ways=4, rule="least_loaded"))
    replay = tr.OpTrace(cls=cls, channel=chan, way=way, parity=par,
                        channels=2, ways=4,
                        arrival_us=np.asarray(arr, np.float32))
    ref = simulate_trace_ref(sim.table, replay, "eager")
    assert abs(end - ref) <= _tol(ref, replay.n_ops)
    for engine in ("prefix", "pallas"):
        got = sim.run(replay, engine=engine).end_us
        assert abs(got - ref) <= _tol(ref, replay.n_ops), engine
    # completions agree with the oracle's per-op completions
    _, comp_ref = simulate_trace_completions_ref(sim.table, replay, "eager")
    np.testing.assert_allclose(comp, comp_ref,
                               atol=_tol(ref, replay.n_ops))
    # raw fold validates its rule literal
    with pytest.raises(ValueError, match="unknown dispatch rule"):
        dispatch_trace(*(np.zeros(1, np.float32),) * 7,
                       np.zeros(1, np.int32), np.zeros(1, np.float32),
                       n_channels=1, n_ways=1, rule="bogus")


def test_dynamic_least_loaded_beats_static_stripe_on_skewed_load():
    """Property (fixed deterministic grid): on hot/cold-skewed
    multi-tenant workloads — a bursty write-heavy tenant over a trickle
    of reads — dynamic least-loaded dispatch never ends later than the
    static stripe lowering, and wins clearly on average."""
    ratios = []
    for seed in range(6):
        for channels, ways in ((2, 4), (2, 8), (4, 4), (4, 8)):
            cfg = SSDConfig(cell=CellType.MLC, channels=channels, ways=ways)
            sim = api.Simulator.for_config(cfg)
            hot = api.bursty_stream(100, burst_len=20, gap_us=1500.0,
                                    read_fraction=0.1, seed=seed, stream=0)
            cold = api.poisson_stream(100, mean_interarrival_us=80.0,
                                      read_fraction=0.9, seed=seed + 100,
                                      stream=1)
            load = api.multi_tenant([hot, cold])
            st = sim.run(load, sched_policy="stripe")
            dyn = sim.run(load, sched_policy="least_loaded")
            ratios.append(dyn.end_us / st.end_us)
            # the tail is where dispatch pays: p99 dominance holds on
            # the whole grid ...
            assert dyn.p99_us <= st.p99_us * (1 + 1e-6), \
                (seed, channels, ways)
            # ... makespan dominance on every contended geometry (at 32
            # chips / 200 requests the device is underloaded and the
            # makespan is an arrival-bound near-tie either way)
            if (channels, ways) != (4, 8):
                assert dyn.end_us <= st.end_us * (1 + 1e-6), \
                    (seed, channels, ways)
    assert np.mean(ratios) < 0.9


def test_latency_percentiles_cover_payload_requests_only():
    """Hedged duplicates are transport, not requests: they must not
    appear in the latency percentiles (a duplicate queueing behind its
    primary would inflate the tail of the very mechanism that exists to
    cut it).  Also pins the bucketed completions closure: nearby
    workload lengths share one compiled fold."""
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    sim = api.Simulator(cfg)                      # fresh session
    req = wl.datapipe_requests(4 << 20, cfg, hedge_fraction=0.5, seed=0)
    assert not req.payload_mask().all()
    res = sim.run(req, sched_policy="stripe")
    assert len(res.request_lat_us) == int(req.payload_mask().sum())
    # same power-of-two bucket -> the completions closure is a cache hit
    misses = sim.cache_info().misses
    sim.run(wl.poisson_stream(req.n_requests - 7, 20.0), sched_policy="stripe")
    assert sim.cache_info().misses == misses


# --- OpTrace validation hardening (satellite) --------------------------------


def test_optrace_validates_geometry_on_construction():
    """Out-of-range channel/way used to scatter with mode='drop' in the
    prefix path (the op silently vanished); now construction raises."""
    ok = dict(cls=np.zeros(4, np.int32), channel=np.zeros(4, np.int32),
              way=np.zeros(4, np.int32), parity=np.zeros(4, np.int32),
              channels=2, ways=4)
    tr.OpTrace(**ok)                               # in range: fine
    with pytest.raises(ValueError, match="channel out of range"):
        tr.OpTrace(**{**ok, "channel": np.array([0, 1, 2, 0], np.int32)})
    with pytest.raises(ValueError, match="way out of range"):
        tr.OpTrace(**{**ok, "way": np.array([0, 4, 0, 0], np.int32)})
    with pytest.raises(ValueError, match="non-negative"):
        tr.OpTrace(**{**ok, "cls": np.array([0, -1, 0, 0], np.int32)})
    with pytest.raises(ValueError, match="length"):
        tr.OpTrace(**{**ok, "way": np.zeros(3, np.int32)})
    with pytest.raises(ValueError, match="arrival_us"):
        tr.OpTrace(**ok, arrival_us=np.array([0, -1, 0, 0], np.float32))
    # the op-class bound needs the table; the session checks it
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=4)
    sim = api.Simulator.for_config(cfg)
    bad_cls = tr.OpTrace(**{**ok, "cls": np.array([0, 7, 0, 0], np.int32)})
    with pytest.raises(ValueError, match="n_classes"):
        sim.run(bad_cls)
    with pytest.raises(ValueError, match="n_classes"):
        sim.run_many([bad_cls])
    # the workload path checks before the dispatch fold runs (a clamped
    # simulation followed by a numpy IndexError is not a report)
    bad_req = dataclasses.replace(wl.poisson_stream(8, 10.0),
                                  op_cls=np.full(8, 7, np.int32))
    for policy in ("stripe", "least_loaded"):
        with pytest.raises(ValueError, match="n_classes"):
            sim.run(bad_req, sched_policy=policy)
    # degenerate builder sizes stay well-formed
    assert wl.poisson_stream(0, 10.0).n_requests == 0


# --- dynamic dispatch under adversarial input (satellite, DESIGN.md §2.8) ----


def test_dispatch_survives_single_chip_and_burst_degeneracies():
    """Adversarial inputs the dispatch fold must not fall over on: a
    1x1 geometry (every op forced to the only chip), an all-at-once
    burst (every arrival 0), and a single-op stream."""
    sim1 = api.Simulator.for_config(
        SSDConfig(cell=CellType.MLC, channels=1, ways=1))
    load = api.poisson_stream(50, 20.0, seed=0)
    for rule in ("least_loaded", "earliest_ready"):
        res = sim1.run(load, sched_policy=rule)
        assert res.end_us > 0 and len(res.request_lat_us) == 50
    # an all-at-once write burst (writes: the chip busy time dominates,
    # so the greedy metric must spread over every chip, not convoy one;
    # a read burst legitimately reuses one way per channel — reads
    # release the chip the moment the bus drains)
    burst = dataclasses.replace(
        api.poisson_stream(48, 20.0, read_fraction=0.0, seed=0),
        arrival_us=np.zeros(48, np.float32))
    sim = api.Simulator.for_config(
        SSDConfig(cell=CellType.MLC, channels=2, ways=4))
    cls, arr, _, _ = wl.request_ops(burst)
    for rule in ("least_loaded", "earliest_ready"):
        _, _, chan, way, _ = api.get_engine("scan").dispatch_run(
            sim, cls, arr, n_channels=2, n_ways=4, rule=rule)
        counts = np.bincount(np.asarray(chan) * 4 + np.asarray(way),
                             minlength=8)
        assert counts.min() >= 1, rule
        assert counts.max() - counts.min() <= 2, rule
    one = api.poisson_stream(1, 10.0, seed=1)
    res = sim.run(one, sched_policy="least_loaded")
    assert len(res.request_lat_us) == 1 and res.request_lat_us[0] > 0


def test_zero_length_streams_raise_everywhere():
    sim = api.Simulator.for_config(
        SSDConfig(cell=CellType.MLC, channels=2, ways=4))
    empty = wl.poisson_stream(0, 10.0)
    assert empty.n_requests == 0
    for policy in ("stripe", "least_loaded"):
        with pytest.raises(ValueError, match="empty workload"):
            sim.run(empty, sched_policy=policy)
    # static lowering of an empty stream is well-formed but unservable
    low = sched.lower_static(empty, 2, 4)
    assert low.trace.n_ops == 0
    with pytest.raises(ValueError, match="empty trace"):
        sim.run(low.trace)
    # hedging an empty stream is a no-op, not a crash
    assert wl.with_hedges(empty, 0.5).n_requests == 0
