"""Per-arch smoke tests: reduced configs, forward + one train step on CPU,
prefill/decode cache consistency (the assignment's required smoke grid)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch, smoke_batch
from repro.launch.steps import make_train_step
from repro.models.transformer import (decode_step, forward, init_params,
                                      prefill)
from repro.train.optimizer import OptConfig, adamw_init


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            arch = get_arch(name)
            params = init_params(arch.smoke, jax.random.PRNGKey(0))
            cache[name] = (arch, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_and_finite(name, arch_state):
    arch, params = arch_state(name)
    cfg = arch.smoke
    batch = smoke_batch(cfg)
    logits, aux = forward(cfg, params, batch["inputs"],
                          position_ids=batch.get("position_ids"), mode="eval")
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # padded vocab columns must carry no probability mass
    if cfg.padded_vocab > cfg.vocab_size:
        assert float(jnp.max(logits[..., cfg.vocab_size:])) < -1e20


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step_decreases_loss(name, arch_state):
    arch, params = arch_state(name)
    cfg = arch.smoke
    ocfg = OptConfig(weight_decay=0.0, clip_norm=1.0)
    state = {"params": params, "opt": adamw_init(ocfg, params)}
    step = jax.jit(make_train_step(cfg, ocfg))
    batch = smoke_batch(cfg, batch=2, seq=16)
    losses = []
    for _ in range(4):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert jnp.isfinite(metrics["loss"])
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_matches_forward(name, arch_state):
    arch, params = arch_state(name)
    cfg = arch.smoke
    batch = smoke_batch(cfg)
    logits_p, _ = prefill(cfg, params, batch["inputs"], max_seq=24,
                          position_ids=batch.get("position_ids"))
    logits_f, _ = forward(cfg, params, batch["inputs"],
                          position_ids=batch.get("position_ids"), mode="eval")
    assert jnp.allclose(logits_p[:, 0], logits_f[:, -1], atol=1e-4)


@pytest.mark.parametrize("name", ARCH_IDS)
def test_decode_consistent_with_forward(name, arch_state):
    """Teacher-forced decode equals full forward at every step — exercises
    every cache type (linear KV, ring-buffer KV, RG-LRU/mLSTM/sLSTM state).
    Run in fp32 compute: this asserts the *math* of the two paths; bf16
    numerics are exercised by the other smoke tests."""
    import dataclasses
    arch, _ = arch_state(name)
    cfg = dataclasses.replace(arch.smoke, compute_dtype="f32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s_prompt, n_extra = 2, 12, 4
    batch = smoke_batch(cfg, batch=b, seq=s_prompt + n_extra)
    full = batch["inputs"]
    prompt = full[:, :s_prompt] if cfg.input_mode == "tokens" else full[:, :s_prompt, :]
    _, cache = prefill(cfg, params, prompt, max_seq=s_prompt + n_extra)
    ref_logits, _ = forward(cfg, params, full, mode="eval")
    for i in range(n_extra):
        pos = s_prompt + i
        tok = (full[:, pos:pos + 1] if cfg.input_mode == "tokens"
               else full[:, pos:pos + 1, :])
        step_logits, cache = decode_step(cfg, params, cache, tok,
                                         jnp.asarray(pos, jnp.int32))
        err = float(jnp.max(jnp.abs(step_logits[:, 0] - ref_logits[:, pos])))
        assert err < 2e-3, (name, pos, err)


def test_tail_layers_used():
    """recurrentgemma's 38 = 12×(R,R,L) + (R,R) tail must route through tail params."""
    arch, params = get_arch("recurrentgemma-9b"), None
    cfg = arch.smoke
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert "tail" in params and len(params["tail"]) == 2
    assert cfg.num_units * len(cfg.pattern) + len(cfg.tail) == cfg.n_layers


def test_param_counts_full_configs():
    """Full-scale param counts match the published sizes (±10%)."""
    import numpy as np
    from repro.launch.dryrun import active_param_count
    expected = {
        "qwen2-0.5b": (0.49e9, 0.15),
        "minicpm-2b": (2.7e9, 0.15),
        "granite-3-2b": (2.6e9, 0.20),
        "starcoder2-3b": (3.0e9, 0.15),
        "llama4-maverick-400b-a17b": (400e9, 0.15),
        "granite-moe-3b-a800m": (3.4e9, 0.25),
        "recurrentgemma-9b": (9.5e9, 0.20),
        "qwen2-vl-2b": (1.5e9, 0.35),
        "xlstm-350m": (0.35e9, 0.30),
        "musicgen-medium": (1.5e9, 0.35),
    }
    for name, (target, tol) in expected.items():
        total, active = active_param_count(get_arch(name).config)
        assert abs(total - target) / target < tol, (name, total, target)
    _, active = active_param_count(get_arch("llama4-maverick-400b-a17b").config)
    assert 12e9 < active < 25e9, active  # ≈17B active
