"""Gradient compression, error feedback, elastic resharding (multi-device
parts run in a subprocess with a forced 8-device host platform)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import ErrorFeedback
from repro.launch.hlo_analysis import analyze_module


def test_error_feedback_is_unbiased_over_time():
    """EF residual re-injection: sum of compressed grads ≈ sum of true grads."""
    rng = np.random.default_rng(0)
    grads = [jnp.asarray(rng.normal(size=(64,)) * 1e-3, jnp.float32)
             for _ in range(50)]
    res = ErrorFeedback.init(grads[0])
    total_c = jnp.zeros((64,))
    for g in grads:
        c, res = ErrorFeedback.compress(g, res)
        total_c = total_c + c
    total_g = sum(grads)
    # residual bounded by one quantisation step => totals converge
    err = float(jnp.max(jnp.abs(total_c + res - total_g)))
    assert err < 1e-5


def test_compressed_psum_subprocess_8dev():
    """int8-wire psum == exact psum (within quant tol) on 8 devices."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.compression import make_dp_grad_sync
        mesh = jax.make_mesh((8,), ("data",))
        g = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 64.0}
        sync_c = make_dp_grad_sync(mesh, compress=True)
        sync_u = make_dp_grad_sync(mesh, compress=False)
        out_c = jax.jit(sync_c)(g)
        out_u = jax.jit(sync_u)(g)
        err = float(jnp.max(jnp.abs(out_c["w"] - out_u["w"])))
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        assert err <= scale + 1e-6, (err, scale)
        print("COMPRESSED_PSUM_OK", err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COMPRESSED_PSUM_OK" in r.stdout, r.stdout + r.stderr


def test_elastic_reshard_subprocess():
    """Checkpoint on a (4,2) mesh, restore onto (2,2) and (8,1) — elastic."""
    code = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.storage.checkpoint import CheckpointEngine, place_on_mesh

        state = {"w": jnp.arange(512, dtype=jnp.float32).reshape(16, 32)}
        d = tempfile.mkdtemp()
        m1 = jax.make_mesh((4, 2), ("data", "model"))
        sharded = jax.device_put(state["w"], NamedSharding(m1, P("data", "model")))
        eng = CheckpointEngine(d, channels=2)
        eng.save(1, {"w": sharded}, blocking=True)

        for shape in ((2, 2), (8, 1)):   # elastic: fewer / rearranged devices
            m2 = jax.make_mesh(shape, ("data", "model"))
            step, host, _ = eng.restore(template={"w": state["w"]})
            placed = place_on_mesh(host, {"w": NamedSharding(m2, P("data", "model"))})
            np.testing.assert_array_equal(np.asarray(placed["w"]),
                                          np.asarray(state["w"]))
        print("ELASTIC_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


def test_hlo_analysis_counts_scan_trips():
    """A scanned matmul must be charged trip_count × 2MNK flops."""
    n, t = 64, 12

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=t)
        return out

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((n, n), jnp.float32),
                               jax.ShapeDtypeStruct((n, n), jnp.float32))
    stats = analyze_module(lowered.compile().as_text())
    expect = 2.0 * n * n * n * t
    assert stats.dot_flops == pytest.approx(expect, rel=0.01), \
        (stats.dot_flops, expect, stats.trip_counts)
    assert t in stats.trip_counts.values()
