"""FTL subsystem core (DESIGN.md §2.10): the L2P map invariants, GC
victim policies, the steady-state WAF pin against the analytic
greedy-GC fixed point, the 7-class op table, byte conservation through
translation, and the block-level fault accounting.

Deliberately hypothesis-free (plain numpy RNG / fixed seed grids) so
the suite runs in minimal environments, like tests/test_trace_engines.py."""

import dataclasses

import numpy as np
import pytest

from repro.core import ftl
from repro.core.nand import CellType, chip as nand_chip
from repro.core.sim import SSDConfig
from repro.core.trace import READ, WRITE, op_class_table
from repro.core.workload import overwrite_stream, request_lpns, request_ops

CFG = SSDConfig(cell=CellType.MLC, channels=2, ways=4)


# --- spec validation + registry ---------------------------------------------


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="blocks"):
        ftl.FTLSpec(blocks=2)
    with pytest.raises(ValueError, match="overprovision"):
        ftl.FTLSpec(overprovision=0.0)
    with pytest.raises(ValueError, match="gc_free_blocks"):
        ftl.FTLSpec(blocks=8, gc_free_blocks=7)
    with pytest.raises(ValueError, match="map_us"):
        ftl.FTLSpec(map_us=-1.0)
    with pytest.raises(ValueError, match="unknown GC policy"):
        ftl.FTLSpec(gc_policy="rr")


def test_gc_policy_registry_error_names_kinds():
    with pytest.raises(ValueError) as e:
        ftl.select_victim("bogus", np.ones(4), np.ones(4, bool),
                          np.arange(4))
    for kind in ftl.GC_POLICIES:
        assert kind in str(e.value)


def test_victim_selection_policies():
    valid = np.array([5, 2, 9, 2, 7])
    cand = np.array([True, True, False, True, True])
    fill = np.array([4, 3, 0, 1, 2])
    # greedy: min valid among candidates = blocks 1 and 3 (both 2);
    # tie broken by oldest fill_seq -> block 3 (fill 1 < 3)
    assert ftl.select_victim("greedy", valid, cand, fill) == 3
    # lru: oldest-opened candidate = block 3 (fill_seq 1)
    assert ftl.select_victim("lru", valid, cand, fill) == 3
    fill2 = np.array([0, 3, 1, 2, 4])
    assert ftl.select_victim("lru", valid, cand, fill2) == 0


def test_spec_geometry_properties():
    spec = ftl.FTLSpec(blocks=64, pages_per_block=32, overprovision=0.25)
    assert spec.total_pages == 2048
    assert spec.logical_pages == int(2048 / 1.25)
    assert spec.utilization == pytest.approx(0.8, abs=0.001)
    assert "gc=greedy" in spec.describe()


# --- analytic WAF fixed point ------------------------------------------------


def test_analytic_waf_fixed_point_and_monotonicity():
    for u in (0.5, 0.7, 0.8, 0.9):
        w = ftl.analytic_waf(u)
        # it IS the fixed point
        assert w == pytest.approx(1.0 / (1.0 - np.exp(-1.0 / (u * w))),
                                  rel=1e-9)
        assert w > 1.0
    assert ftl.analytic_waf(0.9) > ftl.analytic_waf(0.8) \
        > ftl.analytic_waf(0.5)
    with pytest.raises(ValueError):
        ftl.analytic_waf(1.0)
    with pytest.raises(ValueError):
        ftl.analytic_waf(0.0)


# --- the WAF pin: measured steady-state vs analytic -------------------------


@pytest.mark.parametrize("overprovision", [0.15, 0.25, 0.5])
def test_steady_state_waf_matches_analytic_greedy(overprovision):
    """Uniform random overwrites over the full logical space, greedy GC,
    preconditioned to steady state: measured WAF within 10% of the
    analytic fixed point (ISSUE acceptance gate).  Geometry is sized so
    the held-back free reserve is a negligible fraction of the pool."""
    spec = ftl.FTLSpec(blocks=256, pages_per_block=64,
                       overprovision=overprovision, gc_free_blocks=1,
                       precondition=True, precondition_passes=3.0)
    stream = overwrite_stream(60_000, spec.logical_pages, seed=11)
    tr = ftl.translate(stream, spec)
    expect = ftl.analytic_waf(spec.utilization)
    assert tr.stats.waf == pytest.approx(expect, rel=0.10), \
        (tr.stats.waf, expect)


def test_lru_no_better_than_greedy_on_uniform():
    """Under uniform overwrites validity decays with age, so greedy and
    LRU-cold nearly coincide — but greedy (min valid) can never do
    worse.  Small tolerance for finite-pool noise."""
    wafs = {}
    for policy in ftl.GC_POLICIES:
        spec = ftl.FTLSpec(blocks=128, pages_per_block=32,
                           overprovision=0.25, gc_policy=policy,
                           precondition=True, precondition_passes=2.0)
        stream = overwrite_stream(20_000, spec.logical_pages, seed=5)
        wafs[policy] = ftl.translate(stream, spec).stats.waf
    assert wafs["greedy"] <= wafs["lru"] * 1.05, wafs


def test_waf_decreases_with_overprovisioning():
    wafs = []
    for op in (0.1, 0.25, 0.6):
        spec = ftl.FTLSpec(blocks=128, pages_per_block=32,
                           overprovision=op, precondition=True)
        stream = overwrite_stream(15_000, spec.logical_pages, seed=3)
        wafs.append(ftl.translate(stream, spec).stats.waf)
    assert wafs[0] > wafs[1] > wafs[2]
    assert wafs[2] >= 1.0


# --- L2P map invariants (round-trip + conservation) -------------------------


def _invariants(state: ftl.FTLState):
    """The map invariants every translation must preserve."""
    mapped = np.flatnonzero(state.l2p >= 0)
    # round trip: p2l[l2p[lpn]] == lpn for every mapped page
    assert np.array_equal(state.p2l[state.l2p[mapped]], mapped)
    # and the reverse: every mapped physical page points back
    phys = np.flatnonzero(state.p2l >= 0)
    assert np.array_equal(state.l2p[state.p2l[phys]], phys)
    # no two logical pages share a physical page
    assert len(np.unique(state.l2p[mapped])) == len(mapped)
    # per-block valid counts agree with the p2l map
    ppb = state._ppb
    counts = np.bincount(phys // ppb, minlength=state.spec.blocks)
    assert np.array_equal(counts, state.valid_count)


@pytest.mark.parametrize("policy", ftl.GC_POLICIES)
@pytest.mark.parametrize("seed", [0, 7])
def test_l2p_round_trip_through_gc(policy, seed):
    spec = ftl.FTLSpec(blocks=32, pages_per_block=16, overprovision=0.3,
                       gc_policy=policy)
    stream = overwrite_stream(4000, spec.logical_pages, seed=seed)
    tr = ftl.translate(stream, spec)
    assert tr.stats.gc_op_count > 0          # GC actually ran
    _invariants(tr.state)
    # every host write is readable at its latest location
    lpns = request_lpns(stream, spec.logical_pages)
    cls, _, _, _ = request_ops(stream)
    written = np.unique(lpns[cls == WRITE])
    assert (tr.state.l2p[written] >= 0).all()


def test_translation_byte_and_op_conservation():
    """Host payload ops survive translation exactly once each; GC ops
    carry no payload credit; op counts reconcile with the stats."""
    spec = ftl.FTLSpec(blocks=32, pages_per_block=16, overprovision=0.3)
    stream = overwrite_stream(3000, spec.logical_pages,
                              read_fraction=0.3, seed=2)
    cls, _, _, payload = request_ops(stream)
    tr = ftl.translate(stream, spec)
    # one translated op per host op, payload preserved op-for-op
    host = ~tr.gc
    assert host.sum() == len(cls)
    assert np.array_equal(tr.payload[host], payload)
    assert not tr.payload[tr.gc].any()
    # class accounting
    assert (tr.op_cls[host] == np.where(cls == READ, ftl.FTL_READ,
                                        ftl.FTL_WRITE)).all()
    st = tr.stats
    assert (tr.op_cls == ftl.GC_READ).sum() == st.gc_reads
    assert (tr.op_cls == ftl.GC_WRITE).sum() == st.gc_writes
    assert (tr.op_cls == ftl.ERASE).sum() == st.erases
    assert st.host_pages_written == int((cls == WRITE).sum())
    assert st.total_pages_written == st.host_pages_written + st.gc_writes
    # arrivals stay nondecreasing after injection
    assert (np.diff(tr.arrival_us) >= 0).all()
    # request ids: host ops keep theirs, GC ops have none
    assert (tr.request_id[tr.gc] == -1).all()
    assert (tr.request_id[host] >= 0).all()


def test_translate_is_deterministic_and_chains_state():
    spec = ftl.FTLSpec(blocks=32, pages_per_block=16, overprovision=0.3,
                       precondition=True, seed=9)
    stream = overwrite_stream(2000, spec.logical_pages, seed=1)
    a = ftl.translate(stream, spec)
    b = ftl.translate(stream, spec)
    assert np.array_equal(a.op_cls, b.op_cls)
    assert np.array_equal(a.arrival_us, b.arrival_us)
    assert a.stats.waf == b.stats.waf
    # chaining: the second window on the same state starts aged
    first = ftl.translate(stream, dataclasses.replace(
        spec, precondition=False))
    second = ftl.translate(stream, spec, state=first.state)
    assert second.stats.waf > 1.0


def test_free_page_low_watermark_monotone():
    spec = ftl.FTLSpec(blocks=32, pages_per_block=16, overprovision=0.3)
    stream = overwrite_stream(3000, spec.logical_pages, seed=4)
    tr = ftl.translate(stream, spec)
    wm = tr.stats.free_page_low_watermark
    assert 0 <= wm <= tr.state.free_pages
    # the watermark is the floor: GC keeps at least the reserve free
    assert wm >= (spec.gc_free_blocks - 1) * spec.pages_per_block


# --- the 7-class table -------------------------------------------------------


def test_ftl_op_class_table_extends_base():
    spec = ftl.FTLSpec(map_us=0.7)
    base = op_class_table(CFG)
    tab = ftl.ftl_op_class_table(CFG, spec)
    assert tab.n_classes == 7
    assert tuple(tab.labels) == ftl.FTL_LABELS
    # rows 0/1 are bitwise the host table (non-FTL traces price equal)
    for f in ("cmd_us", "pre_us", "slot_us", "post_lo_us", "post_hi_us",
              "ctrl_us", "arb_us", "data_bytes", "io_us"):
        np.testing.assert_array_equal(np.asarray(getattr(tab, f))[:2],
                                      np.asarray(getattr(base, f)))
    # FTL classes charge the map on the controller, not the bus
    assert tab.ctrl_us[ftl.FTL_READ] == pytest.approx(
        base.ctrl_us[READ] + 0.7)
    assert tab.ctrl_us[ftl.FTL_WRITE] == pytest.approx(
        base.ctrl_us[WRITE] + 0.7)
    assert tab.slot_us[ftl.FTL_READ] == base.slot_us[READ]
    # GC ops move no host payload
    assert tab.data_bytes[ftl.GC_READ] == tab.data_bytes[READ]
    assert tab.data_bytes[ftl.ERASE] == 0
    # erase occupies the die for t_BERS
    assert tab.post_lo_us[ftl.ERASE] == pytest.approx(
        nand_chip(CFG.cell).t_bers_us)
    spec2 = ftl.FTLSpec(erase_us=123.0)
    assert ftl.ftl_op_class_table(CFG, spec2).post_hi_us[ftl.ERASE] \
        == pytest.approx(123.0)


# --- fault integration: block-level retirement ------------------------------


def test_program_failure_retires_blocks_through_accounting():
    spec = ftl.FTLSpec(blocks=128, pages_per_block=32, overprovision=0.3)
    stream = overwrite_stream(9000, spec.logical_pages, seed=6)
    tr = ftl.translate(stream, spec, prog_fail_prob=0.001,
                       erase_fail_prob=0.01, fault_seed=13)
    st = tr.stats
    assert st.prog_fails > 0 and st.blocks_retired > 0
    # failed programs still wrote physical pages (WAF sees them)
    assert st.total_pages_written >= st.host_pages_written + st.gc_writes
    clean = ftl.translate(stream, spec)
    assert st.waf > clean.stats.waf          # failures amplify writes
    _invariants(tr.state)
    # retired blocks are out of the pool: never the open frontier
    assert not tr.state.retired[tr.state.open_block]
    assert not any(tr.state.retired[b] for b in tr.state.free)


def test_fault_sampling_is_deterministic_per_seed():
    spec = ftl.FTLSpec(blocks=128, pages_per_block=32, overprovision=0.3)
    stream = overwrite_stream(6000, spec.logical_pages, seed=6)
    a = ftl.translate(stream, spec, prog_fail_prob=0.002, fault_seed=3)
    b = ftl.translate(stream, spec, prog_fail_prob=0.002, fault_seed=3)
    c = ftl.translate(stream, spec, prog_fail_prob=0.002, fault_seed=4)
    assert np.array_equal(a.op_cls, b.op_cls)
    assert a.stats.prog_fails == b.stats.prog_fails
    assert not np.array_equal(a.op_cls, c.op_cls) \
        or a.stats.prog_fails != c.stats.prog_fails


def test_drive_death_raises_not_hangs():
    """Retiring most of the pool must end in a loud RuntimeError, not an
    infinite GC loop."""
    spec = ftl.FTLSpec(blocks=16, pages_per_block=8, overprovision=0.1)
    stream = overwrite_stream(4000, spec.logical_pages, seed=0)
    with pytest.raises(RuntimeError):
        ftl.translate(stream, spec, erase_fail_prob=0.5, fault_seed=1)


def test_translate_rejects_non_host_classes_and_empty():
    spec = ftl.FTLSpec()
    stream = overwrite_stream(10, 64, seed=0)
    bad = dataclasses.replace(
        stream, op_cls=np.full(stream.n_requests, 5, np.int32))
    with pytest.raises(ValueError, match="READ/WRITE"):
        ftl.translate(bad, spec)
