"""Pallas kernel correctness sweeps (interpret=True) vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.maxplus.kernel import maxplus_fold_kernel
from repro.kernels.maxplus.ref import maxplus_fold_ref
from repro.kernels.rglru.ops import rglru_linear_scan
from repro.kernels.rglru.ref import rglru_scan_ref

KEY = jax.random.PRNGKey(7)


# --- flash attention ---------------------------------------------------------

FLASH_CASES = [
    # b, h, kvh, sq, sk, d, causal, window, dtype, bq, bk
    (2, 4, 2, 128, 128, 64, True, None, jnp.float32, 64, 64),
    (1, 4, 1, 256, 256, 64, True, 64, jnp.float32, 64, 64),
    (2, 2, 2, 128, 128, 32, False, None, jnp.bfloat16, 64, 64),
    (1, 6, 2, 128, 256, 64, True, None, jnp.float32, 64, 64),  # q_offset
    (1, 8, 8, 64, 64, 128, True, None, jnp.float32, 32, 32),   # MHA
    (1, 2, 1, 64, 64, 16, True, 16, jnp.bfloat16, 64, 64),     # tiny window
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(i) for i in range(len(FLASH_CASES))])
def test_flash_attention_matches_reference(case):
    b, h, kvh, sq, sk, d, causal, window, dtype, bq, bk = case
    ks = jax.random.split(jax.random.fold_in(KEY, sq + sk + d), 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, kvh, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, kvh, sk, d), dtype)
    off = sk - sq
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk, q_offset=off)
    ref = attention_reference(q, k, v, causal=causal, window=window, q_offset=off)
    tol = 2.5e-2 if dtype == jnp.bfloat16 else 5e-5
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, err


def test_flash_attention_grouped_layout():
    """Model-native [B, S, kvH, G, D] layout round-trips correctly."""
    ks = jax.random.split(KEY, 3)
    b, s, kvh, g, d = 2, 128, 2, 3, 32
    q = jax.random.normal(ks[0], (b, s, kvh, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    qx = q.transpose(0, 2, 3, 1, 4).reshape(b, kvh * g, s, d)
    ref = attention_reference(qx, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    ref = ref.reshape(b, kvh, g, s, d).transpose(0, 3, 1, 2, 4)
    assert float(jnp.max(jnp.abs(out - ref))) < 5e-5


# --- maxplus -----------------------------------------------------------------


@pytest.mark.parametrize("b,p,n,t", [(4, 8, 18, 40), (130, 4, 18, 17), (1, 2, 6, 9)])
def test_maxplus_kernel_matches_ref(b, p, n, t):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, b * p + t))
    mats = jax.random.uniform(k1, (b, p, n, n), jnp.float32, 0.0, 10.0)
    mats = jnp.where(jax.random.bernoulli(k2, 0.4, mats.shape), mats, -1e30)
    s0 = jnp.zeros((b, n), jnp.float32)
    out = maxplus_fold_kernel(mats, s0, t_steps=t)
    ref = maxplus_fold_ref(mats, s0, t_steps=t)
    assert np.allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-3)


# --- rglru scan --------------------------------------------------------------


@pytest.mark.parametrize("b,s,r,bs,dtype", [
    (2, 512, 128, 128, jnp.float32),
    (1, 256, 256, 64, jnp.float32),
    (2, 128, 128, 128, jnp.bfloat16),
    (1, 64, 128, 32, jnp.float32),
    (3, 96, 128, 96, jnp.float32),
])
def test_rglru_kernel_matches_associative_scan(b, s, r, bs, dtype):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, s + r))
    a = jax.random.uniform(k1, (b, s, r), jnp.float32, 0.85, 0.999).astype(dtype)
    x = jax.random.normal(k2, (b, s, r), jnp.float32).astype(dtype)
    out = rglru_linear_scan(a, x, block_s=bs)
    ref = rglru_scan_ref(a, x)
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-4
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < tol, err
