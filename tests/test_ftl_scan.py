"""The compiled FTL translation engine (DESIGN.md §2.11): the lax.scan
machine must be op-for-op the host translator — same op classes,
arrivals, payloads, request ids, GC flags, stats, erase counts and
final drive state — across the policy × geometry × overprovisioning
grid, errors included; the fused sweep and the chunked streaming
variant must reproduce the per-point / one-shot answers exactly; and
the FTL sub-session cache must stay LRU-bounded."""

import dataclasses

import numpy as np
import pytest

import repro.api as api
from repro.core import ftl, ftl_scan, sched
from repro.core.nand import CellType
from repro.core.sim import SSDConfig
from repro.core.workload import (aging_stream, iter_request_chunks,
                                 overwrite_stream)


def _assert_translations_equal(th, ts):
    assert np.array_equal(th.op_cls, ts.op_cls)
    assert np.array_equal(th.arrival_us, ts.arrival_us)
    assert np.array_equal(th.payload, ts.payload)
    assert np.array_equal(th.request_id, ts.request_id)
    assert np.array_equal(th.gc, ts.gc)
    assert th.stats == ts.stats
    assert np.array_equal(th.state.l2p, ts.state.l2p)
    assert np.array_equal(th.state.p2l, ts.state.p2l)
    assert np.array_equal(th.state.valid_count, ts.state.valid_count)
    assert np.array_equal(th.state.full, ts.state.full)
    assert np.array_equal(th.state.fill_seq, ts.state.fill_seq)
    assert np.array_equal(th.state.erase_count, ts.state.erase_count)
    assert list(th.state.free) == list(ts.state.free)
    assert th.state.open_block == ts.state.open_block
    assert th.state.next_page == ts.state.next_page
    assert th.state._seq == ts.state._seq


# --- oracle agreement: the tentpole invariant -------------------------------


@pytest.mark.parametrize("policy", ftl.GC_POLICIES)
@pytest.mark.parametrize("blocks,ppb", [(16, 4), (32, 16), (64, 32)])
@pytest.mark.parametrize("op", [0.15, 0.28, 0.5])
def test_scan_matches_host_grid(policy, blocks, ppb, op):
    """Op-for-op agreement over policy × geometry × overprovisioning,
    with preconditioning (the ISSUE acceptance grid)."""
    spec = ftl.FTLSpec(blocks=blocks, pages_per_block=ppb,
                       overprovision=op, gc_policy=policy,
                       precondition=True)
    stream = overwrite_stream(200, 100, seed=3)
    try:
        th = translate_err = None
        th = ftl.translate(stream, spec)
    except RuntimeError as e:
        translate_err = str(e)
    if translate_err is not None:
        with pytest.raises(RuntimeError) as ei:
            ftl_scan.translate_scan(stream, spec)
        assert str(ei.value) == translate_err
        return
    _assert_translations_equal(th, ftl_scan.translate_scan(stream, spec))


@pytest.mark.parametrize("policy", ftl.GC_POLICIES)
def test_scan_matches_host_read_mix(policy):
    """Reads, Poisson arrivals and a skewed footprint exercise every
    branch of the machine (host reads never touch the map)."""
    spec = ftl.FTLSpec(blocks=64, pages_per_block=16, overprovision=0.25,
                       gc_policy=policy, precondition=True)
    stream = aging_stream(800, 600, read_fraction=0.3,
                          mean_interarrival_us=2.0, seed=11)
    _assert_translations_equal(ftl.translate(stream, spec),
                               ftl_scan.translate_scan(stream, spec))


@pytest.mark.parametrize("policy", ftl.GC_POLICIES)
def test_scan_chaining_matches_host(policy):
    """state= chains aging: scan→scan and host→scan both continue the
    drive exactly like host→host (stats stay cumulative)."""
    spec = ftl.FTLSpec(blocks=64, pages_per_block=16, overprovision=0.28,
                       gc_policy=policy, precondition=True)
    s1 = overwrite_stream(300, 120, seed=7)
    s2 = overwrite_stream(300, 120, seed=8)
    ref = ftl.translate(s2, spec, state=ftl.translate(s1, spec).state)
    ts1 = ftl_scan.translate_scan(s1, spec)
    _assert_translations_equal(
        ref, ftl_scan.translate_scan(s2, spec, state=ts1.state))
    _assert_translations_equal(
        ref, ftl_scan.translate_scan(
            s2, spec, state=ftl.translate(s1, spec).state))


def test_scan_error_messages_match_host():
    """Deferred error decode reproduces the host RuntimeErrors
    verbatim (the deadlock grid cell)."""
    spec = ftl.FTLSpec(blocks=8, pages_per_block=8, overprovision=0.15,
                       precondition=True)
    stream = overwrite_stream(64, 24, seed=3)
    with pytest.raises(RuntimeError) as host_err:
        ftl.translate(stream, spec)
    with pytest.raises(RuntimeError) as scan_err:
        ftl_scan.translate_scan(stream, spec)
    assert str(scan_err.value) == str(host_err.value)


def test_scan_rejects_faulty_state_and_bad_streams():
    spec = ftl.FTLSpec(blocks=32, pages_per_block=8, overprovision=0.3)
    st = ftl.FTLState(spec)
    st.bad[3] = True
    with pytest.raises(ValueError, match="fault-free"):
        ftl_scan.scan_state_from_host(st)
    s = overwrite_stream(4, 4)
    empty = dataclasses.replace(s, **{
        f.name: getattr(s, f.name)[:0]
        for f in dataclasses.fields(s)
        if isinstance(getattr(s, f.name), np.ndarray)})
    with pytest.raises(ValueError, match="empty workload"):
        ftl_scan.translate_scan(empty, spec)


def test_small_buffer_retry_converges():
    """An undersized output buffer is detected and doubled, not
    mis-translated: force a tiny t_max through the low-level runner."""
    spec = ftl.FTLSpec(blocks=32, pages_per_block=8, overprovision=0.3,
                       precondition=True)
    stream = overwrite_stream(256, 128, seed=5)
    th = ftl.translate(stream, spec)
    ts = ftl_scan.translate_scan(stream, spec)
    _assert_translations_equal(th, ts)
    # the public path already buckets; drive _run_machine directly with
    # a hint far below the emitted count to exercise the doubling loop
    from repro.core.workload import request_lpns, request_ops
    cls, arr, rid, pay = request_ops(stream)
    lpns = request_lpns(stream, spec.logical_pages)
    fs = ftl_scan.scan_state_from_host(ftl.FTLState(spec))
    fs, ys = ftl_scan._run_machine(fs, spec, cls, arr, pay, rid, lpns, 1)
    assert int(np.sum(np.asarray(ys[-1]))) >= len(cls)


# --- satellite: erase-count accounting --------------------------------------


def test_erase_counts_host_and_scan():
    """Per-block wear lands in FTLStats from both translators, covers
    the preconditioning phase, and sums to the erase ops ever emitted."""
    spec = ftl.FTLSpec(blocks=32, pages_per_block=8, overprovision=0.25,
                       precondition=True)
    stream = overwrite_stream(400, 150, seed=9)
    th = ftl.translate(stream, spec)
    ts = ftl_scan.translate_scan(stream, spec)
    assert th.stats.max_erase_count == ts.stats.max_erase_count
    assert th.stats.mean_erase_count == ts.stats.mean_erase_count
    assert np.array_equal(th.state.erase_count, ts.state.erase_count)
    # the measured window resets counters; lifetime wear keeps growing
    assert int(th.state.erase_count.sum()) >= th.stats.erases > 0
    assert th.stats.max_erase_count == int(th.state.erase_count.max())
    assert th.stats.mean_erase_count == pytest.approx(
        float(th.state.erase_count.mean()))
    fresh = ftl.translate(
        stream, dataclasses.replace(spec, precondition=False))
    assert int(fresh.state.erase_count.sum()) == fresh.stats.erases


# --- the API surface: default path, sweep, streaming ------------------------


def _sim(channels=2, ways=4):
    return api.Simulator(SSDConfig(cell=CellType.MLC, channels=channels,
                                   ways=ways))


SPEC = ftl.FTLSpec(blocks=64, pages_per_block=32, overprovision=0.25,
                   precondition=True)


def test_run_default_path_is_scan(monkeypatch):
    """Fault-free FTL queries ride the compiled engine; block-level
    fault probabilities fall back to the host oracle."""
    sim = _sim()
    stream = overwrite_stream(400, 300, seed=2)
    calls = {"scan": 0, "host": 0}
    orig_scan, orig_host = ftl_scan.translate_scan, ftl.translate

    def spy_scan(*a, **kw):
        calls["scan"] += 1
        return orig_scan(*a, **kw)

    def spy_host(*a, **kw):
        calls["host"] += 1
        return orig_host(*a, **kw)

    import repro.core.api as core_api
    monkeypatch.setattr(core_api._ftl_scan, "translate_scan", spy_scan)
    monkeypatch.setattr(core_api._ftl, "translate", spy_host)
    sim.run(stream, ftl=SPEC)
    assert calls == {"scan": 1, "host": 0}
    sim.run(stream,
            ftl=dataclasses.replace(SPEC, overprovision=0.5,
                                    precondition=False),
            faults=api.FaultSpec(prog_fail_prob=0.002, seed=3))
    assert calls == {"scan": 1, "host": 1}
    # per-op surcharges alone (retry/jitter) stay on the scan path
    sim.run(stream, ftl=SPEC, faults=api.FaultSpec(wear=0.5, seed=3))
    assert calls == {"scan": 2, "host": 1}


@pytest.mark.parametrize("engine", ["scan", "prefix", "pallas",
                                    "streaming", "oracle"])
def test_engines_agree_scan_vs_host_translation(engine, monkeypatch):
    """ISSUE acceptance: every ftl-capable engine answers the scan
    -translated stream within 1e-3 of the host-translated one (they
    are op-for-op equal, so the ends are bitwise equal)."""
    sim = _sim()
    stream = overwrite_stream(600, 450, read_fraction=0.2, seed=4)
    scan_res = sim.run(stream, ftl=SPEC, engine=engine)
    import repro.core.api as core_api
    monkeypatch.setattr(
        core_api._ftl_scan, "translate_scan",
        lambda s, sp, **kw: ftl.translate(s, sp, **kw))
    host_res = sim.run(stream, ftl=SPEC, engine=engine)
    assert scan_res.end_us == host_res.end_us
    assert scan_res.waf == host_res.waf
    assert scan_res.ftl_stats == host_res.ftl_stats
    assert scan_res.n_ops == host_res.n_ops


def test_sweep_ftl_matches_per_point_runs():
    """The fused vmap sweep answers within the 1e-3 cross-engine
    contract of the serial run(SimRequest(ftl=...)) path — the op
    sequence is identical by the oracle gate; the end time is the
    sweep's masked prefix fold vs run()'s scan engine."""
    sim = _sim()
    stream = overwrite_stream(300, 150, seed=5)
    specs = [dataclasses.replace(SPEC, blocks=64, pages_per_block=16,
                                 overprovision=op, gc_policy=pol)
             for op in (0.15, 0.3, 0.5) for pol in ftl.GC_POLICIES]
    ends = sim.sweep(None, stream, ftl=specs)
    assert ends.shape == (len(specs),)
    for i, s in enumerate(specs):
        ref = sim.run(stream, ftl=s).end_us
        assert abs(ends[i] - ref) / ref < 1e-3, (s, ends[i], ref)
    # WAF ordering sanity across the OP axis (greedy points)
    greedy = [sim.run(stream, ftl=s).waf for s in specs[::2]]
    assert greedy[0] > greedy[1] > greedy[2]


def test_sweep_ftl_validation():
    sim = _sim()
    stream = overwrite_stream(64, 32, seed=1)
    with pytest.raises(ValueError, match="tables must be"):
        sim.sweep([sim.table], stream, ftl=[SPEC])
    with pytest.raises(ValueError, match="share geometry"):
        sim.sweep(None, stream, ftl=[
            SPEC, dataclasses.replace(SPEC, blocks=32)])
    with pytest.raises(ValueError, match="dynamic"):
        sim.sweep(None, stream, ftl=[SPEC], sched_policy="least_loaded")
    with pytest.raises(ValueError, match="at least one"):
        sim.sweep(None, stream, ftl=[])


def test_sweep_ftl_error_decode():
    """A deadlocked lane raises the host message for its own spec."""
    sim = _sim()
    stream = overwrite_stream(64, 24, seed=3)
    bad = ftl.FTLSpec(blocks=8, pages_per_block=8, overprovision=0.15,
                      precondition=True)
    with pytest.raises(RuntimeError, match="fully valid"):
        sim.sweep(None, stream, ftl=[bad])


def test_run_stream_ftl_matches_one_shot():
    """Chunked translation + chunk lowering + streaming fold equals
    the one-shot FTL run bit-for-bit (end, WAF, stats)."""
    sim = _sim()
    spec = dataclasses.replace(SPEC, blocks=64, pages_per_block=16,
                               overprovision=0.28)
    stream = overwrite_stream(500, 200, seed=6)
    one = sim.run(stream, ftl=spec)
    for chunk in (64, 128, 500):
        res = sim.run_stream(iter_request_chunks(stream, chunk),
                             ftl=spec)
        assert res.end_us == one.end_us, chunk
        assert res.waf == one.waf
        assert res.ftl_stats == one.ftl_stats
        assert res.n_ops == one.n_ops
        assert res.payload_bytes == one.payload_bytes


def test_run_stream_ftl_faults_composition():
    """FTL × faults × chunked streaming: the sequential fault sampler
    makes the chunked surcharges identical to the one-shot ones."""
    sim = _sim()
    spec = dataclasses.replace(SPEC, blocks=64, pages_per_block=16,
                               overprovision=0.3)
    faults = api.FaultSpec(wear=0.6, jitter_us=0.4, seed=13)
    stream = overwrite_stream(400, 160, seed=7)
    one = sim.run(stream, ftl=spec, faults=faults)
    res = sim.run_stream(iter_request_chunks(stream, 96), ftl=spec,
                         faults=faults)
    assert res.end_us == one.end_us
    assert res.waf == one.waf
    # chunk-size invariance of the whole composition
    res2 = sim.run_stream(iter_request_chunks(stream, 37), ftl=spec,
                          faults=faults)
    assert res2.end_us == res.end_us


def test_run_stream_ftl_validation():
    sim = _sim()
    stream = overwrite_stream(64, 32, seed=1)
    with pytest.raises(ValueError, match="needs ftl="):
        sim.run_stream(iter([]), faults=api.FaultSpec(wear=0.5))
    with pytest.raises(ValueError, match="dynamic"):
        sim.run_stream(iter_request_chunks(stream, 32), ftl=SPEC,
                       sched_policy="least_loaded")
    with pytest.raises(ValueError, match="one-shot"):
        sim.run_stream(iter_request_chunks(stream, 32), ftl=SPEC,
                       faults=api.FaultSpec(prog_fail_prob=0.1))
    with pytest.raises(ValueError, match="empty workload"):
        sim.run_stream(iter([]), ftl=SPEC)


# --- satellite: chunked lowering exactness ----------------------------------


@pytest.mark.parametrize("policy", sched.STATIC_POLICIES)
def test_lower_ops_chunk_matches_lower_ops(policy):
    rng = np.random.default_rng(2)
    n, C, W = 317, 4, 2
    cls = rng.integers(2, 7, n).astype(np.int32)
    arr = np.sort(rng.random(n)).astype(np.float32)
    pay = rng.random(n) < 0.7
    one = sched.lower_ops(cls, arr, C, W, policy, pay)
    off, parts = 0, []
    for lo in range(0, n, 60):
        tr, off = sched.lower_ops_chunk(
            cls[lo:lo + 60], arr[lo:lo + 60], C, W, policy,
            pay[lo:lo + 60], off)
        parts.append(tr)
    assert off == n
    for f in ("cls", "channel", "way", "parity"):
        assert np.array_equal(
            np.asarray(getattr(one, f)),
            np.concatenate([np.asarray(getattr(t, f)) for t in parts])), f
    with pytest.raises(ValueError, match="dynamic"):
        sched.lower_ops_chunk(cls, arr, C, W, "least_loaded")


# --- satellite: lru WAF under skew ------------------------------------------


def test_lru_waf_under_skew():
    """Under a hot/cold skew, LRU's oldest-block victims carry the cold
    (still-valid) data, so LRU relocates at least as much as greedy;
    both sit in the analytic neighbourhood for the utilization."""
    spec_g = ftl.FTLSpec(blocks=64, pages_per_block=16,
                         overprovision=0.28, gc_policy="greedy",
                         precondition=True)
    spec_l = dataclasses.replace(spec_g, gc_policy="lru")
    stream = aging_stream(6000, 700, hot_fraction=0.2, hot_traffic=0.8,
                          seed=17)
    waf_g = ftl_scan.translate_scan(stream, spec_g).stats.waf
    waf_l = ftl_scan.translate_scan(stream, spec_l).stats.waf
    assert waf_l >= waf_g > 1.0
    # regression band: pinned against the host translator's values
    assert waf_g == pytest.approx(
        ftl.translate(stream, spec_g).stats.waf)
    assert waf_l == pytest.approx(
        ftl.translate(stream, spec_l).stats.waf)
    assert 1.0 < waf_l < 3.0 * ftl.analytic_waf(spec_l.utilization)


# --- satellite: FTL sub-session cache ---------------------------------------


def test_ftl_session_cache_lru_eviction():
    """The sub-session cache is LRU-bounded with CacheInfo counters:
    the oldest timing key is evicted past max_ftl_sessions, and a
    rebuilt session still answers identically."""
    sim = api.Simulator(SSDConfig(channels=2, ways=2),
                        max_ftl_sessions=2)
    stream = overwrite_stream(120, 60, seed=1)
    spec = ftl.FTLSpec(blocks=32, pages_per_block=8, overprovision=0.3)
    specs = [dataclasses.replace(spec, map_us=m)
             for m in (0.5, 0.7, 0.9)]
    first = sim.run(stream, ftl=specs[0]).end_us
    info0 = sim.ftl_cache_info()
    assert info0.entries == 1 and info0.max_entries == 2
    sim.run(stream, ftl=specs[1])
    sim.run(stream, ftl=specs[2])            # evicts specs[0]'s session
    info = sim.ftl_cache_info()
    assert info.entries == 2 and info.evictions == 1
    assert sim.run(stream, ftl=specs[0]).end_us == first   # rebuilt
    assert sim.ftl_cache_info().evictions == 2
    sim.run(stream, ftl=specs[0])                          # now a hit
    assert sim.ftl_cache_info().hits >= 1
    with pytest.raises(ValueError, match="max_ftl_sessions"):
        api.Simulator(SSDConfig(channels=2, ways=2), max_ftl_sessions=0)


def test_ftl_session_memoised_identity_preserved():
    """Same timing key → same sibling session object (the behaviour the
    pre-LRU dict gave); different map_us → different session."""
    sim = _sim()
    a = sim._ftl_session(SPEC)
    b = sim._ftl_session(dataclasses.replace(SPEC, overprovision=0.4))
    c = sim._ftl_session(dataclasses.replace(SPEC, map_us=2.5))
    assert a is b and a is not c
