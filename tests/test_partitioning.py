"""Divisibility-aware sharding rules against the production 16×16 mesh
(AbstractMesh: no devices needed)."""

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_arch
from repro.distributed import partitioning as part
from repro.launch.steps import abstract_train_state, train_state_pspecs
from repro.models.transformer import init_cache, init_params
from repro.train.optimizer import OptConfig

# jax 0.4.37's AbstractMesh takes a shape_tuple of (name, size) pairs
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def _specs(name, mesh=MESH):
    arch = get_arch(name)
    shapes = jax.eval_shape(lambda: init_params(arch.config, jax.random.PRNGKey(0)))
    return arch.config, shapes, part.param_pspecs(arch.config, mesh, shapes)


def _flat(tree):
    out = {}
    jax.tree_util.tree_map_with_path(
        lambda kp, l: out.setdefault(part._path_str(kp), l),
        tree, is_leaf=lambda x: isinstance(x, P))
    return out


def _assert_all_divisible(shapes, specs, mesh):
    sizes = dict(mesh.shape)
    fs, fsh = _flat(specs), _flat(shapes)
    for path, spec in fs.items():
        shape = fsh[path].shape
        for dim, entry in zip(shape, tuple(spec)):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            prod = 1
            for a in axes:
                prod *= sizes[a]
            assert dim % prod == 0, (path, shape, spec)


@pytest.mark.parametrize("name", ["qwen2-0.5b", "recurrentgemma-9b",
                                  "llama4-maverick-400b-a17b",
                                  "granite-moe-3b-a800m", "xlstm-350m",
                                  "minicpm-2b"])
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["single", "multi"])
def test_param_specs_divisible(name, mesh):
    cfg, shapes, specs = _specs(name, mesh)
    _assert_all_divisible(shapes, specs, mesh)


def test_attention_fallback_chain():
    # recurrentgemma: G = 16 divides -> Megatron head parallel on the group axis
    _, _, specs = _specs("recurrentgemma-9b")
    fs = _flat(specs)
    wq = [v for k, v in fs.items() if k.endswith("mixer/wq")][0]
    assert tuple(wq) == (None, None, None, "model", None)  # [U, d, kvH, G, Dh]
    # qwen2: kv=2, G=7 -> replicated weights (sequence-sharded activations)
    _, _, specs = _specs("qwen2-0.5b")
    wq = [v for k, v in _flat(specs).items() if k.endswith("mixer/wq")][0]
    assert all(e is None for e in tuple(wq))


def test_moe_fallback_chain():
    # llama4: E=128 divides 16 -> expert parallel (layer1 is the MoE layer)
    _, _, specs = _specs("llama4-maverick-400b-a17b")
    wi = _flat(specs)["unit/layer1/ffn/wi"]
    assert tuple(wi)[1] == "model"
    # granite-moe: E=40 does not divide -> capacity-slot parallel
    # (weights replicated; the [G,E,C,d] dispatch buffer shards its slot
    # axis via an activation constraint — see partitioning._moe_spec)
    _, _, specs = _specs("granite-moe-3b-a800m")
    wi = [v for k, v in _flat(specs).items() if k.endswith("ffn/wi")][0]
    assert all(e is None for e in tuple(wi))


def test_fsdp_units_only_llama4():
    _, _, specs = _specs("llama4-maverick-400b-a17b")
    used = [v for k, v in _flat(specs).items() if k.startswith("unit/")]
    assert any("data" in str(tuple(s)) for s in used)
    _, _, specs = _specs("qwen2-0.5b")
    used = [v for k, v in _flat(specs).items() if k.startswith("unit/")]
    assert not any("data" in str(tuple(s)) for s in used)


def test_vocab_padding():
    cfg = get_arch("minicpm-2b").config
    assert cfg.vocab_size == 122753
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= cfg.vocab_size


def test_zero1_and_train_state_specs_divisible():
    arch = get_arch("granite-3-2b")
    ocfg = OptConfig()
    st = abstract_train_state(arch.config, ocfg)
    specs = train_state_pspecs(arch.config, ocfg, MESH, st)
    _assert_all_divisible(st, specs, MESH)
    # moments must pick up a 'data' sharding somewhere
    mspecs = _flat(specs["opt"])
    assert any("data" in str(tuple(v)) for k, v in mspecs.items()
               if k.startswith("m/"))


def test_cache_specs_shard_seq_over_model():
    arch = get_arch("qwen2-0.5b")
    cache = jax.eval_shape(lambda: init_cache(arch.config, 128, 32768))
    specs = part.cache_pspecs(arch.config, MESH, cache)
    fs, fsh = _flat(specs), _flat(cache)
    kspec = [v for k, v in fs.items() if k.endswith("/k")][0]
    assert tuple(kspec)[3] == "model"       # [U, B, kvH, S, Dh] -> S sharded
    _assert_all_divisible(cache, specs, MESH)


def test_activation_rules():
    r = part.activation_rules(get_arch("qwen2-0.5b").config, MESH, 256)
    assert r["seq"] == "model"              # context-parallel fallback
    r = part.activation_rules(get_arch("recurrentgemma-9b").config, MESH, 256)
    assert r["seq"] is None                 # head-TP available
    assert part.batch_axes(MESH, 1) is None
    assert part.batch_axes(MESH3, 256) == ("pod", "data")
