"""AdamW (+ int8 moments, master weights) vs a NumPy reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (OptConfig, _dequantize, _quantize,
                                   adamw_init, adamw_update, global_norm)
from repro.train.schedules import constant, warmup_cosine, wsd


def _numpy_adamw(params, grads_seq, lr, cfg: OptConfig):
    m = {k: np.zeros_like(v, np.float32) for k, v in params.items()}
    v = {k: np.zeros_like(p, np.float32) for k, p in params.items()}
    master = {k: p.astype(np.float32) for k, p in params.items()}
    for t, grads in enumerate(grads_seq, start=1):
        gn = np.sqrt(sum(np.sum(g.astype(np.float32) ** 2) for g in grads.values()))
        scale = min(1.0, cfg.clip_norm / max(gn, 1e-12))
        for k in params:
            g = grads[k].astype(np.float32) * scale
            m[k] = cfg.b1 * m[k] + (1 - cfg.b1) * g
            v[k] = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
            mh = m[k] / (1 - cfg.b1 ** t)
            vh = v[k] / (1 - cfg.b2 ** t)
            step = mh / (np.sqrt(vh) + cfg.eps)
            master[k] = master[k] - lr * (step + cfg.weight_decay * master[k])
    return master


def test_adamw_matches_numpy_reference():
    cfg = OptConfig(weight_decay=0.01, clip_norm=1.0)
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (8, 4), jnp.float32),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (5,), jnp.float32)}
    state = adamw_init(cfg, params)
    grads_seq = []
    p = params
    for i in range(5):
        g = {k: jax.random.normal(jax.random.fold_in(key, 10 + i), v.shape)
             for k, v in p.items()}
        grads_seq.append({k: np.asarray(v) for k, v in g.items()})
        p, state, info = adamw_update(cfg, constant(1e-2), p, g, state)
    ref = _numpy_adamw({k: np.asarray(v) for k, v in params.items()},
                       grads_seq, 1e-2, cfg)
    for k in params:
        assert np.allclose(np.asarray(p[k]), ref[k], atol=1e-5), k


def test_int8_moments_close_to_f32():
    key = jax.random.PRNGKey(2)
    params = {"w": jax.random.normal(key, (32, 16), jnp.bfloat16)}
    out = {}
    for md in ("f32", "int8"):
        cfg = OptConfig(moment_dtype=md, weight_decay=0.0)
        state = adamw_init(cfg, params)
        p = params
        for i in range(8):
            g = {"w": jax.random.normal(jax.random.fold_in(key, i), (32, 16))}
            p, state, _ = adamw_update(cfg, constant(5e-3), p, g, state)
        out[md] = np.asarray(p["w"], np.float32)
    denom = np.maximum(np.abs(out["f32"]), 1e-3)
    assert np.median(np.abs(out["int8"] - out["f32"]) / denom) < 0.15


def test_quantize_roundtrip_error_bound():
    x = np.random.default_rng(0).normal(size=(64, 32)).astype(np.float32)
    q = _quantize(jnp.asarray(x))
    back = np.asarray(_dequantize(q))
    scale = np.max(np.abs(x), axis=-1, keepdims=True) / 127.0
    assert np.all(np.abs(back - x) <= scale * 0.5 + 1e-8)


def test_master_weights_preserve_precision():
    """bf16 params + fp32 master: tiny updates must not be lost to rounding."""
    cfg = OptConfig(weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.ones((4,), jnp.bfloat16) * 100.0}
    state = adamw_init(cfg, params)
    p = params
    for _ in range(10):
        g = {"w": jnp.ones((4,), jnp.float32)}
        p, state, _ = adamw_update(cfg, constant(1e-3), p, g, state)
    # master accumulated 10 * ~1e-3 even though each step underflows bf16@100
    assert float(state["master"]["w"][0]) < 100.0 - 5e-3


def test_schedules():
    lr = warmup_cosine(1.0, warmup=10, total=110, min_ratio=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(110)) == pytest.approx(0.1, abs=1e-6)
    w = wsd(1.0, warmup=10, stable=80, decay=20, min_ratio=0.1)
    assert float(w(5)) == pytest.approx(0.5)
    assert float(w(50)) == pytest.approx(1.0)        # stable plateau
    assert float(w(110)) == pytest.approx(0.1, rel=1e-3)
    assert float(global_norm({"a": jnp.ones((3,)) * 2.0})) == pytest.approx(
        np.sqrt(12.0))
