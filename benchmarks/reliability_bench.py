"""Benchmark: reliability and tail latency (DESIGN.md §2.8).

The paper's drive is fresh silicon; a deployed drive spends most of its
life worn.  This section measures what the reliability layer adds on
top of the request-level serving model: the p99/p99.9-vs-offered-load
curves of a worn drive (with and without hedged reads), the
p99-vs-wear degradation curve, and the degraded-mode bandwidth /
remap-op accounting under program faults.

Three gates run even under ``--smoke``:

* **faulty cross-engine agreement** — scan / prefix / pallas /
  streaming must agree < 1e-3 with the oracle on a fault-extended
  trace (the surcharge threads five independent implementations of the
  recurrence);
* **hedged p99 win** — under the frozen retry-storm configuration
  (~3% of reads draw a >= 500 us retry ladder), hedging every read
  must cut the p99 request latency, not just move it;
* **monotone degradation** — p99 must be non-decreasing in wear.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import (FaultSpec, Simulator, SSDConfig, apply_faults,
                       poisson_stream)
from repro.core.nand import CellType
from repro.core.trace import mixed_trace
from repro.core.sim_ref import simulate_trace_ref

# The frozen retry-storm gate configuration (tests/test_faults.py pins
# the same numbers): at wear 1.0, p_retry = 3e-5/1e-3 = 3% of reads
# draw a geometric retry ladder starting at 500 us — rare enough that
# primary+duplicate double-storms (p^2) stay out of the p99, common
# enough that the unhedged p99 IS a storm.
STORM = dict(wear=1.0, rber_worn=3e-5, max_retries=4,
             retry_step_us=(500.0, 1000.0, 2000.0, 4000.0))
STORM_SEED = 7


def _agreement_gate(sim: Simulator, n_ops: int) -> float:
    """Max rel disagreement of every fault-capable engine vs the oracle
    on a fault-extended mixed trace."""
    spec = FaultSpec(wear=0.95, jitter_us=2.0, prog_fail_prob=0.02,
                     seed=17)
    trace, _, _ = apply_faults(
        mixed_trace(n_ops, sim.config.channels, sim.config.ways, 0.7,
                    seed=3),
        spec, sim.table)
    assert np.any(np.asarray(trace.extra_us) > 0.0)
    ref = simulate_trace_ref(sim.table, trace, "eager")
    tol_abs = 1e-3 * trace.n_ops + 1e-5 * ref
    agree = 0.0
    for engine in ("scan", "prefix", "pallas", "streaming"):
        got = sim.run(trace, engine=engine).end_us
        assert abs(got - ref) <= tol_abs, \
            f"{engine} disagrees on faulty trace: {got} vs {ref}"
        agree = max(agree, abs(got - ref) / ref)
    return agree


def run(small: bool = False) -> list[dict]:
    n_req = 200 if small else 1000
    interarrivals = (600.0, 300.0) if small else (900.0, 600.0, 300.0,
                                                  150.0)
    rows: list[dict] = []
    cfg = SSDConfig(cell=CellType.MLC, channels=4, ways=4)
    sim = Simulator.for_config(cfg)

    # --- tail latency vs offered load, worn drive, +- hedging ------------
    worn = FaultSpec(seed=STORM_SEED, **STORM)
    hedged = dataclasses.replace(worn, hedge_fraction=1.0,
                                 hedge_after_us=250.0)
    for ia in interarrivals:
        load = poisson_stream(n_req, ia, seed=2)
        for tag, spec in (("unhedged", worn), ("hedged", hedged)):
            res = sim.run(load, faults=spec)
            rows.append({"name": f"rel/p99_us/ia{ia:g}/{tag}",
                         "value": round(res.p99_us, 1), "paper": "-"})
            rows.append({"name": f"rel/p99_9_us/ia{ia:g}/{tag}",
                         "value": round(res.p99_9_us, 1), "paper": "-"})

    # --- the hedging gate (smoke too): frozen storm seed -----------------
    load = poisson_stream(max(n_req, 400), 600.0, seed=2)
    ru = sim.run(load, faults=worn)
    rh = sim.run(load, faults=hedged)
    assert int(ru.retry_hist[1:].sum()) > 0, "storm seed drew no storms"
    assert rh.p99_us <= ru.p99_us, \
        f"hedged p99 {rh.p99_us} did not beat unhedged {ru.p99_us}"
    rows.append({"name": "rel/hedged_p99_over_unhedged",
                 "value": round(rh.p99_us / ru.p99_us, 4), "paper": "<=1"})

    # --- p99 vs wear (monotone gate, smoke too) --------------------------
    prev = -1.0
    for wear in (0.0, 0.25, 0.5, 0.75, 1.0):
        spec = FaultSpec(seed=STORM_SEED, **{**STORM, "wear": wear})
        res = sim.run(load, faults=spec)
        assert res.p99_us >= prev - 1e-9, \
            f"p99 fell with wear: {res.p99_us} < {prev} at wear {wear}"
        prev = res.p99_us
        rows.append({"name": f"rel/p99_us_vs_wear/{wear:g}",
                     "value": round(res.p99_us, 1), "paper": "-"})

    # --- degraded-mode bandwidth + remap accounting ----------------------
    t = mixed_trace(2000 if small else 20000, 4, 4, 0.5, seed=9)
    fresh = sim.run(t)
    degraded = sim.run(t, faults=FaultSpec(
        wear=1.0, rber_worn=2e-4, prog_fail_prob=0.01,
        erase_fail_prob=0.05, seed=5))
    assert degraded.n_remap_ops > 0
    assert degraded.end_us >= fresh.end_us
    rows.append({"name": "rel/degraded_over_fresh_mb_s",
                 "value": round(degraded.mb_s / fresh.mb_s, 4),
                 "paper": "<=1"})
    rows.append({"name": "rel/remap_ops_per_kop",
                 "value": round(1e3 * degraded.n_remap_ops / t.n_ops, 2),
                 "paper": "-"})
    rows.append({"name": "rel/retry_reads_per_kop",
                 "value": round(1e3 * int(degraded.retry_hist[1:].sum())
                                / t.n_ops, 2),
                 "paper": "-"})

    # --- faulty cross-engine agreement gate (smoke too) ------------------
    agree = _agreement_gate(sim, 400 if small else 2000)
    rows.append({"name": "rel/faulty_engine_max_rel_disagreement",
                 "value": f"{agree:.1e}", "paper": "<1e-3"})
    return rows
