"""Benchmark harness: one entry per paper table/figure + roofline summary.

    PYTHONPATH=src python -m benchmarks.run

Prints ``name,value,paper_or_derived[,rel_err]`` CSV lines and writes the
roofline markdown table to benchmarks/results/roofline.md.
"""

from __future__ import annotations

import pathlib


def _emit(rows):
    for r in rows:
        err = r.get("rel_err")
        tail = f",{err}" if err is not None else ""
        print(f"{r['name']},{r['value']},{r['paper']}{tail}")


def main() -> None:
    from benchmarks import api_bench, freq, roofline, sweep_bench, tables

    print("# freq (paper §5.2)")
    _emit(freq.run())
    print("# api (Simulator session: cache + run_many + engine agreement)")
    _emit(api_bench.run())
    print("# table3 (paper Table 3 / Fig 8)")
    _emit(tables.run_table3())
    print("# table4 (paper Table 4 / Fig 9)")
    _emit(tables.run_table4())
    print("# table5 (paper Table 5 / Fig 10)")
    _emit(tables.run_table5())
    print("# design-space sweep engines")
    _emit(sweep_bench.run())

    rows = roofline.run()
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"# roofline ({len(ok)} ok cells of {len(rows)}; full table -> "
          f"benchmarks/results/roofline.md)")
    for r in ok:
        print(f"roofline/{r['cell']},{r['roofline_fraction']},"
              f"dominant={r['dominant']}")
    if rows:     # only write a table when dry-run records exist
        out = pathlib.Path(__file__).resolve().parent / "results" / "roofline.md"
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(roofline.markdown_table(rows) + "\n")
        print(f"# wrote {out}")
    else:
        print("# no dry-run records under benchmarks/results/dryrun — "
              "roofline table skipped")


if __name__ == "__main__":
    main()
