"""Benchmark: design-space sweep throughput (paper's simulator, modernised).

The paper evaluates ~60 design points with an RTL co-simulation.  This
framework's contribution is making that sweep a data-parallel tensor
program: we time (a) the plain-Python event loop, (b) the jit+vmap
``lax.scan`` engine, and (c) the (max,+) Pallas kernel in interpret mode
(CPU; on TPU the same kernel runs compiled) over a
channels × ways × interface × cell × mode grid.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, chip
from repro.core.sim import page_op_params, sweep_bandwidth_mb_s
from repro.core.sim_ref import bandwidth_ref_mb_s
from repro.kernels.maxplus.ops import bandwidth_maxplus_mb_s

N_PAGES = 256


def _grid():
    ops, ways = [], []
    for kind in InterfaceKind:
        for cell in CellType:
            for mode in ("read", "write"):
                for w in (1, 2, 4, 8, 16):
                    ops.append(page_op_params(make_interface(kind), chip(cell),
                                              mode, w))
                    ways.append(w)
    return ops, ways


def run() -> list[dict]:
    ops, ways = _grid()
    n = len(ops)

    t0 = time.perf_counter()
    ref = np.array([bandwidth_ref_mb_s(o, w, N_PAGES) for o, w in zip(ops, ways)])
    t_ref = time.perf_counter() - t0

    args = tuple(jnp.array(x, jnp.float32) for x in (
        [o.cmd_us for o in ops], [o.pre_us for o in ops],
        [o.slot_us for o in ops], [o.post_lo_us for o in ops],
        [o.post_hi_us for o in ops], [o.data_bytes for o in ops]))
    wv = jnp.array(ways, jnp.int32)
    sweep_bandwidth_mb_s(*args, wv, n_pages=N_PAGES).block_until_ready()  # compile
    t0 = time.perf_counter()
    vm = np.asarray(sweep_bandwidth_mb_s(*args, wv, n_pages=N_PAGES))
    t_vm = time.perf_counter() - t0

    t0 = time.perf_counter()
    mp = bandwidth_maxplus_mb_s(ops, ways, n_pages=N_PAGES)
    t_mp = time.perf_counter() - t0

    assert np.allclose(ref, vm, rtol=1e-3)
    assert np.allclose(ref, mp, rtol=1e-3)
    return [
        {"name": "sweep/python_event_loop_us_per_point",
         "value": round(t_ref / n * 1e6, 1), "paper": "-"},
        {"name": "sweep/jit_vmap_scan_us_per_point",
         "value": round(t_vm / n * 1e6, 1), "paper": "-"},
        {"name": "sweep/maxplus_interpret_us_per_point",
         "value": round(t_mp / n * 1e6, 1),
         "paper": "(compiled Pallas on TPU)"},
        {"name": "sweep/vmap_speedup_vs_python",
         "value": round(t_ref / max(t_vm, 1e-9), 1), "paper": "-"},
    ]
