"""Benchmark: design-space sweep throughput (paper's simulator, modernised).

The paper evaluates ~60 design points with an RTL co-simulation.  This
framework's contribution is making that sweep a data-parallel tensor
program: we time (a) the plain-Python event loop, (b) the jit+vmap
``lax.scan`` engine, (c) the (max,+) Pallas kernel (interpret on CPU;
compiled on TPU — including the scalar-prefetch trace-indexed path),
and (d) the **log-depth engines** (DESIGN.md §2.3) — periodic matrix
squaring for the homogeneous grid and the segmented parallel-prefix
fold for heterogeneous traces — over a channels × ways × interface ×
cell × mode grid and over mixed-workload op traces.  ``run_logdepth``
pushes the trace length to T >= 2048, where the O(log T) engines must
beat the O(T) scan per design point (the speedup rows asserted by
``benchmarks/run_all.py`` / CI).  Every query dispatches through the
``repro.api`` registry/``Simulator`` sessions, so the engine-agreement
gate exercises the unified serving surface (the repeated-query cache
benchmark itself lives in ``benchmarks/api_bench.py``)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Simulator, sweep_steady_bandwidth_mb_s, sweep_tables
from repro.core.energy import breakdown_from_sums
from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, chip
from repro.core.sim import SSDConfig, page_op_params
from repro.core.sim_ref import (bandwidth_ref_mb_s,
                                simulate_trace_energy_ref,
                                trace_bandwidth_ref_mb_s)
from repro.core.trace import mixed_trace
from repro.kernels.maxplus.ops import (bandwidth_maxplus_mb_s,
                                       trace_bandwidth_maxplus_mb_s)

N_PAGES = 256
T_LOGDEPTH = 2048     # acceptance gate: log-depth engines must win here


def _grid():
    ops, ways = [], []
    for kind in InterfaceKind:
        for cell in CellType:
            for mode in ("read", "write"):
                for w in (1, 2, 4, 8, 16):
                    ops.append(page_op_params(make_interface(kind), chip(cell),
                                              mode, w))
                    ways.append(w)
    return ops, ways


def _sweep_args(ops):
    return tuple(jnp.array([getattr(o, f) for o in ops], jnp.float32)
                 for f in ("cmd_us", "pre_us", "slot_us", "post_lo_us",
                           "post_hi_us", "ctrl_us", "data_bytes"))


def run(small: bool = False) -> list[dict]:
    n_pages = 64 if small else N_PAGES
    ops, ways = _grid()
    n = len(ops)

    t0 = time.perf_counter()
    ref = np.array([bandwidth_ref_mb_s(o, w, n_pages) for o, w in zip(ops, ways)])
    t_ref = time.perf_counter() - t0

    args = _sweep_args(ops)
    wv = jnp.array(ways, jnp.int32)
    sweep_steady_bandwidth_mb_s(
        *args, wv, n_pages=n_pages).block_until_ready()           # compile
    t0 = time.perf_counter()
    vm = np.asarray(sweep_steady_bandwidth_mb_s(*args, wv, n_pages=n_pages))
    t_vm = time.perf_counter() - t0

    t0 = time.perf_counter()
    mp = bandwidth_maxplus_mb_s(ops, ways, n_pages=n_pages)
    t_mp = time.perf_counter() - t0

    assert np.allclose(ref, vm, rtol=1e-3)
    assert np.allclose(ref, mp, rtol=1e-3)
    return [
        {"name": "sweep/python_event_loop_us_per_point",
         "value": round(t_ref / n * 1e6, 1), "paper": "-"},
        {"name": "sweep/jit_vmap_scan_us_per_point",
         "value": round(t_vm / n * 1e6, 1), "paper": "-"},
        {"name": "sweep/maxplus_interpret_us_per_point",
         "value": round(t_mp / n * 1e6, 1),
         "paper": "(compiled Pallas on TPU)"},
        {"name": "sweep/vmap_speedup_vs_python",
         "value": round(t_ref / max(t_vm, 1e-9), 1), "paper": "-"},
    ] + run_mixed(small) + run_logdepth(small)


def run_mixed(small: bool = False) -> list[dict]:
    """Mixed-workload design-point sweep (beyond the paper's §5.3 grid):
    read fraction × (channels, ways), all three engines on one trace per
    geometry, batching interfaces×cells through the (max,+) kernel.
    Each point also carries its phase-resolved controller energy
    (DESIGN.md §2.4), gated on cross-engine agreement like the
    bandwidths."""
    n_pages = 64 if small else N_PAGES
    rows, agree, agree_e = [], 0.0, 0.0
    n_points = 0
    t_scan = t_mp = t_ref = 0.0
    for channels, ways in ((1, 8), (2, 4), (4, 8)):
        for read_frac in (1.0, 0.7, 0.5, 0.0):
            tr = mixed_trace(n_pages * channels, channels, ways, read_frac,
                             seed=channels * 100 + int(read_frac * 10))
            cfgs = [SSDConfig(interface=k, cell=c, channels=channels,
                              ways=ways)
                    for k in InterfaceKind for c in CellType]
            sims = [Simulator.for_config(cfg) for cfg in cfgs]
            tables = [s.table for s in sims]
            t0 = time.perf_counter()
            scan_bw = np.array([s.run(tr, objective="bandwidth").mb_s
                                for s in sims])
            t_scan += time.perf_counter() - t0
            t0 = time.perf_counter()
            mp_bw = trace_bandwidth_maxplus_mb_s(tables, tr)
            t_mp += time.perf_counter() - t0
            t0 = time.perf_counter()
            ref_bw = np.array([trace_bandwidth_ref_mb_s(t, tr)
                               for t in tables])
            t_ref += time.perf_counter() - t0
            agree = max(agree,
                        float(np.max(np.abs(scan_bw - ref_bw) / ref_bw)),
                        float(np.max(np.abs(mp_bw - ref_bw) / ref_bw)))
            n_points += len(tables)
            # phase-resolved energy of the PROPOSED/MLC point, all three
            # engines vs the event-loop oracle (heterogeneous-trace half
            # of the energy smoke gate; Table 5 covers the steady half)
            kind = InterfaceKind.PROPOSED
            bds = {eng: sims[-1].run(tr, objective="energy",
                                     engine=eng).energy
                   for eng in ("scan", "prefix", "pallas")}
            end_e, sums_e = simulate_trace_energy_ref(tables[-1], tr, kind)
            ref_bd = breakdown_from_sums(sums_e, end_e,
                                         tr.total_bytes(tables[-1]), kind,
                                         channels=channels)
            agree_e = max(agree_e, *(
                abs(bd.controller_j - ref_bd.controller_j)
                / ref_bd.controller_j for bd in bds.values()))
            name = (f"mixed/{channels}ch{ways}way/"
                    f"read{int(read_frac * 100)}")
            rows.append({"name": f"{name}/proposed_mlc_mb_s",
                         "value": round(float(scan_bw[-1]), 1),
                         "paper": "-"})
            rows.append({"name": f"{name}/proposed_mlc_nj_per_byte",
                         "value": round(bds["scan"].nj_per_byte, 3),
                         "paper": "-",
                         "idle_frac": round(bds["scan"].idle_j
                                            / bds["scan"].controller_j, 4)})
    assert agree < 1e-3, f"engines disagree by {agree:.2e} on mixed traces"
    assert agree_e < 1e-3, \
        f"energy engines disagree by {agree_e:.2e} on mixed traces"
    rows += [
        {"name": "mixed/engine_max_rel_disagreement", "value": f"{agree:.1e}",
         "paper": "<1e-3"},
        {"name": "mixed/energy_engine_max_rel_disagreement",
         "value": f"{agree_e:.1e}", "paper": "<1e-3"},
        {"name": "mixed/scan_us_per_point",
         "value": round(t_scan / n_points * 1e6, 1), "paper": "-"},
        {"name": "mixed/maxplus_interpret_us_per_point",
         "value": round(t_mp / n_points * 1e6, 1), "paper": "-"},
        {"name": "mixed/python_oracle_us_per_point",
         "value": round(t_ref / n_points * 1e6, 1), "paper": "-"},
    ]
    return rows


def run_logdepth(small: bool = False) -> list[dict]:
    """Old-vs-new engine timings at long horizons (DESIGN.md §2.3).

    Homogeneous: the 60-point paper grid at T pages per point, O(T) scan
    vs O(log T) periodic squaring.  Heterogeneous: one mixed trace of T
    ops on a 2ch×8way geometry under interfaces×cells tables, per-point
    scan vs the segmented parallel-prefix engines.  Both speedup rows
    must exceed 1 at T >= 2048 and every engine must agree with the
    python oracle to 1e-3 — ``run_all.py`` (and the CI smoke step)
    asserts both."""
    t_pages = 256 if small else T_LOGDEPTH
    ops, ways = _grid()
    n = len(ops)
    args = _sweep_args(ops)
    wv = jnp.array(ways, jnp.int32)

    def timed(fn, reps=3):
        out = fn()
        out.block_until_ready()                      # compile
        dt = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn().block_until_ready()
            dt = min(dt, time.perf_counter() - t0)
        return np.asarray(out), dt

    scan_bw, t_scan = timed(lambda: sweep_steady_bandwidth_mb_s(
        *args, wv, n_pages=t_pages))
    sq_bw, t_sq = timed(lambda: sweep_steady_bandwidth_mb_s(
        *args, wv, n_pages=t_pages, engine="squaring"))
    agree = float(np.max(np.abs(sq_bw - scan_bw) / scan_bw))
    # python oracle on a few spot points (full grid at this T is slow)
    for i in (0, n // 2, n - 1):
        want = bandwidth_ref_mb_s(ops[i], ways[i], t_pages)
        agree = max(agree, abs(float(sq_bw[i]) - want) / want)
    assert agree < 1e-3, f"squaring disagrees by {agree:.2e} at T={t_pages}"

    rows = [
        {"name": f"logdepth/homog_T{t_pages}/scan_us_per_point",
         "value": round(t_scan / n * 1e6, 1), "paper": "-"},
        {"name": f"logdepth/homog_T{t_pages}/squaring_us_per_point",
         "value": round(t_sq / n * 1e6, 1), "paper": "-"},
        {"name": f"logdepth/homog_T{t_pages}/squaring_speedup_vs_scan",
         "value": round(t_scan / max(t_sq, 1e-9), 2), "paper": ">1"},
        {"name": f"logdepth/homog_T{t_pages}/max_rel_disagreement",
         "value": f"{agree:.1e}", "paper": "<1e-3"},
    ]

    # heterogeneous: one long mixed trace, batch of design-point tables
    channels, ways_h = 2, 8
    tr = mixed_trace(t_pages, channels, ways_h, 0.7, seed=42)
    sims = [Simulator.for_config(SSDConfig(interface=k, cell=c,
                                           channels=channels, ways=ways_h))
            for k in InterfaceKind for c in CellType]
    tables = [s.table for s in sims]
    b = len(tables)
    seg_len = 128

    def timed_np(fn, reps=3):
        out = fn()                                   # compile
        dt = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            dt = min(dt, time.perf_counter() - t0)
        return np.asarray(out), dt

    scan_us, t_scan_h = timed_np(
        lambda: np.array([s.run(tr).end_us for s in sims]))
    scanb_us, t_scanb = timed_np(
        lambda: sweep_tables(tables, tr, engine="scan"))
    px_us, t_px = timed_np(
        lambda: sweep_tables(tables, tr, engine="prefix",
                             segment_len=seg_len))

    from repro.kernels.maxplus.ops import trace_end_time_maxplus
    seg_us, t_seg = timed_np(
        lambda: trace_end_time_maxplus(tables, tr, strategy="segmented"),
        reps=1)                                      # dense: slow on CPU

    from repro.core.sim_ref import simulate_trace_ref
    ref_us = np.array([simulate_trace_ref(t, tr) for t in tables])
    agree_h = max(float(np.max(np.abs(e - ref_us) / ref_us))
                  for e in (scan_us, scanb_us, px_us, seg_us))
    assert agree_h < 1e-3, \
        f"trace engines disagree by {agree_h:.2e} at T={t_pages}"

    rows += [
        {"name": f"logdepth/mixed_T{t_pages}/scan_us_per_point",
         "value": round(t_scan_h / b * 1e6, 1), "paper": "-"},
        {"name": f"logdepth/mixed_T{t_pages}/scan_batch_us_per_point",
         "value": round(t_scanb / b * 1e6, 1), "paper": "-"},
        {"name": f"logdepth/mixed_T{t_pages}/prefix_batch_us_per_point",
         "value": round(t_px / b * 1e6, 1), "paper": "-"},
        {"name": f"logdepth/mixed_T{t_pages}/dense_segmented_us_per_point",
         "value": round(t_seg / b * 1e6, 1),
         "paper": "(MXU-shaped; compiled Pallas batching on TPU)"},
        {"name": f"logdepth/mixed_T{t_pages}/prefix_speedup_vs_scan",
         "value": round(t_scan_h / max(t_px, 1e-9), 2), "paper": ">1"},
        {"name": f"logdepth/mixed_T{t_pages}/prefix_speedup_vs_scan_batch",
         "value": round(t_scanb / max(t_px, 1e-9), 2), "paper": "-"},
        {"name": f"logdepth/mixed_T{t_pages}/max_rel_disagreement",
         "value": f"{agree_h:.1e}", "paper": "<1e-3"},
    ]
    return rows
