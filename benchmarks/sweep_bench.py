"""Benchmark: design-space sweep throughput (paper's simulator, modernised).

The paper evaluates ~60 design points with an RTL co-simulation.  This
framework's contribution is making that sweep a data-parallel tensor
program: we time (a) the plain-Python event loop, (b) the jit+vmap
``lax.scan`` engine, and (c) the (max,+) Pallas kernel in interpret mode
(CPU; on TPU the same kernel runs compiled) over a
channels × ways × interface × cell × mode grid — and, beyond the paper,
over **mixed-workload op traces** (read fraction × geometry grid) that
exercise the shared-controller contention path on all three engines.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.interface import InterfaceKind, make_interface
from repro.core.nand import CellType, chip
from repro.core.sim import SSDConfig, page_op_params, sweep_bandwidth_mb_s
from repro.core.sim_ref import bandwidth_ref_mb_s, trace_bandwidth_ref_mb_s
from repro.core.trace import mixed_trace, op_class_table, trace_bandwidth_mb_s
from repro.kernels.maxplus.ops import (bandwidth_maxplus_mb_s,
                                       trace_bandwidth_maxplus_mb_s)

N_PAGES = 256


def _grid():
    ops, ways = [], []
    for kind in InterfaceKind:
        for cell in CellType:
            for mode in ("read", "write"):
                for w in (1, 2, 4, 8, 16):
                    ops.append(page_op_params(make_interface(kind), chip(cell),
                                              mode, w))
                    ways.append(w)
    return ops, ways


def run() -> list[dict]:
    ops, ways = _grid()
    n = len(ops)

    t0 = time.perf_counter()
    ref = np.array([bandwidth_ref_mb_s(o, w, N_PAGES) for o, w in zip(ops, ways)])
    t_ref = time.perf_counter() - t0

    args = tuple(jnp.array(x, jnp.float32) for x in (
        [o.cmd_us for o in ops], [o.pre_us for o in ops],
        [o.slot_us for o in ops], [o.post_lo_us for o in ops],
        [o.post_hi_us for o in ops], [o.data_bytes for o in ops]))
    wv = jnp.array(ways, jnp.int32)
    sweep_bandwidth_mb_s(*args, wv, n_pages=N_PAGES).block_until_ready()  # compile
    t0 = time.perf_counter()
    vm = np.asarray(sweep_bandwidth_mb_s(*args, wv, n_pages=N_PAGES))
    t_vm = time.perf_counter() - t0

    t0 = time.perf_counter()
    mp = bandwidth_maxplus_mb_s(ops, ways, n_pages=N_PAGES)
    t_mp = time.perf_counter() - t0

    assert np.allclose(ref, vm, rtol=1e-3)
    assert np.allclose(ref, mp, rtol=1e-3)
    return [
        {"name": "sweep/python_event_loop_us_per_point",
         "value": round(t_ref / n * 1e6, 1), "paper": "-"},
        {"name": "sweep/jit_vmap_scan_us_per_point",
         "value": round(t_vm / n * 1e6, 1), "paper": "-"},
        {"name": "sweep/maxplus_interpret_us_per_point",
         "value": round(t_mp / n * 1e6, 1),
         "paper": "(compiled Pallas on TPU)"},
        {"name": "sweep/vmap_speedup_vs_python",
         "value": round(t_ref / max(t_vm, 1e-9), 1), "paper": "-"},
    ] + run_mixed()


def run_mixed() -> list[dict]:
    """Mixed-workload design-point sweep (beyond the paper's §5.3 grid):
    read fraction × (channels, ways), all three engines on one trace per
    geometry, batching interfaces×cells through the (max,+) kernel."""
    rows, agree = [], 0.0
    n_points = 0
    t_scan = t_mp = t_ref = 0.0
    for channels, ways in ((1, 8), (2, 4), (4, 8)):
        for read_frac in (1.0, 0.7, 0.5, 0.0):
            tr = mixed_trace(N_PAGES * channels, channels, ways, read_frac,
                             seed=channels * 100 + int(read_frac * 10))
            cfgs = [SSDConfig(interface=k, cell=c, channels=channels,
                              ways=ways)
                    for k in InterfaceKind for c in CellType]
            tables = [op_class_table(cfg) for cfg in cfgs]
            t0 = time.perf_counter()
            scan_bw = np.array([trace_bandwidth_mb_s(t, tr) for t in tables])
            t_scan += time.perf_counter() - t0
            t0 = time.perf_counter()
            mp_bw = trace_bandwidth_maxplus_mb_s(tables, tr)
            t_mp += time.perf_counter() - t0
            t0 = time.perf_counter()
            ref_bw = np.array([trace_bandwidth_ref_mb_s(t, tr)
                               for t in tables])
            t_ref += time.perf_counter() - t0
            agree = max(agree,
                        float(np.max(np.abs(scan_bw - ref_bw) / ref_bw)),
                        float(np.max(np.abs(mp_bw - ref_bw) / ref_bw)))
            n_points += len(tables)
            rows.append({
                "name": (f"mixed/{channels}ch{ways}way/"
                         f"read{int(read_frac * 100)}"
                         "/proposed_mlc_mb_s"),
                "value": round(float(scan_bw[-1]), 1),
                "paper": "-"})
    assert agree < 1e-3, f"engines disagree by {agree:.2e} on mixed traces"
    rows += [
        {"name": "mixed/engine_max_rel_disagreement", "value": f"{agree:.1e}",
         "paper": "<1e-3"},
        {"name": "mixed/scan_us_per_point",
         "value": round(t_scan / n_points * 1e6, 1), "paper": "-"},
        {"name": "mixed/maxplus_interpret_us_per_point",
         "value": round(t_mp / n_points * 1e6, 1), "paper": "-"},
        {"name": "mixed/python_oracle_us_per_point",
         "value": round(t_ref / n_points * 1e6, 1), "paper": "-"},
    ]
    return rows
