"""Benchmark: fleet-scale simulation throughput (DESIGN.md §2.7).

Three scaling axes of the serving path are measured and gated:

* **fused megakernel** — ``Simulator.run_many(engine="pallas")``
  evaluates a whole fleet of heterogeneous traces as ONE Pallas launch
  (lanes = traces, union combo dictionary, identity-padded lengths);
  it must beat the per-trace launch loop (the pre-fusion serving path,
  one ``pallas_call`` per trace) by >= 2x at T = 2048 in a full run,
  and agree with the scan engine < 1e-3 always (smoke included);
* **streaming engine** — ``Simulator.run_stream`` folds a >= 1M-op
  generated trace in fixed-size chunks with the occupancy state carried
  between chunks; the full run asserts the Python-side peak allocation
  is set by the chunk size, not the trace length (flat across a 4x
  longer trace, and well under materialising it), and every run asserts
  < 1e-3 agreement with the event-loop oracle on an overlapping size;
* **shard_map sweeps** — a subprocess with a forced 8-device host
  platform times the design-point sweep with the table batch sharded
  across devices vs the single-device vmap path and asserts bit-equal
  results (wall-clock scaling on a shared-core CPU host is reported,
  not gated — the devices share the same silicon).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
import tracemalloc

import numpy as np

from repro.api import Simulator, SSDConfig
from repro.core.nand import CellType
from repro.core.sim_ref import simulate_trace_ref
from repro.core.trace import mixed_trace, mixed_trace_chunks

T_FLEET = 2048        # acceptance gate: megakernel must win here
N_FLEET = 24
N_STREAM = 1_000_000  # acceptance gate: million-op trace, constant memory
CHUNK = 32_768


def _rel(a: float, b: float) -> float:
    return abs(a - b) / abs(b)


def run_megakernel(small: bool = False) -> list[dict]:
    t_ops = 256 if small else T_FLEET
    n_fleet = 6 if small else N_FLEET
    cfg = SSDConfig(cell=CellType.MLC, channels=2, ways=8)
    sim = Simulator(cfg)
    fleet = [mixed_trace(t_ops, 2, 8, read_fraction=0.5, seed=i)
             for i in range(n_fleet)]

    from repro.kernels.maxplus.ops import (run_many_end_time_maxplus,
                                           trace_end_time_maxplus)

    def per_trace():
        return [float(trace_end_time_maxplus(sim.table, t)) for t in fleet]

    def fused():
        return run_many_end_time_maxplus(sim.table, fleet)

    loop_ends = per_trace()            # warm both compiled shapes
    fused_ends = fused()
    t0 = time.perf_counter()
    loop_ends = per_trace()
    t_loop = time.perf_counter() - t0
    t0 = time.perf_counter()
    fused_ends = fused()
    t_fused = time.perf_counter() - t0

    scan_ends = [r.end_us for r in sim.run_many(fleet)]
    agree = max(max(_rel(a, s) for a, s in zip(loop_ends, scan_ends)),
                max(_rel(a, s) for a, s in zip(fused_ends, scan_ends)))
    assert agree < 1e-3, \
        f"megakernel disagrees with scan by {agree:.2e} at T={t_ops}"
    speedup = t_loop / max(t_fused, 1e-9)
    if not small:
        assert speedup >= 2.0, \
            f"megakernel speedup {speedup:.2f}x < 2x over per-trace " \
            f"launches (fleet={n_fleet}, T={t_ops})"
    return [
        {"name": f"scale/megakernel_T{t_ops}_B{n_fleet}/per_trace_ms",
         "value": round(t_loop * 1e3, 1), "paper": "-"},
        {"name": f"scale/megakernel_T{t_ops}_B{n_fleet}/fused_ms",
         "value": round(t_fused * 1e3, 1), "paper": "-"},
        {"name": f"scale/megakernel_T{t_ops}_B{n_fleet}/speedup",
         "value": round(speedup, 1), "paper": ">=2"},
        {"name": "scale/megakernel_vs_scan_rel",
         "value": f"{agree:.1e}", "paper": "<1e-3"},
    ]


def run_streaming(small: bool = False) -> list[dict]:
    n_ops = 65_536 if small else N_STREAM
    chunk = 8_192 if small else CHUNK
    cfg = SSDConfig(cell=CellType.MLC, channels=4, ways=8)
    sim = Simulator(cfg)

    # warm the chunk-shape closures outside the traced windows
    sim.run_stream(mixed_trace_chunks(2 * chunk, 4, 8, 0.5,
                                      chunk_len=chunk, seed=1))

    def peak_of(n):
        tracemalloc.start()
        t0 = time.perf_counter()
        res = sim.run_stream(mixed_trace_chunks(n, 4, 8, 0.5,
                                                chunk_len=chunk, seed=2))
        dt = time.perf_counter() - t0
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        assert res.n_ops == n
        return peak / 1e6, dt

    peak_small_mb, _ = peak_of(n_ops // 4)
    peak_mb, t_stream = peak_of(n_ops)
    # constant memory = the peak is set by the chunk size, not the trace
    # length: quadrupling the op count must leave the Python-side peak
    # essentially flat (and far below materialising the ~6 int32/float32
    # columns of the whole trace)
    full_mb = n_ops * 6 * 4 / 1e6
    if not small:
        assert peak_mb < 1.5 * peak_small_mb + 1.0, \
            f"streaming peak grew {peak_small_mb:.1f} -> {peak_mb:.1f} MB " \
            f"over a 4x longer trace — not constant-memory"
        assert peak_mb < full_mb / 2, \
            f"streaming peak {peak_mb:.1f} MB vs full trace {full_mb:.1f} " \
            f"MB — not constant-memory"

    # overlapping-size agreement vs the event-loop oracle (always gated)
    t_small = 512 if small else 4096
    probe = mixed_trace(t_small, 4, 8, 0.5, seed=3)
    want = simulate_trace_ref(sim.table, probe, "eager")
    got = sim.run(probe, engine="streaming").end_us
    agree = _rel(got, want)
    assert agree < 1e-3, \
        f"streaming disagrees with oracle by {agree:.2e} at T={t_small}"
    return [
        {"name": f"scale/stream_{n_ops}ops/wall_s",
         "value": round(t_stream, 2), "paper": "-"},
        {"name": f"scale/stream_{n_ops}ops/ops_per_s",
         "value": int(n_ops / t_stream), "paper": "-"},
        {"name": f"scale/stream_{n_ops}ops/py_peak_mb",
         "value": round(peak_mb, 1),
         "paper": f"<{full_mb / 2:.0f}" if not small else "-"},
        {"name": f"scale/stream_{n_ops // 4}ops/py_peak_mb",
         "value": round(peak_small_mb, 1), "paper": "-"},
        {"name": "scale/stream_vs_oracle_rel",
         "value": f"{agree:.1e}", "paper": "<1e-3"},
    ]


def run_shard(small: bool = False) -> list[dict]:
    """Forced 8-device subprocess: sharded sweep == vmap sweep, timed."""
    b = 16 if small else 64
    t_ops = 128 if small else 512
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import time
        import numpy as np, jax
        import repro.api as api
        from repro.core.nand import CellType
        from repro.core.sim import SSDConfig
        from repro.core.trace import mixed_trace

        sim = api.Simulator(SSDConfig(cell=CellType.MLC, channels=2,
                                      ways=8))
        trace = mixed_trace({t_ops}, 2, 8, read_fraction=0.5, seed=5)
        tabs = [sim.table] * {b}
        for shard in (True, False):          # warm both compiled paths
            api.sweep_tables(tabs, trace, engine="scan", shard=shard)
        t0 = time.perf_counter()
        a = np.asarray(api.sweep_tables(tabs, trace, engine="scan",
                                        shard=True))
        t_shard = time.perf_counter() - t0
        t0 = time.perf_counter()
        v = np.asarray(api.sweep_tables(tabs, trace, engine="scan",
                                        shard=False))
        t_vmap = time.perf_counter() - t0
        assert np.array_equal(a, v), "shard_map != vmap"
        print("SHARD_ROWS", len(jax.devices()), t_shard, t_vmap)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("SHARD_ROWS")]
    assert line, f"sharded sweep subprocess failed:\n{r.stdout}{r.stderr}"
    _, n_dev, t_shard, t_vmap = line[0].split()
    return [
        {"name": f"scale/shard_sweep_B{b}_T{t_ops}/devices",
         "value": int(n_dev), "paper": "8"},
        {"name": f"scale/shard_sweep_B{b}_T{t_ops}/shard_map_ms",
         "value": round(float(t_shard) * 1e3, 1), "paper": "-"},
        {"name": f"scale/shard_sweep_B{b}_T{t_ops}/vmap_ms",
         "value": round(float(t_vmap) * 1e3, 1), "paper": "-"},
        {"name": f"scale/shard_sweep_B{b}_T{t_ops}/agreement",
         "value": "bit-equal", "paper": "="},
    ]


def run(small: bool = False) -> list[dict]:
    return (run_megakernel(small) + run_streaming(small)
            + run_shard(small))
