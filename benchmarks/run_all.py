"""Run every benchmark module and emit a machine-readable BENCH_<n>.json.

    PYTHONPATH=src python benchmarks/run_all.py [--smoke] [--out DIR]

Each run writes ``benchmarks/results/BENCH_<n>.json`` (n = one past the
highest existing index) holding every benchmark row (name/value/paper),
per-section wall time, and the environment — so the perf trajectory of
the engines is tracked across PRs by diffing the JSON files.

``--smoke`` shrinks trace lengths for CI: it still executes every
engine and **fails on engine disagreement** — on end times (the
``assert agree < 1e-3`` paths inside ``sweep_bench``), on the
phase-resolved Table 5 / mixed-trace energy totals (the matching
asserts in ``tables.run_table5`` and ``sweep_bench.run_mixed``), and
on the fleet-scale paths (``scale_bench``: streaming vs oracle,
megakernel vs scan, sharded sweep == vmap), and on the FTL stage
(``ftl_bench``: greedy WAF vs the analytic fixed point, the aging
bandwidth cliff, GC-translated engine agreement) — and, in a full
(non-smoke) run only, on a log-depth speedup < 1, a megakernel
speedup < 2x, or a non-constant-memory streaming fold.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import platform
import re
import sys
import time
import warnings

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def _next_index(out_dir: pathlib.Path) -> int:
    taken = [int(m.group(1))
             for f in out_dir.glob("BENCH_*.json")
             if (m := re.fullmatch(r"BENCH_(\d+)\.json", f.name))]
    return max(taken, default=0) + 1


def _section(name, fn):
    t0 = time.perf_counter()
    rows = fn()
    dt = round(time.perf_counter() - t0, 3)
    print(f"# {name}: {len(rows)} rows in {dt:.1f}s")
    for r in rows:
        print(f"{r['name']},{r['value']},{r['paper']}")
    return {"name": name, "rows": rows, "wall_s": dt}


def _check_speedups(sections, smoke: bool) -> None:
    """The acceptance gates: log-depth engines must beat the O(T) scan
    per design point at the full T (speedup rows > 1; smoke runs at
    reduced T only warn — short traces are overhead-dominated), and the
    ``Simulator`` session cache must serve a repeated identical query
    >= 5x faster than the cold first query (gated even under --smoke:
    cold-vs-warm is compile-dominated, so the ratio is size-robust)."""
    bad, bad_smoke = [], []
    for sec in sections:
        for r in sec["rows"]:
            if r["name"].endswith("_speedup_vs_scan") and r["paper"] == ">1":
                if float(r["value"]) <= 1.0:
                    bad_smoke.append(f"{r['name']} = {r['value']} (want > 1)")
            if r["paper"] == ">=5" and float(r["value"]) < 5.0:
                bad.append(f"{r['name']} = {r['value']} (want >= 5)")
    if bad_smoke:
        msg = "speedup gate rows failed: " + "; ".join(bad_smoke)
        if smoke:
            print(f"# WARNING (smoke sizes, not gating): {msg}")
        else:
            bad += bad_smoke
    if bad:
        raise AssertionError("speedup gate rows failed: " + "; ".join(bad))


def main() -> None:
    # repro-internal code may never reach its own deprecated query shims
    # (DESIGN.md §2.5); the module field keys on the *calling* module.
    # Programmatic because `python -W` re.escapes the module field into
    # an exact match (pytest gets the same rule from pytest.ini).
    warnings.filterwarnings("error", category=DeprecationWarning,
                            module=r"repro\.")
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI; still checks engine "
                         "agreement")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="output dir for BENCH_<n>.json (default: the "
                         "tracked results dir; smoke runs default to a "
                         "temp dir so reduced-size datapoints never "
                         "pollute the cross-PR trajectory)")
    ap.add_argument("--index", type=int, default=None,
                    help="force the BENCH_<n>.json index (default: one "
                         "past the highest existing; use to align the "
                         "committed file with the PR number)")
    args = ap.parse_args()
    if args.out is None:
        if args.smoke:
            import tempfile
            args.out = pathlib.Path(tempfile.mkdtemp(prefix="bench_smoke_"))
        else:
            args.out = RESULTS

    import jax

    from benchmarks import (api_bench, freq, ftl_bench, reliability_bench,
                            roofline, scale_bench, sched_bench, sweep_bench,
                            tables)

    t0 = time.perf_counter()
    sections = [
        _section("freq", freq.run),
        # Simulator session serving path: repeated-query cache speedup,
        # run_many bucket packing, all-five-engine agreement through the
        # unified surface (runs first so its compile shapes are cold)
        _section("api", lambda: api_bench.run(small=args.smoke)),
        _section("table3", tables.run_table3),
        _section("table4", tables.run_table4),
        # trace-level phase-resolved energy; asserts < 1e-3 cross-engine
        # agreement on every cell (the energy half of the smoke gate)
        _section("table5", lambda: tables.run_table5(small=args.smoke)),
        _section("table5_closed_form", tables.run_table5_closed_form),
        _section("sweep", lambda: sweep_bench.run(small=args.smoke)),
        # latency under load: p99-vs-offered-load curves per way count;
        # gates (smoke too): arrival-aware cross-engine agreement and
        # dynamic-dispatch-vs-static-stripe end-time/p99 sanity
        _section("sched", lambda: sched_bench.run(small=args.smoke)),
        # fleet-scale paths (DESIGN.md §2.7); gates: streaming/megakernel
        # cross-engine agreement < 1e-3 + sharded==vmap (smoke too);
        # megakernel >= 2x over per-trace launches and million-op
        # constant-memory streaming in full runs only
        _section("scale", lambda: scale_bench.run(small=args.smoke)),
        # reliability + tail latency (DESIGN.md §2.8); gates (smoke too):
        # faulty-trace cross-engine agreement < 1e-3, hedged p99 <=
        # unhedged under the frozen retry-storm seed, p99 monotone in wear
        _section("reliability",
                 lambda: reliability_bench.run(small=args.smoke)),
        # FTL aging + garbage collection (DESIGN.md §2.10/§2.11); gates
        # (smoke too): greedy WAF within 10% of the analytic fixed point
        # at every overprovisioning ratio, aged < fresh bandwidth
        # whenever GC ran, GC-translated cross-engine agreement < 1e-3,
        # scan translation op-for-op identical to the host oracle; full
        # runs additionally gate the >= 5x fused aged-sweep speedup
        _section("ftl", lambda: ftl_bench.run(small=args.smoke)),
    ]
    _check_speedups(sections, args.smoke)

    roof = roofline.run()
    ok = [r for r in roof if r["status"] == "ok"]
    print(f"# roofline: {len(ok)} ok cells of {len(roof)}")
    if roof:
        out = args.out / "roofline.md"
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(roofline.markdown_table(roof) + "\n")
        print(f"# wrote {out}")

    args.out.mkdir(parents=True, exist_ok=True)
    n = args.index if args.index is not None else _next_index(args.out)
    payload = {
        "bench_index": n,
        "smoke": args.smoke,
        "wall_s_total": round(time.perf_counter() - t0, 3),
        "env": {"backend": jax.default_backend(),
                "jax": jax.__version__,
                "python": platform.python_version(),
                "machine": platform.machine()},
        "sections": sections,
        "roofline_ok_cells": len(ok),
    }
    path = args.out / f"BENCH_{n}.json"
    path.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"# wrote {path} ({payload['wall_s_total']}s total)")


if __name__ == "__main__":
    main()
